"""Paper §5.1 / Fig. 1 reproduction: distributed logistic regression over the
ring topology, iid and non-iid, all five algorithms.

    PYTHONPATH=src python examples/logistic_regression.py --n 20 --steps 1500
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import simulate
from repro.data import make_logistic_problem


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20)
    ap.add_argument("--steps", type=int, default=800)
    ap.add_argument("--H", type=int, default=16)
    ap.add_argument("--iid", action="store_true")
    ap.add_argument("--topology", default="ring",
                    choices=["ring", "grid", "exp", "one_peer_exp"])
    args = ap.parse_args()

    prob = make_logistic_problem(n=args.n, M=2000, d=10, iid=args.iid)
    lr = lambda k: 0.2 * 0.5 ** (k // 1000)   # paper §5.1

    print(f"n={args.n} topology={args.topology} "
          f"{'iid' if args.iid else 'non-iid'} H={args.H}")
    print(f"{'iter':>6s} " + " ".join(f"{a:>12s}" for a in
          ["parallel", "gossip", "local", "gossip_pga", "gossip_aga"]))

    outs = {}
    for alg in ["parallel", "gossip", "local", "gossip_pga", "gossip_aga"]:
        outs[alg] = simulate(
            algorithm=alg, grad_fn=prob.grad_fn(batch=8),
            loss_fn=prob.loss_fn(), x0=jnp.zeros(prob.d), n=prob.n,
            steps=args.steps, lr=lr, topology=args.topology, H=args.H,
            eval_every=max(args.steps // 10, 1), seed=0)

    its = outs["parallel"]["iteration"]
    for i, it in enumerate(its):
        row = " ".join(f"{outs[a]['loss'][i]:12.5f}" for a in outs)
        print(f"{it:6d} {row}")

    print("\nconsensus ‖x−x̄‖²/n at the end:")
    for a, o in outs.items():
        print(f"  {a:12s} {o['consensus'][-1]:.3e}")


if __name__ == "__main__":
    main()
