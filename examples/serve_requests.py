"""Batched serving example: continuous batching over a queue of requests with
a KV-cached decode loop (greedy).

    PYTHONPATH=src python examples/serve_requests.py --requests 6 --slots 2
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_model_config
from repro.models import make_model
from repro.serve import BatchedServer, Engine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="pga-lm-100m")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--s-max", type=int, default=64)
    args = ap.parse_args()

    cfg = get_model_config(args.arch, reduced=True)
    model = make_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, s_max=args.s_max)
    server = BatchedServer(engine, params, n_slots=args.slots)

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=rng.integers(4, 12)),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    done = server.run(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(r.generated) for r in done)
    print(f"served {len(done)} requests / {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s on CPU, {args.slots} slots)")
    for r in sorted(done, key=lambda r: r.uid):
        print(f"  req {r.uid}: prompt[{len(r.prompt)}] -> {r.generated}")


if __name__ == "__main__":
    main()
