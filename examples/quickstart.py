"""Quickstart: train a small LM with Gossip-PGA on 8 simulated nodes and
compare against Gossip SGD and Local SGD.

    PYTHONPATH=src python examples/quickstart.py [--steps 40]
"""
import argparse

import jax

from repro.configs import (DataConfig, DistConfig, OptimizerConfig,
                           TrainConfig, get_model_config)
from repro.train import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--nodes", type=int, default=8)
    args = ap.parse_args()

    cfg = get_model_config("pga-lm-100m", reduced=True)
    results = {}
    for algorithm in ("gossip", "local", "gossip_pga"):
        tcfg = TrainConfig(
            model=cfg,
            dist=DistConfig(algorithm=algorithm, topology="ring", H=6),
            optimizer=OptimizerConfig(name="adamw", lr=3e-3,
                                      schedule="constant", warmup_steps=5),
            data=DataConfig(non_iid=True),
            global_batch=16, seq_len=64, log_every=10)
        tr = Trainer(tcfg, n_nodes=args.nodes, with_consensus=True)
        state = tr.init_state(jax.random.PRNGKey(0))
        tr.run(state, steps=args.steps)
        results[algorithm] = tr.history[-1]

    print("\n=== final metrics (non-iid ring, H=6) ===")
    for alg, rec in results.items():
        print(f"{alg:12s} loss={rec['loss']:.4f} "
              f"consensus={rec['consensus']:.3e}")
    print("\nExpected: gossip_pga reaches the lowest loss with the lowest "
          "consensus error — the paper's §4 intuition at toy scale.")


if __name__ == "__main__":
    main()
