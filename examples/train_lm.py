"""End-to-end training driver: a ~100M-parameter GPT-style LM trained with
Gossip-PGA on simulated nodes (synthetic non-iid stream, AdamW, cosine LR,
checkpointing).

Default is a CPU-sized run (reduced model, a few dozen steps).  ``--full``
trains the real pga-lm-100m config (12L/768d/32k vocab ≈ 110M params) for a
few hundred steps — expect tens of minutes on this single-core container.

    PYTHONPATH=src python examples/train_lm.py --steps 60
    PYTHONPATH=src python examples/train_lm.py --full --steps 200
"""
import argparse

import jax

from repro.checkpoint import latest_step, restore_checkpoint
from repro.configs import (DataConfig, DistConfig, OptimizerConfig,
                           TrainConfig, get_model_config)
from repro.train import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="the real ~100M-param config")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--algorithm", default="gossip_pga")
    ap.add_argument("--topology", default="one_peer_exp")
    ap.add_argument("--H", type=int, default=6)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_model_config("pga-lm-100m", reduced=not args.full)
    seq = args.seq_len or (256 if args.full else 64)
    gb = args.global_batch or (args.nodes * 2)
    tcfg = TrainConfig(
        model=cfg,
        dist=DistConfig(algorithm=args.algorithm, topology=args.topology,
                        H=args.H),
        optimizer=OptimizerConfig(name="adamw", lr=3e-4 if args.full else 3e-3,
                                  schedule="warmup_cosine", warmup_steps=20,
                                  total_steps=args.steps, grad_clip=1.0,
                                  weight_decay=0.01),
        data=DataConfig(non_iid=True),
        global_batch=gb, seq_len=seq, steps=args.steps,
        log_every=max(args.steps // 20, 1),
        ckpt_every=max(args.steps // 2, 1), ckpt_dir=args.ckpt_dir)

    from repro.models import make_model
    n_params_est = sum(p.size for p in jax.tree.leaves(
        jax.eval_shape(lambda k: make_model(cfg).init(k)[0],
                       jax.random.PRNGKey(0))))
    print(f"model {cfg.name}: ~{n_params_est/1e6:.1f}M params, "
          f"{args.nodes} nodes, {args.algorithm}/{args.topology} H={args.H}")

    tr = Trainer(tcfg, n_nodes=args.nodes, with_consensus=True)
    state = tr.init_state(jax.random.PRNGKey(0))
    if args.resume and latest_step(args.ckpt_dir):
        state = restore_checkpoint(args.ckpt_dir, state)
        print(f"resumed from step {int(state.step)}")
    state = tr.run(state, steps=args.steps)
    first, last = tr.history[0], tr.history[-1]
    print(f"\nloss {first['loss']:.4f} -> {last['loss']:.4f} over "
          f"{args.steps} steps; final consensus {last['consensus']:.3e}")


if __name__ == "__main__":
    main()
