"""Paper Tables 8 & 15 — averaging-period sweep + SlowMo comparison, on real
LM training (reduced model, synthetic non-iid stream).

Table 15: Gossip-PGA accuracy vs H (moderate H ~ parallel; H→large degrades
toward Gossip).  Table 8: SlowMo (β=0.5) vs Gossip-PGA at small/large H.
"""
from __future__ import annotations

import argparse

import jax

from benchmarks.common import emit
from repro.configs import (DataConfig, DistConfig, OptimizerConfig,
                           TrainConfig, get_model_config)
from repro.train import Trainer


def train_once(algorithm: str, H: int, steps: int, *, slowmo_beta=0.5,
               n_nodes=8, seed=0) -> float:
    cfg = get_model_config("pga-lm-100m", reduced=True)
    tcfg = TrainConfig(
        model=cfg,
        dist=DistConfig(algorithm=algorithm, topology="ring", H=H,
                        slowmo_beta=slowmo_beta),
        optimizer=OptimizerConfig(name="adamw", lr=3e-3, schedule="constant",
                                  warmup_steps=5, grad_clip=1.0),
        data=DataConfig(non_iid=True), global_batch=16, seq_len=64,
        log_every=0)
    tr = Trainer(tcfg, n_nodes=n_nodes)
    state = tr.init_state(jax.random.PRNGKey(seed))
    tr.run(state, steps=steps, log_every=steps - 1)
    return tr.history[-1]["loss"]


def main(steps: int = 60) -> None:
    # Table 15: period sweep
    losses = {}
    for H in (3, 6, 12, 24):
        losses[H] = train_once("gossip_pga", H, steps)
        emit(f"table15_pga_H{H}_final_loss", losses[H], f"steps={steps}")
    base = train_once("gossip", 6, steps)
    emit("table15_gossip_final_loss", base, "H=inf reference")
    emit("table15_moderate_H_beats_gossip",
         float(min(losses.values()) <= base + 1e-6),
         f"best_pga={min(losses.values()):.4f} gossip={base:.4f}")

    # Table 8: SlowMo vs PGA
    for H in (6, 24):
        pga = losses.get(H) or train_once("gossip_pga", H, steps)
        slowmo = train_once("slowmo", H, steps, slowmo_beta=0.5)
        emit(f"table8_H{H}_pga_loss", pga)
        emit(f"table8_H{H}_slowmo_loss", slowmo)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    main(steps=ap.parse_args().steps)
