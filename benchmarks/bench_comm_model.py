"""Paper Tables 1, 7, 11, 17 / App. H — communication-time model + measured
structural proxy.

(a) α-β model of per-iteration communication for ResNet-50 (25.5M params) and
    BERT-Large (330M params): gossip vs All-Reduce vs PGA-amortized — the
    ratios behind the paper's 1.3–1.9× wall-clock speedups.
(b) Measured CPU proxy: wall time of one roll-mixing step vs one global
    average on a stacked parameter pytree (structure, not absolute speed).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import mixing, topology as topo

ALPHA = 50e-6
MODELS = {"resnet50": 25.5e6, "bert_large": 330e6}
BANDWIDTH = 3.125e9          # 25 Gbps TCP (paper's cluster), bytes/s


def alpha_beta_times(d_params: float, n: int = 32, H: int = 6):
    theta_d = d_params * 4 / BANDWIDTH
    allreduce = 2 * theta_d + n * ALPHA
    gossip = 3 * theta_d + ALPHA          # ring |N_i| = 3
    one_peer = 1 * theta_d + ALPHA        # one-peer exp: single neighbor
    pga = one_peer + allreduce / H
    return {"allreduce": allreduce, "gossip_ring": gossip,
            "gossip_one_peer": one_peer, "gossip_pga_H6": pga}


# representative fwd+bwd per-iteration compute (paper's V100 cluster,
# order-of-magnitude — the overlap model only needs the comm/compute ratio)
COMPUTE_S = {"resnet50": 0.120, "bert_large": 0.400}


def overlapped_iteration_times(d_params: float, t_comp: float,
                               n: int = 32, H: int = 6):
    """Per-iteration α-β wall clock, synchronous vs pipelined
    (DESIGN.md §2.6).  Synchronous: compute and the gossip round are
    serial, ``t_comp + t_gossip``.  Overlapped: the round of step t rides
    under the compute of step t+1, so the steady-state iteration costs
    ``max(t_comp, t_gossip)`` — communication is fully hidden once
    ``t_comp ≥ t_gossip``.  The PGA flush every H steps stays synchronous
    (the period boundary drains the pipeline), so its all-reduce is
    additive in both modes at amortized ``allreduce / H``."""
    t = alpha_beta_times(d_params, n, H)
    comm = t["gossip_one_peer"]
    flush = t["allreduce"] / H
    sync = t_comp + comm + flush
    overlapped = max(t_comp, comm) + flush
    return {"sync": sync, "overlap": overlapped,
            "speedup": sync / overlapped,
            "hidden_frac": min(t_comp, comm) / comm}


def push_sum_round_time(d_params: float, topology: str, n: int,
                        n_dropped: int = 0) -> float:
    """α-β time of one push-sum gossip round: wire traffic is the
    *off-diagonal* nnz of the column-stochastic W (each entry is one
    directed point-to-point message of the full parameter vector; the
    diagonal is local).  Dropped nodes send nothing — their column is
    e_j — and survivors renormalize over fewer receivers, so the dropped
    round is strictly cheaper on the wire while the de-biased average
    stays exact (DESIGN.md §2.5)."""
    active = np.ones(n, dtype=bool)
    active[:n_dropped] = False
    W = topo.push_sum_matrix(topology, n, active=active)
    msgs = int(np.count_nonzero(W - np.diag(np.diag(W))))
    theta_d = d_params * 4 / BANDWIDTH
    # per-node critical path: the busiest sender's message count
    per_node = max(int(np.count_nonzero(col)) - 1 for col in W.T)
    return per_node * theta_d + ALPHA, msgs


def main() -> None:
    # --- (a) analytic, reproducing App. H / Table 17 structure -------------
    for name, d in MODELS.items():
        t = alpha_beta_times(d)
        for k, v in t.items():
            emit(f"table17_{name}_{k}_ms", v * 1e3)
        emit(f"table17_{name}_pga_vs_allreduce_speedup",
             t["allreduce"] / t["gossip_pga_H6"],
             "paper measures 1.3-1.9x end-to-end")
        # paper App H measured (one-peer exp graph): ResNet-50 gossip 150ms
        # vs AllReduce 278ms (~1.85x); BERT 566ms vs 1469ms (~2.6x)
        emit(f"table17_{name}_gossip_vs_allreduce_ratio",
             t["allreduce"] / t["gossip_one_peer"],
             "paper measured ~1.85x (ResNet50), ~2.6x (BERT)")

    # --- overlapped iteration model (DESIGN.md §2.6) -----------------------
    for name, d in MODELS.items():
        o = overlapped_iteration_times(d, COMPUTE_S[name])
        emit(f"overlap_{name}_sync_iter_ms", o["sync"] * 1e3)
        emit(f"overlap_{name}_overlap_iter_ms", o["overlap"] * 1e3,
             f"{o['hidden_frac'] * 100:.0f}% of gossip hidden")
        emit(f"overlap_{name}_speedup", o["speedup"],
             "max(compute, comm) vs compute + comm, PGA flush additive")

    # --- push-sum rounds under faults (DESIGN.md §2.5) ---------------------
    n = 32
    for name, d in MODELS.items():
        for n_dropped in (0, 2, 8):
            t, msgs = push_sum_round_time(d, "directed_exp", n, n_dropped)
            emit(f"push_sum_{name}_directed_exp_drop{n_dropped}_ms", t * 1e3,
                 f"{msgs} directed msgs, n={n}")

    # --- (b) measured structural proxy on CPU ------------------------------
    n, dim = 8, 1_000_000
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (n, dim))}
    mix = jax.jit(lambda p: mixing.mix_pytree(p, "ring", n))
    avg = jax.jit(mixing.global_average_pytree)
    t_mix = time_fn(mix, params, iters=10)
    t_avg = time_fn(avg, params, iters=10)
    emit("proxy_cpu_ring_mix_us", t_mix, f"n={n} d={dim}")
    emit("proxy_cpu_global_avg_us", t_avg, f"n={n} d={dim}")


if __name__ == "__main__":
    main()
