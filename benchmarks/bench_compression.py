"""Compressed-gossip benchmark: bytes-on-wire, round latency, and
convergence parity (DESIGN.md §2.3; registered in benchmarks/run.py).

Three sections, CSV rows per benchmarks/common.emit:

* ``compress/bytes/<name>`` — **measured** wire bytes (payload + aux of
  the actual LeafWire arrays) for one gossip broadcast of a synthetic
  parameter blob, with the fp32/compressed ratio as the derived column.
  The acceptance gate from ISSUE 3 — int8 moves ≥ 4× fewer bytes than
  fp32 — is asserted here (``--check``; exit 1 on failure).
* ``compress/round/<phase>/<name>/<backend>`` — wall-clock of one full
  communication round vs the uncompressed baseline.  On this CPU
  container the pallas rows run in interpret mode (absolute numbers
  meaningless, same caveat as bench_mixing_kernels); the reference rows
  measure the jnp compressed math.
* ``compress/global_bytes/<kind>`` — **measured** wire bytes of the
  compressed global/pod-averaging collective (DESIGN.md §2.3 "Compressed
  collectives"): the stage-1 reduce-scatter payload (int8/fp8 codes + one
  uint8 exponent per power-of-two block scale) per node, vs the fp32 psum
  operand — the gate asserts int8 moves ≥ 4× fewer bytes (up to the
  exponent bytes).
* ``compress/logistic/*`` — the paper's §5.1 logistic problem under
  Gossip-PGA: final suboptimality of int8(+EF) vs the uncompressed run.
  Documented tolerance: int8+EF — and the fully-compressed run that adds
  the int8 collective on the PGA round — must land within ``--loss-rtol``
  (default 10%) of the uncompressed final suboptimality; int8 without EF
  is reported for contrast but not gated.

``--out FILE`` writes a BENCH_mixing-style JSON (rows + gate) so CI can
append the global-phase bytes row to ``benchmarks/BENCH_history.jsonl``
via ``report.py --append-history``.

    PYTHONPATH=src python -m benchmarks.bench_compression
    PYTHONPATH=src python -m benchmarks.bench_compression --check
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro import compress as C
from repro.compress import collective as ccol
from repro.core import mixing, simulate
from repro.data import make_logistic_problem

NAMES = ("identity", "int8", "fp8", "topk", "randk")


# ---------------------------------------------------------------------------
# Bytes on wire (measured, not analytic)
# ---------------------------------------------------------------------------
def bench_bytes(n: int, dim: int, k: int) -> dict:
    x = jax.random.normal(jax.random.PRNGKey(0), (n, dim), jnp.float32)
    fp32 = n * dim * 4
    ratios = {}
    for name in NAMES:
        comp = C.make_compressor(name, k=k)
        wires, _ = C.compress_tree(comp, x, None, jnp.uint32(0))
        measured = sum(w.nbytes for w in wires)
        ratios[name] = fp32 / measured
        emit(f"compress/bytes/{name}", float(measured),
             f"fp32_ratio={ratios[name]:.2f}x")
    return ratios


def bench_global_bytes(n: int, dim: int) -> dict:
    """Measured wire bytes of the compressed collective's stage-1 payload
    (per node — the same one-operand accounting as round_wire_bytes's
    ``D·4`` for the uncompressed psum), plus the analytic cross-check."""
    x = jax.random.normal(jax.random.PRNGKey(1), (n, dim), jnp.float32)
    xp = ccol.pad_cols(x, ccol.QBLOCK)
    s1, s2 = ccol.stage_seeds(jnp.uint32(0))
    fp32 = dim * 4
    ratios = {}
    for kind in ("int8", "fp8"):
        codes1, scales1, q1 = ccol.quantize_blocks(xp, kind, s1)
        mbar = ccol.anchored_mean(q1)
        codes2, scales2, _ = ccol.quantize_blocks(mbar, kind, s2)
        # the wire form of a power-of-two scale is one uint8 exponent
        # (ccol.scale_exponents) — the fp32 word never crosses the ICI
        exps1 = ccol.scale_exponents(scales1)
        exps2 = ccol.scale_exponents(scales2)
        measured = (np.asarray(codes1).nbytes + np.asarray(exps1).nbytes) \
            // n
        gather = np.asarray(codes2).nbytes + np.asarray(exps2).nbytes
        ratios[kind] = fp32 / measured
        emit(f"compress/global_bytes/{kind}", float(measured),
             f"fp32_ratio={ratios[kind]:.2f}x gather_bytes={gather}")
        analytic = C.round_wire_bytes("global", "ring", n, dim,
                                      global_compression=kind)
        assert measured == analytic, (kind, measured, analytic)
    return ratios


# ---------------------------------------------------------------------------
# Round latency
# ---------------------------------------------------------------------------
def bench_rounds(n: int, dim: int, k: int, iters: int) -> None:
    x = jax.random.normal(jax.random.PRNGKey(0), (n, dim), jnp.float32)

    spec = mixing.CommSpec(topology="ring", n_nodes=n)

    @jax.jit
    def base_round(x):
        return mixing.communicate(x, spec, phase="gossip")

    t0 = time_fn(base_round, x, iters=iters)
    emit("compress/round/gossip/none/reference", t0)
    for name in ("int8", "fp8", "topk"):
        comp = C.make_compressor(name, k=k)
        for backend in ("reference", "pallas"):
            sp = spec.replace(compressor=comp, backend=backend)

            @jax.jit
            def comp_round(x, _s=sp):
                return mixing.communicate(x, _s, phase="gossip", seed=1)[0]

            t = time_fn(comp_round, x, iters=iters)
            emit(f"compress/round/gossip/{name}/{backend}", t,
                 f"vs_uncompressed={t0 / t:.2f}x")

    @jax.jit
    def base_global(x):
        return mixing.communicate(x, spec, phase="global")

    tg = time_fn(base_global, x, iters=iters)
    emit("compress/round/global/none/reference", tg)
    gcomp = C.make_compressor("int8")
    for backend in ("reference", "pallas"):
        sp = spec.replace(global_compressor=gcomp, backend=backend)

        @jax.jit
        def coll_round(x, _s=sp):
            return mixing.communicate(x, _s, phase="global", seed=1)[0]

        t = time_fn(coll_round, x, iters=iters)
        emit(f"compress/round/global/int8/{backend}", t,
             f"vs_uncompressed={tg / t:.2f}x")


# ---------------------------------------------------------------------------
# Logistic transient (paper §5.1 protocol, reduced)
# ---------------------------------------------------------------------------
def bench_logistic(steps: int, seeds: int, n: int) -> dict:
    prob = make_logistic_problem(n=n, M=2000, d=10, iid=False, seed=0)
    loss_fn = prob.loss_fn()

    def run(**kw):
        finals = []
        for seed in range(seeds):
            out = simulate(algorithm="gossip_pga",
                           grad_fn=prob.grad_fn(batch=8), loss_fn=loss_fn,
                           x0=jnp.zeros(prob.d), n=n, steps=steps,
                           lr=lambda kk: 0.2 * (0.5 ** (kk // 1000)),
                           topology="ring", H=16, eval_every=50, seed=seed,
                           **kw)
            finals.append(out["loss"][-1])
        return float(np.mean(finals))

    ref = run()
    int8_ef = run(compression="int8", error_feedback=True)
    int8_noef = run(compression="int8")
    # fully-compressed wire: int8 gossip halos + the int8 collective on
    # the PGA round (comm_global_compression), EF absorbing both residuals
    int8_full = run(compression="int8", global_compression="int8",
                    error_feedback=True)
    emit("compress/logistic/uncompressed_final", ref)
    emit("compress/logistic/int8_ef_final", int8_ef,
         f"vs_uncompressed={int8_ef / max(ref, 1e-12):.4f}")
    emit("compress/logistic/int8_noef_final", int8_noef,
         f"vs_uncompressed={int8_noef / max(ref, 1e-12):.4f}")
    emit("compress/logistic/int8_global_ef_final", int8_full,
         f"vs_uncompressed={int8_full / max(ref, 1e-12):.4f}")
    return {"ref": ref, "int8_ef": int8_ef, "int8_full": int8_full}


def main(n: int = 8, dim: int = 65_536, k: int = 1024, iters: int = 5,
         steps: int = 400, seeds: int = 2, loss_rtol: float = 0.10,
         check: bool = False, out: str = "") -> int:
    print(f"# compression wire/round/convergence, n={n} dim={dim} "
          f"backend={jax.default_backend()} (pallas interpreted off-TPU)")
    ratios = bench_bytes(n, dim, k)
    gratios = bench_global_bytes(n, dim)
    bench_rounds(n, dim, k, iters)
    logi = bench_logistic(steps, seeds, n)
    # int8 moves exactly D bytes + one fp32 scale word per row, so the
    # measured ratio is 4·D/(D+4) — ≥4× up to the scale overhead (<0.1%
    # at any production leaf size); the gate allows exactly that slack
    ok_bytes = ratios["int8"] >= 4.0 * dim / (dim + 4) - 1e-6
    # global collective: codes + one uint8 scale exponent per QBLOCK
    # columns (scale_exponents — the residual 0.4% of fp32 scale words
    # is gone from the wire)
    dp = -(-dim // ccol.QBLOCK) * ccol.QBLOCK
    g_slack = 4.0 * dim / (dp + dp // ccol.QBLOCK)
    ok_global = gratios["int8"] >= g_slack - 1e-6
    ok_loss = abs(logi["int8_ef"] - logi["ref"]) \
        <= loss_rtol * max(abs(logi["ref"]), 1e-12)
    ok_global_loss = abs(logi["int8_full"] - logi["ref"]) \
        <= loss_rtol * max(abs(logi["ref"]), 1e-12)
    emit("compress/gate/int8_bytes_4x", float(ok_bytes),
         f"ratio={ratios['int8']:.2f}")
    emit("compress/gate/int8_global_bytes_4x", float(ok_global),
         f"ratio={gratios['int8']:.2f} (floor {g_slack:.3f})")
    emit("compress/gate/int8_ef_matches_loss", float(ok_loss),
         f"rtol={loss_rtol}")
    emit("compress/gate/int8_global_ef_matches_loss", float(ok_global_loss),
         f"rtol={loss_rtol}")
    ok = ok_bytes and ok_global and ok_loss and ok_global_loss
    if out:
        rows = [
            {"name": "compress/gossip_bytes/int8", "ratio": ratios["int8"],
             "gated": True},
            {"name": "compress/global_bytes/int8", "ratio": gratios["int8"],
             "gated": True},
            {"name": "compress/global_bytes/fp8", "ratio": gratios["fp8"],
             "gated": False},
            {"name": "compress/logistic/int8_global_ef_vs_ref",
             "ratio": logi["int8_full"] / max(logi["ref"], 1e-12),
             "gated": False},
        ]
        with open(out, "w") as f:
            json.dump({"jax_backend": jax.default_backend(), "dim": dim,
                       "nodes": n, "gate": {"ok": bool(ok),
                                            "loss_rtol": loss_rtol},
                       "rows": rows}, f, indent=1)
        print(f"# wrote {out}")
    if check and not ok:
        print("# compression gate FAILED", flush=True)
        return 1
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=65_536)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--k", type=int, default=1024)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--seeds", type=int, default=2)
    ap.add_argument("--loss-rtol", type=float, default=0.10,
                    help="documented tolerance for int8+EF final loss vs "
                         "uncompressed")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 when a ≥4× bytes gate (gossip or global "
                         "collective) or an EF loss gate fails")
    ap.add_argument("--out", default="",
                    help="write a BENCH_mixing-style JSON for "
                         "report.py --append-history")
    a = ap.parse_args()
    sys.exit(main(n=a.nodes, dim=a.dim, k=a.k, iters=a.iters, steps=a.steps,
                  seeds=a.seeds, loss_rtol=a.loss_rtol, check=a.check,
                  out=a.out))
