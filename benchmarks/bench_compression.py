"""Compressed-gossip benchmark: bytes-on-wire, round latency, and
convergence parity (DESIGN.md §2.3; registered in benchmarks/run.py).

Three sections, CSV rows per benchmarks/common.emit:

* ``compress/bytes/<name>`` — **measured** wire bytes (payload + aux of
  the actual LeafWire arrays) for one gossip broadcast of a synthetic
  parameter blob, with the fp32/compressed ratio as the derived column.
  The acceptance gate from ISSUE 3 — int8 moves ≥ 4× fewer bytes than
  fp32 — is asserted here (``--check``; exit 1 on failure).
* ``compress/round/<phase>/<name>/<backend>`` — wall-clock of one full
  communication round vs the uncompressed baseline.  On this CPU
  container the pallas rows run in interpret mode (absolute numbers
  meaningless, same caveat as bench_mixing_kernels); the reference rows
  measure the jnp compressed math.
* ``compress/logistic/*`` — the paper's §5.1 logistic problem under
  Gossip-PGA: final suboptimality of int8(+EF) vs the uncompressed run.
  Documented tolerance: int8+EF must land within ``--loss-rtol``
  (default 10%) of the uncompressed final suboptimality; int8 without EF
  is reported for contrast but not gated.

    PYTHONPATH=src python -m benchmarks.bench_compression
    PYTHONPATH=src python -m benchmarks.bench_compression --check
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro import compress as C
from repro.core import mixing, simulate
from repro.data import make_logistic_problem

NAMES = ("identity", "int8", "fp8", "topk", "randk")


# ---------------------------------------------------------------------------
# Bytes on wire (measured, not analytic)
# ---------------------------------------------------------------------------
def bench_bytes(n: int, dim: int, k: int) -> dict:
    x = jax.random.normal(jax.random.PRNGKey(0), (n, dim), jnp.float32)
    fp32 = n * dim * 4
    ratios = {}
    for name in NAMES:
        comp = C.make_compressor(name, k=k)
        wires, _ = C.compress_tree(comp, x, None, jnp.uint32(0))
        measured = sum(w.nbytes for w in wires)
        ratios[name] = fp32 / measured
        emit(f"compress/bytes/{name}", float(measured),
             f"fp32_ratio={ratios[name]:.2f}x")
    return ratios


# ---------------------------------------------------------------------------
# Round latency
# ---------------------------------------------------------------------------
def bench_rounds(n: int, dim: int, k: int, iters: int) -> None:
    x = jax.random.normal(jax.random.PRNGKey(0), (n, dim), jnp.float32)

    @jax.jit
    def base_round(x):
        return mixing.communicate(x, phase="gossip", topology="ring",
                                  n_nodes=n)

    t0 = time_fn(base_round, x, iters=iters)
    emit("compress/round/gossip/none/reference", t0)
    for name in ("int8", "fp8", "topk"):
        comp = C.make_compressor(name, k=k)
        for backend in ("reference", "pallas"):
            @jax.jit
            def comp_round(x, _c=comp, _b=backend):
                return mixing.communicate(x, phase="gossip", topology="ring",
                                          n_nodes=n, compressor=_c, seed=1,
                                          backend=_b)[0]

            t = time_fn(comp_round, x, iters=iters)
            emit(f"compress/round/gossip/{name}/{backend}", t,
                 f"vs_uncompressed={t0 / t:.2f}x")


# ---------------------------------------------------------------------------
# Logistic transient (paper §5.1 protocol, reduced)
# ---------------------------------------------------------------------------
def bench_logistic(steps: int, seeds: int, n: int) -> dict:
    prob = make_logistic_problem(n=n, M=2000, d=10, iid=False, seed=0)
    loss_fn = prob.loss_fn()

    def run(**kw):
        finals = []
        for seed in range(seeds):
            out = simulate(algorithm="gossip_pga",
                           grad_fn=prob.grad_fn(batch=8), loss_fn=loss_fn,
                           x0=jnp.zeros(prob.d), n=n, steps=steps,
                           lr=lambda kk: 0.2 * (0.5 ** (kk // 1000)),
                           topology="ring", H=16, eval_every=50, seed=seed,
                           **kw)
            finals.append(out["loss"][-1])
        return float(np.mean(finals))

    ref = run()
    int8_ef = run(compression="int8", error_feedback=True)
    int8_noef = run(compression="int8")
    emit("compress/logistic/uncompressed_final", ref)
    emit("compress/logistic/int8_ef_final", int8_ef,
         f"vs_uncompressed={int8_ef / max(ref, 1e-12):.4f}")
    emit("compress/logistic/int8_noef_final", int8_noef,
         f"vs_uncompressed={int8_noef / max(ref, 1e-12):.4f}")
    return {"ref": ref, "int8_ef": int8_ef}


def main(n: int = 8, dim: int = 65_536, k: int = 1024, iters: int = 5,
         steps: int = 400, seeds: int = 2, loss_rtol: float = 0.10,
         check: bool = False) -> int:
    print(f"# compression wire/round/convergence, n={n} dim={dim} "
          f"backend={jax.default_backend()} (pallas interpreted off-TPU)")
    ratios = bench_bytes(n, dim, k)
    bench_rounds(n, dim, k, iters)
    logi = bench_logistic(steps, seeds, n)
    # int8 moves exactly D bytes + one fp32 scale word per row, so the
    # measured ratio is 4·D/(D+4) — ≥4× up to the scale overhead (<0.1%
    # at any production leaf size); the gate allows exactly that slack
    ok_bytes = ratios["int8"] >= 4.0 * dim / (dim + 4) - 1e-6
    ok_loss = abs(logi["int8_ef"] - logi["ref"]) \
        <= loss_rtol * max(abs(logi["ref"]), 1e-12)
    emit("compress/gate/int8_bytes_4x", float(ok_bytes),
         f"ratio={ratios['int8']:.2f}")
    emit("compress/gate/int8_ef_matches_loss", float(ok_loss),
         f"rtol={loss_rtol}")
    if check and not (ok_bytes and ok_loss):
        print("# compression gate FAILED", flush=True)
        return 1
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=65_536)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--k", type=int, default=1024)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--seeds", type=int, default=2)
    ap.add_argument("--loss-rtol", type=float, default=0.10,
                    help="documented tolerance for int8+EF final loss vs "
                         "uncompressed")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 when the ≥4× int8 bytes gate or the "
                         "int8+EF loss gate fails")
    a = ap.parse_args()
    sys.exit(main(n=a.nodes, dim=a.dim, k=a.k, iters=a.iters, steps=a.steps,
                  seeds=a.seeds, loss_rtol=a.loss_rtol, check=a.check))
