"""Generate the EXPERIMENTS.md §Dry-run / §Roofline / §Perf tables from the
dry-run + hillclimb JSONL dumps, and maintain the perf-gate trend history.

    PYTHONPATH=src python -m benchmarks.report > /tmp/report.md
    PYTHONPATH=src python -m benchmarks.report \
        --append-history BENCH_mixing.json
    PYTHONPATH=src python -m benchmarks.report --trend

The trend history (``benchmarks/BENCH_history.jsonl``, tracked) exists
because a single CI run's pallas/reference ratio jitters ±50% on shared
runners (bench_mixing_kernels docstring): CI appends each run's
``BENCH_mixing.json`` rows here, and the trend table shows per-row ratios
across runs so a real regression (every recent run slower) is separable
from one noisy row (ROADMAP "perf-gate trend" item).
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Any, Dict, List

FILES = {
    "single": "results_dryrun_single.jsonl",
    "multi": "results_dryrun_multi.jsonl",
    "hillclimb": "results_hillclimb.jsonl",
}
HISTORY = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "BENCH_history.jsonl")
# BENCH_history.jsonl row schema: v1 rows predate the stamp (unstamped),
# v2 rows carry {"schema": 2}.  trend_table skips-but-warns on rows with
# a newer schema instead of KeyError-ing on missing fields.
HISTORY_SCHEMA = 2


def _load(path: str) -> List[Dict[str, Any]]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return [json.loads(ln) for ln in f if ln.strip()]


def _fmt_s(x: float) -> str:
    return f"{x:.2e}"


def _gb(x) -> str:
    return f"{(x or 0) / 1e9:.1f}"


def dryrun_table(rows: List[Dict[str, Any]], mesh: str) -> None:
    chips = "512 chips (2,16,16)" if mesh == "multi" else "256 chips (16,16)"
    print(f"\n### Dry-run — {mesh} mesh ({chips})\n")
    print("| arch | shape | status | mode | temp GB/dev | args GB/dev | "
          "compile s |")
    print("|---|---|---|---|---|---|---|")
    for r in rows:
        if r.get("skipped"):
            print(f'| {r["arch"]} | {r["shape"]} | SKIP: {r["skipped"][:58]} '
                  f'| | | | |')
            continue
        if not r.get("ok"):
            print(f'| {r["arch"]} | {r["shape"]} | **FAIL** '
                  f'{r.get("error", "")[:50]} | | | | |')
            continue
        if "phases" in r:
            p = r["phases"]["gossip"]
            mode = f'{r.get("mode")} (n={r.get("n_nodes")})'
        else:
            p = r
            mode = r.get("mode", "")
        m = p["memory"]
        print(f'| {r["arch"]} | {r["shape"]} | ok | {mode} '
              f'| {_gb(m["temp_size_in_bytes"])} '
              f'| {_gb(m["argument_size_in_bytes"])} '
              f'| {p["compile_s"]:.0f} |')


def roofline_table(rows: List[Dict[str, Any]]) -> None:
    print("\n### Roofline — single-pod (256 chips), per chip, per step\n")
    print("| arch | shape | phase | compute s | memory s | collective s | "
          "dominant | MODEL/HLO flops | bottleneck note |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if not r.get("ok"):
            continue
        entries = []
        if "phases" in r:
            for ph, p in r["phases"].items():
                entries.append((ph, p["roofline"]))
        else:
            entries.append((r["shape"].split("_")[0], r["roofline"]))
        for ph, rl in entries:
            ratio = rl.get("useful_flops_ratio")
            ratio_s = f"{ratio:.2f}" if ratio is not None else "-"
            note = _note(rl)
            print(f'| {r["arch"]} | {r["shape"]} | {ph} '
                  f'| {_fmt_s(rl["compute_s"])} | {_fmt_s(rl["memory_s"])} '
                  f'| {_fmt_s(rl["collective_s"])} | {rl["dominant"]} '
                  f'| {ratio_s} | {note} |')


def _note(rl: Dict[str, Any]) -> str:
    dom = rl["dominant"]
    if dom == "collective":
        per = rl.get("coll_per_type") or {}
        top = max(per, key=per.get) if per else "?"
        return f"top collective: {top}"
    if dom == "memory":
        ai = rl["flops"] / max(rl["hlo_bytes"], 1)
        return f"arith intensity {ai:.1f} flop/B"
    return "compute-bound (good)"


def hillclimb_table(rows: List[Dict[str, Any]]) -> None:
    print("\n### Perf hillclimbs\n")
    by_exp: Dict[str, List[Dict[str, Any]]] = {}
    for r in rows:
        by_exp.setdefault(r["experiment"], []).append(r)
    for exp, recs in by_exp.items():
        print(f"\n#### {exp}\n")
        print("| variant | compute s | memory s | collective s | dominant | "
              "temp GB | hypothesis |")
        print("|---|---|---|---|---|---|---|")
        for r in recs:
            if "phases" in r:
                rl = r["phases"]["gossip"]["roofline"]
                mem = r["phases"]["gossip"]["memory"]
            else:
                rl = r["roofline"]
                mem = r["memory"]
            print(f'| {r["variant"]} | {_fmt_s(rl["compute_s"])} '
                  f'| {_fmt_s(rl["memory_s"])} | {_fmt_s(rl["collective_s"])} '
                  f'| {rl["dominant"]} | {_gb(mem["temp_size_in_bytes"])} '
                  f'| {r["hypothesis"][:90]} |')


def append_history(src: str = "BENCH_mixing.json",
                   path: str = HISTORY) -> None:
    """Append one perf-gate run's rows to the tracked trend history.
    Accepts BENCH_mixing.json (timed rows) and BENCH_compression.json
    (byte-ratio rows without timings — bench_compression --out)."""
    with open(src) as f:
        bench = json.load(f)
    rec = {
        "ts": int(time.time()),
        "schema": HISTORY_SCHEMA,
        "sha": os.environ.get("GITHUB_SHA", "local")[:12],
        "jax_backend": bench.get("jax_backend"),
        "dim": bench.get("dim"), "nodes": bench.get("nodes"),
        "gate": bench.get("gate"),
        "rows": [{"name": r["name"], "ratio": r["ratio"],
                  "reference_us": r.get("reference_us"),
                  "pallas_us": r.get("pallas_us"),
                  "gated": r.get("gated", False)}
                 for r in bench.get("rows", [])],
    }
    if bench.get("overlap_rows"):
        # overlapped-round critical path (DESIGN.md §2.6): apply/sync
        # ratio per multi-shift topology, gated strictly below 1.0
        rec["overlap_gate"] = bench.get("overlap_gate")
        rec["overlap_rows"] = [
            {"name": r["name"], "ratio": r["ratio"],
             "sync_us": r.get("sync_us"),
             "overlap_apply_us": r.get("overlap_apply_us"),
             "overlap_issue_us": r.get("overlap_issue_us"),
             "gated": r.get("gated", False)}
            for r in bench["overlap_rows"]]
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")
    print(f"appended {len(rec['rows'])} rows ({rec['sha']}) to {path}")


def trend_table(path: str = HISTORY, last: int = 10) -> None:
    """Per-row pallas/reference ratio across the last ``last`` recorded
    runs — the trend that makes the single-run gate's verdict meaningful."""
    runs = _load(path)[-last:]
    if not runs:
        print(f"(no history at {path})")
        return
    kept = []
    for run in runs:
        sch = run.get("schema", 1)   # v1 rows predate the stamp
        if sch > HISTORY_SCHEMA:
            print(f"(skipping history row sha={run.get('sha', '?')}: "
                  f"unknown schema {sch} > {HISTORY_SCHEMA} — written by "
                  f"a newer tool)", file=sys.stderr)
            continue
        kept.append(run)
    runs = kept
    if not runs:
        print(f"(no readable history rows at {path})")
        return
    names = []
    for run in runs:
        for row in run.get("rows") or []:
            if "name" in row and "ratio" in row and row["name"] not in names:
                names.append(row["name"])
    print(f"\n### Perf-gate trend — pallas/reference ratio, last "
          f"{len(runs)} runs (oldest → newest)\n")
    print("| row | " + " | ".join(r["sha"][:7] for r in runs)
          + " | median |")
    print("|---|" + "---|" * (len(runs) + 1))
    for name in names:
        cells, vals = [], []
        for run in runs:
            hit = [r for r in run.get("rows") or []
                   if r.get("name") == name and "ratio" in r]
            if hit:
                cells.append(f'{hit[0]["ratio"]:.2f}')
                vals.append(hit[0]["ratio"])
            else:
                cells.append("-")
        vals.sort()
        med = vals[len(vals) // 2] if vals else float("nan")
        print(f"| {name} | " + " | ".join(cells) + f" | {med:.2f} |")
    gates = [r.get("gate") or {} for r in runs]
    worst = [g.get("min_gated_ratio") for g in gates
             if g.get("min_gated_ratio") is not None]
    if worst:
        # newest record carrying a ratio limit (compression-gate records
        # interleave in the history and have no max_ratio)
        limit = next((g["max_ratio"] for g in reversed(gates)
                      if g.get("max_ratio") is not None), None)
        print(f"\nmin gated ratio across runs: best {min(worst):.2f}, "
              f"worst {max(worst):.2f} (gate limit {limit})")


def _pct(vals: List[float], q: float) -> float:
    vals = sorted(vals)
    return vals[min(int(q * len(vals)), len(vals) - 1)]


def telemetry_table(path: str) -> None:
    """Render a telemetry JSONL stream (``launch/train --telemetry-dir``,
    ``launch/serve --telemetry-dir``) as markdown: per-phase comm cost
    (analytic vs measured wire bytes, joined with the executed-round
    counts), pipeline occupancy, loss/consensus trend, fault events, and
    serving latency percentiles."""
    recs = _load(path)
    if not recs:
        print(f"(no telemetry at {path})")
        return
    kept = []
    for r in recs:
        sch = r.get("schema", 1)
        if sch > 1:
            print(f"(skipping telemetry record type={r.get('type', '?')}: "
                  f"unknown schema {sch})", file=sys.stderr)
            continue
        kept.append(r)
    by: Dict[str, List[Dict[str, Any]]] = {}
    for r in kept:
        by.setdefault(r.get("type", "?"), []).append(r)
    steps = by.get("step", [])
    comm = by.get("comm_round", [])
    counts = (steps[-1].get("phase_counts") or {}) if steps else {}

    rounds = [r for r in comm if r.get("role") != "occupancy"]
    if rounds:
        print("\n### Telemetry — per-round communication\n")
        print("| phase | role | topology | backend | compression | sends "
              "| analytic B/round | measured B/round | rounds executed |")
        print("|---|---|---|---|---|---|---|---|---|")
        seen = set()
        for r in rounds:
            key = (r.get("phase"), r.get("role"), r.get("compression"),
                   r.get("backend"))
            if key in seen:
                continue
            seen.add(key)
            ana = r.get("analytic_bytes")
            print(f'| {r.get("phase")} | {r.get("role")} '
                  f'| {r.get("topology")} | {r.get("backend")} '
                  f'| {r.get("compression")} | {r.get("sends")} '
                  f'| {ana if ana is not None else "-"} '
                  f'| {r.get("measured_bytes")} '
                  f'| {counts.get(r.get("phase"), "-")} |')

    occ = [r for r in comm if r.get("role") == "occupancy"]
    if occ:
        o = occ[-1]
        print(f"\npipeline occupancy: **{o.get('occupancy', 0.0):.2f}** "
              f"(overlap step {o.get('t_step_overlap_us', 0):.0f}us, "
              f"compute-only {o.get('t_step_compute_us', 0):.0f}us, "
              f"sync round {o.get('t_round_sync_us', 0):.0f}us)")

    if steps:
        a, b = steps[0], steps[-1]
        line = (f"\nloss: {a.get('loss', float('nan')):.4f} @ step "
                f"{a.get('step')} -> {b.get('loss', float('nan')):.4f} "
                f"@ step {b.get('step')}")
        if "consensus" in b:
            line += f"; final consensus {b['consensus']:.3e}"
        print(line)
    faults = by.get("fault", [])
    if faults:
        print(f"fault events: " + ", ".join(
            f"step {f.get('step')} {f.get('kind')} {f.get('nodes')}"
            for f in faults))
    ckpts = by.get("ckpt", [])
    if ckpts:
        print(f"checkpoints: {len(ckpts)} "
              f"(steps {[c.get('step') for c in ckpts]})")

    serve = by.get("serve_req", [])
    if serve:
        lats = [r["latency_s"] for r in serve if "latency_s" in r]
        tps = [r.get("tokens_per_s", 0.0) for r in serve]
        print(f"\n### Telemetry — serving ({len(serve)} requests)\n")
        print(f"latency p50 {_pct(lats, 0.5) * 1e3:.1f}ms / "
              f"p99 {_pct(lats, 0.99) * 1e3:.1f}ms; "
              f"mean tokens/s {sum(tps) / len(tps):.1f}")


def main() -> None:
    single = _load(FILES["single"])
    multi = _load(FILES["multi"])
    hc = _load(FILES["hillclimb"])
    if single:
        dryrun_table(single, "single")
        roofline_table(single)
    if multi:
        dryrun_table(multi, "multi")
    if hc:
        hillclimb_table(hc)


def _capture(fn, *a) -> str:
    import contextlib
    import io
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        fn(*a)
    return buf.getvalue()


def inject_into_experiments(path: str = "EXPERIMENTS.md") -> None:
    """Replace the <!-- REPORT:X --> markers with generated tables.
    Corrected-roofline rows come from the train_4k corrected sweep when
    present (results_dryrun_train4k.jsonl) with fast-sweep rows for
    the rest."""
    single = _load(FILES["single"])
    train4k = _load("results_dryrun_train4k.jsonl")
    multi = _load(FILES["multi"])
    hc = _load(FILES["hillclimb"])
    # prefer corrected train_4k records over fast ones
    corrected = {(r["arch"], r["shape"]): r for r in train4k}
    merged = [corrected.get((r["arch"], r["shape"]), r) for r in single]
    text = open(path).read()
    text = text.replace(
        "<!-- REPORT:DRYRUN -->",
        _capture(dryrun_table, single, "single")
        + _capture(dryrun_table, multi, "multi"))
    text = text.replace("<!-- REPORT:ROOFLINE -->",
                        _capture(roofline_table, merged))
    text = text.replace("<!-- REPORT:PERF -->", _capture(hillclimb_table, hc))
    open(path, "w").write(text)
    print(f"injected report tables into {path}")


if __name__ == "__main__":
    import sys as _sys
    if "--append-history" in _sys.argv:
        i = _sys.argv.index("--append-history")
        src = _sys.argv[i + 1] if len(_sys.argv) > i + 1 \
            and not _sys.argv[i + 1].startswith("-") else "BENCH_mixing.json"
        append_history(src)
    elif "--trend" in _sys.argv:
        trend_table()
    elif "--telemetry" in _sys.argv:
        i = _sys.argv.index("--telemetry")
        telemetry_table(_sys.argv[i + 1])
    elif "--inject" in _sys.argv:
        inject_into_experiments()
    else:
        main()
        trend_table()
