"""Shared benchmark helpers: CSV emission + wall-clock timing.

``time_fn`` delegates to :func:`repro.obs.trace.fenced_time` — the same
fenced timing loop the telemetry layer uses — so BENCH rows and
telemetry spans are the same numbers.  Set ``REPRO_BENCH_TRACE=<path>``
to additionally record every timed call as a span and save a
Chrome-trace timeline at interpreter exit.
"""
from __future__ import annotations

import atexit
import os
from typing import Callable, Optional

from repro.obs.trace import Tracer, fenced_time

_TRACER: Optional[Tracer] = None
_trace_path = os.environ.get("REPRO_BENCH_TRACE", "")
if _trace_path:
    _TRACER = Tracer()
    atexit.register(lambda: _TRACER.save(_trace_path))


def emit(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.3f},{derived}"
    print(line, flush=True)
    return line


def time_fn(fn: Callable, *args, iters: int = 10, warmup: int = 2,
            name: Optional[str] = None) -> float:
    """Median wall-clock microseconds per call (blocks on jax results)."""
    return fenced_time(fn, *args, iters=iters, warmup=warmup,
                       name=name, tracer=_TRACER if name else None)
