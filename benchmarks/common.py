"""Shared benchmark helpers: CSV emission + wall-clock timing."""
from __future__ import annotations

import time
from typing import Any, Callable, Iterable

import jax


def emit(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.3f},{derived}"
    print(line, flush=True)
    return line


def time_fn(fn: Callable, *args, iters: int = 10, warmup: int = 2) -> float:
    """Median wall-clock microseconds per call (blocks on jax results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
