# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark aggregator — one module per paper table/figure:

  bench_logistic_transient — Fig. 1 (§5.1 logistic regression, ring, non-iid)
  bench_transient_theory   — Tables 2, 3, 5, 12–14 (transient stage/time)
  bench_comm_model         — Tables 1, 7, 11, 17 / App. H (α-β comm model)
  bench_period_sweep       — Tables 8, 15 (H sweep + SlowMo), real LM training
  bench_scalability        — Table 10 (node scaling)
  bench_roofline           — deliverable (g): roofline from the dry-run dumps
  bench_compression        — wire compression: bytes/latency/convergence
                             (DESIGN.md §2.3; beyond-paper)
"""
from __future__ import annotations

import time
import traceback


def main() -> None:
    from benchmarks import (bench_comm_model, bench_compression, bench_hier,
                            bench_logistic_transient, bench_period_sweep,
                            bench_roofline, bench_scalability,
                            bench_transient_theory)
    suites = [
        ("transient_theory", bench_transient_theory.main),
        ("comm_model", bench_comm_model.main),
        ("logistic_transient", bench_logistic_transient.main),
        ("period_sweep", bench_period_sweep.main),
        ("scalability", bench_scalability.main),
        ("hier_pga", bench_hier.main),
        ("roofline", bench_roofline.main),
        ("compression", bench_compression.main),
    ]
    failures = []
    for name, fn in suites:
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            fn()
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
        print(f"# --- {name} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        raise SystemExit(f"benchmark suites failed: {failures}")


if __name__ == "__main__":
    main()
