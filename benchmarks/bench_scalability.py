"""Paper Table 10 — scaling nodes at fixed global batch.

Iteration-quality part measured (final loss vs n at fixed global batch and
steps); wall-clock part derived from the α-β communication model (CPU
container can't measure real network time).  Gossip-PGA should track parallel
SGD's loss at every n while paying ~allreduce/H communication.
"""
from __future__ import annotations

import jax

from benchmarks.bench_comm_model import alpha_beta_times
from benchmarks.common import emit
from repro.configs import (DataConfig, DistConfig, OptimizerConfig,
                           TrainConfig, get_model_config)
from repro.train import Trainer


def run(algorithm: str, n_nodes: int, steps: int = 40) -> float:
    cfg = get_model_config("pga-lm-100m", reduced=True)
    tcfg = TrainConfig(
        model=cfg,
        dist=DistConfig(algorithm=algorithm, topology="ring", H=6),
        optimizer=OptimizerConfig(name="adamw", lr=3e-3, schedule="constant",
                                  warmup_steps=5),
        data=DataConfig(non_iid=True), global_batch=16, seq_len=64,
        log_every=0)
    tr = Trainer(tcfg, n_nodes=n_nodes)
    state = tr.init_state(jax.random.PRNGKey(0))
    tr.run(state, steps=steps, log_every=steps - 1)
    return tr.history[-1]["loss"]


def main(steps: int = 40) -> None:
    for n in (2, 4, 8):
        par = run("parallel", n, steps)
        pga = run("gossip_pga", n, steps)
        emit(f"table10_n{n}_parallel_loss", par)
        emit(f"table10_n{n}_pga_loss", pga,
             f"gap={(pga - par):+.4f}")
        t = alpha_beta_times(25.5e6, n=n, H=6)
        emit(f"table10_n{n}_derived_comm_speedup",
             t["allreduce"] / t["gossip_pga_H6"])


if __name__ == "__main__":
    main()
