"""Beyond-paper: Hierarchical PGA (Hier-PGA).

Gossip every step + cheap intra-pod exact average every H_pod + expensive
global All-Reduce every H_global.  On a two-tier network (fast ICI inside a
pod, slow DCI across), Hier-PGA buys most of PGA's consensus control at a
fraction of the cross-pod traffic.

Measured: consensus + suboptimality on §5.1 logistic regression vs Gossip-PGA
at the SAME cross-pod communication budget; modeled: two-tier α-β comm time.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import simulate
from repro.data import make_logistic_problem

ALPHA_ICI, ALPHA_DCI = 10e-6, 200e-6          # intra vs cross-pod latency
BW_ICI, BW_DCI = 25e9, 2.5e9                  # bytes/s


def comm_time(alg: str, n: int, n_pods: int, d: float, H: int,
              H_pod: int = 3) -> float:
    theta_ici = d * 4 / BW_ICI
    theta_dci = d * 4 / BW_DCI
    gossip = theta_ici + ALPHA_ICI                       # one-peer intra-pod
    ar_pod = 2 * theta_ici + (n // n_pods) * ALPHA_ICI
    ar_glob = 2 * theta_dci + n * ALPHA_DCI
    if alg == "gossip_pga":
        return gossip + ar_glob / H
    if alg == "hier_pga":
        return gossip + ar_pod / H_pod + ar_glob / H
    raise ValueError(alg)


def main() -> None:
    n, n_pods = 16, 4
    prob = make_logistic_problem(n=n, M=1000, d=10, iid=False, seed=0)
    kw = dict(grad_fn=prob.grad_fn(batch=8), loss_fn=prob.loss_fn(),
              x0=jnp.zeros(prob.d), n=n, steps=600, lr=0.1,
              topology="ring", eval_every=50, seed=0)
    pga = simulate(algorithm="gossip_pga", H=12, **kw)
    hier = simulate(algorithm="hier_pga", H=12,
                    aga_kwargs={"hier_h_pod": 3, "n_pods": n_pods}, **kw)
    tail = slice(3, None)
    emit("hier_pga_consensus_tail", float(np.mean(hier["consensus"][tail])),
         f"pga={np.mean(pga['consensus'][tail]):.3e} (same cross-pod budget)")
    emit("hier_consensus_improvement",
         float(np.mean(pga["consensus"][tail])
               / max(np.mean(hier["consensus"][tail]), 1e-12)),
         ">1 means Hier-PGA holds tighter consensus at equal DCI traffic")
    emit("hier_loss_final", float(hier["loss"][-1]),
         f"pga={pga['loss'][-1]:.5f}")
    for alg in ("gossip_pga", "hier_pga"):
        t = comm_time(alg, n, n_pods, 25.5e6, H=12)
        emit(f"hier_comm_model_{alg}_ms", t * 1e3,
             "two-tier alpha-beta model, ResNet50-sized params")


if __name__ == "__main__":
    main()
