"""Paper Fig. 1 / §5.1 — logistic regression over the ring topology, non-iid.

Protocol (faithful to §5.1 at reduced trial count for CPU): features
h ~ N(0, 10·I_d), labels from per-node logistic models x*_i (non-iid),
lr 0.2 halved every 1000 iterations, ring topology, H=16.  Curves are
suboptimality f(x̄)−f* averaged over seeds (the paper averages 50 trials and
reads the transient stage off the log-scale plot).

Emitted per (n, algorithm): suboptimality AUC relative to parallel SGD
(>1 ⇒ slower convergence = longer transient) and the first iteration from
which the algorithm's smoothed curve stays within 25% of parallel SGD's.
Expected orderings (paper Tables 2/3, Fig. 1): AUC(PGA) ≤ AUC(Gossip),
AUC(PGA) ≤ AUC(Local), with the Gossip gap growing with n (β→1 on a ring).
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import simulate
from repro.data import dirichlet_noniid_problem, make_logistic_problem

ALGS = ["parallel", "gossip", "local", "gossip_pga", "gossip_aga"]


def lr_schedule(k: int) -> float:
    return 0.2 * (0.5 ** (k // 1000))   # paper §5.1


def f_star(prob) -> float:
    """Full-batch GD to near-optimality on the average objective."""
    loss = prob.loss_fn()
    H, y = prob.H, prob.y

    @jax.jit
    def g(x):
        z = -y * jnp.einsum("nmd,d->nm", H, x)
        return -jnp.einsum("nm,nmd->d", jax.nn.sigmoid(z) * y,
                           H) / (prob.n * prob.M)

    x = jnp.zeros(prob.d)
    for _ in range(4000):
        x = x - 0.05 * g(x)
    return float(loss(x))


def mean_curves(prob, alg, steps, seeds, H, overlap=False):
    curves = []
    for seed in range(seeds):
        out = simulate(
            algorithm=alg, grad_fn=prob.grad_fn(batch=8),
            loss_fn=prob.loss_fn(), x0=jnp.zeros(prob.d), n=prob.n,
            steps=steps, lr=lr_schedule, topology="ring", H=H,
            eval_every=50, seed=seed, overlap=overlap)
        curves.append(out["loss"])
    return np.mean(curves, 0), out["iteration"]


def transient_iter(sub, sub_ref, its, tol=0.25) -> int:
    ratio = sub / np.maximum(sub_ref, 1e-12)
    for i in range(len(ratio)):
        if np.all(ratio[i:] < 1.0 + tol):
            return int(its[i])
    return int(its[-1]) + 1


def main(ns=(16, 32), steps=800, seeds=4, H=16) -> None:
    for n in ns:
        prob = make_logistic_problem(n=n, M=2000, d=10, iid=False, seed=0)
        fs = f_star(prob)
        emit(f"fig1_n{n}_f_star", fs)
        ref, its = mean_curves(prob, "parallel", steps, seeds, H)
        sub_ref = ref - fs
        aucs = {}
        for alg in ALGS:
            if alg == "parallel":
                sub = sub_ref
            else:
                cur, _ = mean_curves(prob, alg, steps, seeds, H)
                sub = cur - fs
            auc = float(np.trapezoid(sub) / max(np.trapezoid(sub_ref), 1e-12))
            aucs[alg] = auc
            t = transient_iter(sub, sub_ref, its)
            emit(f"fig1_n{n}_{alg}_auc_vs_parallel", auc,
                 f"transient_iter~{t}")
        emit(f"fig1_n{n}_pga_beats_gossip",
             float(aucs["gossip_pga"] <= aucs["gossip"] * 1.05),
             f"pga={aucs['gossip_pga']:.3f} gossip={aucs['gossip']:.3f}")
        emit(f"fig1_n{n}_pga_beats_local",
             float(aucs["gossip_pga"] <= aucs["local"] * 1.05),
             f"pga={aucs['gossip_pga']:.3f} local={aucs['local']:.3f}")
        # pipelined (one-step-stale) gossip vs synchronous (DESIGN.md
        # §2.6): the staleness acts like a modestly larger effective H,
        # so the transient AUC should stay within a small factor of sync
        # while the wall-clock model (bench_comm_model) hides the round
        for alg in ("gossip", "gossip_pga"):
            cur, _ = mean_curves(prob, alg, steps, seeds, H, overlap=True)
            sub = cur - fs
            auc = float(np.trapezoid(sub) / max(np.trapezoid(sub_ref),
                                                1e-12))
            emit(f"fig1_n{n}_{alg}_overlap_auc_vs_parallel", auc,
                 f"sync={aucs[alg]:.3f}")
            emit(f"fig1_n{n}_{alg}_overlap_vs_sync_auc_ratio",
                 auc / max(aucs[alg], 1e-12),
                 "one-step-stale gossip vs synchronous round")


def _final_sub(prob, alg, fs, steps, lr, H, tail=4) -> float:
    """Mean tail suboptimality of a deterministic (full-batch) run."""
    out = simulate(algorithm=alg, grad_fn=prob.grad_fn(batch=0),
                   loss_fn=prob.loss_fn(), x0=jnp.zeros(prob.d), n=prob.n,
                   steps=steps, lr=lr, topology="ring", H=H, eval_every=25,
                   seed=0)
    return float(np.mean(out["loss"][-tail:]) - fs)


def noniid_crossover(n=16, M=500, d=10, steps=400, alpha=0.3,
                     feature_shift=2.0, lr=0.05, H=16, out=None) -> bool:
    """Gradient-tracking crossover on Dirichlet-sharded non-IID data.

    Full-batch gradients (deterministic), constant lr, ring: plain gossip
    converges only to a consensus-bias floor set by the heterogeneity
    ζ² (the drift the paper's Remark 4 transient analysis charges it
    for), while GT-PGA's tracker cancels the per-node drift and keeps
    descending — it must reach the floor gossip attains on *IID* data.

    Gated rows (appended to benchmarks/BENCH_history.jsonl by CI via
    ``report.py --append-history BENCH_logistic.json``):

    * ``noniid_gt_vs_iid_floor`` — gt_pga(non-IID) / gossip(IID) tail
      suboptimality, gated ≤ GT_VS_IID_MAX.
    * ``noniid_gossip_stall_vs_gt`` — gossip(non-IID) / gt_pga(non-IID),
      gated ≥ STALL_MIN (gossip measurably stalls where GT does not).
    """
    GT_VS_IID_MAX, STALL_MIN = 4.0, 10.0
    FLOOR = 1e-9   # fp resolution of the f* subtraction
    pn = dirichlet_noniid_problem(n=n, M=M, d=d, alpha=alpha,
                                  feature_shift=feature_shift, seed=0)
    pi = make_logistic_problem(n=n, M=M, d=d, iid=True, seed=0)
    fs_n, fs_i = f_star(pn), f_star(pi)
    gt_sub = max(_final_sub(pn, "gt_pga", fs_n, steps, lr, H), FLOOR)
    gossip_sub = max(_final_sub(pn, "gossip", fs_n, steps, lr, H), FLOOR)
    iid_sub = max(_final_sub(pi, "gossip", fs_i, steps, lr, H), FLOOR)
    pga_sub = max(_final_sub(pn, "gossip_pga", fs_n, steps, lr, H), FLOOR)

    rows = [
        {"name": "noniid_gt_vs_iid_floor", "ratio": gt_sub / iid_sub,
         "gated": True},
        {"name": "noniid_gossip_stall_vs_gt", "ratio": gossip_sub / gt_sub,
         "gated": True},
        {"name": "noniid_pga_vs_gt", "ratio": pga_sub / gt_sub,
         "gated": False},
    ]
    ok = (gt_sub <= iid_sub * GT_VS_IID_MAX
          and gossip_sub >= gt_sub * STALL_MIN)
    for r in rows:
        emit(r["name"], r["ratio"], f"gated={r['gated']}")
    emit("noniid_crossover_pass", float(ok),
         f"gt={gt_sub:.2e} gossip={gossip_sub:.2e} iid={iid_sub:.2e}")
    if out:
        with open(out, "w") as f:
            json.dump({"gate": {"gt_vs_iid_max_ratio": GT_VS_IID_MAX,
                                "stall_min_ratio": STALL_MIN,
                                "passed": ok},
                       "rows": rows}, f, indent=1)
        print(f"wrote {out}")
    if not ok:
        raise SystemExit(
            f"non-IID crossover gate FAILED: gt_sub={gt_sub:.3e} "
            f"iid_sub={iid_sub:.3e} gossip_sub={gossip_sub:.3e}")
    return ok


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale n (20/50/100), more steps/seeds")
    ap.add_argument("--noniid-gate", action="store_true",
                    help="run only the gradient-tracking non-IID "
                         "crossover gate (gossip stalls, gt_pga reaches "
                         "the IID floor)")
    ap.add_argument("--out", default=None,
                    help="with --noniid-gate: write the gated rows as "
                         "JSON for report.py --append-history")
    a = ap.parse_args()
    if a.noniid_gate:
        noniid_crossover(out=a.out)
    elif a.full:
        main(ns=(20, 50, 100), steps=3000, seeds=10)
    else:
        main()
