"""Reference vs fused-Pallas mixing backends — the paper's communication
round as a kernel microbenchmark.

For each topology (ring, one_peer_exp, grid) × node count × phase it times
one full communication round over a synthetic parameter blob and emits

    mixing/<phase>/<topology>/n<n>/<backend>,<us_per_call>,<speedup>

CSV rows (benchmarks/common.emit convention; see benchmarks/README.md for
how these relate to the paper's Table 2 communication model).  On this CPU
container the pallas rows run in interpret mode, so absolute numbers are
not meaningful there — the reference/pallas *ratio* becomes meaningful on
TPU where the kernel compiles to Mosaic; what CPU CI checks is that both
backends run end-to-end and agree (the parity gate lives in
tests/test_mixing_kernels.py).

    PYTHONPATH=src python -m benchmarks.bench_mixing_kernels [--dim 65536]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import mixing
from repro.kernels import mixing_pallas

TOPOLOGIES = ("ring", "one_peer_exp", "grid")
PHASES = ("gossip", "global", "pod_avg")


def bench_round(phase: str, topology: str, n: int, dim: int, n_pods: int,
                iters: int) -> None:
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, dim), jnp.float32)
    g = jax.random.normal(jax.random.PRNGKey(1), (n, dim), jnp.float32)
    gamma = 0.1

    # Reference: unfused half-step then roll/mean mixing (2 + |shifts| passes)
    @jax.jit
    def ref_round(x, g):
        return mixing.communicate(x - gamma * g, phase=phase,
                                  topology=topology, n_nodes=n, step=0,
                                  n_pods=n_pods)

    # Pallas: half-step + mix fused into one pass
    @jax.jit
    def pallas_round(x, g):
        return mixing_pallas.fused_step_mix(x, g, gamma, phase=phase,
                                            topology=topology, n_nodes=n,
                                            n_pods=n_pods)

    base = f"mixing/{phase}/{topology}/n{n}"
    t_ref = time_fn(ref_round, x, g, iters=iters)
    t_pal = time_fn(pallas_round, x, g, iters=iters)
    emit(f"{base}/reference", t_ref)
    emit(f"{base}/pallas", t_pal, f"speedup={t_ref / t_pal:.2f}x")


def main(dim: int = 65_536, nodes=(8, 16), iters: int = 10) -> None:
    print(f"# mixing backends, dim={dim} fp32 per node, "
          f"backend={jax.default_backend()} "
          f"(pallas interpreted off-TPU)")
    for topology in TOPOLOGIES:
        for n in nodes:
            for phase in PHASES:
                if phase == "gossip" or topology == TOPOLOGIES[0]:
                    # averaging phases are topology-independent: once is enough
                    bench_round(phase, topology, n, dim, n_pods=2,
                                iters=iters)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=65_536,
                    help="per-node parameter count")
    ap.add_argument("--nodes", type=int, nargs="+", default=[8, 16])
    ap.add_argument("--iters", type=int, default=10)
    args = ap.parse_args()
    main(dim=args.dim, nodes=tuple(args.nodes), iters=args.iters)
