"""Reference vs fused-Pallas mixing backends — the paper's communication
round as a kernel microbenchmark, doubling as CI's perf-regression gate.

For each topology (ring, one_peer_exp, grid) × node count × phase it times
one full communication round over a synthetic parameter blob and emits

    mixing/<phase>/<topology>/n<n>/<backend>,<us_per_call>,<speedup>

CSV rows (benchmarks/common.emit convention; see benchmarks/README.md for
how these relate to the paper's Table 2 communication model).  On this CPU
container the pallas rows run in interpret mode, so absolute numbers are
not meaningful there — the reference/pallas *ratio* becomes meaningful on
TPU where the kernel compiles to Mosaic; what CPU CI checks is that both
backends run end-to-end, agree (the parity gate lives in
tests/test_mixing_kernels.py), and that the pallas path has not regressed
against the reference.

Perf-regression gate (CI): ``--out BENCH_mixing.json`` writes the rows,
ratios, and gate verdict as JSON; ``--max-ratio R`` exits non-zero when
pallas is *consistently* slower than reference by more than R — i.e. when
the **minimum** pallas/reference ratio over the multi-shift rounds exceeds
R.  A real regression (say, reintroducing the pack/unpack copies the
aliased path eliminated) slows every round, so the minimum catches it;
a single noisy row on a shared CI runner does not trip the gate (wall
clock at these sizes jitters ±50% per row).  One-peer rows are excluded
from the gate: their reference round is a single roll, so on the
interpret-mode CPU path the comparison only measures Python interpreter
overhead (DESIGN.md §2.1 caveat (a)); they are still reported in the
JSON.

A second gate covers the overlapped round (DESIGN.md §2.6): per
multi-shift topology it measures the on-arrival apply — the only
critical-path work of a pipelined round — against the full synchronous
round, and fails unless the best round is strictly below sync.  The
off-path correction compute is reported ungated (it overlaps the next
forward/backward by construction).

    PYTHONPATH=src python -m benchmarks.bench_mixing_kernels [--dim 65536]
    PYTHONPATH=src python -m benchmarks.bench_mixing_kernels \
        --dim 4096 --nodes 8 --iters 3 --out BENCH_mixing.json --max-ratio 1.25
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import mixing
from repro.kernels import mixing_pallas

TOPOLOGIES = ("ring", "one_peer_exp", "grid")
PHASES = ("gossip", "global", "pod_avg")
# one-peer gossip: single-shift reference — excluded from the CPU gate
GATED_TOPOLOGIES = ("ring", "grid")


def bench_round(phase: str, topology: str, n: int, dim: int, n_pods: int,
                iters: int) -> dict:
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, dim), jnp.float32)
    g = jax.random.normal(jax.random.PRNGKey(1), (n, dim), jnp.float32)
    gamma = 0.1

    spec = mixing.CommSpec(topology=topology, n_nodes=n, n_pods=n_pods)

    # Reference: unfused half-step then roll/mean mixing (2 + |shifts| passes)
    @jax.jit
    def ref_round(x, g):
        return mixing.communicate(x - gamma * g, spec, phase=phase, step=0)

    # Pallas: half-step + mix fused into one pass (aliased staging buffer)
    @jax.jit
    def pallas_round(x, g):
        return mixing_pallas.fused_step_mix(x, g, gamma, phase=phase,
                                            topology=topology, n_nodes=n,
                                            n_pods=n_pods)

    base = f"mixing/{phase}/{topology}/n{n}"
    t_ref = time_fn(ref_round, x, g, iters=iters)
    t_pal = time_fn(pallas_round, x, g, iters=iters)
    emit(f"{base}/reference", t_ref)
    emit(f"{base}/pallas", t_pal, f"speedup={t_ref / t_pal:.2f}x")
    return {"name": base, "phase": phase, "topology": topology, "n": n,
            "reference_us": t_ref, "pallas_us": t_pal,
            "ratio": t_pal / t_ref,
            "gated": phase != "gossip" or topology in GATED_TOPOLOGIES}


def bench_overlap_round(topology: str, n: int, dim: int, iters: int) -> dict:
    """Critical-path decomposition of one overlapped gossip round
    (DESIGN.md §2.6).  In pipelined mode the stale buffer's correction
    ``M·b − w⊙b`` is computed off the critical path — it overlaps the
    next step's forward/backward — so the only on-arrival work between
    grads-ready and params-ready is the apply ``(x − γg) + corr``.  The
    gate checks that this apply is strictly cheaper than the full
    synchronous round (half-step + mix), which is the wall-clock claim
    of the overlap mode, measured independently of whether this host can
    actually run compute and communication concurrently (single-core CI
    runners cannot)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (n, dim), jnp.float32)
    g = jax.random.normal(jax.random.PRNGKey(1), (n, dim), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(2), (n, dim), jnp.float32)
    gamma = 0.1
    spec = mixing.CommSpec(topology=topology, n_nodes=n)

    @jax.jit
    def sync_round(x, g):
        return mixing.communicate(x - gamma * g, spec, phase="gossip",
                                  step=0)

    w, M = mixing.compensated_round_factors("gossip", topology, n)
    wj, Mj = jnp.asarray(w), jnp.asarray(M)

    @jax.jit
    def issue(b):                 # off-path: overlaps the next fwd/bwd
        return Mj @ b - wj * b

    corr = jax.block_until_ready(issue(b))

    @jax.jit
    def apply_round(x, g, corr):  # on-arrival: the critical-path piece
        return (x - gamma * g) + corr

    base = f"mixing/overlap/{topology}/n{n}"
    t_sync = time_fn(sync_round, x, g, iters=iters)
    t_apply = time_fn(apply_round, x, g, corr, iters=iters)
    t_issue = time_fn(issue, b, iters=iters)
    emit(f"{base}/sync", t_sync)
    emit(f"{base}/apply", t_apply, f"speedup={t_sync / t_apply:.2f}x")
    emit(f"{base}/issue", t_issue, "off-critical-path")
    return {"name": base, "topology": topology, "n": n,
            "sync_us": t_sync, "overlap_apply_us": t_apply,
            "overlap_issue_us": t_issue, "ratio": t_apply / t_sync,
            "gated": topology in GATED_TOPOLOGIES}


def main(dim: int = 65_536, nodes=(8, 16), iters: int = 10,
         out: str | None = None, max_ratio: float | None = None) -> int:
    print(f"# mixing backends, dim={dim} fp32 per node, "
          f"backend={jax.default_backend()} "
          f"(pallas interpreted off-TPU)")
    rows = []
    for topology in TOPOLOGIES:
        for n in nodes:
            for phase in PHASES:
                if phase == "gossip" or topology == TOPOLOGIES[0]:
                    # averaging phases are topology-independent: once is enough
                    rows.append(bench_round(phase, topology, n, dim,
                                            n_pods=2, iters=iters))
    gated = sorted(r["ratio"] for r in rows if r["gated"])
    best = gated[0] if gated else float("nan")
    verdict = {"min_gated_ratio": best, "max_ratio": max_ratio,
               "passed": max_ratio is None or best <= max_ratio}
    print(f"# gate: min pallas/reference ratio {best:.3f} over "
          f"{len(gated)} multi-shift rounds"
          + ("" if max_ratio is None else
             f" (limit {max_ratio:.2f}: "
             f"{'PASS' if verdict['passed'] else 'FAIL'})"))
    # overlapped-round critical path (DESIGN.md §2.6): same min-over-rounds
    # anti-flake rule; the apply must be strictly below the sync round
    overlap_rows = [bench_overlap_round(topology, n, dim, iters)
                    for topology in TOPOLOGIES for n in nodes]
    o_gated = sorted(r["ratio"] for r in overlap_rows if r["gated"])
    o_best = o_gated[0] if o_gated else float("nan")
    # unlike the pallas gate, the overlap limit needs no CLI calibration:
    # the pipelined apply must be strictly below the sync round (< 1.0)
    # on every host, or the mode buys nothing
    overlap_verdict = {"min_gated_ratio": o_best, "max_ratio": 1.0,
                       "passed": bool(o_gated) and o_best < 1.0}
    print(f"# overlap gate: min apply/sync ratio {o_best:.3f} over "
          f"{len(o_gated)} multi-shift rounds (limit 1.00: "
          f"{'PASS' if overlap_verdict['passed'] else 'FAIL'})")
    if out:
        with open(out, "w") as f:
            json.dump({"dim": dim, "nodes": list(nodes), "iters": iters,
                       "jax_backend": jax.default_backend(),
                       "rows": rows, "gate": verdict,
                       "overlap_rows": overlap_rows,
                       "overlap_gate": overlap_verdict}, f, indent=2)
        print(f"# wrote {out}")
    return 0 if (verdict["passed"] and overlap_verdict["passed"]) else 1


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=65_536,
                    help="per-node parameter count")
    ap.add_argument("--nodes", type=int, nargs="+", default=[8, 16])
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--out", default=None,
                    help="write rows + gate verdict as JSON (CI artifact)")
    ap.add_argument("--max-ratio", type=float, default=None,
                    help="fail (exit 1) when every multi-shift round is "
                         "slower than reference by more than this ratio "
                         "(min gated pallas/reference ratio)")
    args = ap.parse_args()
    sys.exit(main(dim=args.dim, nodes=tuple(args.nodes), iters=args.iters,
                  out=args.out, max_ratio=args.max_ratio))
