"""Roofline table (deliverable g) — reads the dry-run JSONL dumps and prints
the three-term roofline per (arch × shape × mesh): seconds per term, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs ratio."""
from __future__ import annotations

import argparse
import json
import os
from typing import Any, Dict, List

from benchmarks.common import emit

DEFAULT_FILES = ["results_dryrun_single.jsonl", "results_dryrun_multi.jsonl"]


def load(files: List[str]) -> List[Dict[str, Any]]:
    rows = []
    for f in files:
        if os.path.exists(f):
            with open(f) as fh:
                rows += [json.loads(ln) for ln in fh if ln.strip()]
    return rows


def fmt_row(r: Dict[str, Any]) -> None:
    name = f'{r["arch"]}|{r["shape"]}|{r["mesh"]}'
    if r.get("skipped"):
        emit(f"roofline_{name}", -1.0, f"SKIP:{r['skipped'][:60]}")
        return
    if not r.get("ok"):
        emit(f"roofline_{name}", -1.0, f"FAIL:{r.get('error', '')[:60]}")
        return
    entries = []
    if "phases" in r:   # train: gossip + global phases
        for ph, p in r["phases"].items():
            entries.append((f"{name}|{ph}", p["roofline"]))
    else:
        entries.append((name, r["roofline"]))
    for label, rl in entries:
        bound = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        ratio = rl.get("useful_flops_ratio")
        emit(f"roofline_{label}", bound * 1e6,
             f'dom={rl["dominant"]} comp={rl["compute_s"]:.2e}s '
             f'mem={rl["memory_s"]:.2e}s coll={rl["collective_s"]:.2e}s '
             f'useful={ratio:.3f}' if ratio is not None else
             f'dom={rl["dominant"]}')


def main(files=None) -> None:
    rows = load(files or DEFAULT_FILES)
    if not rows:
        emit("roofline_no_dryrun_results", 0.0,
             "run: python -m repro.launch.dryrun --all --out "
             "results_dryrun_single.jsonl")
        return
    for r in rows:
        fmt_row(r)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*", default=None)
    a = ap.parse_args()
    main(a.files or None)
