"""Paper Tables 2, 3, 5, 12–14 — transient-stage theory.

Evaluates the closed-form transient iterations/time for Gossip SGD, Local SGD
and Gossip-PGA over measured β values of concrete topologies, and checks every
ordering claim in the tables.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import topology as topo

# α-β model (paper §3.4): time to send x∈R^d between two nodes = θd; latency α
THETA_D_RESNET = 25.5e6 * 4 / 3.125e9   # 25 Gbps => ~3.125 GB/s, fp32 params
ALPHA = 50e-6                            # 50 µs point-to-point latency


def comm_time_per_iter(alg: str, n: int, H: int, neighborhood: int,
                       theta_d: float = THETA_D_RESNET) -> float:
    allreduce = 2 * theta_d + n * ALPHA
    gossip = neighborhood * theta_d + ALPHA
    if alg == "parallel":
        return allreduce
    if alg == "gossip":
        return gossip
    if alg == "local":
        return allreduce / H
    if alg in ("gossip_pga", "gossip_aga"):
        return gossip + allreduce / H
    raise ValueError(alg)


def main() -> None:
    # --- Tables 2 & 3: transient iterations at measured betas --------------
    for n in (16, 64):
        for t, hood in (("ring", 3), ("grid", 5)):
            b = topo.beta(topo.mixing_matrix(t, n))
            for iid in (True, False):
                H = int(max(2, round(n ** 0.5)))
                tg = topo.transient_stage("gossip", n, b, H, iid=iid)
                tl = topo.transient_stage("local", n, b, H, iid=iid)
                tp = topo.transient_stage("gossip_pga", n, b, H, iid=iid)
                tag = "iid" if iid else "noniid"
                emit(f"table23_{t}_n{n}_{tag}_transient_gossip", tg,
                     f"beta={b:.4f}")
                emit(f"table23_{t}_n{n}_{tag}_transient_local", tl, f"H={H}")
                emit(f"table23_{t}_n{n}_{tag}_transient_pga", tp,
                     f"C_beta={topo.c_beta(b, H):.2f}")
                emit(f"table23_{t}_n{n}_{tag}_pga_shortest",
                     float(tp <= tg and tp <= tl),
                     f"pga={tp:.3g} gossip={tg:.3g} local={tl:.3g}")

    # --- Table 5 / 12-14: transient *time* = transient iters × comm/iter ---
    for n in (16, 64):
        H = int(max(2, round(n ** 0.5)))
        for t, hood in (("ring", 3), ("grid", 5)):
            b = topo.beta(topo.mixing_matrix(t, n))
            for iid in (True, False):
                tag = "iid" if iid else "noniid"
                tt_g = (topo.transient_stage("gossip", n, b, H, iid=iid)
                        * comm_time_per_iter("gossip", n, H, hood))
                tt_p = (topo.transient_stage("gossip_pga", n, b, H, iid=iid)
                        * comm_time_per_iter("gossip_pga", n, H, hood))
                emit(f"table5_{t}_n{n}_{tag}_transient_time_gossip_s", tt_g)
                emit(f"table5_{t}_n{n}_{tag}_transient_time_pga_s", tt_p)
                emit(f"table5_{t}_n{n}_{tag}_pga_time_shorter",
                     float(tt_p <= tt_g),
                     f"ratio={tt_g / max(tt_p, 1e-12):.3g}")


if __name__ == "__main__":
    main()
