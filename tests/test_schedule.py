"""Communication schedules + the paper's algebraic reductions (Remarks 2-4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import DistConfig
from repro.core import simulate
from repro.core.schedule import (AGASchedule, LocalSchedule, PGASchedule,
                                 make_schedule)


def test_pga_phase_pattern():
    s = PGASchedule(H=4)
    phases = [s.phase(k) for k in range(12)]
    assert phases == ["gossip", "gossip", "gossip", "global"] * 3


def test_local_phase_pattern():
    s = LocalSchedule(H=3)
    assert [s.phase(k) for k in range(6)] == \
        ["none", "none", "global"] * 2


def test_aga_period_increases_as_loss_drops():
    s = AGASchedule(H_init=4, warmup=8, H_max=64)
    # during warmup: collect F_init
    for k in range(16):
        s.observe_loss(k, 10.0)
        s.advance(k)
    # loss drops 4x -> H should grow toward 16
    for k in range(16, 64):
        s.observe_loss(k, 2.5)
        s.advance(k)
    assert s.current_H > 4
    assert s.current_H <= 64


def test_aga_h_bounded():
    s = AGASchedule(H_init=4, warmup=4, H_max=8)
    for k in range(64):
        s.observe_loss(k, 1e-9)   # catastrophic ratio
        s.advance(k)
    assert 1 <= s.current_H <= 8


def test_aga_phase_is_pure():
    """ISSUE-4 regression: phase()/peek_phase() must not advance the live
    period counter — a dryrun/roofline/logging probe between training
    steps must not desync H adaptation."""
    s = AGASchedule(H_init=3, warmup=4, H_max=16)
    # calling phase(step) twice returns the same answer, and any number of
    # peeks never changes what advance() will do
    for k in range(24):
        s.observe_loss(k, 5.0)
        first = s.phase(k)
        assert s.phase(k) == first
        for probe in (0, k, k + 7):       # arbitrary-step probes are safe
            s.peek_phase(probe)
        assert s.advance(k) == first


def test_aga_advance_matches_pre_split_sequence():
    """advance() reproduces the pre-split mutate-on-phase sequence exactly
    (global every current_H steps, counter reset on global)."""
    s = AGASchedule(H_init=4, warmup=100, H_max=64)   # warmup: H stays 4
    for k in range(24):
        s.observe_loss(k, 1.0)
        want = "global" if (k + 1) % 4 == 0 else "gossip"
        assert s.advance(k) == want


def test_aga_peek_does_not_desync_trainer_loop():
    """Two identical AGA runs, one interleaved with peeks, produce the
    same phase sequence and the same final H."""
    def run(peek):
        s = AGASchedule(H_init=2, warmup=4, H_max=32)
        seq = []
        for k in range(40):
            s.observe_loss(k, 10.0 / (1 + k))
            if peek:
                for _ in range(3):
                    s.phase(k)
            seq.append(s.advance(k))
        return seq, s.current_H

    a, ha = run(peek=False)
    b, hb = run(peek=True)
    assert a == b and ha == hb


def test_make_schedule_dispatch():
    for alg in ["parallel", "gossip", "local", "gossip_pga", "gossip_aga",
                "slowmo"]:
        s = make_schedule(DistConfig(algorithm=alg))
        assert s.phase(0) in ("gossip", "global", "none", "slowmo")


# ---------------------------------------------------------------------------
# Algebraic reductions on the simulator (paper Remarks 2-4)
# ---------------------------------------------------------------------------
def _quad_problem(n=8, d=4, seed=0):
    c = jnp.asarray(np.random.default_rng(seed).normal(size=(n, d)),
                    jnp.float32)

    def grad_fn(x, key, k):   # deterministic grads -> exact comparisons
        return x - c

    def loss_fn(xbar):
        return 0.5 * jnp.mean(jnp.sum((xbar - c) ** 2, -1))

    return grad_fn, loss_fn, c


def test_pga_with_full_topology_equals_parallel():
    grad_fn, loss_fn, c = _quad_problem()
    kw = dict(grad_fn=grad_fn, loss_fn=loss_fn, x0=jnp.zeros(4), n=8,
              steps=40, lr=0.1, H=4, eval_every=5)
    a = simulate(algorithm="gossip_pga", topology="full", **kw)
    b = simulate(algorithm="parallel", topology="full", **kw)
    np.testing.assert_allclose(a["loss"], b["loss"], rtol=1e-6)


def test_pga_with_huge_h_equals_gossip():
    grad_fn, loss_fn, c = _quad_problem()
    kw = dict(grad_fn=grad_fn, loss_fn=loss_fn, x0=jnp.zeros(4), n=8,
              steps=40, lr=0.1, topology="ring", eval_every=5)
    a = simulate(algorithm="gossip_pga", H=10_000, **kw)
    b = simulate(algorithm="gossip", H=10_000, **kw)
    np.testing.assert_allclose(a["loss"], b["loss"], rtol=1e-6)


def test_pga_with_identity_topology_equals_local():
    grad_fn, loss_fn, c = _quad_problem()
    kw = dict(grad_fn=grad_fn, loss_fn=loss_fn, x0=jnp.zeros(4), n=8,
              steps=40, lr=0.1, H=4, eval_every=5)
    a = simulate(algorithm="gossip_pga", topology="disconnected", **kw)
    b = simulate(algorithm="local", topology="disconnected", **kw)
    np.testing.assert_allclose(a["loss"], b["loss"], rtol=1e-6)


def test_slowmo_beta0_alpha1_equals_pga():
    """Paper §5.2: Gossip-PGA is SlowMo with slow momentum 0, slow lr 1."""
    grad_fn, loss_fn, c = _quad_problem()
    kw = dict(grad_fn=grad_fn, loss_fn=loss_fn, x0=jnp.zeros(4), n=8,
              steps=24, lr=0.1, H=4, topology="ring", eval_every=4)
    a = simulate(algorithm="slowmo", slowmo_beta=0.0, slowmo_lr=1.0, **kw)
    b = simulate(algorithm="gossip_pga", **kw)
    np.testing.assert_allclose(a["loss"], b["loss"], rtol=1e-5)
