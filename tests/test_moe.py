"""MoE: sort-based capacity dispatch vs dense oracle, load conservation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_model_config
from repro.models import moe as moe_lib
from repro.models.moe import (_build_dispatch, apply_moe,
                              apply_moe_dense_reference, route)


@pytest.fixture(scope="module")
def setup():
    cfg = get_model_config("qwen3-moe-30b-a3b", reduced=True)
    key = jax.random.PRNGKey(0)
    params, _ = moe_lib.init_moe(key, cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    return cfg, params, x


def test_sort_dispatch_matches_dense_reference_when_no_drops(setup):
    cfg, params, x = setup
    # capacity_factor = n_experts guarantees zero drops
    out, metrics = apply_moe(params, cfg, x,
                             capacity_factor=float(cfg.moe.n_routed))
    want = apply_moe_dense_reference(params, cfg, x)
    assert float(metrics["drop_frac"]) == 0.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-3)


def test_dispatch_tables_conserve_assignments():
    T, E, C, k = 64, 8, 24, 2
    key = jax.random.PRNGKey(0)
    top_idx = jax.random.randint(key, (T, k), 0, E)
    top_w = jax.nn.softmax(jax.random.normal(key, (T, k)))
    tok, w, drop = _build_dispatch(top_idx, top_w, E, C, T)
    # every non-sentinel slot refers to a real token exactly once per (t,e)
    tok_np = np.asarray(tok)
    valid = tok_np < T
    n_assigned = valid.sum()
    counts = np.bincount(np.asarray(top_idx).reshape(-1), minlength=E)
    expected = np.minimum(counts, C).sum()
    assert n_assigned == expected
    assert 0.0 <= float(drop) < 1.0


def test_capacity_drops_measured(setup):
    cfg, params, x = setup
    out, metrics = apply_moe(params, cfg, x, capacity_factor=0.25)
    assert float(metrics["drop_frac"]) > 0.0
    assert np.all(np.isfinite(np.asarray(out)))


def test_load_balance_loss_uniform_router_is_minimal():
    cfg = get_model_config("qwen3-moe-30b-a3b", reduced=True)
    key = jax.random.PRNGKey(0)
    params, _ = moe_lib.init_moe(key, cfg, jnp.float32)
    # zero router => uniform probs => lb_loss ~= E * E*(1/E)*(1/E)... = 1
    params = dict(params)
    params["router"] = jnp.zeros_like(params["router"])
    x = jax.random.normal(key, (2, 32, cfg.d_model))
    _, _, lb = route(params, cfg.moe, x)
    np.testing.assert_allclose(float(lb), 1.0, rtol=0.2)


def test_shared_experts_always_active():
    cfg = get_model_config("deepseek-v2-lite-16b", reduced=True)
    key = jax.random.PRNGKey(0)
    params, _ = moe_lib.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 8, cfg.d_model))
    out, _ = apply_moe(params, cfg, x)
    # zeroing the routed experts must leave the shared-expert path
    z = dict(params)
    for k in ("w_gate", "w_up", "w_down"):
        z[k] = jnp.zeros_like(params[k])
    out_shared, _ = apply_moe(z, cfg, x)
    assert np.abs(np.asarray(out_shared)).sum() > 0
