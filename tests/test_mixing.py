"""Mixing: roll-based pjit path ≡ dense W; shard_map/ppermute path ≡ dense W
(subprocess with forced host devices); global averaging semantics."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mixing, topology as topo

TOPOLOGIES_1D = ["ring", "exp", "full", "disconnected"]


@pytest.mark.parametrize("t", TOPOLOGIES_1D + ["grid"])
@pytest.mark.parametrize("n", [4, 16])
def test_roll_mixing_equals_dense(t, n, rng_key):
    x = jax.random.normal(rng_key, (n, 5, 3))
    W = topo.mixing_matrix(t, n)
    got = mixing.mix_pytree(x, t, n)
    want = jnp.einsum("ij,jab->iab", jnp.asarray(W), x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("step", [0, 1, 2, 3, 5])
def test_one_peer_exp_roll_equals_dense(step, rng_key):
    n = 8
    x = jax.random.normal(rng_key, (n, 4))
    W = topo.mixing_matrix("one_peer_exp", n, step=step)
    got = mixing.mix_pytree(x, "one_peer_exp", n, step=step)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jnp.asarray(W) @ x), atol=1e-5)


def test_global_average(rng_key):
    x = jax.random.normal(rng_key, (8, 3))
    avg = mixing.global_average_pytree(x)
    np.testing.assert_allclose(np.asarray(avg),
                               np.broadcast_to(np.asarray(x).mean(0), (8, 3)),
                               atol=1e-6)


def test_mixing_pytree_structure(rng_key):
    tree = {"a": jax.random.normal(rng_key, (4, 2)),
            "b": [jax.random.normal(rng_key, (4, 3, 3))]}
    out = mixing.mix_pytree(tree, "ring", 4)
    assert jax.tree.structure(out) == jax.tree.structure(tree)


_SHARD_MAP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import mixing, topology as topo

    mesh = jax.make_mesh((8,), ("nodes",))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 6)),
                    jnp.float32)
    for t in ["ring", "exp", "full"]:
        mixer = mixing.make_shard_map_mixer(mesh, "nodes", t)
        got = mixer(x)
        W = jnp.asarray(topo.mixing_matrix(t, 8), jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(W @ x),
                                   atol=1e-5)
    print("SHARD_MAP_OK")
""")


def test_shard_map_ppermute_equals_dense():
    """The explicit decentralized runtime (8 forced host devices) matches the
    dense mixing matrix — run in a subprocess so this test session's device
    count is untouched."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", _SHARD_MAP_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=300)
    assert "SHARD_MAP_OK" in out.stdout, out.stderr[-2000:]
