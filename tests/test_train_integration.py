"""End-to-end training integration: every algorithm runs; PGA learns; the
checkpoint roundtrip is exact; parallel == PGA(full topology) on the real
model train step."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import (DataConfig, DistConfig, OptimizerConfig,
                           TrainConfig, get_model_config)
from repro.train import Trainer

CFG = get_model_config("pga-lm-100m", reduced=True)


def _tcfg(algorithm="gossip_pga", topology="ring", H=4, opt="adamw",
          lr=3e-3):
    return TrainConfig(
        model=CFG,
        dist=DistConfig(algorithm=algorithm, topology=topology, H=H),
        optimizer=OptimizerConfig(name=opt, lr=lr, schedule="constant",
                                  warmup_steps=0, grad_clip=1.0),
        data=DataConfig(non_iid=True), global_batch=8, seq_len=32,
        log_every=0)


@pytest.mark.repro_guards
@pytest.mark.parametrize("algorithm", ["parallel", "gossip", "local",
                                       "gossip_pga", "gossip_aga", "slowmo"])
def test_every_algorithm_runs(algorithm):
    """Guarded suite: under ``--repro-guards`` the whole run executes with
    the transfer guard + leak checking on, proving the log_every=0 hot
    path of every algorithm never implicitly syncs (assertions below use
    explicit ``jax.device_get`` only)."""
    tr = Trainer(_tcfg(algorithm), n_nodes=4)
    state = tr.init_state(jax.random.PRNGKey(0))
    state = tr.run(state, steps=5, log_every=0)
    host = jax.device_get((state.step, state.params))
    assert int(host[0]) == 5
    for leaf in jax.tree.leaves(host[1]):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))


def test_pga_learns():
    tr = Trainer(_tcfg(), n_nodes=4, with_consensus=True)
    state = tr.init_state(jax.random.PRNGKey(0))
    tr.run(state, steps=30, log_every=29)
    assert tr.history[-1]["loss"] < tr.history[0]["loss"] - 0.2


def test_parallel_equals_pga_full_topology_exactly():
    """W = J reduction on the full train step (paper §3: Gossip-PGA with
    W = (1/n)𝟙𝟙ᵀ *is* parallel SGD)."""
    out = {}
    for alg, topology in [("parallel", "full"), ("gossip_pga", "full")]:
        tr = Trainer(_tcfg(alg, topology=topology, H=1, opt="sgd", lr=0.05),
                     n_nodes=4)
        state = tr.init_state(jax.random.PRNGKey(7))
        state = tr.run(state, steps=4, log_every=0)
        out[alg] = jax.tree.leaves(state.params)[0]
    np.testing.assert_allclose(np.asarray(out["parallel"], np.float32),
                               np.asarray(out["gossip_pga"], np.float32),
                               atol=1e-5)


def test_nodes_stay_identical_under_parallel():
    tr = Trainer(_tcfg("parallel"), n_nodes=4, with_consensus=True)
    state = tr.init_state(jax.random.PRNGKey(0))
    tr.run(state, steps=3, log_every=2)
    assert tr.history[-1]["consensus"] < 1e-8


def test_checkpoint_roundtrip():
    tr = Trainer(_tcfg(), n_nodes=2)
    state = tr.init_state(jax.random.PRNGKey(0))
    state = tr.run(state, steps=2, log_every=0)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, state, 2)
        restored = restore_checkpoint(d, state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gossip_nodes_diverge_then_global_resyncs():
    """Consensus grows between global averages and collapses at the sync —
    the mechanism PGA exploits (paper §4 Intuition)."""
    tcfg = _tcfg("gossip_pga", topology="disconnected", H=5)
    tr = Trainer(tcfg, n_nodes=4, with_consensus=True)
    state = tr.init_state(jax.random.PRNGKey(0))
    cons = []
    for k in range(5):
        state = tr.run(state, steps=1, log_every=0)
        from repro.train.state import consensus_distance
        cons.append(float(consensus_distance(state.params)))
    # steps 1-4: disconnected gossip (=no comm) -> consensus grows
    assert cons[3] > cons[0] * 0.9 and cons[3] > 0
    # step 5 = global averaging -> consensus ~0
    assert cons[4] < 1e-8
