"""Topology invariants: Assumption 3 of the paper + transient-stage theory."""
import numpy as np
import pytest

from repro.core import topology as topo

SIZES = [2, 4, 8, 16, 32, 64]
STATIC = ["ring", "grid", "exp", "full"]


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("t", STATIC)
def test_doubly_stochastic(t, n):
    W = topo.mixing_matrix(t, n)
    assert topo.is_doubly_stochastic(W), (t, n)


@pytest.mark.parametrize("n", [4, 8, 16])
def test_one_peer_exp_doubly_stochastic_every_step(n):
    for k in range(int(np.log2(n)) * 2):
        W = topo.mixing_matrix("one_peer_exp", n, step=k)
        assert topo.is_doubly_stochastic(W)


@pytest.mark.parametrize("t", STATIC)
@pytest.mark.parametrize("n", [4, 16, 64])
def test_beta_in_range(t, n):
    b = topo.beta(topo.mixing_matrix(t, n))
    assert 0.0 <= b < 1.0 + 1e-9, (t, n, b)
    if t == "full":
        assert b < 1e-9


def test_beta_ordering_sparser_is_larger():
    # paper Remark 1: sparser topology => larger beta
    n = 64
    b_ring = topo.beta(topo.mixing_matrix("ring", n))
    b_grid = topo.beta(topo.mixing_matrix("grid", n))
    b_exp = topo.beta(topo.mixing_matrix("exp", n))
    assert b_ring > b_grid > b_exp


def test_ring_beta_grows_with_n():
    betas = [topo.beta(topo.mixing_matrix("ring", n)) for n in [8, 16, 32, 64]]
    assert all(b2 > b1 for b1, b2 in zip(betas, betas[1:]))
    # 1 - beta = O(1/n^2) for the ring (paper Table 13)
    assert 1 - betas[-1] < 0.01


def test_one_peer_exp_exact_average_after_log_n():
    # product of one period of one-peer-exp matrices == J (paper §3)
    n = 16
    P = np.eye(n)
    for k in range(4):
        P = topo.mixing_matrix("one_peer_exp", n, step=k) @ P
    np.testing.assert_allclose(P, np.ones((n, n)) / n, atol=1e-12)
    assert topo.effective_beta("one_peer_exp", n) == 0.0


# ---------------------------------------------------------------------------
# Paper quantities
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("beta", [0.1, 0.5, 0.9, 0.99])
@pytest.mark.parametrize("H", [2, 6, 16, 64])
def test_c_beta_bound(beta, H):
    # C_beta = (1-beta^H)/(1-beta) < min{H, 1/(1-beta)}  (paper Table 2)
    cb = topo.c_beta(beta, H)
    assert cb < min(H, 1.0 / (1.0 - beta)) + 1e-12
    np.testing.assert_allclose(cb, sum(beta ** k for k in range(H)))


@pytest.mark.parametrize("iid", [True, False])
@pytest.mark.parametrize("H", [4, 16, 64])
@pytest.mark.parametrize("beta", [0.3, 0.9, 0.999])
def test_transient_stage_orderings(iid, H, beta):
    """Tables 2 & 3: Gossip-PGA always has the shortest transient stage."""
    n = 64
    t_pga = topo.transient_stage("gossip_pga", n, beta, H, iid=iid)
    t_gossip = topo.transient_stage("gossip", n, beta, H, iid=iid)
    t_local = topo.transient_stage("local", n, beta, H, iid=iid)
    assert t_pga <= t_gossip + 1e-9
    assert t_pga <= t_local + 1e-9


def test_transient_gossip_blows_up_as_beta_to_1():
    n = 64
    t_9 = topo.transient_stage("gossip", n, 0.9, 16)
    t_999 = topo.transient_stage("gossip", n, 0.999, 16)
    p_9 = topo.transient_stage("gossip_pga", n, 0.9, 16)
    p_999 = topo.transient_stage("gossip_pga", n, 0.999, 16)
    # gossip grows ~(1-beta)^-4; PGA is capped by H
    assert t_999 / t_9 > 1e3
    assert p_999 / p_9 < 1e2


def test_schedule_period():
    assert topo.schedule_period("ring", 16) == 1
    assert topo.schedule_period("one_peer_exp", 16) == 4
    assert topo.schedule_period("one_peer_exp", 1) == 1
    assert topo.schedule_period("directed_ring", 16) == 1
    assert topo.schedule_period("directed_exp", 16) == 1


def test_schedule_period_unknown_topology_raises():
    # regression: the old helper returned 1 for ANY string, silently running
    # typo'd topologies as "static, period 1"
    with pytest.raises(ValueError, match="unknown topology"):
        topo.schedule_period("rnig", 16)
    with pytest.raises(ValueError, match="unknown topology"):
        topo.schedule_period("", 8)


# ---------------------------------------------------------------------------
# Directed topologies / push-sum matrices (DESIGN.md §2.5)
# ---------------------------------------------------------------------------
DIRECTED = list(topo.DIRECTED_TOPOLOGIES)


@pytest.mark.parametrize("t", DIRECTED)
@pytest.mark.parametrize("n", [2, 4, 8, 16, 32])
def test_directed_doubly_stochastic_fault_free(t, n):
    # circulants with weights summing to 1 are doubly stochastic even when
    # asymmetric; column-stochasticity-only appears under faults
    W = topo.mixing_matrix(t, n)
    assert topo.is_doubly_stochastic(W), (t, n)
    assert topo.is_column_stochastic(W), (t, n)
    if n >= 4:   # n == 2 degenerates: the one-hop peer is symmetric
        assert not np.array_equal(W, W.T), (t, n)   # genuinely directed


@pytest.mark.parametrize("t", DIRECTED)
@pytest.mark.parametrize("n", [4, 16])
def test_push_sum_matrix_full_participation_equals_mixing_matrix(t, n):
    for s in range(topo.schedule_period(t, n)):
        np.testing.assert_array_equal(topo.push_sum_matrix(t, n, step=s),
                                      topo.mixing_matrix(t, n, step=s))


@pytest.mark.parametrize("t", DIRECTED)
def test_push_sum_matrix_drop_is_column_stochastic_not_doubly(t):
    n = 16
    active = np.ones(n, dtype=bool)
    active[[3, 5]] = False
    W = topo.push_sum_matrix(t, n, active=active)
    assert topo.is_column_stochastic(W)
    assert not topo.is_doubly_stochastic(W)
    # dropped nodes are isolated on identity rows/columns (frozen mass)
    for j in (3, 5):
        np.testing.assert_array_equal(W[j], np.eye(n)[j])
        np.testing.assert_array_equal(W[:, j], np.eye(n)[:, j])


@pytest.mark.parametrize("t", DIRECTED)
@pytest.mark.parametrize("n", [4, 16, 64])
def test_beta_directed_in_range(t, n):
    b = topo.beta(topo.mixing_matrix(t, n))
    assert 0.0 <= b < 1.0, (t, n, b)


def test_beta_column_stochastic_uses_perron_vector():
    # a weighted directed ring where one sender keeps extra self-mass:
    # column-stochastic, NOT doubly stochastic, but irreducible+aperiodic
    n = 4
    W = topo.push_sum_matrix("directed_ring", n)
    W[:, 0] = 0.0
    W[0, 0], W[3, 0] = 0.75, 0.25
    assert topo.is_column_stochastic(W)
    assert not topo.is_doubly_stochastic(W)
    b = topo.beta(W)
    assert 0.0 < b < 1.0, b
    pi = topo.perron_vector(W)
    np.testing.assert_allclose(W @ pi, pi, atol=1e-12)
    np.testing.assert_allclose(pi.sum(), 1.0, atol=1e-12)


def test_beta_fault_matrix_is_honest_about_partition():
    # dropped nodes partition the graph: no global consensus, so beta >= 1
    n = 8
    active = np.ones(n, dtype=bool)
    active[2] = False
    b = topo.beta(topo.push_sum_matrix("directed_exp", n, active=active))
    assert b >= 1.0 - 1e-9, b


def test_beta_rejects_non_stochastic():
    # regression: the old beta() returned ||W - J||_2 for ANY matrix
    with pytest.raises(ValueError, match="column.*stochastic"):
        topo.beta(np.array([[0.5, 0.5], [0.5, 0.6]]))


def test_beta_doubly_stochastic_path_unchanged():
    # the Perron generalization must keep the Assumption-3 path bitwise
    for t in ("ring", "exp", "full"):
        W = topo.mixing_matrix(t, 16)
        J = np.ones((16, 16)) / 16
        want = float(np.linalg.svd(W - J, compute_uv=False)[0])
        assert topo.beta(W) == want


@pytest.mark.parametrize("n", [4, 8, 16])
def test_global_push_matrix(n):
    # full participation: exactly J (resets every weight to 1)
    np.testing.assert_array_equal(topo.global_push_matrix(n),
                                  np.ones((n, n)) / n)
    active = np.ones(n, dtype=bool)
    active[0] = False
    G = topo.global_push_matrix(n, active)
    assert topo.is_column_stochastic(G)
    # active block averages over the live set; dropped node keeps its mass
    np.testing.assert_array_equal(G[0], np.eye(n)[0])
    np.testing.assert_allclose(G[1:, 1:], np.ones((n - 1, n - 1)) / (n - 1))


def test_directed_weights_are_dyadic():
    # power-of-two weights => exact fp column sums => the push-sum weight
    # stays *bitwise* 1.0 under full participation
    for t in DIRECTED:
        for w in topo.shift_weights(t, 16).values():
            m, e = np.frexp(w)
            assert m == 0.5, (t, w)
