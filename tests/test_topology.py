"""Topology invariants: Assumption 3 of the paper + transient-stage theory."""
import numpy as np
import pytest

from repro.core import topology as topo

SIZES = [2, 4, 8, 16, 32, 64]
STATIC = ["ring", "grid", "exp", "full"]


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("t", STATIC)
def test_doubly_stochastic(t, n):
    W = topo.mixing_matrix(t, n)
    assert topo.is_doubly_stochastic(W), (t, n)


@pytest.mark.parametrize("n", [4, 8, 16])
def test_one_peer_exp_doubly_stochastic_every_step(n):
    for k in range(int(np.log2(n)) * 2):
        W = topo.mixing_matrix("one_peer_exp", n, step=k)
        assert topo.is_doubly_stochastic(W)


@pytest.mark.parametrize("t", STATIC)
@pytest.mark.parametrize("n", [4, 16, 64])
def test_beta_in_range(t, n):
    b = topo.beta(topo.mixing_matrix(t, n))
    assert 0.0 <= b < 1.0 + 1e-9, (t, n, b)
    if t == "full":
        assert b < 1e-9


def test_beta_ordering_sparser_is_larger():
    # paper Remark 1: sparser topology => larger beta
    n = 64
    b_ring = topo.beta(topo.mixing_matrix("ring", n))
    b_grid = topo.beta(topo.mixing_matrix("grid", n))
    b_exp = topo.beta(topo.mixing_matrix("exp", n))
    assert b_ring > b_grid > b_exp


def test_ring_beta_grows_with_n():
    betas = [topo.beta(topo.mixing_matrix("ring", n)) for n in [8, 16, 32, 64]]
    assert all(b2 > b1 for b1, b2 in zip(betas, betas[1:]))
    # 1 - beta = O(1/n^2) for the ring (paper Table 13)
    assert 1 - betas[-1] < 0.01


def test_one_peer_exp_exact_average_after_log_n():
    # product of one period of one-peer-exp matrices == J (paper §3)
    n = 16
    P = np.eye(n)
    for k in range(4):
        P = topo.mixing_matrix("one_peer_exp", n, step=k) @ P
    np.testing.assert_allclose(P, np.ones((n, n)) / n, atol=1e-12)
    assert topo.effective_beta("one_peer_exp", n) == 0.0


# ---------------------------------------------------------------------------
# Paper quantities
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("beta", [0.1, 0.5, 0.9, 0.99])
@pytest.mark.parametrize("H", [2, 6, 16, 64])
def test_c_beta_bound(beta, H):
    # C_beta = (1-beta^H)/(1-beta) < min{H, 1/(1-beta)}  (paper Table 2)
    cb = topo.c_beta(beta, H)
    assert cb < min(H, 1.0 / (1.0 - beta)) + 1e-12
    np.testing.assert_allclose(cb, sum(beta ** k for k in range(H)))


@pytest.mark.parametrize("iid", [True, False])
@pytest.mark.parametrize("H", [4, 16, 64])
@pytest.mark.parametrize("beta", [0.3, 0.9, 0.999])
def test_transient_stage_orderings(iid, H, beta):
    """Tables 2 & 3: Gossip-PGA always has the shortest transient stage."""
    n = 64
    t_pga = topo.transient_stage("gossip_pga", n, beta, H, iid=iid)
    t_gossip = topo.transient_stage("gossip", n, beta, H, iid=iid)
    t_local = topo.transient_stage("local", n, beta, H, iid=iid)
    assert t_pga <= t_gossip + 1e-9
    assert t_pga <= t_local + 1e-9


def test_transient_gossip_blows_up_as_beta_to_1():
    n = 64
    t_9 = topo.transient_stage("gossip", n, 0.9, 16)
    t_999 = topo.transient_stage("gossip", n, 0.999, 16)
    p_9 = topo.transient_stage("gossip_pga", n, 0.9, 16)
    p_999 = topo.transient_stage("gossip_pga", n, 0.999, 16)
    # gossip grows ~(1-beta)^-4; PGA is capped by H
    assert t_999 / t_9 > 1e3
    assert p_999 / p_9 < 1e2


def test_schedule_period():
    assert topo.schedule_period("ring", 16) == 1
    assert topo.schedule_period("one_peer_exp", 16) == 4
    assert topo.schedule_period("one_peer_exp", 1) == 1
