"""Checkpoint correctness for compressed runs (ISSUE-4 bugfixes).

Pre-fix failure modes under test:

* ``np.savez`` cannot round-trip ml_dtypes leaves (bf16 params, fp8
  buffers) — depending on numpy it raises or silently degrades them to
  raw ``|V``-kind void that restore cannot cast.  The fix stores such
  leaves as same-width bit views recorded in the manifest's ``dtypes``
  entry — bitwise, so resume is exact.
* a ``TrainState`` with ``ef_state`` restored into a template whose
  ``ef_state=None`` dropped the error-feedback memory (and the reverse
  direction KeyError'd).  Restore now reconciles both directions.
* the headline guarantee: train k compressed steps → save → restore →
  continue equals the uninterrupted run **bitwise** — for the identity
  compressor and for int8+EF (stochastic rounding is seeded by the
  absolute step, the data stream and LR by the absolute counter, so a
  bitwise state restore implies a bitwise trajectory).
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import (DataConfig, DistConfig, OptimizerConfig,
                           TrainConfig, get_model_config)
from repro.train import Trainer
from repro.train.state import TrainState

CFG = get_model_config("qwen3-0.6b", reduced=True)


def _state(params, ef=None, step=0):
    return TrainState(params=params, opt_state={"momentum": params},
                      step=jnp.asarray(step, jnp.int32), ef_state=ef)


def _assert_tree_bitwise(got, want):
    assert jax.tree.structure(got) == jax.tree.structure(want)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert g.dtype == w.dtype, (g.dtype, w.dtype)
        gb, wb = np.asarray(g), np.asarray(w)
        if gb.dtype.kind == "V":          # ml_dtypes: compare raw bits
            view = {1: np.uint8, 2: np.uint16}[gb.dtype.itemsize]
            gb, wb = gb.view(view), wb.view(view)
        np.testing.assert_array_equal(gb, wb)


# ---------------------------------------------------------------------------
# dtype manifest: ml_dtypes leaves survive npz bitwise
# ---------------------------------------------------------------------------
def test_bf16_and_fp8_leaves_roundtrip_bitwise():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (4, 3)).astype(jnp.bfloat16),
              "b": jax.random.normal(key, (4,)).astype(jnp.float32),
              "q": jax.random.normal(key, (4, 2)).astype(jnp.float8_e4m3fn),
              "s": jnp.asarray(1.25, jnp.bfloat16)}       # 0-d bf16
    st = _state(params, step=3)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, st, 3)
        restored = restore_checkpoint(d, _state(params))
    _assert_tree_bitwise(restored.params, params)
    assert int(restored.step) == 3


def test_manifest_records_ml_dtypes():
    import json
    import os
    params = {"w": jnp.ones((2, 2), jnp.bfloat16),
              "b": jnp.ones((2,), jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, _state(params), 1)
        man = json.load(open(os.path.join(d, "manifest.json")))
        assert man["dtypes"] == {".params/w": "bfloat16",
                                 ".opt_state/momentum/w": "bfloat16"}
        # the npz itself holds the bit view, loadable by vanilla numpy
        data = np.load(os.path.join(d, "ckpt_00000001.npz"))
        assert data[".params/w"].dtype == np.uint16


def test_old_step_keeps_its_own_dtypes_after_dtype_change():
    """The dtype record rides inside each npz: saving a later checkpoint
    with different leaf dtypes must not corrupt the restore of an older
    step (the manifest.json 'dtypes' entry only describes the latest
    save)."""
    bf16 = {"w": jnp.full((3,), 1.5, jnp.bfloat16)}
    fp32 = {"w": jnp.full((3,), 1.5, jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, _state(bf16), 2)
        save_checkpoint(d, _state(fp32), 4)      # manifest now dtype-free
        restored = restore_checkpoint(d, _state(bf16), step=2)
    _assert_tree_bitwise(restored.params, bf16)  # 1.5, not 16320.0


def test_bit_view_restores_even_without_any_manifest():
    """A lost manifest.json must not silently value-cast the uint16 bit
    view into garbage bf16 values."""
    import os
    params = {"w": jnp.full((3,), 1.5, jnp.bfloat16)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, _state(params), 1)
        os.remove(os.path.join(d, "manifest.json"))
        restored = restore_checkpoint(d, _state(params))
    _assert_tree_bitwise(restored.params, params)


# ---------------------------------------------------------------------------
# ef_state reconcile, both directions
# ---------------------------------------------------------------------------
def test_bare_array_ef_state_reconciles_both_directions():
    """A single-leaf ef_state flattens to the key '.ef_state' (no slash):
    it must reconcile exactly like the params-mirroring tree."""
    params = jnp.ones((4, 3), jnp.float32)          # bare-array params too
    ef = jnp.full((4, 3), 0.25, jnp.float32)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, _state(params, ef=ef, step=1), 1)
        restored = restore_checkpoint(d, _state(params, ef=None))
        assert restored.ef_state is not None
        np.testing.assert_array_equal(np.asarray(restored.ef_state),
                                      np.asarray(ef))
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, _state(params, ef=None, step=1), 1)
        restored = restore_checkpoint(d, _state(params, ef=ef))
        assert restored.ef_state is not None
        assert float(jnp.sum(jnp.abs(restored.ef_state))) == 0.0
def test_restore_ef_into_efless_template():
    params = {"w": jnp.ones((4, 3), jnp.float32)}
    ef = {"w": jnp.full((4, 3), 0.25, jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, _state(params, ef=ef, step=2), 2)
        restored = restore_checkpoint(d, _state(params, ef=None))
    assert restored.ef_state is not None
    _assert_tree_bitwise(restored.ef_state, ef)


def test_restore_efless_ckpt_into_ef_template():
    params = {"w": jnp.ones((4, 3), jnp.float32)}
    ef_tmpl = {"w": jnp.full((4, 3), 9.0, jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, _state(params, ef=None, step=2), 2)
        restored = restore_checkpoint(d, _state(params, ef=ef_tmpl))
    # EF restarts empty when compression is newly enabled
    assert restored.ef_state is not None
    assert float(jnp.sum(jnp.abs(restored.ef_state["w"]))) == 0.0


# ---------------------------------------------------------------------------
# push_weight reconcile (DESIGN.md §2.5), both directions
# ---------------------------------------------------------------------------
def _push_state(params, w=None, step=0):
    return TrainState(params=params, opt_state={"momentum": params},
                      step=jnp.asarray(step, jnp.int32), push_weight=w)


def test_push_weight_roundtrips_bitwise():
    params = {"w": jnp.ones((4, 3), jnp.float32)}
    pw = jnp.asarray([[0.75], [1.25], [0.5], [1.5]], jnp.float32)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, _push_state(params, w=pw, step=2), 2)
        restored = restore_checkpoint(d, _push_state(params, w=pw * 0 + 1))
    np.testing.assert_array_equal(np.asarray(restored.push_weight),
                                  np.asarray(pw))


def test_push_weight_reconciles_into_none_template():
    # enabling push_sum is not required to *read back* a push-sum ckpt
    params = {"w": jnp.ones((4, 3), jnp.float32)}
    pw = jnp.asarray([[0.75], [1.25], [0.5], [1.5]], jnp.float32)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, _push_state(params, w=pw, step=1), 1)
        restored = restore_checkpoint(d, _push_state(params, w=None))
    assert restored.push_weight is not None
    np.testing.assert_array_equal(np.asarray(restored.push_weight),
                                  np.asarray(pw))


def test_push_weight_backfills_ones_from_plain_ckpt():
    # newly enabling push_sum on an old checkpoint: w must start at ONES
    # (zeros would blow up the x/w de-bias), mirroring the EF-zeros rule
    params = {"w": jnp.ones((4, 3), jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, _push_state(params, w=None, step=1), 1)
        tmpl = _push_state(params, w=jnp.full((4, 1), 9.0, jnp.float32))
        restored = restore_checkpoint(d, tmpl)
    np.testing.assert_array_equal(np.asarray(restored.push_weight),
                                  np.ones((4, 1), np.float32))


# ---------------------------------------------------------------------------
# resume parity: save → restore → continue == uninterrupted, bitwise
# ---------------------------------------------------------------------------
def _tcfg(ckpt_dir, **dist_kw):
    dist_kw.setdefault("topology", "ring")
    return TrainConfig(
        model=CFG,
        dist=DistConfig(algorithm="gossip_pga", H=2, **dist_kw),
        optimizer=OptimizerConfig(name="sgd", lr=0.05, schedule="constant",
                                  warmup_steps=0),
        data=DataConfig(non_iid=True), global_batch=8, seq_len=16,
        steps=4, log_every=0, ckpt_every=2, ckpt_dir=ckpt_dir)


@pytest.mark.parametrize("dist_kw", [
    {"comm_compression": "identity"},
    {"comm_compression": "int8", "comm_error_feedback": True},
    {"comm_global_compression": "int8", "comm_error_feedback": True},
])
def test_compressed_resume_matches_uninterrupted(dist_kw):
    with tempfile.TemporaryDirectory() as d:
        tcfg = _tcfg(d, **dist_kw)
        # uninterrupted: 4 steps straight (checkpoints written at 2 and 4)
        tr = Trainer(tcfg, n_nodes=4)
        full = tr.run(tr.init_state(jax.random.PRNGKey(0)), steps=4)
        # interrupted: a fresh Trainer restores the step-2 checkpoint and
        # continues — schedule/LR/data/SR-seed all key on the absolute
        # step, so the trajectories must coincide bitwise
        tr2 = Trainer(tcfg, n_nodes=4)
        template = tr2.init_state(jax.random.PRNGKey(0))
        state = restore_checkpoint(d, template, step=2)
        assert int(state.step) == 2
        resumed = tr2.run(state, steps=2)
        _assert_tree_bitwise(resumed.params, full.params)
        _assert_tree_bitwise(resumed.opt_state, full.opt_state)
        if full.ef_state is not None:
            _assert_tree_bitwise(resumed.ef_state, full.ef_state)
        assert int(resumed.step) == int(full.step) == 4


def test_push_sum_fault_resume_matches_uninterrupted():
    """Push-sum run with a mid-run drop: save → restore → continue equals
    the uninterrupted run bitwise, including the push weight, and the
    fault counters reconcile through the sidecar."""
    from repro.core.faults import FaultSchedule

    def faults():
        return FaultSchedule(n_nodes=4, drops={1: (2,)}, rejoins={3: (2,)},
                             seed=0)

    with tempfile.TemporaryDirectory() as d:
        tcfg = _tcfg(d, topology="directed_exp", push_sum=True)
        tr = Trainer(tcfg, n_nodes=4, fault_schedule=faults())
        full = tr.run(tr.init_state(jax.random.PRNGKey(0)), steps=4)
        tr2 = Trainer(tcfg, n_nodes=4, fault_schedule=faults())
        state = restore_checkpoint(d, tr2.init_state(jax.random.PRNGKey(0)),
                                   step=2)
        assert state.push_weight is not None
        resumed = tr2.run(state, steps=2)
        _assert_tree_bitwise(resumed.params, full.params)
        _assert_tree_bitwise(resumed.opt_state, full.opt_state)
        np.testing.assert_array_equal(np.asarray(resumed.push_weight),
                                      np.asarray(full.push_weight))
        assert tr2.fault_schedule.state_dict() == \
            tr.fault_schedule.state_dict()
        import os
        assert os.path.exists(os.path.join(d, "faults_00000002.json"))


def test_resume_across_ef_enablement():
    """A run that newly enables compression restores an EF-less checkpoint
    cleanly: EF starts at zeros instead of KeyError-ing."""
    with tempfile.TemporaryDirectory() as d:
        plain = _tcfg(d)
        tr = Trainer(plain, n_nodes=4)
        tr.run(tr.init_state(jax.random.PRNGKey(0)), steps=2)
        comp = _tcfg(d, comm_compression="int8", comm_error_feedback=True)
        tr2 = Trainer(comp, n_nodes=4)
        template = tr2.init_state(jax.random.PRNGKey(0))
        assert template.ef_state is not None
        state = restore_checkpoint(d, template, step=2)
        assert state.ef_state is not None
        state = tr2.run(state, steps=2)
        assert int(state.step) == 4
        for leaf in jax.tree.leaves(state.params):
            assert np.all(np.isfinite(np.asarray(leaf, np.float32)))


def test_aga_resume_matches_uninterrupted_schedule():
    """gossip_aga's period counter and H adaptation are training state:
    the schedule sidecar written next to each checkpoint must restore
    them, so a resumed run fires global rounds on the same steps as the
    uninterrupted one."""
    from repro.core.schedule import AGASchedule

    def drive(sched, ks, losses):
        out = []
        for k in ks:
            sched.observe_loss(k, losses(k))
            out.append(sched.advance(k))
        return out

    def losses(k):
        return 10.0 / (1 + k)

    full = AGASchedule(H_init=2, warmup=4, H_max=32)
    want = drive(full, range(24), losses)

    first = AGASchedule(H_init=2, warmup=4, H_max=32)
    got = drive(first, range(12), losses)
    resumed = AGASchedule(H_init=2, warmup=4, H_max=32)
    resumed.load_state_dict(first.state_dict())        # the sidecar payload
    got += drive(resumed, range(12, 24), losses)
    assert got == want
    assert resumed.current_H == full.current_H


def test_trainer_aga_resume_end_to_end_bitwise():
    """The normal resume flow (restore_checkpoint → Trainer.run) reloads
    the AGA sidecar automatically: the resumed run's params — which
    depend on *when* global rounds fired and how H adapted — match the
    uninterrupted run bitwise."""
    def tcfg(d):
        return TrainConfig(
            model=CFG,
            dist=DistConfig(algorithm="gossip_aga", topology="ring",
                            aga_h_init=2, aga_warmup=1),
            optimizer=OptimizerConfig(name="sgd", lr=0.05,
                                      schedule="constant", warmup_steps=0),
            data=DataConfig(non_iid=True), global_batch=8, seq_len=16,
            steps=6, log_every=0, ckpt_every=3, ckpt_dir=d)

    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(tcfg(d), n_nodes=4)
        full = tr.run(tr.init_state(jax.random.PRNGKey(0)), steps=6)
        tr2 = Trainer(tcfg(d), n_nodes=4)
        state = restore_checkpoint(d, tr2.init_state(jax.random.PRNGKey(0)),
                                   step=3)
        resumed = tr2.run(state, steps=3)
        _assert_tree_bitwise(resumed.params, full.params)
        assert tr2.schedule.state_dict() == tr.schedule.state_dict()


def test_trainer_writes_and_loads_aga_schedule_sidecar():
    import os
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainConfig(
            model=CFG,
            dist=DistConfig(algorithm="gossip_aga", topology="ring",
                            aga_h_init=2, aga_warmup=2),
            optimizer=OptimizerConfig(name="sgd", lr=0.05,
                                      schedule="constant", warmup_steps=0),
            data=DataConfig(), global_batch=8, seq_len=16, steps=4,
            log_every=0, ckpt_every=2, ckpt_dir=d)
        tr = Trainer(tcfg, n_nodes=4)
        tr.run(tr.init_state(jax.random.PRNGKey(0)), steps=4)
        assert os.path.exists(os.path.join(d, "schedule_00000004.json"))
        tr2 = Trainer(tcfg, n_nodes=4)
        tr2.load_schedule(step=4)
        assert tr2.schedule.state_dict() == tr.schedule.state_dict()


# ---------------------------------------------------------------------------
# pod_avg validation (ISSUE-4 satellite): clear error, not mis-shaped halos
# ---------------------------------------------------------------------------
def test_distconfig_validate_nodes_rejects_indivisible_pods():
    dist = DistConfig(algorithm="hier_pga", n_pods=3).validate()
    with pytest.raises(ValueError, match="n_pods=3 does not divide"):
        dist.validate_nodes(8)
    dist.validate_nodes(9)                       # divides: fine


def test_trainer_rejects_indivisible_pods():
    tcfg = TrainConfig(model=CFG,
                       dist=DistConfig(algorithm="hier_pga", n_pods=3),
                       optimizer=OptimizerConfig(name="sgd", lr=0.05),
                       data=DataConfig(), global_batch=8, seq_len=16,
                       log_every=0)
    with pytest.raises(ValueError, match="n_pods=3 does not divide"):
        Trainer(tcfg, n_nodes=8)
