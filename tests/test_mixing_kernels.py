"""Pallas mixing-kernel parity: the fused backend must match the roll-based
reference (itself proven ≡ dense W in test_mixing.py) for every phase ×
topology × shape, including the bf16 wire-cast path, the fused residual
outputs, per-leaf dispatch, and the shard_map-aware sharded path (run in a
subprocess with 8 forced host devices, launch/dryrun.py convention).  All
kernels run in interpret mode on CPU (kernels/ops.py convention), so these
tests exercise the exact code that compiles to Mosaic on TPU."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mixing, topology as topo
from repro.kernels import mixing_pallas as mp

TOPOLOGIES = ["ring", "exp", "full", "grid", "one_peer_exp", "disconnected"]
# deliberately odd/ragged shapes: exercises block-padding and multi-leaf concat
SHAPES = [(5, 3), (7,), ()]


def _tree(key, n, dtype=jnp.float32):
    keys = jax.random.split(key, len(SHAPES))
    return {f"leaf{i}": jax.random.normal(k, (n,) + s).astype(dtype)
            for i, (k, s) in enumerate(zip(keys, SHAPES))}


def _assert_tree_close(got, want, atol):
    got_l, want_l = jax.tree.leaves(got), jax.tree.leaves(want)
    assert jax.tree.structure(got) == jax.tree.structure(want)
    for g, w in zip(got_l, want_l):
        assert g.dtype == w.dtype
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32), atol=atol)


# ---------------------------------------------------------------------------
# Phase parity: gossip / global / pod_avg, fp32 and bf16 wire
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("t", TOPOLOGIES)
@pytest.mark.parametrize("n", [4, 8])
@pytest.mark.parametrize("comm_dtype", [None, jnp.bfloat16])
def test_gossip_parity(t, n, comm_dtype, rng_key):
    tree = _tree(rng_key, n)
    want = mixing.mix_pytree(tree, t, n, step=3, comm_dtype=comm_dtype)
    got = mixing.mix_pytree(tree, t, n, step=3, comm_dtype=comm_dtype,
                            backend="pallas")
    _assert_tree_close(got, want, atol=1e-5 if comm_dtype is None else 3e-2)


@pytest.mark.parametrize("comm_dtype", [None, jnp.bfloat16])
def test_global_parity(comm_dtype, rng_key):
    tree = _tree(rng_key, 8)
    want = mixing.global_average_pytree(tree, comm_dtype=comm_dtype)
    got = mixing.global_average_pytree(tree, comm_dtype=comm_dtype,
                                       backend="pallas")
    _assert_tree_close(got, want, atol=1e-5 if comm_dtype is None else 3e-2)


@pytest.mark.parametrize("n_pods", [2, 4])
@pytest.mark.parametrize("comm_dtype", [None, jnp.bfloat16])
def test_pod_avg_parity(n_pods, comm_dtype, rng_key):
    tree = _tree(rng_key, 8)
    want = mixing.pod_average_pytree(tree, n_pods, comm_dtype=comm_dtype)
    got = mixing.pod_average_pytree(tree, n_pods, comm_dtype=comm_dtype,
                                    backend="pallas")
    _assert_tree_close(got, want, atol=1e-5 if comm_dtype is None else 3e-2)


@pytest.mark.parametrize("phase", ["gossip", "global", "pod_avg"])
def test_communicate_dispatch_parity(phase, rng_key):
    """The selector on mixing.communicate reaches the same numbers."""
    tree = _tree(rng_key, 8)
    spec = mixing.CommSpec(topology="one_peer_exp", n_nodes=8, n_pods=2)
    want = mixing.communicate(tree, spec, phase=phase, step=2)
    got = mixing.communicate(tree, spec.replace(backend="pallas"),
                             phase=phase, step=2)
    _assert_tree_close(got, want, atol=1e-5)


def test_one_peer_exp_time_varying_steps(rng_key):
    """Shift step must select the right one-peer graph in the kernel too."""
    n = 8
    x = jax.random.normal(rng_key, (n, 6))
    for step in range(4):
        W = jnp.asarray(topo.mixing_matrix("one_peer_exp", n, step=step))
        got = mp.fused_step_mix(x, phase="gossip", topology="one_peer_exp",
                                n_nodes=n, step=step)
        np.testing.assert_allclose(np.asarray(got), np.asarray(W @ x),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# Fused SGD half-step and residual outputs
# ---------------------------------------------------------------------------
def test_fused_half_step(rng_key):
    n, gamma = 8, 0.37
    k1, k2 = jax.random.split(rng_key)
    x, g = _tree(k1, n), _tree(k2, n)
    want = mixing.mix_pytree(
        jax.tree.map(lambda p, q: p - gamma * q, x, g), "ring", n)
    got = mp.fused_step_mix(x, g, gamma, phase="gossip", topology="ring",
                            n_nodes=n)
    _assert_tree_close(got, want, atol=1e-5)


@pytest.mark.parametrize("phase", ["gossip", "global", "pod_avg"])
def test_mix_residual_outputs(phase, rng_key):
    n = 8
    tree = _tree(rng_key, n)
    mixed, xbar, resid = mp.mix_residual(tree, phase=phase, topology="ring",
                                         n_nodes=n, n_pods=2)
    want = mixing.communicate(
        tree, mixing.CommSpec(topology="ring", n_nodes=n, n_pods=2),
        phase=phase)
    _assert_tree_close(mixed, want, atol=1e-5)
    # x̄ = node average of the mixed iterate, leaves without the node axis
    want_bar = jax.tree.map(lambda p: jnp.mean(p, axis=0), want)
    _assert_tree_close(xbar, want_bar, atol=1e-5)
    # residual = Σ_i ‖x_i − x̄‖² over every leaf of the mixed iterate
    want_r = sum(float(jnp.sum((p - jnp.mean(p, 0, keepdims=True)) ** 2))
                 for p in jax.tree.leaves(want))
    np.testing.assert_allclose(float(resid), want_r, rtol=1e-4, atol=1e-6)


def test_residual_zero_after_global(rng_key):
    """Global averaging leaves all nodes identical ⇒ residual ≈ 0."""
    _, _, resid = mp.mix_residual(_tree(rng_key, 8), phase="global",
                                  n_nodes=8)
    assert float(resid) < 1e-6


# ---------------------------------------------------------------------------
# Invariants and plumbing
# ---------------------------------------------------------------------------
def test_preserves_bf16_storage_dtype(rng_key):
    tree = _tree(rng_key, 4, dtype=jnp.bfloat16)
    out = mp.fused_step_mix(tree, phase="gossip", topology="ring", n_nodes=4)
    want = mixing.mix_pytree(tree, "ring", 4)
    # kernel accumulates in fp32 (reference accumulates in bf16): bf16 tol
    _assert_tree_close(out, want, atol=3e-2)


def test_gossip_preserves_node_average(rng_key):
    """𝟙ᵀW = 𝟙ᵀ must survive the kernelization."""
    x = jax.random.normal(rng_key, (8, 33))
    mixed = mp.fused_step_mix(x, phase="gossip", topology="exp", n_nodes=8)
    np.testing.assert_allclose(np.asarray(mixed.mean(0)),
                               np.asarray(x.mean(0)), atol=1e-5)


def test_block_boundary_independence(rng_key):
    """Numbers must not depend on the grid block size (padding masked)."""
    x = jax.random.normal(rng_key, (8, 37))
    outs = [np.asarray(mp.fused_step_mix(x, phase="gossip", topology="ring",
                                         n_nodes=8, block_d=bd))
            for bd in (1, 8, 64, 2048)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-6)


def test_simulate_backend_parity(rng_key):
    """Whole-trajectory check: simulate() with backend='pallas' (fused
    half-step + eval residual) tracks the reference trajectory."""
    from repro.core.algorithms import simulate
    d = 6
    A = np.asarray(np.random.default_rng(0).normal(size=(d, d)))
    A = jnp.asarray(A @ A.T / d + np.eye(d), jnp.float32)

    def grad_fn(xs, key, k):
        return xs @ A + jax.random.normal(key, xs.shape) * 0.01

    outs = {b: simulate(algorithm="gossip_pga", grad_fn=grad_fn,
                        loss_fn=lambda x: 0.5 * x @ A @ x,
                        x0=jnp.ones((d,), jnp.float32), n=8, steps=20,
                        lr=0.05, topology="ring", H=4, eval_every=5,
                        backend=b)
            for b in ("reference", "pallas")}
    np.testing.assert_allclose(outs["reference"]["loss"],
                               outs["pallas"]["loss"], rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(outs["reference"]["consensus"],
                               outs["pallas"]["consensus"], rtol=1e-3,
                               atol=1e-5)


def test_pallas_backend_rejects_nonzero_axis(rng_key):
    x = jax.random.normal(rng_key, (3, 8))
    with pytest.raises(ValueError, match="axis"):
        mixing.mix_pytree(x, "ring", 8, axis=1, backend="pallas")


def test_unknown_backend_rejected(rng_key):
    x = jax.random.normal(rng_key, (8, 4))
    with pytest.raises(ValueError, match="backend"):
        mixing.mix_pytree(x, "ring", 8, backend="cuda")


def test_backend_error_names_entry_point(rng_key):
    """The axis/backend raise must name the public entry point that reached
    the check, so a failure routed through simulate()/Decentralized is
    attributable (previously the message carried no caller)."""
    x = jax.random.normal(rng_key, (3, 8))
    with pytest.raises(ValueError, match=r"mixing\.mix_pytree.*axis=1"):
        mixing.mix_pytree(x, "ring", 8, axis=1, backend="pallas")
    with pytest.raises(ValueError, match=r"mixing\.communicate.*axis=2"):
        mixing.communicate(
            x, mixing.CommSpec(topology="ring", n_nodes=8,
                               backend="pallas"), phase="gossip", axis=2)
    with pytest.raises(ValueError, match=r"mixing\.communicate.*cuda"):
        mixing.communicate(
            x, mixing.CommSpec(topology="ring", n_nodes=8,
                               backend="cuda"), phase="gossip")


def test_backend_validated_before_noop_early_returns(rng_key):
    """n == 1 / disconnected rounds are no-ops, but a bogus backend or axis
    must still raise instead of silently dropping to the reference path."""
    x = jax.random.normal(rng_key, (1, 4))
    with pytest.raises(ValueError, match="backend"):
        mixing.mix_pytree(x, "ring", 1, backend="cuda")
    with pytest.raises(ValueError, match="axis"):
        mixing.mix_pytree(x, "disconnected", 8, axis=1, backend="pallas")


# ---------------------------------------------------------------------------
# Per-leaf dispatch and the aliasing contract
# ---------------------------------------------------------------------------
def test_leaf_dispatch_threshold_independence(rng_key):
    """Numbers must not depend on how leaves are grouped into dispatches:
    all-in-one staging buffer, every-leaf-its-own-kernel, and mixed."""
    tree = _tree(rng_key, 8)
    base = mp.fused_step_mix(tree, phase="gossip", topology="ring", n_nodes=8)
    for thresh in (1, 8, 10**9):  # all big / split / all small
        got = mp.fused_step_mix(tree, phase="gossip", topology="ring",
                                n_nodes=8, leaf_threshold=thresh)
        _assert_tree_close(got, base, atol=0)  # per-column math is identical


def test_leaf_dispatch_residual_combines_exactly(rng_key):
    tree = _tree(rng_key, 8)
    m0, x0, r0 = mp.mix_residual(tree, phase="gossip", topology="exp",
                                 n_nodes=8)
    m1, x1, r1 = mp.mix_residual(tree, phase="gossip", topology="exp",
                                 n_nodes=8, leaf_threshold=1)
    _assert_tree_close(m1, m0, atol=0)
    _assert_tree_close(x1, x0, atol=1e-6)
    np.testing.assert_allclose(float(r1), float(r0), rtol=1e-5)


def test_aliasing_does_not_clobber_caller_input(rng_key):
    """input_output_aliases is an in-place contract on the *packed staging
    buffer*; the caller's arrays must come back untouched."""
    x = jax.random.normal(rng_key, (8, 37))
    before = np.asarray(x).copy()
    mp.fused_step_mix(x, phase="gossip", topology="ring", n_nodes=8)
    np.testing.assert_array_equal(np.asarray(x), before)


# ---------------------------------------------------------------------------
# shard_map-aware sharded path (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------
_SHARDED_PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import mixing

    mesh = jax.make_mesh((8,), ("data",))
    SHAPES = [(5, 3), (7,), ()]

    def tree(key, n):
        ks = jax.random.split(key, len(SHAPES))
        return {f"leaf{i}": jax.random.normal(k, (n,) + s)
                for i, (k, s) in enumerate(zip(ks, SHAPES))}

    def close(got, want, atol):
        assert jax.tree.structure(got) == jax.tree.structure(want)
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            assert g.dtype == w.dtype
            np.testing.assert_allclose(np.asarray(g, np.float32),
                                       np.asarray(w, np.float32), atol=atol)

    key, n = jax.random.PRNGKey(0), 16
    CASES = ([("gossip", t, 1) for t in
              ("ring", "exp", "one_peer_exp", "grid", "disconnected")]
             + [("global", "ring", 1), ("pod_avg", "ring", 2),
                ("pod_avg", "ring", 4)])
    for phase, topol, n_pods in CASES:
        for cd in (None, jnp.bfloat16):
            t = tree(key, n)
            kw = dict(phase=phase, topology=topol, n_nodes=n, step=3,
                      comm_dtype=cd, n_pods=n_pods)
            want = mixing.communicate(t, **kw)
            got = mixing.communicate(t, backend="pallas", mesh=mesh, **kw)
            close(got, want, 1e-5 if cd is None else 3e-2)
            print(f"PARITY_OK {phase}/{topol}/p{n_pods}/"
                  f"{'fp32' if cd is None else 'bf16'}")

    # fused residual: psum-combined consensus matches the direct form
    t = tree(key, n)
    mixed, xbar, resid = mixing.communicate_sharded(
        t, phase="gossip", topology="ring", n_nodes=n, mesh=mesh,
        with_residual=True)
    want = mixing.communicate(t, phase="gossip", topology="ring", n_nodes=n)
    close(mixed, want, 1e-5)
    close(xbar, jax.tree.map(lambda p: jnp.mean(p, 0), want), 1e-5)
    want_r = sum(float(jnp.sum((p - jnp.mean(p, 0, keepdims=True)) ** 2))
                 for p in jax.tree.leaves(want))
    np.testing.assert_allclose(float(resid), want_r, rtol=1e-4, atol=1e-6)
    print("RESIDUAL_OK")

    # fused SGD half-step before the halo exchange
    g = tree(jax.random.PRNGKey(1), n)
    got = mixing.communicate_sharded(t, phase="gossip", topology="ring",
                                     n_nodes=n, mesh=mesh, grads=g,
                                     gamma=0.37)
    want = mixing.communicate(jax.tree.map(lambda p, q: p - 0.37 * q, t, g),
                              phase="gossip", topology="ring", n_nodes=n)
    close(got, want, 1e-5)
    print("HALFSTEP_OK")

    # flattened (pod, data) node axis — DistConfig.node_axis="data" semantics
    mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
    got = mixing.communicate(t, phase="gossip", topology="exp", n_nodes=n,
                             backend="pallas", mesh=mesh2)
    close(got, mixing.communicate(t, phase="gossip", topology="exp",
                                  n_nodes=n), 1e-5)
    print("POD_DATA_OK")

    # shard_mode="stacked" forces the local kernels even under a mesh
    got = mixing.communicate(t, phase="gossip", topology="ring", n_nodes=n,
                             backend="pallas", mesh=mesh,
                             shard_mode="stacked")
    close(got, mixing.communicate(t, phase="gossip", topology="ring",
                                  n_nodes=n, backend="pallas"), 1e-6)
    print("STACKED_OVERRIDE_OK")

    # constant state is a fixed point under sharding too
    c = jax.tree.map(lambda p: jnp.full_like(p, 1.5), t)
    got = mixing.communicate(c, phase="gossip", topology="ring", n_nodes=n,
                             backend="pallas", mesh=mesh)
    close(got, c, 1e-6)
    print("CONSTANT_OK")
""")


def _run_forced_device_script(script: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, (out.stdout[-2000:] + out.stderr[-4000:])
    return out.stdout


def test_sharded_pallas_parity_8dev():
    """backend='pallas' under a mesh whose node axis is sharded: the
    shard_map wrapper (ppermute halo + per-shard fused kernel) must match
    the roll-based oracle for every phase × topology × wire dtype, plus the
    fused residual, half-step, flattened (pod, data) axis, and the
    shard_mode override — all on 8 forced host devices."""
    stdout = _run_forced_device_script(_SHARDED_PARITY_SCRIPT)
    assert stdout.count("PARITY_OK") == 16, stdout
    for marker in ("RESIDUAL_OK", "HALFSTEP_OK", "POD_DATA_OK",
                   "STACKED_OVERRIDE_OK", "CONSTANT_OK"):
        assert marker in stdout, stdout


def test_node_axis_pod_without_pod_axis_is_unsharded():
    """node_axis='pod' (DistConfig's hierarchical mode) on a single-pod mesh
    — no 'pod' axis — means one gossip node and no shards, not a KeyError."""
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    assert mixing.node_axis_names(mesh, "pod") == ()
    assert mixing.node_shard_count(mesh, "pod") == 1
    assert not mixing.use_sharded_backend("pallas", mesh, "pod", "auto")
    with pytest.raises(ValueError, match="no axis"):
        mixing.communicate_sharded(
            jnp.ones((4, 2)),
            mixing.CommSpec(topology="ring", n_nodes=4, mesh=mesh,
                            node_axis="pod"), phase="gossip")


def test_shard_mode_sharded_requires_sharded_mesh(rng_key):
    """comm_shard_mode='sharded' with no mesh (or an unsharded node axis)
    must raise, not silently fall back to the stacked kernels."""
    x = jax.random.normal(rng_key, (8, 4))
    with pytest.raises(ValueError, match="sharded"):
        mixing.communicate(
            x, mixing.CommSpec(topology="ring", n_nodes=8,
                               backend="pallas", mesh=None,
                               shard_mode="sharded"), phase="gossip")
    with pytest.raises(ValueError, match="shard_mode"):
        mixing.communicate(
            x, mixing.CommSpec(topology="ring", n_nodes=8,
                               backend="pallas", shard_mode="bogus"),
            phase="gossip")
