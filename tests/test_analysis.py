"""repro.analysis: per-rule good/bad fixtures, suppression and baseline
semantics, the report formats, the CLI contract — and the repo-is-clean
integration gate.

The bad fixtures are minimized replays of the historical regressions the
rules exist to catch: the pre-PR-8 ``float(metrics["loss"])`` in the
Trainer hot loop (RPR001) and the PR-7 un-copied overlap buffer escaping
``train/step.py``'s slowmo branch (RPR003).  Fixtures are written under
``tmp_path`` at the registered repo-relative paths so the rules'
path/function registries match exactly as they do on the real tree.
"""
import json
import re
import textwrap
from pathlib import Path

from repro.analysis.__main__ import main as cli
from repro.analysis.engine import (Baseline, analyze_file, analyze_paths,
                                   apply_baseline, format_findings,
                                   load_baseline, write_baseline)

REPO_ROOT = Path(__file__).resolve().parents[1]


def run(tmp_path, relpath, source):
    """Write ``source`` at ``relpath`` under a scratch root and analyze
    it; returns (findings, n_suppressed)."""
    f = tmp_path / relpath
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text(textwrap.dedent(source))
    return analyze_file(tmp_path, f)


def rules_of(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# RPR001 — host sync in a registered hot path
# ---------------------------------------------------------------------------
TRAINER = "src/repro/train/trainer.py"


def test_rpr001_flags_hot_loop_float(tmp_path):
    # the pre-PR-8 regression, verbatim shape: float() on the device
    # loss every step inside Trainer.run
    findings, _ = run(tmp_path, TRAINER, """\
        class Trainer:
            def run(self, state, steps):
                for _ in range(steps):
                    state, metrics = self._step(state)
                    self.history.append(float(metrics["loss"]))
                return state
        """)
    assert rules_of(findings) == {"RPR001"}
    assert "float()" in findings[0].message


def test_rpr001_clean_hot_loop_passes(tmp_path):
    findings, _ = run(tmp_path, TRAINER, """\
        class Trainer:
            def run(self, state, steps):
                for _ in range(steps):
                    state, metrics = self._step(state)
                    self.telemetry.push(metrics)   # stays on device
                return state
        """)
    assert findings == []


def test_rpr001_ignores_unregistered_functions(tmp_path):
    # _log_boundary is outside the (Trainer.run, Trainer._run) registry:
    # it operates on already-fetched host values by design
    findings, _ = run(tmp_path, TRAINER, """\
        class Trainer:
            def _log_boundary(self, metrics):
                return float(metrics["loss"])
        """)
    assert findings == []


def test_rpr001_flags_item_and_device_get(tmp_path):
    findings, _ = run(tmp_path, "src/repro/core/mixing.py", """\
        import jax

        def mix_round(x):
            y = x.item()
            return jax.device_get(y)
        """)
    assert [f.rule for f in findings] == ["RPR001", "RPR001"]


# ---------------------------------------------------------------------------
# RPR002 — legacy communicate(**kwargs) call form
# ---------------------------------------------------------------------------
def test_rpr002_flags_legacy_kwargs(tmp_path):
    findings, _ = run(tmp_path, "src/demo.py", """\
        from repro.core.mixing import communicate

        def round_(params):
            return communicate(params, phase="gossip", topology="ring",
                               n_nodes=4)
        """)
    assert rules_of(findings) == {"RPR002"}
    assert "n_nodes, topology" in findings[0].message


def test_rpr002_spec_form_passes(tmp_path):
    findings, _ = run(tmp_path, "src/demo.py", """\
        from repro.core.mixing import communicate

        def round_(params, spec):
            return communicate(params, spec, phase="gossip", step=0)
        """)
    assert findings == []


def test_rpr002_flags_starred_dict(tmp_path):
    # the forwarding hole that dropped model_axis in PR 5: spec knobs
    # hidden behind **kwargs
    findings, _ = run(tmp_path, "src/demo.py", """\
        from repro.core.mixing import communicate

        def round_(params):
            kw = dict(topology="ring", n_nodes=4)
            return communicate(params, **kw)
        """)
    assert rules_of(findings) == {"RPR002"}


# ---------------------------------------------------------------------------
# RPR003 — donation hazards
# ---------------------------------------------------------------------------
def test_rpr003_flags_returned_alias(tmp_path):
    # the PR-7 slowmo-branch regression: a copy in one if-arm must not
    # sanctify the other arm's return path
    findings, _ = run(tmp_path, "src/repro/train/step.py", """\
        import jax
        import jax.numpy as jnp
        from repro.core.mixing import start_round

        def prime(params, spec, phase):
            buf, ef = start_round(params, spec)
            if phase == "slowmo":
                buf = jax.tree.map(jnp.copy, buf)
            return params, buf, ef
        """)
    assert rules_of(findings) == {"RPR003"}
    assert "jax.tree.map(jnp.copy" in findings[0].message


def test_rpr003_copy_rebind_passes(tmp_path):
    findings, _ = run(tmp_path, "src/repro/train/step.py", """\
        import jax
        import jax.numpy as jnp
        from repro.core.mixing import start_round

        def prime(params, spec):
            buf, ef = start_round(params, spec)
            buf = jax.tree.map(jnp.copy, buf)
            return params, buf, ef
        """)
    assert findings == []


def test_rpr003_sees_through_constructor(tmp_path):
    # containment follows Capitalized constructor calls: the params ride
    # inside TrainState(...) next to the aliasing buffer
    findings, _ = run(tmp_path, "src/repro/train/step.py", """\
        from repro.core.mixing import start_round

        def prime(state, spec):
            params = state.params
            buf, ef = start_round(params, spec)
            return TrainState(params=params, step=state.step), buf
        """)
    assert rules_of(findings) == {"RPR003"}


def test_rpr003_flags_donated_callsite_reuse(tmp_path):
    findings, _ = run(tmp_path, "src/demo.py", """\
        import jax

        def drive(step_fn, state, batch):
            f = jax.jit(step_fn, donate_argnums=(0,))
            out = f(state, batch)
            return state.step, out
        """)
    assert rules_of(findings) == {"RPR003"}
    assert "donated" in findings[0].message


# ---------------------------------------------------------------------------
# RPR004 — recompile hazards
# ---------------------------------------------------------------------------
def test_rpr004_flags_loop_varying_static(tmp_path):
    findings, _ = run(tmp_path, "src/demo.py", """\
        import jax

        def drive(fn, xs):
            step = jax.jit(fn, static_argnums=(1,))
            for i in range(10):
                out = step(xs, i)
            return out
        """)
    assert rules_of(findings) == {"RPR004"}
    assert "recompile" in findings[0].message


def test_rpr004_constant_static_passes(tmp_path):
    findings, _ = run(tmp_path, "src/demo.py", """\
        import jax

        def drive(fn, xs):
            step = jax.jit(fn, static_argnums=(1,))
            for i in range(10):
                out = step(xs, 4)
            return out
        """)
    assert findings == []


def test_rpr004_flags_static_traced_w(tmp_path):
    # PR 6 contract: W/active are runtime operands; fault patterns must
    # never recompile
    findings, _ = run(tmp_path, "src/demo.py", """\
        import jax

        def build(fn):
            return jax.jit(fn, static_argnames=("W",))
        """)
    assert rules_of(findings) == {"RPR004"}
    assert "'W'" in findings[0].message


def test_rpr004_flags_unhashable_static_literal(tmp_path):
    findings, _ = run(tmp_path, "src/demo.py", """\
        import jax

        def drive(fn, xs):
            step = jax.jit(fn, static_argnums=(1,))
            while True:
                out = step(xs, {"a": 1})
            return out
        """)
    assert rules_of(findings) == {"RPR004"}
    assert "unhashable" in findings[0].message


# ---------------------------------------------------------------------------
# RPR005 — host-stateful randomness in device modules
# ---------------------------------------------------------------------------
def test_rpr005_flags_np_random_in_device_module(tmp_path):
    findings, _ = run(tmp_path, "src/repro/compress/quant.py", """\
        import numpy as np

        def dither(x):
            return x + np.random.standard_normal(x.shape)
        """)
    assert rules_of(findings) == {"RPR005"}


def test_rpr005_jax_random_passes(tmp_path):
    findings, _ = run(tmp_path, "src/repro/compress/quant.py", """\
        import jax

        def dither(x, key):
            return x + jax.random.normal(key, x.shape)
        """)
    assert findings == []


def test_rpr005_host_schedule_modules_exempt(tmp_path):
    # data/ builds host batches — outside the device-module registry
    findings, _ = run(tmp_path, "src/repro/data/synthetic.py", """\
        import numpy as np

        def batch(shape):
            return np.random.standard_normal(shape)
        """)
    assert findings == []


# ---------------------------------------------------------------------------
# RPR006 — pallas_call contracts
# ---------------------------------------------------------------------------
def test_rpr006_missing_out_shape(tmp_path):
    findings, _ = run(tmp_path, "src/repro/kernels/k.py", """\
        from jax.experimental import pallas as pl

        def apply(x):
            return pl.pallas_call(kernel)(x)
        """)
    assert rules_of(findings) == {"RPR006"}
    assert "out_shape" in findings[0].message


def test_rpr006_alias_index_out_of_range(tmp_path):
    findings, _ = run(tmp_path, "src/repro/kernels/k.py", """\
        import jax
        from jax.experimental import pallas as pl

        def apply(x):
            return pl.pallas_call(
                kernel,
                out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
                input_output_aliases={1: 0},
            )(x)
        """)
    assert rules_of(findings) == {"RPR006"}
    assert "out of range" in findings[0].message


def test_rpr006_index_map_grid_rank_mismatch(tmp_path):
    findings, _ = run(tmp_path, "src/repro/kernels/k.py", """\
        import jax
        from jax.experimental import pallas as pl

        def apply(x):
            return pl.pallas_call(
                kernel,
                grid=(4, 4),
                in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
                out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            )(x)
        """)
    assert rules_of(findings) == {"RPR006"}
    assert "rank" in findings[0].message


def test_rpr006_resolves_named_index_maps(tmp_path):
    # index maps written as defs (the post-lint kernel idiom) resolve
    # by name, and a matching arity is clean
    findings, _ = run(tmp_path, "src/repro/kernels/k.py", """\
        import jax
        from jax.experimental import pallas as pl

        def tile(i, j):
            return (i, j)

        def apply(x):
            return pl.pallas_call(
                kernel,
                grid=(4, 4),
                in_specs=[pl.BlockSpec((8, 8), tile)],
                out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            )(x)
        """)
    assert findings == []


def test_rpr006_shapedtypestruct_needs_dtype(tmp_path):
    findings, _ = run(tmp_path, "src/repro/kernels/k.py", """\
        import jax
        from jax.experimental import pallas as pl

        def out(x):
            return jax.ShapeDtypeStruct((4, 4))
        """)
    assert rules_of(findings) == {"RPR006"}
    assert "dtype" in findings[0].message


# ---------------------------------------------------------------------------
# engine semantics: suppressions, RPR000, baseline
# ---------------------------------------------------------------------------
def test_suppression_on_the_flagged_line(tmp_path):
    findings, suppressed = run(tmp_path, TRAINER, """\
        class Trainer:
            def run(self, state):
                x = float(state.loss)  # repro: allow(RPR001)
                return x
        """)
    assert findings == [] and suppressed == 1


def test_suppression_comment_line_above(tmp_path):
    findings, suppressed = run(tmp_path, TRAINER, """\
        class Trainer:
            def run(self, state):
                # repro: allow(RPR001) -- deliberate final fetch
                x = float(state.loss)
                return x
        """)
    assert findings == [] and suppressed == 1


def test_suppression_for_other_rule_does_not_apply(tmp_path):
    findings, suppressed = run(tmp_path, TRAINER, """\
        class Trainer:
            def run(self, state):
                x = float(state.loss)  # repro: allow(RPR002)
                return x
        """)
    assert rules_of(findings) == {"RPR001"} and suppressed == 0


def test_syntax_error_is_a_finding(tmp_path):
    findings, _ = run(tmp_path, "src/broken.py", "def f(:\n")
    assert rules_of(findings) == {"RPR000"}


def test_baseline_absorbs_up_to_count(tmp_path):
    f = tmp_path / TRAINER
    f.parent.mkdir(parents=True)
    f.write_text(textwrap.dedent("""\
        class Trainer:
            def run(self, state):
                a = float(state.a)
                b = float(state.b)
                return a, b
        """))
    findings, _ = analyze_file(tmp_path, f)
    assert len(findings) == 2
    base = Baseline(entries={("RPR001", TRAINER): (1, "known debt")})
    kept, absorbed = apply_baseline(findings, base)
    assert absorbed == 1 and len(kept) == 1
    # a different path never matches the budget
    base2 = Baseline(entries={("RPR001", "src/other.py"): (9, "")})
    kept2, absorbed2 = apply_baseline(findings, base2)
    assert absorbed2 == 0 and len(kept2) == 2


def test_baseline_write_load_roundtrip(tmp_path):
    f = tmp_path / TRAINER
    f.parent.mkdir(parents=True)
    f.write_text(textwrap.dedent("""\
        class Trainer:
            def run(self, state):
                return float(state.loss)
        """))
    findings, _ = analyze_file(tmp_path, f)
    bpath = tmp_path / "analysis_baseline.json"
    write_baseline(bpath, findings)
    data = json.loads(bpath.read_text())
    assert data["version"] == 1
    assert data["entries"][0]["rule"] == "RPR001"
    assert data["entries"][0]["path"] == TRAINER
    loaded = load_baseline(bpath)
    kept, absorbed = apply_baseline(findings, loaded)
    assert kept == [] and absorbed == 1
    assert load_baseline(tmp_path / "missing.json").entries == {}


# ---------------------------------------------------------------------------
# report formats
# ---------------------------------------------------------------------------
def _one_finding(tmp_path):
    findings, _ = run(tmp_path, TRAINER, """\
        class Trainer:
            def run(self, state):
                return float(state.loss)
        """)
    assert len(findings) == 1
    return findings


def test_json_format_schema(tmp_path):
    findings = _one_finding(tmp_path)
    doc = json.loads(format_findings(findings, "json", suppressed=2,
                                     baselined=3))
    assert doc["version"] == 1
    assert doc["counts"] == {"RPR001": 1}
    assert doc["suppressed"] == 2 and doc["baselined"] == 3
    (f,) = doc["findings"]
    assert set(f) == {"rule", "path", "line", "col", "message"}
    assert f["rule"] == "RPR001" and f["path"] == TRAINER


def test_github_format_is_workflow_commands(tmp_path):
    findings = _one_finding(tmp_path)
    out = format_findings(findings, "github")
    assert re.fullmatch(
        r"::error file=src/repro/train/trainer\.py,line=\d+,col=\d+,"
        r"title=RPR001::.+", out)


def test_text_format_tail(tmp_path):
    findings = _one_finding(tmp_path)
    out = format_findings(findings, "text", suppressed=1, baselined=0)
    assert out.splitlines()[0].startswith(f"{TRAINER}:3:")
    assert out.splitlines()[-1] == "1 finding(s) (1 suppressed, 0 baselined)"


# ---------------------------------------------------------------------------
# CLI contract
# ---------------------------------------------------------------------------
def test_cli_exit_codes_and_artifact(tmp_path, capsys):
    clean = tmp_path / "src" / "ok.py"
    clean.parent.mkdir(parents=True)
    clean.write_text("X = 1\n")
    assert cli(["src", "--root", str(tmp_path)]) == 0
    capsys.readouterr()

    (tmp_path / TRAINER).parent.mkdir(parents=True)
    (tmp_path / TRAINER).write_text(textwrap.dedent("""\
        class Trainer:
            def run(self, state):
                return float(state.loss)
        """))
    out_file = tmp_path / "findings.json"
    rc = cli(["src", "--root", str(tmp_path), "--format", "github",
              "--out", str(out_file)])
    assert rc == 1
    assert "::error file=" in capsys.readouterr().out
    # the --out artifact is always JSON, whatever the console format
    doc = json.loads(out_file.read_text())
    assert doc["counts"] == {"RPR001": 1}

    assert cli(["no/such/dir", "--root", str(tmp_path)]) == 2


def test_cli_write_baseline_then_green(tmp_path, capsys):
    (tmp_path / TRAINER).parent.mkdir(parents=True)
    (tmp_path / TRAINER).write_text(textwrap.dedent("""\
        class Trainer:
            def run(self, state):
                return float(state.loss)
        """))
    assert cli(["src", "--root", str(tmp_path), "--write-baseline"]) == 0
    capsys.readouterr()
    # the debt is tracked: the gate is green until the file changes
    assert cli(["src", "--root", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "1 baselined" in out


# ---------------------------------------------------------------------------
# registry self-check: HOT_PATHS resolves against the real tree
# ---------------------------------------------------------------------------
def _def_qualnames(path):
    """Function qualnames ('Class.method', 'fn', 'fn.inner') defined in a
    source file, via the same parent-stack walk the engine's qualname
    resolution uses."""
    import ast
    names = set()

    def walk(node, stack):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                qual = stack + [child.name]
                if not isinstance(child, ast.ClassDef):
                    names.add(".".join(qual))
                walk(child, qual)
            else:
                walk(child, stack)

    walk(ast.parse(path.read_text()), [])
    return names


def test_hot_paths_registry_resolves():
    """Every HOT_PATHS entry resolves against the real tree: the path
    glob matches at least one file, and each named function glob matches
    a function that actually exists there.  A hot path renamed or moved
    by a refactor must fail here instead of silently losing its
    host-sync protection."""
    import fnmatch
    from repro.analysis.rules.host_sync import HOT_PATHS

    all_files = [p.relative_to(REPO_ROOT).as_posix()
                 for p in (REPO_ROOT / "src").rglob("*.py")]
    for pat, fn_globs in HOT_PATHS:
        matches = [f for f in all_files if fnmatch.fnmatch(f, pat)]
        assert matches, f"HOT_PATHS glob {pat!r} matches no file under src/"
        quals = set()
        for m in matches:
            quals |= _def_qualnames(REPO_ROOT / m)
        for g in fn_globs:
            if g == "*":
                continue
            assert any(fnmatch.fnmatch(q, g) for q in quals), (
                f"HOT_PATHS function glob {g!r} resolves to no function "
                f"under {pat!r}")


# ---------------------------------------------------------------------------
# integration: the real tree is clean
# ---------------------------------------------------------------------------
def test_repo_is_clean():
    """The merged tree carries zero unsuppressed, unbaselined findings —
    the same gate the CI analyze job enforces."""
    findings, _ = analyze_paths(REPO_ROOT, ["src", "benchmarks", "tests"])
    baseline = load_baseline(REPO_ROOT / "analysis_baseline.json")
    kept, _ = apply_baseline(findings, baseline)
    assert kept == [], "\n" + format_findings(kept, "text")
