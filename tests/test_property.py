"""Hypothesis property tests on the system's invariants.

Skipped (not failed) when hypothesis is absent — it is an optional extra
(see requirements-dev.txt); tier-1 must collect without it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import mixing, topology as topo
from repro.core.schedule import AGASchedule, PGASchedule

_SIZES = st.sampled_from([2, 4, 8, 16, 32])
_TOPOS = st.sampled_from(["ring", "exp", "full", "grid", "one_peer_exp"])
_SETTINGS = dict(max_examples=25, deadline=None)


@given(n=_SIZES, t=_TOPOS, step=st.integers(0, 7))
@settings(**_SETTINGS)
def test_mixing_matrix_is_doubly_stochastic(n, t, step):
    W = topo.mixing_matrix(t, n, step=step)
    assert topo.is_doubly_stochastic(W)


@given(n=_SIZES, t=_TOPOS, step=st.integers(0, 7))
@settings(**_SETTINGS)
def test_topology_satisfies_assumption_3(n, t, step):
    """Paper Assumption 3 for every topology × node count: W doubly
    stochastic with contraction β < 1.  Static topologies contract per
    step; the time-varying one-peer-exp graph has per-step β = 1 (each
    matrix only pairs nodes) but every per-step W is still doubly
    stochastic and the *effective* β over one period is < 1 (the period
    product is exactly 𝟙𝟙ᵀ/n, paper §3).  ``disconnected`` (W = I, β = 1)
    is the deliberate no-communication baseline and excluded from _TOPOS.
    """
    W = topo.mixing_matrix(t, n, step=step)
    assert topo.is_doubly_stochastic(W)
    if t == "one_peer_exp":
        if n > 1:
            period = topo.schedule_period(t, n)
            P = np.eye(n)
            for k in range(period):
                Wk = topo.mixing_matrix(t, n, step=step + k)
                assert topo.is_doubly_stochastic(Wk)
                P = Wk @ P
            np.testing.assert_allclose(P, np.ones((n, n)) / n, atol=1e-9)
        assert topo.effective_beta(t, n) < 1.0
    else:
        assert topo.beta(W) < 1.0


@given(n=_SIZES, t=_TOPOS, step=st.integers(0, 7),
       seed=st.integers(0, 1000))
@settings(**_SETTINGS)
def test_gossip_preserves_global_average(n, t, step, seed):
    """𝟙ᵀW = 𝟙ᵀ  ⇒  mixing never moves the node average (the quantity the
    descent lemma tracks)."""
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(n, 3)),
                    jnp.float32)
    mixed = mixing.mix_pytree(x, t, n, step=step)
    np.testing.assert_allclose(np.asarray(mixed.mean(0)),
                               np.asarray(x.mean(0)), atol=1e-5)


@given(n=_SIZES, t=st.sampled_from(["ring", "exp", "full", "grid"]),
       seed=st.integers(0, 1000))
@settings(**_SETTINGS)
def test_gossip_contracts_consensus_by_beta(n, t, seed):
    """‖Wx − x̄‖_F ≤ β‖x − x̄‖_F (the consensus-lemma contraction)."""
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(n, 4)),
                    jnp.float32)
    xbar = x.mean(0, keepdims=True)
    mixed = mixing.mix_pytree(x, t, n)
    before = float(jnp.linalg.norm(x - xbar))
    after = float(jnp.linalg.norm(mixed - xbar))
    b = topo.beta(topo.mixing_matrix(t, n))
    assert after <= b * before + 1e-4


_BACKENDS = st.sampled_from(["reference", "pallas"])
# constants bounded away from the subnormal range: the bitwise branch below
# relies on exact power-of-two scaling, which subnormal quotients break
_CONSTS = st.one_of(st.just(0.0),
                    st.floats(1e-3, 1e3, width=32),
                    st.floats(-1e3, -1e-3, width=32))


@given(n=_SIZES, t=_TOPOS, step=st.integers(0, 7), c=_CONSTS,
       backend=_BACKENDS)
@settings(**_SETTINGS)
def test_constant_tree_is_communication_fixed_point(n, t, step, c, backend):
    """Row-stochasticity (W𝟙 = 𝟙): a constant state is a fixed point of one
    ``communicate`` round for every backend × topology × phase.  Bitwise
    for one-peer gossip, whose two ½-weights are exact binary fractions;
    within a few ulp otherwise (neither backend's reduction of 1/3- or
    1/n-weight terms is exactly associative — sequential dot sums round
    even n identical addends)."""
    tree = {"w": jnp.full((n, 3), c, jnp.float32),
            "b": jnp.full((n,), c, jnp.float32)}
    cases = [("gossip", 1), ("global", 1), ("pod_avg", 2)]
    for phase, n_pods in cases:
        spec = mixing.CommSpec(topology=t, n_nodes=n, n_pods=n_pods,
                               backend=backend)
        out = mixing.communicate(tree, spec, phase=phase, step=step)
        for got, want in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
            if phase == "gossip" and t == "one_peer_exp":
                np.testing.assert_array_equal(np.asarray(got),
                                              np.asarray(want))
            else:
                np.testing.assert_allclose(np.asarray(got),
                                           np.asarray(want),
                                           rtol=5e-7, atol=0)


@given(seed=st.integers(0, 10_000))
@settings(**_SETTINGS)
def test_global_average_is_idempotent(seed):
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(8, 5)),
                    jnp.float32)
    once = mixing.global_average_pytree(x)
    twice = mixing.global_average_pytree(once)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice),
                               atol=1e-6)


@given(H=st.integers(1, 64), steps=st.integers(1, 300))
@settings(**_SETTINGS)
def test_pga_schedule_global_every_h(H, steps):
    s = PGASchedule(H=H)
    phases = [s.phase(k) for k in range(steps)]
    for k, p in enumerate(phases):
        assert p == ("global" if (k + 1) % H == 0 else "gossip")


@given(h_init=st.integers(1, 8), h_max=st.integers(8, 64),
       losses=st.lists(st.floats(1e-6, 1e6, allow_nan=False), min_size=10,
                       max_size=200))
@settings(**_SETTINGS)
def test_aga_h_always_bounded(h_init, h_max, losses):
    s = AGASchedule(H_init=h_init, warmup=5, H_max=h_max)
    for k, loss in enumerate(losses):
        s.observe_loss(k, loss)
        s.advance(k)
        assert 1 <= s.current_H <= h_max


@given(beta=st.floats(0.0, 0.999), H=st.integers(1, 128))
@settings(**_SETTINGS)
def test_paper_quantity_bounds(beta, H):
    cb = topo.c_beta(beta, H)
    db = topo.d_beta(beta, H)
    assert cb <= min(H, 1.0 / (1.0 - beta)) + 1e-9
    assert db == min(float(H), 1.0 / (1.0 - beta))


@given(n=st.sampled_from([4, 8, 16]), seed=st.integers(0, 100),
       k=st.integers(2, 6))
@settings(**_SETTINGS)
def test_moe_dispatch_weights_sum_preserved(n, seed, k):
    """Dispatched combine weights sum to 1 per token when nothing drops."""
    from repro.models.moe import _build_dispatch
    rng = np.random.default_rng(seed)
    T, E = 32, n
    k = min(k, E)
    top_idx = jnp.asarray(rng.integers(0, E, size=(T, k)))
    w = rng.random((T, k)).astype(np.float32)
    w /= w.sum(-1, keepdims=True)
    tok, wt, drop = _build_dispatch(jnp.asarray(top_idx), jnp.asarray(w),
                                    E, capacity=T * k, n_tokens=T)
    assert float(drop) == 0.0
    # scatter weights back per token and compare
    sums = np.zeros(T + 1)
    np.add.at(sums, np.asarray(tok).reshape(-1), np.asarray(wt).reshape(-1))
    np.testing.assert_allclose(sums[:T], np.ones(T), atol=1e-5)
