"""Hier-PGA (beyond-paper): pod averaging semantics + schedule + trainer."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (DataConfig, DistConfig, OptimizerConfig,
                           TrainConfig, get_model_config)
from repro.core import mixing, simulate
from repro.core.schedule import HierPGASchedule
from repro.train import Trainer


def test_pod_average_blocks():
    x = jnp.arange(8.0)[:, None] * jnp.ones((8, 3))
    out = mixing.pod_average_pytree(x, n_pods=2)
    want = np.concatenate([np.full((4, 3), 1.5), np.full((4, 3), 5.5)])
    np.testing.assert_allclose(np.asarray(out), want)


def test_pod_average_preserves_global_mean():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 5))
    out = mixing.pod_average_pytree(x, n_pods=4)
    np.testing.assert_allclose(np.asarray(out.mean(0)),
                               np.asarray(x.mean(0)), atol=1e-6)


def test_schedule_pattern():
    s = HierPGASchedule(H_pod=2, H_global=6)
    assert [s.phase(k) for k in range(6)] == \
        ["gossip", "pod_avg", "gossip", "pod_avg", "gossip", "global"]


def test_hier_consensus_between_pga_and_gossip():
    c = jnp.asarray(np.random.default_rng(0).normal(size=(8, 4)), jnp.float32)
    kw = dict(grad_fn=lambda x, k, s: x - c,
              loss_fn=lambda xb: 0.5 * jnp.mean(jnp.sum((xb - c) ** 2, -1)),
              x0=jnp.zeros(4), n=8, steps=60, lr=0.1, topology="ring",
              eval_every=10)
    hier = simulate(algorithm="hier_pga", H=12,
                    aga_kwargs={"hier_h_pod": 3, "n_pods": 2}, **kw)
    pga = simulate(algorithm="gossip_pga", H=12, **kw)
    gossip = simulate(algorithm="gossip", H=12, **kw)
    # more sync than gossip-only, less than adding pod-avg would match PGA
    assert hier["consensus"][-1] <= gossip["consensus"][-1] + 1e-9


def test_hier_pga_trains():
    cfg = get_model_config("pga-lm-100m", reduced=True)
    tcfg = TrainConfig(
        model=cfg,
        dist=DistConfig(algorithm="hier_pga", topology="ring", H=6,
                        hier_h_pod=2, n_pods=2),
        optimizer=OptimizerConfig(name="adamw", lr=3e-3, schedule="constant",
                                  warmup_steps=0),
        data=DataConfig(), global_batch=8, seq_len=32, log_every=0)
    tr = Trainer(tcfg, n_nodes=4)
    state = tr.init_state(jax.random.PRNGKey(0))
    state = tr.run(state, steps=6, log_every=0)
    assert int(state.step) == 6
