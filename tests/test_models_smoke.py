"""Per-architecture smoke tests (deliverable f): each assigned arch's REDUCED
variant runs one forward + one train step on CPU asserting output shapes and
no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (DataConfig, DistConfig, OptimizerConfig,
                           TrainConfig, get_model_config, list_archs)
from repro.models import make_model
from repro.train import Trainer

ARCHS = list(list_archs())
B, S = 2, 32


def _batch(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    batch = {"inputs": jax.random.randint(k1, (B, S), 0, cfg.vocab_size),
             "targets": jax.random.randint(k2, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "encoder":
        mask = jax.random.bernoulli(k3, 0.2, (B, S))
        if cfg.audio is not None:
            batch = {"frames": jax.random.normal(k1, (B, S, cfg.d_model)),
                     "mask": mask, "targets": batch["targets"]}
        else:
            batch["mask"] = mask
    if cfg.family == "vlm":
        n_img = cfg.vision.n_tiles * cfg.vision.patches_per_tile
        batch["patches"] = 0.02 * jax.random.normal(
            k3, (B, n_img, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_model_config(arch, reduced=True)
    model = make_model(cfg)
    key = jax.random.PRNGKey(0)
    params, axes = model.init(key)
    # params/axes trees are structurally identical (by ParamBuilder design)
    assert (jax.tree.structure(params) ==
            jax.tree.structure(jax.tree.map(
                lambda a: 0, axes, is_leaf=lambda x: isinstance(x, tuple))))
    batch = _batch(cfg, key)
    logits, _, lb = jax.jit(
        lambda p, b: model.forward(p, b, mode="train"))(params, batch)
    seq = batch["frames"].shape[1] if "frames" in batch else S
    assert logits.shape == (B, seq, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32)))
    assert np.isfinite(float(lb))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_model_config(arch, reduced=True)
    tcfg = TrainConfig(
        model=cfg,
        dist=DistConfig(algorithm="gossip_pga", topology="ring", H=2),
        optimizer=OptimizerConfig(name="adamw", lr=1e-3,
                                  schedule="constant", warmup_steps=0),
        data=DataConfig(), global_batch=4, seq_len=S, log_every=0)
    tr = Trainer(tcfg, n_nodes=2)
    state = tr.init_state(jax.random.PRNGKey(0))
    state = tr.run(state, steps=2, log_every=0)
    assert int(state.step) == 2
    for leaf in jax.tree.leaves(state.params):
        assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float32)))
