"""SSM mixers: chunkwise mLSTM vs recurrent oracle; forward/decode state
consistency for mamba, mLSTM, sLSTM."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_model_config
from repro.models import ssm as ssm_lib


@pytest.mark.parametrize("shape", [(2, 37, 3, 8, 16, 8), (1, 64, 2, 4, 4, 16),
                                   (3, 100, 4, 16, 32, 32),
                                   (2, 16, 1, 8, 8, 64)])
def test_mlstm_chunkwise_equals_recurrent(shape):
    B, S, nh, dk, dv, chunk = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (B, S, nh, dk))
    k = jax.random.normal(ks[1], (B, S, nh, dk))
    v = jax.random.normal(ks[2], (B, S, nh, dv))
    li = 2.0 * jax.random.normal(ks[3], (B, S, nh))
    lf = jax.nn.log_sigmoid(2.0 * jax.random.normal(ks[4], (B, S, nh)))
    h1, (C1, n1, m1) = ssm_lib._mlstm_chunk_scan(q, k, v, li, lf, chunk)
    h2, (C2, n2, m2) = ssm_lib.mlstm_recurrent_reference(q, k, v, li, lf)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=3e-5, rtol=3e-4)
    np.testing.assert_allclose(
        np.asarray(C1 * np.exp(m1)[..., None, None]),
        np.asarray(C2 * np.exp(m2)[..., None, None]), atol=1e-4, rtol=1e-3)


def _forward_decode_consistency(init_fn, fwd_fn, dec_fn, state_fn, cfg, di):
    key = jax.random.PRNGKey(0)
    params, _ = init_fn(key, cfg, jnp.float32)
    B, S = 2, 10
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    out_full, _ = fwd_fn(params, cfg, x)
    state = state_fn(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        o, state = dec_fn(params, cfg, x[:, t:t + 1], state)
        outs.append(o)
    out_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_dec), np.asarray(out_full),
                               atol=2e-4, rtol=2e-3)


def test_mamba_forward_equals_stepwise_decode():
    cfg = get_model_config("jamba-1.5-large-398b", reduced=True)
    _forward_decode_consistency(
        ssm_lib.init_mamba, ssm_lib.mamba_forward, ssm_lib.mamba_decode,
        ssm_lib.init_mamba_state, cfg, None)


def test_mlstm_forward_equals_stepwise_decode():
    cfg = get_model_config("xlstm-125m", reduced=True)
    _forward_decode_consistency(
        ssm_lib.init_mlstm, ssm_lib.mlstm_forward, ssm_lib.mlstm_decode,
        ssm_lib.init_mlstm_state, cfg, None)


def test_slstm_forward_equals_stepwise_decode():
    cfg = get_model_config("xlstm-125m", reduced=True)
    _forward_decode_consistency(
        ssm_lib.init_slstm, ssm_lib.slstm_forward, ssm_lib.slstm_decode,
        ssm_lib.init_slstm_state, cfg, None)


def test_mamba_associative_scan_matches_sequential():
    """The parallel-scan recurrence h_t = a_t h_{t-1} + b_t is exact."""
    key = jax.random.PRNGKey(0)
    B, S, D, N = 2, 25, 4, 3
    a = jax.nn.sigmoid(jax.random.normal(key, (B, S, D, N)))
    b = jax.random.normal(jax.random.PRNGKey(1), (B, S, D, N))

    def combine(lhs, rhs):
        al, bl = lhs
        ar, br = rhs
        return al * ar, bl * ar + br

    _, h_par = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = jnp.zeros((B, D, N))
    hs = []
    for t in range(S):
        h = a[:, t] * h + b[:, t]
        hs.append(h)
    h_seq = jnp.stack(hs, axis=1)
    np.testing.assert_allclose(np.asarray(h_par), np.asarray(h_seq),
                               atol=1e-5, rtol=1e-5)
