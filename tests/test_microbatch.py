"""Gradient accumulation: m microbatches must match the full-batch step up to
bf16 accumulation-order noise."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (DataConfig, DistConfig, OptimizerConfig,
                           TrainConfig, get_model_config)
from repro.models import make_model
from repro.train import Trainer, build_train_step

CFG = get_model_config("pga-lm-100m", reduced=True)


def test_microbatch_equivalence():
    base = dict(model=CFG, dist=DistConfig(topology="ring", H=4),
                optimizer=OptimizerConfig(name="sgd", lr=0.05,
                                          grad_clip=None, weight_decay=0.0),
                data=DataConfig(), global_batch=8, seq_len=32, log_every=0)
    t1 = TrainConfig(**base, microbatches=1)
    t4 = TrainConfig(**base, microbatches=4)
    tr = Trainer(t1, n_nodes=2)
    batch = jax.tree.map(jnp.asarray, tr.stream.get_batch(0))
    model = make_model(CFG)
    lr = jnp.float32(0.05)
    s1, m1 = jax.jit(build_train_step(model, t1, 2, phase="gossip"))(
        tr.init_state(jax.random.PRNGKey(0)), batch, lr)
    s4, m4 = jax.jit(build_train_step(model, t4, 2, phase="gossip"))(
        tr.init_state(jax.random.PRNGKey(0)), batch, lr)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-3)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s4.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)


def test_microbatch_trains():
    tcfg = TrainConfig(
        model=CFG, dist=DistConfig(algorithm="gossip_pga", H=4),
        optimizer=OptimizerConfig(name="adamw", lr=3e-3, schedule="constant",
                                  warmup_steps=0),
        data=DataConfig(), global_batch=8, seq_len=32, microbatches=2,
        log_every=0)
    tr = Trainer(tcfg, n_nodes=2)
    state = tr.init_state(jax.random.PRNGKey(0))
    state = tr.run(state, steps=3, log_every=0)
    assert int(state.step) == 3
