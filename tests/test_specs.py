"""Sharding-spec resolution + a subprocess mini dry-run (8 forced host
devices) exercising specs → lower → compile end-to-end on a reduced arch."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.core import mixing
from repro.models.sharding import logical_to_spec


def _mesh(shape=(16, 16), axes=("data", "model")):
    # jax >= 0.4.36 wants ((name, size), ...) pairs; older releases took
    # (shape, axes) positionally — support both so the pinned-min CI leg runs.
    try:
        return AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:
        return AbstractMesh(shape, axes)


def test_divisible_dims_are_sharded():
    spec = logical_to_spec(("node", "embed", "heads", None), "train_data",
                           _mesh(), shape=(16, 1024, 16, 64))
    assert spec == P("data", None, "model", None)


def test_non_divisible_dims_stay_replicated():
    # kv_heads=8 on model=16 -> replicated
    spec = logical_to_spec(("embed", "kv_heads", None), "train_data",
                           _mesh(), shape=(1024, 8, 64))
    assert spec == P(None, None, None)


def test_mesh_axis_never_used_twice():
    spec = logical_to_spec(("heads", "ffn"), "train_data", _mesh(),
                           shape=(16, 64))
    # both map to "model": only the first dim gets it
    assert spec == P("model", None)


def test_multi_pod_node_axis_flattens_pod_and_data():
    spec = logical_to_spec(("node", None), "train_data",
                           _mesh((2, 16, 16), ("pod", "data", "model")),
                           shape=(32, 7))
    assert spec == P(("pod", "data"), None)


def test_serve_tp_seq_shards_sequence_not_kv_heads():
    spec = logical_to_spec(("batch", "kv_seq", "kv_heads", None),
                           "serve_tp_seq", _mesh(),
                           shape=(128, 32768, 8, 256))
    assert spec == P("data", "model", None, None)


def test_comm_dtype_bf16_mixing_close_to_f32():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    f32 = mixing.mix_pytree(x, "ring", 8)
    bf16 = mixing.mix_pytree(x, "ring", 8, comm_dtype=jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(bf16), np.asarray(f32),
                               atol=2e-2, rtol=2e-2)
    # mean preservation holds to wire precision
    np.testing.assert_allclose(np.asarray(bf16.mean(0)),
                               np.asarray(x.mean(0)), atol=2e-2)


_DRYRUN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from repro.configs import DistConfig, get_model_config
    from repro.configs.base import InputShape
    from repro.launch.specs import serve_specs, train_specs
    from repro.launch.dryrun import _compile_train, _compile_serve

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_model_config("qwen3-0.6b", reduced=True)
    shape = InputShape("t", 64, 8, "train")
    compiled, specs = _compile_train(
        cfg, shape, mesh, dist=DistConfig(topology="ring"), phase="gossip")
    assert compiled.cost_analysis() is not None
    text = compiled.as_text()
    assert "collective-permute" in text, "gossip must lower to permutes"
    compiled2, _ = _compile_train(
        cfg, shape, mesh, dist=DistConfig(topology="ring"), phase="global")
    assert "all-reduce" in compiled2.as_text()
    dshape = InputShape("d", 128, 8, "decode")
    compiled3, _ = _compile_serve(cfg, dshape, mesh, param_sharding="tp")
    print("MINI_DRYRUN_OK")
""")


def test_mini_dryrun_subprocess():
    """Gossip lowers to collective-permute, global averaging to all-reduce,
    decode compiles — on a real (4,2) device mesh in a subprocess."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", _DRYRUN_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=570)
    assert "MINI_DRYRUN_OK" in out.stdout, out.stderr[-3000:]
