# NOTE: no XLA_FLAGS here by design — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py forces 512 host devices.
import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Opt-in runtime guards (`--repro-guards`): the dynamic counterpart of the
# static pass (python -m repro.analysis).  RPR001 proves hot paths free of
# host syncs *syntactically*; the transfer guard proves the same property
# *operationally* — any implicit device->host transfer inside a guarded
# test raises instead of silently blocking the dispatch queue.  Leak
# checking catches tracers escaping a jit boundary (the failure mode of
# donation/aliasing bugs that only corrupt under XLA buffer reuse).
#
# Off by default: guarded mode changes error behavior, not numerics, and
# tier-1 must keep matching the seed run bit-for-bit.  CI runs the marked
# subset a second time with the flag on.
# ---------------------------------------------------------------------------
def pytest_addoption(parser):
    parser.addoption(
        "--repro-guards", action="store_true", default=False,
        help="wrap @pytest.mark.repro_guards tests in jax.checking_leaks "
             "+ jax.transfer_guard_device_to_host('disallow')")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "repro_guards: test runs under jax.checking_leaks and a "
        "device->host transfer guard when --repro-guards is given "
        "(explicit jax.device_get stays allowed under 'disallow'; "
        "implicit transfers — float(), np.asarray, printing — raise)")


@pytest.fixture(autouse=True)
def _repro_guards(request):
    if not request.config.getoption("--repro-guards") \
            or request.node.get_closest_marker("repro_guards") is None:
        yield
        return
    # 'disallow' still permits *explicit* transfers (jax.device_get);
    # implicit conversions raise — exactly the RPR001 contract,
    # enforced at runtime.
    with jax.checking_leaks(), \
            jax.transfer_guard_device_to_host("disallow"):
        yield
