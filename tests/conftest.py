# NOTE: no XLA_FLAGS here by design — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py forces 512 host devices.
import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
