"""Serving engine: cache padding, batched server vs sequential generation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_model_config
from repro.models import make_model
from repro.serve import BatchedServer, Engine, Request, pad_cache_to

CFG = get_model_config("pga-lm-100m", reduced=True)


@pytest.fixture(scope="module")
def setup():
    model = make_model(CFG)
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


def test_pad_cache_shapes(setup):
    model, params = setup
    _, caches, _ = model.forward(
        params, {"inputs": jnp.zeros((2, 6), jnp.int32)}, mode="prefill",
        want_cache=True)
    padded = pad_cache_to(caches, 32)
    k = padded["scan"]["entry_0"]["k"]
    assert k.shape[2] == 32  # (layers, B, S, kv, hd)


def test_generate_deterministic(setup):
    model, params = setup
    eng = Engine(model, s_max=24)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                                 CFG.vocab_size)
    a = eng.generate(params, prompts, n_new=6)
    b = eng.generate(params, prompts, n_new=6)
    np.testing.assert_array_equal(a, b)


def test_batched_server_matches_sequential(setup):
    """Continuous batching must produce exactly what one-at-a-time greedy
    generation produces."""
    model, params = setup
    eng = Engine(model, s_max=24)
    prompts = [np.asarray(jax.random.randint(jax.random.PRNGKey(i),
                                             (5,), 0,
                                             CFG.vocab_size))
               for i in range(3)]
    # sequential reference
    want = []
    for p in prompts:
        want.append(eng.generate(params, jnp.asarray(p)[None, :], n_new=4)[0])
    # batched server with 2 slots over 3 requests
    srv = BatchedServer(eng, params, n_slots=2)
    reqs = [Request(uid=i, prompt=p, max_new=4) for i, p in
            enumerate(prompts)]
    done = sorted(srv.run(reqs), key=lambda r: r.uid)
    for r, w in zip(done, want):
        np.testing.assert_array_equal(np.asarray(r.generated), w)
