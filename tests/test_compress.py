"""Compressed-gossip wire subsystem parity suite (DESIGN.md §2.3).

Contract under test, per compressor × phase × topology × backend:

* the **identity** compressor is routed to the exact pre-compression code
  path — bit-identical, including under a mesh (sharded subprocess);
* a **constant state is an exact fixed point** of every compressed round
  (shared per-step randomness makes all nodes transmit identical ``q``,
  and the compensated form cancels): bitwise for one-peer gossip (exact
  ½-weights), a few ulp otherwise — the same tolerance convention as
  ``test_property.test_constant_tree_is_communication_fixed_point``;
* the compressed round **preserves the node average** for any compressor
  (column sums of M equal ``1 − d``);
* the fused Pallas path makes **the same rounding decisions** as the
  reference (shared column hash), so backend parity is matmul-tolerance
  tight;
* **error feedback** threads through ``communicate`` / the train step /
  ``simulate``, and int8+EF tracks the uncompressed trajectory.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compress as C
from repro.core import mixing
from repro.kernels import mixing_pallas as mp

LOSSY = ["int8", "fp8", "topk", "randk"]
SHAPES = [(5, 3), (7,), ()]          # ragged: exercises padding + salts
PHASES = [("gossip", "ring", 1), ("gossip", "one_peer_exp", 1),
          ("gossip", "grid", 1), ("gossip", "exp", 1),
          ("global", "ring", 1), ("pod_avg", "ring", 2)]


def _tree(key, n, dtype=jnp.float32):
    keys = jax.random.split(key, len(SHAPES))
    return {f"leaf{i}": jax.random.normal(k, (n,) + s).astype(dtype)
            for i, (k, s) in enumerate(zip(keys, SHAPES))}


def _close(got, want, atol):
    assert jax.tree.structure(got) == jax.tree.structure(want)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32), atol=atol)


# ---------------------------------------------------------------------------
# Registry and config validation
# ---------------------------------------------------------------------------
def test_registry_matches_distconfig_vocabulary():
    """configs/base.py hardcodes the compressor names (it must stay
    dependency-light); this pins the two vocabularies equal."""
    from repro.configs import DistConfig
    for name in C.COMPRESSORS:
        kw = {"comm_compression": name}
        if name not in ("none", "identity"):
            kw["comm_error_feedback"] = True
        DistConfig(**kw).validate()
    with pytest.raises(ValueError, match="comm_compression"):
        DistConfig(comm_compression="gzip").validate()
    with pytest.raises(ValueError, match="error_feedback"):
        DistConfig(comm_compression="none",
                   comm_error_feedback=True).validate()
    with pytest.raises(ValueError, match="comm_compression_k"):
        DistConfig(comm_compression_k=0).validate()
    with pytest.raises(ValueError, match="comm_compression"):
        C.make_compressor("gzip")


@pytest.mark.parametrize("name", LOSSY)
def test_wire_bytes_accounting(name):
    comp = C.make_compressor(name, k=2)
    tree = _tree(jax.random.PRNGKey(0), 8)
    wires, _ = C.compress_tree(comp, tree, None, jnp.uint32(0))
    measured = sum(w.nbytes for w in wires)
    analytic = C.tree_wire_bytes(comp, tree)
    assert measured == analytic, (measured, analytic)
    assert analytic < 8 * 23 * 4          # strictly below fp32 (23 elems)


def test_int8_wire_reduction_at_least_4x():
    """The acceptance ratio: ≥4× fewer bytes than fp32 for int8, up to the
    per-row scale word (4·D/(D+4); <0.1% of a production leaf — the same
    slack bench_compression's gate documents)."""
    d = 4096
    comp = C.make_compressor("int8")
    ratio = (8 * d * 4) / comp.wire_bytes(8, d)
    assert ratio >= 4.0 * d / (d + 4) - 1e-9
    ratio_round = (C.round_wire_bytes("gossip", "ring", 8, d)
                   / C.round_wire_bytes("gossip", "ring", 8, d,
                                        compression="int8"))
    assert ratio_round >= 4.0 * d / (d + 4) - 1e-9


# ---------------------------------------------------------------------------
# Identity: bit-identical to the pre-compression path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("phase,topology,n_pods", PHASES)
@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_identity_bit_identical(phase, topology, n_pods, backend, rng_key):
    tree = _tree(rng_key, 8)
    spec = mixing.CommSpec(topology=topology, n_nodes=8, n_pods=n_pods,
                           backend=backend)
    want = mixing.communicate(tree, spec, phase=phase, step=2)
    got, ef = mixing.communicate(
        tree, spec.replace(compressor=C.make_compressor("identity")),
        phase=phase, step=2)
    assert ef is None
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert g.dtype == w.dtype
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_identity_bit_identical_bf16_wire(rng_key):
    tree = _tree(rng_key, 8)
    spec = mixing.CommSpec(topology="ring", n_nodes=8,
                           comm_dtype=jnp.bfloat16)
    want = mixing.communicate(tree, spec, phase="gossip")
    got, _ = mixing.communicate(
        tree, spec.replace(compressor=C.make_compressor("identity")),
        phase="gossip")
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ---------------------------------------------------------------------------
# Constant state is a fixed point of every compressed round
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", LOSSY)
@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_constant_fixed_point(name, backend):
    comp = C.make_compressor(name, k=3)
    tree = {"w": jnp.full((8, 5, 3), -2.25, jnp.float32),
            "b": jnp.full((8, 7), 0.1, jnp.float32)}
    for phase, topology, n_pods in PHASES:
        spec = mixing.CommSpec(topology=topology, n_nodes=8,
                               n_pods=n_pods, backend=backend,
                               compressor=comp)
        got, _ = mixing.communicate(tree, spec, phase=phase, step=3,
                                    seed=9)
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
            if phase == "gossip" and topology == "one_peer_exp":
                # exact ½-weights: the compensation cancels bitwise
                np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
            else:
                np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                           rtol=5e-7, atol=0)


@pytest.mark.parametrize("name", LOSSY)
def test_gossip_preserves_node_average(name, rng_key):
    """𝟙ᵀ(x + Mq − (1−d)q) = 𝟙ᵀx for doubly-stochastic W — compression
    error never moves the quantity the descent lemma tracks."""
    comp = C.make_compressor(name, k=5)
    x = jax.random.normal(rng_key, (8, 33))
    for topology in ("ring", "exp", "grid", "one_peer_exp"):
        spec = mixing.CommSpec(topology=topology, n_nodes=8,
                               compressor=comp)
        got, _ = mixing.communicate(x, spec, phase="gossip", step=1,
                                    seed=4)
        np.testing.assert_allclose(np.asarray(got.mean(0)),
                                   np.asarray(x.mean(0)), atol=1e-5)


# ---------------------------------------------------------------------------
# Reference ↔ fused-Pallas parity (same rounding decisions)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", LOSSY)
@pytest.mark.parametrize("phase,topology,n_pods", PHASES)
def test_backend_parity(name, phase, topology, n_pods, rng_key):
    comp = C.make_compressor(name, k=3)
    tree = _tree(rng_key, 8)
    spec = mixing.CommSpec(topology=topology, n_nodes=8, n_pods=n_pods,
                           compressor=comp)
    ref, _ = mixing.communicate(tree, spec, phase=phase, step=2, seed=7)
    pal, _ = mixing.communicate(tree, spec.replace(backend="pallas"),
                                phase=phase, step=2, seed=7)
    _close(pal, ref, atol=2e-5)


@pytest.mark.parametrize("name", ["int8", "topk"])
def test_backend_parity_global_bf16_wire(name, rng_key):
    """The global phase wire-casts the estimate per comm_dtype on every
    backend (the psum operand is not the compressed payload); both
    backends must apply the same cast, and constants must stay fixed."""
    comp = C.make_compressor(name, k=3)
    tree = _tree(rng_key, 8)
    spec = mixing.CommSpec(topology="ring", n_nodes=8,
                           comm_dtype=jnp.bfloat16, compressor=comp)
    ref, _ = mixing.communicate(tree, spec, phase="global", seed=7)
    pal, _ = mixing.communicate(tree, spec.replace(backend="pallas"),
                                phase="global", seed=7)
    _close(pal, ref, atol=2e-5)
    ct = jax.tree.map(lambda p: jnp.full_like(p, 1.7), tree)
    got, _ = mixing.communicate(ct, spec, phase="global", seed=7)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(ct)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=5e-7,
                                   atol=0)


@pytest.mark.parametrize("name", LOSSY)
def test_backend_parity_with_error_feedback(name, rng_key):
    comp = C.make_compressor(name, k=3)
    tree = _tree(rng_key, 8)
    ef0 = C.init_ef_state(tree)
    spec = mixing.CommSpec(topology="ring", n_nodes=8, compressor=comp)
    r_m, r_e = mixing.communicate(tree, spec, phase="gossip",
                                  ef_state=ef0, seed=1)
    p_m, p_e = mixing.communicate(tree, spec.replace(backend="pallas"),
                                  phase="gossip", ef_state=ef0, seed=1)
    _close(p_m, r_m, atol=2e-5)
    _close(p_e, r_e, atol=2e-5)
    # EF is nonzero for a lossy compressor on generic data
    assert sum(float(jnp.sum(jnp.abs(lf))) for lf in jax.tree.leaves(r_e)) > 0


def test_compressed_block_boundary_independence(rng_key):
    """Quantization decisions are keyed on absolute column index, so the
    kernel grid block size must not change the numbers."""
    comp = C.make_compressor("int8")
    x = jax.random.normal(rng_key, (8, 37))
    outs = [np.asarray(mp.compressed_step_mix(
        x, compressor=comp, seed=3, phase="gossip", topology="ring",
        n_nodes=8, block_d=bd)[0]) for bd in (1, 8, 64, 2048)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-6)


def test_seed_varies_rounding(rng_key):
    """Different seeds → different stochastic rounding (unbiasedness over
    steps needs the seed to move)."""
    comp = C.make_compressor("int8")
    x = jax.random.normal(rng_key, (8, 64))
    spec = mixing.CommSpec(topology="ring", n_nodes=8, compressor=comp)
    a, _ = mixing.communicate(x, spec, phase="gossip", seed=1)
    b, _ = mixing.communicate(x, spec, phase="gossip", seed=2)
    assert np.any(np.asarray(a) != np.asarray(b))


def test_compression_rejects_nonzero_axis(rng_key):
    x = jax.random.normal(rng_key, (3, 8))
    with pytest.raises(ValueError, match="axis"):
        mixing.communicate(
            x, mixing.CommSpec(topology="ring", n_nodes=8,
                               compressor=C.make_compressor("int8")),
            phase="gossip", axis=1)


def test_pallas_rejects_non_bf16_global_wire(rng_key):
    """The fused kernel's wire cast is bf16 (same convention as
    _mix_kernel); any other comm_dtype on the compressed global phase
    must raise instead of silently diverging from the reference."""
    x = jax.random.normal(rng_key, (8, 16))
    spec = mixing.CommSpec(topology="ring", n_nodes=8,
                           comm_dtype=jnp.float16,
                           compressor=C.make_compressor("int8"))
    with pytest.raises(ValueError, match="bfloat16"):
        mixing.communicate(x, spec.replace(backend="pallas"),
                           phase="global", seed=1)
    # fp16 wire stays available through the reference backend
    out, _ = mixing.communicate(x, spec, phase="global", seed=1)
    assert np.all(np.isfinite(np.asarray(out)))


# ---------------------------------------------------------------------------
# Error feedback closes the loop: compressed PGA tracks uncompressed
# ---------------------------------------------------------------------------
def test_int8_ef_tracks_uncompressed_trajectory():
    from repro.core.algorithms import simulate
    d = 6
    A = np.asarray(np.random.default_rng(0).normal(size=(d, d)))
    A = jnp.asarray(A @ A.T / d + np.eye(d), jnp.float32)

    def grad_fn(xs, key, k):
        return xs @ A + jax.random.normal(key, xs.shape) * 0.01

    kw = dict(algorithm="gossip_pga", grad_fn=grad_fn,
              loss_fn=lambda x: 0.5 * x @ A @ x,
              x0=jnp.ones((d,), jnp.float32), n=8, steps=40, lr=0.05,
              topology="ring", H=4, eval_every=10)
    ref = simulate(**kw)
    got = simulate(**kw, compression="int8", error_feedback=True)
    # compression error is fed back, so the final loss matches closely
    np.testing.assert_allclose(got["loss"][-1], ref["loss"][-1], rtol=5e-2,
                               atol=1e-6)


def test_train_step_threads_ef_state():
    from repro.configs import (DataConfig, DistConfig, OptimizerConfig,
                               TrainConfig, get_model_config)
    from repro.train.trainer import Trainer
    cfg = get_model_config("qwen3-0.6b", reduced=True)
    tcfg = TrainConfig(model=cfg,
                       dist=DistConfig(algorithm="gossip_pga",
                                       topology="ring",
                                       comm_compression="int8",
                                       comm_error_feedback=True),
                       optimizer=OptimizerConfig(name="sgd", lr=0.05),
                       data=DataConfig(), global_batch=8, seq_len=16,
                       steps=2, log_every=0)
    tr = Trainer(tcfg, n_nodes=4, with_consensus=True)
    state = tr.init_state(jax.random.PRNGKey(0))
    assert state.ef_state is not None
    state = tr.run(state, steps=2)
    assert state.ef_state is not None
    ef_norm = sum(float(jnp.sum(jnp.abs(lf)))
                  for lf in jax.tree.leaves(state.ef_state))
    assert np.isfinite(ef_norm) and ef_norm > 0
    for p in jax.tree.leaves(state.params):
        assert np.all(np.isfinite(np.asarray(p, np.float32)))


# ---------------------------------------------------------------------------
# Compressed global/pod-averaging collective (DESIGN.md §2.3 "Compressed
# collectives"): the ISSUE-4 tentpole, stacked backends
# ---------------------------------------------------------------------------
COLLECTIVE = ["int8", "fp8"]
AVG_PHASES = [("global", 1), ("pod_avg", 2), ("pod_avg", 4)]


def test_collective_registry_matches_distconfig_vocabulary():
    from repro.configs import DistConfig
    for name in C.COLLECTIVE_COMPRESSORS:
        kw = {"comm_global_compression": name}
        if name in ("int8", "fp8"):
            kw["comm_error_feedback"] = True
        DistConfig(**kw).validate()
    with pytest.raises(ValueError, match="comm_global_compression"):
        DistConfig(comm_global_compression="topk").validate()
    # EF is legal with only the collective compressed
    DistConfig(comm_global_compression="int8",
               comm_error_feedback=True).validate()


@pytest.mark.parametrize("name", COLLECTIVE)
@pytest.mark.parametrize("phase,n_pods", AVG_PHASES)
def test_collective_backend_parity(name, phase, n_pods, rng_key):
    comp = C.make_compressor(name)
    tree = _tree(rng_key, 8)
    spec = mixing.CommSpec(topology="ring", n_nodes=8, n_pods=n_pods,
                           global_compressor=comp)
    ref, ef_r = mixing.communicate(tree, spec, phase=phase, seed=7)
    pal, ef_p = mixing.communicate(tree, spec.replace(backend="pallas"),
                                   phase=phase, seed=7)
    assert ef_r is None and ef_p is None
    _close(pal, ref, atol=2e-5)
    # the lossy collective actually moved the state (not a silent no-op)
    moved = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(ref),
                                jax.tree.leaves(tree)))
    assert moved > 0


@pytest.mark.parametrize("name", COLLECTIVE)
def test_collective_constant_fixed_point_bitwise(name):
    """Stronger than the psum path: the anchored accumulate + shared
    two-stage randomness make a consensus state survive **bitwise** on
    both stacked backends."""
    comp = C.make_compressor(name)
    tree = {"w": jnp.full((8, 5, 3), -2.25, jnp.float32),
            "b": jnp.full((8, 7), 0.1, jnp.float32)}
    for phase, n_pods in AVG_PHASES:
        for backend in ("reference", "pallas"):
            spec = mixing.CommSpec(topology="ring", n_nodes=8,
                                   n_pods=n_pods, backend=backend,
                                   global_compressor=comp)
            got, _ = mixing.communicate(tree, spec, phase=phase, seed=9)
            for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
                np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_collective_identity_bit_identical(backend, rng_key):
    """comm_global_compression='identity' routes to the exact psum path."""
    tree = _tree(rng_key, 8)
    for phase, n_pods in AVG_PHASES:
        spec = mixing.CommSpec(topology="ring", n_nodes=8, n_pods=n_pods,
                               backend=backend)
        want = mixing.communicate(tree, spec, phase=phase)
        got, ef = mixing.communicate(
            tree,
            spec.replace(global_compressor=C.make_compressor("identity")),
            phase=phase)
        assert ef is None
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            assert g.dtype == w.dtype
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_collective_error_feedback_parity(rng_key):
    comp = C.make_compressor("int8")
    tree = _tree(rng_key, 8)
    ef0 = C.init_ef_state(tree)
    spec = mixing.CommSpec(topology="ring", n_nodes=8,
                           global_compressor=comp)
    r_m, r_e = mixing.communicate(tree, spec, phase="global",
                                  ef_state=ef0, seed=1)
    p_m, p_e = mixing.communicate(tree, spec.replace(backend="pallas"),
                                  phase="global", ef_state=ef0, seed=1)
    _close(p_m, r_m, atol=2e-5)
    _close(p_e, r_e, atol=2e-5)
    assert sum(float(jnp.sum(jnp.abs(lf))) for lf in jax.tree.leaves(r_e)) > 0


def test_identity_global_supersedes_lossy_gossip(rng_key):
    """Regression: an averaging phase configured with the **identity**
    global codec runs the documented "exact psum path, bit-identically"
    even when the gossip ``compressor`` is lossy — previously the
    dispatch recursed with the lossy gossip compressor still attached and
    ran the compensated psum instead.  Gossip rounds keep the gossip
    compressor."""
    tree = _tree(rng_key, 8)
    ident, lossy = C.make_compressor("identity"), C.make_compressor("int8")
    for phase, n_pods in AVG_PHASES:
        for backend in ("reference", "pallas"):
            spec = mixing.CommSpec(topology="ring", n_nodes=8,
                                   n_pods=n_pods, backend=backend)
            want = mixing.communicate(tree, spec, phase=phase)
            got, ef = mixing.communicate(
                tree, spec.replace(compressor=lossy,
                                   global_compressor=ident),
                phase=phase, seed=3)
            assert ef is None
            for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
                assert g.dtype == w.dtype
                np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    # ...and the gossip phase still runs the lossy gossip compressor
    gspec = mixing.CommSpec(topology="ring", n_nodes=8, compressor=lossy)
    want, _ = mixing.communicate(tree, gspec, phase="gossip", seed=3)
    got, _ = mixing.communicate(tree,
                                gspec.replace(global_compressor=ident),
                                phase="gossip", seed=3)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_collective_supersedes_gossip_compressor_on_global(rng_key):
    """With both knobs lossy, the averaging phase is served by the
    collective alone (per-phase override): identical to the run where only
    the collective is configured."""
    tree = _tree(rng_key, 8)
    gc = C.make_compressor("int8")
    spec = mixing.CommSpec(topology="ring", n_nodes=8,
                           global_compressor=gc)
    only_global, _ = mixing.communicate(tree, spec, phase="global", seed=5)
    both, _ = mixing.communicate(
        tree, spec.replace(compressor=C.make_compressor("topk", k=3)),
        phase="global", seed=5)
    for g, w in zip(jax.tree.leaves(both), jax.tree.leaves(only_global)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    # ...and gossip rounds stay with the gossip compressor
    gspec = mixing.CommSpec(topology="ring", n_nodes=8,
                            compressor=C.make_compressor("int8"))
    want, _ = mixing.communicate(tree, gspec, phase="gossip", seed=5)
    got, _ = mixing.communicate(tree, gspec.replace(global_compressor=gc),
                                phase="gossip", seed=5)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_collective_wire_bytes_model():
    """The analytic global-phase model follows the collective payload
    (codes + one uint8 exponent per power-of-two block scale — the fp32
    scale word no longer crosses the wire): ≥4× vs fp32 up to the
    exponent-byte slack, and the dry-run's honest 1.0× is gone."""
    from repro.compress import collective as ccol
    d = 1 << 20
    fp32 = C.round_wire_bytes("global", "ring", 8, d)
    comp = C.round_wire_bytes("global", "ring", 8, d,
                              global_compression="int8")
    dp = -(-d // ccol.QBLOCK) * ccol.QBLOCK
    floor = 4.0 * d / (dp + dp // ccol.QBLOCK)
    assert fp32 / comp >= floor - 1e-9
    assert fp32 / comp > 3.99
    # pod_avg follows the same collective accounting
    assert C.round_wire_bytes("pod_avg", "ring", 8, d, n_pods=2,
                              global_compression="int8") == comp
    # without the knob the psum stays comm_dtype-bound (old behavior)
    assert C.round_wire_bytes("global", "ring", 8, d) == d * 4
    assert C.round_wire_bytes("global", "ring", 8, d,
                              comm_dtype="bfloat16") == d * 2


def test_collective_qblock_padding_invariance(rng_key):
    """Padding amount must not leak into real columns: a ragged D and the
    same data embedded in a wider zero-padded matrix quantize real columns
    identically (block boundaries are absolute-column keyed)."""
    from repro.compress import collective as ccol
    x = jax.random.normal(rng_key, (8, 37))
    a, _ = ccol.collective_round(x, None, "int8", jnp.uint32(3))
    wide, _ = ccol.collective_round(ccol.pad_cols(x, 8 * ccol.QBLOCK), None,
                                    "int8", jnp.uint32(3))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(wide[:, :37]))


def test_collective_rejects_sparsifier_kind():
    from repro.compress import collective as ccol
    with pytest.raises(ValueError, match="unsupported kind"):
        ccol.quantize_blocks(jnp.zeros((2, ccol.QBLOCK)), "topk",
                             jnp.uint32(0))


def test_pod_avg_rejects_indivisible_pods_before_noop(rng_key):
    """Validation fires before any no-op early return: even the n_nodes=1
    degenerate call reports the misconfiguration instead of silently
    returning the input."""
    x = jax.random.normal(rng_key, (8, 4))
    base = mixing.CommSpec(topology="ring", n_nodes=8, n_pods=3)
    for spec in (base,
                 base.replace(global_compressor=C.make_compressor("int8")),
                 base.replace(compressor=C.make_compressor("int8"))):
        with pytest.raises(ValueError, match="does not divide"):
            mixing.communicate(x, spec, phase="pod_avg", seed=1)
    with pytest.raises(ValueError, match="does not divide"):
        mixing.communicate(jnp.zeros((1, 4)),
                           mixing.CommSpec(topology="ring", n_nodes=1,
                                           n_pods=3), phase="pod_avg")


# ---------------------------------------------------------------------------
# Sharded path: compressed halo exchange (8 forced host devices)
# ---------------------------------------------------------------------------
_SHARDED_COMPRESSED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import mixing
    from repro import compress as C

    mesh = jax.make_mesh((8,), ("data",))
    n = 16
    t = {"w": jax.random.normal(jax.random.PRNGKey(0), (n, 5, 3)),
         "b": jax.random.normal(jax.random.PRNGKey(1), (n,))}

    def close(got, want, atol):
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_allclose(np.asarray(g, np.float32),
                                       np.asarray(w, np.float32), atol=atol)

    CASES = [("int8", "gossip", "ring", 1), ("int8", "gossip", "grid", 1),
             ("int8", "global", "ring", 1), ("int8", "pod_avg", "ring", 4),
             ("fp8", "gossip", "one_peer_exp", 1),
             ("topk", "gossip", "ring", 1), ("randk", "gossip", "exp", 1)]
    for name, phase, topol, n_pods in CASES:
        comp = C.make_compressor(name, k=3)
        spec = mixing.CommSpec(topology=topol, n_nodes=n, n_pods=n_pods,
                               compressor=comp)
        want, _ = mixing.communicate(t, spec, phase=phase, step=3, seed=11)
        got, _ = mixing.communicate(
            t, spec.replace(backend="pallas", mesh=mesh),
            phase=phase, step=3, seed=11)
        close(got, want, 2e-5)
        print(f"CPARITY_OK {name}/{phase}/{topol}")

    # global phase with bf16 wire: the psum operand cast matches the
    # local backends' cast of q
    comp = C.make_compressor("int8")
    spec = mixing.CommSpec(topology="ring", n_nodes=n,
                           comm_dtype=jnp.bfloat16, compressor=comp)
    want, _ = mixing.communicate(t, spec, phase="global", seed=7)
    got, _ = mixing.communicate(
        t, spec.replace(backend="pallas", mesh=mesh), phase="global",
        seed=7)
    close(got, want, 2e-5)
    print("CGLOBAL_BF16_OK")

    # EF threading across the sharded path matches the local reference
    comp = C.make_compressor("int8")
    ef0 = C.init_ef_state(t)
    spec = mixing.CommSpec(topology="exp", n_nodes=n, compressor=comp)
    wm, we = mixing.communicate(t, spec, phase="gossip", ef_state=ef0,
                                seed=2)
    gm, ge = mixing.communicate(
        t, spec.replace(backend="pallas", mesh=mesh), phase="gossip",
        ef_state=ef0, seed=2)
    close(gm, wm, 2e-5); close(ge, we, 2e-5)
    print("CEF_OK")

    # identity under a sharded mesh: bitwise vs the uncompressed path
    sspec = mixing.CommSpec(topology="ring", n_nodes=n, backend="pallas",
                            mesh=mesh)
    want = mixing.communicate(t, sspec, phase="gossip")
    got, ef = mixing.communicate(
        t, sspec.replace(compressor=C.make_compressor("identity")),
        phase="gossip")
    assert ef is None
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    print("CIDENTITY_OK")

    # constant fixed point survives the halo exchange
    ct = jax.tree.map(lambda p: jnp.full_like(p, 1.5), t)
    got, _ = mixing.communicate(
        ct, sspec.replace(compressor=C.make_compressor("int8")),
        phase="gossip", seed=5)
    close(got, ct, 1e-6)
    print("CCONSTANT_OK")

    # ---- compressed collective (ISSUE 4): real all_to_all/all_gather of
    # int8/fp8 wire arrays vs the local reference ----
    for name, phase, pods in [("int8", "global", 1), ("int8", "pod_avg", 4),
                              ("int8", "pod_avg", 8),
                              ("fp8", "global", 1), ("fp8", "pod_avg", 4)]:
        comp = C.make_compressor(name)
        spec = mixing.CommSpec(topology="ring", n_nodes=n, n_pods=pods,
                               global_compressor=comp)
        want, _ = mixing.communicate(t, spec, phase=phase, seed=11)
        got, _ = mixing.communicate(
            t, spec.replace(backend="pallas", mesh=mesh), phase=phase,
            seed=11)
        close(got, want, 2e-5)
        print(f"COLL_OK {name}/{phase}/p{pods}")

    # collective EF threading matches the local reference
    comp = C.make_compressor("int8")
    ef0 = C.init_ef_state(t)
    spec = mixing.CommSpec(topology="ring", n_nodes=n,
                           global_compressor=comp)
    wm, we = mixing.communicate(t, spec, phase="global", ef_state=ef0,
                                seed=2)
    gm, ge = mixing.communicate(
        t, spec.replace(backend="pallas", mesh=mesh), phase="global",
        ef_state=ef0, seed=2)
    close(gm, wm, 2e-5); close(ge, we, 2e-5)
    print("COLL_EF_OK")

    # consensus state is a bitwise fixed point through the real exchange
    got, _ = mixing.communicate(
        ct, spec.replace(backend="pallas", mesh=mesh), phase="global",
        seed=5)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(ct)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    print("COLL_CONSTANT_OK")

    # identity collective under the mesh: bitwise vs the uncompressed psum
    want = mixing.communicate(t, sspec, phase="global")
    got, ef = mixing.communicate(
        t, sspec.replace(global_compressor=C.make_compressor("identity")),
        phase="global")
    assert ef is None
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    print("COLL_IDENTITY_OK")

    # regression: identity collective + LOSSY gossip compressor still runs
    # the exact psum on the averaging phases (the recursion used to
    # re-attach the gossip compressor and run the compensated psum)
    for phase, pods in (("global", 1), ("pod_avg", 4)):
        pspec = sspec.replace(n_pods=pods)
        want = mixing.communicate(t, pspec, phase=phase)
        got, ef = mixing.communicate(
            t, pspec.replace(compressor=C.make_compressor("int8"),
                             global_compressor=C.make_compressor(
                                 "identity")),
            phase=phase, seed=4)
        assert ef is None
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    print("COLL_IDENT_LOSSY_OK")

    # two-axis (pod, data) mesh: the flattened shard index keeps segment
    # order, so parity holds on hierarchical meshes too
    mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
    spec = mixing.CommSpec(topology="ring", n_nodes=n,
                           global_compressor=comp)
    want, _ = mixing.communicate(t, spec, phase="global", seed=9)
    got, _ = mixing.communicate(
        t, spec.replace(backend="pallas", mesh=mesh2), phase="global",
        seed=9)
    close(got, want, 2e-5)
    print("COLL_2AXIS_OK")
""")


def _run_forced_device_script(script: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:] + out.stderr[-4000:])
    return out.stdout


def test_sharded_compressed_parity_8dev():
    """Compressed halo exchange under a mesh-sharded node axis: the
    ppermuted wire arrays + compensated per-shard kernel must match the
    local reference for every compressor kind, EF included, with identity
    bit-identical (DESIGN.md §2.3).  The same subprocess also proves the
    compressed collective: the all_to_all/all_gather of int8/fp8 wire
    arrays matches both local backends, keeps consensus states bitwise
    fixed, and holds on two-axis (pod, data) meshes."""
    stdout = _run_forced_device_script(_SHARDED_COMPRESSED_SCRIPT)
    assert stdout.count("CPARITY_OK") == 7, stdout
    assert stdout.count("COLL_OK") == 5, stdout
    for marker in ("CGLOBAL_BF16_OK", "CEF_OK", "CIDENTITY_OK",
                   "CCONSTANT_OK", "COLL_EF_OK", "COLL_CONSTANT_OK",
                   "COLL_IDENTITY_OK", "COLL_IDENT_LOSSY_OK",
                   "COLL_2AXIS_OK"):
        assert marker in stdout, stdout
