"""Pallas kernel contract tests: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (flash_attention_op, flash_attention_ref,
                           rmsnorm_op, rmsnorm_ref)

FLASH_SWEEP = [
    # B, Sq, Sk, H, KH, D, causal, window, softcap, bq, bk
    (1, 64, 64, 4, 2, 32, True, None, None, 32, 32),
    (2, 100, 100, 4, 4, 16, True, 32, None, 32, 32),
    (1, 48, 48, 2, 1, 64, True, None, 50.0, 16, 16),
    (2, 32, 32, 8, 8, 8, False, None, None, 32, 32),
    (1, 128, 128, 2, 2, 128, True, None, None, 128, 128),
    (1, 17, 33, 3, 1, 24, False, None, None, 8, 16),   # ragged + cross-len
    (1, 256, 256, 1, 1, 64, True, 64, 30.0, 64, 64),   # window + softcap
]


@pytest.mark.parametrize("case", FLASH_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(case, dtype):
    B, Sq, Sk, H, KH, D, causal, window, softcap, bq, bk = case
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % 2**31), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, KH, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, KH, D), dtype)
    got = flash_attention_op(q, k, v, causal=causal, window=window,
                             softcap=softcap, block_q=bq, block_k=bk,
                             interpret=True)
    want = flash_attention_ref(q, k, v, causal=causal, window=window,
                               softcap=softcap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)
    assert got.dtype == dtype


@pytest.mark.parametrize("shape", [(8, 64), (3, 7, 96), (1, 128), (5, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("offset", [0.0, 1.0])
def test_rmsnorm_vs_ref(shape, dtype, offset):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, shape, dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (shape[-1],), jnp.float32)
    got = rmsnorm_op(x, w, offset=offset, block_rows=4, interpret=True)
    want = rmsnorm_ref(x, w, offset=offset)
    tol = 1e-5 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)
    assert got.dtype == dtype


MLSTM_SWEEP = [
    # B, S, nh, dk, dv, chunk
    (1, 37, 2, 8, 16, 8),
    (2, 64, 2, 16, 16, 16),
    (1, 100, 3, 8, 8, 32),
    (2, 16, 1, 4, 4, 16),
]


@pytest.mark.parametrize("case", MLSTM_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mlstm_chunk_kernel_vs_recurrent_oracle(case, dtype):
    from repro.kernels import mlstm_chunk_op, mlstm_chunk_ref
    B, S, nh, dk, dv, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % 2**31), 5)
    q = (jax.random.normal(ks[0], (B, S, nh, dk)) / np.sqrt(dk)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, nh, dk), dtype)
    v = jax.random.normal(ks[2], (B, S, nh, dv), dtype)
    li = 2.0 * jax.random.normal(ks[3], (B, S, nh), jnp.float32)
    lf = jax.nn.log_sigmoid(2.0 * jax.random.normal(ks[4], (B, S, nh)))
    got = mlstm_chunk_op(q, k, v, li, lf, chunk=chunk, interpret=True)
    want = mlstm_chunk_ref(q, k, v, li, lf)
    tol = 5e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=10 * tol)
    assert got.dtype == dtype


def test_flash_matches_model_attention_layer():
    """The kernel implements the model's GQA contract (same mask semantics)."""
    from repro.models.attention import _sdpa, attention_mask
    B, S, KH, g, D = 1, 64, 2, 2, 32
    H = KH * g
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KH, D))
    v = jax.random.normal(ks[2], (B, S, KH, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    mask = attention_mask(pos, pos, causal=True, window=16)
    want = _sdpa(q.reshape(B, S, KH, g, D), k, v, mask,
                 scale=D ** -0.5, cap=None, group=g).reshape(B, S, H, D)
    got = flash_attention_op(q, k, v, causal=True, window=16,
                             block_q=16, block_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)
