"""Prefill→decode consistency: decoding token-by-token after a prefill must
reproduce the full-sequence forward logits — per mixer family (GQA, MLA,
sliding-window, mamba, mLSTM, sLSTM hybrid paths)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_model_config
from repro.models import make_model
from repro.serve import Engine, pad_cache_to

CASES = ["pga-lm-100m", "gemma2-9b", "deepseek-v2-lite-16b", "xlstm-125m",
         "jamba-1.5-large-398b", "qwen2-0.5b"]


@pytest.mark.parametrize("arch", CASES)
def test_prefill_then_decode_matches_forward(arch):
    import dataclasses
    cfg = get_model_config(arch, reduced=True)
    if cfg.moe is not None:
        # This test checks PATH EQUALITY (prefill+decode vs full forward), so
        # two sources of *legitimate* path divergence are pinned:
        #  - drop-free capacity: expert-capacity dropping depends on the call's
        #    token count, so different paths drop different tokens at finite
        #    capacity (documented in models/moe.py);
        #  - fp32 activations: bf16 rounding differences amplify through the
        #    recurrent-state feedback of deep hybrid stacks (router near-tie
        #    flips), which is dtype robustness, not decode logic.
        # With both pinned the paths agree to ~1e-5 (verified exact).
        cfg = dataclasses.replace(
            cfg, dtype="float32", moe=dataclasses.replace(
                cfg.moe, capacity_factor=float(cfg.moe.n_routed)))
    model = make_model(cfg)
    key = jax.random.PRNGKey(1)
    params, _ = model.init(key)
    B, S_total, S_prompt = 2, 12, 6
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S_total), 0,
                                cfg.vocab_size)

    logits_full, _, _ = model.forward(params, {"inputs": tokens},
                                      mode="train")
    # prefill the prompt, then decode the remaining positions one by one
    _, caches, _ = model.forward(params, {"inputs": tokens[:, :S_prompt]},
                                 mode="prefill", want_cache=True)
    caches = pad_cache_to(caches, S_total)
    for t in range(S_prompt, S_total):
        pos = jnp.full((B,), t, jnp.int32)
        logits_t, caches = model.decode_step(params, caches,
                                             tokens[:, t:t + 1], pos)
        np.testing.assert_allclose(
            np.asarray(logits_t[:, 0], np.float32),
            np.asarray(logits_full[:, t], np.float32),
            atol=5e-2, rtol=5e-2)


@pytest.mark.parametrize("arch", ["pga-lm-100m", "xlstm-125m"])
def test_engine_greedy_matches_forward_argmax(arch):
    cfg = get_model_config(arch, reduced=True)
    model = make_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, s_max=16)
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 6), 0,
                                 cfg.vocab_size)
    gen = eng.generate(params, prompts, n_new=1)
    logits_full, _, _ = model.forward(params, {"inputs": prompts},
                                      mode="train")
    want = np.asarray(jnp.argmax(logits_full[:, -1], -1))
    np.testing.assert_array_equal(gen[:, 0], want)
