"""Async overlap (DESIGN.md §2.6) + the CommSpec round API (ISSUE 7).

Parity bar: the pipelined ``start_round``/``finish_round`` split must
reproduce the one-step-stale reference recursion

    x_{k+1} = y_k + (W − I)·y_{k−1},   y_k = x_k − γ g_k

*bit-for-bit* on the stacked backends (everything runs under jit, where
reference and pallas lower to the same fused arithmetic), and on the
sharded ppermute path for single-shift topologies; multi-neighbor sharded
rounds reduce neighbor terms in offset-block order, so they carry the
same ≤1-ulp association caveat as the synchronous sharded path and are
checked at atol.  Global/PGA rounds flush synchronously (exact global
average), the EF-compensated round preserves the node average against the
*stale* buffer, and one-step staleness only modestly lengthens the
logistic transient (paper's PGA analysis: staleness ~ larger effective H).
"""
import functools
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import make_compressor
from repro.configs.base import DistConfig
from repro.core import mixing, topology as topo
from repro.core.algorithms import Decentralized, simulate
from repro.core.schedule import make_schedule
from repro.data import make_logistic_problem

PROBLEM = make_logistic_problem(n=8, M=200, d=10, iid=False, seed=0)


# ---------------------------------------------------------------------------
# Tentpole semantics: simulate(overlap=True) == the stale recursion, bitwise
# ---------------------------------------------------------------------------
def _manual_stale_trajectory(problem, *, topology, n, steps, H, lr,
                             seed=0, eval_every=5):
    """Hand-rolled unpipelined oracle for gossip_pga: the buffered round
    of step k applies the compensated factors of the buffer's *priming*
    shift to y_{k-1}; global steps average synchronously and re-prime."""
    grad_fn = problem.grad_fn(batch=16)
    loss_fn = jax.jit(problem.loss_fn())
    sched = make_schedule(DistConfig(algorithm="gossip_pga",
                                     topology=topology, H=H))
    period = topo.schedule_period(topology, n)
    x = jnp.broadcast_to(jnp.zeros(problem.d), (n, problem.d))

    @functools.partial(jax.jit, static_argnames=("bshift",))
    def gossip_step(x, buf, key, k, gamma, bshift):
        g = grad_fn(x, key, k)
        y = x - gamma * g
        w, M = mixing.compensated_round_factors("gossip", topology, n,
                                                bshift, 1)
        x2 = y + (jnp.asarray(M) @ buf - jnp.asarray(w) * buf)
        return x2, y

    @jax.jit
    def global_step(x, key, k, gamma):
        g = grad_fn(x, key, k)
        y = x - gamma * g
        return jnp.broadcast_to(jnp.mean(y, axis=0), y.shape)

    key = jax.random.PRNGKey(seed)
    buf, bshift = x, sched.gossip_shift_step(0, period)
    losses, consensus = [], []
    for k in range(steps):
        key, sub = jax.random.split(key)
        gamma = float(lr(k)) if callable(lr) else float(lr)
        phase = sched.advance(k)
        shift = sched.gossip_shift_step(k, period)
        if phase == "gossip":
            # bshift cycles through the topology's bounded shift set —
            # jit compiles once per value, not once per iteration
            # repro: allow(RPR004)
            x, buf = gossip_step(x, buf, sub, k, gamma, bshift=bshift)
        else:
            x = global_step(x, sub, k, gamma)
            buf = x
        bshift = shift
        if k % eval_every == 0 or k == steps - 1:
            xbar = jnp.mean(x, axis=0)
            losses.append(float(loss_fn(xbar)))
            consensus.append(float(jnp.mean(jnp.sum((x - xbar) ** 2, -1))))
    return np.array(losses), np.array(consensus)


@pytest.mark.parametrize("topology", ["ring", "one_peer_exp"])
def test_overlap_simulate_matches_stale_recursion_bitwise(topology):
    steps, H = 25, 6
    out = simulate(algorithm="gossip_pga", grad_fn=PROBLEM.grad_fn(batch=16),
                   loss_fn=PROBLEM.loss_fn(), x0=jnp.zeros(PROBLEM.d),
                   n=PROBLEM.n, steps=steps, lr=0.1, topology=topology,
                   H=H, eval_every=5, overlap=True)
    want_loss, want_cons = _manual_stale_trajectory(
        PROBLEM, topology=topology, n=PROBLEM.n, steps=steps, H=H, lr=0.1)
    np.testing.assert_array_equal(out["loss"], want_loss)
    np.testing.assert_array_equal(out["consensus"], want_cons)


@pytest.mark.parametrize("topology", ["ring", "one_peer_exp", "grid"])
def test_overlap_reference_pallas_bitwise(topology):
    """Gossip-only pipelined trajectories are bit-identical across the
    stacked backends: under jit both lower to the same compensated-round
    arithmetic (global steps are excluded only because the *synchronous*
    global collective was already non-bitwise across backends)."""
    outs = {}
    for backend in ("reference", "pallas"):
        outs[backend] = simulate(
            algorithm="gossip", grad_fn=PROBLEM.grad_fn(batch=16),
            loss_fn=PROBLEM.loss_fn(), x0=jnp.zeros(PROBLEM.d),
            n=PROBLEM.n, steps=20, lr=0.1, topology=topology,
            eval_every=4, overlap=True, backend=backend)
    np.testing.assert_array_equal(outs["reference"]["loss"],
                                  outs["pallas"]["loss"])
    np.testing.assert_array_equal(outs["reference"]["consensus"],
                                  outs["pallas"]["consensus"])


# ---------------------------------------------------------------------------
# Sharded backend: start/finish over the ppermute halo (8 forced devices)
# ---------------------------------------------------------------------------
_SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import mixing
    from repro.compress import make_compressor, init_ef_state

    mesh = jax.make_mesh((8,), ("data",))
    n, d = 8, 96
    rng = np.random.default_rng(0)
    y = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)

    def finish(spec, step=1):
        rs, _ = mixing.start_round(b, spec)
        return mixing.finish_round(y, rs, spec, step=step)

    def jit_finish(spec, step=1):
        rs, _ = mixing.start_round(b, spec)
        return jax.jit(lambda yy, bb: mixing.finish_round(
            yy, bb, spec, step=step))(y, rs)

    # dense: single-shift topology is bitwise, multi-neighbor reduces
    # neighbor terms in offset-block order (<= 1 ulp association)
    for t, tol in (("one_peer_exp", 0.0), ("ring", 1e-6)):
        ref = mixing.CommSpec(topology=t, n_nodes=n)
        sh = mixing.CommSpec(topology=t, n_nodes=n, backend="pallas",
                             mesh=mesh, shard_mode="sharded")
        want = np.asarray(jit_finish(ref))
        got = np.asarray(finish(sh))
        if tol == 0.0:
            np.testing.assert_array_equal(got, want)
        else:
            np.testing.assert_allclose(got, want, atol=tol)

    # bf16 wire: both sides quantize the buffered payload identically
    ref16 = mixing.CommSpec(topology="ring", n_nodes=n,
                            comm_dtype=jnp.bfloat16)
    sh16 = mixing.CommSpec(topology="ring", n_nodes=n, backend="pallas",
                           mesh=mesh, shard_mode="sharded",
                           comm_dtype=jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(finish(sh16)),
                               np.asarray(jit_finish(ref16)), atol=1e-5)

    # int8 wire + EF: the packed codes ride the double buffer; the
    # compensated finish preserves the node average for any payload,
    # and the EF update matches the stacked path
    comp = make_compressor("int8")
    ef0 = init_ef_state(b)
    for t in ("one_peer_exp", "ring"):
        refc = mixing.CommSpec(topology=t, n_nodes=n, compressor=comp)
        shc = mixing.CommSpec(topology=t, n_nodes=n, backend="pallas",
                              mesh=mesh, shard_mode="sharded",
                              compressor=comp)
        rs_r, ef_r = mixing.start_round(b, refc, ef_state=ef0, seed=3)
        rs_s, ef_s = mixing.start_round(b, shc, ef_state=ef0, seed=3)
        for lr_, ls_ in zip(jax.tree.leaves(ef_r), jax.tree.leaves(ef_s)):
            np.testing.assert_allclose(np.asarray(lr_), np.asarray(ls_),
                                       atol=1e-7)
        got = np.asarray(mixing.finish_round(y, rs_s, shc, step=1))
        want = np.asarray(jax.jit(lambda yy: mixing.finish_round(
            yy, rs_r, refc, step=1))(y))
        np.testing.assert_allclose(got, want, atol=1e-5)
        np.testing.assert_allclose(got.mean(0), np.asarray(y).mean(0),
                                   atol=1e-5)
    print("OVERLAP_SHARDED_OK")
""")


def test_sharded_overlap_matches_reference():
    """start/finish over the shard_map ppermute path (subprocess so the
    forced 8-device host count never leaks into this session)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert "OVERLAP_SHARDED_OK" in out.stdout, out.stderr[-2000:]


# ---------------------------------------------------------------------------
# Flush, EF average preservation, staleness semantics
# ---------------------------------------------------------------------------
@pytest.mark.repro_guards
def test_pga_flush_restores_exact_global_average():
    n, d = 8, 33
    y = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    spec = mixing.CommSpec(topology="ring", n_nodes=n)
    mixed, buf, ef = mixing.overlap_flush(y, spec, phase="global")
    # explicit device_get only: this test runs under --repro-guards
    # (the oracle mean stays on device — numpy's pairwise float32 sum
    # need not match XLA's reduction bitwise)
    mixed_h, buf_q, want_row = jax.device_get((mixed, buf["q"],
                                               jnp.mean(y, axis=0)))
    want = np.broadcast_to(want_row, (n, d))
    np.testing.assert_array_equal(mixed_h, want)
    # the re-primed buffer is the flushed iterate itself
    np.testing.assert_array_equal(buf_q, mixed_h)
    assert ef is None


@pytest.mark.repro_guards
@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_ef_compressed_overlap_preserves_node_average(backend):
    """The self-compensated finish ``y + (M·q − w⊙q)`` preserves the node
    average for ANY buffered payload — including a stale int8+EF one."""
    n, d = 8, 50
    y = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    b = jax.random.normal(jax.random.PRNGKey(2), (n, d))
    from repro.compress import init_ef_state
    spec = mixing.CommSpec(topology="ring", n_nodes=n, backend=backend,
                           compressor=make_compressor("int8"))
    rs, ef = mixing.start_round(b, spec, ef_state=init_ef_state(b), seed=5)
    out = mixing.finish_round(y, rs, spec, step=1)
    # explicit device_get only: this test runs under --repro-guards
    got_mean, want_mean, ef_mass = jax.device_get(
        (jnp.mean(out, 0), jnp.mean(y, 0),
         jnp.sum(jnp.abs(jax.tree.leaves(ef)[0]))))
    np.testing.assert_allclose(got_mean, want_mean, atol=1e-5)
    # EF memory advanced against the buffered (stale) payload
    assert float(ef_mass) > 0.0


def test_phase_none_leaves_buffer_in_flight():
    """'none' steps neither finish nor re-prime: the stale buffer stays
    exactly as primed (simulate's disconnected-local steps rely on it)."""
    n, d = 4, 12
    x = jax.random.normal(jax.random.PRNGKey(3), (n, d))
    spec = mixing.CommSpec(topology="ring", n_nodes=n)
    buf, _ = mixing.start_round(x, spec)
    out = simulate(algorithm="local", grad_fn=PROBLEM.grad_fn(batch=16),
                   loss_fn=PROBLEM.loss_fn(), x0=jnp.zeros(PROBLEM.d),
                   n=PROBLEM.n, steps=12, lr=0.1, H=6, eval_every=3,
                   overlap=True)
    assert np.all(np.isfinite(out["loss"]))


def test_overlap_transient_bounded_vs_sync():
    """One-step staleness behaves like a modestly larger effective H
    (paper's PGA bound): the pipelined transient must stay within a
    small factor of the synchronous one and reach the same loss scale."""
    kw = dict(algorithm="gossip_pga", grad_fn=PROBLEM.grad_fn(batch=16),
              loss_fn=PROBLEM.loss_fn(), x0=jnp.zeros(PROBLEM.d),
              n=PROBLEM.n, steps=300, lr=0.1, topology="ring", H=8,
              eval_every=10)
    sync = simulate(**kw)
    over = simulate(**kw, overlap=True)
    f_end = min(sync["loss"].min(), over["loss"].min())
    sub_sync = np.maximum(sync["loss"] - f_end, 1e-12)
    sub_over = np.maximum(over["loss"] - f_end, 1e-12)
    assert over["loss"][-1] <= sync["loss"][-1] + 0.02
    assert np.trapezoid(sub_over) <= 2.0 * np.trapezoid(sub_sync)


def test_push_sum_overlap_rejected():
    with pytest.raises(ValueError, match="comm_overlap"):
        DistConfig(push_sum=True, comm_overlap=True,
                   topology="directed_ring").validate()


# ---------------------------------------------------------------------------
# CommSpec API: shim deprecation, spec+legacy mixing, forwarding regression
# ---------------------------------------------------------------------------
def test_legacy_kwarg_form_deprecated_but_equivalent():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
    with pytest.warns(DeprecationWarning, match="CommSpec"):
        # the deprecated form itself is the subject under test
        # repro: allow(RPR002)
        legacy = mixing.communicate(x, phase="gossip", topology="ring",
                                    n_nodes=4)
    spec = mixing.CommSpec(topology="ring", n_nodes=4)
    with warnings.catch_warnings():
        warnings.simplefilter("error")   # spec form must be warning-free
        primary = mixing.communicate(x, spec, phase="gossip")
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(primary))


def test_spec_plus_legacy_kwarg_is_an_error():
    x = jnp.zeros((4, 8))
    spec = mixing.CommSpec(topology="ring", n_nodes=4)
    with pytest.raises(TypeError, match="CommSpec"):
        mixing.communicate(x, spec, phase="gossip", topology="ring")
    with pytest.raises(TypeError, match="CommSpec"):
        mixing.communicate(x, spec, phase="gossip", backend="pallas")


def test_communicate_without_topology_raises():
    with pytest.raises(TypeError):
        mixing.communicate(jnp.zeros((4, 8)), phase="gossip")


def test_commspec_validate_rejects_bad_knobs():
    with pytest.raises(ValueError):
        mixing.CommSpec(topology="ring", n_nodes=4,
                        backend="cuda").validate()
    with pytest.raises(ValueError):
        mixing.CommSpec(topology="ring", n_nodes=4,
                        shard_mode="maybe").validate()


def test_dist_config_comm_spec_carries_every_knob():
    dist = DistConfig(topology="grid", n_pods=2, comm_backend="pallas",
                      comm_dtype="bfloat16", comm_compression="int8",
                      comm_global_compression="fp8",
                      node_axis="nodes", model_axis="mdl",
                      comm_shard_mode="stacked",
                      pallas_leaf_threshold=1234)
    spec = dist.comm_spec(16)
    assert (spec.topology, spec.n_nodes, spec.n_pods) == ("grid", 16, 2)
    assert spec.backend == "pallas" and spec.comm_dtype == jnp.bfloat16
    assert (spec.node_axis, spec.model_axis) == ("nodes", "mdl")
    assert spec.shard_mode == "stacked" and spec.leaf_threshold == 1234
    assert spec.compressor.name == "int8" and spec.lossy
    assert spec.global_compressor.name == "fp8"


def test_decentralized_forwards_sharded_routing():
    """Regression (ISSUE 7): Decentralized used to hand-forward a subset
    of the comm knobs, silently dropping mesh/shard_mode and degrading
    spec-carried sharded routing to stacked mode.  With the CommSpec
    migration the forced 'sharded' mode now *fails loudly* when no
    multi-device mesh reaches the round — the silent fallback is gone."""
    dist = DistConfig(comm_backend="pallas", comm_shard_mode="sharded")
    algo = Decentralized(dist, 4)
    assert algo.spec.shard_mode == "sharded"
    assert algo.spec.backend == "pallas"
    x = jnp.zeros((4, 8))
    with pytest.raises(ValueError, match="more than one device"):
        algo.communicate(x, "gossip", 0)
