"""Optimizers + schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OptimizerConfig
from repro.optim import clip_by_global_norm, make_optimizer, make_schedule


def _quad_min(opt_name, lr, steps=200, **kw):
    cfg = OptimizerConfig(name=opt_name, lr=lr, weight_decay=0.0,
                          schedule="constant", warmup_steps=0, **kw)
    opt = make_optimizer(cfg)
    target = jnp.asarray([1.0, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    for _ in range(steps):
        grads = {"w": params["w"] - target}
        params, state = opt.update(grads, state, params, lr)
    return float(jnp.linalg.norm(params["w"] - target))


@pytest.mark.parametrize("name,lr,tol", [("sgd", 0.1, 0.05),
                                         ("adamw", 0.05, 0.05),
                                         ("lamb", 0.05, 0.15)])
def test_optimizer_minimizes_quadratic(name, lr, tol):
    # LAMB's trust ratio gives scale-relative steps: it orbits the optimum at
    # a radius ~ lr·||w*|| ≈ 0.115 here on a bare quadratic — the tolerance
    # must sit above that radius (at 200 steps the orbit hasn't decayed yet;
    # it reaches 0.02 by 400)
    assert _quad_min(name, lr, steps=200) < tol


def test_sgd_nesterov_differs_from_plain():
    a = _quad_min("sgd", 0.05, steps=10, nesterov=True, momentum=0.9)
    b = _quad_min("sgd", 0.05, steps=10, nesterov=False, momentum=0.9)
    assert a != b


def test_lamb_per_node_trust_ratio_is_per_replica():
    """With per_node=True, scaling one node's params must not change the
    other node's update."""
    cfg = OptimizerConfig(name="lamb", lr=0.1, weight_decay=0.0)
    opt = make_optimizer(cfg, per_node=True)
    params = {"w": jnp.stack([jnp.ones(4), 100.0 * jnp.ones(4)])}
    grads = {"w": jnp.ones((2, 4))}
    state = opt.init(params)
    new_params, _ = opt.update(grads, state, params, 0.1)
    delta = np.asarray(params["w"] - new_params["w"])
    # trust ratio scales with ||w||: node 1's step must be ~100x node 0's
    assert delta[1].mean() / delta[0].mean() > 50


def test_clip_by_global_norm():
    grads = {"a": jnp.ones(4) * 10, "b": jnp.ones(3) * 10}
    clipped = clip_by_global_norm(grads, 1.0)
    total = np.sqrt(sum(np.sum(np.asarray(g) ** 2)
                        for g in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(total, 1.0, rtol=1e-5)


def test_schedules():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          schedule="warmup_cosine")
    fn = make_schedule(cfg)
    assert fn(0) < fn(9) <= 1.0
    assert fn(99) < 0.01
    step_cfg = OptimizerConfig(lr=1.0, warmup_steps=0, schedule="step",
                               decay_steps=(30, 60), decay_factor=0.1)
    sfn = make_schedule(step_cfg)
    np.testing.assert_allclose([sfn(0), sfn(30), sfn(60)], [1.0, 0.1, 0.01],
                               rtol=1e-6)
