"""Telemetry subsystem (DESIGN.md §2.7): sink schema round-trip, Chrome
trace export, comm-round byte meters vs the analytic cost model, overlap
issue/apply accounting, fault events in the stream, and the
zero-per-step-host-sync regression on the Trainer hot path."""
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.compress import round_wire_bytes
from repro.configs import (DataConfig, DistConfig, OptimizerConfig,
                           TrainConfig, get_model_config)
from repro.core import mixing
from repro.core.algorithms import simulate
from repro.core.faults import FaultSchedule
from repro.train import Trainer

CFG = get_model_config("pga-lm-100m", reduced=True)


def _tcfg(algorithm="gossip_pga", H=4, **dist_kw):
    return TrainConfig(
        model=CFG,
        dist=DistConfig(algorithm=algorithm, topology="ring", H=H,
                        **dist_kw),
        optimizer=OptimizerConfig(name="sgd", lr=0.05, schedule="constant",
                                  warmup_steps=0, grad_clip=1.0),
        data=DataConfig(non_iid=True), global_batch=8, seq_len=32,
        log_every=0)


def _quadratic(d=6, m=48):
    A = jax.random.normal(jax.random.PRNGKey(11), (m, d))
    b = jax.random.normal(jax.random.PRNGKey(12), (m,))

    def loss_fn(x):
        return 0.5 * jnp.mean((A @ x - b) ** 2)

    def grad_fn(xs, key, k):
        return jax.vmap(jax.grad(loss_fn))(xs)

    return loss_fn, grad_fn, d


# ---------------------------------------------------------------------------
# Hub + sinks
# ---------------------------------------------------------------------------
def test_sink_schema_roundtrip(tmp_path):
    path = str(tmp_path / "t.jsonl")
    tel = obs.Telemetry(sinks=[obs.JsonlSink(path), obs.RingSink()],
                        tags={"algorithm": "unit"})
    tel.emit("step", step=3, phase="gossip", loss=1.25)
    tel.emit("comm_round", phase="global", role="round",
             measured_bytes=128)
    tel.emit("ckpt", step=4)
    tel.close()
    recs = [json.loads(ln) for ln in open(path)]
    assert [r["type"] for r in recs] == ["step", "comm_round", "ckpt"]
    for r in recs:
        assert r["schema"] == obs.SCHEMA_VERSION
        assert r["algorithm"] == "unit"      # hub tags stamped on every rec
        assert isinstance(r["ts"], float)
    assert recs[0]["loss"] == 1.25
    # the ring sink saw the identical records
    ring = tel.ring()
    assert [r["type"] for r in ring.records()] == [r["type"] for r in recs]
    assert ring.records("step")[0]["step"] == 3


def test_emit_unknown_type_and_missing_fields_raise():
    tel = obs.Telemetry()
    with pytest.raises(ValueError, match="unknown record type"):
        tel.emit("nonsense", step=0)
    with pytest.raises(ValueError, match="missing required"):
        tel.emit("step", step=0)             # no phase


def test_pretty_sink_matches_legacy_format():
    import io
    buf = io.StringIO()
    tel = obs.Telemetry(sinks=[obs.PrettySink(stream=buf)],
                        tags={"algorithm": "gossip_pga"})
    tel.emit("step", step=7, phase="gossip", loss=6.5, consensus=1e-3)
    tel.emit("comm_round", phase="gossip", role="round")  # not printed
    out = buf.getvalue()
    assert out == ("[gossip_pga] step     7 loss=6.5000 phase=gossip"
                   " consensus=1.000e-03\n")


def test_telemetry_scope_nesting():
    a, b = obs.Telemetry(), obs.Telemetry()
    assert obs.get_telemetry() is None
    with obs.telemetry_scope(a):
        assert obs.get_telemetry() is a
        with obs.telemetry_scope(b):
            assert obs.get_telemetry() is b
        assert obs.get_telemetry() is a
    assert obs.get_telemetry() is None


# ---------------------------------------------------------------------------
# Chrome trace export
# ---------------------------------------------------------------------------
def test_chrome_trace_valid_and_nested(tmp_path):
    tr = obs.Tracer()
    with tr.span("train/step", step=0):
        with tr.span("comm/issue"):
            pass
        with tr.span("comm/apply"):
            pass
    path = tr.save(str(tmp_path / "trace.json"))
    doc = json.load(open(path))            # valid JSON round-trip
    evs = doc["traceEvents"]
    assert {e["name"] for e in evs} == {"train/step", "comm/issue",
                                        "comm/apply"}
    for e in evs:
        assert e["ph"] == "X" and e["ts"] >= 0 and e["dur"] >= 0
    outer = next(e for e in evs if e["name"] == "train/step")
    for e in evs:
        if e is outer:
            continue
        # child spans nest inside the parent by time containment
        assert e["ts"] >= outer["ts"]
        assert e["ts"] + e["dur"] <= outer["ts"] + outer["dur"] + 1e-3
    assert outer["args"] == {"step": 0}


def test_fenced_time_records_spans():
    tr = obs.Tracer()
    x = jnp.arange(8.0)
    us = obs.fenced_time(jnp.sum, x, iters=3, warmup=1,
                         name="bench/sum", tracer=tr)
    assert us > 0
    assert [e["name"] for e in tr.events] == ["bench/sum"] * 3


# ---------------------------------------------------------------------------
# Comm meters: measured == analytic on the reference backend
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("compression", ["identity", "int8"])
@pytest.mark.parametrize("phase", ["gossip", "global"])
def test_comm_round_measured_matches_analytic(compression, phase):
    n, shapes = 8, [(32,), (7,)]
    params = [jnp.ones((n,) + s, jnp.float32) for s in shapes]
    per_node = sum(int(np.prod(s)) for s in shapes)
    spec = DistConfig(algorithm="gossip_pga", topology="ring",
                      comm_backend="reference",
                      comm_compression=compression).comm_spec(n)
    tel = obs.Telemetry(sinks=[obs.RingSink()])
    with obs.telemetry_scope(tel):
        mixing.communicate(params, spec, phase=phase, step=0)
    recs = tel.ring().records("comm_round")
    assert len(recs) == 1
    r = recs[0]
    assert r["phase"] == phase and r["role"] == "round"
    assert r["compression"] == compression
    want = round_wire_bytes(phase, "ring", n, per_node,
                            compression=compression,
                            leaf_sizes=[int(np.prod(s)) for s in shapes])
    assert r["analytic_bytes"] == want
    assert r["measured_bytes"] == want     # packed-buffer bytes agree


def test_comm_round_meter_noop_without_hub():
    n = 4
    params = [jnp.ones((n, 8), jnp.float32)]
    spec = DistConfig(algorithm="gossip_pga",
                      topology="ring").comm_spec(n)
    assert obs.get_telemetry() is None
    out = mixing.communicate(params, spec, phase="gossip", step=0)
    assert jax.tree.leaves(out)[0].shape == (n, 8)


# ---------------------------------------------------------------------------
# Overlap: issue/apply records iff comm_overlap; occupancy reported
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("overlap", [False, True])
def test_overlap_issue_apply_iff_comm_overlap(overlap):
    loss_fn, grad_fn, d = _quadratic()
    tel = obs.Telemetry(sinks=[obs.RingSink()])
    simulate(algorithm="gossip_pga", grad_fn=grad_fn, loss_fn=loss_fn,
             x0=jnp.zeros(d), n=4, steps=8, lr=0.05, topology="ring",
             H=4, eval_every=4, overlap=overlap, telemetry=tel)
    roles = {r["role"] for r in tel.ring().records("comm_round")}
    span_names = {e["name"] for e in tel.tracer.events}
    if overlap:
        assert {"issue", "apply"} <= roles
        assert {"comm/issue", "comm/apply"} <= span_names
    else:
        assert "issue" not in roles and "apply" not in roles
        assert "comm/issue" not in span_names
        assert "round" in roles


def test_trainer_overlap_occupancy_record():
    tcfg = _tcfg(comm_overlap=True)
    tr = Trainer(tcfg, n_nodes=4, measure_occupancy=True)
    state = tr.init_state(jax.random.PRNGKey(0))
    tr.run(state, steps=4, log_every=2)
    occ = [r for r in tr.telemetry.ring().records("comm_round")
           if r.get("role") == "occupancy"]
    assert len(occ) == 1
    assert 0.0 <= occ[0]["occupancy"] <= 1.0
    assert occ[0]["t_round_sync_us"] > 0
    # period boundaries emitted pipeline-flush records
    assert tr.telemetry.ring().records("flush")


# ---------------------------------------------------------------------------
# Fault events appear in the stream
# ---------------------------------------------------------------------------
def test_fault_events_in_stream():
    loss_fn, grad_fn, d = _quadratic()
    fs = FaultSchedule(n_nodes=4, drops={3: (1,)}, rejoins={6: (1,)})
    tel = obs.Telemetry(sinks=[obs.RingSink()])
    simulate(algorithm="gossip_pga", grad_fn=grad_fn, loss_fn=loss_fn,
             x0=jnp.zeros(d), n=4, steps=8, lr=0.05,
             topology="directed_ring", H=4, eval_every=4,
             push_sum=True, fault_schedule=fs, telemetry=tel)
    faults = tel.ring().records("fault")
    assert [(f["step"], f["kind"], f["nodes"]) for f in faults] == \
        [(3, "drop", [1]), (6, "rejoin", [1])]
    # push-sum rounds still meter their wire traffic (runtime-W record)
    comm = tel.ring().records("comm_round")
    assert comm and all(c["phase"] == "push_sum" for c in comm)
    steps = tel.ring().records("step")
    assert steps and "mass" in steps[-1]


# ---------------------------------------------------------------------------
# Zero per-step host syncs on the no-logging hot path (regression)
# ---------------------------------------------------------------------------
@pytest.mark.repro_guards
def test_trainer_hot_path_zero_per_step_host_syncs(monkeypatch):
    """log_every=0 gossip_aga run crossing a global boundary: the loop
    must never implicitly sync (float()/np.asarray on device values) —
    enforced by the transfer guard, which permits only the *explicit*
    ``jax.device_get`` transfers; those must stay O(boundaries), not
    O(steps)."""
    tcfg = _tcfg(algorithm="gossip_aga")
    tr = Trainer(tcfg, n_nodes=4)
    state = tr.init_state(jax.random.PRNGKey(0))

    calls = {"n": 0}
    real = jax.device_get

    def counting(tree):
        calls["n"] += 1
        return real(tree)

    monkeypatch.setattr(jax, "device_get", counting)
    steps = 10    # AGA H_init=4 -> crosses two global boundaries
    with jax.transfer_guard_device_to_host("disallow"):
        state = tr.run(state, steps=steps, log_every=0)
    # start-step read + one lazy materialization per global boundary;
    # strictly fewer transfers than steps == no per-step sync
    assert calls["n"] < steps
    assert int(real(state.step)) == steps
    # the schedule did adapt (the lazy loss signal arrived)
    assert len(tr.schedule.history) >= 2


def test_trainer_log_boundary_batched_fetch():
    """With logging on, host materialization is ONE counted fetch per
    log boundary (not per step), and history keeps the legacy keys."""
    tcfg = _tcfg()
    tr = Trainer(tcfg, n_nodes=4, with_consensus=True)
    state = tr.init_state(jax.random.PRNGKey(0))
    tr.run(state, steps=8, log_every=4)        # boundaries: k=0, 4, 7
    assert tr.telemetry.host_fetches == 3
    assert len(tr.history) == 3
    for rec in tr.history:
        for key in ("step", "phase", "lr", "time", "loss", "consensus"):
            assert key in rec
    assert tr.history[-1]["phase_counts"].get("gossip", 0) >= 1


# ---------------------------------------------------------------------------
# Serving telemetry
# ---------------------------------------------------------------------------
def test_serve_req_records():
    from repro.models import make_model
    from repro.serve import BatchedServer, Engine, Request
    model = make_model(CFG)
    params, _ = model.init(jax.random.PRNGKey(0))
    tel = obs.Telemetry(sinks=[obs.RingSink()])
    server = BatchedServer(Engine(model, s_max=32), params, n_slots=2,
                           telemetry=tel)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, CFG.vocab_size, size=4),
                    max_new=3) for i in range(3)]
    done = server.run(reqs)
    assert len(done) == 3
    recs = tel.ring().records("serve_req")
    assert sorted(r["uid"] for r in recs) == [0, 1, 2]
    for r in recs:
        assert r["latency_s"] > 0
        assert r["new_tokens"] == 3 and r["prompt_tokens"] == 4
        assert r["tokens_per_s"] > 0
    names = {e["name"] for e in tel.tracer.events}
    assert {"serve/prefill", "serve/decode"} <= names


# ---------------------------------------------------------------------------
# report.py integration
# ---------------------------------------------------------------------------
def test_telemetry_table_smoke(tmp_path, capsys):
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.report import telemetry_table
    path = str(tmp_path / "t.jsonl")
    tel = obs.Telemetry(sinks=[obs.JsonlSink(path)])
    tel.emit("comm_round", phase="gossip", role="round", topology="ring",
             backend="reference", compression="none", sends=2,
             analytic_bytes=312, measured_bytes=312)
    tel.emit("comm_round", phase="gossip", role="occupancy",
             occupancy=0.75, t_step_overlap_us=10.0,
             t_step_compute_us=8.0, t_round_sync_us=8.0)
    tel.emit("step", step=0, phase="gossip", loss=2.0, consensus=1e-2,
             phase_counts={"gossip": 9})
    tel.emit("step", step=9, phase="global", loss=1.0, consensus=1e-4)
    tel.emit("fault", step=3, kind="drop", nodes=[1])
    tel.emit("serve_req", uid=0, latency_s=0.01, tokens_per_s=100.0)
    tel.close()
    telemetry_table(path)
    out = capsys.readouterr().out
    assert "per-round communication" in out
    assert "| gossip | round | ring | reference | none | 2 | 312 | 312" \
        in out
    assert "pipeline occupancy: **0.75**" in out
    assert "loss: 2.0000 @ step 0 -> 1.0000 @ step 9" in out
    assert "step 3 drop [1]" in out
    assert "latency p50 10.0ms" in out


def test_trend_table_skips_unknown_schema(tmp_path, capsys):
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.report import HISTORY_SCHEMA, trend_table
    path = str(tmp_path / "hist.jsonl")
    rows = [
        {"sha": "aaaaaaa", "rows": [{"name": "mix", "ratio": 1.1}]},
        {"sha": "bbbbbbb", "schema": HISTORY_SCHEMA,
         "rows": [{"name": "mix", "ratio": 1.2}]},
        {"sha": "ccccccc", "schema": HISTORY_SCHEMA + 99,
         "future_field": [{"whatever": 1}]},
    ]
    with open(path, "w") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")
    trend_table(path)                      # must not raise
    cap = capsys.readouterr()
    assert "1.10 | 1.20" in cap.out        # v1 + v2 rows rendered
    assert "ccccccc" not in cap.out        # unknown schema skipped...
    assert "unknown schema" in cap.err     # ...with a warning
