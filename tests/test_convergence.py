"""Convergence behaviour on the paper's logistic-regression problem (§5.1):
consensus orderings and transient-stage behaviour at small scale."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import simulate
from repro.data import make_logistic_problem


@pytest.fixture(scope="module")
def problem():
    return make_logistic_problem(n=16, M=500, d=10, iid=False, seed=0)


def _run(problem, algorithm, steps=400, H=16, lr=0.05, topology="ring"):
    return simulate(
        algorithm=algorithm, grad_fn=problem.grad_fn(batch=16),
        loss_fn=problem.loss_fn(), x0=jnp.zeros(problem.d), n=problem.n,
        steps=steps, lr=lr, topology=topology, H=H, eval_every=20, seed=1)


def test_all_algorithms_decrease_loss(problem):
    for alg in ["parallel", "gossip", "local", "gossip_pga", "gossip_aga"]:
        out = _run(problem, alg, steps=200)
        assert out["loss"][-1] < out["loss"][0], alg


def test_consensus_ordering_pga_beats_gossip_and_local(problem):
    """Gossip-PGA's consensus error is below both baselines (averaged over
    the trajectory tail) — the mechanism behind Tables 2/3."""
    pga = _run(problem, "gossip_pga")
    gossip = _run(problem, "gossip")
    local = _run(problem, "local")
    tail = slice(len(pga["loss"]) // 2, None)
    assert pga["consensus"][tail].mean() < gossip["consensus"][tail].mean()
    assert pga["consensus"][tail].mean() < local["consensus"][tail].mean()


def test_pga_tracks_parallel_sgd(problem):
    """After the transient stage Gossip-PGA matches parallel SGD loss
    (paper Fig. 1) — within a small margin at this scale."""
    pga = _run(problem, "gossip_pga", steps=400)
    par = _run(problem, "parallel", steps=400)
    assert pga["loss"][-1] < par["loss"][-1] * 1.10 + 1e-3


def test_gossip_trails_on_sparse_ring(problem):
    """On a sparse ring with non-iid data, plain Gossip SGD's consensus error
    stays above Gossip-PGA's (slower transient, paper Fig. 1)."""
    pga = _run(problem, "gossip_pga", steps=300)
    gos = _run(problem, "gossip", steps=300)
    assert gos["consensus"][-1] > pga["consensus"][-1]


def test_aga_adapts_period(problem):
    out = simulate(
        algorithm="gossip_aga", grad_fn=problem.grad_fn(batch=16),
        loss_fn=problem.loss_fn(), x0=jnp.zeros(problem.d), n=problem.n,
        steps=300, lr=0.05, topology="ring", eval_every=1,
        aga_kwargs={"aga_h_init": 2, "aga_warmup": 20, "aga_h_max": 32})
    assert "H_history" in out and len(out["H_history"]) > 0
    assert all(1 <= h <= 32 for h in out["H_history"])
