"""Push-sum mixing (DESIGN.md §2.5): backend parity on directed topologies,
the de-biased fixed point, the PGA global weight reset, and composition with
wire compression — the weight scalar must stay exact throughout."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mixing, topology as topo
from repro.train.state import debias, init_push_weight

DIRECTED = list(topo.DIRECTED_TOPOLOGIES)


def _round(params, w, W, n, backend, **kw):
    return mixing.communicate_push_sum(
        params, w, W=jnp.asarray(W, jnp.float32), n_nodes=n,
        backend=backend, **kw)


# ---------------------------------------------------------------------------
# Reference semantics: one round is exactly (W·x, W·w)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("t", DIRECTED)
@pytest.mark.parametrize("n", [4, 8, 16])
def test_reference_round_is_dense_matmul(t, n, rng_key):
    x = jax.random.normal(rng_key, (n, 5, 3))
    w = jax.random.uniform(jax.random.PRNGKey(7), (n, 1), minval=0.5,
                           maxval=1.5)
    W = topo.push_sum_matrix(t, n)
    x2, w2 = _round(x, w, W, n, "reference")
    want_x = jnp.einsum("ij,jab->iab", jnp.asarray(W, jnp.float32), x)
    np.testing.assert_allclose(np.asarray(x2), np.asarray(want_x), atol=1e-6)
    np.testing.assert_allclose(np.asarray(w2),
                               np.asarray(W, np.float32) @ np.asarray(w),
                               atol=1e-6)


@pytest.mark.parametrize("t", DIRECTED)
def test_mass_conserved_bitwise(t):
    # column-stochastic + dyadic weights: Σw stays exactly n round after round
    n = 16
    w = init_push_weight(n)
    x = jax.random.normal(jax.random.PRNGKey(0), (n, 7))
    for k in range(12):
        active = np.ones(n, dtype=bool)
        if k >= 4:
            active[[2, 9]] = k >= 8    # drop mid-run, rejoin later
        W = topo.push_sum_matrix(t, n, active=active)
        x, w = _round(x, w, W, n, "reference")
        assert float(jnp.sum(w)) == float(n), k


# ---------------------------------------------------------------------------
# Backend parity: reference ≡ pallas stacked ≡ shard_map/ppermute
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("t", DIRECTED)
@pytest.mark.parametrize("with_fault", [False, True])
def test_pallas_stacked_matches_reference(t, with_fault, rng_key):
    n = 8
    tree = {"a": jax.random.normal(rng_key, (n, 6, 4)),
            "b": [jax.random.normal(jax.random.PRNGKey(3), (n, 17))]}
    w = jax.random.uniform(jax.random.PRNGKey(5), (n, 1), minval=0.5,
                           maxval=1.5)
    active = np.ones(n, dtype=bool)
    if with_fault:
        active[[1, 6]] = False
    W = topo.push_sum_matrix(t, n, active=active)
    xr, wr = _round(tree, w, W, n, "reference")
    xp, wp = _round(tree, w, W, n, "pallas")
    for lr, lp in zip(jax.tree.leaves(xr), jax.tree.leaves(xp)):
        np.testing.assert_allclose(np.asarray(lr), np.asarray(lp), atol=1e-6)
    np.testing.assert_allclose(np.asarray(wr), np.asarray(wp), atol=1e-6)


_SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import mixing, topology as topo

    mesh = jax.make_mesh((8,), ("nodes",))
    n = 8
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(n, 24)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 1.5, size=(n, 1)), jnp.float32)
    active = np.ones(n, dtype=bool)
    for t in ("directed_ring", "directed_exp"):
        for drop in (None, (3,)):
            a = active.copy()
            if drop:
                a[list(drop)] = False
            W = jnp.asarray(topo.push_sum_matrix(t, n, active=a), jnp.float32)
            xr, wr = mixing.communicate_push_sum(
                x, w, W=W, n_nodes=n, backend="reference")
            xs, ws = mixing.communicate_push_sum(
                x, w, W=W, n_nodes=n, backend="pallas", mesh=mesh,
                node_axis="nodes", shard_mode="sharded")
            np.testing.assert_allclose(np.asarray(xs), np.asarray(xr),
                                       atol=1e-5)
            np.testing.assert_allclose(np.asarray(ws), np.asarray(wr),
                                       atol=1e-6)
    # static offset superset from the fault schedule's hop set
    offs = mixing.push_sum_shard_offsets(8, 8, (0, 1, 2, 4))
    W = jnp.asarray(topo.push_sum_matrix("directed_exp", n), jnp.float32)
    xs, ws = mixing.communicate_push_sum(
        x, w, W=W, n_nodes=n, backend="pallas", mesh=mesh,
        node_axis="nodes", shard_mode="sharded", offsets=offs)
    xr, wr = mixing.communicate_push_sum(x, w, W=W, n_nodes=n,
                                         backend="reference")
    np.testing.assert_allclose(np.asarray(xs), np.asarray(xr), atol=1e-5)
    print("PUSH_SUM_SHARDED_OK")
""")


def test_sharded_ppermute_matches_reference():
    """The transpose-free sharded path (8 forced host devices) matches the
    dense reference for directed + fault matrices — subprocess so this
    session's device count is untouched."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert "PUSH_SUM_SHARDED_OK" in out.stdout, out.stderr[-2000:]


def test_push_sum_shard_offsets_superset():
    # 16 nodes over 8 shards (m=2): shift 1 straddles -> offsets {0, 1};
    # shift 4 is aligned -> offset 2; shift 2 -> offset 1
    offs = mixing.push_sum_shard_offsets(16, 8, (1, 2, 4))
    assert offs == (0, 1, 2)
    # everything-reachable fallback for the global phase
    assert mixing.push_sum_shard_offsets(8, 8, range(8)) == tuple(range(8))


# ---------------------------------------------------------------------------
# De-bias fixed point & PGA weight reset
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("t", DIRECTED)
def test_debiased_constant_fixed_point_bitwise(t):
    # x_i == c·w_i is invariant under the joint round, and while W's entries
    # stay dyadic (full participation) power-of-two scaling commutes with
    # fp rounding — the ratio x/w recovers c *bitwise* every round
    n = 8
    c = 2.0 ** -3
    w = init_push_weight(n)
    x = jnp.full((n, 4), c, jnp.float32)
    for k in range(6):
        W = topo.push_sum_matrix(t, n)
        x, w = _round(x, w, W, n, "reference")
        np.testing.assert_array_equal(np.asarray(debias(x, w)),
                                      np.full((n, 4), c, np.float32))


@pytest.mark.parametrize("t", DIRECTED)
def test_debiased_constant_fixed_point_under_faults(t):
    # fault renormalization makes W entries non-dyadic (e.g. 1/7), so the
    # x- and w-matmuls may round in different orders — the fixed point
    # holds to fp tolerance, and snaps back once participation is full
    n = 8
    c = 2.0 ** -3
    w = init_push_weight(n)
    x = jnp.full((n, 4), c, jnp.float32)
    for k in range(8):
        active = np.ones(n, dtype=bool)
        if k in (2, 3):
            active[5] = False
        W = topo.push_sum_matrix(t, n, active=active)
        x, w = _round(x, w, W, n, "reference")
        np.testing.assert_allclose(np.asarray(debias(x, w)), c, atol=1e-6)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_global_round_averages_weight_to_one(backend):
    # the raw kernel: a full-participation global round (W = 𝟙𝟙ᵀ/n) takes
    # every w_i to Σw/n = 1 up to summation-order rounding (≤ a few ulp)
    n = 8
    w = init_push_weight(n)
    x = jax.random.normal(jax.random.PRNGKey(1), (n, 5))
    active = np.ones(n, dtype=bool)
    active[4] = False
    for k in range(3):    # skew the weights with fault gossip rounds
        W = topo.push_sum_matrix("directed_exp", n, active=active)
        x, w = _round(x, w, W, n, backend)
    assert not np.allclose(np.asarray(w), 1.0)
    G = topo.global_push_matrix(n)          # full participation: exactly J
    x, w = _round(x, w, G, n, backend)
    np.testing.assert_allclose(np.asarray(w), 1.0, atol=1e-6)
    # and the de-biased params all equal the (exact) global average
    xa = np.asarray(x)
    assert np.abs(xa - xa[0]).max() < 1e-6


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_pga_global_phase_resets_weight_bitwise(backend):
    # the step layer snaps w to the exact-arithmetic result of the full-
    # participation global round — after any PGA global phase the weight
    # is *bitwise* 1.0 no matter how faults skewed it before
    from repro.core.algorithms import simulate
    from repro.core.faults import FaultSchedule
    loss_fn, grad_fn, d = _quadratic()
    n = 8
    fs = FaultSchedule(n_nodes=n, drops={5: (1, 4)}, rejoins={13: (1, 4)},
                       seed=2)
    out = simulate(algorithm="gossip_pga", grad_fn=grad_fn, loss_fn=loss_fn,
                   x0=jnp.zeros(d), n=n, steps=20, lr=0.05,
                   topology="directed_exp", H=4, backend=backend,
                   push_sum=True, fault_schedule=fs, eval_every=5)
    # step 19 is a global phase (H=4) with everyone rejoined
    np.testing.assert_array_equal(out["push_weight"],
                                  np.ones((n, 1), np.float32))
    np.testing.assert_allclose(out["mass"], float(n), atol=1e-3)


# ---------------------------------------------------------------------------
# Composition with wire compression (+ error feedback)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("codec", ["int8", "fp8"])
@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_compressed_push_sum_weight_stays_exact(codec, backend, rng_key):
    from repro.compress import init_ef_state, make_compressor
    n = 8
    comp = make_compressor(codec)
    x = jax.random.normal(rng_key, (n, 64))
    w = jax.random.uniform(jax.random.PRNGKey(9), (n, 1), minval=0.5,
                           maxval=1.5)
    ef = init_ef_state(x)
    W = topo.push_sum_matrix("directed_exp", n)
    xq, wq, ef2 = _round(x, w, W, n, backend, compressor=comp, ef_state=ef,
                         seed=3)
    # the de-bias denominator bypasses the codec entirely: exact dense W·w
    np.testing.assert_allclose(np.asarray(wq),
                               np.asarray(W, np.float32) @ np.asarray(w),
                               atol=1e-7)
    # params follow the compensated compressed round, close to exact
    xe, _ = _round(x, w, W, n, "reference")
    err = np.abs(np.asarray(xq) - np.asarray(xe)).max()
    assert 0 < err < 0.2, err
    # EF memory picked up the quantization residual
    assert any(float(jnp.abs(lf).max()) > 0 for lf in jax.tree.leaves(ef2))


def test_identity_codec_is_exact_passthrough(rng_key):
    from repro.compress import make_compressor
    n = 8
    comp = make_compressor("identity")
    x = jax.random.normal(rng_key, (n, 16))
    w = init_push_weight(n)
    W = topo.push_sum_matrix("directed_ring", n)
    xi, wi, ef = _round(x, w, W, n, "reference", compressor=comp)
    xe, we = _round(x, w, W, n, "reference")
    np.testing.assert_array_equal(np.asarray(xi), np.asarray(xe))
    np.testing.assert_array_equal(np.asarray(wi), np.asarray(we))
    assert ef is None


def test_compressed_sharded_push_sum_raises(rng_key):
    from repro.compress import make_compressor
    n = len(jax.devices())  # whatever this host has; the check fires first

    class FakeMesh:  # only consulted for the axis size via node_shard_count
        shape = {"nodes": 8}
        axis_names = ("nodes",)

    comp = make_compressor("int8")
    with pytest.raises(ValueError, match="no.*sharded|sharded path"):
        mixing.communicate_push_sum(
            jax.random.normal(rng_key, (8, 4)), init_push_weight(8),
            W=jnp.asarray(topo.push_sum_matrix("directed_ring", 8)),
            n_nodes=8, backend="pallas", mesh=FakeMesh(), node_axis="nodes",
            shard_mode="sharded", compressor=comp)


# ---------------------------------------------------------------------------
# End-to-end: simulate() with push_sum on directed topologies
# ---------------------------------------------------------------------------
def _quadratic(d=6, m=48):
    A = jax.random.normal(jax.random.PRNGKey(11), (m, d))
    b = jax.random.normal(jax.random.PRNGKey(12), (m,))

    def loss_fn(x):
        return 0.5 * jnp.mean((A @ x - b) ** 2)

    def grad_fn(xs, key, k):
        return jax.vmap(jax.grad(loss_fn))(xs)

    return loss_fn, grad_fn, d


@pytest.mark.parametrize("t", DIRECTED)
def test_simulate_push_sum_backend_parity(t):
    from repro.core.algorithms import simulate
    loss_fn, grad_fn, d = _quadratic()
    outs = {}
    for backend in ("reference", "pallas"):
        outs[backend] = simulate(
            algorithm="gossip_pga", grad_fn=grad_fn, loss_fn=loss_fn,
            x0=jnp.zeros(d), n=8, steps=30, lr=0.05, topology=t, H=4,
            backend=backend, push_sum=True, eval_every=5)
    np.testing.assert_allclose(outs["reference"]["loss"],
                               outs["pallas"]["loss"], rtol=1e-6)
    for backend, out in outs.items():
        np.testing.assert_allclose(out["mass"], 8.0, atol=1e-4,
                                   err_msg=backend)
        assert out["consensus"][-1] < 1e-6, backend


def test_simulate_push_sum_compressed_ef_converges():
    from repro.core.algorithms import simulate
    loss_fn, grad_fn, d = _quadratic()
    out = simulate(
        algorithm="gossip_pga", grad_fn=grad_fn, loss_fn=loss_fn,
        x0=jnp.zeros(d), n=8, steps=60, lr=0.05, topology="directed_exp",
        H=4, push_sum=True, compression="int8", error_feedback=True,
        eval_every=10)
    np.testing.assert_allclose(out["mass"], 8.0, atol=1e-3)
    assert out["loss"][-1] < out["loss"][0]
    exact = simulate(
        algorithm="gossip_pga", grad_fn=grad_fn, loss_fn=loss_fn,
        x0=jnp.zeros(d), n=8, steps=60, lr=0.05, topology="directed_exp",
        H=4, push_sum=True, eval_every=10)
    assert abs(out["loss"][-1] - exact["loss"][-1]) < 0.05


def test_directed_topology_requires_push_sum():
    from repro.configs.base import DistConfig
    with pytest.raises(ValueError, match="push_sum"):
        DistConfig(algorithm="gossip", topology="directed_exp").validate()
    DistConfig(algorithm="gossip", topology="directed_exp",
               push_sum=True).validate()
