"""Fault-injection scenario suite (DESIGN.md §2.5).

The checkable invariant throughout: every matrix a FaultSchedule emits is
column-stochastic, so the push-sum mass ``Σw = n`` survives every drop
pattern, every resample draw, every step — asserted here per-step, by a
deterministic seeded sweep that always runs and a hypothesis property test
over (topology, n, drop pattern) when hypothesis is installed.
"""
import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mixing, topology as topo
from repro.core.faults import FaultSchedule, parse_fault_events
from repro.train.state import debias, init_push_weight

DIRECTED = list(topo.DIRECTED_TOPOLOGIES)


def _quadratic(d=6, m=48):
    A = jax.random.normal(jax.random.PRNGKey(11), (m, d))
    b = jax.random.normal(jax.random.PRNGKey(12), (m,))

    def loss_fn(x):
        return 0.5 * jnp.mean((A @ x - b) ** 2)

    def grad_fn(xs, key, k):
        return jax.vmap(jax.grad(loss_fn))(xs)

    return loss_fn, grad_fn, d


# ---------------------------------------------------------------------------
# FaultSchedule semantics
# ---------------------------------------------------------------------------
def test_parse_fault_events():
    assert parse_fault_events("") == {}
    assert parse_fault_events("40:3,5;90:0") == {40: (3, 5), 90: (0,)}
    assert parse_fault_events("7:2;7:1") == {7: (1, 2)}     # merged, sorted


def test_active_mask_drop_rejoin_lifecycle():
    fs = FaultSchedule(n_nodes=8, drops={5: (2, 6)}, rejoins={12: (2,)})
    assert fs.active_mask(4).all()
    m = fs.active_mask(5)
    assert not m[2] and not m[6] and m.sum() == 6
    m = fs.active_mask(12)
    assert m[2] and not m[6]                  # 2 rejoined, 6 still down
    # rejoin wins over a same-step drop
    fs2 = FaultSchedule(n_nodes=4, drops={3: (1,)}, rejoins={3: (1,)})
    assert fs2.active_mask(3).all()


def test_fault_schedule_validates():
    with pytest.raises(ValueError, match="resample"):
        FaultSchedule(n_nodes=4, resample="bogus")
    with pytest.raises(ValueError, match="outside"):
        FaultSchedule(n_nodes=4, drops={0: (7,)})


def test_resample_is_deterministic_and_step_keyed():
    fs = FaultSchedule(n_nodes=16, resample="peer", seed=42)
    fs_again = FaultSchedule(n_nodes=16, resample="peer", seed=42)
    # pure function of (seed, step): two instances agree, any query order
    for step in (9, 3, 9, 0):
        assert fs.out_weights(step) == fs_again.out_weights(step)
    # and the wiring actually varies across steps
    mats = [fs.matrix("directed_exp", k) for k in range(8)]
    assert any(not np.array_equal(mats[0], M) for M in mats[1:])
    # different seed -> different trajectory
    other = FaultSchedule(n_nodes=16, resample="peer", seed=43)
    assert any(fs.out_weights(k) != other.out_weights(k) for k in range(8))


@pytest.mark.parametrize("mode", ["hop", "peer"])
def test_resampled_matrices_are_column_stochastic(mode):
    fs = FaultSchedule(n_nodes=8, drops={3: (5,)}, resample=mode, seed=1)
    for k in range(10):
        W = fs.matrix("directed_exp", k)
        assert topo.is_column_stochastic(W), (mode, k)


def test_advance_counters_and_sidecar_roundtrip():
    fs = FaultSchedule(n_nodes=8, drops={2: (1, 3)}, rejoins={5: (1,)})
    for k in range(6):
        fs.advance(k)
    assert fs.state_dict() == {"steps_seen": 6, "drops_applied": 2,
                               "rejoins_applied": 1}
    assert fs.events_before(6) == (2, 1)
    fresh = FaultSchedule(n_nodes=8, drops={2: (1, 3)}, rejoins={5: (1,)})
    fresh.load_state_dict(fs.state_dict())
    assert fresh.state_dict() == fs.state_dict()


# ---------------------------------------------------------------------------
# Mass conservation: every step of every scenario
# ---------------------------------------------------------------------------
def _run_scenario(t, n, fs, steps, backend="reference"):
    """Drive raw push-sum rounds under ``fs``; assert Σw = n every step."""
    x = jax.random.normal(jax.random.PRNGKey(0), (n, 5))
    w = init_push_weight(n)
    for k in range(steps):
        W = jnp.asarray(fs.matrix(t, k), jnp.float32)
        x, w = mixing.communicate_push_sum(x, w, W=W, n_nodes=n,
                                           backend=backend)
        mass = float(jnp.sum(w))
        assert abs(mass - n) < 1e-3 * n, (t, n, k, mass)
    return x, w


def test_mass_conserved_every_step_seeded_sweep():
    # deterministic sweep over (topology, n, drop pattern, resample mode):
    # runs always, independent of whether hypothesis is installed
    rng = np.random.default_rng(123)
    for t in DIRECTED:
        for n in (4, 8, 16):
            for mode in ("none", "hop", "peer"):
                drops, rejoins = {}, {}
                for step in rng.choice(12, size=3, replace=False):
                    ids = rng.choice(n, size=rng.integers(1, max(2, n // 4)
                                                          + 1),
                                     replace=False)
                    drops[int(step)] = tuple(int(i) for i in ids)
                    rejoins[int(step) + 4] = drops[int(step)]
                fs = FaultSchedule(n_nodes=n, drops=drops, rejoins=rejoins,
                                   resample=mode, seed=int(rng.integers(100)))
                _run_scenario(t, n, fs, steps=16)


try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                           # optional extra; sweep above
    _HAVE_HYPOTHESIS = False                  # covers the same domain


if _HAVE_HYPOTHESIS:
    @given(t=st.sampled_from(DIRECTED),
           n=st.sampled_from([4, 8, 16]),
           drop_bits=st.integers(0, 2 ** 16 - 1),
           drop_step=st.integers(0, 6),
           rejoin_after=st.integers(1, 6),
           mode=st.sampled_from(["none", "hop", "peer"]),
           seed=st.integers(0, 999))
    @settings(max_examples=30, deadline=None)
    def test_mass_conservation_property(t, n, drop_bits, drop_step,
                                        rejoin_after, mode, seed):
        """Σw = n at every step for arbitrary (topology, n, drop pattern)."""
        ids = tuple(i for i in range(n) if drop_bits & (1 << i))
        drops = {drop_step: ids} if ids else {}
        rejoins = {drop_step + rejoin_after: ids} if ids else {}
        fs = FaultSchedule(n_nodes=n, drops=drops, rejoins=rejoins,
                           resample=mode, seed=seed)
        w = jnp.ones((n, 1), jnp.float32)
        for k in range(drop_step + rejoin_after + 3):
            W = fs.matrix(t, k)
            assert topo.is_column_stochastic(W)
            w = jnp.asarray(W, jnp.float32) @ w
            assert abs(float(jnp.sum(w)) - n) < 1e-3 * n, k


# ---------------------------------------------------------------------------
# Convergence with faults: de-biased average stays intact
# ---------------------------------------------------------------------------
def test_dropout_midrun_converges_with_debiased_average_intact():
    from repro.core.algorithms import simulate
    loss_fn, grad_fn, d = _quadratic()
    fs = FaultSchedule(n_nodes=8, drops={10: (2, 5)}, rejoins={25: (2, 5)},
                       seed=0)
    out = simulate(algorithm="gossip_pga", grad_fn=grad_fn, loss_fn=loss_fn,
                   x0=jnp.zeros(d), n=8, steps=60, lr=0.05,
                   topology="directed_exp", H=4, push_sum=True,
                   fault_schedule=fs, eval_every=5)
    clean = simulate(algorithm="gossip_pga", grad_fn=grad_fn,
                     loss_fn=loss_fn, x0=jnp.zeros(d), n=8, steps=60,
                     lr=0.05, topology="directed_exp", H=4, push_sum=True,
                     eval_every=5)
    np.testing.assert_allclose(out["mass"], 8.0, atol=1e-2)
    # the de-biased trajectory survives the outage: same optimum, consensus
    # re-collapses after rejoin
    assert out["consensus"][-1] < 1e-6
    assert abs(out["loss"][-1] - clean["loss"][-1]) < 0.05
    assert fs.state_dict()["drops_applied"] == 2
    assert fs.state_dict()["rejoins_applied"] == 2


@pytest.mark.parametrize("mode", ["hop", "peer"])
def test_per_step_resampling_converges(mode):
    from repro.core.algorithms import simulate
    loss_fn, grad_fn, d = _quadratic()
    fs = FaultSchedule(n_nodes=8, resample=mode, seed=5)
    out = simulate(algorithm="gossip_pga", grad_fn=grad_fn, loss_fn=loss_fn,
                   x0=jnp.zeros(d), n=8, steps=48, lr=0.05,
                   topology="directed_exp", H=4, push_sum=True,
                   fault_schedule=fs, eval_every=8)
    np.testing.assert_allclose(out["mass"], 8.0, atol=1e-2)
    assert out["consensus"][-1] < 1e-5
    assert out["loss"][-1] < out["loss"][0]


# ---------------------------------------------------------------------------
# Acceptance scenario: 16 nodes, drop 2, rejoin, all three backends
# ---------------------------------------------------------------------------
def _acceptance(backend):
    from repro.core.algorithms import simulate
    loss_fn, grad_fn, d = _quadratic()
    fs = FaultSchedule(n_nodes=16, drops={12: (3, 11)},
                       rejoins={28: (3, 11)}, seed=0)
    return simulate(algorithm="gossip_pga", grad_fn=grad_fn,
                    loss_fn=loss_fn, x0=jnp.zeros(d), n=16, steps=64,
                    lr=0.05, topology="directed_exp", H=8, push_sum=True,
                    backend=backend, fault_schedule=fs, eval_every=8)


def test_acceptance_16node_drop2_rejoin_stacked_backends():
    """16-node directed-exp, 2 nodes dropped at t=12, rejoined at t=28:
    both stacked backends reach the same de-biased consensus fixed point."""
    ref = _acceptance("reference")
    pal = _acceptance("pallas")
    for out in (ref, pal):
        np.testing.assert_allclose(out["mass"], 16.0, atol=1e-2)
        assert out["consensus"][-1] < 1e-6
    np.testing.assert_allclose(ref["loss"], pal["loss"], rtol=1e-5)
    np.testing.assert_allclose(ref["push_weight"], pal["push_weight"],
                               atol=1e-6)


_SHARDED_SCENARIO = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import mixing, topology as topo
    from repro.core.faults import FaultSchedule

    n, d = 16, 6
    mesh = jax.make_mesh((8,), ("nodes",))
    A = jax.random.normal(jax.random.PRNGKey(11), (48, d))
    b = jax.random.normal(jax.random.PRNGKey(12), (48,))
    loss = lambda x: 0.5 * jnp.mean((A @ x - b) ** 2)
    gradf = jax.vmap(jax.grad(loss))
    fs = FaultSchedule(n_nodes=n, drops={12: (3, 11)},
                       rejoins={28: (3, 11)}, seed=0)
    offs = mixing.push_sum_shard_offsets(n, 8, fs.hop_superset("directed_exp"))

    def run(backend, mesh=None):
        x = jnp.zeros((n, d)); w = jnp.ones((n, 1), jnp.float32)
        for k in range(64):
            active = jnp.asarray(fs.active_mask(k), jnp.float32)
            if (k + 1) % 8 == 0:
                W = topo.global_push_matrix(n, fs.active_mask(k))
                off = tuple(range(8))
            else:
                W = fs.matrix("directed_exp", k)
                off = offs
            x = x - 0.05 * gradf(x) * active[:, None]
            kw = dict(mesh=mesh, node_axis="nodes",
                      shard_mode="sharded", offsets=off) if mesh else {}
            x, w = mixing.communicate_push_sum(
                x, w, W=jnp.asarray(W, jnp.float32), n_nodes=n,
                backend=backend, **kw)
            assert abs(float(jnp.sum(w)) - n) < 1e-2, (backend, k)
        return np.asarray(x / w), np.asarray(w)

    xr, wr = run("reference")
    xs, ws = run("pallas", mesh=mesh)
    np.testing.assert_allclose(xs, xr, atol=1e-5)
    np.testing.assert_allclose(ws, wr, atol=1e-5)
    spread = np.abs(xr - xr.mean(0)).max()
    assert spread < 1e-5, spread        # de-biased consensus fixed point
    print("FAULT_SHARDED_OK")
""")


def test_acceptance_sharded_backend_matches_reference():
    """The same 16-node drop-2-rejoin scenario on the shard_map/ppermute
    backend (8 forced host devices, 2 nodes per shard) lands on the same
    de-biased fixed point as the dense reference."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", _SHARDED_SCENARIO],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert "FAULT_SHARDED_OK" in out.stdout, out.stderr[-2000:]


# ---------------------------------------------------------------------------
# Trainer: drop → checkpoint → rejoin resumes bit-stably
# ---------------------------------------------------------------------------
def _trainer_cfg(ckpt_dir):
    from repro.configs import (DataConfig, DistConfig, OptimizerConfig,
                               TrainConfig, get_model_config)
    return TrainConfig(
        model=get_model_config("qwen3-0.6b", reduced=True),
        dist=DistConfig(algorithm="gossip_pga", topology="directed_exp",
                        H=2, push_sum=True),
        optimizer=OptimizerConfig(name="sgd", lr=0.05, schedule="constant",
                                  warmup_steps=0),
        data=DataConfig(non_iid=True), global_batch=8, seq_len=16,
        steps=6, log_every=0, ckpt_every=3, ckpt_dir=ckpt_dir)


def _faults():
    # drop node 1 at step 2 (before the checkpoint at 3), rejoin at step 4
    # (after it): the restore lands mid-outage
    return FaultSchedule(n_nodes=4, drops={2: (1,)}, rejoins={4: (1,)},
                         seed=0)


def test_trainer_drop_checkpoint_rejoin_resumes_bitwise():
    from repro.checkpoint import restore_checkpoint
    from repro.train import Trainer
    with tempfile.TemporaryDirectory() as d:
        tcfg = _trainer_cfg(d)
        tr = Trainer(tcfg, n_nodes=4, fault_schedule=_faults())
        full = tr.run(tr.init_state(jax.random.PRNGKey(0)), steps=6)
        # fresh process: restore the mid-outage checkpoint and continue
        tr2 = Trainer(tcfg, n_nodes=4, fault_schedule=_faults())
        state = restore_checkpoint(d, tr2.init_state(jax.random.PRNGKey(0)),
                                   step=3)
        assert int(state.step) == 3
        # push weight restored mid-outage: skewed, not ones
        assert not np.allclose(np.asarray(state.push_weight), 1.0)
        resumed = tr2.run(state, steps=3)
        for a, b in zip(jax.tree.leaves(resumed.params),
                        jax.tree.leaves(full.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(resumed.push_weight),
                                      np.asarray(full.push_weight))
        # counters reconciled through the sidecar
        assert tr2.fault_schedule.state_dict() == \
            tr.fault_schedule.state_dict()
        assert float(jnp.sum(resumed.push_weight)) == pytest.approx(4.0,
                                                                    abs=1e-4)


def test_trainer_requires_push_sum_for_faults():
    from repro.configs import (DataConfig, DistConfig, OptimizerConfig,
                               TrainConfig, get_model_config)
    from repro.train import Trainer
    tcfg = TrainConfig(
        model=get_model_config("qwen3-0.6b", reduced=True),
        dist=DistConfig(algorithm="gossip_pga", topology="ring", H=2),
        optimizer=OptimizerConfig(name="sgd", lr=0.05),
        data=DataConfig(), global_batch=8, seq_len=16, log_every=0)
    with pytest.raises(ValueError, match="push_sum"):
        Trainer(tcfg, n_nodes=4, fault_schedule=_faults())
    with pytest.raises(ValueError, match="4 nodes"):
        Trainer(TrainConfig(
            model=tcfg.model,
            dist=DistConfig(algorithm="gossip_pga", topology="directed_exp",
                            H=2, push_sum=True),
            optimizer=tcfg.optimizer, data=tcfg.data, global_batch=8,
            seq_len=16, log_every=0), n_nodes=8, fault_schedule=_faults())
