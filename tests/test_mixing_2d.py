"""2-D ``(node, model)`` sharded-mixing parity suite (DESIGN.md §2.1).

The sharded communication stack composes with model-parallel column
slicing: on a mesh carrying the ``model`` axis the packed state's columns
are sliced over it (``mixing_pallas.flatten_nodes_sharded``), halos move
only the local column slice, the global psum reduces over the node axis
only, and the compressed collective's reduce-scatter segments split
``D/k_model``.  This suite proves, on 8 forced host devices (subprocess,
launch/dryrun.py convention):

* every phase × {uncompressed, int8 gossip, int8 collective} matches the
  stacked reference on ``(data=2, model=4)`` and
  ``(pod=2, data=2, model=2)`` meshes — bitwise for identity compression,
  within matmul tolerance for lossy;
* rounding decisions are **bit-stable under resharding** (1-D vs 2-D
  meshes differ only by fp reduction order — column hashes and
  power-of-two scales key on absolute columns);
* per-device halo wire bytes drop by the model-axis size (the acceptance
  ratio), measured == analytic (``round_wire_bytes(model_shards=)``);
* a model-resharded checkpoint resumes to the same iterates.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compress as C
from repro.core import mixing

# ---------------------------------------------------------------------------
# Local (single-device) pieces: axis resolution + the wire cost model
# ---------------------------------------------------------------------------
def test_model_axis_names_resolution():
    devs = np.array(jax.devices()[:1])
    mesh = jax.sharding.Mesh(devs.reshape(1, 1), ("data", "model"))
    names = mixing.node_axis_names(mesh, "data")
    assert mixing.model_axis_names(mesh, "model", node_names=names) == \
        ("model",)
    # absent axis / axis already spent on the node axis → replicated
    assert mixing.model_axis_names(mesh, "tp", node_names=names) == ()
    assert mixing.model_axis_names(mesh, "data", node_names=names) == ()
    assert mixing.model_shard_count(None) == 1
    mesh1 = jax.sharding.Mesh(devs.reshape(1), ("data",))
    assert mixing.model_shard_count(mesh1) == 1


def test_distconfig_validates_model_axis():
    from repro.configs import DistConfig
    DistConfig().validate()
    with pytest.raises(ValueError, match="model_axis"):
        DistConfig(model_axis="").validate()
    with pytest.raises(ValueError, match="model_axis"):
        DistConfig(model_axis="data").validate()
    with pytest.raises(ValueError, match="model_axis"):
        DistConfig(model_axis="pod").validate()


def test_collective_validation_names_caller():
    """The sharded collective validates with its caller's name (previously
    it raised prefixed ``communicate_sharded:`` no matter who called, and
    skipped the names-empty check its caller performs — a direct call on a
    model-only mesh failed opaquely inside shard_map tracing)."""
    devs = np.array(jax.devices()[:1])
    mesh = jax.sharding.Mesh(devs.reshape(1, 1), ("data", "model"))
    comp = C.make_compressor("int8")
    x = jnp.ones((4, 8), jnp.float32)
    # direct call: its own name
    with pytest.raises(ValueError,
                       match=r"mixing\._communicate_sharded_collective.*"
                             r"node_axis"):
        mixing._communicate_sharded_collective(
            x, compressor=comp, ef_state=None, seed=0, phase="global",
            n_nodes=4, n_pods=1, mesh=mesh, node_axis="pod")
    # dispatch through communicate_sharded: the public entry point's name
    with pytest.raises(ValueError, match=r"communicate_sharded.*no axis"):
        mixing.communicate_sharded(
            x, mixing.CommSpec(topology="ring", n_nodes=4, mesh=mesh,
                               node_axis="pod", global_compressor=comp),
            phase="global")


def test_flatten_nodes_sharded_roundtrip():
    from repro.kernels import mixing_pallas as mp
    key = jax.random.PRNGKey(0)
    tree = {"a": jax.random.normal(key, (4, 5, 3)),
            "b": jax.random.normal(key, (4, 7)).astype(jnp.bfloat16),
            "c": jax.random.normal(key, (4,))}
    for km in (1, 2, 4, 8):
        flat, unflatten = mp.flatten_nodes_sharded(tree, km)
        assert flat.shape[1] % max(km, 1) == 0
        out = unflatten(flat)
        assert jax.tree.structure(out) == jax.tree.structure(tree)
        for g, w in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
            assert g.dtype == w.dtype and g.shape == w.shape
            np.testing.assert_array_equal(
                np.asarray(g, np.float32), np.asarray(w, np.float32))
    # km == 1 degenerates to flatten_nodes exactly
    f0, _ = mp.flatten_nodes(tree)
    f1, _ = mp.flatten_nodes_sharded(tree, 1)
    np.testing.assert_array_equal(np.asarray(f0), np.asarray(f1))


def test_wire_column_spec_negotiation():
    from jax.sharding import PartitionSpec as P
    from repro.models.sharding import wire_column_spec
    names, mn = ("data",), ("model",)
    # quantizer codes: node rows + model-divisible columns → 2-D slice
    assert wire_column_spec((8, 64), 8, names, mn, 4) == P(names, mn)
    # per-row scales: 1 column cannot slice → node axis only
    assert wire_column_spec((8, 1), 8, names, mn, 4) == P(names)
    # sparsifier payloads opt out via empty model names
    assert wire_column_spec((8, 64), 8, names, (), 4) == P(names)
    # shared leading-axis-1 metadata rides replicated
    assert wire_column_spec((1, 12), 8, names, mn, 4) == P()
    # 1-D mesh (k_model == 1): yesterday's specs verbatim
    assert wire_column_spec((8, 64), 8, names, (), 1) == P(names)


def test_round_wire_bytes_model_shards_divisor():
    """Per-device bytes divide by the model-axis size: exactly 4× for the
    uncompressed halo/psum and the packed collective (divisible sizes),
    code-bytes-only for the quantizers (scale words stay replicated),
    untouched for sparsifiers (model-replicated payloads)."""
    sizes = [2048, 256]
    d = sum(sizes)
    for phase in ("gossip", "global", "pod_avg"):
        full = C.round_wire_bytes(phase, "ring", 8, d, n_pods=2,
                                  leaf_sizes=sizes)
        dev = C.round_wire_bytes(phase, "ring", 8, d, n_pods=2,
                                 leaf_sizes=sizes, model_shards=4)
        assert full == 4 * dev, (phase, full, dev)
    # int8 gossip: codes slice, per-row scale words stay whole
    full = C.round_wire_bytes("gossip", "ring", 8, d, compression="int8",
                              leaf_sizes=sizes)
    dev = C.round_wire_bytes("gossip", "ring", 8, d, compression="int8",
                             leaf_sizes=sizes, model_shards=4)
    shifts = full // sum(s + 4 for s in sizes)
    assert dev == shifts * sum(s // 4 + 4 for s in sizes)
    assert full / dev > 3.9
    # collective: packed operand divides (QBLOCK-divisible size)
    from repro.compress.collective import QBLOCK
    d2 = 8 * QBLOCK
    full = C.round_wire_bytes("global", "ring", 8, d2,
                              global_compression="int8")
    dev = C.round_wire_bytes("global", "ring", 8, d2,
                             global_compression="int8", model_shards=4)
    assert full == 4 * dev
    # ragged block count: per-device bytes are whole QBLOCK blocks per
    # model slice (the runtime pads every slice to a block boundary) —
    # ceil(5 blocks / 4 slices) = 2 blocks/device, not 5/4 of one
    dev = C.round_wire_bytes("global", "ring", 8, 5 * QBLOCK,
                             global_compression="int8", model_shards=4)
    assert dev == 2 * (QBLOCK + 1), dev
    # sparsifier payloads ride model-replicated: no division
    full = C.round_wire_bytes("gossip", "ring", 8, d, compression="topk",
                              k=16, leaf_sizes=sizes)
    dev = C.round_wire_bytes("gossip", "ring", 8, d, compression="topk",
                             k=16, leaf_sizes=sizes, model_shards=4)
    assert full == dev


def test_scale_exponent_packing_exact_roundtrip():
    """pow2_block_scale guarantees pure-exponent fp32 words, so the uint8
    exponent wire form round-trips bitwise — the collective's dequantized
    values cannot depend on the packing."""
    from repro.compress import collective as ccol
    y = jax.random.normal(jax.random.PRNGKey(3), (4, 8, 64)) * \
        jnp.asarray([1e-20, 1e-3, 1.0, 1e12]).reshape(4, 1, 1)
    for shift in (7, 8):
        s = ccol.pow2_block_scale(y, shift)
        back = ccol.exponent_scales(ccol.scale_exponents(s))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(back))
    # all-zero blocks map to scale 1.0 → exponent 127 → exact too
    s = ccol.pow2_block_scale(jnp.zeros((2, 1, 8)), 7)
    np.testing.assert_array_equal(
        np.asarray(ccol.exponent_scales(ccol.scale_exponents(s))),
        np.ones((2, 1, 1), np.float32))


# ---------------------------------------------------------------------------
# 2-D mesh parity (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------
_PARITY_2D_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import mixing
    from repro import compress as C

    MESHES = [("d2m4", jax.make_mesh((2, 4), ("data", "model")), 4),
              ("p2d2m2", jax.make_mesh((2, 2, 2), ("pod", "data", "model")),
               2)]
    mesh1d = jax.make_mesh((8,), ("data",))
    n = 8
    SHAPES = [(5, 3), (7,), ()]
    ks = jax.random.split(jax.random.PRNGKey(0), len(SHAPES))
    t = {f"leaf{i}": jax.random.normal(k, (n,) + s)
         for i, (k, s) in enumerate(zip(ks, SHAPES))}

    def close(got, want, atol):
        assert jax.tree.structure(got) == jax.tree.structure(want)
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_allclose(np.asarray(g, np.float32),
                                       np.asarray(w, np.float32), atol=atol)

    def bitwise(got, want):
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            assert g.dtype == w.dtype
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    PHASES = [("gossip", "ring", 1), ("gossip", "one_peer_exp", 1),
              ("gossip", "grid", 1), ("global", "ring", 1),
              ("pod_avg", "ring", 2), ("pod_avg", "ring", 4)]
    for tag, mesh, km in MESHES:
        for phase, topol, pods in PHASES:
            # uncompressed (fp32 + bf16 wire)
            for cd in (None, jnp.bfloat16):
                kw = dict(phase=phase, topology=topol, n_nodes=n, step=3,
                          comm_dtype=cd, n_pods=pods)
                want = mixing.communicate(t, **kw)
                got = mixing.communicate(t, backend="pallas", mesh=mesh,
                                         **kw)
                close(got, want, 1e-5 if cd is None else 3e-2)
            # int8 gossip compressor (all phases route through it)
            kw = dict(phase=phase, topology=topol, n_nodes=n, step=3,
                      n_pods=pods, compressor=C.make_compressor("int8"),
                      seed=11)
            want, _ = mixing.communicate(t, **kw)
            got, _ = mixing.communicate(t, backend="pallas", mesh=mesh,
                                        **kw)
            close(got, want, 2e-5)
            # int8 collective on the averaging phases
            if phase in ("global", "pod_avg"):
                kw = dict(phase=phase, topology=topol, n_nodes=n,
                          n_pods=pods,
                          global_compressor=C.make_compressor("int8"),
                          seed=11)
                want, _ = mixing.communicate(t, **kw)
                got, _ = mixing.communicate(t, backend="pallas", mesh=mesh,
                                            **kw)
                close(got, want, 2e-5)
            print(f"P2D_OK {tag}/{phase}/{topol}/p{pods}")

        # identity compression: bitwise vs the uncompressed 2-D path
        want = mixing.communicate(t, phase="gossip", topology="ring",
                                  n_nodes=n, backend="pallas", mesh=mesh)
        got, ef = mixing.communicate(t, phase="gossip", topology="ring",
                                     n_nodes=n, backend="pallas", mesh=mesh,
                                     compressor=C.make_compressor(
                                         "identity"))
        assert ef is None
        bitwise(got, want)
        print(f"P2D_IDENTITY_OK {tag}")

        # identity GLOBAL codec + lossy gossip compressor: the averaging
        # phase runs the exact psum path bit-identically (regression for
        # the recursion that re-attached the lossy gossip compressor)
        for phase, pods in (("global", 1), ("pod_avg", 2)):
            want = mixing.communicate(t, phase=phase, topology="ring",
                                      n_nodes=n, n_pods=pods,
                                      backend="pallas", mesh=mesh)
            got, ef = mixing.communicate(
                t, phase=phase, topology="ring", n_nodes=n, n_pods=pods,
                backend="pallas", mesh=mesh,
                compressor=C.make_compressor("int8"),
                global_compressor=C.make_compressor("identity"), seed=3)
            assert ef is None
            bitwise(got, want)
        print(f"P2D_IDENT_GLOBAL_OK {tag}")

        # EF threading (gossip halo + collective)
        ef0 = C.init_ef_state(t)
        for kw in (dict(phase="gossip", topology="exp",
                        compressor=C.make_compressor("int8")),
                   dict(phase="global", topology="ring",
                        global_compressor=C.make_compressor("int8"))):
            kw.update(n_nodes=n, ef_state=ef0, seed=2)
            wm, we = mixing.communicate(t, **kw)
            gm, ge = mixing.communicate(t, backend="pallas", mesh=mesh,
                                        **kw)
            close(gm, wm, 2e-5); close(ge, we, 2e-5)
        print(f"P2D_EF_OK {tag}")

        # constant state: fixed point (bitwise through the collective)
        ct = jax.tree.map(lambda p: jnp.full_like(p, 1.5), t)
        got, _ = mixing.communicate(ct, phase="gossip", topology="ring",
                                    n_nodes=n, backend="pallas", mesh=mesh,
                                    compressor=C.make_compressor("int8"),
                                    seed=5)
        close(got, ct, 1e-6)
        got, _ = mixing.communicate(ct, phase="global", topology="ring",
                                    n_nodes=n, backend="pallas", mesh=mesh,
                                    global_compressor=C.make_compressor(
                                        "int8"), seed=5)
        bitwise(got, ct)
        print(f"P2D_CONSTANT_OK {tag}")

        # bit-stable resharding: 1-D vs 2-D differ only by fp order
        for kw in (dict(phase="gossip", topology="ring",
                        compressor=C.make_compressor("int8")),
                   dict(phase="global", topology="ring",
                        global_compressor=C.make_compressor("int8"))):
            kw.update(n_nodes=n, seed=7)
            a, _ = mixing.communicate(t, backend="pallas", mesh=mesh1d,
                                      **kw)
            b, _ = mixing.communicate(t, backend="pallas", mesh=mesh, **kw)
            close(b, a, 2e-6)
        print(f"P2D_RESHARD_OK {tag}")

    # sparsifiers fall back to the model-replicated path on 2-D meshes
    # (leaf-global index sets cannot column-slice); fp8 rides the sliced
    # quantizer path like int8
    mesh = MESHES[0][1]
    for name in ("topk", "randk"):
        comp = C.make_compressor(name, k=3)
        kw = dict(phase="gossip", topology="ring", n_nodes=n,
                  compressor=comp, seed=6)
        want, _ = mixing.communicate(t, **kw)
        got, _ = mixing.communicate(t, backend="pallas", mesh=mesh, **kw)
        close(got, want, 2e-5)
    for kw in (dict(phase="gossip", topology="one_peer_exp",
                    compressor=C.make_compressor("fp8")),
               dict(phase="global", topology="ring",
                    global_compressor=C.make_compressor("fp8"))):
        kw.update(n_nodes=n, seed=6)
        want, _ = mixing.communicate(t, **kw)
        got, _ = mixing.communicate(t, backend="pallas", mesh=mesh, **kw)
        close(got, want, 2e-5)
    print("P2D_SPARSIFIER_FP8_OK")

    # fused residual + half-step on the (data=2, model=4) mesh
    g = {k2: jax.random.normal(jax.random.PRNGKey(9), v.shape)
         for k2, v in t.items()}
    mixed, xbar, resid = mixing.communicate_sharded(
        t, phase="gossip", topology="ring", n_nodes=n, mesh=mesh,
        with_residual=True)
    want = mixing.communicate(t, phase="gossip", topology="ring", n_nodes=n)
    close(mixed, want, 1e-5)
    close(xbar, jax.tree.map(lambda p: jnp.mean(p, 0), want), 1e-5)
    want_r = sum(float(jnp.sum((p - jnp.mean(p, 0, keepdims=True)) ** 2))
                 for p in jax.tree.leaves(want))
    np.testing.assert_allclose(float(resid), want_r, rtol=1e-4, atol=1e-6)
    got = mixing.communicate_sharded(t, phase="gossip", topology="ring",
                                     n_nodes=n, mesh=mesh, grads=g,
                                     gamma=0.37)
    close(got, mixing.communicate(
        jax.tree.map(lambda p, q: p - 0.37 * q, t, g),
        phase="gossip", topology="ring", n_nodes=n), 1e-5)
    print("P2D_RESID_OK")

    # ---- acceptance: per-device halo wire bytes are 4x lower on the
    # (data=2, model=4) mesh, measured == analytic ----
    km, k = 4, 2
    sizes = [2048, 256]
    big = {"w": jax.random.normal(jax.random.PRNGKey(1), (n, 2048)),
           "b": jax.random.normal(jax.random.PRNGKey(2), (n, 256))}
    d = sum(sizes)
    from repro.core import topology as topo
    shifts = sum(1 for s in topo.shift_weights("ring", n) if s != 0)
    for phase, pods in (("gossip", 1), ("global", 1), ("pod_avg", 2)):
        full = C.round_wire_bytes(phase, "ring", n, d, n_pods=pods,
                                  leaf_sizes=sizes)
        dev = C.round_wire_bytes(phase, "ring", n, d, n_pods=pods,
                                 leaf_sizes=sizes, model_shards=km)
        assert full == 4 * dev, (phase, full, dev)
        # measured: the per-device column slice the 2-D runtime moves
        from repro.kernels.mixing_pallas import flatten_nodes_sharded
        flat, _ = flatten_nodes_sharded(big, km)
        local_cols = flat.shape[1] // km
        measured = local_cols * 4 * (shifts if phase == "gossip" else 1)
        assert measured == dev, (phase, measured, dev)
    print("WIRE_UNCOMP_OK")

    # int8 gossip: measured per-device wire = column-sliced code arrays +
    # replicated per-row scales, exactly the analytic model
    comp = C.make_compressor("int8")
    x2 = [v.reshape(n, -1).astype(jnp.float32) for v in
          (big["b"], big["w"])]          # jax.tree order: b before w
    wires, _ = C.compress_tree(comp, x2, None, jnp.uint32(0))
    measured = 0
    for w in wires:
        for a in (*w.payload, *w.aux):
            per_node = a.nbytes // n
            cols = a.shape[-1] if a.ndim >= 2 else 1
            measured += per_node // km if cols % km == 0 and cols >= km \\
                else per_node
    measured *= shifts
    dev = C.round_wire_bytes("gossip", "ring", n, d, compression="int8",
                             leaf_sizes=sizes, model_shards=km)
    full = C.round_wire_bytes("gossip", "ring", n, d, compression="int8",
                              leaf_sizes=sizes)
    assert measured == dev, (measured, dev)
    assert full / dev > 3.9
    print("WIRE_INT8_OK")

    # collective: stage-1 payload per device (codes + uint8 exponents)
    from repro.compress import collective as ccol
    d2 = km * k * ccol.QBLOCK            # divisible: no padding slack
    big2 = jnp.asarray(np.random.default_rng(0).normal(size=(n, d2)),
                       jnp.float32)
    codes, scales, _ = ccol.quantize_blocks(big2, "int8", jnp.uint32(1))
    exps = ccol.scale_exponents(scales)
    measured = (codes.nbytes + exps.nbytes) // n // km
    dev = C.round_wire_bytes("global", "ring", n, d2,
                             global_compression="int8", model_shards=km)
    full = C.round_wire_bytes("global", "ring", n, d2,
                              global_compression="int8")
    assert measured == dev, (measured, dev)
    assert full == km * dev
    fp32_dev = C.round_wire_bytes("global", "ring", n, d2,
                                  model_shards=km)
    assert fp32_dev / dev > 3.9
    print("WIRE_COLLECTIVE_OK")
""")


def _run_forced_device_script(script: str, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, (out.stdout[-2000:] + out.stderr[-4000:])
    return out.stdout


def test_sharded_2d_parity_8dev():
    """All phases × {uncompressed, int8 gossip, int8 collective} on
    (data=2, model=4) and (pod=2, data=2, model=2) meshes match the
    stacked reference; identity bitwise; identity-global supersedes a
    lossy gossip compressor bitwise; EF threads; constants stay fixed;
    rounding is bit-stable under resharding; per-device halo wire bytes
    are 4× lower (measured == analytic)."""
    stdout = _run_forced_device_script(_PARITY_2D_SCRIPT)
    assert stdout.count("P2D_OK") == 12, stdout
    for tag in ("d2m4", "p2d2m2"):
        for marker in ("P2D_IDENTITY_OK", "P2D_IDENT_GLOBAL_OK",
                       "P2D_EF_OK", "P2D_CONSTANT_OK", "P2D_RESHARD_OK"):
            assert f"{marker} {tag}" in stdout, stdout
    for marker in ("P2D_SPARSIFIER_FP8_OK", "P2D_RESID_OK",
                   "WIRE_UNCOMP_OK", "WIRE_INT8_OK", "WIRE_COLLECTIVE_OK"):
        assert marker in stdout, stdout


# ---------------------------------------------------------------------------
# Model-resharded checkpoint resume (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------
_RESHARD_RESUME_SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.checkpoint import restore_checkpoint
    from repro.configs import (DataConfig, DistConfig, OptimizerConfig,
                               TrainConfig, get_model_config)
    from repro.train.trainer import Trainer

    cfg = get_model_config("qwen3-0.6b", reduced=True)

    def tcfg(ckpt_dir):
        # the compressed collective's power-of-two scales + absolute
        # column hashes are the bit-stable-under-resharding machinery
        # (the gossip int8 compressor's absmax/127 scales are only
        # fusion-stable within one compiled program — DESIGN.md §2.3)
        return TrainConfig(
            model=cfg,
            dist=DistConfig(algorithm="gossip_pga", topology="ring", H=2,
                            comm_backend="pallas", comm_shard_mode="sharded",
                            comm_global_compression="int8",
                            comm_error_feedback=True),
            optimizer=OptimizerConfig(name="sgd", lr=0.05,
                                      schedule="constant", warmup_steps=0),
            data=DataConfig(non_iid=True), global_batch=8, seq_len=16,
            steps=4, log_every=0, ckpt_every=2, ckpt_dir=ckpt_dir)

    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    # model-only reshard: same node sharding (k=2), model axis 4 → 2.
    # Every per-column op (mix matmuls, psums, quantizer codecs) is
    # column-independent and keyed on absolute leaf columns, so the
    # trajectory must coincide to fp noise — resharding the model axis
    # flips no stochastic-rounding decision.
    mesh_b = jax.sharding.Mesh(
        np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
    with tempfile.TemporaryDirectory() as d:
        # uninterrupted 4 steps on (data=2, model=4)
        tr = Trainer(tcfg(d), n_nodes=4, mesh=mesh_a)
        full = tr.run(tr.init_state(jax.random.PRNGKey(0)), steps=4)
        # resume the step-2 checkpoint on the model-resharded mesh
        tr2 = Trainer(tcfg(d), n_nodes=4, mesh=mesh_b)
        state = restore_checkpoint(d, tr2.init_state(jax.random.PRNGKey(0)),
                                   step=2)
        assert int(state.step) == 2
        resumed = tr2.run(state, steps=2)
        # same iterates, quantified honestly: resharding compiles a new
        # program, and XLA's per-program fusion introduces ulp-level fp
        # noise that can flip an isolated stochastic-rounding decision —
        # bounded by one quantization step per compressed round and
        # absorbed by EF.  So: every element within a couple of steps
        # (5e-3 at this scale), the overwhelming majority at ulp
        # level.  (Single-round model resharding with a
        # bitwise-identical input is tolerance-tight — the parity
        # subprocess pins it at 2e-6.)
        for tree_a, tree_b in ((resumed.params, full.params),
                               (resumed.ef_state, full.ef_state)):
            total = flipped = 0
            for a, b in zip(jax.tree.leaves(tree_a), jax.tree.leaves(tree_b)):
                diff = np.abs(np.asarray(a, np.float32)
                              - np.asarray(b, np.float32))
                assert diff.max() < 5e-3, diff.max()
                total += diff.size
                flipped += int((diff > 1e-5).sum())
            assert flipped / total < 0.05, (flipped, total)
        assert int(resumed.step) == int(full.step) == 4
    print("RESHARD_RESUME_OK")
""")


def test_model_resharded_checkpoint_resume_8dev():
    """A checkpoint written on a (data=2, model=4) mesh resumes on a
    model-resharded (data=2, model=2) mesh — same node sharding — to the
    same iterates: compression randomness and scales key on absolute leaf
    columns, so resharding the model axis flips no rounding decision
    beyond cross-compilation fp noise (bounded in-script)."""
    stdout = _run_forced_device_script(_RESHARD_RESUME_SCRIPT,
                                       timeout=1200)
    assert "RESHARD_RESUME_OK" in stdout, stdout
