"""Algorithm-layer parity matrix + gt_pga acceptance (ISSUE 10).

The composable algorithm layer (``repro.core.algo``) collapsed the five
step-variant forks in ``simulate`` and ``train/step.py`` onto one
pipeline.  The contract is that every pre-existing algorithm trajectory
comes out **bitwise unchanged** — pinned below as float-hex goldens
captured on the pre-refactor tree (commit 7e05cee) with exactly the
harness mirrored by ``_sim_hexes`` / ``_trainer_digest``.

Also here: gt_pga coverage the goldens cannot pin (it is new) —
checkpoint save -> restore -> continue bitwise parity, tracker-mixing
backend parity, composition smoke across comm modes, the non-IID
crossover in miniature — plus unit tests for the registry/hooks and the
Dirichlet non-IID sharder that feeds the crossover benchmark gate.
"""
import hashlib
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import (DataConfig, DistConfig, OptimizerConfig,
                           TrainConfig, get_model_config)
from repro.core import algo, simulate
from repro.data import dirichlet_noniid_problem, make_logistic_problem
from repro.train import Trainer


def _parse_goldens(blob):
    """Blank-line-separated records: key line, then whitespace-joined
    values (wrapped to the line limit)."""
    out = {}
    for rec in blob.strip().split("\n\n"):
        lines = rec.strip().split("\n")
        out[lines[0].strip()] = " ".join(lines[1:]).split()
    return out


# ---------------------------------------------------------------------------
# Pinned goldens: 5 losses then 5 consensus values (float.hex, "c:" prefix)
# per ``algorithm|backend|mode`` simulate case; sha256 over the params
# pytree after 5 Trainer steps per trainer case.
# ---------------------------------------------------------------------------
_SIM_GOLDENS = _parse_goldens("""
gossip_aga|pallas|overlap
0x1.4837fc0000000p-1 0x1.5986220000000p-1 0x1.501bd80000000p-1
0x1.4d88700000000p-1 0x1.56923c0000000p-1 c:0x1.6504dc0000000p-3
c:0x1.07d7140000000p-4 c:0x1.5875e20000000p-3 c:0x0.0p+0
c:0x1.17c99a0000000p-3

gossip_aga|pallas|push_sum
0x1.4837fc0000000p-1 0x1.5706700000000p-1 0x1.51616c0000000p-1
0x1.4576da0000000p-1 0x1.5466540000000p-1 c:0x1.3670340000000p-4
c:0x1.331c840000000p-5 c:0x1.b0bea80000000p-4 c:0x1.3800000000000p-53
c:0x1.ddcab00000000p-5

gossip_aga|pallas|sync
0x1.4837fc0000000p-1 0x1.50ffb00000000p-1 0x1.50d07c0000000p-1
0x1.45ca940000000p-1 0x1.511fc00000000p-1 c:0x1.3d59a80000000p-6
c:0x1.b12c960000000p-8 c:0x1.bc04c80000000p-6 c:0x0.0p+0
c:0x1.e436760000000p-7

gossip_aga|reference|overlap
0x1.4837fc0000000p-1 0x1.5986220000000p-1 0x1.501bd80000000p-1
0x1.4d88700000000p-1 0x1.56923c0000000p-1 c:0x1.6504dc0000000p-3
c:0x1.07d7140000000p-4 c:0x1.5875e20000000p-3 c:0x0.0p+0
c:0x1.17c99c0000000p-3

gossip_aga|reference|push_sum
0x1.4837fc0000000p-1 0x1.5706700000000p-1 0x1.51616c0000000p-1
0x1.4576da0000000p-1 0x1.5466540000000p-1 c:0x1.3670340000000p-4
c:0x1.331c840000000p-5 c:0x1.b0bea80000000p-4 c:0x1.3800000000000p-53
c:0x1.ddcab00000000p-5

gossip_aga|reference|sync
0x1.4837fc0000000p-1 0x1.50ffb00000000p-1 0x1.50d07c0000000p-1
0x1.45ca940000000p-1 0x1.511fc00000000p-1 c:0x1.3d59a80000000p-6
c:0x1.b12c9c0000000p-8 c:0x1.bc04c60000000p-6 c:0x0.0p+0
c:0x1.e436760000000p-7

gossip_pga|pallas|int8_ef
0x1.4837fc0000000p-1 0x1.5109fa0000000p-1 0x1.5318640000000p-1
0x1.4761000000000p-1 0x1.5087580000000p-1 c:0x1.3eebdc0000000p-6
c:0x1.b8c4040000000p-18 c:0x1.bdd4ac0000000p-6 c:0x1.a9dede0000000p-18
c:0x1.f223dc0000000p-7

gossip_pga|pallas|overlap
0x1.4837fc0000000p-1 0x1.5986220000000p-1 0x1.583e2c0000000p-1
0x1.43339a0000000p-1 0x1.530db60000000p-1 c:0x1.6504dc0000000p-3 c:0x0.0p+0
c:0x1.f11d940000000p-3 c:0x0.0p+0 c:0x1.09bcc40000000p-3

gossip_pga|pallas|push_sum
0x1.4837fc0000000p-1 0x1.5706720000000p-1 0x1.53adf00000000p-1
0x1.44458c0000000p-1 0x1.51d5640000000p-1 c:0x1.3670340000000p-4
c:0x1.4000000000000p-56 c:0x1.80835e0000000p-4 c:0x1.b200000000000p-53
c:0x1.e09b840000000p-5

gossip_pga|pallas|sync
0x1.4837fc0000000p-1 0x1.50ffb00000000p-1 0x1.532fd80000000p-1
0x1.4771a80000000p-1 0x1.50a2700000000p-1 c:0x1.3d59a80000000p-6 c:0x0.0p+0
c:0x1.bc7f8e0000000p-6 c:0x0.0p+0 c:0x1.f0d7340000000p-7

gossip_pga|pallas|sync_opexp
0x1.4837fc0000000p-1 0x1.5706720000000p-1 0x1.53adf00000000p-1
0x1.44458e0000000p-1 0x1.51d5640000000p-1 c:0x1.3670340000000p-4 c:0x0.0p+0
c:0x1.8083600000000p-4 c:0x0.0p+0 c:0x1.e09b860000000p-5

gossip_pga|reference|int8_ef
0x1.4837fc0000000p-1 0x1.5109fa0000000p-1 0x1.5318640000000p-1
0x1.4761000000000p-1 0x1.5087580000000p-1 c:0x1.3eebdc0000000p-6
c:0x1.b8c4040000000p-18 c:0x1.bdd4ac0000000p-6 c:0x1.a9dede0000000p-18
c:0x1.f223dc0000000p-7

gossip_pga|reference|overlap
0x1.4837fc0000000p-1 0x1.5986220000000p-1 0x1.583e2c0000000p-1
0x1.43339a0000000p-1 0x1.530db60000000p-1 c:0x1.6504dc0000000p-3 c:0x0.0p+0
c:0x1.f11d920000000p-3 c:0x0.0p+0 c:0x1.09bcc40000000p-3

gossip_pga|reference|push_sum
0x1.4837fc0000000p-1 0x1.5706720000000p-1 0x1.53adf00000000p-1
0x1.44458c0000000p-1 0x1.51d5640000000p-1 c:0x1.3670340000000p-4
c:0x1.4000000000000p-56 c:0x1.80835e0000000p-4 c:0x1.b200000000000p-53
c:0x1.e09b840000000p-5

gossip_pga|reference|sync
0x1.4837fc0000000p-1 0x1.50ffb00000000p-1 0x1.532fd80000000p-1
0x1.4771a80000000p-1 0x1.50a2700000000p-1 c:0x1.3d59a80000000p-6 c:0x0.0p+0
c:0x1.bc7f8c0000000p-6 c:0x0.0p+0 c:0x1.f0d7380000000p-7

gossip_pga|reference|sync_opexp
0x1.4837fc0000000p-1 0x1.5706700000000p-1 0x1.53adf00000000p-1
0x1.44458c0000000p-1 0x1.51d5640000000p-1 c:0x1.3670340000000p-4 c:0x0.0p+0
c:0x1.8083600000000p-4 c:0x0.0p+0 c:0x1.e09b840000000p-5

gossip|pallas|overlap
0x1.4837fc0000000p-1 0x1.5986220000000p-1 0x1.501bd80000000p-1
0x1.4d88700000000p-1 0x1.55910c0000000p-1 c:0x1.6504dc0000000p-3
c:0x1.07d7140000000p-4 c:0x1.5875e20000000p-3 c:0x1.53c7ca0000000p-3
c:0x1.c813280000000p-3

gossip|pallas|push_sum
0x1.4837fc0000000p-1 0x1.5706700000000p-1 0x1.51616c0000000p-1
0x1.4576da0000000p-1 0x1.5522460000000p-1 c:0x1.3670340000000p-4
c:0x1.331c840000000p-5 c:0x1.b0bea80000000p-4 c:0x1.0d44480000000p-4
c:0x1.67e9c80000000p-4

gossip|pallas|sync
0x1.4837fc0000000p-1 0x1.50ffb00000000p-1 0x1.50d07c0000000p-1
0x1.45ca940000000p-1 0x1.4f1b300000000p-1 c:0x1.3d59a80000000p-6
c:0x1.b12c960000000p-8 c:0x1.bc04c80000000p-6 c:0x1.8e7a540000000p-7
c:0x1.fcb2e40000000p-7

gossip|reference|overlap
0x1.4837fc0000000p-1 0x1.5986220000000p-1 0x1.501bd80000000p-1
0x1.4d88700000000p-1 0x1.55910c0000000p-1 c:0x1.6504dc0000000p-3
c:0x1.07d7140000000p-4 c:0x1.5875e20000000p-3 c:0x1.53c7ca0000000p-3
c:0x1.c813280000000p-3

gossip|reference|push_sum
0x1.4837fc0000000p-1 0x1.5706700000000p-1 0x1.51616c0000000p-1
0x1.4576da0000000p-1 0x1.5522460000000p-1 c:0x1.3670340000000p-4
c:0x1.331c840000000p-5 c:0x1.b0bea80000000p-4 c:0x1.0d44480000000p-4
c:0x1.67e9c80000000p-4

gossip|reference|sync
0x1.4837fc0000000p-1 0x1.50ffb00000000p-1 0x1.50d07c0000000p-1
0x1.45ca940000000p-1 0x1.4f1b300000000p-1 c:0x1.3d59a80000000p-6
c:0x1.b12c9c0000000p-8 c:0x1.bc04c60000000p-6 c:0x1.8e7a540000000p-7
c:0x1.fcb2e80000000p-7

hier_pga|pallas|overlap
0x1.4837fc0000000p-1 0x1.5986220000000p-1 0x1.583e2c0000000p-1
0x1.43339a0000000p-1 0x1.530db60000000p-1 c:0x1.6504dc0000000p-3 c:0x0.0p+0
c:0x1.f11d940000000p-3 c:0x0.0p+0 c:0x1.09bcc40000000p-3

hier_pga|pallas|sync
0x1.4837fc0000000p-1 0x1.50ffb00000000p-1 0x1.532fd80000000p-1
0x1.4771a80000000p-1 0x1.50a2700000000p-1 c:0x1.3d59a80000000p-6 c:0x0.0p+0
c:0x1.bc7f8e0000000p-6 c:0x0.0p+0 c:0x1.f0d7340000000p-7

hier_pga|reference|overlap
0x1.4837fc0000000p-1 0x1.5986220000000p-1 0x1.583e2c0000000p-1
0x1.43339a0000000p-1 0x1.530db60000000p-1 c:0x1.6504dc0000000p-3 c:0x0.0p+0
c:0x1.f11d920000000p-3 c:0x0.0p+0 c:0x1.09bcc40000000p-3

hier_pga|reference|sync
0x1.4837fc0000000p-1 0x1.50ffb00000000p-1 0x1.532fd80000000p-1
0x1.4771a80000000p-1 0x1.50a2700000000p-1 c:0x1.3d59a80000000p-6 c:0x0.0p+0
c:0x1.bc7f8c0000000p-6 c:0x0.0p+0 c:0x1.f0d7380000000p-7

local|pallas|overlap
0x1.4837fc0000000p-1 0x1.5986220000000p-1 0x1.583e2c0000000p-1
0x1.43339c0000000p-1 0x1.530db60000000p-1 c:0x1.6504dc0000000p-3 c:0x0.0p+0
c:0x1.f11d940000000p-3 c:0x0.0p+0 c:0x1.09bcc80000000p-3

local|pallas|push_sum
0x1.4837fc0000000p-1 0x1.5986220000000p-1 0x1.583e2c0000000p-1
0x1.43339c0000000p-1 0x1.530db60000000p-1 c:0x1.6504dc0000000p-3
c:0x1.08c0000000000p-50 c:0x1.f11d920000000p-3 c:0x1.d000000000000p-53
c:0x1.09bcc60000000p-3

local|pallas|sync
0x1.4837fc0000000p-1 0x1.5986220000000p-1 0x1.583e2c0000000p-1
0x1.43339c0000000p-1 0x1.530db60000000p-1 c:0x1.6504dc0000000p-3 c:0x0.0p+0
c:0x1.f11d940000000p-3 c:0x0.0p+0 c:0x1.09bcc80000000p-3

local|reference|overlap
0x1.4837fc0000000p-1 0x1.5986220000000p-1 0x1.583e2c0000000p-1
0x1.43339a0000000p-1 0x1.530db60000000p-1 c:0x1.6504dc0000000p-3 c:0x0.0p+0
c:0x1.f11d920000000p-3 c:0x0.0p+0 c:0x1.09bcc80000000p-3

local|reference|push_sum
0x1.4837fc0000000p-1 0x1.5986220000000p-1 0x1.583e2c0000000p-1
0x1.43339c0000000p-1 0x1.530db60000000p-1 c:0x1.6504dc0000000p-3
c:0x1.08c0000000000p-50 c:0x1.f11d920000000p-3 c:0x1.d000000000000p-53
c:0x1.09bcc60000000p-3

local|reference|sync
0x1.4837fc0000000p-1 0x1.5986220000000p-1 0x1.583e2c0000000p-1
0x1.43339a0000000p-1 0x1.530db60000000p-1 c:0x1.6504dc0000000p-3 c:0x0.0p+0
c:0x1.f11d920000000p-3 c:0x0.0p+0 c:0x1.09bcc80000000p-3

parallel|pallas|overlap
0x1.4837fc0000000p-1 0x1.4d26cc0000000p-1 0x1.4f107c0000000p-1
0x1.4a05c00000000p-1 0x1.51e7c40000000p-1 c:0x0.0p+0 c:0x0.0p+0 c:0x0.0p+0
c:0x0.0p+0 c:0x0.0p+0

parallel|pallas|push_sum
0x1.4837fc0000000p-1 0x1.4d26cc0000000p-1 0x1.4f107c0000000p-1
0x1.4a05c00000000p-1 0x1.51e7c40000000p-1 c:0x1.a060000000000p-53
c:0x1.2000000000000p-54 c:0x1.ac00000000000p-52 c:0x1.1800000000000p-53
c:0x1.8400000000000p-52

parallel|pallas|sync
0x1.4837fc0000000p-1 0x1.4d26cc0000000p-1 0x1.4f107c0000000p-1
0x1.4a05c00000000p-1 0x1.51e7c40000000p-1 c:0x0.0p+0 c:0x0.0p+0 c:0x0.0p+0
c:0x0.0p+0 c:0x0.0p+0

parallel|reference|overlap
0x1.4837fc0000000p-1 0x1.4d26cc0000000p-1 0x1.4f107c0000000p-1
0x1.4a05c00000000p-1 0x1.51e7c40000000p-1 c:0x0.0p+0 c:0x0.0p+0 c:0x0.0p+0
c:0x0.0p+0 c:0x0.0p+0

parallel|reference|push_sum
0x1.4837fc0000000p-1 0x1.4d26cc0000000p-1 0x1.4f107c0000000p-1
0x1.4a05c00000000p-1 0x1.51e7c40000000p-1 c:0x1.a060000000000p-53
c:0x1.2000000000000p-54 c:0x1.ac00000000000p-52 c:0x1.1800000000000p-53
c:0x1.8400000000000p-52

parallel|reference|sync
0x1.4837fc0000000p-1 0x1.4d26cc0000000p-1 0x1.4f107c0000000p-1
0x1.4a05c00000000p-1 0x1.51e7c40000000p-1 c:0x0.0p+0 c:0x0.0p+0 c:0x0.0p+0
c:0x0.0p+0 c:0x0.0p+0

slowmo|pallas|overlap
0x1.4837fc0000000p-1 0x1.4d57320000000p-1 0x1.652d820000000p-1
0x1.5d97180000000p-1 0x1.5e9aa60000000p-1 c:0x1.6504dc0000000p-3 c:0x0.0p+0
c:0x1.d278900000000p-3 c:0x0.0p+0 c:0x1.00c06c0000000p-3

slowmo|pallas|sync
0x1.4837fc0000000p-1 0x1.47f9d00000000p-1 0x1.612f5c0000000p-1
0x1.57a4380000000p-1 0x1.59f3e00000000p-1 c:0x1.3d59a80000000p-6 c:0x0.0p+0
c:0x1.a0c9620000000p-6 c:0x0.0p+0 c:0x1.bb85f00000000p-7

slowmo|reference|overlap
0x1.4837fc0000000p-1 0x1.4d57320000000p-1 0x1.652d820000000p-1
0x1.5d97180000000p-1 0x1.5e9aa60000000p-1 c:0x1.6504dc0000000p-3 c:0x0.0p+0
c:0x1.d278900000000p-3 c:0x0.0p+0 c:0x1.00c06c0000000p-3

slowmo|reference|sync
0x1.4837fc0000000p-1 0x1.47f9d00000000p-1 0x1.612f5c0000000p-1
0x1.57a4380000000p-1 0x1.59f3e00000000p-1 c:0x1.3d59a80000000p-6 c:0x0.0p+0
c:0x1.a0c9600000000p-6 c:0x0.0p+0 c:0x1.bb85ee0000000p-7
""")

_TRAINER_GOLDENS = {k: v[0] for k, v in _parse_goldens("""
gossip_aga|reference|sync
338afc926de0541d3efa1f1d73cab300b98ba5470b7b2e652da81293873820dd

gossip_pga|pallas|overlap
a68cdf5112fe20d4a0737482d9494efa16a81bd97497346f824ea11c52622d8d

gossip_pga|pallas|push_sum
d10f703e3ec321d79ab1a88a02e23fb661773faced4f4d2822ab67d011c017b1

gossip_pga|pallas|sync
b71d1a1cc931f892bf413c2fb9c453173e153de6bcab5f57a7869e3011780bd5

gossip_pga|reference|int8_ef
46bacba2361232b66d1e1e5a5e4a2a1587d63c1b94997abc2f3cf7d5480ec432

gossip_pga|reference|overlap
09a9ecf8f7db0c75ba2e4d1593359613cbcec8b105c6873491ad39fdadfe93dc

gossip_pga|reference|push_sum
d10f703e3ec321d79ab1a88a02e23fb661773faced4f4d2822ab67d011c017b1

gossip_pga|reference|sync
745e1573b8de5113e9ccf4cc068cf95b55b68313708ffc70929efe3b20dbab95

gossip|reference|sync
e603bcc44c8780c80444c64b615f37b949e9560ec6bc63bf684a408652a1c7d3

hier_pga|pallas|sync
b71d1a1cc931f892bf413c2fb9c453173e153de6bcab5f57a7869e3011780bd5

hier_pga|reference|sync
745e1573b8de5113e9ccf4cc068cf95b55b68313708ffc70929efe3b20dbab95

local|reference|sync
a76d11a4cf7bdbcf8ebf5c8865e16bf4f34fc254eb0a60ddb60e57b075f60d78

parallel|reference|sync
de7f380b97dccd3d5cd87c16ec552e69918b30af291a513c27f22cc2d7c8ee4e

slowmo|pallas|sync
08436d35f4fa5846f61d1801f9c482b9c8bf03b74bfe7039c800a8b83369f3cd

slowmo|reference|overlap
d04deb8092652fde9e38a588a29da2dcde268942f698a9ab19dad2e17e45f535

slowmo|reference|sync
dda978efb7f9d9f7eb437a231da701507159bde7206f5bac7148b41d52750cdb
""").items()}


# ---------------------------------------------------------------------------
# simulate matrix
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def sim_problem():
    return make_logistic_problem(n=4, M=64, d=6, iid=False, seed=0)


def _sim_kwargs(prob, key):
    alg, backend, mode = key.split("|")
    kwargs = dict(algorithm=alg, grad_fn=prob.grad_fn(batch=4),
                  loss_fn=prob.loss_fn(), x0=jnp.zeros(prob.d), n=4,
                  steps=5, lr=0.2, topology="ring", H=2, eval_every=1,
                  seed=0, backend=backend, slowmo_beta=0.9, slowmo_lr=0.7)
    if alg == "hier_pga":
        kwargs["aga_kwargs"] = {"n_pods": 2, "hier_h_pod": 2}
    if mode == "overlap":
        kwargs["overlap"] = True
    elif mode == "push_sum":
        kwargs.update(topology="directed_ring", push_sum=True)
    elif mode == "int8_ef":
        kwargs.update(compression="int8", error_feedback=True)
    elif mode == "sync_opexp":
        kwargs.update(topology="one_peer_exp")
    return kwargs


def _sim_hexes(prob, key):
    out = simulate(**_sim_kwargs(prob, key))
    return ([float(v).hex() for v in out["loss"]]
            + ["c:" + float(v).hex() for v in out["consensus"]])


@pytest.mark.parametrize("key", sorted(_SIM_GOLDENS))
def test_simulate_trajectory_bitwise_golden(sim_problem, key):
    assert _sim_hexes(sim_problem, key) == _SIM_GOLDENS[key], key


# ---------------------------------------------------------------------------
# Trainer matrix
# ---------------------------------------------------------------------------
CFG = get_model_config("pga-lm-100m", reduced=True)


def _tcfg(alg, backend="reference", topology="ring", push=False,
          overlap=False, compression="none", ef=False):
    return TrainConfig(
        model=CFG,
        dist=DistConfig(algorithm=alg, topology=topology, H=2,
                        comm_backend=backend, push_sum=push,
                        comm_overlap=overlap, comm_compression=compression,
                        comm_error_feedback=ef, hier_h_pod=2, n_pods=2,
                        slowmo_beta=0.9, slowmo_lr=0.7),
        optimizer=OptimizerConfig(name="adamw", lr=3e-3,
                                  schedule="constant", warmup_steps=0,
                                  grad_clip=1.0),
        data=DataConfig(non_iid=True), global_batch=8, seq_len=32,
        log_every=0)


def _params_digest(state):
    h = hashlib.sha256()
    flat, _ = jax.tree_util.tree_flatten_with_path(
        jax.device_get(state.params))
    for path, leaf in flat:
        h.update(str(path).encode())
        h.update(np.ascontiguousarray(leaf).tobytes())
    return h.hexdigest()


def _trainer_digest(key):
    alg, backend, mode = key.split("|")
    kw = dict(alg=alg, backend=backend)
    if mode == "overlap":
        kw["overlap"] = True
    elif mode == "push_sum":
        kw.update(push=True, topology="directed_ring")
    elif mode == "int8_ef":
        kw.update(compression="int8", ef=True)
    # the capture enabled consensus telemetry on exactly one case to pin
    # that the with_consensus graph variant stays bitwise too
    with_consensus = key == "gossip_pga|pallas|sync"
    tr = Trainer(_tcfg(**kw), n_nodes=4, with_consensus=with_consensus)
    state = tr.init_state(jax.random.PRNGKey(0))
    for _ in range(5):
        state = tr.run(state, steps=1, log_every=0)
    return _params_digest(state)


@pytest.mark.parametrize("key", sorted(_TRAINER_GOLDENS))
def test_trainer_params_bitwise_golden(key):
    assert _trainer_digest(key) == _TRAINER_GOLDENS[key], key


# ---------------------------------------------------------------------------
# gt_pga: checkpoint round-trip, backend parity, composition, crossover
# ---------------------------------------------------------------------------
def test_gt_pga_checkpoint_save_restore_continue_bitwise():
    """Save at step 2, restore into a *differently initialised* trainer,
    continue 3 steps: params AND tracker extras must match the
    uninterrupted run bitwise (batches are keyed off ``state.step``)."""
    tcfg = _tcfg("gt_pga")
    tr = Trainer(tcfg, n_nodes=4)
    state = tr.init_state(jax.random.PRNGKey(0))
    state = tr.run(state, steps=2, log_every=0)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, state, 2)
        cont = tr.run(state, steps=3, log_every=0)
        tr2 = Trainer(tcfg, n_nodes=4)
        other = tr2.init_state(jax.random.PRNGKey(9))
        restored = restore_checkpoint(d, other)
        assert set(restored.extras) == {"gt_tracker", "gt_prev_grad"}
        cont2 = tr2.run(restored, steps=3, log_every=0)
    for a, b in zip(jax.tree.leaves(jax.device_get(cont)),
                    jax.tree.leaves(jax.device_get(cont2))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gt_pga_tracker_mixing_backend_parity(sim_problem):
    """The tracker rides the same joint comm round on both backends;
    reference vs pallas agree to float tolerance (sync rounds are not
    bitwise across backends for ANY algorithm — mixing kernels differ)."""
    outs = {b: simulate(**_sim_kwargs(sim_problem, f"gt_pga|{b}|sync"))
            for b in ("reference", "pallas")}
    np.testing.assert_allclose(outs["reference"]["loss"],
                               outs["pallas"]["loss"],
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(outs["reference"]["consensus"],
                               outs["pallas"]["consensus"],
                               rtol=1e-3, atol=1e-6)


@pytest.mark.parametrize("mode", ["overlap", "int8_ef", "sync_opexp"])
@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_gt_pga_composes_with_comm_modes(sim_problem, backend, mode):
    """Because the tracker travels inside the one joint tree handed to
    ``communicate``, overlap / compression+EF / time-varying topologies
    compose with gradient tracking with no special cases."""
    kwargs = _sim_kwargs(sim_problem, f"gt_pga|{backend}|{mode}")
    # longer horizon than the golden harness: 5 steps is too short for a
    # descent assertion under one-step-stale overlap
    kwargs.update(steps=40, eval_every=10, lr=0.1)
    out = simulate(**kwargs)
    assert np.all(np.isfinite(out["loss"]))
    assert out["loss"][-1] < out["loss"][0]


def test_gt_pga_rejects_push_sum():
    with pytest.raises(ValueError, match="push_sum"):
        DistConfig(algorithm="gt_pga", topology="directed_ring",
                   push_sum=True).validate()


def test_gt_pga_noniid_crossover_miniature():
    """Shrunk version of the benchmark gate: on Dirichlet-sharded data
    plain gossip stalls at a heterogeneity floor while gt_pga keeps
    descending past it (full-batch, constant lr, ring)."""
    prob = dirichlet_noniid_problem(n=8, M=128, d=6, alpha=0.3, seed=0)

    def tail(alg):
        out = simulate(algorithm=alg, grad_fn=prob.grad_fn(batch=0),
                       loss_fn=prob.loss_fn(), x0=jnp.zeros(prob.d),
                       n=8, steps=200, lr=0.05, topology="ring", H=16,
                       eval_every=25, seed=0)
        return float(np.mean(out["loss"][-2:]))

    gt, gossip = tail("gt_pga"), tail("gossip")
    assert gt < gossip, (gt, gossip)
    assert gossip - gt > 1e-6, (gt, gossip)


# ---------------------------------------------------------------------------
# registry + hooks
# ---------------------------------------------------------------------------
def test_unknown_algorithm_error_names_caller_and_lists_valid():
    with pytest.raises(ValueError) as ei:
        algo.get_algorithm("nope", caller="simulate")
    msg = str(ei.value)
    assert msg.startswith("simulate:")
    assert "'nope'" in msg
    for name in algo.algorithm_names():
        assert name in msg


def test_simulate_rejects_unknown_algorithm(sim_problem):
    with pytest.raises(ValueError, match="gossip_pga"):
        simulate(**{**_sim_kwargs(sim_problem, "gossip|reference|sync"),
                    "algorithm": "nope"})


def test_configs_algorithm_lists_source_from_registry():
    from repro.configs import ALGORITHMS, PUSH_SUM_ALGORITHMS
    assert tuple(ALGORITHMS) == algo.algorithm_names()
    assert tuple(PUSH_SUM_ALGORITHMS) == algo.push_sum_algorithm_names()
    assert "gt_pga" in ALGORITHMS
    assert "gt_pga" not in PUSH_SUM_ALGORITHMS


def test_gt_pga_extras_slots_init_and_axes():
    dist = DistConfig(algorithm="gt_pga", topology="ring", H=2).validate()
    params = {"w": jnp.ones((4, 3)), "b": jnp.ones((4,))}
    ex = algo.init_extras(dist, params, 4)
    assert set(ex) == {"gt_tracker", "gt_prev_grad"}
    for name in ex:
        assert (jax.tree.structure(ex[name])
                == jax.tree.structure(params))
        for leaf, p in zip(jax.tree.leaves(ex[name]),
                           jax.tree.leaves(params)):
            assert leaf.shape == p.shape
            assert leaf.dtype == jnp.float32
            assert not np.asarray(leaf).any()        # y_0 = g_{-1} = 0
    axes = algo.extras_axes(dist, {"w": 0, "b": 0},
                            {"w": None, "b": None})
    assert axes == {"gt_tracker": {"w": 0, "b": 0},
                    "gt_prev_grad": {"w": 0, "b": 0}}


def test_gt_pga_ef_state_mirrors_joint_payload():
    dist = DistConfig(algorithm="gt_pga", topology="ring", H=2,
                      comm_compression="int8",
                      comm_error_feedback=True).validate()
    params = {"w": jnp.ones((4, 3))}
    ex = algo.init_extras(dist, params, 4)
    assert set(ex) == {"gt_tracker", "gt_prev_grad", "ef_state"}
    # one residual per *transmitted* leaf: params plus the tracker
    assert set(ex["ef_state"]) == {"params", "gt_tracker"}
    axes = algo.extras_axes(dist, {"w": 0}, {"w": None})
    assert axes["ef_state"] == {"params": {"w": 0}, "gt_tracker": {"w": 0}}


def test_gt_tracker_node_mean_tracks_grad_mean():
    """The GT invariant behind the crossover: with y_0 = g_{-1} = 0 the
    tracker's node-mean equals the current grads' node-mean, every step."""
    a = algo.get_algorithm("gt_pga")
    dist = DistConfig(algorithm="gt_pga", topology="ring", H=2).validate()
    rng = np.random.default_rng(0)
    params = {"w": jnp.zeros((4, 3))}
    ex = algo.init_extras(dist, params, 4)
    for _ in range(3):
        g = {"w": jnp.asarray(rng.standard_normal((4, 3)), jnp.float32)}
        upd, ex = a.pre_update(ex, g)
        np.testing.assert_allclose(np.mean(np.asarray(upd["w"]), axis=0),
                                   np.mean(np.asarray(g["w"]), axis=0),
                                   rtol=1e-5, atol=1e-6)
        assert ex["gt_prev_grad"]["w"] is g["w"]


def test_slot_backfill_kinds_and_known_names():
    assert algo.backfill_kind("push_weight") == "ones"
    assert algo.backfill_kind("ef_state") == "zeros"
    assert algo.backfill_kind("gt_tracker") == "zeros"
    for name in ("gt_tracker", "gt_prev_grad", "slow_params", "slow_u",
                 "ef_state", "push_weight"):
        assert name in algo.known_slot_names()


def test_join_payload_keeps_bare_params_when_empty():
    """Legacy algorithms must hand ``communicate`` the exact same tree as
    before the refactor (bitwise comm graphs) — no dict wrapper."""
    p = {"w": 1}
    assert algo.join_payload({}, p) is p
    joint = algo.join_payload({"t": 2}, p)
    assert joint == {"params": p, "t": 2}
    assert algo.unwrap_mixed(joint, True) is p
    assert algo.unwrap_mixed(p, False) is p
    assert algo.wrap_mixed(p, False) == {"params": p}
    assert algo.wrap_mixed(joint, True) is joint


# ---------------------------------------------------------------------------
# Dirichlet non-IID sharder
# ---------------------------------------------------------------------------
def test_dirichlet_shapes_and_label_domain():
    prob = dirichlet_noniid_problem(n=4, M=32, d=5, seed=0)
    assert prob.H.shape == (4, 32, 5)
    assert prob.y.shape == (4, 32)
    assert set(np.unique(np.asarray(prob.y))) <= {1.0, -1.0}


def test_dirichlet_deterministic_per_seed():
    a = dirichlet_noniid_problem(n=4, M=32, d=5, seed=3)
    b = dirichlet_noniid_problem(n=4, M=32, d=5, seed=3)
    np.testing.assert_array_equal(np.asarray(a.H), np.asarray(b.H))
    np.testing.assert_array_equal(np.asarray(a.y), np.asarray(b.y))
    c = dirichlet_noniid_problem(n=4, M=32, d=5, seed=4)
    assert not np.array_equal(np.asarray(a.H), np.asarray(c.H))


def test_dirichlet_label_skew_scales_with_alpha():
    """Small alpha -> near-single-class nodes; large alpha -> balanced."""
    def node_pos_fracs(alpha):
        prob = dirichlet_noniid_problem(n=16, M=64, d=4, alpha=alpha,
                                        seed=0)
        return np.mean(np.asarray(prob.y) > 0, axis=1)

    lo, hi = node_pos_fracs(0.05), node_pos_fracs(100.0)
    assert lo.std() > 3 * hi.std()
    assert np.abs(hi - 0.5).max() < 0.2
    assert lo.min() < 0.1 and lo.max() > 0.9


def test_dirichlet_feature_shift_moves_node_marginals():
    """Same seed, shift on vs off: the only difference is a constant
    per-node translation of magnitude ``feature_shift`` along a
    node-specific direction (the rng draws are identical either way)."""
    shifted = dirichlet_noniid_problem(n=6, M=512, d=5, feature_shift=5.0,
                                       seed=0)
    plain = dirichlet_noniid_problem(n=6, M=512, d=5, feature_shift=0.0,
                                     seed=0)
    np.testing.assert_array_equal(np.asarray(shifted.y),
                                  np.asarray(plain.y))
    diff = np.asarray(shifted.H) - np.asarray(plain.H)     # (n, M, d)
    dirs = []
    for i in range(6):
        rows = diff[i]
        assert np.abs(rows - rows[0]).max() < 1e-5
        assert abs(np.linalg.norm(rows[0]) - 5.0) < 1e-3
        dirs.append(rows[0] / 5.0)
    # node-specific directions, not one global offset
    assert np.linalg.norm(dirs[0] - dirs[1]) > 0.1


def test_dirichlet_validation_errors():
    with pytest.raises(ValueError, match="n must be"):
        dirichlet_noniid_problem(n=0, M=8, d=2)
    with pytest.raises(ValueError, match="alpha must be"):
        dirichlet_noniid_problem(n=2, M=8, d=2, alpha=0.0)
