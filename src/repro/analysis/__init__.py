"""repro.analysis — repo-specific static analysis for the comm stack.

``python -m repro.analysis [paths...]`` walks the Python sources and
enforces the invariants DESIGN.md documents and earlier PRs established
at runtime: host-sync-free hot paths (RPR001), the CommSpec call form
(RPR002), donation safety around the overlap double buffer (RPR003),
traced-W recompile discipline (RPR004), counter-hash-only randomness in
device modules (RPR005), and ``pl.pallas_call`` contracts (RPR006).

Stdlib-only on purpose: the CI ``analyze`` job runs it before any heavy
dependency is installed, and importing it never initializes jax.
"""
from __future__ import annotations

from repro.analysis.engine import (Baseline, FileContext, Finding, Rule,
                                   all_rules, analyze_file, analyze_paths,
                                   apply_baseline, format_findings,
                                   load_baseline)

__all__ = [
    "Baseline", "FileContext", "Finding", "Rule", "all_rules",
    "analyze_file", "analyze_paths", "apply_baseline", "format_findings",
    "load_baseline",
]
