"""Static-analysis engine for the repo's comm-stack invariants.

The codebase's correctness rests on conventions that, before this pass,
only runtime tests guarded: the host-sync-free Trainer hot loop (PR 8,
DESIGN.md §2.7), donation-safe buffer handling in the async-overlap path
(PR 7, DESIGN.md §2.6), the CommSpec primary call form (PR 7), traced-W
recompile discipline (PR 6, DESIGN.md §2.5), counter-hash-only
randomness in device code (PR 3, DESIGN.md §2.3), and the
``pl.pallas_call`` aliasing contracts (PR 1–2, DESIGN.md §2.1).  Each
:class:`Rule` in :mod:`repro.analysis.rules` machine-checks one of those
conventions over the AST; this module owns the shared machinery:

* per-file parsing and :class:`FileContext` construction (import-alias
  resolution, parent links, enclosing-function qualnames);
* inline suppressions — ``# repro: allow(RPR001)`` (comma-separate for
  several rules) on the flagged line or the line directly above it
  silences a finding; the comment doubles as the in-place justification;
* a tracked **baseline** (``analysis_baseline.json``) for pre-existing
  findings: entries are ``{rule, path, count, note}`` and absorb up to
  ``count`` findings of ``rule`` in ``path`` — the gate stays green
  while the note documents why the debt is allowed to exist;
* text / JSON / GitHub-annotation reporting for the CLI
  (``python -m repro.analysis``) and the CI ``analyze`` job.

The engine is deliberately stdlib-only (``ast`` + ``tokenize``-free line
scanning): it must run in a bare CI container before any heavy
dependency is installed, and importing it must never initialize jax.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import json
import re
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

__all__ = [
    "Finding", "Rule", "FileContext", "register", "all_rules",
    "analyze_file", "analyze_paths", "load_baseline", "apply_baseline",
    "format_findings", "Baseline",
]


# ---------------------------------------------------------------------------
# Findings
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""
    rule: str          # "RPR001"
    path: str          # repo-root-relative posix path
    line: int          # 1-based
    col: int           # 0-based (ast convention)
    message: str

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# File context shared by every rule
# ---------------------------------------------------------------------------
class FileContext:
    """Parsed file + the cross-rule lookups every visitor needs.

    ``imports`` maps local names to fully-qualified dotted module/object
    paths (``np`` → ``numpy``, ``pl`` → ``jax.experimental.pallas``,
    ``communicate`` → ``repro.core.mixing.communicate``), so rules match
    call targets structurally instead of by surface spelling.
    """

    def __init__(self, path: str, source: str, tree: ast.Module):
        self.path = path            # posix, relative to the analysis root
        self.source = source
        self.lines = source.splitlines()
        self.tree = tree
        self.imports = _collect_imports(tree)
        self.parents: Dict[ast.AST, ast.AST] = {}
        self.qualnames: Dict[ast.AST, str] = {}
        self._index(tree, None, ())

    def _index(self, node: ast.AST, parent: Optional[ast.AST],
               stack: Tuple[str, ...]) -> None:
        if parent is not None:
            self.parents[node] = parent
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            stack = stack + (node.name,)
        self.qualnames[node] = ".".join(stack)
        for child in ast.iter_child_nodes(node):
            self._index(child, node, stack)

    # -- lookups ----------------------------------------------------------
    def qualname(self, node: ast.AST) -> str:
        """Dotted enclosing definition name ('' at module level)."""
        return self.qualnames.get(node, "")

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self.parents.get(node)

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Fully-qualified dotted name of an expression, if it is a plain
        (possibly aliased) attribute chain — else None."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        root = self.imports.get(node.id, node.id)
        return ".".join([root] + list(reversed(parts)))

    def enclosing_function(self, node: ast.AST
                           ) -> Optional[ast.FunctionDef]:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = self.parents.get(cur)
        return None

    def finding(self, rule: "Rule", node: ast.AST, message: str) -> Finding:
        return Finding(rule=rule.id, path=self.path,
                       line=getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0), message=message)


def _collect_imports(tree: ast.Module) -> Dict[str, str]:
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.level == 0:
            for a in node.names:
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


# ---------------------------------------------------------------------------
# Rule registry
# ---------------------------------------------------------------------------
class Rule:
    """One checkable convention.  Subclasses set the class attributes and
    implement :meth:`check`; the docstring names the invariant, the
    DESIGN.md section, and the PR that established it (surfaced by
    ``--list-rules`` and the DESIGN §2.8 rule table)."""

    id: str = ""            # "RPRxxx"
    title: str = ""
    design_ref: str = ""    # "DESIGN.md §2.7 (PR 8)"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        raise NotImplementedError

    # paths the rule applies to; default: every analyzed file
    path_globs: Tuple[str, ...] = ("*",)

    def applies_to(self, path: str) -> bool:
        return any(fnmatch.fnmatch(path, g) for g in self.path_globs)


_REGISTRY: Dict[str, Rule] = {}


def register(cls):
    """Class decorator adding a rule (by instance) to the registry."""
    inst = cls()
    if not inst.id or inst.id in _REGISTRY:
        raise ValueError(f"rule id missing or duplicated: {inst.id!r}")
    _REGISTRY[inst.id] = inst
    return cls


def all_rules() -> List[Rule]:
    # import for side effect: the rules package registers on import
    from repro.analysis import rules as _rules  # noqa: F401
    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


# ---------------------------------------------------------------------------
# Suppressions:  # repro: allow(RPR001[, RPR002])  [— justification]
# ---------------------------------------------------------------------------
_ALLOW_RE = re.compile(r"#\s*repro:\s*allow\(([^)]*)\)")


def _suppressions(lines: List[str]) -> Dict[int, set]:
    """Map of 1-based line numbers to the set of rule ids allowed there.
    An allow comment covers its own line and, when it is the whole line
    (a comment-only line), the line below it."""
    out: Dict[int, set] = {}
    for i, line in enumerate(lines, 1):
        m = _ALLOW_RE.search(line)
        if not m:
            continue
        ids = {s.strip() for s in m.group(1).split(",") if s.strip()}
        out.setdefault(i, set()).update(ids)
        if line.lstrip().startswith("#"):
            out.setdefault(i + 1, set()).update(ids)
    return out


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Baseline:
    """Tracked debt: ``entries[(rule, path)] -> (count, note)``."""
    entries: Dict[Tuple[str, str], Tuple[int, str]]

    @staticmethod
    def empty() -> "Baseline":
        return Baseline(entries={})


def load_baseline(path: Path) -> Baseline:
    if not path.exists():
        return Baseline.empty()
    data = json.loads(path.read_text())
    entries: Dict[Tuple[str, str], Tuple[int, str]] = {}
    for e in data.get("entries", []):
        key = (e["rule"], e["path"])
        entries[key] = (int(e.get("count", 1)), e.get("note", ""))
    return Baseline(entries=entries)


def apply_baseline(findings: List[Finding], baseline: Baseline
                   ) -> Tuple[List[Finding], int]:
    """Drop up to ``count`` findings per baselined (rule, path); returns
    (remaining findings, number absorbed)."""
    budget = {k: c for k, (c, _note) in baseline.entries.items()}
    kept: List[Finding] = []
    absorbed = 0
    for f in findings:
        key = (f.rule, f.path)
        if budget.get(key, 0) > 0:
            budget[key] -= 1
            absorbed += 1
        else:
            kept.append(f)
    return kept, absorbed


def write_baseline(path: Path, findings: List[Finding]) -> None:
    groups: Dict[Tuple[str, str], int] = {}
    for f in findings:
        groups[(f.rule, f.path)] = groups.get((f.rule, f.path), 0) + 1
    entries = [{"rule": r, "path": p, "count": c,
                "note": "TODO: justify or fix"}
               for (r, p), c in sorted(groups.items())]
    path.write_text(json.dumps({"version": 1, "entries": entries},
                               indent=2) + "\n")


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------
def analyze_file(root: Path, file: Path,
                 rules: Optional[List[Rule]] = None
                 ) -> Tuple[List[Finding], int]:
    """Run every applicable rule over one file; returns
    (findings, n_suppressed).  A syntax error is itself a finding
    (RPR000) so a broken file can never silently pass the gate."""
    rules = rules if rules is not None else all_rules()
    rel = file.relative_to(root).as_posix()
    source = file.read_text()
    try:
        tree = ast.parse(source, filename=str(file))
    except SyntaxError as e:
        return [Finding(rule="RPR000", path=rel, line=e.lineno or 1,
                        col=e.offset or 0,
                        message=f"syntax error: {e.msg}")], 0
    ctx = FileContext(rel, source, tree)
    allow = _suppressions(ctx.lines)
    findings: List[Finding] = []
    suppressed = 0
    for rule in rules:
        if not rule.applies_to(rel):
            continue
        for f in rule.check(ctx):
            if f.rule in allow.get(f.line, ()):
                suppressed += 1
            else:
                findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings, suppressed


def iter_python_files(root: Path, targets: List[str]) -> Iterator[Path]:
    for t in targets:
        p = (root / t) if not Path(t).is_absolute() else Path(t)
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if "__pycache__" not in f.parts:
                    yield f


def analyze_paths(root: Path, targets: List[str],
                  rules: Optional[List[Rule]] = None
                  ) -> Tuple[List[Finding], int]:
    findings: List[Finding] = []
    suppressed = 0
    for f in iter_python_files(root, targets):
        fs, sup = analyze_file(root, f, rules)
        findings.extend(fs)
        suppressed += sup
    return findings, suppressed


# ---------------------------------------------------------------------------
# Reporting
# ---------------------------------------------------------------------------
def format_findings(findings: List[Finding], fmt: str, *,
                    suppressed: int = 0, baselined: int = 0) -> str:
    if fmt == "json":
        counts: Dict[str, int] = {}
        for f in findings:
            counts[f.rule] = counts.get(f.rule, 0) + 1
        return json.dumps({
            "version": 1,
            "findings": [f.to_dict() for f in findings],
            "counts": counts,
            "suppressed": suppressed,
            "baselined": baselined,
        }, indent=2)
    if fmt == "github":
        # one workflow-command annotation per finding
        return "\n".join(
            f"::error file={f.path},line={f.line},col={f.col + 1},"
            f"title={f.rule}::{f.message}" for f in findings)
    if fmt == "text":
        lines = [f"{f.path}:{f.line}:{f.col + 1}: {f.rule} {f.message}"
                 for f in findings]
        tail = (f"{len(findings)} finding(s)"
                f" ({suppressed} suppressed, {baselined} baselined)")
        return "\n".join(lines + [tail])
    raise ValueError(f"unknown format {fmt!r} "
                     f"(expected text, json, or github)")
