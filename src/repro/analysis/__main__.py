"""CLI for the repo static-analysis pass.

Usage::

    python -m repro.analysis [paths...] [--format=text|json|github]
                             [--baseline FILE] [--write-baseline]
                             [--out FILE] [--list-rules]

Paths default to ``src benchmarks tests`` relative to the repo root (the
directory holding ``analysis_baseline.json`` / ``ROADMAP.md``, found by
walking up from cwd).  Exit codes: 0 clean, 1 findings, 2 usage/config
error.  ``--format=github`` emits one ``::error`` workflow command per
finding so the CI ``analyze`` job annotates the diff in place.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.engine import (all_rules, analyze_paths,
                                   apply_baseline, format_findings,
                                   load_baseline, write_baseline)

DEFAULT_TARGETS = ["src", "benchmarks", "tests"]
BASELINE_NAME = "analysis_baseline.json"


def _find_root(start: Path) -> Path:
    for p in (start, *start.parents):
        if (p / "ROADMAP.md").exists() or (p / BASELINE_NAME).exists():
            return p
    return start


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analysis for the repo's comm-stack "
                    "invariants (RPR001-RPR006).")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to analyze "
                         "(default: src benchmarks tests)")
    ap.add_argument("--format", choices=("text", "json", "github"),
                    default="text")
    ap.add_argument("--baseline", type=Path, default=None,
                    help=f"baseline file (default: <root>/{BASELINE_NAME})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline file entirely")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to the baseline file "
                         "and exit 0")
    ap.add_argument("--out", type=Path, default=None,
                    help="also write the report to this file")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root override (default: auto-detect)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    root = (args.root or _find_root(Path.cwd())).resolve()

    if args.list_rules:
        for r in all_rules():
            doc = (r.__class__.__doc__ or
                   type(r).__module__).strip().splitlines()[0]
            print(f"{r.id}  {r.title}  [{r.design_ref}]")
            print(f"       {doc}")
        return 0

    targets = args.paths or DEFAULT_TARGETS
    missing = [t for t in targets
               if not (root / t).exists() and not Path(t).exists()]
    if missing:
        print(f"error: no such path(s): {', '.join(missing)} "
              f"(root={root})", file=sys.stderr)
        return 2

    findings, suppressed = analyze_paths(root, targets)

    baseline_path = args.baseline or (root / BASELINE_NAME)
    if args.write_baseline:
        write_baseline(baseline_path, findings)
        print(f"wrote {len(findings)} finding(s) to {baseline_path}")
        return 0

    baselined = 0
    if not args.no_baseline:
        findings, baselined = apply_baseline(
            findings, load_baseline(baseline_path))

    report = format_findings(findings, args.format,
                             suppressed=suppressed, baselined=baselined)
    if args.out is not None:
        # the CI artifact is always JSON, whatever the console format
        args.out.write_text(format_findings(
            findings, "json", suppressed=suppressed,
            baselined=baselined) + "\n")
    if report:
        print(report)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
