"""RPR005 — only counter-hash randomness in device-code modules.

Invariant (DESIGN.md §2.3, established by PR 3): randomness that
participates in a communication round is a **pure function of
(seed, step, leaf, column)** — the shared counter-hash that makes every
node take the same stochastic-rounding decision, keeps a constant state
an exact fixed point, and makes quantizer randomness bit-stable under
resharding (PR 5).  Host-stateful generators (``np.random``, the
``random`` module) inside device-code modules break all of that: their
state advances per call, so replay ≠ live, nodes desynchronize, and a
re-trace changes the trajectory.  Host-side schedule code is exempt by
registry — ``core/faults.py`` deliberately uses a counter-*keyed*
``np.random.Philox`` (pure function of (seed, step)) and ``data/``
builds host batches.

Flagged: any ``np.random.*`` attribute use, ``random.*`` call, or
``from random import ...`` inside the registered device modules; use
``jax.random`` with an explicit key, or the repro.compress counter-hash
(``shared per-step randomness``), instead.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.engine import (FileContext, Finding, Rule, register)

DEVICE_MODULES = (
    "src/repro/core/mixing.py",
    "src/repro/kernels/*.py",
    "src/repro/compress/*.py",
    "src/repro/train/step.py",
)


@register
class RandomnessRule(Rule):
    id = "RPR005"
    title = "host-stateful randomness in device code"
    design_ref = "DESIGN.md §2.3 (PR 3)"
    path_globs = DEVICE_MODULES

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ImportFrom) and node.module == "random":
                yield ctx.finding(
                    self, node,
                    "stdlib `random` imported in a device-code module: "
                    "device randomness must be the counter-hash (pure in "
                    f"(seed, step, leaf, column)) ({self.design_ref})")
            elif isinstance(node, ast.Attribute):
                fq = ctx.resolve(node)
                if fq is None:
                    continue
                # flag the base `np.random` attribute exactly once per
                # use (nested attributes like np.random.default_rng
                # contain it as a child node)
                if fq == "numpy.random":
                    yield ctx.finding(
                        self, node,
                        "np.random in a device-code module: np.random "
                        "is host-stateful — nodes desynchronize and "
                        "replay breaks; use jax.random with an explicit "
                        "key or the repro.compress counter-hash "
                        f"({self.design_ref})")
                elif isinstance(node.value, ast.Name) \
                        and node.value.id == "random" \
                        and ctx.imports.get("random") == "random":
                    yield ctx.finding(
                        self, node,
                        f"stdlib random.{node.attr} in a device-code "
                        f"module: use the counter-hash or jax.random "
                        f"instead ({self.design_ref})")
