"""RPR006 — ``pl.pallas_call`` contract checks.

Invariant (DESIGN.md §2.1/§6, established by PR 1–2): every Pallas
kernel invocation states its output contract explicitly —
``out_shape`` with an explicit dtype (``jax.ShapeDtypeStruct(shape,
dtype)``), ``input_output_aliases`` indices that actually exist (the
zero-copy staging-buffer aliasing PR 2 added is silently dropped by XLA
when an index is wrong — the kernel still runs, just slower and with a
second allocation, which is why a lint has to catch it), and a
``grid`` whose rank agrees with every ``BlockSpec`` index map (a rank
mismatch is a Mosaic error on TPU but can pass silently in CPU
interpret mode, i.e. in exactly the environment the tier-1 suite runs).

Checks (literal-syntax best effort — dynamically built spec lists are
checked where the literals are visible):

* ``out_shape=`` present on every ``pl.pallas_call``;
* ``jax.ShapeDtypeStruct(...)`` carries an explicit dtype;
* ``input_output_aliases`` literal keys are ints, in range of the
  operand count (when the call's operands are visible and not starred),
  and values in range of the out_shape entry count (when literal);
* every literal ``pl.BlockSpec(block_shape, index_map)`` in
  ``in_specs``/``out_specs``: the index map's arity equals the grid
  rank, and its returned index tuple has the block shape's rank.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional

from repro.analysis.engine import (FileContext, Finding, Rule, register)

PALLAS_MODULES = ("jax.experimental.pallas",)


def _is_pallas_file(ctx: FileContext) -> bool:
    return any(v.startswith("jax.experimental.pallas")
               for v in ctx.imports.values())


def _grid_rank(call: ast.Call) -> Optional[int]:
    for kw in call.keywords:
        if kw.arg == "grid":
            if isinstance(kw.value, (ast.Tuple, ast.List)):
                return len(kw.value.elts)
            if isinstance(kw.value, ast.Constant) \
                    and isinstance(kw.value.value, int):
                return 1
    return None


def _kwarg(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


@register
class PallasContractRule(Rule):
    id = "RPR006"
    title = "pallas_call contract violation"
    design_ref = "DESIGN.md §2.1/§6 (PR 1-2)"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        if not _is_pallas_file(ctx):
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fq = ctx.resolve(node.func)
            if fq == "jax.ShapeDtypeStruct":
                if len(node.args) < 2 and _kwarg(node, "dtype") is None:
                    yield ctx.finding(
                        self, node,
                        "jax.ShapeDtypeStruct without an explicit dtype: "
                        "the out_shape contract must pin the output "
                        f"dtype ({self.design_ref})")
            if fq != "jax.experimental.pallas.pallas_call":
                continue
            yield from self._check_pallas_call(ctx, node)

    # ------------------------------------------------------------------
    def _check_pallas_call(self, ctx: FileContext,
                           call: ast.Call) -> Iterator[Finding]:
        out_shape = _kwarg(call, "out_shape")
        if out_shape is None:
            yield ctx.finding(
                self, call,
                "pl.pallas_call without out_shape=: the output "
                f"shape/dtype contract must be explicit "
                f"({self.design_ref})")
        n_out = self._count_entries(out_shape)
        n_in = self._operand_count(ctx, call)
        aliases = _kwarg(call, "input_output_aliases")
        if isinstance(aliases, ast.Dict):
            yield from self._check_aliases(ctx, aliases, n_in, n_out)
        grid = _grid_rank(call)
        if grid is not None:
            for spec_kw in ("in_specs", "out_specs"):
                specs = _kwarg(call, spec_kw)
                if specs is None:
                    continue
                for bs in self._literal_blockspecs(ctx, specs):
                    yield from self._check_blockspec(ctx, bs, grid)

    @staticmethod
    def _count_entries(out_shape: Optional[ast.expr]) -> Optional[int]:
        if out_shape is None:
            return None
        if isinstance(out_shape, (ast.Tuple, ast.List)):
            return len(out_shape.elts)
        if isinstance(out_shape, ast.Call):
            return 1
        return None

    def _operand_count(self, ctx: FileContext,
                       call: ast.Call) -> Optional[int]:
        """Number of operands when the pallas_call result is immediately
        invoked with plain (non-starred) arguments."""
        parent = ctx.parent(call)
        if isinstance(parent, ast.Call) and parent.func is call:
            if any(isinstance(a, ast.Starred) for a in parent.args):
                return None
            return len(parent.args)
        return None

    def _check_aliases(self, ctx: FileContext, aliases: ast.Dict,
                       n_in: Optional[int], n_out: Optional[int]
                       ) -> Iterator[Finding]:
        for k, v in zip(aliases.keys, aliases.values):
            if isinstance(k, ast.Constant):
                if not isinstance(k.value, int):
                    yield ctx.finding(
                        self, k,
                        f"input_output_aliases key {k.value!r} is not "
                        f"an int operand index ({self.design_ref})")
                    continue
                if n_in is not None and not (0 <= k.value < n_in):
                    yield ctx.finding(
                        self, k,
                        f"input_output_aliases key {k.value} out of "
                        f"range for {n_in} operand(s): the zero-copy "
                        f"aliasing is silently dropped "
                        f"({self.design_ref})")
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                if n_out is not None and not (0 <= v.value < n_out):
                    yield ctx.finding(
                        self, v,
                        f"input_output_aliases value {v.value} out of "
                        f"range for {n_out} output(s) "
                        f"({self.design_ref})")

    # ------------------------------------------------------------------
    def _literal_blockspecs(self, ctx: FileContext,
                            specs: ast.expr) -> List[ast.Call]:
        nodes = specs.elts if isinstance(specs, (ast.Tuple, ast.List)) \
            else [specs]
        out = []
        for n in nodes:
            if isinstance(n, ast.Call) and \
                    (ctx.resolve(n.func) or "").endswith("BlockSpec"):
                out.append(n)
        return out

    def _index_map_arity(self, ctx: FileContext,
                         im: ast.expr) -> Optional[int]:
        if isinstance(im, ast.Lambda):
            a = im.args
            return len(a.args) + len(a.posonlyargs)
        if isinstance(im, ast.Name):
            for node in ast.walk(ctx.tree):
                if isinstance(node, ast.FunctionDef) \
                        and node.name == im.id:
                    a = node.args
                    return len(a.args) + len(a.posonlyargs)
        return None

    @staticmethod
    def _index_map_rank(im: ast.expr) -> Optional[int]:
        """Length of the index tuple a literal lambda returns."""
        if isinstance(im, ast.Lambda):
            if isinstance(im.body, (ast.Tuple, ast.List)):
                return len(im.body.elts)
        return None

    def _check_blockspec(self, ctx: FileContext, bs: ast.Call,
                         grid: int) -> Iterator[Finding]:
        shape = bs.args[0] if bs.args else _kwarg(bs, "block_shape")
        im = bs.args[1] if len(bs.args) > 1 else _kwarg(bs, "index_map")
        if im is None:
            return
        arity = self._index_map_arity(ctx, im)
        if arity is not None and arity != grid:
            yield ctx.finding(
                self, bs,
                f"BlockSpec index map takes {arity} argument(s) but the "
                f"grid has rank {grid}: rank mismatch passes in CPU "
                f"interpret mode and fails on Mosaic "
                f"({self.design_ref})")
        rank = self._index_map_rank(im)
        if rank is not None and \
                isinstance(shape, (ast.Tuple, ast.List)) and \
                rank != len(shape.elts):
            yield ctx.finding(
                self, bs,
                f"BlockSpec block shape has rank {len(shape.elts)} but "
                f"its index map returns {rank} indices "
                f"({self.design_ref})")
