"""RPR001 — no host syncs inside registered hot paths.

Invariant (DESIGN.md §2.7, established by PR 8): the Trainer hot loop,
the train-step builders, the mixing rounds, the kernels, and the serving
decode loop perform **zero implicit host synchronizations** — device
scalars queue in the monitor window and materialize through the one
sanctioned batched fetch (``Telemetry.fetch``) at log boundaries.  The
historical regression this rule replays: the pre-PR-8 Trainer called
``float(metrics["loss"])`` every step, serializing the dispatch pipeline
and hiding a per-step device→host transfer that the transfer-guard test
now also pins at runtime (the static and dynamic guard check the same
invariant from both sides).

Flagged calls: ``float(...)``, ``.item()``, ``np.asarray(...)`` /
``np.array(...)``, ``jax.device_get(...)``, and ``block_until_ready``
(method or ``jax.block_until_ready``) — inside the registered
(module, function) scopes below.  Code outside the registry (e.g. the
log-boundary ``Trainer._log_boundary``, which operates on already
fetched host values, or the ``repro.obs`` internals that implement the
sanctioned fetch) is not scanned.  A deliberate, explicit transfer in a
hot scope carries ``# repro: allow(RPR001)`` with its justification.
"""
from __future__ import annotations

import ast
import fnmatch
from typing import Iterator, Tuple

from repro.analysis.engine import (FileContext, Finding, Rule, register)

# (path glob, function-qualname globs) — the sanctioned hot-path registry.
# "*" registers the whole module (every function and module level).
HOT_PATHS: Tuple[Tuple[str, Tuple[str, ...]], ...] = (
    ("src/repro/train/step.py", ("*",)),
    ("src/repro/train/trainer.py", ("Trainer.run", "Trainer._run")),
    ("src/repro/core/mixing.py", ("*",)),
    ("src/repro/core/algo.py", ("*",)),
    ("src/repro/kernels/*.py", ("*",)),
    ("src/repro/serve/engine.py",
     ("Engine.generate", "Engine.decode_step", "Engine.prefill",
      "BatchedServer.run")),
)

_SYNC_FQ = {
    "numpy.asarray": "np.asarray materializes the operand on the host",
    "numpy.array": "np.array materializes the operand on the host",
    "jax.device_get": "jax.device_get is a blocking device->host transfer",
    "jax.block_until_ready": "block_until_ready stalls the dispatch "
                             "pipeline",
}


def hot_function_globs(path: str) -> Tuple[str, ...]:
    globs: Tuple[str, ...] = ()
    for pat, fns in HOT_PATHS:
        if fnmatch.fnmatch(path, pat):
            globs = globs + fns
    return globs


@register
class HostSyncRule(Rule):
    id = "RPR001"
    title = "host sync inside a registered hot path"
    design_ref = "DESIGN.md §2.7 (PR 8)"
    path_globs = tuple(p for p, _ in HOT_PATHS)

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        fn_globs = hot_function_globs(ctx.path)
        if not fn_globs:
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            qual = ctx.qualname(node)
            if not any(fnmatch.fnmatch(qual, g) or g == "*"
                       for g in fn_globs):
                continue
            why = self._sync_reason(ctx, node)
            if why is not None:
                yield ctx.finding(
                    self, node,
                    f"{why} — hot paths must stay host-sync-free; queue "
                    f"device values and drain them through the batched "
                    f"Telemetry.fetch at a log boundary "
                    f"({self.design_ref})")

    def _sync_reason(self, ctx: FileContext, node: ast.Call):
        func = node.func
        if isinstance(func, ast.Name) and func.id == "float" \
                and len(node.args) == 1 \
                and not isinstance(node.args[0], ast.Constant):
            return "float() forces a device->host sync on a jax value"
        if isinstance(func, ast.Attribute):
            if func.attr == "item" and not node.args:
                return ".item() forces a device->host sync"
            if func.attr == "block_until_ready":
                return ("block_until_ready stalls the dispatch "
                        "pipeline")
        fq = ctx.resolve(func)
        if fq in _SYNC_FQ:
            return _SYNC_FQ[fq]
        return None
