"""Rule pack for :mod:`repro.analysis`.

Importing this package registers every rule with the engine registry
(each module's ``@register`` decorator runs at import time); the engine's
``all_rules()`` imports it for exactly that side effect.
"""
from __future__ import annotations

from repro.analysis.rules import commspec  # noqa: F401
from repro.analysis.rules import donation  # noqa: F401
from repro.analysis.rules import host_sync  # noqa: F401
from repro.analysis.rules import pallas_contracts  # noqa: F401
from repro.analysis.rules import randomness  # noqa: F401
from repro.analysis.rules import recompile  # noqa: F401
