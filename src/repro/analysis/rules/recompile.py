"""RPR004 — recompile hazards: loop-varying values in static positions.

Invariant (DESIGN.md §2.2/§2.5, established by PR 6): everything that
varies at runtime is a **traced** jit operand.  The push-sum mixing
matrix ``W`` is the canonical case — fault drops, rejoins, and per-step
topology resampling change ``W`` every round, so it crosses the jit
boundary as data; marking it static would recompile the step on every
fault event (and silently, since jit caches by value).  The Trainer's
compile cache is keyed host-side on the genuinely static knobs
``(phase, shift, buf_shift)`` instead.

Three checks:

* a call to a ``jax.jit``-wrapped function inside a ``for``/``while``
  loop passing a **loop-varying name** (the loop target, or a name
  assigned in the loop body) in a ``static_argnums`` position or as a
  ``static_argnames`` keyword — every iteration with a new value is a
  fresh compile;
* a ``dict``/``list``/``set`` literal in a static position — unhashable
  static operands are a ``TypeError`` at trace time;
* ``static_argnames`` naming a traced-W operand (``W``, ``active``) —
  PR 6's contract is that fault patterns never recompile.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.engine import (FileContext, Finding, Rule, register)

TRACED_OPERANDS = {"W", "active"}


def _static_spec(call: ast.Call) -> Tuple[List[int], List[str]]:
    """Literal static_argnums / static_argnames of a jit(...) call."""
    nums: List[int] = []
    names: List[str] = []
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                nums.append(v.value)
            elif isinstance(v, (ast.Tuple, ast.List)):
                nums.extend(e.value for e in v.elts
                            if isinstance(e, ast.Constant)
                            and isinstance(e.value, int))
        elif kw.arg == "static_argnames":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                names.append(v.value)
            elif isinstance(v, (ast.Tuple, ast.List)):
                names.extend(e.value for e in v.elts
                             if isinstance(e, ast.Constant)
                             and isinstance(e.value, str))
    return nums, names


def _jit_call(ctx: FileContext, node: ast.Call) -> Optional[ast.Call]:
    """Return the jit(...) call if ``node`` is ``jax.jit(...)`` or
    ``functools.partial(jax.jit, ...)``."""
    fq = ctx.resolve(node.func)
    if fq == "jax.jit":
        return node
    if fq == "functools.partial" and node.args \
            and ctx.resolve(node.args[0]) == "jax.jit":
        return node
    return None


@register
class RecompileRule(Rule):
    id = "RPR004"
    title = "recompile hazard in a static jit position"
    design_ref = "DESIGN.md §2.2/§2.5 (PR 6)"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        jitted = self._collect_jitted(ctx)
        yield from self._check_traced_w(ctx)
        for loop in ast.walk(ctx.tree):
            if not isinstance(loop, (ast.For, ast.While)):
                continue
            varying = self._loop_varying(loop)
            for call in ast.walk(loop):
                if not isinstance(call, ast.Call) \
                        or not isinstance(call.func, ast.Name):
                    continue
                spec = jitted.get(call.func.id)
                if spec is None:
                    continue
                nums, names = spec
                yield from self._check_call(ctx, call, nums, names,
                                            varying)

    # ------------------------------------------------------------------
    def _collect_jitted(self, ctx: FileContext
                        ) -> Dict[str, Tuple[List[int], List[str]]]:
        """name -> (static_argnums, static_argnames) for jit-wrapped
        assignments and decorated defs."""
        out: Dict[str, Tuple[List[int], List[str]]] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assign) \
                    and isinstance(node.value, ast.Call):
                jc = _jit_call(ctx, node.value)
                t = node.targets[0]
                if jc is not None and isinstance(t, ast.Name):
                    spec = _static_spec(jc)
                    if spec[0] or spec[1]:
                        out[t.id] = spec
            elif isinstance(node, (ast.FunctionDef,
                                   ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    if isinstance(dec, ast.Call):
                        jc = _jit_call(ctx, dec)
                        if jc is not None:
                            spec = _static_spec(jc)
                            if spec[0] or spec[1]:
                                out[node.name] = spec
        return out

    @staticmethod
    def _loop_varying(loop: ast.AST) -> Set[str]:
        varying: Set[str] = set()
        if isinstance(loop, ast.For):
            varying |= {n.id for n in ast.walk(loop.target)
                        if isinstance(n, ast.Name)}
        for node in ast.walk(loop):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    varying |= {n.id for n in ast.walk(t)
                                if isinstance(n, ast.Name)}
            elif isinstance(node, ast.AugAssign) \
                    and isinstance(node.target, ast.Name):
                varying.add(node.target.id)
        return varying

    def _check_call(self, ctx: FileContext, call: ast.Call,
                    nums: List[int], names: List[str],
                    varying: Set[str]) -> Iterator[Finding]:
        slots = [(f"position {i}", call.args[i]) for i in nums
                 if i < len(call.args)]
        slots += [(f"keyword {kw.arg!r}", kw.value)
                  for kw in call.keywords if kw.arg in names]
        # findings anchor at the call so one `# repro: allow(RPR004)` on
        # the call line covers every static slot of that call
        for where, val in slots:
            if isinstance(val, (ast.Dict, ast.List, ast.Set)):
                yield ctx.finding(
                    self, call,
                    f"unhashable literal in static {where}: static jit "
                    f"operands must be hashable — pass it traced or as "
                    f"a frozen/tuple value ({self.design_ref})")
            elif isinstance(val, ast.Name) and val.id in varying:
                yield ctx.finding(
                    self, call,
                    f"loop-varying {val.id!r} flows into static {where} "
                    f"of a jitted call: every new value is a silent "
                    f"recompile — make it a traced operand, or key a "
                    f"host-side compile cache on it like "
                    f"Trainer._get_step_fn ({self.design_ref})")

    def _check_traced_w(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) \
                    and _jit_call(ctx, node) is not None:
                _nums, names = _static_spec(
                    node if ctx.resolve(node.func) == "jax.jit"
                    else node)
                for w in sorted(TRACED_OPERANDS & set(names)):
                    yield ctx.finding(
                        self, node,
                        f"static_argnames marks {w!r} static: the "
                        f"push-sum round's W/active are runtime "
                        f"operands by contract — faults and topology "
                        f"resampling must never recompile "
                        f"({self.design_ref})")
