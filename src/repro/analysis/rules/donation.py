"""RPR003 — donation hazards around the overlap double buffer.

Invariant (DESIGN.md §2.6, established by PR 7): the Trainer jits the
overlapped step with ``donate_argnums=(0, 3)`` — the TrainState *and*
the in-flight comm buffer are donated back each step.  XLA donation is
only sound when the donated operands do not alias each other, and
``mixing.start_round`` / ``mixing.overlap_flush`` return a buffer that
**aliases the params it snapshot** on the dense (uncompressed) path.
The PR-7 convention: any start_round/overlap_flush buffer that escapes a
function alongside the params it aliases (returned together — possibly
inside a ``TrainState(...)`` — or stored on ``self`` for a later
donated call) must be re-bound through ``jax.tree.map(jnp.copy, buf)``
first; otherwise XLA is handed the same buffer twice (the regression
this rule replays from ``train/step.py``'s slowmo/flush branches).

Two checks:

* **alias-escape** — a name bound from ``start_round(src, ...)`` (or the
  buffer slot of ``overlap_flush``) escapes — via ``return`` together
  with ``src`` (containment through constructor calls like
  ``TrainState(params=src)`` is followed), or via an attribute store —
  without an interposed ``jnp.copy`` rebind.  The walk is
  **path-sensitive** over ``if``/``elif`` arms: each arm forks its own
  (hazard, containment) state and a ``return`` is checked against every
  feasible state — a copy in one arm does not sanctify another, and a
  hazard primed in one arm is never combined with an aliasing chain that
  only exists in a mutually-exclusive arm (``phase`` dispatch is
  trace-time static, so such mixed paths cannot compile).
* **donated-callsite reuse** — ``f = jax.jit(g, donate_argnums=...)``
  followed by ``f(a, b, ...)`` and a later read of a donated argument
  name that was never rebound: the buffer was given to XLA and may
  already be reused.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.engine import (FileContext, Finding, Rule, register)

PRIMING_CALLS = {
    "repro.core.mixing.start_round",
    "repro.core.mixing.overlap_flush",
}


def _names(node: Optional[ast.AST]) -> Set[str]:
    if node is None:
        return set()
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _has_copy_call(ctx: FileContext, node: ast.AST) -> bool:
    """True when the expression pipes its value through a copy (directly
    or as ``jax.tree.map(jnp.copy, ...)``)."""
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and n.attr in ("copy", "deepcopy"):
            return True
    return False


def _contained_names(node: ast.AST) -> Set[str]:
    """Names whose referents the expression plausibly *keeps a reference
    to*: plain names, tuple/list/dict literals, and constructor-style
    calls (Capitalized func, or ``.replace(...)``).  Ordinary function
    calls compute fresh values and do not propagate containment."""
    if isinstance(node, ast.Name):
        return {node.id}
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        out: Set[str] = set()
        for e in node.elts:
            out |= _contained_names(e)
        return out
    if isinstance(node, ast.Dict):
        out = set()
        for v in node.values:
            out |= _contained_names(v)
        return out
    if isinstance(node, ast.Starred):
        return _contained_names(node.value)
    if isinstance(node, ast.Attribute):
        return _contained_names(node.value)
    if isinstance(node, ast.Call):
        fname = node.func.attr if isinstance(node.func, ast.Attribute) \
            else (node.func.id if isinstance(node.func, ast.Name) else "")
        if fname[:1].isupper() or fname == "replace":
            out = set()
            for a in node.args:
                out |= _contained_names(a)
            for kw in node.keywords:
                out |= _contained_names(kw.value)
            return out
    return set()


@register
class DonationRule(Rule):
    id = "RPR003"
    title = "donation hazard: aliased/reused donated buffer"
    design_ref = "DESIGN.md §2.6 (PR 7)"

    #: cap on forked (hazards, contains) path states per function; states
    #: beyond it are merged into the last one kept (conservative, keeps
    #: every hazard alive)
    MAX_STATES = 64

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                findings: List[Finding] = []
                self._walk_block(ctx, node.body, [({}, {})], findings)
                seen = set()
                for f in findings:
                    key = (f.line, f.message)
                    if key not in seen:
                        seen.add(key)
                        yield f
                yield from self._check_donated_reuse(ctx, node)

    # ------------------------------------------------------------------
    # alias-escape
    # ------------------------------------------------------------------
    def _priming(self, ctx: FileContext, stmt: ast.Assign
                 ) -> Optional[Tuple[str, Set[str]]]:
        """If ``stmt`` binds a priming-call result, return
        (buffer name, aliased source names)."""
        call = stmt.value
        if not isinstance(call, ast.Call):
            return None
        fq = ctx.resolve(call.func)
        if fq not in PRIMING_CALLS:
            return None
        tgt = stmt.targets[0]
        elts = tgt.elts if isinstance(tgt, ast.Tuple) else [tgt]
        names = [e.id if isinstance(e, ast.Name) else None for e in elts]
        if fq.endswith("start_round"):
            # buf = start_round(src, ...)  |  buf, ef = start_round(...)
            buf = names[0]
            src = _names(call.args[0] if call.args else None)
        else:
            # params, buf, ef = overlap_flush(...): buf aliases params
            if len(names) < 2 or names[0] is None:
                return None
            buf, src = names[1], {names[0]}
        if buf is None or not src:
            return None
        return buf, src

    # one path state: (hazards, contains); the walk carries a list of
    # them and forks at every if/elif arm
    def _walk_block(self, ctx: FileContext, stmts: List[ast.stmt],
                    states: List[Tuple[Dict[str, Set[str]],
                                       Dict[str, Set[str]]]],
                    findings: List[Finding]
                    ) -> List[Tuple[Dict[str, Set[str]],
                                    Dict[str, Set[str]]]]:
        for stmt in stmts:
            if isinstance(stmt, ast.Assign):
                for hazards, contains in states:
                    self._assign(ctx, stmt, hazards, contains, findings)
            elif isinstance(stmt, ast.Return) and stmt.value is not None:
                for hazards, contains in states:
                    self._check_return(ctx, stmt, hazards, contains,
                                       findings)
            elif isinstance(stmt, ast.If):
                forked = []
                for hazards, contains in states:
                    forked += self._walk_block(
                        ctx, stmt.body,
                        [(dict(hazards), dict(contains))], findings)
                    forked += self._walk_block(
                        ctx, stmt.orelse,
                        [(dict(hazards), dict(contains))], findings)
                states = self._dedupe(forked)
            elif isinstance(stmt, (ast.For, ast.While)):
                states = self._walk_block(ctx, stmt.body, states,
                                          findings)
                states = self._walk_block(ctx, stmt.orelse, states,
                                          findings)
            elif isinstance(stmt, ast.With):
                states = self._walk_block(ctx, stmt.body, states,
                                          findings)
            elif isinstance(stmt, ast.Try):
                states = self._walk_block(ctx, stmt.body, states,
                                          findings)
                for handler in stmt.handlers:
                    self._walk_block(
                        ctx, handler.body,
                        [(dict(h), dict(c)) for h, c in states],
                        findings)
                states = self._walk_block(ctx, stmt.finalbody, states,
                                          findings)
        return states

    def _assign(self, ctx: FileContext, stmt: ast.Assign,
                hazards: Dict[str, Set[str]],
                contains: Dict[str, Set[str]],
                findings: List[Finding]) -> None:
        prime = self._priming(ctx, stmt)
        if prime is not None:
            buf, src = prime
            hazards[buf] = src
            contains.pop(buf, None)
            return
        tgts: List[ast.expr] = []
        for t in stmt.targets:
            tgts.extend(t.elts if isinstance(t, ast.Tuple) else [t])
        # attribute-store escape: self.x = <expr using buf>
        for t in tgts:
            if isinstance(t, ast.Attribute):
                used = _names(stmt.value) & set(hazards)
                if used and not _has_copy_call(ctx, stmt.value):
                    findings.append(ctx.finding(
                        self, stmt,
                        f"start_round/overlap_flush buffer "
                        f"{sorted(used)[0]!r} stored without "
                        f"jnp.copy — it aliases the params and "
                        f"both are donated on the next step; "
                        f"re-bind via jax.tree.map(jnp.copy, "
                        f"...) first ({self.design_ref})"))
        for t in tgts:
            if isinstance(t, ast.Name):
                # rebind: a copy rebind sanitizes; any other
                # rebind replaces the binding entirely
                hazards.pop(t.id, None)
                contains[t.id] = _contained_names(stmt.value)

    def _check_return(self, ctx: FileContext, stmt: ast.Return,
                      hazards: Dict[str, Set[str]],
                      contains: Dict[str, Set[str]],
                      findings: List[Finding]) -> None:
        ret = self._closure(_contained_names(stmt.value), contains)
        for buf, src in hazards.items():
            if buf in ret and (src & ret):
                findings.append(ctx.finding(
                    self, stmt,
                    f"buffer {buf!r} (aliases "
                    f"{sorted(src & ret)[0]!r}) returned "
                    f"un-copied: donating both hands XLA the "
                    f"same buffer twice; re-bind via "
                    f"jax.tree.map(jnp.copy, {buf}) before "
                    f"returning ({self.design_ref})"))

    def _dedupe(self, states):
        """Collapse identical path states (arms that never touch a
        tracked name fork into equal states) and cap the population."""
        out, keys = [], set()
        for hazards, contains in states:
            key = (
                frozenset((k, frozenset(v))
                          for k, v in hazards.items()),
                frozenset((k, frozenset(v))
                          for k, v in contains.items() if v),
            )
            if key not in keys:
                keys.add(key)
                out.append((hazards, contains))
        if len(out) > self.MAX_STATES:
            # conservative merge of the overflow into one state so no
            # hazard is dropped
            head, tail = out[:self.MAX_STATES - 1], out[self.MAX_STATES - 1:]
            mh: Dict[str, Set[str]] = {}
            mc: Dict[str, Set[str]] = {}
            for hazards, contains in tail:
                for k, v in hazards.items():
                    mh.setdefault(k, set()).update(v)
                for k, v in contains.items():
                    mc.setdefault(k, set()).update(v)
            out = head + [(mh, mc)]
        return out

    @staticmethod
    def _closure(names: Set[str], contains: Dict[str, Set[str]]
                 ) -> Set[str]:
        out, frontier = set(names), list(names)
        while frontier:
            n = frontier.pop()
            for m in contains.get(n, ()):
                if m not in out:
                    out.add(m)
                    frontier.append(m)
        return out

    # ------------------------------------------------------------------
    # donated-callsite reuse
    # ------------------------------------------------------------------
    def _check_donated_reuse(self, ctx: FileContext,
                             fn: ast.FunctionDef) -> Iterator[Finding]:
        donating: Dict[str, List[int]] = {}
        for stmt in ast.walk(fn):
            if isinstance(stmt, ast.Assign) \
                    and isinstance(stmt.value, ast.Call) \
                    and ctx.resolve(stmt.value.func) == "jax.jit":
                nums = self._donate_argnums(stmt.value)
                t = stmt.targets[0]
                if nums and isinstance(t, ast.Name):
                    donating[t.id] = nums
        if donating:
            yield from self._scan_block(ctx, fn.body, donating)

    @staticmethod
    def _donate_argnums(call: ast.Call) -> List[int]:
        for kw in call.keywords:
            if kw.arg == "donate_argnums":
                v = kw.value
                if isinstance(v, ast.Constant) and isinstance(v.value, int):
                    return [v.value]
                if isinstance(v, (ast.Tuple, ast.List)):
                    return [e.value for e in v.elts
                            if isinstance(e, ast.Constant)
                            and isinstance(e.value, int)]
        return []

    def _scan_block(self, ctx: FileContext, stmts: List[ast.stmt],
                    donating: Dict[str, List[int]]) -> Iterator[Finding]:
        dead: Set[str] = set()
        for stmt in stmts:
            rebound: Set[str] = set()
            if isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    rebound |= {e.id for e in
                                (t.elts if isinstance(t, ast.Tuple)
                                 else [t]) if isinstance(e, ast.Name)}
            elif isinstance(stmt, ast.For) and \
                    isinstance(stmt.target, ast.Name):
                rebound.add(stmt.target.id)
            read = {n.id for n in ast.walk(stmt)
                    if isinstance(n, ast.Name)
                    and isinstance(n.ctx, ast.Load)}
            for name in sorted(read & dead - rebound):
                yield ctx.finding(
                    self, stmt,
                    f"{name!r} was donated to a jax.jit call above "
                    f"(donate_argnums) and read again without being "
                    f"rebound — the buffer may already be reused by "
                    f"XLA ({self.design_ref})")
                dead.discard(name)      # report once per donation
            for n in ast.walk(stmt):
                if isinstance(n, ast.Call) and \
                        isinstance(n.func, ast.Name) and \
                        n.func.id in donating:
                    for i in donating[n.func.id]:
                        if i < len(n.args) and \
                                isinstance(n.args[i], ast.Name):
                            dead.add(n.args[i].id)
            dead -= rebound
            if isinstance(stmt, (ast.For, ast.While, ast.If, ast.With)):
                yield from self._scan_block(ctx, stmt.body, donating)
                yield from self._scan_block(
                    ctx, getattr(stmt, "orelse", []), donating)
