"""RPR002 — the legacy all-kwargs ``communicate`` form is deprecated.

Invariant (DESIGN.md §2.6, established by PR 7): every communication
round threads its ~12 round-invariant knobs through one frozen
:class:`repro.core.mixing.CommSpec` —
``communicate(params, spec, phase=..., step=...)`` — built canonically
by ``DistConfig.comm_spec()``.  The legacy kwarg form
(``communicate(params, phase=..., topology=..., n_nodes=..., ...)``)
survives only as a deprecated shim; hand-forwarding kwargs is exactly
how PR 5's ``model_axis`` was silently dropped by
``Decentralized.communicate`` (the mesh/shard_mode forwarding hole PR 7
closed).  New call sites must pass a spec; tests that deliberately
exercise the shim carry ``# repro: allow(RPR002)``.

Detection: a call to ``mixing.communicate`` / ``communicate_sharded``
(alias-resolved) with **no second positional argument** that passes a
round-invariant knob — either literally (``topology=...``) or through a
``**kwargs`` expansion whose dict literal is assigned in the same
function scope and visibly contains one.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional, Set

from repro.analysis.engine import (FileContext, Finding, Rule, register)

TARGETS = {
    "repro.core.mixing.communicate",
    "repro.core.mixing.communicate_sharded",
}

# CommSpec fields: the round-invariant vocabulary (mixing.CommSpec)
SPEC_KEYS: Set[str] = {
    "topology", "n_nodes", "n_pods", "backend", "mesh", "node_axis",
    "model_axis", "shard_mode", "leaf_threshold", "comm_dtype",
    "compressor", "global_compressor",
}


def _dict_literal_keys(node: ast.AST) -> Optional[Set[str]]:
    """String keys of a ``dict(...)`` call or ``{...}`` literal."""
    if isinstance(node, ast.Dict):
        keys = set()
        for k in node.keys:
            if isinstance(k, ast.Constant) and isinstance(k.value, str):
                keys.add(k.value)
        return keys
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id == "dict" and not node.args:
        return {kw.arg for kw in node.keywords if kw.arg}
    return None


@register
class LegacyCommunicateRule(Rule):
    id = "RPR002"
    title = "legacy communicate(**kwargs) call form"
    design_ref = "DESIGN.md §2.6 (PR 7)"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fq = ctx.resolve(node.func)
            if fq not in TARGETS:
                continue
            if len(node.args) >= 2:     # communicate(params, spec, ...)
                continue
            bad = sorted(SPEC_KEYS & {kw.arg for kw in node.keywords
                                      if kw.arg})
            if not bad:
                bad = sorted(self._starred_spec_keys(ctx, node))
            if bad:
                yield ctx.finding(
                    self, node,
                    f"legacy communicate kwargs ({', '.join(bad)}): "
                    f"build a CommSpec (DistConfig.comm_spec() or "
                    f"mixing.CommSpec) and call communicate(params, "
                    f"spec, phase=..., step=...) ({self.design_ref})")

    def _starred_spec_keys(self, ctx: FileContext,
                           node: ast.Call) -> Set[str]:
        """Spec keys visible through ``**name`` where ``name`` is a dict
        literal assigned in the enclosing function (or module) scope."""
        starred = [kw.value for kw in node.keywords if kw.arg is None]
        names = {v.id for v in starred if isinstance(v, ast.Name)}
        if not names:
            return set()
        scope = ctx.enclosing_function(node) or ctx.tree
        found: Set[str] = set()
        for stmt in ast.walk(scope):
            if not isinstance(stmt, ast.Assign):
                continue
            for tgt in stmt.targets:
                if isinstance(tgt, ast.Name) and tgt.id in names:
                    keys = _dict_literal_keys(stmt.value)
                    if keys:
                        found |= keys & SPEC_KEYS
        return found
