"""Serving engine: prefill + decode with KV / recurrent-state caches, greedy
or temperature sampling, and a slot-based continuous-batching loop.

``serve_step`` (one new token against a full-length cache) is the function the
decode-shape dry-runs lower.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import Model

PyTree = Any

# cache leaf names whose (second-to-batch) axis is the sequence axis, with the
# axis position counted from the END (robust to a leading stacked-layer dim)
_SEQ_AXIS_FROM_END = {"k": 3, "v": 3, "c_kv": 2, "k_rope": 2}


def pad_cache_to(caches: PyTree, s_max: int) -> PyTree:
    """Pad prefill-built attention caches out to the serving window."""
    def pad(path, leaf):
        name = str(getattr(path[-1], "key", ""))
        ax = _SEQ_AXIS_FROM_END.get(name)
        if ax is None or leaf.ndim < ax:
            return leaf
        axis = leaf.ndim - ax
        cur = leaf.shape[axis]
        if cur >= s_max:
            return leaf
        widths = [(0, 0)] * leaf.ndim
        widths[axis] = (0, s_max - cur)
        return jnp.pad(leaf, widths)
    return jax.tree_util.tree_map_with_path(pad, caches)


@dataclasses.dataclass
class Engine:
    model: Model
    s_max: int

    @property
    def cfg(self) -> ModelConfig:
        return self.model.cfg

    # ------------------------------------------------------------------
    def prefill(self, params: PyTree, tokens: jax.Array
                ) -> Tuple[jax.Array, PyTree]:
        """tokens (B, S_prompt) -> (last-position logits, padded cache)."""
        logits, caches, _ = self.model.forward(
            params, {"inputs": tokens}, mode="prefill", want_cache=True)
        caches = pad_cache_to(caches, self.s_max)
        return logits[:, -1], caches

    def decode_step(self, params: PyTree, caches: PyTree, tokens: jax.Array,
                    pos: jax.Array) -> Tuple[jax.Array, PyTree]:
        logits, caches = self.model.decode_step(params, caches, tokens, pos)
        return logits[:, 0], caches

    # ------------------------------------------------------------------
    def generate(self, params: PyTree, prompts: jax.Array, n_new: int, *,
                 temperature: float = 0.0, key: Optional[jax.Array] = None
                 ) -> np.ndarray:
        """Greedy/temperature generation for a fixed batch of equal-length
        prompts.  Returns (B, n_new) generated ids."""
        B, S0 = prompts.shape
        logits, caches = jax.jit(self.prefill)(params, prompts)
        step = jax.jit(self.decode_step)
        out = []
        tok = self._sample(logits, temperature, key)
        pos = jnp.full((B,), S0, jnp.int32)
        for i in range(n_new):
            out.append(tok)   # device array — no per-token host sync
            logits, caches = step(params, caches, tok[:, None], pos)
            if key is not None:
                key, sub = jax.random.split(key)
            else:
                sub = None
            tok = self._sample(logits, temperature, sub)
            pos = pos + 1
        # one batched transfer for the whole generation; the dispatch
        # loop above stays async so decode steps pipeline on device
        # repro: allow(RPR001)
        return np.stack(jax.device_get(out), axis=1)

    @staticmethod
    def _sample(logits: jax.Array, temperature: float,
                key: Optional[jax.Array]) -> jax.Array:
        if temperature <= 0.0 or key is None:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / temperature).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_admit: float = 0.0        # perf_counter at admission (telemetry)


class BatchedServer:
    """Slot-based continuous batching: fixed B decode slots; finished
    requests retire and free their slot for the next queued request.
    Per-slot prefill (B=1) keeps admission simple and bounded.

    ``telemetry`` (repro.obs.Telemetry, optional): each retired request
    emits a ``serve_req`` record (latency, prompt/new token counts,
    tokens/s) and prefill/decode run under ``serve/*`` spans — the same
    schema and sinks the training loop reports through."""

    def __init__(self, engine: Engine, params: PyTree, n_slots: int,
                 telemetry=None):
        self.engine = engine
        self.params = params
        self.n_slots = n_slots
        cfg = engine.cfg
        self.caches = engine.model.init_cache(n_slots, engine.s_max)
        self.tok = jnp.zeros((n_slots, 1), jnp.int32)
        self.pos = jnp.zeros((n_slots,), jnp.int32)
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.telemetry = telemetry
        self._decode = jax.jit(engine.model.decode_step)
        self._prefill1 = jax.jit(
            lambda p, t: engine.model.forward(p, {"inputs": t},
                                              mode="prefill", want_cache=True))

    def _span(self, name: str, **args):
        if self.telemetry is None:
            import contextlib
            return contextlib.nullcontext()
        return self.telemetry.span(name, **args)

    def _admit(self, req: Request, slot: int) -> None:
        import time
        req.t_admit = time.perf_counter()
        prompt = jnp.asarray(req.prompt[None, :], jnp.int32)
        with self._span("serve/prefill", uid=req.uid, slot=slot):
            logits, cache, _ = self._prefill1(self.params, prompt)
        cache = pad_cache_to(cache, self.engine.s_max)
        # write the slot: every cache leaf's batch axis is right after any
        # stacked-layer dims; use tree surgery via dynamic_update_slice
        def _batch_axis(c_all, c_new):
            for ax in range(c_all.ndim):
                if c_all.shape[ax] == self.n_slots and c_new.shape[ax] == 1:
                    return ax
            raise ValueError((c_all.shape, c_new.shape))

        def write(c_all, c_new):
            idx = [0] * c_all.ndim
            idx[_batch_axis(c_all, c_new)] = slot
            return jax.lax.dynamic_update_slice(
                c_all, c_new.astype(c_all.dtype), tuple(idx))

        self.caches = jax.tree.map(write, self.caches, cache)
        first = int(jnp.argmax(logits[0, -1]))
        req.generated.append(first)
        self.slots[slot] = req
        self.tok = self.tok.at[slot, 0].set(first)
        self.pos = self.pos.at[slot].set(len(req.prompt))

    def _retire(self, req: Request) -> None:
        if self.telemetry is None:
            return
        import time
        latency = time.perf_counter() - req.t_admit
        new_tokens = len(req.generated)
        self.telemetry.emit(
            "serve_req", uid=req.uid, latency_s=latency,
            prompt_tokens=int(len(req.prompt)), new_tokens=new_tokens,
            tokens_per_s=new_tokens / max(latency, 1e-9))

    def run(self, requests: List[Request]) -> List[Request]:
        queue = list(requests)
        finished: List[Request] = []
        while queue or any(s is not None for s in self.slots):
            for i in range(self.n_slots):
                if self.slots[i] is None and queue:
                    self._admit(queue.pop(0), i)
            with self._span("serve/decode"):
                logits, self.caches = self._decode(self.params, self.caches,
                                                   self.tok, self.pos)
                # the scheduler is host-side by design: admission and
                # completion decisions need this tick's token ids, so
                # one explicit fetch per decode tick is the floor
                # repro: allow(RPR001)
                nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
            self.pos = self.pos + 1
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                req.generated.append(int(nxt[i]))
                self.tok = self.tok.at[i, 0].set(int(nxt[i]))
                if len(req.generated) >= req.max_new:
                    req.done = True
                    self._retire(req)
                    finished.append(req)
                    self.slots[i] = None
        return finished
