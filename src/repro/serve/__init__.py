from repro.serve.engine import BatchedServer, Engine, Request, pad_cache_to  # noqa: F401
