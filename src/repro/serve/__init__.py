from repro.serve.engine import (BatchedServer, Engine,  # noqa: F401
                                Request, pad_cache_to)
