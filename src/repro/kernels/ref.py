"""Pure-jnp oracles for every Pallas kernel (contract tests sweep)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        scale: Optional[float] = None) -> jax.Array:
    """q: (B,Sq,H,D); k,v: (B,Sk,KH,D) -> (B,Sq,H,D); fp32 softmax."""
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    group = H // KH
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, Sq, KH, group, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows: softmax yields uniform; zero them to match the kernel
    any_valid = jnp.any(mask, axis=-1)                   # (Sq,)
    p = p * any_valid[None, None, None, :, None]
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def rmsnorm_ref(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
                offset: float = 0.0) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (offset + w.astype(jnp.float32))).astype(x.dtype)


def mlstm_chunk_ref(q, k, v, log_i, log_f):
    """Oracle for the chunkwise-mLSTM kernel: the step-by-step stabilized
    recurrence from repro.models.ssm."""
    from repro.models.ssm import mlstm_recurrent_reference
    h, _ = mlstm_recurrent_reference(q, k, v, log_i, log_f)
    return h
