"""Chunkwise mLSTM — Pallas TPU kernel.

The xLSTM matrix-memory recurrence in its chunkwise-parallel form
(repro.models.ssm._mlstm_chunk_scan): intra-chunk attention-style matmuls on
the MXU + a sequential inter-chunk state (C, n, m) carried in VMEM scratch.

Grid: (B·nh, S/chunk) — the chunk dim iterates sequentially per TensorCore so
the (d_k × d_v) matrix memory persists in scratch across chunk steps; one
(chunk × d) tile of q/k/v lives in VMEM per step.  Log-space gate
stabilization is identical to the reference (m carried per head).

VMEM per step ≈ 3·L·d·2B tiles + (d_k·d_v + L²)·4B scratch — with L=64,
d=128: well under 1 MB.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_BIG = -1e9


def _mlstm_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, h_ref,
                  C_scr, n_scr, m_scr, *, chunk: int, seq_len: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        C_scr[...] = jnp.zeros_like(C_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG_BIG)

    q = q_ref[0].astype(jnp.float32)            # (L, dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)            # (L, dv)
    li = li_ref[0].astype(jnp.float32)          # (L,)
    lf = lf_ref[0].astype(jnp.float32)

    # mask pad positions beyond seq_len: forget=1 (log 0), input gate -inf
    pos = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk,), 0)
    valid = pos < seq_len
    li = jnp.where(valid, li, NEG_BIG)
    lf = jnp.where(valid, lf, 0.0)

    F = jnp.cumsum(lf)                          # inclusive (L,)
    w = F[:, None] - F[None, :] + li[None, :]   # (L, L): t rows, τ cols
    tril = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    w = jnp.where(tril, w, -jnp.inf)
    w_max = jnp.max(w, axis=1)                  # (L,)
    m_prev = m_scr[0]
    m_in = m_prev + F
    m_t = jnp.maximum(w_max, m_in)

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (L,L)
    gates = jnp.where(tril, jnp.exp(w - m_t[:, None]), 0.0)
    probs = scores * gates
    h_intra = jax.lax.dot_general(probs, v, (((1,), (0,)), ((), ())))
    den_intra = jnp.sum(probs, axis=1)

    C = C_scr[...]                              # (dk, dv), stabilized
    n = n_scr[...]                              # (dk,)
    sgate = jnp.exp(m_in - m_t)
    h_state = jax.lax.dot_general(q, C, (((1,), (0,)), ((), ()))) \
        * sgate[:, None]
    den_state = (q @ n) * sgate
    den = jnp.maximum(jnp.abs(den_intra + den_state), jnp.exp(-m_t))
    h = (h_intra + h_state) / den[:, None]
    h_ref[0] = h.astype(h_ref.dtype)

    # ---- state update to end of chunk ----
    F_L = F[-1]
    w_end = F_L - F + li                        # (L,)
    m_end = jnp.maximum(jnp.max(w_end), m_prev + F_L)
    kg = jnp.exp(w_end - m_end)
    decay = jnp.exp(m_prev + F_L - m_end)
    C_scr[...] = C * decay + jax.lax.dot_general(
        k * kg[:, None], v, (((0,), (0,)), ((), ())))
    n_scr[...] = n * decay + jnp.sum(k * kg[:, None], axis=0)
    m_scr[0] = m_end


def mlstm_chunk(q: jax.Array, k: jax.Array, v: jax.Array, log_i: jax.Array,
                log_f: jax.Array, *, chunk: int = 64,
                interpret: bool = False) -> jax.Array:
    """q,k: (B,S,nh,dk); v: (B,S,nh,dv); log_i/log_f: (B,S,nh).
    Returns h: (B,S,nh,dv) — matches models.ssm._mlstm_chunk_scan outputs."""
    B, S, nh, dk = q.shape
    dv = v.shape[-1]
    L = max(min(chunk, S), 8)
    pad = (-S) % L

    def heads_major(t):
        # (B,S,nh,d) -> (B*nh, S+pad, d)
        t = jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        t = jnp.moveaxis(t, 2, 1)
        return t.reshape((B * nh, S + pad) + t.shape[3:])

    qh, kh, vh = heads_major(q), heads_major(k), heads_major(v)
    lih, lfh = heads_major(log_i), heads_major(log_f)
    nc = (S + pad) // L

    kernel = functools.partial(_mlstm_kernel, chunk=L, seq_len=S)
    out = pl.pallas_call(
        kernel,
        grid=(B * nh, nc),
        in_specs=[
            pl.BlockSpec((1, L, dk), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, L, dk), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, L, dv), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, L), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, L), lambda bh, ci: (bh, ci)),
        ],
        out_specs=pl.BlockSpec((1, L, dv), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((B * nh, S + pad, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dk, dv), jnp.float32),
            pltpu.VMEM((dk,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qh, kh, vh, lih, lfh)
    out = out[:, :S].reshape(B, nh, S, dv)
    return jnp.moveaxis(out, 1, 2)
