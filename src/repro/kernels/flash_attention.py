"""Flash attention — Pallas TPU kernel with explicit BlockSpec VMEM tiling.

TPU mapping: grid (batch·q_heads, Sq/block_q, Sk/block_k); the innermost grid
dim iterates sequentially on a TensorCore, so the online-softmax running state
(m, l, acc) lives in VMEM scratch that persists across kv-block steps.
Blocks are MXU-aligned (block_q/block_k default 128; head_dim is the
contraction dim).  GQA is expressed through the k/v BlockSpec index maps
(q-head → kv-head), so kv blocks are never replicated into VMEM.

Supports: causal masking, sliding window, Gemma-2 attn-logit softcap.
Validated in interpret mode against repro.kernels.ref (CPU container);
the compiled path targets TPU.

VMEM budget per grid step ≈ (block_q + 2·block_k)·D·2B input tiles
+ block_q·D·4B f32 acc + block_q·block_k·4B scores — well under a v5e
core's ~16 MB VMEM for the default tiles at any supported head_dim.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  softcap: Optional[float], block_q: int, block_k: int,
                  n_kv_blocks: int, sq: int, sk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0].astype(jnp.float32)            # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = (q_pos < sq) & (k_pos < sk)          # pad positions
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new
    l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1)

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        lsum = l_scr[...]
        safe_l = jnp.where(lsum > 0.0, lsum, 1.0)
        o_ref[0] = (acc_scr[...] / safe_l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, D); k,v: (B, Sk, KH, D), H % KH == 0 → (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    assert H % KH == 0, (H, KH)
    group = H // KH
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    block_q_ = max(min(block_q, Sq), 8)
    block_k_ = max(min(block_k, Sk), 8)
    pad_q = (-Sq) % block_q_
    pad_k = (-Sk) % block_k_
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    # (B, S, H, D) -> (B*H, S, D) head-major layout
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq + pad_q, D)
    kh = k.transpose(0, 2, 1, 3).reshape(B * KH, Sk + pad_k, D)
    vh = v.transpose(0, 2, 1, 3).reshape(B * KH, Sk + pad_k, D)

    nq = (Sq + pad_q) // block_q_
    nk = (Sk + pad_k) // block_k_

    def q_index(bh, qi, ki):
        return (bh, qi, 0)

    def kv_index(bh, qi, ki):
        b, h = bh // H, bh % H
        return (b * KH + h // group, ki, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q_, block_k=block_k_, n_kv_blocks=nk,
        sq=Sq, sk=Sk)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q_, D), q_index),
            pl.BlockSpec((1, block_k_, D), kv_index),
            pl.BlockSpec((1, block_k_, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q_, D), q_index),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq + pad_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q_,), jnp.float32),       # m
            pltpu.VMEM((block_q_,), jnp.float32),       # l
            pltpu.VMEM((block_q_, D), jnp.float32),     # acc
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qh, kh, vh)

    out = out[:, :Sq].reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
    return out
