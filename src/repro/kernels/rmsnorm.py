"""Fused RMSNorm — Pallas TPU kernel.

Row-blocked: grid (N/block_rows,), each step normalizes a (block_rows, D) tile
in VMEM with fp32 accumulation and applies the (broadcast) weight tile.  Fuses
the two reduction+scale passes XLA would otherwise emit through HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float, offset: float,
                    n_rows: int, block_rows: int):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)                    # (bm, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    w = w_ref[...].astype(jnp.float32)                    # (D,)
    y = y * (offset + w)[None, :]
    # mask pad rows (harmless garbage otherwise, but keep determinism)
    row = i * block_rows + jax.lax.broadcasted_iota(
        jnp.int32, (block_rows, 1), 0)
    y = jnp.where(row < n_rows, y, 0.0)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
            offset: float = 0.0, block_rows: int = 256,
            interpret: bool = False) -> jax.Array:
    """x: (..., D); w: (D,).  Matches repro.models.layers.rms_norm."""
    orig_shape = x.shape
    D = x.shape[-1]
    xf = x.reshape(-1, D)
    N = xf.shape[0]
    bm = max(min(block_rows, N), 1)
    pad = (-N) % bm
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    kernel = functools.partial(_rmsnorm_kernel, eps=eps, offset=offset,
                               n_rows=N, block_rows=bm)
    out = pl.pallas_call(
        kernel,
        grid=((N + pad) // bm,),
        in_specs=[
            pl.BlockSpec((bm, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N + pad, D), x.dtype),
        interpret=interpret,
    )(xf, w)
    return out[:N].reshape(orig_shape)
