"""Fused Pallas mixing kernels — the paper's communication primitive as a
first-class TPU kernel (DESIGN.md §2.1, "pallas backend").

The reference path in :mod:`repro.core.mixing` applies the gossip round as a
chain of unfused jnp ops: the SGD half-step ``x − γg`` is one pass over HBM,
then every circulant shift term ``w_s · roll(x, s)`` re-reads the parameters,
then the weighted sum writes them back — ``2 + |shifts|`` HBM round-trips per
round.  Here the whole round is one ``pallas_call``:

* every leaf of the parameter pytree is flattened and concatenated into a
  single ``(n, D)`` node-major matrix, so one kernel covers the whole model
  instead of one dispatch per leaf.  The pack/unpack around the kernel is
  itself one extra fp32 copy each way (visible to XLA, fused where it can
  be), so the honest pass count is kernel(1) + pack/unpack — still ahead of
  the reference's ``2 + |shifts|`` passes for multi-shift topologies;
  input/output aliasing and per-leaf dispatch for very large leaves are the
  next optimization (ROADMAP);
* the grid walks ``D`` in ``block_d`` columns; each step loads an
  ``(n, block_d)`` tile into VMEM exactly once, applies the half-step, the
  mix, and (optionally) the consensus residual in-register, and writes the
  tile back once — one HBM round-trip total;
* the circulant mix itself runs as an ``(n, n) @ (n, block_d)`` matmul on the
  MXU.  The node count is tiny (n ≤ 32), so the dense circulant factor lives
  in VMEM for the whole kernel; the "never materialize W" rule (DESIGN.md
  §2.1) is about the *sharded production path*, where W would be an n×n
  matrix of cross-chip traffic — inside a fused single-chip kernel the n×n
  factor is the cheapest possible encoding.

Three public entry points, one kernel body:

``fused_step_mix``   — ``W · (x − γg)`` (γ, g optional → plain ``W·x``)
``global_average`` / ``pod_average`` — the same kernel with W = 𝟙𝟙ᵀ/n or its
                       pod-block-diagonal variant (the PGA / Hier-PGA rounds)
``mix_residual``     — additionally emits ``x̄`` and the consensus distance
                       ``Σ_i ‖x_i − x̄‖²`` of the *mixed* iterate, so eval
                       loops stop re-reading the parameters they just wrote

Wire-dtype ("orthogonal quantization") semantics match the reference: for
gossip rounds the *self* term stays in the storage dtype and only neighbor
terms are cast to ``comm_dtype``; averaging rounds cast everything.  The grid
topology ignores ``comm_dtype`` exactly like the reference does.

``interpret`` defaults to True off-TPU (same convention as kernels/ops.py),
so the backend is exercised end-to-end in CPU CI and compiles to Mosaic on
TPU unchanged.

Scope: these kernels operate on the *local, unsharded* stacked node axis —
the simulator, single-host training, and the per-chip tail of a sharded
step.  They are not yet shard_map-aware: selecting ``backend="pallas"``
under a mesh whose node axis is sharded would gather the stacked state onto
each device.  The sharded production path stays on ``backend="reference"``
(whose rolls lower to collective-permutes) until the kernels grow a
shard_map wrapper (DESIGN.md §2.1, ROADMAP).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import topology as topo

PyTree = Any

KERNEL_PHASES = ("gossip", "global", "pod_avg")


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Phase → (self-weight diagonal, off/cast factor) decomposition
# ---------------------------------------------------------------------------
def phase_matrices(phase: str, topology: str, n: int, step: int = 0,
                   n_pods: int = 1) -> Tuple[np.ndarray, np.ndarray]:
    """Decompose one communication round into ``x ← d ⊙ x + M · cast(x)``.

    Returns ``(d, M)`` with ``d`` shape (n, 1), ``M`` shape (n, n):

    * gossip:  ``d = diag(W)``, ``M = W − diag(W)`` — the self term is kept
      out of ``M`` so the wire cast touches only neighbor traffic, matching
      ``mixing.mix_array``.
    * global:  ``d = 0``, ``M = 𝟙𝟙ᵀ/n`` — the reference all-reduce casts its
      whole operand, and with ``d = 0`` the cast-everything semantics fall
      out of the same ``d ⊙ x + M · cast(x)`` form.
    * pod_avg: ``d = 0``, ``M = blockdiag(𝟙𝟙ᵀ/per)`` — likewise.
    """
    if phase == "gossip":
        W = topo.mixing_matrix(topology, n, step=step)
        d = np.diag(W).copy()
        M = W - np.diag(d)
        return d.reshape(n, 1).astype(np.float32), M.astype(np.float32)
    if phase == "global":
        M = np.full((n, n), 1.0 / n)
        return np.zeros((n, 1), np.float32), M.astype(np.float32)
    if phase == "pod_avg":
        if n % n_pods != 0:
            raise ValueError(f"n={n} not divisible by n_pods={n_pods}")
        per = n // n_pods
        M = np.zeros((n, n))
        for p in range(n_pods):
            M[p * per:(p + 1) * per, p * per:(p + 1) * per] = 1.0 / per
        return np.zeros((n, 1), np.float32), M.astype(np.float32)
    raise ValueError(f"no kernel decomposition for phase {phase!r}")


# ---------------------------------------------------------------------------
# PyTree <-> (n, D) node-major matrix
# ---------------------------------------------------------------------------
def flatten_nodes(tree: PyTree) -> Tuple[jax.Array, Callable]:
    """Concatenate every leaf's non-node dims into one fp32 ``(n, D)`` matrix.

    Returns ``(flat, unflatten)``; ``unflatten(flat2, drop_node=False)``
    restores the original structure, shapes, and per-leaf dtypes.  With
    ``drop_node=True`` it maps a ``(1, D)`` row (e.g. the kernel's x̄ output)
    back to leaves without the node axis.
    """
    leaves, treedef = jax.tree.flatten(tree)
    n = leaves[0].shape[0]
    shapes = [l.shape for l in leaves]
    dtypes = [l.dtype for l in leaves]
    sizes = [int(np.prod(s[1:], dtype=np.int64)) for s in shapes]
    flat = jnp.concatenate(
        [l.reshape(n, -1).astype(jnp.float32) for l in leaves], axis=1)

    def unflatten(f: jax.Array, drop_node: bool = False) -> PyTree:
        out, off = [], 0
        for shape, dtype, size in zip(shapes, dtypes, sizes):
            piece = f[:, off:off + size]
            if drop_node:
                out.append(piece.reshape(shape[1:]).astype(dtype))
            else:
                out.append(piece.reshape((n,) + shape[1:]).astype(dtype))
            off += size
        return jax.tree.unflatten(treedef, out)

    return flat, unflatten


# ---------------------------------------------------------------------------
# Kernel body (shared by all entry points)
# ---------------------------------------------------------------------------
def _mix_kernel(*refs, with_g: bool, with_residual: bool, wire: bool):
    """One grid step: load an (n, bd) tile, fuse half-step + mix (+ residual).

    Ref order: [gamma?, x, g?, d, M] then outputs [o, xbar?, r?].
    """
    idx = 0
    if with_g:
        gamma_ref = refs[idx]; idx += 1
    x_ref = refs[idx]; idx += 1
    if with_g:
        g_ref = refs[idx]; idx += 1
    d_ref = refs[idx]; idx += 1
    m_ref = refs[idx]; idx += 1
    o_ref = refs[idx]; idx += 1
    if with_residual:
        xbar_ref = refs[idx]; idx += 1
        r_ref = refs[idx]; idx += 1

    x = x_ref[...].astype(jnp.float32)                       # (n, bd)
    if with_g:
        x = x - gamma_ref[0, 0] * g_ref[...].astype(jnp.float32)
    # wire-dtype cast applies to the M term only: neighbor traffic for gossip
    # (d carries the uncast self term), everything for averages (d = 0)
    onwire = x.astype(jnp.bfloat16).astype(jnp.float32) if wire else x
    mixed = jnp.dot(m_ref[...], onwire, preferred_element_type=jnp.float32)
    mixed = mixed + d_ref[...] * x
    o_ref[...] = mixed.astype(o_ref.dtype)

    if with_residual:
        xbar = jnp.mean(mixed, axis=0, keepdims=True)        # (1, bd)
        xbar_ref[...] = xbar.astype(xbar_ref.dtype)

        @pl.when(pl.program_id(0) == 0)
        def _init():
            r_ref[0, 0] = 0.0

        r_ref[0, 0] += jnp.sum(jnp.square(mixed - xbar))


@functools.partial(
    jax.jit,
    static_argnames=("with_g", "with_residual", "wire", "block_d",
                     "interpret"))
def _mix_flat(xf: jax.Array, gf: Optional[jax.Array],
              gamma: Optional[jax.Array], d: jax.Array, M: jax.Array, *,
              with_g: bool, with_residual: bool, wire: bool,
              block_d: int, interpret: bool):
    """Run the fused kernel over an already-flattened (n, D) matrix."""
    n, D = xf.shape
    bd = max(1, min(block_d, D))
    pad = (-D) % bd
    if pad:  # zero columns: contribute 0 to mix and residual alike
        xf = jnp.pad(xf, ((0, 0), (0, pad)))
        if with_g:
            gf = jnp.pad(gf, ((0, 0), (0, pad)))
    Dp = D + pad

    tile = lambda i: (0, i)
    in_specs, inputs = [], []
    if with_g:
        in_specs.append(pl.BlockSpec((1, 1), lambda i: (0, 0)))
        inputs.append(jnp.asarray(gamma, jnp.float32).reshape(1, 1))
    in_specs.append(pl.BlockSpec((n, bd), tile))
    inputs.append(xf)
    if with_g:
        in_specs.append(pl.BlockSpec((n, bd), tile))
        inputs.append(gf)
    in_specs.append(pl.BlockSpec((n, 1), lambda i: (0, 0)))
    inputs.append(d)
    in_specs.append(pl.BlockSpec((n, n), lambda i: (0, 0)))
    inputs.append(M)

    out_shape = [jax.ShapeDtypeStruct((n, Dp), xf.dtype)]
    out_specs = [pl.BlockSpec((n, bd), tile)]
    if with_residual:
        out_shape.append(jax.ShapeDtypeStruct((1, Dp), jnp.float32))
        out_specs.append(pl.BlockSpec((1, bd), tile))
        out_shape.append(jax.ShapeDtypeStruct((1, 1), jnp.float32))
        out_specs.append(pl.BlockSpec((1, 1), lambda i: (0, 0)))

    kernel = functools.partial(_mix_kernel, with_g=with_g,
                               with_residual=with_residual, wire=wire)
    out = pl.pallas_call(
        kernel,
        grid=(Dp // bd,),
        in_specs=in_specs,
        out_specs=tuple(out_specs) if with_residual else out_specs[0],
        out_shape=tuple(out_shape) if with_residual else out_shape[0],
        interpret=interpret,
    )(*inputs)

    if with_residual:
        mixed, xbar, r = out
        return mixed[:, :D], xbar[:, :D], r[0, 0]
    return out[:, :D]


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------
def fused_step_mix(params: PyTree, grads: Optional[PyTree] = None,
                   gamma: Optional[jax.Array] = None, *, phase: str,
                   topology: str = "ring", n_nodes: int, step: int = 0,
                   comm_dtype=None, n_pods: int = 1, block_d: int = 2048,
                   interpret: Optional[bool] = None,
                   with_residual: bool = False):
    """Fused ``W · (params − γ·grads)`` for one communication round.

    With ``grads is None`` this is a plain mixing round (the production
    trainer's optimizer already produced the half-step iterate); with grads
    and γ it is the simulator's whole SGD+gossip step in one HBM pass.

    Returns the mixed pytree; with ``with_residual=True`` returns
    ``(mixed, xbar, residual)`` where ``xbar`` is the node average (leaves
    without the node axis) and ``residual = Σ_i ‖x_i − x̄‖²`` of the mixed
    iterate (divide by n for the paper's consensus distance).
    """
    if phase not in KERNEL_PHASES:
        raise ValueError(f"phase {phase!r} has no fused kernel "
                         f"(expected one of {KERNEL_PHASES})")
    interp = _default_interpret() if interpret is None else interpret
    d, M = phase_matrices(phase, topology, n_nodes, step=step, n_pods=n_pods)
    # grid mixing ignores comm_dtype in the reference path — mirror that
    wire = (comm_dtype is not None
            and not (phase == "gossip" and topology == "grid"))
    with_g = grads is not None
    if with_g and gamma is None:
        raise ValueError("grads given without gamma")

    xf, unflatten = flatten_nodes(params)
    gf = flatten_nodes(grads)[0] if with_g else None
    out = _mix_flat(xf, gf, gamma if with_g else None,
                    jnp.asarray(d), jnp.asarray(M),
                    with_g=with_g, with_residual=with_residual, wire=wire,
                    block_d=block_d, interpret=interp)
    if with_residual:
        mixed, xbar, r = out
        return unflatten(mixed), unflatten(xbar, drop_node=True), r
    return unflatten(out)


def global_average(params: PyTree, n_nodes: int, *, comm_dtype=None,
                   block_d: int = 2048, interpret: Optional[bool] = None,
                   with_residual: bool = False):
    """Fused periodic global averaging ``x ← (1/n)𝟙𝟙ᵀ x`` (PGA round)."""
    return fused_step_mix(params, phase="global", n_nodes=n_nodes,
                          comm_dtype=comm_dtype, block_d=block_d,
                          interpret=interpret, with_residual=with_residual)


def pod_average(params: PyTree, n_nodes: int, n_pods: int, *,
                comm_dtype=None, block_d: int = 2048,
                interpret: Optional[bool] = None,
                with_residual: bool = False):
    """Fused intra-pod exact averaging (Hier-PGA round, DESIGN.md §4)."""
    return fused_step_mix(params, phase="pod_avg", n_nodes=n_nodes,
                          n_pods=n_pods, comm_dtype=comm_dtype,
                          block_d=block_d, interpret=interpret,
                          with_residual=with_residual)


def mix_residual(params: PyTree, grads: Optional[PyTree] = None,
                 gamma: Optional[jax.Array] = None, *, phase: str,
                 topology: str = "ring", n_nodes: int, step: int = 0,
                 comm_dtype=None, n_pods: int = 1, block_d: int = 2048,
                 interpret: Optional[bool] = None):
    """``(W·x, x̄, Σ_i ‖x_i − x̄‖²)`` in one pass — eval without re-reading."""
    return fused_step_mix(params, grads, gamma, phase=phase,
                          topology=topology, n_nodes=n_nodes, step=step,
                          comm_dtype=comm_dtype, n_pods=n_pods,
                          block_d=block_d, interpret=interpret,
                          with_residual=True)
