"""Fused Pallas mixing kernels — the paper's communication primitive as a
first-class TPU kernel (DESIGN.md §2.1, "pallas backend").

The reference path in :mod:`repro.core.mixing` applies the gossip round as a
chain of unfused jnp ops: the SGD half-step ``x − γg`` is one pass over HBM,
then every circulant shift term ``w_s · roll(x, s)`` re-reads the parameters,
then the weighted sum writes them back — ``2 + |shifts|`` HBM round-trips per
round.  Here the whole round is one ``pallas_call``:

* leaves *below* ``leaf_threshold`` per-node elements are flattened and
  concatenated into a single ``(n, D)`` node-major matrix, so one kernel
  covers the long tail of small parameters; leaves *at or above* the
  threshold get their own kernel dispatch on ``leaf.reshape(n, -1)`` and
  never touch the concatenation staging buffer.  Every ``pallas_call``
  aliases its packed input with the mixed output
  (``input_output_aliases``), so inside a jitted caller (train step,
  simulator) XLA reuses the staging buffer in place instead of allocating
  and copying a second ``(n, D)`` output — the aliasing contract is that
  the packed matrix is consumed by the kernel and must not be read again
  (DESIGN.md §2.1);
* the grid walks ``D`` in ``block_d`` columns; each step loads an
  ``(n, block_d)`` tile into VMEM exactly once, applies the half-step, the
  mix, and (optionally) the consensus residual in-register, and writes the
  tile back once — one HBM round-trip total;
* the circulant mix itself runs as an ``(n, n) @ (n, block_d)`` matmul on the
  MXU.  The node count is tiny (n ≤ 32), so the dense circulant factor lives
  in VMEM for the whole kernel; the "never materialize W" rule (DESIGN.md
  §2.1) is about the *sharded production path*, where W would be an n×n
  matrix of cross-chip traffic — inside a fused single-chip kernel the n×n
  factor is the cheapest possible encoding.

Three public entry points, one kernel body:

``fused_step_mix``   — ``W · (x − γg)`` (γ, g optional → plain ``W·x``)
``global_average`` / ``pod_average`` — the same kernel with W = 𝟙𝟙ᵀ/n or its
                       pod-block-diagonal variant (the PGA / Hier-PGA rounds)
``mix_residual``     — additionally emits ``x̄`` and the consensus distance
                       ``Σ_i ‖x_i − x̄‖²`` of the *mixed* iterate, so eval
                       loops stop re-reading the parameters they just wrote

Wire-dtype ("orthogonal quantization") semantics match the reference: for
gossip rounds the *self* term stays in the storage dtype and only neighbor
terms are cast to ``comm_dtype``; averaging rounds cast everything.  The grid
topology ignores ``comm_dtype`` exactly like the reference does.

``interpret`` defaults to True off-TPU (same convention as kernels/ops.py),
so the backend is exercised end-to-end in CPU CI and compiles to Mosaic on
TPU unchanged.

Scope: ``fused_step_mix`` / ``global_average`` / ``pod_average`` /
``mix_residual`` operate on the *local* stacked node axis — the simulator,
single-host training, and the per-chip tail of a sharded step.  For a mesh
whose node axis is sharded, :func:`shard_mix_block` is the per-shard kernel
behind ``repro.core.mixing.communicate_sharded``: each shard holds an
``(m, D)`` row-block of the stacked state, receives its neighbor blocks via
``jax.lax.ppermute`` halo exchange, and this kernel fuses the rectangular
mix ``d ⊙ x_local + M_r · xs`` (plus the consensus partial sums) in one
pass over the local block (DESIGN.md §2.1 dispatch table).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import topology as topo

PyTree = Any

KERNEL_PHASES = ("gossip", "global", "pod_avg")

# Per-node element count at or above which a leaf gets its own kernel
# dispatch instead of riding the concatenation staging buffer
# (DistConfig.pallas_leaf_threshold overrides per run).
LEAF_DISPATCH_THRESHOLD = 262_144


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Phase → (self-weight diagonal, off/cast factor) decomposition
# ---------------------------------------------------------------------------
def phase_matrices(phase: str, topology: str, n: int, step: int = 0,
                   n_pods: int = 1) -> Tuple[np.ndarray, np.ndarray]:
    """Decompose one communication round into ``x ← d ⊙ x + M · cast(x)``.

    Returns ``(d, M)`` with ``d`` shape (n, 1), ``M`` shape (n, n):

    * gossip:  ``d = diag(W)``, ``M = W − diag(W)`` — the self term is kept
      out of ``M`` so the wire cast touches only neighbor traffic, matching
      ``mixing.mix_array``.
    * global:  ``d = 0``, ``M = 𝟙𝟙ᵀ/n`` — the reference all-reduce casts its
      whole operand, and with ``d = 0`` the cast-everything semantics fall
      out of the same ``d ⊙ x + M · cast(x)`` form.
    * pod_avg: ``d = 0``, ``M = blockdiag(𝟙𝟙ᵀ/per)`` — likewise.
    """
    if phase == "gossip":
        W = topo.mixing_matrix(topology, n, step=step)
        d = np.diag(W).copy()
        M = W - np.diag(d)
        return d.reshape(n, 1).astype(np.float32), M.astype(np.float32)
    if phase == "global":
        M = np.full((n, n), 1.0 / n)
        return np.zeros((n, 1), np.float32), M.astype(np.float32)
    if phase == "pod_avg":
        if n % n_pods != 0:
            raise ValueError(f"n={n} not divisible by n_pods={n_pods}")
        per = n // n_pods
        M = np.zeros((n, n))
        for p in range(n_pods):
            M[p * per:(p + 1) * per, p * per:(p + 1) * per] = 1.0 / per
        return np.zeros((n, 1), np.float32), M.astype(np.float32)
    raise ValueError(f"no kernel decomposition for phase {phase!r}")


# ---------------------------------------------------------------------------
# PyTree <-> (n, D) node-major matrix
# ---------------------------------------------------------------------------
def _pack_rows(leaves, n: int) -> jax.Array:
    """Concatenate leaves' non-node dims into one fp32 ``(n, D)`` matrix."""
    cols = [lf.reshape(n, -1).astype(jnp.float32) for lf in leaves]
    return cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)


def flatten_nodes(tree: PyTree) -> Tuple[jax.Array, Callable]:
    """``(flat, unflatten)`` for a node-stacked pytree: ``flat`` is the fp32
    ``(n, D)`` node-major packing of every leaf;
    ``unflatten(flat2, drop_node=False)`` restores the original structure,
    shapes, and per-leaf dtypes.  With ``drop_node=True`` it maps a
    ``(1, D)`` row (e.g. the kernel's x̄ output) back to leaves without the
    node axis.  Shared by the stacked entry points and
    ``mixing.communicate_sharded`` — the packing layout must stay identical
    between them.
    """
    leaves, treedef = jax.tree.flatten(tree)
    n = leaves[0].shape[0]
    shapes = [lf.shape for lf in leaves]
    dtypes = [lf.dtype for lf in leaves]
    sizes = [int(np.prod(s[1:], dtype=np.int64)) for s in shapes]
    flat = _pack_rows(leaves, n)

    def unflatten(f: jax.Array, drop_node: bool = False) -> PyTree:
        out, off = [], 0
        for shape, dtype, size in zip(shapes, dtypes, sizes):
            piece = f[:, off:off + size]
            if drop_node:
                out.append(piece.reshape(shape[1:]).astype(dtype))
            else:
                out.append(piece.reshape((n,) + shape[1:]).astype(dtype))
            off += size
        return jax.tree.unflatten(treedef, out)

    return flat, unflatten


def flatten_nodes_sharded(tree: PyTree, k_model: int
                          ) -> Tuple[jax.Array, Callable]:
    """Model-sharded variant of :func:`flatten_nodes` (``k_model == 1``
    degenerates to it exactly, byte for byte).

    Each leaf's flattened columns are zero-padded to a multiple of
    ``k_model`` and split into ``k_model`` equal chunks; the packed matrix
    concatenates chunk ``j`` of *every* leaf contiguously, so a
    ``P(node_axes, model_axes)`` sharding hands model shard ``j`` exactly
    chunk ``j`` of every leaf — a per-leaf wire array sharded
    ``P(node_axes, model_axes)`` on its own column axis stays
    column-aligned with the packed matrix inside the shard_map body
    (``mixing._communicate_sharded_compressed``).  Zero padding is inert
    (same pad-to-multiple semantics as ``compress.collective.pad_cols``,
    inlined here to keep the kernels layer free of compress imports): pad
    columns mix to zero and quantize to zero codes, and ``unflatten``
    strips them per leaf.
    """
    if k_model <= 1:
        return flatten_nodes(tree)
    leaves, treedef = jax.tree.flatten(tree)
    n = leaves[0].shape[0]
    shapes = [lf.shape for lf in leaves]
    dtypes = [lf.dtype for lf in leaves]
    sizes = [int(np.prod(s[1:], dtype=np.int64)) for s in shapes]
    chunks = [-(-s // k_model) for s in sizes]       # per-shard leaf width
    width = sum(chunks)                              # columns per model shard
    x2 = [lf.reshape(n, -1).astype(jnp.float32) for lf in leaves]
    x2 = [jnp.pad(x, ((0, 0), (0, c * k_model - s))) if c * k_model != s
          else x for x, c, s in zip(x2, chunks, sizes)]
    cols = [x[:, j * c:(j + 1) * c]
            for j in range(k_model) for x, c in zip(x2, chunks)]
    flat = cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)

    def unflatten(f: jax.Array, drop_node: bool = False) -> PyTree:
        out, off = [], 0
        for shape, dtype, size, c in zip(shapes, dtypes, sizes, chunks):
            parts = [f[:, j * width + off:j * width + off + c]
                     for j in range(k_model)]
            piece = jnp.concatenate(parts, axis=1)[:, :size]
            if drop_node:
                out.append(piece.reshape(shape[1:]).astype(dtype))
            else:
                out.append(piece.reshape((n,) + shape[1:]).astype(dtype))
            off += c
        return jax.tree.unflatten(treedef, out)

    return flat, unflatten


# ---------------------------------------------------------------------------
# Kernel body (shared by all entry points)
# ---------------------------------------------------------------------------
def _mix_kernel(*refs, with_g: bool, with_residual: bool, wire: bool):
    """One grid step: load an (n, bd) tile, fuse half-step + mix (+ residual).

    Ref order: [gamma?, x, g?, d, M] then outputs [o, xbar?, r?].
    """
    idx = 0
    if with_g:
        gamma_ref = refs[idx]; idx += 1
    x_ref = refs[idx]; idx += 1
    if with_g:
        g_ref = refs[idx]; idx += 1
    d_ref = refs[idx]; idx += 1
    m_ref = refs[idx]; idx += 1
    o_ref = refs[idx]; idx += 1
    if with_residual:
        xbar_ref = refs[idx]; idx += 1
        r_ref = refs[idx]; idx += 1

    x = x_ref[...].astype(jnp.float32)                       # (n, bd)
    if with_g:
        x = x - gamma_ref[0, 0] * g_ref[...].astype(jnp.float32)
    # wire-dtype cast applies to the M term only: neighbor traffic for gossip
    # (d carries the uncast self term), everything for averages (d = 0)
    onwire = x.astype(jnp.bfloat16).astype(jnp.float32) if wire else x
    mixed = jnp.dot(m_ref[...], onwire, preferred_element_type=jnp.float32)
    mixed = mixed + d_ref[...] * x
    o_ref[...] = mixed.astype(o_ref.dtype)

    if with_residual:
        xbar = jnp.mean(mixed, axis=0, keepdims=True)        # (1, bd)
        xbar_ref[...] = xbar.astype(xbar_ref.dtype)

        @pl.when(pl.program_id(0) == 0)
        def _init():
            r_ref[0, 0] = 0.0

        r_ref[0, 0] += jnp.sum(jnp.square(mixed - xbar))


@functools.partial(
    jax.jit,
    static_argnames=("with_g", "with_residual", "wire", "block_d",
                     "interpret"))
def _mix_flat(xf: jax.Array, gf: Optional[jax.Array],
              gamma: Optional[jax.Array], d: jax.Array, M: jax.Array, *,
              with_g: bool, with_residual: bool, wire: bool,
              block_d: int, interpret: bool):
    """Run the fused kernel over an already-flattened (n, D) matrix."""
    n, D = xf.shape
    bd = max(1, min(block_d, D))
    pad = (-D) % bd
    if pad:  # zero columns: contribute 0 to mix and residual alike
        xf = jnp.pad(xf, ((0, 0), (0, pad)))
        if with_g:
            gf = jnp.pad(gf, ((0, 0), (0, pad)))
    Dp = D + pad

    def tile(i):
        return (0, i)

    in_specs, inputs = [], []
    if with_g:
        in_specs.append(pl.BlockSpec((1, 1), lambda i: (0, 0)))
        inputs.append(jnp.asarray(gamma, jnp.float32).reshape(1, 1))
    in_specs.append(pl.BlockSpec((n, bd), tile))
    inputs.append(xf)
    if with_g:
        in_specs.append(pl.BlockSpec((n, bd), tile))
        inputs.append(gf)
    in_specs.append(pl.BlockSpec((n, 1), lambda i: (0, 0)))
    inputs.append(d)
    in_specs.append(pl.BlockSpec((n, n), lambda i: (0, 0)))
    inputs.append(M)

    out_shape = [jax.ShapeDtypeStruct((n, Dp), xf.dtype)]
    out_specs = [pl.BlockSpec((n, bd), tile)]
    if with_residual:
        out_shape.append(jax.ShapeDtypeStruct((1, Dp), jnp.float32))
        out_specs.append(pl.BlockSpec((1, bd), tile))
        out_shape.append(jax.ShapeDtypeStruct((1, 1), jnp.float32))
        out_specs.append(pl.BlockSpec((1, 1), lambda i: (0, 0)))

    kernel = functools.partial(_mix_kernel, with_g=with_g,
                               with_residual=with_residual, wire=wire)
    # the packed (n, Dp) matrix is consumed in place: the mixed output
    # aliases the x input, so jitted callers never allocate a second copy
    x_idx = 1 if with_g else 0
    out = pl.pallas_call(
        kernel,
        grid=(Dp // bd,),
        in_specs=in_specs,
        out_specs=tuple(out_specs) if with_residual else out_specs[0],
        out_shape=tuple(out_shape) if with_residual else out_shape[0],
        input_output_aliases={x_idx: 0},
        interpret=interpret,
    )(*inputs)

    if with_residual:
        mixed, xbar, r = out
        return mixed[:, :D], xbar[:, :D], r[0, 0]
    return out[:, :D]


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------
def _dispatch_groups(leaves, threshold: int):
    """Leaf indices grouped per kernel dispatch: one group holding every
    leaf below ``threshold`` per-node elements (concatenated into the
    staging buffer), plus one single-leaf group per large leaf (dispatched
    on ``leaf.reshape(n, -1)`` directly — no staging copy)."""
    sizes = [int(np.prod(lf.shape[1:], dtype=np.int64)) for lf in leaves]
    small = [i for i, s in enumerate(sizes) if s < threshold]
    big = [i for i, s in enumerate(sizes) if s >= threshold]
    groups = [small] if small else []
    return groups + [[i] for i in big]


def fused_step_mix(params: PyTree, grads: Optional[PyTree] = None,
                   gamma: Optional[jax.Array] = None, *, phase: str,
                   topology: str = "ring", n_nodes: int, step: int = 0,
                   comm_dtype=None, n_pods: int = 1, block_d: int = 2048,
                   interpret: Optional[bool] = None,
                   with_residual: bool = False,
                   leaf_threshold: Optional[int] = None):
    """Fused ``W · (params − γ·grads)`` for one communication round.

    With ``grads is None`` this is a plain mixing round (the production
    trainer's optimizer already produced the half-step iterate); with grads
    and γ it is the simulator's whole SGD+gossip step in one HBM pass.

    Leaves at or above ``leaf_threshold`` per-node elements are dispatched
    as their own kernel call and skip the concatenation staging buffer;
    the residual/x̄ outputs are combined exactly across dispatches (the
    consensus sum decomposes over columns).

    Returns the mixed pytree; with ``with_residual=True`` returns
    ``(mixed, xbar, residual)`` where ``xbar`` is the node average (leaves
    without the node axis) and ``residual = Σ_i ‖x_i − x̄‖²`` of the mixed
    iterate (divide by n for the paper's consensus distance).
    """
    if phase not in KERNEL_PHASES:
        raise ValueError(f"phase {phase!r} has no fused kernel "
                         f"(expected one of {KERNEL_PHASES})")
    interp = _default_interpret() if interpret is None else interpret
    thresh = LEAF_DISPATCH_THRESHOLD if leaf_threshold is None \
        else leaf_threshold
    d, M = phase_matrices(phase, topology, n_nodes, step=step, n_pods=n_pods)
    dj, Mj = jnp.asarray(d), jnp.asarray(M)
    # grid mixing ignores comm_dtype in the reference path — mirror that
    wire = (comm_dtype is not None
            and not (phase == "gossip" and topology == "grid"))
    with_g = grads is not None
    if with_g and gamma is None:
        raise ValueError("grads given without gamma")

    leaves, treedef = jax.tree.flatten(params)
    gleaves = jax.tree.flatten(grads)[0] if with_g else None
    n = leaves[0].shape[0]
    mixed_leaves: list = [None] * len(leaves)
    xbar_leaves: list = [None] * len(leaves)
    resid = None
    for group in _dispatch_groups(leaves, thresh):
        xf = _pack_rows([leaves[i] for i in group], n)
        gf = _pack_rows([gleaves[i] for i in group], n) if with_g else None
        out = _mix_flat(xf, gf, gamma if with_g else None, dj, Mj,
                        with_g=with_g, with_residual=with_residual,
                        wire=wire, block_d=block_d, interpret=interp)
        if with_residual:
            mixed, xbar, r = out
            resid = r if resid is None else resid + r
        else:
            mixed, xbar = out, None
        off = 0
        for i in group:
            shape, size = leaves[i].shape, \
                int(np.prod(leaves[i].shape[1:], dtype=np.int64))
            piece = mixed[:, off:off + size]
            mixed_leaves[i] = piece.reshape(shape).astype(leaves[i].dtype)
            if with_residual:
                xbar_leaves[i] = (xbar[:, off:off + size]
                                  .reshape(shape[1:])
                                  .astype(leaves[i].dtype))
            off += size
    mixed_tree = jax.tree.unflatten(treedef, mixed_leaves)
    if with_residual:
        return mixed_tree, jax.tree.unflatten(treedef, xbar_leaves), resid
    return mixed_tree


def fused_step_mix_dense(params: PyTree, W: jax.Array, *, n_nodes: int,
                         comm_dtype=None, block_d: int = 2048,
                         interpret: Optional[bool] = None,
                         leaf_threshold: Optional[int] = None) -> PyTree:
    """Fused mixing round for a **runtime** dense ``W`` (push-sum,
    DESIGN.md §2.5).

    The phase-based entry points bake W in at trace time from the
    ``(phase, topology, shift)`` triple — fine when the matrix repertoire
    is small and static.  Push-sum under faults draws a *different*
    column-stochastic W every step (drop renormalization, per-step
    resampling), so here W is an ``(n, n)`` jax array threaded through jit
    as a regular traced operand: one compiled kernel serves every failure
    pattern, zero recompiles.  ``_mix_flat`` already treats ``d``/``M`` as
    runtime data, so this is the same kernel body as
    :func:`fused_step_mix` — only the factor construction moves into the
    traced graph (``d = diag(W)``, ``M = W − diag(W)``).

    Gossip wire semantics: ``comm_dtype`` (bf16 only, like the other fused
    paths) casts the M (neighbor) term; the self term stays in the storage
    dtype.  The push-sum weight column rides the packed matrix as just
    another leaf — mixing x and w through the *same* kernel invocation is
    what keeps the de-bias ratio consistent (DESIGN.md §2.5).
    """
    interp = _default_interpret() if interpret is None else interpret
    thresh = LEAF_DISPATCH_THRESHOLD if leaf_threshold is None \
        else leaf_threshold
    if comm_dtype is not None \
            and jnp.dtype(comm_dtype) != jnp.dtype(jnp.bfloat16):
        raise ValueError(
            f"fused_step_mix_dense wire-casts to bfloat16 only (got "
            f"comm_dtype={jnp.dtype(comm_dtype)}); use backend='reference'")
    Wj = jnp.asarray(W, jnp.float32)
    dj = jnp.diagonal(Wj).reshape(n_nodes, 1)
    Mj = Wj - jnp.diag(jnp.diagonal(Wj))
    wire = comm_dtype is not None

    leaves, treedef = jax.tree.flatten(params)
    n = leaves[0].shape[0]
    mixed_leaves: list = [None] * len(leaves)
    for group in _dispatch_groups(leaves, thresh):
        xf = _pack_rows([leaves[i] for i in group], n)
        mixed = _mix_flat(xf, None, None, dj, Mj, with_g=False,
                          with_residual=False, wire=wire, block_d=block_d,
                          interpret=interp)
        off = 0
        for i in group:
            shape = leaves[i].shape
            size = int(np.prod(shape[1:], dtype=np.int64))
            mixed_leaves[i] = (mixed[:, off:off + size]
                               .reshape(shape).astype(leaves[i].dtype))
            off += size
    return jax.tree.unflatten(treedef, mixed_leaves)


def global_average(params: PyTree, n_nodes: int, *, comm_dtype=None,
                   block_d: int = 2048, interpret: Optional[bool] = None,
                   with_residual: bool = False,
                   leaf_threshold: Optional[int] = None):
    """Fused periodic global averaging ``x ← (1/n)𝟙𝟙ᵀ x`` (PGA round)."""
    return fused_step_mix(params, phase="global", n_nodes=n_nodes,
                          comm_dtype=comm_dtype, block_d=block_d,
                          interpret=interpret, with_residual=with_residual,
                          leaf_threshold=leaf_threshold)


def pod_average(params: PyTree, n_nodes: int, n_pods: int, *,
                comm_dtype=None, block_d: int = 2048,
                interpret: Optional[bool] = None,
                with_residual: bool = False,
                leaf_threshold: Optional[int] = None):
    """Fused intra-pod exact averaging (Hier-PGA round, DESIGN.md §4)."""
    return fused_step_mix(params, phase="pod_avg", n_nodes=n_nodes,
                          n_pods=n_pods, comm_dtype=comm_dtype,
                          block_d=block_d, interpret=interpret,
                          with_residual=with_residual,
                          leaf_threshold=leaf_threshold)


def mix_residual(params: PyTree, grads: Optional[PyTree] = None,
                 gamma: Optional[jax.Array] = None, *, phase: str,
                 topology: str = "ring", n_nodes: int, step: int = 0,
                 comm_dtype=None, n_pods: int = 1, block_d: int = 2048,
                 interpret: Optional[bool] = None,
                 leaf_threshold: Optional[int] = None):
    """``(W·x, x̄, Σ_i ‖x_i − x̄‖²)`` in one pass — eval without re-reading."""
    return fused_step_mix(params, grads, gamma, phase=phase,
                          topology=topology, n_nodes=n_nodes, step=step,
                          comm_dtype=comm_dtype, n_pods=n_pods,
                          block_d=block_d, interpret=interpret,
                          with_residual=True, leaf_threshold=leaf_threshold)


# ---------------------------------------------------------------------------
# Per-shard block kernel (the shard_map-aware path, DESIGN.md §2.1)
# ---------------------------------------------------------------------------
# ---------------------------------------------------------------------------
# Compressed rounds: fused quantize → mix → dequantize (DESIGN.md §2.3)
# ---------------------------------------------------------------------------
def _cmix_kernel(*refs, kind: str, with_ef: bool, wire: bool):
    """One grid step of the compensated compressed round
    ``o = x + (M·q − w ⊙ q)``.

    For the quantizer kinds ("int8", "fp8") the wire estimate ``q`` is
    computed **in-register** from the tile: random bits from the shared
    column hash (repro.compress.base), codes via the same element-wise
    math as the reference compressor (repro.compress.quantize), dequant,
    mix — the quantized payload never exists in HBM.  ``kind ==
    "precomputed"`` takes ``q`` as an input (sparsifier selections are
    data-dependent gathers, not tile-local ops) and fuses only the
    compensated mix.

    Ref order: [seed?, x, e?, scale?, q?, w, M] → [o, ef?]
    (seed/scale for quantizers, e with error feedback, q precomputed).
    ``wire=True`` (the global phase with a comm_dtype) additionally
    bf16-casts the estimate — both occurrences, preserving the constant
    fixed point — mirroring the reference collective's operand cast.
    """
    from repro.compress import base as cbase
    from repro.compress import quantize as cq

    quant = kind in ("int8", "fp8")
    idx = 0
    if quant:
        seed_ref = refs[idx]; idx += 1
    x_ref = refs[idx]; idx += 1
    if with_ef and quant:
        e_ref = refs[idx]; idx += 1
    if quant:
        scale_ref = refs[idx]; idx += 1
    else:
        q_ref = refs[idx]; idx += 1
    w_ref = refs[idx]; idx += 1
    m_ref = refs[idx]; idx += 1
    o_ref = refs[idx]; idx += 1
    if with_ef and quant:
        ef_ref = refs[idx]; idx += 1

    x = x_ref[...].astype(jnp.float32)                       # (n, bd)
    if quant:
        y = x + e_ref[...].astype(jnp.float32) if with_ef else x
        n, bd = x.shape
        base = (pl.program_id(0) * bd).astype(jnp.uint32)
        cols = base + jax.lax.broadcasted_iota(jnp.uint32, (n, bd), 1)
        scale = scale_ref[...]
        if kind == "int8":
            u = cbase.uniform_columns(seed_ref[0, 0], cols)
            q = cq.int8_dequant(cq.int8_codes(y, scale, u), scale)
        else:
            bits = cbase.column_bits(seed_ref[0, 0], cols)
            q = cq.fp8_dequant(cq.fp8_codes(y, scale, bits), scale)
        if with_ef:
            ef_ref[...] = (y - q).astype(ef_ref.dtype)
    else:
        q = q_ref[...].astype(jnp.float32)
    if wire:
        q = q.astype(jnp.bfloat16).astype(jnp.float32)
    corr = jnp.dot(m_ref[...], q, preferred_element_type=jnp.float32) \
        - w_ref[...] * q
    o_ref[...] = (x + corr).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "with_ef", "wire", "block_d", "interpret"))
def _cmix_flat(xf: jax.Array, ef: Optional[jax.Array],
               qf: Optional[jax.Array], seed: Optional[jax.Array],
               scale: Optional[jax.Array], w: jax.Array, M: jax.Array, *,
               kind: str, with_ef: bool, wire: bool, block_d: int,
               interpret: bool):
    """Run the compressed-mix kernel over one flattened (n, D) leaf."""
    n, D = xf.shape
    bd = max(1, min(block_d, D))
    pad = (-D) % bd
    if pad:  # zero columns quantize to exact zero codes → contribute 0
        xf = jnp.pad(xf, ((0, 0), (0, pad)))
        if ef is not None:
            ef = jnp.pad(ef, ((0, 0), (0, pad)))
        if qf is not None:
            qf = jnp.pad(qf, ((0, 0), (0, pad)))
    Dp = D + pad
    quant = kind in ("int8", "fp8")

    def tile(i):
        return (0, i)

    def scalar(i):
        return (0, 0)

    in_specs, inputs = [], []
    if quant:
        in_specs.append(pl.BlockSpec((1, 1), scalar))
        inputs.append(jnp.asarray(seed).astype(jnp.uint32).reshape(1, 1))
    in_specs.append(pl.BlockSpec((n, bd), tile))
    inputs.append(xf)
    if with_ef and quant:
        in_specs.append(pl.BlockSpec((n, bd), tile))
        inputs.append(ef)
    if quant:
        in_specs.append(pl.BlockSpec((n, 1), scalar))
        inputs.append(scale)
    else:
        in_specs.append(pl.BlockSpec((n, bd), tile))
        inputs.append(qf)
    in_specs.append(pl.BlockSpec((n, 1), scalar))
    inputs.append(w)
    in_specs.append(pl.BlockSpec((n, n), scalar))
    inputs.append(M)

    out_shape = [jax.ShapeDtypeStruct((n, Dp), xf.dtype)]
    out_specs = [pl.BlockSpec((n, bd), tile)]
    if with_ef and quant:
        out_shape.append(jax.ShapeDtypeStruct((n, Dp), jnp.float32))
        out_specs.append(pl.BlockSpec((n, bd), tile))

    multi = with_ef and quant
    x_idx = 1 if quant else 0
    out = pl.pallas_call(
        functools.partial(_cmix_kernel, kind=kind, with_ef=with_ef,
                          wire=wire),
        grid=(Dp // bd,),
        in_specs=in_specs,
        out_specs=tuple(out_specs) if multi else out_specs[0],
        out_shape=tuple(out_shape) if multi else out_shape[0],
        input_output_aliases={x_idx: 0},
        interpret=interpret,
    )(*inputs)

    if multi:
        mixed, ef_out = out
        return mixed[:, :D], ef_out[:, :D]
    return out[:, :D], None


def compressed_step_mix(params: PyTree, *, compressor,
                        ef_state: Optional[PyTree] = None, seed=0,
                        phase: str, topology: str = "ring", n_nodes: int,
                        step: int = 0, n_pods: int = 1, block_d: int = 2048,
                        interpret: Optional[bool] = None, comm_dtype=None):
    """Fused compressed communication round (DESIGN.md §2.3):
    ``mixed = x + (M·q − (1−d)⊙q)`` with ``q`` the compressed-wire
    estimate of ``x (+ ef)``, one HBM pass per leaf.

    Quantizer compressors (int8/fp8) fuse quantize → mix → dequantize
    in-register (the per-leaf scale is the one extra cheap reduction);
    sparsifiers precompute ``q`` via the reference codec and fuse the
    compensated mix.  Dispatch is always per-leaf — the scales, salts,
    and (for sparsifiers) selections are per-leaf, so the concat staging
    buffer of the uncompressed path would mix scales across leaves.

    Returns ``(mixed, new_ef_state)`` (``new_ef_state`` is None when
    ``ef_state`` is None).  Consensus-residual fusion deliberately does
    not compose with compression — callers fall back to
    ``train.state.consensus_distance`` (DESIGN.md §2.3).
    """
    if phase not in KERNEL_PHASES:
        raise ValueError(f"phase {phase!r} has no fused kernel "
                         f"(expected one of {KERNEL_PHASES})")
    interp = _default_interpret() if interpret is None else interpret
    d, M = phase_matrices(phase, topology, n_nodes, step=step, n_pods=n_pods)
    w = (1.0 - d).astype(np.float32)
    wj, Mj = jnp.asarray(w), jnp.asarray(M)
    kind = compressor.name if compressor.name in ("int8", "fp8") \
        else "precomputed"
    with_ef = ef_state is not None
    # global phase: the collective operand is uncompressed fp32 sums, so
    # comm_dtype still wire-casts the estimate (both occurrences; matches
    # _compressed_round_reference and the sharded psum — DESIGN.md §2.3)
    wire = phase == "global" and comm_dtype is not None
    if wire and jnp.dtype(comm_dtype) != jnp.dtype(jnp.bfloat16):
        # the kernel's wire cast is bf16 like _mix_kernel's; other dtypes
        # would silently diverge from the reference backend
        raise ValueError(
            f"compressed_step_mix: the fused kernel wire-casts to bfloat16 "
            f"only (got comm_dtype={jnp.dtype(comm_dtype)}); use "
            f"backend='reference' for other wire dtypes")

    return _compressed_leaf_loop(params, compressor, ef_state, seed, wj, Mj,
                                 kind=kind, wire=wire, block_d=block_d,
                                 interp=interp)


def _compressed_leaf_loop(params: PyTree, compressor, ef_state, seed,
                          wj: jax.Array, Mj: jax.Array, *, kind: str,
                          wire: bool, block_d: int, interp: bool):
    """Per-leaf dispatch of the compensated compressed round — shared by
    the phase-based (:func:`compressed_step_mix`) and runtime-dense-W
    (:func:`compressed_step_mix_dense`) entry points.  Dispatch must stay
    per-leaf: scales, salts, and sparsifier selections are per-leaf."""
    from repro import compress as compress_mod
    from repro.compress import quantize as cq

    with_ef = ef_state is not None
    leaves, treedef = jax.tree.flatten(params)
    n = leaves[0].shape[0]
    ef_leaves = jax.tree.flatten(ef_state)[0] if with_ef \
        else [None] * len(leaves)

    if kind == "precomputed":
        q_tree, new_ef = compress_mod.apply_tree(compressor, params,
                                                 ef_state, seed)
        q_leaves = jax.tree.leaves(q_tree)
    mixed_leaves, new_ef_leaves = [], []
    for i, (leaf, e) in enumerate(zip(leaves, ef_leaves)):
        x2 = leaf.reshape(n, -1).astype(jnp.float32)
        e2 = e.reshape(n, -1).astype(jnp.float32) if e is not None else None
        if kind == "precomputed":
            q2 = q_leaves[i].reshape(n, -1).astype(jnp.float32)
            mixed, _ = _cmix_flat(x2, None, q2, None, None, wj, Mj,
                                  kind=kind, with_ef=False, wire=wire,
                                  block_d=block_d, interpret=interp)
        else:
            y2 = x2 if e2 is None else x2 + e2
            scale = cq.int8_scale(y2) if kind == "int8" else cq.fp8_scale(y2)
            seed_i = compress_mod.leaf_seed(seed, i)
            mixed, ef_out = _cmix_flat(x2, e2, None, seed_i, scale, wj, Mj,
                                       kind=kind, with_ef=with_ef,
                                       wire=wire, block_d=block_d,
                                       interpret=interp)
            if with_ef:
                new_ef_leaves.append(ef_out.reshape(e.shape).astype(e.dtype))
        mixed_leaves.append(mixed.reshape(leaf.shape).astype(leaf.dtype))
    mixed_tree = jax.tree.unflatten(treedef, mixed_leaves)
    if not with_ef:
        return mixed_tree, None
    if kind == "precomputed":
        return mixed_tree, new_ef
    return mixed_tree, jax.tree.unflatten(treedef, new_ef_leaves)


def compressed_step_mix_dense(params: PyTree, *, W: jax.Array, compressor,
                              ef_state: Optional[PyTree] = None, seed=0,
                              n_nodes: int, block_d: int = 2048,
                              interpret: Optional[bool] = None):
    """Compensated compressed gossip round for a runtime dense ``W``
    (push-sum under faults — the dense-W analogue of
    :func:`compressed_step_mix`, same kernel body, factors built in the
    traced graph).

    ``mixed = x + (M·q − (1−d)⊙q)`` with ``d = diag(W)``, ``M = W −
    diag(W)``.  The correction is a weighted combination of a *shared*
    per-node quantity q, so any column-stochastic W conserves push-sum
    mass exactly like the uncompressed round does — the caller mixes the
    weight column outside this lossy codec (DESIGN.md §2.5).  Returns
    ``(mixed, new_ef_state)``.
    """
    interp = _default_interpret() if interpret is None else interpret
    Wj = jnp.asarray(W, jnp.float32)
    dj = jnp.diagonal(Wj).reshape(n_nodes, 1)
    wj = 1.0 - dj
    Mj = Wj - jnp.diag(jnp.diagonal(Wj))
    kind = compressor.name if compressor.name in ("int8", "fp8") \
        else "precomputed"
    # gossip wire semantics only — the push-sum global phase is never
    # compressed (DistConfig forbids it), so no wire flag here
    return _compressed_leaf_loop(params, compressor, ef_state, seed, wj, Mj,
                                 kind=kind, wire=False, block_d=block_d,
                                 interp=interp)


def _collective_kernel(*refs, kind: str, with_ef: bool, n_pods: int):
    """One ``qblock`` tile of the compressed-collective averaging round
    (DESIGN.md §2.3 "Compressed collectives"):

        q₁ = Q₁(x + e);  m̄ = q₁[pod,0] + mean(q₁ − q₁[pod,0]);
        o  = x + (Q₂(m̄)[pod] − Q₂(q₁));  e' = (x + e) − q₁

    entirely in-register — stage-1 and stage-2 codes never exist in HBM on
    the stacked path.  The kernel tile *is* the scale block (the grid walks
    D in ``qblock`` columns), so the per-tile row absmax is exactly the
    per-(row, block) scale of the reference
    (repro.compress.collective.quantize_blocks), and the random bits come
    from the same column hash — bit-identical rounding decisions.

    Ref order: [s1, s2, x, e?] → [o, ef?].
    """
    from repro.compress import base as cbase
    from repro.compress import collective as ccol
    from repro.compress import quantize as cq

    s1_ref, s2_ref, x_ref = refs[0], refs[1], refs[2]
    idx = 3
    if with_ef:
        e_ref = refs[idx]; idx += 1
    o_ref = refs[idx]; idx += 1
    if with_ef:
        ef_ref = refs[idx]; idx += 1

    x = x_ref[...].astype(jnp.float32)                       # (n, bd)
    y = x + e_ref[...].astype(jnp.float32) if with_ef else x
    n, bd = x.shape
    base = (pl.program_id(0) * bd).astype(jnp.uint32)
    cols = base + jax.lax.broadcasted_iota(jnp.uint32, (n, bd), 1)

    # power-of-two block scales (ccol.pow2_block_scale): every codec op is
    # exact or single-rounded, so this in-kernel instance and the
    # reference/sharded instances are bit-identical on equal inputs — the
    # bitwise consensus fixed point does not depend on fusion decisions
    if kind == "int8":
        def enc(v, seed, c):
            scale = ccol.pow2_block_scale(v, 7)
            u = cbase.uniform_columns(seed, c)
            return cq.int8_dequant(cq.int8_codes(v, scale, u), scale)
    else:
        def enc(v, seed, c):
            scale = ccol.pow2_block_scale(v, 8)
            bits = cbase.column_bits(seed, c)
            return cq.fp8_dequant(cq.fp8_codes(v, scale, bits), scale)

    q1 = enc(y, s1_ref[0, 0], cols)
    if with_ef:
        ef_ref[...] = (y - q1).astype(ef_ref.dtype)
    per = n // n_pods
    qp = q1.reshape(n_pods, per, bd)
    anchor = qp[:, 0]
    # anchored accumulate: a consensus tile passes through bitwise
    mbar = anchor + jnp.mean(qp - anchor[:, None], axis=1)   # (p, bd)
    r = enc(mbar, s2_ref[0, 0], cols[:n_pods])
    rho = enc(q1, s2_ref[0, 0], cols)
    r_rows = jnp.broadcast_to(r[:, None], (n_pods, per, bd)).reshape(n, bd)
    o_ref[...] = (x + (r_rows - rho)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "with_ef", "n_pods", "qblock", "interpret"))
def _collective_flat(xf: jax.Array, ef: Optional[jax.Array],
                     s1: jax.Array, s2: jax.Array, *, kind: str,
                     with_ef: bool, n_pods: int, qblock: int,
                     interpret: bool):
    """Run the collective kernel over the packed (n, D) matrix; the grid
    tile equals the scale block, so padding to a ``qblock`` multiple keeps
    block boundaries identical to the reference."""
    from repro.compress import collective as ccol

    n, D = xf.shape
    xf = ccol.pad_cols(xf, qblock)
    ef = ccol.pad_cols(ef, qblock)
    Dp = xf.shape[1]

    def tile(i):
        return (0, i)

    def scalar(i):
        return (0, 0)

    in_specs = [pl.BlockSpec((1, 1), scalar), pl.BlockSpec((1, 1), scalar),
                pl.BlockSpec((n, qblock), tile)]
    inputs = [jnp.asarray(s1).astype(jnp.uint32).reshape(1, 1),
              jnp.asarray(s2).astype(jnp.uint32).reshape(1, 1), xf]
    if with_ef:
        in_specs.append(pl.BlockSpec((n, qblock), tile))
        inputs.append(ef)

    out_shape = [jax.ShapeDtypeStruct((n, Dp), xf.dtype)]
    out_specs = [pl.BlockSpec((n, qblock), tile)]
    if with_ef:
        out_shape.append(jax.ShapeDtypeStruct((n, Dp), jnp.float32))
        out_specs.append(pl.BlockSpec((n, qblock), tile))

    out = pl.pallas_call(
        functools.partial(_collective_kernel, kind=kind, with_ef=with_ef,
                          n_pods=n_pods),
        grid=(Dp // qblock,),
        in_specs=in_specs,
        out_specs=tuple(out_specs) if with_ef else out_specs[0],
        out_shape=tuple(out_shape) if with_ef else out_shape[0],
        input_output_aliases={2: 0},
        interpret=interpret,
    )(*inputs)

    if with_ef:
        mixed, ef_out = out
        return mixed[:, :D], ef_out[:, :D]
    return out[:, :D], None


def collective_step_mix(params: PyTree, *, compressor,
                        ef_state: Optional[PyTree] = None, seed=0,
                        phase: str, n_nodes: int, n_pods: int = 1,
                        qblock: Optional[int] = None,
                        interpret: Optional[bool] = None):
    """Fused compressed global/pod-averaging round (DESIGN.md §2.3
    "Compressed collectives"): the packed ``(n, D)`` state goes through
    quantize → anchored accumulate → re-quantize → compensate in one HBM
    pass; int8/fp8 codes never hit HBM.  Unlike ``compressed_step_mix``
    dispatch is the *packed* matrix, not per-leaf — collective scales are
    per ``qblock`` column block, so leaf boundaries don't carry salts.

    Returns ``(mixed, new_ef_state)`` (``new_ef_state`` None when
    ``ef_state`` is None).
    """
    from repro.compress import collective as ccol

    if phase not in ("global", "pod_avg"):
        raise ValueError(f"collective_step_mix: phase {phase!r} is not an "
                         f"averaging round (expected 'global' or 'pod_avg')")
    pods = n_pods if phase == "pod_avg" else 1
    if n_nodes % max(pods, 1) or pods < 1:
        raise ValueError(f"collective_step_mix: n_pods={pods} does not "
                         f"divide n_nodes={n_nodes}")
    kind = compressor.name
    qb = ccol.QBLOCK if qblock is None else qblock
    interp = _default_interpret() if interpret is None else interpret

    xf, unflatten = flatten_nodes(params)
    with_ef = ef_state is not None
    ef_unflatten = None
    ef2 = None
    if with_ef:
        ef2, ef_unflatten = flatten_nodes(ef_state)
    s1, s2 = ccol.stage_seeds(seed)
    mixed, ef_out = _collective_flat(xf, ef2, s1, s2, kind=kind,
                                     with_ef=with_ef, n_pods=pods,
                                     qblock=qb, interpret=interp)
    return (unflatten(mixed),
            ef_unflatten(ef_out) if with_ef else None)


def _shard_cmix_kernel(x_ref, q_ref, qs_ref, w_ref, m_ref, o_ref):
    """Per-shard compensated compressed mix: ``x + (M_r·qs − w ⊙ q_self)``
    where ``qs`` stacks the locally rebuilt neighbor estimates (the
    compressed wire arrays were what crossed the ICI — see
    ``mixing._communicate_sharded_compressed``)."""
    x = x_ref[...].astype(jnp.float32)                       # (m, bd)
    q = q_ref[...].astype(jnp.float32)                       # (m, bd)
    qs = qs_ref[...].astype(jnp.float32)                     # (K·m, bd)
    corr = jnp.dot(m_ref[...], qs, preferred_element_type=jnp.float32) \
        - w_ref[...] * q
    o_ref[...] = (x + corr).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def shard_comp_mix_block(x: jax.Array, q_self: jax.Array, qs: jax.Array,
                         w: jax.Array, M: jax.Array, *, block_d: int = 2048,
                         interpret: Optional[bool] = None):
    """Compensated per-shard round over one ``(m, D)`` row-block (the
    compressed-wire analogue of :func:`shard_mix_block`; same aliasing
    contract on ``x``)."""
    interp = _default_interpret() if interpret is None else interpret
    m, D = x.shape
    K = qs.shape[0]
    bd = max(1, min(block_d, D))
    pad = (-D) % bd
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        q_self = jnp.pad(q_self, ((0, 0), (0, pad)))
        qs = jnp.pad(qs, ((0, 0), (0, pad)))
    Dp = D + pad

    def tile(i):
        return (0, i)

    in_specs = [pl.BlockSpec((m, bd), tile),
                pl.BlockSpec((m, bd), tile),
                pl.BlockSpec((K, bd), tile),
                pl.BlockSpec((m, 1), lambda i: (0, 0)),
                pl.BlockSpec((m, K), lambda i: (0, 0))]
    out = pl.pallas_call(
        _shard_cmix_kernel,
        grid=(Dp // bd,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((m, bd), tile),
        out_shape=jax.ShapeDtypeStruct((m, Dp), x.dtype),
        input_output_aliases={0: 0},
        interpret=interp,
    )(x, q_self, qs, w, M)
    return out[:, :D]


def _shard_mix_kernel(x_ref, xs_ref, d_ref, m_ref, *out_refs,
                      with_residual: bool):
    """One grid step of the per-shard mix: ``d ⊙ x + M · xs`` where ``x`` is
    this shard's (m, bd) tile and ``xs`` stacks the halo-exchanged neighbor
    blocks (already wire-cast by the caller).  With residual, also emits
    the shard's column sums of the mixed tile — the caller psums them into
    x̄.  (The consensus residual itself cannot be fused here: it needs the
    cross-shard x̄, and the cancellation-free form Σ‖x − x̄‖² requires a
    second local pass once the psum lands — see communicate_sharded.)"""
    o_ref = out_refs[0]
    x = x_ref[...].astype(jnp.float32)                       # (m, bd)
    xs = xs_ref[...].astype(jnp.float32)                     # (K·m, bd)
    mixed = jnp.dot(m_ref[...], xs, preferred_element_type=jnp.float32)
    mixed = mixed + d_ref[...] * x
    o_ref[...] = mixed.astype(o_ref.dtype)

    if with_residual:
        out_refs[1][...] = jnp.sum(mixed, axis=0,
                                   keepdims=True).astype(out_refs[1].dtype)


@functools.partial(jax.jit,
                   static_argnames=("with_residual", "block_d", "interpret"))
def shard_mix_block(x: jax.Array, xs: jax.Array, d: jax.Array, M: jax.Array,
                    *, with_residual: bool = False, block_d: int = 2048,
                    interpret: Optional[bool] = None):
    """Fused per-shard communication round over one ``(m, D)`` row-block.

    Called inside ``shard_map`` (repro.core.mixing.communicate_sharded):
    ``x`` is the shard's uncast local block, ``xs`` the ``(K·m, D)`` stack
    of halo blocks (self + ppermute-received neighbors, wire-cast), ``d``
    the shard's rows of the self-weight diagonal and ``M`` its
    ``(m, K·m)`` row-block of the mixing matrix restricted to the received
    blocks.  Returns the mixed ``(m, D)`` block; with residual also its
    ``(1, D)`` column sums (the shard-local partial of x̄).  The x input
    is aliased with the mixed output (same in-place contract as the
    stacked kernel).
    """
    interp = _default_interpret() if interpret is None else interpret
    m, D = x.shape
    K = xs.shape[0]
    bd = max(1, min(block_d, D))
    pad = (-D) % bd
    if pad:  # zero columns: contribute 0 to mix, column sums, and Σ‖·‖²
        x = jnp.pad(x, ((0, 0), (0, pad)))
        xs = jnp.pad(xs, ((0, 0), (0, pad)))
    Dp = D + pad

    def tile(i):
        return (0, i)

    in_specs = [pl.BlockSpec((m, bd), tile),
                pl.BlockSpec((K, bd), tile),
                pl.BlockSpec((m, 1), lambda i: (0, 0)),
                pl.BlockSpec((m, K), lambda i: (0, 0))]
    out_shape = [jax.ShapeDtypeStruct((m, Dp), x.dtype)]
    out_specs = [pl.BlockSpec((m, bd), tile)]
    if with_residual:
        out_shape.append(jax.ShapeDtypeStruct((1, Dp), jnp.float32))
        out_specs.append(pl.BlockSpec((1, bd), tile))

    out = pl.pallas_call(
        functools.partial(_shard_mix_kernel, with_residual=with_residual),
        grid=(Dp // bd,),
        in_specs=in_specs,
        out_specs=tuple(out_specs) if with_residual else out_specs[0],
        out_shape=tuple(out_shape) if with_residual else out_shape[0],
        input_output_aliases={0: 0},
        interpret=interp,
    )(x, xs, d, M)

    if with_residual:
        mixed, cs = out
        return mixed[:, :D], cs[:, :D]
    return out[:, :D]
