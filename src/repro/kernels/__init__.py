"""Pallas TPU kernels for the substrate's compute hot spots.

The paper's contribution is a communication schedule (no kernel-level
contribution), so kernels/ holds the attention + norm hot spots of the model
substrate (DESIGN.md §6): flash_attention.py, rmsnorm.py, with ops.py jit
wrappers and ref.py pure-jnp oracles.
"""
from repro.kernels.ops import (flash_attention_op, mlstm_chunk_op,  # noqa: F401
                               rmsnorm_op)
from repro.kernels.ref import (flash_attention_ref, mlstm_chunk_ref,  # noqa: F401
                               rmsnorm_ref)
