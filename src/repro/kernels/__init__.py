"""Pallas TPU kernels for the system's compute and communication hot spots.

Two families (DESIGN.md §6):

* **substrate kernels** — flash_attention.py, rmsnorm.py, mlstm_chunk.py:
  the attention/norm/recurrence hot spots of the model substrate, with
  ops.py jit wrappers and ref.py pure-jnp oracles.
* **mixing kernels** — mixing_pallas.py: the paper's own primitive
  (gossip mixing + periodic averaging, DESIGN.md §2.1) fused into
  single-pass kernels, selected via ``backend="pallas"`` on
  ``repro.core.mixing.communicate``.
"""
from repro.kernels.mixing_pallas import (fused_step_mix,  # noqa: F401
                                         global_average, mix_residual,
                                         pod_average)
from repro.kernels.ops import (flash_attention_op,  # noqa: F401
                               mlstm_chunk_op, rmsnorm_op)
from repro.kernels.ref import (flash_attention_ref,  # noqa: F401
                               mlstm_chunk_ref, rmsnorm_ref)
