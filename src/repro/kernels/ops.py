"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites work in this CPU
container (kernel body executed in Python) and compile to Mosaic on TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.mlstm_chunk import mlstm_chunk as _mlstm
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "block_q", "block_k", "interpret"))
def flash_attention_op(q, k, v, *, causal: bool = True,
                       window: Optional[int] = None,
                       softcap: Optional[float] = None,
                       scale: Optional[float] = None,
                       block_q: int = 128, block_k: int = 128,
                       interpret: Optional[bool] = None):
    interp = _default_interpret() if interpret is None else interpret
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  scale=scale, block_q=block_q, block_k=block_k,
                  interpret=interp)


@functools.partial(jax.jit, static_argnames=(
    "eps", "offset", "block_rows", "interpret"))
def rmsnorm_op(x, w, *, eps: float = 1e-6, offset: float = 0.0,
               block_rows: int = 256, interpret: Optional[bool] = None):
    interp = _default_interpret() if interpret is None else interpret
    return _rmsnorm(x, w, eps=eps, offset=offset, block_rows=block_rows,
                    interpret=interp)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunk_op(q, k, v, log_i, log_f, *, chunk: int = 64,
                   interpret: Optional[bool] = None):
    interp = _default_interpret() if interpret is None else interpret
    return _mlstm(q, k, v, log_i, log_f, chunk=chunk, interpret=interp)
