"""Sparsifying compressors: top-k (per-node magnitude selection) and
rand-k (shared random column subset).

Selection is per (node, leaf): each node keeps ``k`` of the leaf's ``D``
flattened elements and the rest are zero on the wire.  Unsent coordinates
are *not* rescaled (no D/k inflation): error feedback — not unbiasedness
per round — is the convergence mechanism for sparsified gossip, and
rescaling state (rather than gradient) payloads distorts the iterate.
Pair these with ``comm_error_feedback=True`` (DESIGN.md §2.3).

``randk`` draws its column subset from the shared per-step hash
(:func:`repro.compress.base.uniform_columns`), so every node keeps the
*same* columns — the indices never need to cross the wire (any receiver
can re-derive them from the step seed), and at a consensus state all
nodes transmit identical payloads, preserving the exact-fixed-point
property.  ``topk`` indices are data-dependent per node and do ride the
wire; ``jax.lax.top_k``'s deterministic tie-breaking keeps identical rows
selecting identical columns.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.compress.base import Compressor, LeafWire, uniform_columns


def _scatter_rows(vals: jax.Array, idx: jax.Array, d: int) -> jax.Array:
    """(rows, k) values + column indices ((rows, k) or broadcastable
    (1, k)) → dense (rows, d) with zeros."""
    rows = vals.shape[0]
    out = jnp.zeros((rows, d), jnp.float32)
    idx = jnp.broadcast_to(idx, vals.shape)
    return out.at[jnp.arange(rows)[:, None], idx].set(
        vals.astype(jnp.float32))


@dataclasses.dataclass(frozen=True)
class TopKCompressor(Compressor):
    """Keep each node's k largest-magnitude elements per leaf.
    Wire: k fp32 values + k int32 column indices per row."""
    name: str = "topk"
    lossy: bool = True
    k: int = 32

    def _k(self, d: int) -> int:
        return max(1, min(self.k, d))

    def compress_leaf(self, y2, seed):
        k = self._k(y2.shape[-1])
        _, idx = jax.lax.top_k(jnp.abs(y2), k)
        vals = jnp.take_along_axis(y2, idx, axis=-1)
        return LeafWire(payload=(vals,), aux=(idx.astype(jnp.int32),))

    def decompress_leaf(self, wire, d):
        return _scatter_rows(wire.payload[0], wire.aux[0], d)

    def wire_bytes(self, rows, d):
        return rows * self._k(d) * (4 + 4)      # values + indices


@dataclasses.dataclass(frozen=True)
class RandKCompressor(Compressor):
    """Keep a shared random subset of k columns per leaf, redrawn each
    step from the round seed.  Wire: k fp32 values per row (the indices
    are derivable from the seed on the receiver, so only a 4-byte count
    of index bytes is budgeted for the one-off seed exchange)."""
    name: str = "randk"
    lossy: bool = True
    k: int = 32

    def _k(self, d: int) -> int:
        return max(1, min(self.k, d))

    def _columns(self, seed, d: int) -> jax.Array:
        u = uniform_columns(seed, jnp.arange(d, dtype=jnp.uint32))
        return jax.lax.top_k(-u, self._k(d))[1].astype(jnp.int32)

    def compress_leaf(self, y2, seed):
        idx = self._columns(seed, y2.shape[-1])
        vals = jnp.take(y2, idx, axis=-1)
        # indices ride as a single (1, k) row — node-independent by
        # construction, so the sharded path replicates them instead of
        # ppermuting a per-row copy (wire_bytes budgets them once)
        return LeafWire(payload=(vals,), aux=(idx[None, :],))

    def decompress_leaf(self, wire, d):
        return _scatter_rows(wire.payload[0], wire.aux[0], d)

    def wire_bytes(self, rows, d):
        return rows * self._k(d) * 4 + self._k(d) * 4

    def wire_bytes_per_send(self, rows, d):
        # the shared indices are re-derived from the step seed on the
        # receiver (and ride replicated on the sharded path): only the
        # values cross per transmission
        return rows * self._k(d) * 4
