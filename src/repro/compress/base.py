"""Compressed-gossip wire subsystem — protocol, shared randomness, EF algebra.

The third axis of wire-traffic reduction (DESIGN.md §2.3): after gossip
replaces the all-reduce and ``comm_dtype`` halves the payload, lossy
compression shrinks what crosses the ICI another 4–8×.  A ``Compressor``
maps a node-stacked value to a compact wire representation (``LeafWire``)
and back; the mixing layer (core/mixing.py) applies the round in the
**self-compensated form**

    mixed = x + (M · q − (1 − d) ⊙ q),      q = decompress(compress(x + e))

so the node's own state never loses precision, the global node average is
preserved to fp rounding for any compressor (column sums of M equal
``1 − d`` for a doubly-stochastic W), and — because every node draws the
*same* per-step random bits (`shared randomness`, :func:`uniform_columns`)
— a constant state is an exact fixed point of the round under every
compressor: identical inputs quantize to identical ``q`` rows and the
correction cancels.

Per-node **error feedback** (EF / EF21-style residual memory) threads the
compression error back into the next round instead of dropping it:
``y = x + e``, ``wire = compress(y)``, ``e' = y − decompress(wire)``.  The
EF state lives in ``train.state.TrainState.ef_state`` and is updated by
the same ``compress`` call that produces the wire payload, matching the
``compress(x, state) -> (wire, state)`` contract below.

Compressors operate on one **leaf row-block** at a time: a ``(rows, D)``
fp32 matrix whose rows are per-node flattened leaf values.  Pytree
plumbing (per-leaf salts, EF threading, reassembly) lives in
:func:`apply_tree`; the Pallas fast path (kernels/mixing_pallas.py)
reuses the same per-element math via the helpers in quantize.py so the
two backends make bit-identical rounding decisions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


class LeafWire(NamedTuple):
    """Wire representation of one compressed leaf row-block.

    ``payload`` carries the bulk bytes (int8/fp8 codes, top-k values);
    ``aux`` the per-row metadata (scales, indices).  Both are pytrees of
    arrays with a leading node/row axis, so the sharded path can hand them
    straight to ``shard_map``/``ppermute`` — the payload bytes are exactly
    what crosses the ICI.
    """
    payload: Tuple[jax.Array, ...]
    aux: Tuple[jax.Array, ...]

    @property
    def nbytes(self) -> int:
        """Total bytes-on-wire of this leaf (payload + aux)."""
        return int(sum(int(np.prod(a.shape, dtype=np.int64))
                       * a.dtype.itemsize
                       for a in tuple(self.payload) + tuple(self.aux)))


@dataclasses.dataclass(frozen=True)
class Compressor:
    """Base compressor: subclasses override the leaf-level codec.

    ``lossy = False`` (identity) routes ``mixing.communicate`` to the
    exact pre-compression code path — bit-identical by construction.
    """
    name: str = "identity"
    lossy: bool = False

    # -- leaf-level codec ------------------------------------------------
    def compress_leaf(self, y2: jax.Array, seed: jax.Array) -> LeafWire:
        """``y2``: (rows, D) fp32; ``seed``: uint32 scalar (already salted
        per leaf).  Identity sends the values verbatim."""
        return LeafWire(payload=(y2,), aux=())

    def decompress_leaf(self, wire: LeafWire, d: int) -> jax.Array:
        """Reconstruct the (rows, d) fp32 estimate from the wire."""
        return wire.payload[0]

    # -- accounting ------------------------------------------------------
    def wire_bytes(self, rows: int, d: int) -> int:
        """Analytic bytes of one (rows, d) leaf's full wire representation
        (payload + all aux, matching ``LeafWire.nbytes``)."""
        return rows * d * 4

    def wire_bytes_per_send(self, rows: int, d: int) -> int:
        """Bytes that cross the interconnect per *transmission* of the
        leaf.  Differs from :meth:`wire_bytes` only when part of the wire
        is derivable on the receiver (randk's shared column indices) and
        so is never actually sent — the per-shift cost model
        (``round_wire_bytes``) uses this."""
        return self.wire_bytes(rows, d)

    # -- the ISSUE contract: compress(x, state) -> (wire, state) ---------
    def compress(self, y2: jax.Array, state: Optional[jax.Array],
                 seed: jax.Array) -> Tuple[LeafWire, Optional[jax.Array]]:
        """EF-aware leaf compression: feeds the residual ``state`` into the
        wire input and returns the updated residual.  ``state=None``
        disables error feedback (the compensated mixing form still keeps
        the self term exact)."""
        y = y2 if state is None else y2 + state
        wire = self.compress_leaf(y, seed)
        if state is None:
            return wire, None
        q = self.decompress_leaf(wire, y2.shape[-1])
        return wire, y - q


# ---------------------------------------------------------------------------
# Shared randomness: one counter-based hash, identical on every node and in
# both backends (reference jnp + Pallas kernel), parameterized only by
# (seed, leaf salt, element index).
# ---------------------------------------------------------------------------
_GOLDEN = np.uint32(0x9E3779B9)


def hash_u32(h: jax.Array) -> jax.Array:
    """32-bit avalanche (xorshift-multiply); uint32 in, uint32 out.  Plain
    jnp ops so it runs identically under jit, Pallas interpret mode, and
    Mosaic."""
    h = h.astype(jnp.uint32)
    h = (h ^ (h >> 16)) * np.uint32(0x7FEB352D)
    h = (h ^ (h >> 15)) * np.uint32(0x846CA68B)
    return h ^ (h >> 16)


def leaf_seed(seed: jax.Array, salt: int) -> jax.Array:
    """Per-leaf effective seed: fold the (traced) round seed with a static
    per-leaf salt.  Both backends iterate leaves in ``jax.tree`` order, so
    matching salts guarantee matching random bits."""
    s = jnp.asarray(seed).astype(jnp.uint32)
    return hash_u32(s + np.uint32(((salt + 1) * int(_GOLDEN)) & 0xFFFFFFFF))


def column_bits(seed: jax.Array, cols: jax.Array) -> jax.Array:
    """uint32 random bits per column index.  ``cols`` may be any shape of
    uint32 element indices (an ``arange`` on the reference path, a
    ``program_id``-offset iota inside the kernel); ``seed`` a uint32
    scalar from :func:`leaf_seed`.  Deliberately *node-independent*: every
    node rounds the same way, which is what makes a constant state an
    exact fixed point of the compressed round."""
    return hash_u32(cols.astype(jnp.uint32) ^ seed)


def uniform_columns(seed: jax.Array, cols: jax.Array) -> jax.Array:
    """U[0, 1) from the top 24 bits of :func:`column_bits` (fp32-exact)."""
    return (column_bits(seed, cols) >> 8).astype(jnp.float32) * np.float32(
        2.0 ** -24)


# ---------------------------------------------------------------------------
# Pytree plumbing
# ---------------------------------------------------------------------------
def _rows_view(leaf: jax.Array) -> jax.Array:
    return leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)


def compress_tree(comp: Compressor, x: PyTree, ef: Optional[PyTree],
                  seed: jax.Array):
    """Compress every leaf of a node-stacked pytree.

    Returns ``(wires, new_ef)``: ``wires`` is the list of per-leaf
    ``LeafWire`` in ``jax.tree`` leaf order (the order fixes each leaf's
    randomness salt), ``new_ef`` the updated error-feedback tree (or None
    when ``ef`` is None).
    """
    leaves, treedef = jax.tree.flatten(x)
    ef_leaves = jax.tree.flatten(ef)[0] if ef is not None else [None] * len(
        leaves)
    wires, new_ef = [], []
    for i, (leaf, e) in enumerate(zip(leaves, ef_leaves)):
        e2 = None if e is None else _rows_view(e)
        wire, e_new = comp.compress(_rows_view(leaf), e2, leaf_seed(seed, i))
        wires.append(wire)
        if e is not None:
            new_ef.append(e_new.reshape(e.shape).astype(e.dtype))
    ef_tree = jax.tree.unflatten(treedef, new_ef) if ef is not None else None
    return wires, ef_tree


def decompress_tree(comp: Compressor, wires, like: PyTree) -> PyTree:
    """Rebuild the (rows, D)-per-leaf estimate tree from per-leaf wires;
    leaves keep 2-D row-block shape (the mixing algebra consumes them
    flattened)."""
    leaves, treedef = jax.tree.flatten(like)
    out = [comp.decompress_leaf(w, int(np.prod(lf.shape[1:], dtype=np.int64)))
           for w, lf in zip(wires, leaves)]
    return jax.tree.unflatten(treedef, out)


def apply_tree(comp: Compressor, x: PyTree, ef: Optional[PyTree],
               seed: jax.Array):
    """``(q, new_ef)``: the decompressed wire estimate of ``x (+ ef)`` with
    leaves restored to their stacked shapes/dtypes-agnostic fp32 rows —
    the reference path's one-call compress→decompress."""
    wires, new_ef = compress_tree(comp, x, ef, seed)
    q2 = decompress_tree(comp, wires, x)
    q = jax.tree.map(lambda lf, q_: q_.reshape(lf.shape[0], *lf.shape[1:]),
                     x, q2)
    return q, new_ef


def init_ef_state(params: PyTree) -> PyTree:
    """Zero-initialized per-node error-feedback memory (fp32: the residual
    is the difference of fp32 wire inputs and must not re-quantize)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def tree_wire_bytes(comp: Compressor, x: PyTree) -> int:
    """Analytic bytes-on-wire for one compressed broadcast of ``x``."""
    return sum(comp.wire_bytes(lf.shape[0],
                               int(np.prod(lf.shape[1:], dtype=np.int64)))
               for lf in jax.tree.leaves(x))
