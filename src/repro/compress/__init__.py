"""Compressor registry + wire-bytes cost model (DESIGN.md §2.3).

``make_compressor`` resolves ``DistConfig.comm_compression`` into a
:class:`repro.compress.base.Compressor` (or None for the uncompressed
path); ``round_wire_bytes`` is the analytic bytes-on-wire model the
dry-run report and ``benchmarks/bench_compression.py`` share.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.compress.base import (Compressor, LeafWire, apply_tree,
                                 column_bits, compress_tree, decompress_tree,
                                 hash_u32, init_ef_state, leaf_seed,
                                 tree_wire_bytes, uniform_columns)
from repro.compress.collective import (COLLECTIVE_COMPRESSORS, QBLOCK,
                                       collective_wire_bytes)
from repro.compress.quantize import Fp8Compressor, Int8Compressor
from repro.compress.sparsify import RandKCompressor, TopKCompressor

__all__ = [
    "COLLECTIVE_COMPRESSORS", "COMPRESSORS", "Compressor", "LeafWire",
    "apply_tree", "collective_wire_bytes", "column_bits", "compress_tree",
    "decompress_tree", "hash_u32", "init_ef_state", "leaf_seed",
    "make_compressor", "round_wire_bytes", "tree_wire_bytes",
    "uniform_columns",
]

# "none": no compressor object, the hook is inert.  "identity": a real
# registry entry whose round is routed to the exact uncompressed code path
# (bit-identical; it exists so the plumbing itself is testable).
COMPRESSORS = ("none", "identity", "int8", "fp8", "topk", "randk")


def make_compressor(name: str, k: int = 32) -> Optional[Compressor]:
    """Resolve a ``DistConfig.comm_compression`` name.  ``k`` feeds the
    sparsifiers (elements kept per node per leaf, clipped to leaf size)."""
    if name == "none":
        return None
    if name == "identity":
        return Compressor()
    if name == "int8":
        return Int8Compressor()
    if name == "fp8":
        return Fp8Compressor()
    if name == "topk":
        return TopKCompressor(k=k)
    if name == "randk":
        return RandKCompressor(k=k)
    raise ValueError(f"unknown comm_compression {name!r} "
                     f"(expected one of {COMPRESSORS})")


def round_wire_bytes(phase: str, topology: str, n_nodes: int,
                     per_node_params: int, *, comm_dtype: str = "float32",
                     compression: str = "none", k: int = 32,
                     step: int = 0, n_pods: int = 1,
                     leaf_sizes=None, global_compression: str = "none",
                     model_shards: int = 1) -> int:
    """Per-node bytes crossing the interconnect for one communication
    round (the dry-run cost model; DESIGN.md §2.3).

    ``leaf_sizes`` — per-leaf flattened element counts — matters for the
    compressed payload: scales are per leaf and the sparsifiers keep ``k``
    elements *per leaf*, so collapsing the parameter vector into one leaf
    would understate their bytes by ~num_leaves×.  Without it the model
    treats the vector as a single leaf (fine for the quantizers).

    ``model_shards`` — the model-axis size of a 2-D ``(node, model)``
    mesh — turns the answer into **per-device** bytes: the sharded
    runtime column-slices the packed state (and the quantizer code
    arrays) over the model axis, so halo ppermutes, psum operands, and
    the collective's stage payloads each move ``1/model_shards`` of the
    columns per device (leaf columns are padded to the model grid, hence
    the per-leaf ceil).  Sparsifier payloads ride model-replicated
    (global index sets cannot column-slice) and are *not* divided;
    quantizer per-row scale words are likewise replicated across the
    model axis and stay whole.

    * gossip: one collective-permute per nonzero off-diagonal shift, each
      moving the (possibly compressed) per-node payload;
    * global / pod_avg: one (intra-pod) all-reduce of the full operand,
      counted as one operand's worth of bytes.  With a lossy
      ``global_compression`` the collective runs the compressed
      reduce-scatter → all-gather (repro.compress.collective) and the
      operand's worth becomes int8/fp8 codes + per-block scale exponents
      — the collective is *packed* (one operand spanning all leaves), so
      ``leaf_sizes`` does not split it;
    * pod_avg with only a lossy gossip ``compression``: the sharded path
      serves it with the compressed halo exchange — each node's payload
      reaches the other ``n/n_pods − 1`` pod members.
    """
    from repro.core import topology as topo

    elem = 2 if comm_dtype == "bfloat16" else 4
    comp = make_compressor(compression, k=k)
    lossy = comp is not None and comp.lossy
    quant = lossy and comp.name in ("int8", "fp8")
    glossy = global_compression in ("int8", "fp8")
    ms = max(int(model_shards), 1)
    sizes = list(leaf_sizes) if leaf_sizes else [per_node_params]
    # uncompressed operand columns per device: per-leaf padded to the
    # model grid (flatten_nodes_sharded), then 1/ms of each leaf
    dense_cols = sum(-(-d // ms) for d in sizes)
    # a sparsifier-compressed round runs model-replicated end to end
    # (kmq == 1 in _communicate_sharded_compressed), so even its
    # global-phase psum operand stays full width per device
    psum_cols = sum(sizes) if (lossy and not quant) else dense_cols
    if lossy:
        if quant and ms > 1:
            # code bytes slice over the model axis; the per-row scale
            # word (wire_bytes_per_send − d code bytes) stays whole
            payload = sum(-(-d // ms)
                          + int(comp.wire_bytes_per_send(1, d)) - d
                          for d in sizes)
        else:
            payload = sum(int(comp.wire_bytes_per_send(1, d))
                          for d in sizes)
    else:
        payload = None
    def collective_dev_bytes():
        # per-device stage payload: the packed operand splits into ms
        # model slices of whole QBLOCK blocks (the runtime pads so every
        # slice starts on a block boundary), each block one QBLOCK of
        # codes + one exponent byte.  The runtime's further padding of
        # each slice to k_node·QBLOCK segments is not modeled — at most
        # k_node−1 blocks of slack per device, negligible at production D.
        nb = -(-per_node_params // QBLOCK)
        nb_dev = -(-nb // ms)
        return nb_dev * (QBLOCK + 1)

    if phase == "global":
        if glossy:
            return collective_dev_bytes()
        return psum_cols * elem
    if phase == "pod_avg":
        if glossy:
            return collective_dev_bytes()
        if not lossy:
            return dense_cols * elem
        per = max(n_nodes // max(n_pods, 1), 1)
        return (per - 1) * payload
    if phase != "gossip" or topology == "disconnected" or n_nodes == 1:
        return 0
    if topology == "grid":
        shifts = sum(1 for s in topo.grid_shift_weights(n_nodes)
                     if s != (0, 0))
        elem = 4  # grid gossip ignores comm_dtype (mixing.mix_array_grid)
    else:
        shifts = sum(1 for s in topo.shift_weights(topology, n_nodes, step)
                     if s != 0)
    if not lossy:
        return shifts * dense_cols * elem
    return shifts * payload
