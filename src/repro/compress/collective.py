"""Compressed global/pod-averaging collective (DESIGN.md §2.3 "Compressed
collectives").

The periodic All-Reduce was the last uncompressed phase on the wire: gossip
and pod halos move int8/fp8 payloads (PR 3) while the PGA round still psums
an fp32/bf16 operand.  This module is the reference math for the compressed
replacement — a **chunked reduce-scatter → dequant-accumulate → all-gather**
collective over int8/fp8 blocks with per-(row, block) scales:

    stage 1 (reduce-scatter):  every node quantizes its operand ``y = x + e``
        blockwise (``QBLOCK`` columns per scale) and sends each column
        segment's codes+scales to the segment owner;
    accumulate:                the owner dequantizes and averages in fp32,
        **anchored at the first row**: ``m̄ = q₀ + mean(q − q₀)`` — the
        subtraction makes a consensus state survive the accumulate bitwise
        (mean of exact zeros is exactly zero), the compressed analogue of
        the cancellation-free consensus pass (§2.1);
    stage 2 (all-gather):      the owner re-quantizes the mean chunk and
        broadcasts codes+scales; receivers dequantize to ``r``.

The mixing layer applies the **self-compensated round**

    mixed = x + (r − ρ),        ρ = Q₂(q₁),   q₁ = Q₁(x + e)

where ``ρ`` is the node's *local* emulation of its own operand through both
quantization stages.  Because the random bits of each stage are keyed on
(stage seed, absolute column) — node-independent, same counter-hash as the
gossip compressors — identical inputs produce identical codes at every
stage, the anchored accumulate returns ``q₁`` bitwise, and ``r == ρ``:
a constant state is an **exact fixed point** (bitwise, stronger than the
psum path's ulp-level guarantee).  The node's own state enters at full
precision, and error feedback absorbs the stage-1 residual
``e' = (x + e) − q₁`` (the stage-2 error is common-mode across nodes and
unbiased over steps).  The price of compressing the collective: the node
*average* is preserved only to quantizer precision, not exactly — the
common stage-2 error shifts all nodes together (DESIGN.md §2.3).

Element-wise quantizer math is imported from :mod:`repro.compress.quantize`
verbatim, so the fused Pallas kernel
(:func:`repro.kernels.mixing_pallas.collective_step_mix`), this reference,
and the sharded ``all_to_all``/``all_gather`` runtime
(:func:`repro.core.mixing._communicate_sharded_collective`) make
bit-identical rounding decisions; parity reduces to fp reduction order.

Unlike the gossip compressors the collective operates on the **packed**
``(n, D)`` node-major matrix (``mixing_pallas.flatten_nodes`` layout):
scales are per ``QBLOCK``-column block, not per leaf, so one collective
covers the whole parameter vector and the block grid is identical on all
three backends.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import quantize as cq
from repro.compress.base import column_bits, hash_u32, leaf_seed, \
    uniform_columns

# Columns per scale block ("per-shard scales"): one uint8 exponent byte
# (scale_exponents — powers of two carry no mantissa) amortized over QBLOCK
# one-byte codes keeps the wire within 0.1% of exactly 4x vs fp32.
QBLOCK = 1024

# Compressors the collective supports: quantizers only.  Sparsifier payloads
# cannot ride a reduce-scatter (per-node index sets make the accumulate
# dense again and the gather stage saves nothing); configs/base.py mirrors
# this vocabulary for DistConfig.comm_global_compression.
COLLECTIVE_COMPRESSORS = ("none", "identity", "int8", "fp8")
_KINDS = ("int8", "fp8")

_STAGE2 = np.uint32(0x9E3779B9)


def stage_seeds(seed: jax.Array, salt: int = 0) -> Tuple[jax.Array, jax.Array]:
    """Decorrelated uint32 seeds for the two quantization stages of one
    round.  Both derive from the round seed through the shared counter
    hash, so every backend (and every node) draws the same bits."""
    s1 = leaf_seed(seed, salt)
    return s1, hash_u32(s1 ^ _STAGE2)


def pad_cols(x2: Optional[jax.Array], mult: int) -> Optional[jax.Array]:
    """Zero-pad the column axis to a multiple of ``mult``.  Zero columns
    quantize to zero codes at every stage (the block absmax ignores them
    and ``floor(0 + u) = 0``), so padding never leaks into real columns."""
    if x2 is None:
        return None
    pad = (-x2.shape[1]) % mult
    return jnp.pad(x2, ((0, 0), (0, pad))) if pad else x2


def pow2_block_scale(y2b: jax.Array, shift: int) -> jax.Array:
    """Per-(row, block) power-of-two scale ``2^(ceil(log2 absmax) − shift)``
    computed purely by exponent **bit manipulation** (no log/exp libm).

    Why powers of two, and why bit ops: the collective's fixed-point
    guarantee needs the stage-2 codec applied to ``q₁`` (locally, for ρ)
    and to ``m̄`` (possibly on another device, inside another fusion
    context, for r) to produce **bit-identical** results on equal inputs.
    XLA does not promise that two separately-fused instances of the same
    formula round identically (e.g. one instance's ``/127`` may be
    strength-reduced to a reciprocal multiply).  With a power-of-two
    scale every downstream op is either *exact* (scale division,
    dequantization multiply, integer hash) or a *single* IEEE-rounded op
    (``v + u``), so any compiler schedule computes the same bits — the
    same trick fp8 uses, extended to the int8 collective.  ``shift=7``
    lands int8 codes in (−128, 128] (clipped to ±127); ``shift=8`` lands
    fp8 operands within e4m3 range.  All-zero blocks map to scale 1.
    """
    m = jnp.max(jnp.abs(y2b), axis=-1, keepdims=True)
    bits = jax.lax.bitcast_convert_type(m, jnp.uint32)
    e = ((bits >> 23) & np.uint32(0xFF)).astype(jnp.int32)
    e = e + (bits & np.uint32(0x7FFFFF) != 0)        # ceil to next pow2
    sbits = jnp.clip(e - shift, 1, 254).astype(jnp.uint32) << 23
    scale = jax.lax.bitcast_convert_type(sbits, jnp.float32)
    return jnp.where(m > 0, scale, np.float32(1.0))


def scale_exponents(scales: jax.Array) -> jax.Array:
    """Pack power-of-two fp32 scales as one **uint8 biased exponent** per
    scale word — the wire form of the collective's scale payload.

    :func:`pow2_block_scale` guarantees every scale is positive with a
    zero mantissa and a biased exponent clipped to [1, 254], so the fp32
    word is pure exponent: the round trip through
    :func:`exponent_scales` is exact by construction (bit-twiddling only,
    no libm), and shipping 1 byte instead of 4 removes the residual scale
    overhead from the ``all_to_all``/``all_gather`` payloads without
    changing a single dequantized bit."""
    bits = jax.lax.bitcast_convert_type(scales.astype(jnp.float32),
                                        jnp.uint32)
    return (bits >> 23).astype(jnp.uint8)


def exponent_scales(exps: jax.Array) -> jax.Array:
    """Inverse of :func:`scale_exponents`: uint8 biased exponents → fp32
    power-of-two scales (bitcast of ``exp << 23``)."""
    return jax.lax.bitcast_convert_type(
        exps.astype(jnp.uint32) << np.uint32(23), jnp.float32)


def quantize_blocks(y2: jax.Array, kind: str, seed: jax.Array,
                    qblock: int = QBLOCK, col0=0):
    """Blockwise stochastic quantization of a ``(rows, Dp)`` fp32 matrix
    (``Dp`` a multiple of ``qblock``).

    Returns ``(codes, scales, q)``: ``codes`` the wire array (int8 or fp8,
    ``(rows, Dp)``), ``scales`` one fp32 word per ``(row, block)``
    (``(rows, Dp/qblock)``), ``q`` the dequantized fp32 estimate.  Random
    bits are keyed on ``col0 +`` the local column index — pass the absolute
    column offset when quantizing a segment of a wider matrix (the sharded
    stage-2) so all backends agree.  Scales are powers of two
    (:func:`pow2_block_scale`), making the codec's fp results independent
    of compiler fusion — the load-bearing fact behind the bitwise
    consensus fixed point.
    """
    if kind not in _KINDS:
        raise ValueError(f"collective.quantize_blocks: unsupported kind "
                         f"{kind!r} (expected one of {_KINDS})")
    rows, Dp = y2.shape
    if Dp % qblock:
        raise ValueError(f"collective.quantize_blocks: {Dp} columns not a "
                         f"multiple of qblock={qblock} (pad_cols first)")
    nb = Dp // qblock
    yb = y2.reshape(rows, nb, qblock)
    cols = (jnp.asarray(col0, jnp.uint32)
            + jnp.arange(Dp, dtype=jnp.uint32)).reshape(1, nb, qblock)
    if kind == "int8":
        scale = pow2_block_scale(yb, 7)                 # (rows, nb, 1)
        codes = cq.int8_codes(yb, scale, uniform_columns(seed, cols))
        q = cq.int8_dequant(codes, scale)
        wire = codes.astype(jnp.int8)
    else:
        scale = pow2_block_scale(yb, 8)
        codes = cq.fp8_codes(yb, scale, column_bits(seed, cols))
        q = cq.fp8_dequant(codes, scale)
        wire = codes
    return (wire.reshape(rows, Dp), scale.reshape(rows, nb),
            q.reshape(rows, Dp))


def dequant_blocks(codes: jax.Array, scales: jax.Array,
                   qblock: int = QBLOCK) -> jax.Array:
    """Inverse of :func:`quantize_blocks`' wire arrays → fp32 estimate."""
    rows, Dp = codes.shape
    nb = Dp // qblock
    return (codes.astype(jnp.float32).reshape(rows, nb, qblock)
            * scales.reshape(rows, nb, 1)).reshape(rows, Dp)


def anchored_mean(q1: jax.Array, n_pods: int = 1) -> jax.Array:
    """Per-pod dequant-accumulate ``m̄_p = q_{p,0} + mean(q_p − q_{p,0})``
    over the ``(n, Dp)`` stage-1 estimates → ``(n_pods, Dp)``.  Anchoring at
    the pod's first row makes a consensus state pass through bitwise (the
    mean of exact zeros is exactly zero)."""
    n, Dp = q1.shape
    per = n // n_pods
    qp = q1.reshape(n_pods, per, Dp)
    anchor = qp[:, 0]
    return anchor + jnp.mean(qp - anchor[:, None], axis=1)


def collective_mean(y2: jax.Array, kind: str, seed: jax.Array, *,
                    n_pods: int = 1, qblock: int = QBLOCK):
    """Reference two-stage compressed mean of a ``(n, D)`` operand block.

    Returns ``(r, rho, q1)`` trimmed back to ``D`` columns: ``r`` the
    broadcast mean estimate expanded to per-row ``(n, D)`` (each row its
    pod's stage-2 estimate), ``rho`` the row's own operand through both
    stages, ``q1`` the stage-1 estimate (whose residual feeds EF).
    """
    n, D = y2.shape
    yp = pad_cols(y2, qblock)
    s1, s2 = stage_seeds(seed)
    _, _, q1 = quantize_blocks(yp, kind, s1, qblock)
    mbar = anchored_mean(q1, n_pods)
    _, _, r = quantize_blocks(mbar, kind, s2, qblock)
    _, _, rho = quantize_blocks(q1, kind, s2, qblock)
    per = n // n_pods
    r_rows = jnp.broadcast_to(r[:, None], (n_pods, per, r.shape[1]))
    r_rows = r_rows.reshape(n, -1)
    return r_rows[:, :D], rho[:, :D], q1[:, :D]


def collective_round(x2: jax.Array, e2: Optional[jax.Array], kind: str,
                     seed: jax.Array, *, n_pods: int = 1,
                     qblock: int = QBLOCK):
    """One compensated compressed-averaging round on the packed ``(n, D)``
    block: ``mixed = x + (r − ρ)``, EF residual ``e' = (x + e) − q₁``.
    Returns ``(mixed, new_e)`` (``new_e`` None when ``e2`` is None).  This
    is the oracle the fused kernel and the sharded runtime are tested
    against."""
    y2 = x2 if e2 is None else x2 + e2
    r, rho, q1 = collective_mean(y2, kind, seed, n_pods=n_pods,
                                 qblock=qblock)
    mixed = x2 + (r - rho)
    new_e = None if e2 is None else y2 - q1
    return mixed, new_e


def collective_wire_bytes(kind: str, d: int, qblock: int = QBLOCK) -> int:
    """Analytic per-node bytes-on-wire for one compressed-collective round
    over a ``d``-element operand — one operand's worth of stage-1 payload
    (codes + one uint8 exponent per power-of-two block scale,
    :func:`scale_exponents`), the same accounting convention as the
    uncompressed model's ``d · elem`` for the psum (round_wire_bytes)."""
    if kind not in _KINDS:
        raise ValueError(f"collective_wire_bytes: unsupported kind {kind!r}")
    nb = -(-d // qblock)
    return nb * qblock * 1 + nb * 1
