"""Stochastic-rounding quantizers: int8 and fp8 (e4m3) with per-leaf,
per-node scales.

The element-wise math lives in small pure functions (`int8_codes`,
`fp8_codes`, …) shared verbatim by the reference compressor below and the
fused Pallas kernel (kernels/mixing_pallas.py) — both backends therefore
make bit-identical rounding decisions, and parity between them reduces to
the mixing matmul's fp associativity (DESIGN.md §2.3).

Randomness comes from :func:`repro.compress.base.column_bits`, keyed on
(round seed, leaf salt, element column) and deliberately independent of
the node index: all nodes round identically, which makes a constant state
an exact fixed point of the compressed round.  The seed varies per
training step, so the rounding is unbiased across steps.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress.base import (Compressor, LeafWire, column_bits,
                                 uniform_columns)

# fp8 e4m3fn: 3 mantissa bits, max finite 448.  Stochastic rounding keeps
# the top 3 fp32 mantissa bits after adding random low bits (carry performs
# the round-up); _FP8_DROP is how many fp32 mantissa bits get dropped.
_FP8_MAX = np.float32(448.0)
_FP8_DROP = 23 - 3
_FP8_MASK = np.uint32((1 << _FP8_DROP) - 1)
_LOG2_FP8_MAX = float(np.log2(448.0))


# ---------------------------------------------------------------------------
# int8: symmetric absmax scale, stochastic floor
# ---------------------------------------------------------------------------
def int8_scale(y2: jax.Array) -> jax.Array:
    """(rows, 1) per-row scale so codes land in [−127, 127]; an all-zero
    row maps to scale 1 (codes 0 → exact zero round-trip)."""
    m = jnp.max(jnp.abs(y2), axis=-1, keepdims=True)
    return jnp.where(m > 0, m / np.float32(127.0), np.float32(1.0))


def int8_codes(y2: jax.Array, scale: jax.Array, u: jax.Array) -> jax.Array:
    """Stochastically rounded integer codes as fp32 values in [−127, 127]
    (``floor(v + u)`` is exact on integer ``v``, so values already on the
    grid — constants included — round-trip bit-exactly)."""
    v = y2 / scale
    return jnp.clip(jnp.floor(v + u), -127.0, 127.0)


def int8_dequant(codes: jax.Array, scale: jax.Array) -> jax.Array:
    return codes.astype(jnp.float32) * scale


@dataclasses.dataclass(frozen=True)
class Int8Compressor(Compressor):
    """8-bit stochastic quantization, per-(node, leaf) absmax scale.
    Wire: int8 codes + one fp32 scale per row → ~4× fewer bytes than fp32
    (the acceptance ratio in bench_compression)."""
    name: str = "int8"
    lossy: bool = True

    def compress_leaf(self, y2, seed):
        cols = jnp.arange(y2.shape[-1], dtype=jnp.uint32)[None, :]
        scale = int8_scale(y2)
        codes = int8_codes(y2, scale, uniform_columns(seed, cols))
        return LeafWire(payload=(codes.astype(jnp.int8),), aux=(scale,))

    def decompress_leaf(self, wire, d):
        return int8_dequant(wire.payload[0], wire.aux[0])

    def wire_bytes(self, rows, d):
        return rows * d * 1 + rows * 4          # codes + per-row scale


# ---------------------------------------------------------------------------
# fp8 (e4m3): power-of-two scale, mantissa-bit stochastic rounding
# ---------------------------------------------------------------------------
def fp8_scale(y2: jax.Array) -> jax.Array:
    """(rows, 1) power-of-two scale with ``absmax/scale ≤ 448``.  A
    power of two makes the scale division/multiplication exact in fp32,
    so the only loss is the mantissa truncation itself."""
    m = jnp.max(jnp.abs(y2), axis=-1, keepdims=True)
    e = jnp.ceil(jnp.log2(jnp.maximum(m, np.float32(1e-30)))
                 - np.float32(_LOG2_FP8_MAX))
    e = jnp.clip(e, -100.0, 100.0)
    return jnp.where(m > 0, jnp.exp2(e), np.float32(1.0))


def fp8_codes(y2: jax.Array, scale: jax.Array, bits: jax.Array) -> jax.Array:
    """Stochastically rounded e4m3 codes (returned as the fp8 array that
    goes on the wire).  SR by the mantissa-bit trick: add random low bits,
    truncate to the 3-bit grid (the carry rounds up with probability equal
    to the dropped fraction; magnitudes round away from zero), then cast —
    exact for normals, round-to-nearest on the fp8 denormal tail."""
    v = jnp.clip(y2 / scale, -_FP8_MAX, _FP8_MAX)
    b = jax.lax.bitcast_convert_type(v.astype(jnp.float32), jnp.uint32)
    b = (b + (bits & _FP8_MASK)) & ~_FP8_MASK
    f = jax.lax.bitcast_convert_type(b, jnp.float32)
    return jnp.clip(f, -_FP8_MAX, _FP8_MAX).astype(jnp.float8_e4m3fn)


def fp8_dequant(codes: jax.Array, scale: jax.Array) -> jax.Array:
    return codes.astype(jnp.float32) * scale


@dataclasses.dataclass(frozen=True)
class Fp8Compressor(Compressor):
    """fp8 (e4m3) stochastic quantization, per-(node, leaf) power-of-two
    scale.  Wire: fp8 codes + one fp32 scale per row."""
    name: str = "fp8"
    lossy: bool = True

    def compress_leaf(self, y2, seed):
        cols = jnp.arange(y2.shape[-1], dtype=jnp.uint32)[None, :]
        scale = fp8_scale(y2)
        codes = fp8_codes(y2, scale, column_bits(seed, cols))
        return LeafWire(payload=(codes,), aux=(scale,))

    def decompress_leaf(self, wire, d):
        return fp8_dequant(wire.payload[0], wire.aux[0])

    def wire_bytes(self, rows, d):
        return rows * d * 1 + rows * 4
