"""Span tracing: host-side timed spans with optional device fencing and
Chrome-trace-event export (DESIGN.md §2.7).

A :class:`Tracer` collects ``"X"`` (complete) events from ``with
tracer.span("comm/issue")`` blocks.  Spans measure *host* wall-clock by
default — under JAX's async dispatch that is dispatch time, not device
time.  Fencing closes the gap: ``span(...)`` yields a handle whose
``fence(value)`` registers a jax value to ``block_until_ready`` at span
exit, either always (``fence="always"``) or only when the tracer was
built with ``fence=True`` (the ``--trace-fence`` flag; ``fence="auto"``,
the default).  Unfenced spans are nearly free; fenced spans serialize
the pipeline they measure — that trade is the point of the flag.

:func:`to_chrome` emits the Chrome trace-event JSON format
(``{"traceEvents": [{"ph": "X", "ts": µs, "dur": µs, ...}]}``), which
loads directly in Perfetto / ``chrome://tracing``.  Nesting is implied
by time containment per (pid, tid) track, so properly nested host spans
render as a flame graph with no extra bookkeeping.

:func:`fenced_time` is the one fenced-timer helper shared by
``benchmarks/common.time_fn`` and the telemetry layer, so BENCH rows and
telemetry spans are the same numbers.
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, Iterator, List, Optional

MAX_EVENTS = 1 << 16   # ring-bounded: long runs keep the newest spans


class _SpanHandle:
    """Yielded by :meth:`Tracer.span`; lets the block attach result
    values to fence on and extra args recorded into the event."""

    __slots__ = ("value", "mode", "args")

    def __init__(self, args: Dict[str, Any]):
        self.value = None
        self.mode = "auto"
        self.args = args

    def fence(self, value: Any, mode: str = "auto") -> Any:
        """Register ``value`` to ``jax.block_until_ready`` at span exit.
        ``mode``: "auto" fences only when the tracer has fencing on
        (``--trace-fence``); "always" fences unconditionally; "never"
        drops a previously registered value.  Returns ``value``."""
        self.value = value if mode != "never" else None
        self.mode = mode
        return value


class Tracer:
    """Collects timed span events; thread-safe; export via
    :meth:`to_chrome` / :meth:`save`."""

    def __init__(self, fence: bool = False, max_events: int = MAX_EVENTS):
        self.fence = fence
        self.events: deque = deque(maxlen=max_events)
        self._origin = time.perf_counter()
        self._lock = threading.Lock()
        self._tids: Dict[int, int] = {}

    # ------------------------------------------------------------------
    def _tid(self) -> int:
        ident = threading.get_ident()
        with self._lock:
            if ident not in self._tids:
                self._tids[ident] = len(self._tids)
            return self._tids[ident]

    @contextlib.contextmanager
    def span(self, name: str, **args) -> Iterator[_SpanHandle]:
        """Time a block as one complete ("X") event.  ``args`` become the
        event's ``args`` payload (shown on click in Perfetto)."""
        handle = _SpanHandle(dict(args))
        t0 = time.perf_counter()
        try:
            yield handle
        finally:
            if handle.value is not None and (
                    handle.mode == "always" or self.fence):
                import jax
                jax.block_until_ready(handle.value)
            t1 = time.perf_counter()
            self.events.append({
                "name": name,
                "t0": t0 - self._origin,
                "dur": t1 - t0,
                "tid": self._tid(),
                "args": handle.args,
            })

    def add_event(self, name: str, t0: float, dur: float,
                  **args) -> None:
        """Record an externally timed span (``t0`` in perf_counter
        seconds — e.g. from :func:`fenced_time`'s inner loop)."""
        self.events.append({"name": name, "t0": t0 - self._origin,
                            "dur": dur, "tid": self._tid(),
                            "args": dict(args)})

    # ------------------------------------------------------------------
    def to_chrome(self) -> Dict[str, Any]:
        """Chrome trace-event JSON (Perfetto / about:tracing loadable)."""
        return {
            "displayTimeUnit": "ms",
            "traceEvents": [
                {"name": e["name"], "ph": "X", "pid": 0, "tid": e["tid"],
                 "ts": round(e["t0"] * 1e6, 3),
                 "dur": round(e["dur"] * 1e6, 3),
                 "cat": e["name"].split("/", 1)[0],
                 "args": e["args"]}
                for e in self.events],
        }

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


# ---------------------------------------------------------------------------
# The shared fenced timer (benchmarks/common.time_fn delegates here)
# ---------------------------------------------------------------------------
def fenced_time(fn: Callable, *args, iters: int = 10, warmup: int = 2,
                name: Optional[str] = None,
                tracer: Optional[Tracer] = None, **kwargs) -> float:
    """Median wall-clock **microseconds** per call, each call fenced with
    ``jax.block_until_ready`` — the one timing loop benchmarks and the
    telemetry layer share.  With ``tracer`` (and ``name``) every timed
    call is also recorded as a span, so BENCH rows and trace timelines
    come from the same measurements."""
    import jax
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn(*args, **kwargs))
    times: List[float] = []
    for i in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        dt = time.perf_counter() - t0
        times.append(dt)
        if tracer is not None and name is not None:
            tracer.add_event(name, t0, dt, iter=i)
    times.sort()
    return times[len(times) // 2] * 1e6


@contextlib.contextmanager
def jax_profiler_trace(logdir: str) -> Iterator[None]:
    """Thin wrapper over ``jax.profiler.trace`` (TensorBoard-viewable XLA
    profile) that degrades to a no-op when the profiler is unavailable
    (e.g. a second concurrent trace, or a stripped jaxlib)."""
    import jax
    try:
        with jax.profiler.trace(logdir):
            yield
    except Exception as e:  # profiler double-start, missing backend, ...
        import warnings
        warnings.warn(f"obs.jax_profiler_trace: profiler unavailable "
                      f"({e}); continuing without an XLA profile")
        yield
