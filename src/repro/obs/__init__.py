"""Observability layer (DESIGN.md §2.7): structured telemetry records,
span tracing with Chrome-trace export, and comm-round byte meters.

    from repro import obs

    tel = obs.Telemetry(sinks=[obs.JsonlSink("run.jsonl"), obs.RingSink()])
    with obs.telemetry_scope(tel):
        ...                         # mixing rounds self-report comm_round
        tel.emit("step", step=k, phase="gossip", loss=0.7)
        with tel.span("comm/issue") as sp:
            sp.fence(mixing.start_round(...))
    tel.tracer.save("trace.json")   # load in Perfetto
"""
from repro.obs import meters
from repro.obs.telemetry import (RECORD_TYPES, SCHEMA_VERSION,
                                 JsonlSink, PrettySink, RingSink, Sink,
                                 Telemetry, get_telemetry, set_telemetry,
                                 telemetry_scope)
from repro.obs.trace import Tracer, fenced_time, jax_profiler_trace

__all__ = [
    "JsonlSink", "PrettySink", "RingSink", "RECORD_TYPES",
    "SCHEMA_VERSION", "Sink", "Telemetry", "Tracer", "fenced_time",
    "get_telemetry", "jax_profiler_trace", "meters", "set_telemetry",
    "telemetry_scope",
]
