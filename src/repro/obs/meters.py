"""Comm-round meters: byte accounting + pipeline occupancy
(DESIGN.md §2.7).

Every ``comm_round`` record carries two independent byte figures:

* ``analytic_bytes`` — ``compress.round_wire_bytes``, the pure
  config-level cost model (what the dry-run / design docs quote);
* ``measured_bytes`` — recomputed here from the **live** round: actual
  leaf shapes and dtypes of the pytree entering the round, the actual
  compressor objects, and (on the sharded lossy path) the packed wire
  arrays themselves.

The two agreeing is the cross-check: the cost model has config-math
inputs (declared dims, declared dtype) while the meter sees what the
runtime actually built (padding, casts, per-leaf wire layouts) — a
divergence is a bug in one of them (this is exactly how PR 5's
column-padding mismatch would have surfaced).

Byte figures are **per node per round** (per device when
``model_shards > 1``), matching ``round_wire_bytes`` semantics.

Occupancy: for an overlapped pipeline (DESIGN.md §2.6), the fraction of
the synchronous round's cost actually hidden under compute::

    occupancy = clip(1 - max(0, t_step_overlap - t_compute) / t_comm_sync,
                     0, 1)

1.0 = the overlapped step costs no more than bare compute (comm fully
hidden); 0.0 = the full synchronous round cost is still visible.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

PyTree = Any


def _itemsize(dtype) -> int:
    import numpy as np
    return int(np.dtype(dtype).itemsize)


def _arr_nbytes(a) -> int:
    """Bytes of an array-like from shape/dtype (works on jax tracers,
    which have no .nbytes)."""
    size = 1
    for s in a.shape:
        size *= int(s)
    return size * _itemsize(a.dtype)


def per_node_leaf_sizes(params: PyTree, n_nodes: int) -> List[int]:
    """Per-node flattened element count of each leaf, from live shapes
    (a leading axis of size ``n_nodes`` is the stacked node axis)."""
    import jax
    sizes = []
    for leaf in jax.tree.leaves(params):
        shape = tuple(leaf.shape)
        dims = shape[1:] if (shape and shape[0] == n_nodes) else shape
        per = 1
        for s in dims:
            per *= int(s)
        sizes.append(per)
    return sizes


def round_sends(phase: str, topology: str, n_nodes: int,
                step: int = 0) -> int:
    """Number of payload transmissions in one round: nonzero off-diagonal
    shifts for gossip (one collective-permute each), 1 for the averaging
    collectives, 0 when no bytes move."""
    if n_nodes <= 1 or phase == "none":
        return 0
    if phase in ("global", "pod_avg"):
        return 1
    if phase != "gossip" or topology == "disconnected":
        return 0
    from repro.core import topology as topo
    if topology == "grid":
        return sum(1 for s in topo.grid_shift_weights(n_nodes)
                   if s != (0, 0))
    return sum(1 for s in topo.shift_weights(topology, n_nodes, step)
               if s != 0)


def measured_round_bytes(params: PyTree, *, phase: str, topology: str,
                         n_nodes: int, step: int = 0, n_pods: int = 1,
                         comm_dtype=None, compressor=None,
                         global_compressor=None, model_shards: int = 1,
                         wires=None) -> int:
    """Per-node (per-device when ``model_shards > 1``) wire bytes of one
    round, derived from the live pytree / wire arrays — see the module
    docstring for how this differs from ``round_wire_bytes``."""
    import jax
    leaves = jax.tree.leaves(params)
    n, ms = n_nodes, max(int(model_shards), 1)
    if not leaves or n <= 1 or phase == "none":
        return 0
    sizes = per_node_leaf_sizes(params, n)
    elems = [(_itemsize(comm_dtype) if comm_dtype is not None
              else _itemsize(leaf.dtype)) for leaf in leaves]
    if phase == "gossip" and topology == "grid":
        elems = [4] * len(elems)   # mix_array_grid ignores comm_dtype
    lossy = compressor is not None and compressor.lossy
    quant = lossy and compressor.name in ("int8", "fp8")
    glossy = (global_compressor is not None and global_compressor.lossy)
    sends = round_sends(phase, topology, n, step)

    if phase in ("global", "pod_avg") and glossy:
        # compressed reduce-scatter -> all-gather: whole QBLOCK blocks of
        # codes + one exponent byte each, model-sliced on block boundaries
        from repro.compress import QBLOCK
        nb = -(-sum(sizes) // QBLOCK)
        return (-(-nb // ms)) * (QBLOCK + 1)

    if wires is not None:
        # sharded lossy path: the packed wire arrays ARE the payload —
        # sum their bytes (leading stacked node axis -> per node)
        per_send = 0
        for w in wires:
            payload = w["payload"] if isinstance(w, dict) else w.payload
            aux = w["aux"] if isinstance(w, dict) else w.aux
            for a in tuple(payload) + tuple(aux):
                per_send += _arr_nbytes(a) // (n if a.shape
                                               and a.shape[0] == n else 1)
        if phase == "pod_avg":
            return (max(n // max(n_pods, 1), 1) - 1) * per_send
        return sends * per_send

    if lossy and phase in ("gossip", "pod_avg"):
        if quant and ms > 1:
            # code bytes column-slice over the model axis; the per-row
            # scale word (wire_bytes_per_send - d code bytes) stays whole
            per_send = sum(-(-d // ms)
                           + int(compressor.wire_bytes_per_send(1, d)) - d
                           for d in sizes)
        else:
            per_send = sum(int(compressor.wire_bytes_per_send(1, d))
                           for d in sizes)
        if phase == "pod_avg":
            return (max(n // max(n_pods, 1), 1) - 1) * per_send
        return sends * per_send

    if phase == "global" and lossy and not quant:
        # sparsifier rounds run model-replicated end to end: the global
        # psum operand stays full width per device
        return sum(s * e for s, e in zip(sizes, elems))
    # dense operand, column-sliced over the model axis per leaf
    return sends * sum((-(-s // ms)) * e for s, e in zip(sizes, elems))


def comm_round_fields(params: PyTree, *, phase: str, topology: str,
                      n_nodes: int, step: int = 0, n_pods: int = 1,
                      backend: str = "reference", sharded: bool = False,
                      comm_dtype=None, compressor=None,
                      global_compressor=None, model_shards: int = 1,
                      wires=None, role: str = "round") -> Dict[str, Any]:
    """Build one ``comm_round`` record's fields: tags + analytic bytes
    (``round_wire_bytes``) + measured bytes (live tree/wires)."""
    import jax
    import numpy as np
    from repro.compress import round_wire_bytes
    sizes = per_node_leaf_sizes(params, n_nodes)
    comp_name = compressor.name if compressor is not None else "none"
    gcomp_name = (global_compressor.name
                  if global_compressor is not None else "none")
    dtype_name = (np.dtype(comm_dtype).name if comm_dtype is not None
                  else "float32")
    analytic = round_wire_bytes(
        phase, topology, n_nodes, sum(sizes), comm_dtype=dtype_name,
        compression=comp_name, k=getattr(compressor, "k", 32), step=step,
        n_pods=n_pods, leaf_sizes=sizes, global_compression=gcomp_name,
        model_shards=model_shards)
    measured = measured_round_bytes(
        params, phase=phase, topology=topology, n_nodes=n_nodes,
        step=step, n_pods=n_pods, comm_dtype=comm_dtype,
        compressor=compressor, global_compressor=global_compressor,
        model_shards=model_shards, wires=wires)
    leaves = jax.tree.leaves(params)
    traced = bool(leaves) and isinstance(leaves[0], jax.core.Tracer)
    return {
        "phase": phase, "role": role, "shift": int(step),
        "topology": topology, "backend": backend, "sharded": bool(sharded),
        "n_nodes": int(n_nodes), "n_pods": int(n_pods),
        "model_shards": int(model_shards), "comm_dtype": dtype_name,
        "compression": comp_name, "global_compression": gcomp_name,
        "sends": round_sends(phase, topology, n_nodes, step),
        "analytic_bytes": int(analytic), "measured_bytes": int(measured),
        "traced": traced,
    }


def occupancy(t_compute_s: float, t_comm_sync_s: float,
              t_step_overlap_s: float) -> float:
    """Fraction of the synchronous comm cost hidden under compute by the
    overlapped pipeline (see module docstring)."""
    if t_comm_sync_s <= 0.0:
        return 1.0
    visible = max(0.0, t_step_overlap_s - t_compute_s)
    return max(0.0, min(1.0, 1.0 - visible / t_comm_sync_s))
