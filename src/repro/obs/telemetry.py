"""Telemetry hub: typed records fanned out to pluggable sinks
(DESIGN.md §2.7).

One :class:`Telemetry` object is the process's metric bus.  Producers —
the Trainer loop, ``simulate``, the mixing-round meters, the serving
engine — call ``tel.emit(<type>, **fields)``; every record is stamped
with the schema version and a wall-clock timestamp and forwarded to each
sink.  Record types and their required fields::

    step       {step, phase}    one training-step log point (loss, lr,
                                consensus, grad_norm, mass, ... ride as
                                free-form numeric fields)
    comm_round {phase, role}    one communication round's byte/latency
                                accounting (obs.meters); role is
                                "round" | "issue" | "apply" | "flush" |
                                "occupancy"
    flush      {step, phase}    an overlap pipeline flush at a period
                                boundary
    fault      {step, kind}     a FaultSchedule event (kind "drop" /
                                "rejoin", nodes=[...])
    ckpt       {step}           a checkpoint write
    serve_req  {uid, latency_s} one retired serving request

Sinks: :class:`JsonlSink` (one JSON object per line), :class:`RingSink`
(bounded in-memory deque — ``Trainer.history`` is a view over it), and
:class:`PrettySink` (the stdout pretty-printer that subsumes the old
``Trainer.run`` print path).

Host-sync discipline: the hub never implicitly transfers device values.
Producers hold device scalars and materialize them through
:meth:`Telemetry.fetch` — one *explicit*, counted ``jax.device_get`` per
log boundary (``tel.host_fetches`` is the regression-test counter for
the zero-per-step-sync guarantee).

The module-level ambient hub (:func:`set_telemetry` /
:func:`get_telemetry` / :func:`telemetry_scope`) is how deep layers
(``core/mixing`` round meters) find the active hub without threading it
through every call; when no hub is installed the meters are no-ops.
"""
from __future__ import annotations

import contextlib
import json
import sys
import threading
import time
from collections import deque
from typing import Any, Dict, Iterable, Iterator, List, Optional

from repro.obs.trace import Tracer

SCHEMA_VERSION = 1

# record type -> required field names (extra numeric/str fields are free)
RECORD_TYPES: Dict[str, tuple] = {
    "step": ("step", "phase"),
    "comm_round": ("phase", "role"),
    "flush": ("step", "phase"),
    "fault": ("step", "kind"),
    "ckpt": ("step",),
    "serve_req": ("uid", "latency_s"),
}


def _jsonify(v: Any) -> Any:
    """JSON-safe scalar coercion (numpy / 0-d jax values -> python)."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, dict):
        return {str(k): _jsonify(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonify(x) for x in v]
    item = getattr(v, "item", None)
    if callable(item) and getattr(v, "ndim", None) == 0:
        return item()
    tolist = getattr(v, "tolist", None)
    if callable(tolist):
        return tolist()
    return repr(v)


# ---------------------------------------------------------------------------
# Sinks
# ---------------------------------------------------------------------------
class Sink:
    def emit(self, rec: Dict[str, Any]) -> None:
        raise NotImplementedError

    def close(self) -> None:
        pass


class JsonlSink(Sink):
    """One JSON object per line; the file format ``benchmarks.report
    --telemetry`` renders."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "a")

    def emit(self, rec: Dict[str, Any]) -> None:
        self._f.write(json.dumps(_jsonify(rec)) + "\n")

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            self._f.close()


class RingSink(Sink):
    """Bounded in-memory record buffer (``Trainer.history`` reads it)."""

    def __init__(self, capacity: int = 4096):
        self.ring: deque = deque(maxlen=capacity)

    def emit(self, rec: Dict[str, Any]) -> None:
        self.ring.append(rec)

    def records(self, rtype: Optional[str] = None) -> List[Dict[str, Any]]:
        if rtype is None:
            return list(self.ring)
        return [r for r in self.ring if r.get("type") == rtype]


class PrettySink(Sink):
    """Human-readable stdout lines — subsumes the legacy ``Trainer.run``
    print format (``[{algorithm}] step {k} loss=... phase=...``).  Only
    ``step`` records print by default; pass ``types`` to widen."""

    def __init__(self, stream=None, types: Iterable[str] = ("step",)):
        self.stream = stream if stream is not None else sys.stdout
        self.types = frozenset(types)

    def emit(self, rec: Dict[str, Any]) -> None:
        if rec.get("type") not in self.types:
            return
        if rec["type"] == "step":
            alg = rec.get("algorithm", "train")
            line = f"[{alg:10s}] step {rec['step']:5d}"
            if "loss" in rec:
                line += f" loss={rec['loss']:.4f}"
            line += f" phase={rec.get('phase')}"
            if "consensus" in rec:
                line += f" consensus={rec['consensus']:.3e}"
        elif rec["type"] == "serve_req":
            line = (f"[serve     ] req {rec['uid']} "
                    f"latency={rec['latency_s'] * 1e3:.1f}ms "
                    f"tok/s={rec.get('tokens_per_s', 0.0):.1f}")
        else:
            body = {k: v for k, v in rec.items()
                    if k not in ("type", "ts", "schema")}
            line = f"[{rec['type']:10s}] {_jsonify(body)}"
        print(line, file=self.stream, flush=True)


# ---------------------------------------------------------------------------
# Hub
# ---------------------------------------------------------------------------
class Telemetry:
    """The metric bus: validates + stamps records, fans out to sinks,
    owns the span :class:`Tracer`, and counts explicit host fetches."""

    def __init__(self, sinks: Iterable[Sink] = (),
                 tags: Optional[Dict[str, Any]] = None,
                 tracer: Optional[Tracer] = None, fence: bool = False):
        self.sinks: List[Sink] = list(sinks)
        self.tags: Dict[str, Any] = dict(tags or {})
        self.tracer = tracer if tracer is not None else Tracer(fence=fence)
        self.host_fetches = 0
        self._lock = threading.Lock()

    # -- records -------------------------------------------------------
    def emit(self, rtype: str, **fields) -> Dict[str, Any]:
        required = RECORD_TYPES.get(rtype)
        if required is None:
            raise ValueError(
                f"Telemetry.emit: unknown record type {rtype!r} "
                f"(expected one of {sorted(RECORD_TYPES)})")
        missing = [f for f in required if f not in fields]
        if missing:
            raise ValueError(f"Telemetry.emit({rtype!r}): missing required "
                             f"fields {missing}")
        rec = {"type": rtype, "schema": SCHEMA_VERSION, "ts": time.time()}
        rec.update(self.tags)
        rec.update(fields)
        with self._lock:
            for sink in self.sinks:
                sink.emit(rec)
        return rec

    # -- spans ---------------------------------------------------------
    def span(self, name: str, **args):
        return self.tracer.span(name, **args)

    # -- host transfers ------------------------------------------------
    def fetch(self, tree: Any) -> Any:
        """The ONE sanctioned device→host materialization: an explicit,
        counted ``jax.device_get`` over a whole pytree.  Producers batch
        a log window's device scalars into a single call here — never a
        per-step ``float()`` (which is an implicit, blocking transfer)."""
        import jax
        self.host_fetches += 1
        return jax.device_get(tree)

    # -- sinks ---------------------------------------------------------
    def ring(self) -> Optional[RingSink]:
        """First RingSink, if any (the Trainer.history backing store)."""
        for s in self.sinks:
            if isinstance(s, RingSink):
                return s
        return None

    def close(self) -> None:
        for s in self.sinks:
            s.close()


# ---------------------------------------------------------------------------
# Ambient hub
# ---------------------------------------------------------------------------
_AMBIENT: List[Optional[Telemetry]] = [None]


def set_telemetry(tel: Optional[Telemetry]) -> Optional[Telemetry]:
    """Install ``tel`` as the ambient hub; returns the previous one."""
    prev = _AMBIENT[0]
    _AMBIENT[0] = tel
    return prev


def get_telemetry() -> Optional[Telemetry]:
    """The ambient hub, or None when telemetry is inactive (the mixing
    meters use this — a None return makes them near-zero-cost no-ops)."""
    return _AMBIENT[0]


@contextlib.contextmanager
def telemetry_scope(tel: Optional[Telemetry]) -> Iterator[Optional[Telemetry]]:
    """Ambient-hub scope: installs ``tel`` for the block, restores the
    previous hub on exit (nesting-safe)."""
    prev = set_telemetry(tel)
    try:
        yield tel
    finally:
        set_telemetry(prev)
