"""Learning-rate schedules (paper recipes: warmup + step decay for ResNet,
warmup + poly decay for BERT/LAMB; cosine for the LM driver)."""
from __future__ import annotations

import math
from typing import Callable

from repro.configs.base import OptimizerConfig


def make_schedule(cfg: OptimizerConfig) -> Callable[[int], float]:
    base = cfg.lr
    warm = max(cfg.warmup_steps, 0)
    total = max(cfg.total_steps, warm + 1)

    def warmup(step: int) -> float:
        if warm and step < warm:
            return base * (step + 1) / warm
        return base

    if cfg.schedule == "constant":
        return warmup

    if cfg.schedule == "warmup_cosine":
        def fn(step: int) -> float:
            if warm and step < warm:
                return warmup(step)
            t = (step - warm) / max(total - warm, 1)
            t = min(max(t, 0.0), 1.0)
            floor = cfg.min_lr_ratio * base
            return floor + (base - floor) * 0.5 * (1 + math.cos(math.pi * t))
        return fn

    if cfg.schedule == "warmup_poly":
        def fn(step: int) -> float:
            if warm and step < warm:
                return warmup(step)
            t = (step - warm) / max(total - warm, 1)
            t = min(max(t, 0.0), 1.0)
            return base * (1 - t)
        return fn

    if cfg.schedule == "step":
        def fn(step: int) -> float:
            lr = warmup(step)
            for boundary in cfg.decay_steps:
                if step >= boundary:
                    lr *= cfg.decay_factor
            return lr
        return fn

    raise ValueError(f"unknown schedule {cfg.schedule!r}")
