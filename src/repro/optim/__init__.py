from repro.optim.optimizers import (Optimizer, adamw, clip_by_global_norm,  # noqa: F401
                                    lamb, make_optimizer, sgd)
from repro.optim.schedules import make_schedule  # noqa: F401
