from repro.optim.optimizers import (Optimizer, adamw,  # noqa: F401
                                    clip_by_global_norm, lamb,
                                    make_optimizer, sgd)
from repro.optim.schedules import make_schedule  # noqa: F401
