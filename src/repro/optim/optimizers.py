"""Optimizers: SGD(+Nesterov momentum), AdamW, LAMB — functional (init, update)
pairs over pytrees.

Decentralized layout: with per-node parameter replicas stacked on a leading
node axis, elementwise optimizers vectorize transparently.  LAMB's layerwise
trust ratio must be *per node* — pass ``per_node=True`` so tensor norms reduce
over all-but-the-first axis (paper trains BERT with LAMB, §5.3/App. F).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, jax.Array],
                     Tuple[PyTree, PyTree]]
    # update(grads, opt_state, params, lr) -> (new_params, new_opt_state)


def _tensor_norm(x: jax.Array, per_node: bool) -> jax.Array:
    axes = tuple(range(1, x.ndim)) if per_node and x.ndim > 1 else None
    n = jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32)), axis=axes))
    if per_node and x.ndim > 1:
        n = n.reshape((x.shape[0],) + (1,) * (x.ndim - 1))
    return n


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads)


def sgd(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        return {"momentum": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params, lr):
        def upd(g, m, p):
            g32 = g.astype(jnp.float32)
            if cfg.weight_decay:
                g32 = g32 + cfg.weight_decay * p.astype(jnp.float32)
            m_new = cfg.momentum * m.astype(jnp.float32) + g32
            step = (g32 + cfg.momentum * m_new) if cfg.nesterov else m_new
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), \
                m_new.astype(m.dtype)
        flat = jax.tree.map(upd, grads, state["momentum"], params)
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"momentum": new_m}

    return Optimizer(init, update)


def _adam_moments(cfg, grads, state):
    count = state["count"] + 1
    def mom(g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        return m_new, v_new
    pairs = jax.tree.map(mom, grads, state["m"], state["v"])
    m = jax.tree.map(lambda t: t[0], pairs,
                     is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda t: t[1], pairs,
                     is_leaf=lambda x: isinstance(x, tuple))
    bc1 = 1 - cfg.b1 ** count
    bc2 = 1 - cfg.b2 ** count
    return m, v, count, bc1, bc2


def adamw(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        def z(p):
            return jnp.zeros(p.shape, jnp.float32)

        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        m, v, count, bc1, bc2 = _adam_moments(cfg, grads, state)
        def upd(p, mi, vi):
            u = (mi / bc1) / (jnp.sqrt(vi / bc2) + cfg.eps)
            u = u + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"m": m, "v": v, "count": count}

    return Optimizer(init, update)


def lamb(cfg: OptimizerConfig, per_node: bool = False) -> Optimizer:
    def init(params):
        def z(p):
            return jnp.zeros(p.shape, jnp.float32)

        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        m, v, count, bc1, bc2 = _adam_moments(cfg, grads, state)
        def upd(p, mi, vi):
            u = (mi / bc1) / (jnp.sqrt(vi / bc2) + cfg.eps)
            u = u + cfg.weight_decay * p.astype(jnp.float32)
            wn = _tensor_norm(p, per_node)
            un = _tensor_norm(u, per_node)
            trust = jnp.where((wn > 0) & (un > 0), wn / jnp.maximum(un, 1e-12),
                              jnp.ones_like(wn))
            return (p.astype(jnp.float32) - lr * trust * u).astype(p.dtype)
        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"m": m, "v": v, "count": count}

    return Optimizer(init, update)


def make_optimizer(cfg: OptimizerConfig, per_node: bool = False) -> Optimizer:
    if cfg.name == "sgd":
        return sgd(cfg)
    if cfg.name == "adamw":
        return adamw(cfg)
    if cfg.name == "lamb":
        return lamb(cfg, per_node=per_node)
    raise ValueError(f"unknown optimizer {cfg.name!r}")
