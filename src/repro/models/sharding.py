"""Logical-axis → mesh-axis resolution.

Modes (DESIGN.md §4):
  train/data   — paper-faithful decentralized training: per-node parameter
                 replicas stacked on a leading "node" axis sharded over the
                 mesh data axis (flattened (pod, data) on the multi-pod mesh);
                 tensor-parallel within a node over the model axis.
  train/pod    — hierarchical: gossip nodes = pods; parameters FSDP-sharded
                 over data × TP over model inside each pod node.
  serve/tp     — inference, weights TP over model axis only.
  serve/2d     — inference, weights 2D-sharded over (data, model) (big archs).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

def _IS_AXES(x):
    return isinstance(x, tuple)


def _rules(mode: str, mesh: Mesh) -> dict:
    axis_names = mesh.axis_names
    multi_pod = "pod" in axis_names
    node_phys: Any = ("pod", "data") if multi_pod else "data"
    if mode == "train_data":
        return {"node": node_phys, "batch": "data", "per_node_batch": None,
                "vocab": "model", "embed": None,
                "heads": "model", "kv_heads": "model", "ffn": "model",
                "expert": "model", "layers": None, "kv_seq": None}
    if mode == "train_pod":
        # node axis == "pod" (absent on single-pod mesh -> replicated), FSDP
        # shards the embed dim over "data".
        return {"node": "pod" if multi_pod else None, "batch": "data",
                "per_node_batch": "data", "vocab": "model",
                "embed": "data", "heads": "model", "kv_heads": "model",
                "ffn": "model", "expert": "model", "layers": None,
                "kv_seq": None}
    serve_batch: Any = ("pod", "data") if multi_pod else "data"
    if mode == "serve_tp":
        return {"node": None, "batch": serve_batch, "vocab": "model",
                "embed": None, "heads": "model", "kv_heads": "model",
                "ffn": "model", "expert": "model", "layers": None,
                "kv_seq": None}
    if mode == "serve_2d":
        return {"node": None, "batch": serve_batch, "vocab": "model",
                "embed": "data", "heads": "model", "kv_heads": "model",
                "ffn": "model", "expert": "model", "layers": None,
                "kv_seq": None}
    if mode == "serve_tp_seq":
        # flash-decoding style: KV cache sequence dim sharded over the model
        # axis (partial softmax + small all-reduce) — for GQA archs whose
        # kv_heads don't divide the model axis and would otherwise replicate
        # the whole cache per chip (§Perf hillclimb 1).
        return {"node": None, "batch": serve_batch, "vocab": "model",
                "embed": None, "heads": "model", "kv_heads": None,
                "ffn": "model", "expert": "model", "layers": None,
                "kv_seq": "model"}
    if mode == "serve_cp":
        # context-parallel decode: tiny batch, KV sequence sharded over data
        return {"node": None, "batch": "pod" if multi_pod else None,
                "vocab": "model", "embed": None, "heads": "model",
                "kv_heads": "model", "ffn": "model", "expert": "model",
                "layers": None, "kv_seq": "data"}
    raise ValueError(f"unknown sharding mode {mode!r}")


def logical_to_spec(axes: Tuple[Optional[str], ...], mode: str, mesh: Mesh,
                    shape: Optional[Tuple[int, ...]] = None) -> P:
    """Resolve logical axes to a PartitionSpec.  With ``shape`` given, a mesh
    axis is applied only when the dim size is divisible by it — pjit argument
    shardings require exact divisibility (e.g. kv_heads=8 on a model=16 axis
    stays replicated)."""
    rules = _rules(mode, mesh)
    mesh_sizes = dict(mesh.shape)
    phys, used = [], set()
    for i, a in enumerate(axes):
        if a is None:
            phys.append(None)
            continue
        p = rules.get(a, None)
        # never map two tensor dims to the same mesh axis
        flat = tuple(p) if isinstance(p, tuple) else (p,)
        if p is None or any(f in used for f in flat if f is not None):
            phys.append(None)
            continue
        if shape is not None:
            size = 1
            for f in flat:
                size *= mesh_sizes.get(f, 1)
            if size == 0 or shape[i] % size != 0:
                phys.append(None)
                continue
        phys.append(p)
        used.update(f for f in flat if f is not None)
    return P(*phys)


def specs_for(axes_tree: PyTree, mode: str, mesh: Mesh) -> PyTree:
    return jax.tree.map(lambda a: logical_to_spec(a, mode, mesh),
                        axes_tree, is_leaf=_IS_AXES)


def shardings_for(axes_tree: PyTree, mode: str, mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda a: NamedSharding(mesh, logical_to_spec(a, mode, mesh)),
        axes_tree, is_leaf=_IS_AXES)


def constrain(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint that is a no-op outside jit/mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def wire_column_spec(shape: Tuple[int, ...], n_rows: int,
                     node_names: Tuple[str, ...],
                     model_names: Tuple[str, ...], k_model: int) -> P:
    """Leaf→column-slice spec negotiation for the sharded communication
    path's packed/wire arrays (``repro.core.mixing``, DESIGN.md §2.1).

    * arrays carrying the node axis (leading dim == ``n_rows``) shard it
      over ``node_names``;
    * a node-sharded array whose trailing column axis divides the model
      shard count is additionally column-sliced over ``model_names`` —
      the caller guarantees the column layout matches the packed matrix's
      (``mixing_pallas.flatten_nodes_sharded`` chunk order), and passes
      ``model_names=()`` for payloads whose columns cannot slice
      (sparsifier index sets, per-row scales);
    * everything else (leading-axis-1 shared metadata, scalars) rides
      replicated.
    """
    row = tuple(node_names) if shape and shape[0] == n_rows else None
    if (row is not None and model_names and k_model > 1 and len(shape) >= 2
            and shape[-1] >= k_model and shape[-1] % k_model == 0):
        mid = (None,) * (len(shape) - 2)
        return P(row, *mid, tuple(model_names))
    return P(row) if row is not None else P()
