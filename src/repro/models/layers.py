"""Shared building blocks: parameter builder with logical sharding axes,
norms, embeddings, rotary, MLPs.

Parameters are plain pytrees (nested dicts of jnp arrays).  Every parameter is
created through :class:`ParamBuilder`, which records a parallel tree of
*logical axis names* — resolved to mesh ``PartitionSpec``s by sharding rules
(``repro.models.sharding``).  This keeps the value tree and the spec tree
structurally identical by construction.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
Axes = Tuple[Optional[str], ...]

# Logical axis vocabulary (resolved by sharding rules):
#   "node"   — decentralized replica axis (the paper's n nodes)
#   "vocab"  — vocabulary dim
#   "embed"  — model dim (FSDP shard target in hierarchical mode)
#   "heads" / "kv_heads" — attention heads
#   "ffn"    — feed-forward hidden
#   "expert" — MoE expert dim
#   "layers" — scanned-layer stacking dim (never sharded)
#   None     — replicated


class ParamBuilder:
    """Creates parameters and their logical-axes tree in lockstep."""

    def __init__(self, key: jax.Array, param_dtype: jnp.dtype):
        self._key = key
        self.param_dtype = param_dtype
        self.params: Dict[str, Any] = {}
        self.axes: Dict[str, Any] = {}

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def add(self, name: str, shape: Sequence[int], axes: Axes,
            init: str = "fan_in", scale: Optional[float] = None) -> jax.Array:
        assert len(axes) == len(shape), (name, shape, axes)
        shape = tuple(int(s) for s in shape)
        if init == "zeros":
            val = jnp.zeros(shape, self.param_dtype)
        elif init == "ones":
            val = jnp.ones(shape, self.param_dtype)
        elif init == "normal":
            std = scale if scale is not None else 0.02
            val = std * jax.random.normal(self._next_key(), shape,
                                          self.param_dtype)
        elif init == "fan_in":
            fan_in = shape[0] if len(shape) == 1 else math.prod(shape[:-1])
            std = ((scale if scale is not None else 1.0)
                   / math.sqrt(max(fan_in, 1)))
            val = std * jax.random.normal(self._next_key(), shape,
                                          self.param_dtype)
        elif init == "constant":
            val = jnp.full(shape, scale, self.param_dtype)
        else:
            raise ValueError(f"unknown init {init!r}")
        self.params[name] = val
        self.axes[name] = axes
        return val

    def sub(self, name: str) -> "ParamBuilder":
        child = ParamBuilder(self._next_key(), self.param_dtype)
        self.params[name] = child.params
        self.axes[name] = child.axes
        return child

    def attach(self, name: str, params: PyTree, axes: PyTree) -> None:
        self.params[name] = params
        self.axes[name] = axes


def stack_inits(init_fn: Callable[[jax.Array], Tuple[PyTree, PyTree]],
                key: jax.Array, n: int) -> Tuple[PyTree, PyTree]:
    """Initialize ``n`` structurally-identical blocks and stack leaf-wise on a
    new leading "layers" axis (scan-over-layers layout)."""
    keys = jax.random.split(key, n)
    params0, axes0 = init_fn(keys[0])
    rest = [init_fn(keys[i])[0] for i in range(1, n)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), params0, *rest)
    axes = jax.tree.map(lambda a: ("layers",) + tuple(a),
                        axes0, is_leaf=lambda x: isinstance(x, tuple))
    return stacked, axes


# ---------------------------------------------------------------------------
# Functional layers
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, weight: jax.Array, eps: float,
             offset: float = 0.0) -> jax.Array:
    """RMSNorm in fp32 accumulation; ``offset=1`` gives Gemma-style (1+w)."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (offset + weight.astype(jnp.float32))).astype(dtype)


def init_rms_norm(b: ParamBuilder, name: str, dim: int,
                  zeros: bool = False) -> None:
    b.add(name, (dim,), (None,), init="zeros" if zeros else "ones")


def make_rope(positions: jax.Array, head_dim: int, theta: float,
              dtype=jnp.float32) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for rotary embedding; positions (...,S)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (...,S,half)
    return jnp.cos(angles).astype(dtype), jnp.sin(angles).astype(dtype)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, n_heads, head_dim); cos/sin: (..., S, head_dim//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)  # broadcast over heads
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def softcap(logits: jax.Array, cap: Optional[float]) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(logits / cap)."""
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------
def init_mlp(key: jax.Array, d_model: int, d_ff: int,
             param_dtype) -> Tuple[PyTree, PyTree]:
    b = ParamBuilder(key, param_dtype)
    b.add("w_gate", (d_model, d_ff), ("embed", "ffn"))
    b.add("w_up", (d_model, d_ff), ("embed", "ffn"))
    b.add("w_down", (d_ff, d_model), ("ffn", "embed"))
    return b.params, b.axes


def apply_mlp(params: PyTree, x: jax.Array, *, act=jax.nn.silu) -> jax.Array:
    h = (act(x @ params["w_gate"].astype(x.dtype))
         * (x @ params["w_up"].astype(x.dtype)))
    return h @ params["w_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def init_embedding(key: jax.Array, vocab: int, d_model: int, param_dtype,
                   tie: bool) -> Tuple[PyTree, PyTree]:
    b = ParamBuilder(key, param_dtype)
    b.add("embedding", (vocab, d_model), ("vocab", "embed"), init="normal",
          scale=0.02)
    if not tie:
        b.add("unembed", (d_model, vocab), ("embed", "vocab"))
    return b.params, b.axes


def embed_tokens(params: PyTree, tokens: jax.Array, dtype,
                 scale_by_dim: bool = False) -> jax.Array:
    emb = params["embedding"].astype(dtype)[tokens]
    if scale_by_dim:  # Gemma convention
        emb = emb * jnp.asarray(
            math.sqrt(params["embedding"].shape[-1]), dtype)
    return emb


def unembed(params: PyTree, h: jax.Array, tie: bool,
            final_softcap: Optional[float] = None) -> jax.Array:
    if tie:
        logits = h @ params["embedding"].astype(h.dtype).T
    else:
        logits = h @ params["unembed"].astype(h.dtype)
    return softcap(logits.astype(jnp.float32), final_softcap)
