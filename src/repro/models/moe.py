"""Mixture-of-Experts FFN with expert-parallel sharding.

Dispatch is *sort-based with static capacity* (Switch/GShard-style dropping):
token→expert assignments are sorted by expert id and packed into a dense
(E, C, d) buffer, experts run batched matmuls over their capacity slots, and
results scatter-add back to token order.  Experts are sharded over the mesh
``model`` axis (logical "expert" dim), so the pack/unpack gathers lower to the
expert-parallel collectives (all-gather of the token shard in, all-reduce of
the combined output out) while the expert matmuls stay local — activated-FLOP
compute, bounded memory.  Capacity slack and token dropping are measured and
surfaced through metrics.

Covers the three assigned MoE variants:
  * deepseek-v2-lite: 2 shared + 64 routed, top-6
  * qwen3-moe-30b:    128 routed top-8
  * jamba-1.5:        16 routed top-2 on alternate layers
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import ParamBuilder, apply_mlp

PyTree = Any

DEFAULT_CAPACITY_FACTOR = 1.25


def init_moe(key: jax.Array, cfg: ModelConfig,
             param_dtype) -> Tuple[PyTree, PyTree]:
    m = cfg.moe
    d = cfg.d_model
    b = ParamBuilder(key, param_dtype)
    b.add("router", (d, m.n_routed), ("embed", None))
    b.add("w_gate", (m.n_routed, d, m.d_ff_expert), ("expert", "embed", None))
    b.add("w_up", (m.n_routed, d, m.d_ff_expert), ("expert", "embed", None))
    b.add("w_down", (m.n_routed, m.d_ff_expert, d), ("expert", None, "embed"))
    if m.n_shared:
        b.add("sw_gate", (d, m.n_shared * m.d_ff_expert), ("embed", "ffn"))
        b.add("sw_up", (d, m.n_shared * m.d_ff_expert), ("embed", "ffn"))
        b.add("sw_down", (m.n_shared * m.d_ff_expert, d), ("ffn", "embed"))
    return b.params, b.axes


def route(params: PyTree, m: MoEConfig, x: jax.Array
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k router (fp32).  Returns (top_w (…,k), top_idx (…,k), lb_loss)."""
    logits = x.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                       # (...,E)
    top_w, top_idx = jax.lax.top_k(probs, m.top_k)                # (...,k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    # Switch-style load-balance loss: E * <fraction routed, mean prob>
    tokens_frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_idx, m.n_routed, dtype=jnp.float32), -2),
        axis=tuple(range(top_idx.ndim - 1)))
    prob_frac = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    lb_loss = m.n_routed * jnp.sum(tokens_frac / m.top_k * prob_frac)
    return top_w, top_idx, lb_loss


def expert_capacity(m: MoEConfig, n_tokens: int,
                    capacity_factor: float = DEFAULT_CAPACITY_FACTOR) -> int:
    c = int(math.ceil(n_tokens * m.top_k * capacity_factor / m.n_routed))
    return max(min(c, n_tokens), 8)


def _build_dispatch(top_idx: jax.Array, top_w: jax.Array, n_experts: int,
                    capacity: int, n_tokens: int):
    """Sort assignments by expert, compute each one's slot within its expert's
    capacity, and emit (E,C) token-index/weight tables.  Overflow slots point
    at the sentinel row ``n_tokens`` (zero-padded)."""
    k = top_idx.shape[-1]
    flat_e = top_idx.reshape(-1)                                  # (T*k,)
    flat_t = jnp.repeat(jnp.arange(n_tokens), k)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.bincount(flat_e, length=n_experts)
    starts = jnp.cumsum(counts) - counts                          # exclusive
    slot = jnp.arange(flat_e.shape[0]) - starts[se]             # pos in expert
    ok = slot < capacity
    # overflowed assignments are dropped (measured via drop_frac)
    e_idx = jnp.where(ok, se, 0)
    c_idx = jnp.where(ok, slot, 0)
    token_table = jnp.full((n_experts, capacity), n_tokens, jnp.int32)
    weight_table = jnp.zeros((n_experts, capacity), flat_w.dtype)
    token_table = token_table.at[e_idx, c_idx].set(
        jnp.where(ok, st, n_tokens).astype(jnp.int32), mode="drop")
    weight_table = weight_table.at[e_idx, c_idx].set(
        jnp.where(ok, sw, 0.0), mode="drop")
    drop_frac = 1.0 - jnp.mean(ok.astype(jnp.float32))
    return token_table, weight_table, drop_frac


def apply_moe(params: PyTree, cfg: ModelConfig, x: jax.Array,
              capacity_factor: float = None
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B,S,d) -> (out, moe_metrics{lb_loss, drop_frac}).

    NOTE: capacity (and therefore the drop set) depends on the token count T
    of the call — full-sequence forward, prefill and decode see different T,
    so capacity-dropped tokens may differ across paths.  Set
    ``MoEConfig.capacity_factor >= n_routed`` for drop-free (path-exact)
    behavior."""
    m = cfg.moe
    if capacity_factor is None:
        capacity_factor = m.capacity_factor
    B, S, d = x.shape
    T = B * S
    top_w, top_idx, lb_loss = route(params, m, x)
    C = expert_capacity(m, T, capacity_factor)
    tok, w, drop_frac = _build_dispatch(
        top_idx.reshape(T, -1), top_w.reshape(T, -1), m.n_routed, C, T)

    x_flat = x.reshape(T, d)
    x_pad = jnp.concatenate([x_flat, jnp.zeros((1, d), x.dtype)], axis=0)
    xe = x_pad[tok]                                               # (E,C,d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe,
                               params["w_gate"].astype(x.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, params["w_up"].astype(x.dtype))
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(x.dtype))
    ye = ye * w[..., None].astype(x.dtype)

    y_flat = jnp.zeros((T + 1, d), x.dtype).at[tok.reshape(-1)].add(
        ye.reshape(-1, d))
    out = y_flat[:T].reshape(B, S, d)
    if m.n_shared:
        shared = {"w_gate": params["sw_gate"], "w_up": params["sw_up"],
                  "w_down": params["sw_down"]}
        out = out + apply_mlp(shared, x)
    return out, {"lb_loss": lb_loss, "drop_frac": drop_frac}


def apply_moe_dense_reference(params: PyTree, cfg: ModelConfig, x: jax.Array
                              ) -> jax.Array:
    """Oracle: every expert on every token, combined with routing weights.
    O(E) FLOPs — tests only (equals apply_moe when nothing drops)."""
    m = cfg.moe
    top_w, top_idx, _ = route(params, m, x)
    combine = jnp.sum(
        jax.nn.one_hot(top_idx, m.n_routed, dtype=top_w.dtype)
        * top_w[..., None], axis=-2)                              # (B,S,E)
    h_g = jnp.einsum("bsd,edf->besf", x, params["w_gate"].astype(x.dtype))
    h_u = jnp.einsum("bsd,edf->besf", x, params["w_up"].astype(x.dtype))
    h = jax.nn.silu(h_g) * h_u
    y = jnp.einsum("besf,efd->besd", h, params["w_down"].astype(x.dtype))
    out = jnp.einsum("besd,bse->bsd", y, combine.astype(x.dtype))
    if m.n_shared:
        shared = {"w_gate": params["sw_gate"], "w_up": params["sw_up"],
                  "w_down": params["sw_down"]}
        out = out + apply_mlp(shared, x)
    return out
