"""Top-level Model: init / forward / decode / loss for every assigned family.

Batch formats (all synthetic-data-pipeline compatible):
  decoder LM (dense/moe/ssm/hybrid):
      {"inputs": (B,S) i32, "targets": (B,S) i32}
  vlm:  + {"patches": (B, n_img, d_model)}  — stubbed frontend embeddings;
      image positions occupy the sequence prefix, loss masked there.
  encoder (hubert audio / bert):
      audio: {"frames": (B,S,d_model) f, "mask": (B,S) bool, "targets": (B,S)}
      text:  {"inputs": (B,S) i32, "mask": (B,S) bool, "targets": (B,S)}
  decode (serving): tokens (B,1) i32 + per-layer cache + pos (B,) i32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks
from repro.models.layers import (ParamBuilder, embed_tokens, init_embedding,
                                 init_rms_norm, rms_norm, unembed)

PyTree = Any


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------------
    # Init
    # ------------------------------------------------------------------
    def init(self, key: jax.Array) -> Tuple[PyTree, PyTree]:
        cfg = self.cfg
        pdt = _dtype(cfg.param_dtype)
        b = ParamBuilder(key, pdt)
        p, a = init_embedding(b._next_key(), cfg.vocab_size, cfg.d_model, pdt,
                              cfg.tie_embeddings)
        b.attach("embed", p, a)
        if cfg.family == "encoder":
            b.add("mask_emb", (cfg.d_model,), (None,), init="normal")
        p, a = blocks.init_stack(b._next_key(), cfg, pdt)
        b.attach("stack", p, a)
        init_rms_norm(b, "final_norm", cfg.d_model)
        return b.params, b.axes

    # ------------------------------------------------------------------
    # Embedding per family
    # ------------------------------------------------------------------
    def _embed(self, params: PyTree, batch: Dict[str, jax.Array],
               dtype) -> jax.Array:
        cfg = self.cfg
        if cfg.family == "encoder" and cfg.audio is not None:
            h = batch["frames"].astype(dtype)
            mask = batch["mask"]
            h = jnp.where(mask[..., None], params["mask_emb"].astype(dtype), h)
            return h
        h = embed_tokens(params["embed"], batch["inputs"], dtype,
                         scale_by_dim=cfg.final_logit_softcap is not None)
        if cfg.family == "encoder":
            h = jnp.where(batch["mask"][..., None],
                          params["mask_emb"].astype(dtype), h)
        if cfg.family == "vlm" and "patches" in batch:
            n_img = batch["patches"].shape[1]
            h = jnp.concatenate(
                [batch["patches"].astype(dtype), h[:, n_img:]], axis=1)
        return h

    # ------------------------------------------------------------------
    # Full-sequence forward (train / prefill)
    # ------------------------------------------------------------------
    def forward(self, params: PyTree, batch: Dict[str, jax.Array], *,
                mode: str = "train", remat: str = "none",
                want_cache: bool = False, unroll: bool = False
                ) -> Tuple[jax.Array, Optional[PyTree], jax.Array]:
        cfg = self.cfg
        dtype = _dtype(cfg.dtype)
        h = self._embed(params, batch, dtype)
        B, S = h.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        h, caches, lb_loss = blocks.apply_stack(
            params["stack"], cfg, h, mode=mode, positions=positions,
            remat=remat, want_cache=want_cache, unroll=unroll)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], h, cfg.tie_embeddings,
                         cfg.final_logit_softcap)
        return logits, caches, lb_loss

    # ------------------------------------------------------------------
    # One-token decode
    # ------------------------------------------------------------------
    def decode_step(self, params: PyTree, caches: PyTree, tokens: jax.Array,
                    pos: jax.Array, *, unroll: bool = False
                    ) -> Tuple[jax.Array, PyTree]:
        """tokens: (B,1) i32; pos: (B,) i32 — position being written."""
        cfg = self.cfg
        dtype = _dtype(cfg.dtype)
        h = embed_tokens(params["embed"], tokens, dtype,
                         scale_by_dim=cfg.final_logit_softcap is not None)
        h, caches_out, _ = blocks.apply_stack(
            params["stack"], cfg, h, mode="decode", caches=caches, pos=pos,
            unroll=unroll)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], h, cfg.tie_embeddings,
                         cfg.final_logit_softcap)
        return logits, caches_out

    def init_cache(self, batch: int, s_max: int, dtype_name: str = None
                   ) -> PyTree:
        dtype = _dtype(dtype_name or self.cfg.dtype)
        return blocks.init_stack_cache(self.cfg, batch, s_max, dtype)

    def cache_axes(self) -> PyTree:
        return blocks.stack_cache_axes(self.cfg)

    # ------------------------------------------------------------------
    # Loss
    # ------------------------------------------------------------------
    def loss(self, params: PyTree, batch: Dict[str, jax.Array], *,
             remat: str = "none", z_loss: float = 0.0, unroll: bool = False
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        logits, _, lb_loss = self.forward(params, batch, mode="train",
                                          remat=remat, unroll=unroll)
        targets = batch["targets"]
        if cfg.family == "encoder":
            weights = batch["mask"].astype(jnp.float32)      # masked positions
        elif cfg.family == "vlm" and "patches" in batch:
            n_img = batch["patches"].shape[1]
            w = jnp.ones(targets.shape, jnp.float32)
            weights = w.at[:, :n_img].set(0.0)
        else:
            weights = jnp.ones(targets.shape, jnp.float32)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        denom = jnp.maximum(jnp.sum(weights), 1.0)
        ce = jnp.sum(nll * weights) / denom
        total = ce
        metrics = {"ce": ce, "lb_loss": lb_loss}
        if cfg.moe is not None:
            total = total + cfg.moe.aux_coef * lb_loss
        if z_loss:
            zl = jnp.sum(
                jax.scipy.special.logsumexp(
                    logits.astype(jnp.float32), axis=-1) ** 2
                * weights) / denom
            total = total + z_loss * zl
            metrics["z_loss"] = zl
        metrics["loss"] = total
        return total, metrics


def make_model(cfg: ModelConfig) -> Model:
    return Model(cfg.validate())
