"""Model zoo: composable JAX definitions for all assigned architectures."""
from repro.models import sharding  # noqa: F401
from repro.models.model import Model, make_model  # noqa: F401
