"""Recurrent mixers: Mamba (S6), mLSTM and sLSTM (xLSTM).

TPU adaptation (DESIGN.md §2): the CUDA selective-scan / fused-recurrence
kernels these papers ship have no TPU analogue, so each mixer is re-expressed
in an XLA/TPU-native parallel form:

* Mamba   — first-order linear recurrence via ``jax.lax.associative_scan``
            (parallel prefix, O(S log S) work, MXU-free elementwise).
* mLSTM   — *chunkwise-parallel*: intra-chunk attention-style matmuls (MXU)
            + an inter-chunk scan over the (d_k × d_v) matrix memory with
            log-space gate stabilization.  ``mlstm_recurrent_reference`` is
            the step-by-step oracle used by tests.
* sLSTM   — inherently sequential scalar recurrence (recurrent weights R
            depend on h_{t-1}); kept as ``lax.scan`` — documented as the one
            TPU-hostile layer; configs place it sparsely (xlstm-125m: 2/12).

Decode steps are exact single-token recurrences against a constant-size state
— this is what makes the SSM/hybrid archs eligible for ``long_500k``.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamBuilder, rms_norm

PyTree = Any


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B,S,D); w: (W,D); b: (D,)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32), w.astype(jnp.float32)[:, None, :],
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _conv_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array,
               b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single-token causal conv against a (B, W-1, D) state."""
    window = jnp.concatenate([conv_state, x_t[:, None]], axis=1)  # (B,W,D)
    out = jnp.einsum("bwd,wd->bd", window.astype(jnp.float32),
                     w.astype(jnp.float32)) + b.astype(jnp.float32)
    return out.astype(x_t.dtype), window[:, 1:]


# ===========================================================================
# Mamba (S6)
# ===========================================================================
def _mamba_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    dt_rank = s.dt_rank or max(cfg.d_model // 16, 1)
    return d_inner, s.d_state, dt_rank


def init_mamba(key: jax.Array, cfg: ModelConfig,
               param_dtype) -> Tuple[PyTree, PyTree]:
    d = cfg.d_model
    di, N, R = _mamba_dims(cfg)
    s = cfg.ssm
    b = ParamBuilder(key, param_dtype)
    b.add("in_proj", (d, 2 * di), ("embed", "ffn"))
    b.add("conv_w", (s.d_conv, di), (None, "ffn"))
    b.add("conv_b", (di,), ("ffn",), init="zeros")
    b.add("x_proj", (di, R + 2 * N), ("ffn", None))
    b.add("dt_proj", (R, di), (None, "ffn"))
    b.add("dt_bias", (di,), ("ffn",), init="constant",
          scale=math.log(math.expm1(0.01)))  # softplus^-1(0.01)
    # A_log init: log(1..N) per channel (S4D-real)
    b.params["A_log"] = jnp.broadcast_to(
        jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32)), (di, N)
    ).astype(param_dtype)
    b.axes["A_log"] = ("ffn", None)
    b.add("D", (di,), ("ffn",), init="ones")
    b.add("out_proj", (di, d), ("ffn", "embed"))
    return b.params, b.axes


def _mamba_ssm_inputs(params, cfg: ModelConfig, x_conv, dt_rank, N):
    dbc = x_conv @ params["x_proj"].astype(x_conv.dtype)
    dt, Bc, Cc = jnp.split(dbc, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(
        dt @ params["dt_proj"].astype(x_conv.dtype)
        + params["dt_bias"].astype(x_conv.dtype))                # (…,di)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))            # (di,N)
    return dt, Bc, Cc, A


def mamba_forward(params: PyTree, cfg: ModelConfig, x: jax.Array
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B,S,d) -> (out, state) — parallel scan over the full sequence.

    The scan state dtype follows ``cfg.ssm.scan_dtype`` — bf16 halves the
    (B,S,d_inner,N) scan-state traffic, the dominant memory term of the
    hybrid archs (§Perf hillclimb 2); gate/decay math stays fp32.
    """
    Bsz, S, d = x.shape
    di, N, R = _mamba_dims(cfg)
    sdt = jnp.bfloat16 if cfg.ssm.scan_dtype == "bfloat16" else jnp.float32
    xz = x @ params["in_proj"].astype(x.dtype)
    xc, z = jnp.split(xz, 2, axis=-1)
    x_conv = jax.nn.silu(_causal_conv(xc, params["conv_w"], params["conv_b"]))
    dt, Bc, Cc, A = _mamba_ssm_inputs(params, cfg, x_conv, R, N)

    dtA = dt.astype(jnp.float32)[..., None] * A                  # (B,S,di,N)
    a = jnp.exp(dtA).astype(sdt)
    bu = ((dt.astype(jnp.float32) * x_conv.astype(jnp.float32))[..., None]
          * Bc.astype(jnp.float32)[..., None, :]).astype(sdt)    # (B,S,di,N)

    def combine(lhs, rhs):
        al, bl = lhs
        ar, br = rhs
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, bu), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", h.astype(sdt), Cc.astype(sdt))
    y = y.astype(jnp.float32) \
        + params["D"].astype(jnp.float32) * x_conv.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(x.dtype)
    state = {"conv": _final_conv_state(xc, cfg.ssm.d_conv),
             "h": h[:, -1].astype(x.dtype)}
    return out, state


def _final_conv_state(xc: jax.Array, width: int) -> jax.Array:
    pad = jnp.zeros((xc.shape[0], width - 1, xc.shape[-1]), xc.dtype)
    return jnp.concatenate([pad, xc], axis=1)[:, -(width - 1):]


def mamba_decode(params: PyTree, cfg: ModelConfig, x: jax.Array,
                 state: Dict[str, jax.Array]
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B,1,d); state {conv (B,W-1,di), h (B,di,N)}."""
    di, N, R = _mamba_dims(cfg)
    xz = x[:, 0] @ params["in_proj"].astype(x.dtype)
    xc, z = jnp.split(xz, 2, axis=-1)
    x_conv, new_conv = _conv_step(xc, state["conv"], params["conv_w"],
                                  params["conv_b"])
    x_conv = jax.nn.silu(x_conv)
    dt, Bc, Cc, A = _mamba_ssm_inputs(params, cfg, x_conv, R, N)
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A)           # (B,di,N)
    bu = (dt.astype(jnp.float32) * x_conv.astype(jnp.float32))[..., None] \
        * Bc.astype(jnp.float32)[..., None, :]
    h = a * state["h"].astype(jnp.float32) + bu
    y = jnp.einsum("bdn,bn->bd", h, Cc.astype(jnp.float32))
    y = y + params["D"].astype(jnp.float32) * x_conv.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = (y @ params["out_proj"].astype(x.dtype))[:, None]
    return out, {"conv": new_conv, "h": h.astype(x.dtype)}


def init_mamba_state(cfg: ModelConfig, batch: int,
                     dtype) -> Dict[str, jax.Array]:
    di, N, _ = _mamba_dims(cfg)
    return {"conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, di), dtype),
            "h": jnp.zeros((batch, di, N), dtype)}


def mamba_state_axes(cfg: ModelConfig) -> Dict[str, tuple]:
    return {"conv": ("batch", None, "ffn"), "h": ("batch", "ffn", None)}


# ===========================================================================
# mLSTM (xLSTM) — chunkwise-parallel with log-space gate stabilization
# ===========================================================================
def _mlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    s = cfg.ssm
    di = s.mlstm_expand * cfg.d_model
    nh = di // (2 * s.mlstm_head_dim)   # qk head dim = di/(2nh), v dim = di/nh
    nh = max(nh, 1)
    return di, nh, s.mlstm_head_dim


def init_mlstm(key: jax.Array, cfg: ModelConfig,
               param_dtype) -> Tuple[PyTree, PyTree]:
    d = cfg.d_model
    di, nh, dk = _mlstm_dims(cfg)
    b = ParamBuilder(key, param_dtype)
    b.add("up_proj", (d, 2 * di), ("embed", "ffn"))
    b.add("conv_w", (4, di), (None, "ffn"))
    b.add("conv_b", (di,), ("ffn",), init="zeros")
    b.add("w_q", (di, nh, dk), ("ffn", "heads", None))
    b.add("w_k", (di, nh, dk), ("ffn", "heads", None))
    b.add("w_v", (di, nh, di // nh), ("ffn", "heads", None))
    b.add("w_i", (di, nh), ("ffn", "heads"), init="fan_in")
    b.add("b_i", (nh,), ("heads",), init="zeros")
    b.add("w_f", (di, nh), ("ffn", "heads"), init="fan_in")
    b.add("b_f", (nh,), ("heads",), init="constant", scale=3.0)  # open forget
    b.add("gn", (di,), ("ffn",), init="ones")                     # group norm
    b.add("down_proj", (di, d), ("ffn", "embed"))
    return b.params, b.axes


def _mlstm_qkvif(params, cfg, x_in):
    """x_in: (B,S,di) up-projected mixer branch."""
    x_conv = jax.nn.silu(
        _causal_conv(x_in, params["conv_w"], params["conv_b"]))
    q = jnp.einsum("bsd,dhk->bshk", x_conv, params["w_q"].astype(x_in.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x_conv, params["w_k"].astype(x_in.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x_in, params["w_v"].astype(x_in.dtype))
    i_raw = (x_in @ params["w_i"].astype(x_in.dtype)
             + params["b_i"].astype(x_in.dtype)).astype(jnp.float32)
    f_raw = (x_in @ params["w_f"].astype(x_in.dtype)
             + params["b_f"].astype(x_in.dtype)).astype(jnp.float32)
    dk = q.shape[-1]
    q = q / math.sqrt(dk)
    return q, k, v, i_raw, jax.nn.log_sigmoid(f_raw)


def _mlstm_chunk_scan(q, k, v, log_i, log_f, chunk: int):
    """Chunkwise mLSTM.  q,k: (B,S,nh,dk); v: (B,S,nh,dv);
    log_i/log_f: (B,S,nh).  Returns h: (B,S,nh,dv) and final (C,n,m)."""
    B, S, nh, dk = q.shape
    dv = v.shape[-1]
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        padt = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(t, padt) for t in (q, k, v))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)))
        # padded steps must not pollute the state: f=1 (log 0), i=-inf
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
        log_i = log_i.at[:, S:].set(-1e9)
    nc = q.shape[1] // L

    def to_chunks(t):
        return t.reshape((B, nc, L) + t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, lic, lfc = map(to_chunks, (q, k, v, log_i, log_f))
    # cumulative log-forget within chunk, inclusive: bchl
    F = jnp.cumsum(lfc, axis=2)                                   # (nc,B,L,nh)
    F_total = F[:, :, -1]                                         # (nc,B,nh)

    # intra-chunk pair weights: w[t,τ] = F_t − F_τ + li_τ  (τ ≤ t)
    tril = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(carry, xs):
        C, n, m = carry             # C: (B,nh,dk,dv), n: (B,nh,dk), m: (B,nh)
        qi, ki, vi, li, Fi, Ft = xs  # (B,L,nh,*) …, Fi: (B,L,nh), Ft: (B,nh)
        w = (Fi[:, :, None] - Fi[:, None, :] + li[:, None, :])    # (B,t,τ,nh)
        w = jnp.where(tril[None, :, :, None], w, -jnp.inf)
        w_max = jnp.max(w, axis=2)                                # (B,L,nh)
        m_in = m[:, None] + Fi                                    # state path
        m_t = jnp.maximum(w_max, m_in)                            # (B,L,nh)
        # intra-chunk attention
        scores = jnp.einsum("blhk,bthk->blth", qi, ki).astype(jnp.float32)
        gates = jnp.exp(w - m_t[:, :, None])                      # (B,t,τ,nh)
        probs = scores * gates
        h_intra = jnp.einsum("blth,bthv->blhv", probs.astype(qi.dtype), vi)
        den_intra = jnp.sum(probs, axis=2)  # Σ_τ gate_{tτ} (q_t·k_τ)  (B,L,nh)
        # inter-chunk (initial state) contribution
        sgate = jnp.exp(m_in - m_t)                               # (B,L,nh)
        h_state = jnp.einsum("blhk,bhkv->blhv", qi.astype(jnp.float32), C)
        h_state = h_state * sgate[..., None]
        den_state = jnp.einsum("blhk,bhk->blh", qi.astype(jnp.float32), n)
        den_state = den_state * sgate
        den = jnp.maximum(jnp.abs(den_intra + den_state),
                          jnp.exp(-m_t))                          # (B,L,nh)
        h = (h_intra.astype(jnp.float32) + h_state) / den[..., None]
        # ---- state update to end of chunk ----
        w_end = Ft[:, None] - Fi + li                             # (B,L,nh)
        m_end = jnp.maximum(jnp.max(w_end, axis=1), m + Ft)       # (B,nh)
        kg = jnp.exp(w_end - m_end[:, None])                      # (B,L,nh)
        C_new = jnp.einsum("blhk,blhv->bhkv",
                           (ki.astype(jnp.float32) * kg[..., None]),
                           vi.astype(jnp.float32))
        n_new = jnp.einsum("blhk,blh->bhk", ki.astype(jnp.float32), kg)
        decay = jnp.exp(m + Ft - m_end)                           # (B,nh)
        C = C * decay[..., None, None] + C_new
        n = n * decay[..., None] + n_new
        return (C, n, m_end), h

    C0 = jnp.zeros((B, nh, dk, dv), jnp.float32)
    n0 = jnp.zeros((B, nh, dk), jnp.float32)
    m0 = jnp.full((B, nh), -1e9, jnp.float32)
    (C, n, m), h = jax.lax.scan(chunk_step, (C0, n0, m0),
                                (qc, kc, vc, lic, F, F_total))
    h = h.swapaxes(0, 1).reshape(B, nc * L, nh, dv)[:, :S]
    return h.astype(q.dtype), (C, n, m)


def mlstm_forward(params: PyTree, cfg: ModelConfig, x: jax.Array
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, S, d = x.shape
    di, nh, dk = _mlstm_dims(cfg)
    up = x @ params["up_proj"].astype(x.dtype)
    x_in, z = jnp.split(up, 2, axis=-1)
    q, k, v, log_i, log_f = _mlstm_qkvif(params, cfg, x_in)
    if cfg.ssm.use_pallas_mlstm:
        # TPU hot path: Pallas chunkwise kernel (validated vs the oracle in
        # tests/test_kernels.py); decode still needs the final state, so the
        # state is recovered with a lightweight scan over chunk boundaries.
        from repro.kernels.ops import mlstm_chunk_op
        h = mlstm_chunk_op(q, k, v, log_i, log_f, chunk=cfg.ssm.mlstm_chunk)
        # exact final state for decode handoff via the host-scan (the kernel
        # keeps its state in VMEM scratch; XLA DCEs the duplicate h outputs)
        _, (C, n, m) = _mlstm_chunk_scan(q, k, v, log_i, log_f,
                                         cfg.ssm.mlstm_chunk)
    else:
        h, (C, n, m) = _mlstm_chunk_scan(q, k, v, log_i, log_f,
                                         cfg.ssm.mlstm_chunk)
    h = h.reshape(B, S, di)
    h = rms_norm(h, params["gn"], cfg.norm_eps)                   # group norm
    out = (h * jax.nn.silu(z)) @ params["down_proj"].astype(x.dtype)
    # conv state for decode
    conv_state = _final_conv_state(x_in, 4)
    state = {"C": C.astype(x.dtype), "n": n.astype(x.dtype), "m": m,
             "conv": conv_state}
    return out, state


def mlstm_recurrent_reference(q, k, v, log_i, log_f):
    """Step-by-step stabilized mLSTM recurrence — oracle for tests."""
    B, S, nh, dk = q.shape
    dv = v.shape[-1]

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, li, lf = xs
        m_new = jnp.maximum(lf + m, li)
        fg = jnp.exp(lf + m - m_new)
        ig = jnp.exp(li - m_new)
        C = C * fg[..., None, None] + ig[..., None, None] * (
            kt[..., :, None].astype(jnp.float32)
            * vt[..., None, :].astype(jnp.float32))
        n = n * fg[..., None] + ig[..., None] * kt.astype(jnp.float32)
        num = jnp.einsum("bhkv,bhk->bhv", C, qt.astype(jnp.float32))
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt.astype(jnp.float32))),
            jnp.exp(-m_new))
        h = num / den[..., None]
        return (C, n, m_new), h

    C0 = jnp.zeros((B, nh, dk, dv), jnp.float32)
    n0 = jnp.zeros((B, nh, dk), jnp.float32)
    m0 = jnp.full((B, nh), -1e9, jnp.float32)
    xs = tuple(t.swapaxes(0, 1) for t in (q, k, v, log_i, log_f))
    (C, n, m), h = jax.lax.scan(step, (C0, n0, m0), xs)
    return h.swapaxes(0, 1).astype(q.dtype), (C, n, m)


def mlstm_decode(params: PyTree, cfg: ModelConfig, x: jax.Array,
                 state: Dict[str, jax.Array]
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B = x.shape[0]
    di, nh, dk = _mlstm_dims(cfg)
    up = x[:, 0] @ params["up_proj"].astype(x.dtype)
    x_in, z = jnp.split(up, 2, axis=-1)
    x_conv, new_conv = _conv_step(x_in, state["conv"], params["conv_w"],
                                  params["conv_b"])
    x_conv = jax.nn.silu(x_conv)
    q = jnp.einsum("bd,dhk->bhk", x_conv, params["w_q"].astype(x.dtype))
    k = jnp.einsum("bd,dhk->bhk", x_conv, params["w_k"].astype(x.dtype))
    v = jnp.einsum("bd,dhk->bhk", x_in, params["w_v"].astype(x.dtype))
    q = q / math.sqrt(dk)
    i_raw = (x_in @ params["w_i"].astype(x.dtype)
             + params["b_i"].astype(x.dtype)).astype(jnp.float32)
    lf = jax.nn.log_sigmoid(
        (x_in @ params["w_f"].astype(x.dtype)
         + params["b_f"].astype(x.dtype)).astype(jnp.float32))
    C, n, m = (state["C"].astype(jnp.float32),
               state["n"].astype(jnp.float32), state["m"])
    m_new = jnp.maximum(lf + m, i_raw)
    fg = jnp.exp(lf + m - m_new)
    ig = jnp.exp(i_raw - m_new)
    C = C * fg[..., None, None] + ig[..., None, None] * (
        k[..., :, None].astype(jnp.float32)
        * v[..., None, :].astype(jnp.float32))
    n = n * fg[..., None] + ig[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhkv,bhk->bhv", C, q.astype(jnp.float32))
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n,
                                         q.astype(jnp.float32))),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, di).astype(x.dtype)
    h = rms_norm(h, params["gn"], cfg.norm_eps)
    out = ((h * jax.nn.silu(z)) @ params["down_proj"].astype(x.dtype))[:, None]
    return out, {"C": C.astype(x.dtype), "n": n.astype(x.dtype), "m": m_new,
                 "conv": new_conv}


def init_mlstm_state(cfg: ModelConfig, batch: int,
                     dtype) -> Dict[str, jax.Array]:
    di, nh, dk = _mlstm_dims(cfg)
    dv = di // nh
    return {"C": jnp.zeros((batch, nh, dk, dv), dtype),
            "n": jnp.zeros((batch, nh, dk), dtype),
            "m": jnp.full((batch, nh), -1e9, jnp.float32),
            "conv": jnp.zeros((batch, 3, di), dtype)}


def mlstm_state_axes(cfg: ModelConfig) -> Dict[str, tuple]:
    return {"C": ("batch", "heads", None, None),
            "n": ("batch", "heads", None),
            "m": ("batch", "heads"),
            "conv": ("batch", None, "ffn")}


# ===========================================================================
# sLSTM — sequential scalar recurrence (TPU-hostile; placed sparsely)
# ===========================================================================
def _slstm_dims(cfg: ModelConfig) -> Tuple[int, int]:
    nh = cfg.ssm.slstm_heads
    return nh, cfg.d_model // nh


def init_slstm(key: jax.Array, cfg: ModelConfig,
               param_dtype) -> Tuple[PyTree, PyTree]:
    d = cfg.d_model
    nh, dh = _slstm_dims(cfg)
    b = ParamBuilder(key, param_dtype)
    # input projections for gates i,f,z,o
    b.add("w_x", (d, 4, nh, dh), ("embed", None, "heads", None))
    # block-diagonal recurrent weights per head, per gate
    b.add("r_h", (4, nh, dh, dh), (None, "heads", None, None), init="fan_in")
    b.add("bias", (4, nh, dh), (None, "heads", None), init="zeros")
    b.add("gn", (d,), (None,), init="ones")
    b.add("out_proj", (d, d), ("embed", "embed"))
    return b.params, b.axes


def _slstm_step(params_f32, carry, x_t):
    """x_t: (B,4,nh,dh) pre-projected gate inputs."""
    r_h, bias = params_f32
    c, n, h, m = carry
    gates = x_t + jnp.einsum("ghij,bhj->bghi", r_h, h) + bias     # (B,4,nh,dh)
    i_raw, f_raw, z_raw, o_raw = (gates[:, 0], gates[:, 1],
                                  gates[:, 2], gates[:, 3])
    lf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(lf + m, i_raw)
    ig = jnp.exp(i_raw - m_new)
    fg = jnp.exp(lf + m - m_new)
    c = fg * c + ig * jnp.tanh(z_raw)
    n = fg * n + ig
    h_new = jax.nn.sigmoid(o_raw) * c / jnp.maximum(n, 1e-6)
    return (c, n, h_new, m_new), h_new


def slstm_forward(params: PyTree, cfg: ModelConfig, x: jax.Array
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, S, d = x.shape
    nh, dh = _slstm_dims(cfg)
    xg = jnp.einsum("bsd,dghj->bsghj", x.astype(jnp.float32),
                    params["w_x"].astype(jnp.float32))    # (B,S,4,nh,dh)
    r_h = params["r_h"].astype(jnp.float32)
    bias = params["bias"].astype(jnp.float32)
    zeros = jnp.zeros((B, nh, dh), jnp.float32)
    carry0 = (zeros, zeros, zeros, jnp.full((B, nh, dh), -1e9, jnp.float32))
    (c, n, h, m), hs = jax.lax.scan(
        lambda carry, xt: _slstm_step((r_h, bias), carry, xt),
        carry0, xg.swapaxes(0, 1))
    hs = hs.swapaxes(0, 1).reshape(B, S, d).astype(x.dtype)
    hs = rms_norm(hs, params["gn"], cfg.norm_eps)
    out = hs @ params["out_proj"].astype(x.dtype)
    state = {"c": c.astype(x.dtype), "n": n.astype(x.dtype),
             "h": h.astype(x.dtype), "m": m}
    return out, state


def slstm_decode(params: PyTree, cfg: ModelConfig, x: jax.Array,
                 state: Dict[str, jax.Array]
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B = x.shape[0]
    nh, dh = _slstm_dims(cfg)
    xg = jnp.einsum("bd,dghj->bghj", x[:, 0].astype(jnp.float32),
                    params["w_x"].astype(jnp.float32))
    carry = (state["c"].astype(jnp.float32), state["n"].astype(jnp.float32),
             state["h"].astype(jnp.float32), state["m"])
    (c, n, h, m), h_new = _slstm_step(
        (params["r_h"].astype(jnp.float32),
         params["bias"].astype(jnp.float32)),
        carry, xg)
    hs = h_new.reshape(B, x.shape[-1]).astype(x.dtype)
    hs = rms_norm(hs, params["gn"], cfg.norm_eps)
    out = (hs @ params["out_proj"].astype(x.dtype))[:, None]
    return out, {"c": c.astype(x.dtype), "n": n.astype(x.dtype),
                 "h": h.astype(x.dtype), "m": m}


def init_slstm_state(cfg: ModelConfig, batch: int,
                     dtype) -> Dict[str, jax.Array]:
    nh, dh = _slstm_dims(cfg)
    return {"c": jnp.zeros((batch, nh, dh), dtype),
            "n": jnp.zeros((batch, nh, dh), dtype),
            "h": jnp.zeros((batch, nh, dh), dtype),
            "m": jnp.full((batch, nh, dh), -1e9, jnp.float32)}


def slstm_state_axes(cfg: ModelConfig) -> Dict[str, tuple]:
    return {"c": ("batch", "heads", None), "n": ("batch", "heads", None),
            "h": ("batch", "heads", None), "m": ("batch", "heads", None)}
