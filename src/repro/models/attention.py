"""Attention mixers: GQA/MHA (RoPE, sliding window, softcap, qk-norm, biases)
and Multi-head Latent Attention (DeepSeek-V2).

Three entry modes share one weight set:
  * full-sequence (train / prefill): returns output (+ freshly built cache)
  * decode: one query position against a pre-filled cache

The reference math here is plain einsum + fp32 softmax; the Pallas flash
kernel in ``repro.kernels`` implements the same contract for the TPU target
and is validated against :mod:`repro.kernels.ref`.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (ParamBuilder, apply_rope, make_rope,
                                 rms_norm, softcap)

PyTree = Any
NEG_INF = -2.3819763e38  # matches XLA's mask value


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def init_attention(key: jax.Array, cfg: ModelConfig,
                   param_dtype) -> Tuple[PyTree, PyTree]:
    b = ParamBuilder(key, param_dtype)
    d, nh, nkv, hd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                      cfg.resolved_head_dim)
    if cfg.mla is not None:
        m = cfg.mla
        qd = m.nope_head_dim + m.rope_head_dim
        b.add("w_q", (d, nh, qd), ("embed", "heads", None))
        b.add("w_dkv", (d, m.kv_lora_rank), ("embed", None))
        b.add("w_kr", (d, m.rope_head_dim), ("embed", None))
        b.add("kv_norm", (m.kv_lora_rank,), (None,), init="ones")
        b.add("w_uk", (m.kv_lora_rank, nh, m.nope_head_dim),
              (None, "heads", None))
        b.add("w_uv", (m.kv_lora_rank, nh, m.v_head_dim),
              (None, "heads", None))
        b.add("w_o", (nh, m.v_head_dim, d), ("heads", None, "embed"))
        return b.params, b.axes
    b.add("w_q", (d, nh, hd), ("embed", "heads", None))
    b.add("w_k", (d, nkv, hd), ("embed", "kv_heads", None))
    b.add("w_v", (d, nkv, hd), ("embed", "kv_heads", None))
    b.add("w_o", (nh, hd, d), ("heads", None, "embed"))
    if cfg.qkv_bias:
        b.add("b_q", (nh, hd), ("heads", None), init="zeros")
        b.add("b_k", (nkv, hd), ("kv_heads", None), init="zeros")
        b.add("b_v", (nkv, hd), ("kv_heads", None), init="zeros")
    if cfg.qk_norm:
        b.add("q_norm", (hd,), (None,), init="ones")
        b.add("k_norm", (hd,), (None,), init="ones")
    return b.params, b.axes


# ---------------------------------------------------------------------------
# Masking
# ---------------------------------------------------------------------------
def attention_mask(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool,
                   window: Optional[int], k_valid: Optional[jax.Array] = None
                   ) -> jax.Array:
    """Boolean (…, Sq, Sk) mask. ``window`` = sliding-window width."""
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    mask = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    if causal:
        mask &= k <= q
    if window is not None:
        mask &= k > q - window
    if k_valid is not None:
        mask &= k_valid[..., None, :]
    return mask


def _sdpa(q, k, v, mask, *, scale, cap, group: int):
    """q: (B,Sq,nkv,g,hd); k,v: (B,Sk,nkv,hd); mask (B|1,Sq,Sk)."""
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32) * scale
    logits = softcap(logits, cap)
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)


# Query-chunk size for the memory-bounded path; S >= this switches to the
# blocked implementation (never materializes an S×S score matrix).
BLOCKED_THRESHOLD = 8192
_Q_CHUNK = 512


def _sdpa_blocked(q, k, v, q_pos, k_pos, *, causal, window, scale, cap,
                  group: int, chunk: int = _Q_CHUNK):
    """Same contract as :func:`_sdpa` but scans over query chunks so the live
    score tensor is (B, nkv, g, chunk, Sk).  FLOPs identical; memory linear
    in S.  (The TPU-target flash kernel in repro.kernels additionally blocks
    the KV dim with online softmax; this host path only needs bounded memory
    for lowering and CPU validation.)"""
    B, Sq, nkv, g, hd = q.shape
    chunk = min(chunk, Sq)
    pad = (-Sq) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=-1)
    n_chunks = q.shape[1] // chunk
    qc = q.reshape(B, n_chunks, chunk, nkv, g, hd).swapaxes(0, 1)
    pc = q_pos.reshape(B, n_chunks, chunk).swapaxes(0, 1)

    def one_chunk(_, qp):
        qi, pi = qp
        mask = attention_mask(pi, k_pos, causal=causal, window=window)
        mask &= pi[..., :, None] >= 0
        return None, _sdpa(qi, k, v, mask, scale=scale, cap=cap, group=group)

    _, out = jax.lax.scan(one_chunk, None, (qc, pc))
    out = out.swapaxes(0, 1).reshape(B, n_chunks * chunk, nkv, g, hd)
    return out[:, :Sq]


# ---------------------------------------------------------------------------
# GQA forward
# ---------------------------------------------------------------------------
def _project_qkv(params, cfg: ModelConfig, x, positions):
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["w_k"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["w_v"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["b_q"].astype(x.dtype)
        k = k + params["b_k"].astype(x.dtype)
        v = v + params["b_v"].astype(x.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    cos, sin = make_rope(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def attn_forward(params: PyTree, cfg: ModelConfig, x: jax.Array, *,
                 layer_kind: str, positions: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence attention (train / prefill).  Returns (out, cache)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.mla is not None:
        return _mla_forward(params, cfg, x, positions=positions,
                            layer_kind=layer_kind)
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q, k, v = _project_qkv(params, cfg, x, positions)
    g = nh // nkv
    qg = q.reshape(B, S, nkv, g, hd)
    window = cfg.sliding_window if layer_kind == "attn_sw" else None
    scale = 1.0 / math.sqrt(hd)
    if S >= BLOCKED_THRESHOLD:
        out = _sdpa_blocked(qg, k, v, positions, positions, causal=cfg.causal,
                            window=window, scale=scale,
                            cap=cfg.attn_logit_softcap, group=g)
    else:
        mask = attention_mask(positions, positions, causal=cfg.causal,
                              window=window)
        out = _sdpa(qg, k, v, mask, scale=scale,
                    cap=cfg.attn_logit_softcap, group=g)
    out = out.reshape(B, S, nh, hd)
    out = jnp.einsum("bshk,hkd->bsd", out, params["w_o"].astype(x.dtype))
    return out, {"k": k, "v": v}


def attn_decode(params: PyTree, cfg: ModelConfig, x: jax.Array,
                cache: Dict[str, jax.Array], pos: jax.Array, *,
                layer_kind: str) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode. x: (B,1,d); cache k/v: (B,S_max,nkv,hd); pos: (B,)."""
    if cfg.mla is not None:
        return _mla_decode(params, cfg, x, cache, pos, layer_kind=layer_kind)
    B = x.shape[0]
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q, k_new, v_new = _project_qkv(params, cfg, x, pos[:, None])
    def upd(c, u, p):
        return jax.lax.dynamic_update_slice(c, u, (p, 0, 0))

    k = jax.vmap(upd)(cache["k"], k_new, pos)
    v = jax.vmap(upd)(cache["v"], v_new, pos)
    S_max = k.shape[1]
    g = nh // nkv
    qg = q.reshape(B, 1, nkv, g, hd)
    k_pos = jnp.broadcast_to(jnp.arange(S_max)[None], (B, S_max))
    window = cfg.sliding_window if layer_kind == "attn_sw" else None
    mask = attention_mask(pos[:, None], k_pos, causal=True, window=window)
    out = _sdpa(qg, k, v, mask, scale=1.0 / math.sqrt(hd),
                cap=cfg.attn_logit_softcap, group=g)
    out = out.reshape(B, 1, nh, hd)
    out = jnp.einsum("bshk,hkd->bsd", out, params["w_o"].astype(x.dtype))
    return out, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2) — cache holds the compressed latent + shared RoPE key
# ---------------------------------------------------------------------------
def _mla_qkv(params, cfg: ModelConfig, x, positions):
    m = cfg.mla
    q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"].astype(x.dtype))
    q_nope, q_rope = q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]
    cos, sin = make_rope(positions, m.rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    c_kv = x @ params["w_dkv"].astype(x.dtype)                     # (B,S,r)
    c_kv = rms_norm(c_kv, params["kv_norm"], cfg.norm_eps)
    k_rope = (x @ params["w_kr"].astype(x.dtype))[:, :, None, :]   # (B,S,1,rd)
    k_rope = apply_rope(k_rope, cos, sin)[:, :, 0]                # shared head
    return q_nope, q_rope, c_kv, k_rope


def _mla_expand_kv(params, c_kv):
    """Up-project the compressed latent into per-head keys/values."""
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv,
                        params["w_uk"].astype(c_kv.dtype))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uv"].astype(c_kv.dtype))
    return k_nope, v


def _mla_scores(params, cfg: ModelConfig, q_nope, q_rope, k_nope, k_rope, v,
                mask):
    m = cfg.mla
    scale = 1.0 / math.sqrt(m.nope_head_dim + m.rope_head_dim)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
    logits = logits + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope)
    logits = logits.astype(jnp.float32) * scale
    logits = jnp.where(mask[:, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q_nope.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return jnp.einsum("bqhd,hdo->bqo", out, params["w_o"].astype(q_nope.dtype))


def _mla_attend(params, cfg: ModelConfig, q_nope, q_rope, c_kv, k_rope, mask):
    k_nope, v = _mla_expand_kv(params, c_kv)
    return _mla_scores(params, cfg, q_nope, q_rope, k_nope, k_rope, v, mask)


def _mla_attend_blocked(params, cfg: ModelConfig, q_nope, q_rope, c_kv,
                        k_rope, q_pos, k_pos, *, causal,
                        chunk: int = _Q_CHUNK):
    B, Sq = q_nope.shape[:2]
    chunk = min(chunk, Sq)
    pad = (-Sq) % chunk
    if pad:
        padq = ((0, 0), (0, pad), (0, 0), (0, 0))
        q_nope = jnp.pad(q_nope, padq)
        q_rope = jnp.pad(q_rope, padq)
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=-1)
    n_chunks = q_nope.shape[1] // chunk

    def reshape_chunks(t):
        return t.reshape((B, n_chunks, chunk) + t.shape[2:]).swapaxes(0, 1)

    qn, qr, pc = map(reshape_chunks, (q_nope, q_rope, q_pos))
    k_nope, v = _mla_expand_kv(params, c_kv)   # hoisted: expand latent once

    def one_chunk(_, qs):
        qni, qri, pi = qs
        mask = attention_mask(pi, k_pos, causal=causal, window=None)
        mask &= pi[..., :, None] >= 0
        return None, _mla_scores(params, cfg, qni, qri, k_nope,
                                 k_rope, v, mask)

    _, out = jax.lax.scan(one_chunk, None, (qn, qr, pc))
    out = out.swapaxes(0, 1).reshape(B, n_chunks * chunk, -1)
    return out[:, :Sq]


def _mla_forward(params, cfg: ModelConfig, x, *, positions, layer_kind):
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, cfg, x, positions)
    S = x.shape[1]
    if S >= BLOCKED_THRESHOLD:
        out = _mla_attend_blocked(params, cfg, q_nope, q_rope, c_kv, k_rope,
                                  positions, positions, causal=cfg.causal)
    else:
        mask = attention_mask(positions, positions, causal=cfg.causal,
                              window=None)
        out = _mla_attend(params, cfg, q_nope, q_rope, c_kv, k_rope, mask)
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def _mla_decode(params, cfg: ModelConfig, x, cache, pos, *, layer_kind):
    B = x.shape[0]
    q_nope, q_rope, c_new, kr_new = _mla_qkv(params, cfg, x, pos[:, None])
    def upd(c, u, p):
        return jax.lax.dynamic_update_slice(c, u, (p, 0))

    c_kv = jax.vmap(upd)(cache["c_kv"], c_new, pos)
    k_rope = jax.vmap(upd)(cache["k_rope"], kr_new, pos)
    S_max = c_kv.shape[1]
    k_pos = jnp.broadcast_to(jnp.arange(S_max)[None], (B, S_max))
    mask = attention_mask(pos[:, None], k_pos, causal=True, window=None)
    out = _mla_attend(params, cfg, q_nope, q_rope, c_kv, k_rope, mask)
    return out, {"c_kv": c_kv, "k_rope": k_rope}


# ---------------------------------------------------------------------------
# Cache allocation
# ---------------------------------------------------------------------------
def init_attn_cache(cfg: ModelConfig, batch: int, s_max: int, dtype,
                    layer_kind: str = "attn") -> Dict[str, jax.Array]:
    if cfg.mla is not None:
        m = cfg.mla
        return {"c_kv": jnp.zeros((batch, s_max, m.kv_lora_rank), dtype),
                "k_rope": jnp.zeros((batch, s_max, m.rope_head_dim), dtype)}
    nkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {"k": jnp.zeros((batch, s_max, nkv, hd), dtype),
            "v": jnp.zeros((batch, s_max, nkv, hd), dtype)}


def attn_cache_axes(cfg: ModelConfig) -> Dict[str, tuple]:
    if cfg.mla is not None:
        return {"c_kv": ("batch", "kv_seq", None),
                "k_rope": ("batch", "kv_seq", None)}
    return {"k": ("batch", "kv_seq", "kv_heads", None),
            "v": ("batch", "kv_seq", "kv_heads", None)}
