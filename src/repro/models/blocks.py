"""Transformer/SSM block assembly and scan-over-layers stacking.

A block = pre-norm mixer + residual, then pre-norm FFN (dense MLP / MoE /
none) + residual; Gemma-2 style post-norms optional.  Blocks with identical
(mixer, ffn) structure repeat as a ``lax.scan`` over stacked parameters —
compile time stays flat in depth (MaxText-style).  Heterogeneous patterns
(gemma alternating, jamba 1:7+MoE) scan over the *pattern period*: one scan
step applies every entry of the pattern once.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import BlockSpec, ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (ParamBuilder, apply_mlp, init_mlp,
                                 init_rms_norm, rms_norm)

PyTree = Any


# ---------------------------------------------------------------------------
# Single block
# ---------------------------------------------------------------------------
def init_block(key: jax.Array, cfg: ModelConfig, kind: BlockSpec,
               param_dtype) -> Tuple[PyTree, PyTree]:
    mixer, ffn = kind
    b = ParamBuilder(key, param_dtype)
    init_rms_norm(b, "ln1", cfg.d_model)
    if mixer in ("attn", "attn_sw"):
        p, a = attn.init_attention(b._next_key(), cfg, param_dtype)
    elif mixer == "mamba":
        p, a = ssm_lib.init_mamba(b._next_key(), cfg, param_dtype)
    elif mixer == "mlstm":
        p, a = ssm_lib.init_mlstm(b._next_key(), cfg, param_dtype)
    elif mixer == "slstm":
        p, a = ssm_lib.init_slstm(b._next_key(), cfg, param_dtype)
    else:
        raise ValueError(mixer)
    b.attach("mixer", p, a)
    if cfg.post_block_norm:
        init_rms_norm(b, "post_ln1", cfg.d_model)
    if ffn != "none":
        init_rms_norm(b, "ln2", cfg.d_model)
        if ffn == "dense":
            p, a = init_mlp(b._next_key(), cfg.d_model, cfg.d_ff, param_dtype)
        else:
            p, a = moe_lib.init_moe(b._next_key(), cfg, param_dtype)
        b.attach("ffn", p, a)
        if cfg.post_block_norm:
            init_rms_norm(b, "post_ln2", cfg.d_model)
    return b.params, b.axes


def apply_block(params: PyTree, cfg: ModelConfig, kind: BlockSpec,
                x: jax.Array, *, mode: str,
                positions: Optional[jax.Array] = None,
                cache: Optional[PyTree] = None,
                pos: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Optional[PyTree], jax.Array]:
    """Returns (x, new_cache, moe_lb_loss).  mode: train|prefill|decode."""
    mixer, ffn = kind
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    new_cache = None
    if mixer in ("attn", "attn_sw"):
        if mode == "decode":
            out, new_cache = attn.attn_decode(params["mixer"], cfg, h, cache,
                                              pos, layer_kind=mixer)
        else:
            out, new_cache = attn.attn_forward(params["mixer"], cfg, h,
                                               layer_kind=mixer,
                                               positions=positions)
    elif mixer == "mamba":
        if mode == "decode":
            out, new_cache = ssm_lib.mamba_decode(params["mixer"],
                                                  cfg, h, cache)
        else:
            out, new_cache = ssm_lib.mamba_forward(params["mixer"], cfg, h)
    elif mixer == "mlstm":
        if mode == "decode":
            out, new_cache = ssm_lib.mlstm_decode(params["mixer"],
                                                  cfg, h, cache)
        else:
            out, new_cache = ssm_lib.mlstm_forward(params["mixer"], cfg, h)
    elif mixer == "slstm":
        if mode == "decode":
            out, new_cache = ssm_lib.slstm_decode(params["mixer"],
                                                  cfg, h, cache)
        else:
            out, new_cache = ssm_lib.slstm_forward(params["mixer"], cfg, h)
    else:
        raise ValueError(mixer)
    if cfg.post_block_norm:
        out = rms_norm(out, params["post_ln1"], cfg.norm_eps)
    x = x + out
    lb_loss = jnp.zeros((), jnp.float32)
    if ffn != "none":
        h = rms_norm(x, params["ln2"], cfg.norm_eps)
        if ffn == "dense":
            out = apply_mlp(params["ffn"], h, act=(
                jax.nn.gelu if cfg.family == "encoder" else jax.nn.silu))
        else:
            out, moe_metrics = moe_lib.apply_moe(params["ffn"], cfg, h)
            lb_loss = moe_metrics["lb_loss"]
        if cfg.post_block_norm:
            out = rms_norm(out, params["post_ln2"], cfg.norm_eps)
        x = x + out
    return x, new_cache, lb_loss


# ---------------------------------------------------------------------------
# Cache allocation per block kind
# ---------------------------------------------------------------------------
def init_block_cache(cfg: ModelConfig, kind: BlockSpec, batch: int,
                     s_max: int, dtype) -> PyTree:
    mixer, _ = kind
    if mixer in ("attn", "attn_sw"):
        return attn.init_attn_cache(cfg, batch, s_max, dtype, mixer)
    if mixer == "mamba":
        return ssm_lib.init_mamba_state(cfg, batch, dtype)
    if mixer == "mlstm":
        return ssm_lib.init_mlstm_state(cfg, batch, dtype)
    if mixer == "slstm":
        return ssm_lib.init_slstm_state(cfg, batch, dtype)
    raise ValueError(mixer)


def block_cache_axes(cfg: ModelConfig, kind: BlockSpec) -> PyTree:
    mixer, _ = kind
    if mixer in ("attn", "attn_sw"):
        return attn.attn_cache_axes(cfg)
    if mixer == "mamba":
        return ssm_lib.mamba_state_axes(cfg)
    if mixer == "mlstm":
        return ssm_lib.mlstm_state_axes(cfg)
    if mixer == "slstm":
        return ssm_lib.slstm_state_axes(cfg)
    raise ValueError(mixer)


# ---------------------------------------------------------------------------
# Stacked (scanned) layers
# ---------------------------------------------------------------------------
def make_remat(fn, policy: str):
    if policy == "none":
        return fn
    jpolicy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
               if policy == "dots" else None)
    return jax.checkpoint(fn, policy=jpolicy)


def apply_stack(params: PyTree, cfg: ModelConfig, x: jax.Array, *,
                mode: str, positions: Optional[jax.Array] = None,
                caches: Optional[PyTree] = None,
                pos: Optional[jax.Array] = None,
                remat: str = "none",
                want_cache: bool = False,
                unroll: bool = False
                ) -> Tuple[jax.Array, Optional[PyTree], jax.Array]:
    """Apply prefix blocks then the scanned pattern repeats.

    params: {"prefix_<i>": block_params, "scan": {"entry_<j>": stacked}}
    caches (decode): same structure with per-layer (stacked) caches.
    Returns (x, caches_out, total_lb_loss).
    """
    total_lb = jnp.zeros((), jnp.float32)
    caches_out: Dict[str, Any] = {}

    for i, kind in enumerate(cfg.prefix_pattern):
        c_in = caches.get(f"prefix_{i}") if caches else None
        x, c_out, lb = apply_block(params[f"prefix_{i}"], cfg, kind, x,
                                   mode=mode, positions=positions,
                                   cache=c_in, pos=pos)
        total_lb = total_lb + lb
        if want_cache or mode == "decode":
            caches_out[f"prefix_{i}"] = c_out

    n_reps = cfg.n_scan_blocks
    if n_reps == 0:
        return x, (caches_out or None), total_lb

    pattern = cfg.pattern
    need_cache = want_cache or mode == "decode"

    def body(carry, xs):
        h, lb_acc = carry
        block_params, block_caches = xs
        new_caches = []
        for j, kind in enumerate(pattern):
            c_in = block_caches[j] if block_caches is not None else None
            h, c_out, lb = apply_block(block_params[j], cfg, kind, h,
                                       mode=mode, positions=positions,
                                       cache=c_in, pos=pos)
            lb_acc = lb_acc + lb
            new_caches.append(c_out if need_cache else None)
        ys = tuple(new_caches) if need_cache else None
        return (h, lb_acc), ys

    body = make_remat(body, remat)
    scan_params = tuple(params["scan"][f"entry_{j}"]
                        for j in range(len(pattern)))
    scan_caches = (tuple(caches["scan"][f"entry_{j}"]
                         for j in range(len(pattern)))
                   if caches is not None else None)
    if unroll:
        # Python-loop unroll: identical math, every rep materialized in the
        # HLO.  Used by the dry-run cost correction — XLA's cost_analysis
        # counts a lax.scan body once regardless of trip count, so scanned
        # programs under-report flops/bytes/collectives by ~n_reps.
        ys_list = []
        carry = (x, total_lb)
        for i in range(n_reps):
            xs_i = (
                tuple(jax.tree.map(lambda t: t[i], p) for p in scan_params),
                (tuple(jax.tree.map(lambda t: t[i], c) for c in scan_caches)
                 if scan_caches is not None else None),
            )
            carry, y = body(carry, xs_i)
            ys_list.append(y)
        x, total_lb = carry
        ys = (jax.tree.map(lambda *a: jnp.stack(a, 0), *ys_list)
              if need_cache else None)
    else:
        (x, total_lb), ys = jax.lax.scan(
            body, (x, total_lb), (scan_params, scan_caches))
    if need_cache and ys is not None:
        caches_out["scan"] = {f"entry_{j}": ys[j] for j in range(len(pattern))}
    return x, (caches_out or None), total_lb


def init_stack(key: jax.Array, cfg: ModelConfig, param_dtype
               ) -> Tuple[PyTree, PyTree]:
    from repro.models.layers import stack_inits
    b = ParamBuilder(key, param_dtype)
    for i, kind in enumerate(cfg.prefix_pattern):
        p, a = init_block(b._next_key(), cfg, kind, param_dtype)
        b.attach(f"prefix_{i}", p, a)
    scan_p, scan_a = {}, {}
    for j, kind in enumerate(cfg.pattern):
        p, a = stack_inits(
            lambda k, kind=kind: init_block(k, cfg, kind, param_dtype),
            b._next_key(), cfg.n_scan_blocks)
        scan_p[f"entry_{j}"] = p
        scan_a[f"entry_{j}"] = a
    b.attach("scan", scan_p, scan_a)
    return b.params, b.axes


def init_stack_cache(cfg: ModelConfig, batch: int, s_max: int,
                     dtype) -> PyTree:
    caches: Dict[str, Any] = {}
    for i, kind in enumerate(cfg.prefix_pattern):
        caches[f"prefix_{i}"] = init_block_cache(cfg, kind, batch,
                                                 s_max, dtype)
    scan_c = {}
    for j, kind in enumerate(cfg.pattern):
        one = init_block_cache(cfg, kind, batch, s_max, dtype)
        scan_c[f"entry_{j}"] = jax.tree.map(
            lambda t: jnp.broadcast_to(
                t[None], (cfg.n_scan_blocks,) + t.shape),
            one)
    caches["scan"] = scan_c
    return caches


def stack_cache_axes(cfg: ModelConfig) -> PyTree:
    axes: Dict[str, Any] = {}
    for i, kind in enumerate(cfg.prefix_pattern):
        axes[f"prefix_{i}"] = block_cache_axes(cfg, kind)
    scan_a = {}
    def is_axes(t):
        return isinstance(t, tuple)

    for j, kind in enumerate(cfg.pattern):
        one = block_cache_axes(cfg, kind)
        scan_a[f"entry_{j}"] = jax.tree.map(
            lambda a: ("layers",) + tuple(a), one, is_leaf=is_axes)
    axes["scan"] = scan_a
    return axes
