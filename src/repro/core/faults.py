"""Deterministic fault injection for push-sum gossip.

A :class:`FaultSchedule` is the single source of truth for *who is alive* and
*what the wiring looks like* at every step: it drops nodes mid-run, rejoins
them later (from checkpoint, in the trainer), and optionally resamples the
directed topology per step (GossipGraD-style partner rotation).  Everything is
derived from ``(seed, step)`` through a counter-based Philox generator, so the
schedule is a pure function of the step index — two processes (or a resumed
checkpoint) replay the exact same failure trajectory without sharing state.

The contract with the mixing layer: every matrix this schedule emits is
column-stochastic (:func:`repro.core.topology.push_sum_matrix` renormalizes a
sender's column over its surviving receivers), so the push-sum mass invariant
``Σᵢ wᵢ = n`` holds at every step of every scenario — that invariant is what
makes fault scenarios *checkable* rather than merely survivable.

Like :class:`repro.core.schedule.CommSchedule`, the pure queries
(``active_mask`` / ``out_weights`` / ``matrix``) never mutate, while
``advance`` commits bookkeeping counters that ride the checkpoint sidecar
(``state_dict`` / ``load_state_dict``) so a resumed run reports the same
drop/rejoin totals as an uninterrupted one.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import topology as topo

Events = Dict[int, Tuple[int, ...]]      # step -> node ids
RESAMPLE_MODES = ("none", "hop", "peer")


def parse_fault_events(spec: str) -> Events:
    """Parse ``"step:id,id;step:id"`` (launch-flag syntax) into events."""
    out: Events = {}
    if not spec:
        return out
    for part in spec.split(";"):
        part = part.strip()
        if not part:
            continue
        step_s, ids_s = part.split(":")
        ids = tuple(int(i) for i in ids_s.split(",") if i.strip() != "")
        if ids:
            out[int(step_s)] = tuple(sorted(set(out.get(int(step_s), ())
                                                + ids)))
    return out


@dataclass
class FaultSchedule:
    """Seeded, deterministic drop/rejoin/resample schedule.

    ``drops[t]`` lists nodes that go down *at* step t (inactive from t
    inclusive); ``rejoins[t]`` lists nodes that come back at step t (active
    from t inclusive — rejoin wins over a same-step drop).  ``resample``:

    * ``"none"`` — static wiring from the topology's own shift set.
    * ``"hop"``  — all nodes share one freshly drawn exponential hop per
      step (a randomized one-peer exponential graph; still circulant).
    * ``"peer"`` — every node draws its *own* hop per step: genuinely
      asymmetric, column-stochastic-only wiring even with no faults.
    """
    n_nodes: int
    drops: Events = field(default_factory=dict)
    rejoins: Events = field(default_factory=dict)
    resample: str = "none"
    seed: int = 0

    # bookkeeping committed by advance(); part of the checkpoint sidecar
    steps_seen: int = 0
    drops_applied: int = 0
    rejoins_applied: int = 0

    def __post_init__(self):
        if self.resample not in RESAMPLE_MODES:
            raise ValueError(f"resample must be one of {RESAMPLE_MODES}, "
                             f"got {self.resample!r}")
        for ev in (self.drops, self.rejoins):
            for t, ids in ev.items():
                bad = [i for i in ids if not (0 <= i < self.n_nodes)]
                if bad:
                    raise ValueError(f"fault event at step {t} names nodes "
                                     f"{bad} outside [0, {self.n_nodes})")

    # -- pure queries ------------------------------------------------------
    def _rng(self, step: int) -> np.random.Generator:
        # counter-based: (seed, step) -> independent stream, no global state
        return np.random.Generator(
            np.random.Philox(key=[self.seed & 0xFFFFFFFF, step]))

    def active_mask(self, step: int) -> np.ndarray:
        """Boolean (n,) mask of live nodes at ``step`` (pure)."""
        inactive: set = set()
        for t in sorted(set(self.drops) | set(self.rejoins)):
            if t > step:
                break
            inactive |= set(self.drops.get(t, ()))
            inactive -= set(self.rejoins.get(t, ()))
        mask = np.ones(self.n_nodes, dtype=bool)
        for i in inactive:
            mask[i] = False
        return mask

    def out_weights(self, step: int
                    ) -> Optional[List[topo.ShiftWeights]]:
        """Per-node sender shift sets at ``step``; None = topology default."""
        if self.resample == "none":
            return None
        n = self.n_nodes
        if n == 1:
            return [{0: 1.0}]
        p = max(1, int(round(np.log2(n))))
        rng = self._rng(step)
        if self.resample == "hop":
            hop = 2 ** int(rng.integers(0, p)) % n
            shared = {0: 0.5} if hop == 0 else {0: 0.5, hop: 0.5}
            return [shared] * n
        # "peer": every node its own hop — asymmetric even fault-free
        hops = 2 ** rng.integers(0, p, size=n) % n
        return [({0: 0.5} if h == 0 else {0: 0.5, int(h): 0.5})
                for h in hops]

    def matrix(self, topology: str, step: int,
               shift_step: Optional[int] = None) -> np.ndarray:
        """Column-stochastic mixing matrix for the gossip round at ``step``
        (pure).  ``shift_step`` is the period-reduced index used for the
        topology's own time variation (one_peer_exp); defaults to ``step``."""
        return topo.push_sum_matrix(
            topology, self.n_nodes,
            step=step if shift_step is None else shift_step,
            active=self.active_mask(step),
            out_weights=self.out_weights(step))

    def hop_superset(self, topology: str) -> Tuple[int, ...]:
        """Every shift any sender might ever use — the static superset the
        sharded backend needs to precompute its ppermute sources once."""
        shifts: set = set()
        period = max(1, topo.schedule_period(topology, self.n_nodes))
        for k in range(period):
            shifts |= set(topo.shift_weights(topology, self.n_nodes, k))
        if self.resample != "none" and self.n_nodes > 1:
            p = max(1, int(round(np.log2(self.n_nodes))))
            shifts |= {0} | {2 ** j % self.n_nodes for j in range(p)}
        return tuple(sorted(shifts))

    def events_before(self, step: int) -> Tuple[int, int]:
        """(drops, rejoins) event counts at steps < ``step`` — what an
        uninterrupted run would have committed by then."""
        d = sum(len(ids) for t, ids in self.drops.items() if t < step)
        r = sum(len(ids) for t, ids in self.rejoins.items() if t < step)
        return d, r

    # -- stateful commit / checkpoint -------------------------------------
    def advance(self, step: int) -> np.ndarray:
        """Commit step ``step``: return the active mask and update the
        counters that ride the checkpoint sidecar."""
        mask = self.active_mask(step)
        self.steps_seen += 1
        self.drops_applied += len(self.drops.get(step, ()))
        self.rejoins_applied += len(self.rejoins.get(step, ()))
        return mask

    def state_dict(self) -> Dict[str, int]:
        return {"steps_seen": self.steps_seen,
                "drops_applied": self.drops_applied,
                "rejoins_applied": self.rejoins_applied}

    def load_state_dict(self, sd: Dict[str, int]) -> None:
        self.steps_seen = int(sd.get("steps_seen", 0))
        self.drops_applied = int(sd.get("drops_applied", 0))
        self.rejoins_applied = int(sd.get("rejoins_applied", 0))
