"""Algorithm definitions + a faithful single-process simulator.

``Decentralized`` wires a communication schedule (core.schedule) to the mixing
primitives (core.mixing) — this is what the production trainer uses.

``simulate`` is the exact-math reference: n nodes as a leading axis on one
device, dense or circulant W, reproducing paper Alg. 1/2 step-for-step.  The
logistic-regression experiments (paper Fig. 1 / §5.1) and the convergence
tests run on it.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DistConfig
from repro.core import algo as algo_registry
from repro.core import mixing, topology as topo
from repro.core.schedule import CommSchedule, make_schedule

PyTree = Any


@dataclass
class Decentralized:
    """The paper's technique as a composable object: owns the schedule and
    applies the right communication round to decentralized parameters.

    ``mesh`` (optional) rides the spec into every round — before the
    CommSpec migration this wrapper hand-forwarded a subset of the comm
    knobs and silently dropped ``mesh``/``node_axis``/``shard_mode``/
    ``model_axis``, degrading spec-carried sharded routing to stacked
    mode (ISSUE 7 regression test in tests/test_overlap.py)."""
    dist: DistConfig
    n_nodes: int
    schedule: CommSchedule = None  # type: ignore[assignment]
    mesh: Optional[jax.sharding.Mesh] = None

    def __post_init__(self):
        if self.schedule is None:
            self.schedule = make_schedule(self.dist)
        # round-invariant spec, with the compressor slots cleared: the
        # legacy communicate() arity contract (plain pytree unless a
        # compressor is passed) re-attaches them per call
        self._spec = self.dist.comm_spec(self.n_nodes, mesh=self.mesh) \
            .replace(compressor=None, global_compressor=None)

    @property
    def spec(self) -> mixing.CommSpec:
        """The round-invariant :class:`repro.core.mixing.CommSpec` this
        wrapper threads (compressor slots cleared — attach per call)."""
        return self._spec

    def phase(self, step: int) -> str:
        """Pure phase query (schedule.peek_phase) — never advances a
        stateful schedule; use :meth:`advance` for executed steps."""
        if self.n_nodes == 1:
            return "none"
        return self.schedule.peek_phase(step)

    def advance(self, step: int) -> str:
        """Phase of an *executed* step: commits stateful schedules (AGA's
        period counter).  Call once per training step, in order."""
        if self.n_nodes == 1:
            return "none"
        return self.schedule.advance(step)

    def communicate(self, params: PyTree, phase: str, step: int,
                    axis: int = 0, backend: Optional[str] = None,
                    compressor=None, ef_state: Optional[PyTree] = None,
                    seed=0, global_compressor=None) -> PyTree:
        if phase == "slowmo":
            # parameter part only; momentum handled by caller
            phase = "global"
        spec = self._spec.replace(compressor=compressor,
                                  global_compressor=global_compressor)
        if backend is not None:
            spec = spec.replace(backend=backend)
        return mixing.communicate(params, spec, phase=phase, step=step,
                                  axis=axis, ef_state=ef_state, seed=seed)


# ---------------------------------------------------------------------------
# Reference simulator (paper Alg. 1 / Alg. 2 / baselines)
# ---------------------------------------------------------------------------
def simulate(
    *,
    algorithm: str,
    grad_fn: Callable[[jax.Array, jax.Array, int], jax.Array],
    loss_fn: Callable[[jax.Array], jax.Array],
    x0: jax.Array,                      # (d,) common initial point
    n: int,
    steps: int,
    lr: Callable[[int], float] | float,
    topology: str = "ring",
    H: int = 16,
    seed: int = 0,
    slowmo_beta: float = 0.0,
    slowmo_lr: float = 1.0,
    aga_kwargs: Optional[dict] = None,
    eval_every: int = 10,
    backend: str = "reference",
    compression: str = "none",
    compression_k: int = 32,
    error_feedback: bool = False,
    global_compression: str = "none",
    push_sum: bool = False,
    fault_schedule=None,
    overlap: bool = False,
    telemetry=None,
) -> Dict[str, np.ndarray]:
    """Run ``algorithm`` on n simulated nodes; returns the trajectory of the
    node-average loss f(x̄^k) and consensus distance ‖x − x̄‖²/n.

    grad_fn(x_stacked (n,d), key, step) -> per-node stochastic grads (n,d).
    loss_fn(x̄ (d,)) -> scalar global objective f(x̄).

    ``backend="pallas"`` routes communication through the fused kernels
    (repro.kernels.mixing_pallas): the SGD half-step and the mix run as one
    pass, and at eval iterations the same pass also emits x̄ and the
    consensus residual, so the eval loop never re-reads the parameters.

    ``compression`` selects a wire compressor (repro.compress registry;
    DESIGN.md §2.3); ``error_feedback=True`` threads per-node EF memory
    through the trajectory.  The step index seeds the stochastic rounding,
    so compressed runs are reproducible per seed.  ``global_compression``
    (int8|fp8) runs the averaging phases through the compressed
    reduce-scatter → all-gather collective (DESIGN.md §2.3 "Compressed
    collectives") instead of the exact mean.

    ``push_sum=True`` (DESIGN.md §2.5) runs every round as a
    column-stochastic push-sum step with a per-node weight scalar; eval
    reads the de-biased average ``Σx/Σw`` and the output gains a per-step
    ``mass`` trajectory (``Σw``, invariantly n).  ``fault_schedule``
    (:class:`repro.core.faults.FaultSchedule`, requires push_sum) drops /
    rejoins nodes and resamples the wiring per step — each step's W is a
    host-built *runtime* operand, so the compiled step never recompiles
    across failure patterns.

    ``overlap=True`` (DESIGN.md §2.6) runs the one-step-stale pipelined
    semantics: each gossip step applies the *previous* step's buffered
    half-step iterate as the compensated correction,
    ``x_{k+1} = y_k + (W − I)·y_{k−1}`` with ``y_k = x_k − γ g_k`` and
    the warm-up buffer ``y_{−1} = x_0`` — this is the reference recursion
    the production train-step's ``start_round``/``finish_round`` pipeline
    is tested bit-for-bit against.  The mixing matrix applied at step k
    is the one of the buffer's *priming* step (time-varying topologies
    stay aligned with the wire state actually in flight; the warm-up
    round reuses step 0's shift).  Global/pod-averaging/SlowMo steps run
    synchronously and re-prime the buffer (the period boundary is the
    pipeline flush).  Composes with ``compression``/``error_feedback``
    (EF updates against the payload actually buffered) but not
    ``push_sum``.

    ``telemetry`` (an :class:`repro.obs.Telemetry`) installs the hub as
    ambient for the run — eval points emit ``step`` records, fault
    injections emit ``fault`` records, and the mixing-layer comm meters
    self-report ``comm_round`` records.  Equivalently, call inside an
    enclosing ``obs.telemetry_scope``.
    """
    if telemetry is not None:
        from repro import obs
        with obs.telemetry_scope(telemetry):
            return simulate(
                algorithm=algorithm, grad_fn=grad_fn, loss_fn=loss_fn,
                x0=x0, n=n, steps=steps, lr=lr, topology=topology, H=H,
                seed=seed, slowmo_beta=slowmo_beta, slowmo_lr=slowmo_lr,
                aga_kwargs=aga_kwargs, eval_every=eval_every,
                backend=backend, compression=compression,
                compression_k=compression_k,
                error_feedback=error_feedback,
                global_compression=global_compression,
                push_sum=push_sum, fault_schedule=fault_schedule,
                overlap=overlap, telemetry=None)
    if fault_schedule is not None:
        if not push_sum:
            raise ValueError("simulate: fault_schedule requires "
                             "push_sum=True (DESIGN.md §2.5)")
        if fault_schedule.n_nodes != n:
            raise ValueError(f"simulate: fault_schedule built for "
                             f"{fault_schedule.n_nodes} nodes, got n={n}")
    dist = DistConfig(algorithm=algorithm, topology=topology, H=H,
                      comm_backend=backend, comm_compression=compression,
                      comm_compression_k=compression_k,
                      comm_error_feedback=error_feedback,
                      comm_global_compression=global_compression,
                      push_sum=push_sum, comm_overlap=overlap,
                      **(aga_kwargs or {})).validate()
    if algorithm == "slowmo":
        dist = dataclasses.replace(dist, slowmo_beta=slowmo_beta,
                                   slowmo_lr=slowmo_lr)
    algo = Decentralized(dist, n)
    algo_impl = algo_registry.get_algorithm(algorithm, caller="simulate")
    has_payload = bool(algo_impl.payload_names())
    lr_fn = lr if callable(lr) else (lambda k: lr)
    from repro.compress import make_compressor
    compressor = make_compressor(compression, k=compression_k)
    lossy = compressor is not None and compressor.lossy
    global_comp = make_compressor(global_compression)
    glossy = global_comp is not None and global_comp.lossy
    ov_spec = algo.spec.replace(compressor=compressor,
                                global_compressor=global_comp) \
        if overlap else None
    use_pallas = backend == "pallas"
    # the fused half-step+mix kernel consumes raw grads and only the bare
    # params ride it — algorithms that transform the update (GT tracking)
    # or attach a comm payload take the generic communicate path instead
    fused_ok = use_pallas and not has_payload \
        and not algo_impl.transforms_grads
    if use_pallas:
        from repro.kernels import mixing_pallas

    x = jnp.broadcast_to(x0, (n,) + x0.shape)          # x_i^(0) identical
    # algorithm slots + mode slots (EF memory, push weight) in one dict —
    # validate() guarantees comm_error_feedback implies a lossy codec, so
    # this matches the legacy `(lossy or glossy) and error_feedback` init
    extras = algo_registry.init_extras(dist, x, n)

    def _ctx(gamma):
        return algo_registry.StepContext(dist=dist, n_nodes=n, lr=gamma)

    def _joint(extras, y):
        return algo_registry.join_payload(
            algo_impl.comm_payload(extras, y), y)

    @functools.partial(jax.jit,
                       static_argnames=("phase", "shift_step", "use_lossy"))
    def sync_step_fn(x, extras, key, k, gamma, phase, shift_step, use_lossy):
        """Synchronous round: pre_update -> half-step -> joint communicate
        (compressed when the phase's codec is lossy) -> post_round."""
        g = grad_fn(x, key, k)
        upd, extras = algo_impl.pre_update(dict(extras), g)
        extras = dict(extras)
        y = x - gamma * upd
        joint = _joint(extras, y)
        if use_lossy:
            mixed, new_ef = algo.communicate(
                joint, phase, shift_step, compressor=compressor,
                ef_state=extras.get("ef_state"), seed=k,
                global_compressor=global_comp)
            if new_ef is not None:
                extras["ef_state"] = new_ef
        else:
            mixed = algo.communicate(joint, phase, shift_step)
        new_x, extras = algo_impl.post_round(
            extras, algo_registry.wrap_mixed(mixed, has_payload), phase,
            _ctx(gamma))
        return new_x, extras

    @functools.partial(jax.jit,
                       static_argnames=("phase", "shift_step", "buf_shift"))
    def ov_step_fn(x, extras, buf, key, k, gamma, phase, shift_step,
                   buf_shift):
        """One pipelined step (DESIGN.md §2.6): the half-step iterate
        absorbs the *buffered* round on arrival (``finish_round`` with the
        buffer's priming shift), then re-primes the double buffer from
        itself; averaging phases flush synchronously."""
        g = grad_fn(x, key, k)
        upd, extras = algo_impl.pre_update(dict(extras), g)
        extras = dict(extras)
        y = x - gamma * upd
        if phase == "none":
            return y, buf, extras
        joint = _joint(extras, y)
        ef = extras.get("ef_state")
        if phase == "gossip":
            mixed = mixing.finish_round(joint, buf, ov_spec, step=buf_shift)
            buf2, ef2 = mixing.start_round(joint, ov_spec, ef_state=ef,
                                           seed=k)
            if ef2 is not None:
                extras["ef_state"] = ef2
            new_x, extras = algo_impl.post_round(
                extras, algo_registry.wrap_mixed(mixed, has_payload), phase,
                _ctx(gamma))
            return new_x, buf2, extras
        mixed, buf2, ef2 = mixing.overlap_flush(
            joint, ov_spec, phase=phase, step=shift_step, ef_state=ef,
            seed=k)
        if ef2 is not None:
            extras["ef_state"] = ef2
        new_x, extras = algo_impl.post_round(
            extras, algo_registry.wrap_mixed(mixed, has_payload), phase,
            _ctx(gamma))
        # the dense re-primed buffer aliases `mixed`; copy so returning
        # both follows the PR-7 donation-safety convention (this jit is
        # not donated, but the reference path mirrors the Trainer's)
        return new_x, jax.tree.map(jnp.copy, buf2), extras

    @functools.partial(jax.jit,
                       static_argnames=("phase", "shift_step",
                                        "with_residual"))
    def pallas_step_fn(x, key, k, gamma, phase, shift_step, with_residual):
        g = grad_fn(x, key, k)
        return mixing_pallas.fused_step_mix(
            x, g, gamma, phase=phase, topology=topology, n_nodes=n,
            step=shift_step, with_residual=with_residual)

    # Push-sum: one jitted round per phase — W and the activity mask are
    # *traced* operands, so drop / rejoin / resample never recompiles.
    # Wire compression (when enabled) applies to gossip rounds only; the
    # weight scalar and the global reset stay exact, because the de-bias
    # denominator x/w must never pass through a lossy codec.
    mass_hist: List[float] = []

    @functools.partial(jax.jit,
                       static_argnames=("phase", "use_lossy", "is_global"))
    def ps_step_fn(x, extras, key, k, gamma, W, active, phase, use_lossy,
                   is_global):
        g = grad_fn(x, key, k)
        upd, extras = algo_impl.pre_update(
            dict(extras), g * active[:, None])   # dropped nodes freeze
        extras = dict(extras)
        y = x - gamma * upd
        joint = _joint(extras, y)
        w = extras["push_weight"]
        if use_lossy:
            mixed, w2, new_ef = mixing.communicate_push_sum(
                joint, w, W=W, n_nodes=n, backend=backend,
                compressor=compressor, ef_state=extras.get("ef_state"),
                seed=k)
            if new_ef is not None:
                extras["ef_state"] = new_ef
        else:
            mixed, w2 = mixing.communicate_push_sum(joint, w, W=W,
                                                    n_nodes=n,
                                                    backend=backend)
        if is_global:
            # full-participation global round: w_i = Σw/n = 1 exactly in
            # exact arithmetic — snap to it to wash out fp drift in w
            w2 = jnp.where(jnp.all(active > 0), jnp.ones_like(w2), w2)
        extras["push_weight"] = w2
        new_x, extras = algo_impl.post_round(
            extras, algo_registry.wrap_mixed(mixed, has_payload), phase,
            _ctx(gamma))
        return new_x, extras

    @functools.partial(jax.jit, static_argnames=("phase",))
    def owned_step_fn(y, extras, gamma, phase):
        """Owned phase (SlowMo's outer step): no comm round — post_round
        consumes the half-step iterate directly, same jit boundary as the
        historical `slowmo_outer`."""
        return algo_impl.post_round(dict(extras), {"params": y}, phase,
                                    _ctx(gamma))

    eval_loss = jax.jit(loss_fn)
    key = jax.random.PRNGKey(seed)
    losses, consensus, its = [], [], []
    period = topo.schedule_period(topology, n)
    from repro.obs import get_telemetry
    tel = get_telemetry()   # ambient hub (simulate(telemetry=...) installs)

    buf = buf_shift = None
    if overlap:
        # warm-up buffer b = x_0; the warm-up round reuses step 0's shift
        buf, ef0 = mixing.start_round(_joint(extras, x), ov_spec,
                                      ef_state=extras.get("ef_state"),
                                      seed=0)
        if ef0 is not None:
            extras["ef_state"] = ef0
        buf_shift = algo.schedule.gossip_shift_step(0, period)

    for k in range(steps):
        key, sub = jax.random.split(key)
        gamma = float(lr_fn(k))
        phase = algo.advance(k)   # executed step: commit schedule state
        shift_step = algo.schedule.gossip_shift_step(k, period)
        is_eval = k % eval_every == 0 or k == steps - 1
        xbar = resid = None
        lossy_round = (lossy and phase in ("gossip", "global", "pod_avg")) \
            or (glossy and phase in ("global", "pod_avg"))
        if push_sum:
            if fault_schedule is not None:
                active = fault_schedule.advance(k)
                if tel is not None:
                    if k in fault_schedule.drops:
                        tel.emit("fault", step=k, kind="drop",
                                 nodes=list(fault_schedule.drops[k]))
                    if k in fault_schedule.rejoins:
                        tel.emit("fault", step=k, kind="rejoin",
                                 nodes=list(fault_schedule.rejoins[k]))
            else:
                active = np.ones(n, dtype=bool)
            if phase == "gossip":
                if fault_schedule is not None:
                    W = fault_schedule.matrix(topology, k,
                                              shift_step=shift_step)
                else:
                    W = topo.push_sum_matrix(topology, n, step=shift_step)
            elif phase in ("global", "pod_avg"):
                W = topo.global_push_matrix(n, active)
            else:                     # "none": identity keeps Σw checkable
                W = np.eye(n)
            # phase cycles through the schedule's bounded set; W/active
            # stay traced so fault patterns never recompile (PR 6)
            # repro: allow(RPR004)
            x, extras = ps_step_fn(x, extras, sub, k, gamma,
                                   jnp.asarray(W, jnp.float32),
                                   jnp.asarray(active, jnp.float32),
                                   phase=phase,
                                   use_lossy=lossy and phase == "gossip",
                                   is_global=phase in ("global", "pod_avg"))
            w = extras["push_weight"]
            mass_hist.append(float(jnp.sum(w)))
            if is_eval:
                xbar = jnp.sum(x, axis=0) / jnp.sum(w)  # de-biased Σx/Σw
                xd = x / w                              # per-node x_i/w_i
                f = float(eval_loss(xbar))
                algo.schedule.observe_loss(k, f)
                losses.append(f)
                consensus.append(
                    float(jnp.mean(jnp.sum((xd - xbar) ** 2, -1))))
                its.append(k)
                if tel is not None:
                    tel.emit("step", step=k, phase=phase, loss=f,
                             consensus=consensus[-1], mass=mass_hist[-1])
            elif losses:
                algo.schedule.observe_loss(k, losses[-1])
            continue
        if phase in algo_impl.owned_phases:
            # owned phase: eager grad + half-step, jitted post_round —
            # preserving the historical slowmo_outer jit boundary exactly
            g = grad_fn(x, sub, k)
            upd, extras = algo_impl.pre_update(dict(extras), g)
            x_half = x - gamma * upd
            # owned phases are a bounded subset of the schedule's phases
            # repro: allow(RPR004)
            x, extras = owned_step_fn(x_half, extras, gamma, phase=phase)
            if overlap:   # outer step is a synchronous flush: re-prime
                buf, ef2 = mixing.start_round(
                    _joint(extras, x), ov_spec,
                    ef_state=extras.get("ef_state"), seed=k)
                if ef2 is not None:
                    extras["ef_state"] = ef2
                buf_shift = shift_step
        elif overlap:
            # phase/shift/buf_shift cycle through a small bounded set, so
            # jit's value cache compiles each combination exactly once —
            # the production Trainer keys a host-side cache on the same
            # tuple (DESIGN.md §2.5); this is not a per-step recompile
            # repro: allow(RPR004)
            x, buf, extras = ov_step_fn(x, extras, buf, sub, k, gamma,
                                        phase=phase,
                                        shift_step=shift_step,
                                        buf_shift=buf_shift)
            if phase != "none":   # "none" leaves the in-flight buffer alone
                buf_shift = shift_step
        elif lossy_round:
            # phase/shift_step cycle through a small bounded set — one
            # compile per combination, not a per-step recompile
            # repro: allow(RPR004)
            x, extras = sync_step_fn(x, extras, sub, k, gamma, phase=phase,
                                     shift_step=shift_step, use_lossy=True)
        elif fused_ok and phase in ("gossip", "global", "pod_avg"):
            if is_eval:  # fused: mix + x̄ + consensus in one parameter pass
                x, xbar, resid = pallas_step_fn(x, sub, k, gamma, phase,
                                                shift_step, True)
            else:
                x = pallas_step_fn(x, sub, k, gamma, phase, shift_step,
                                   False)
        else:
            # same bounded phase/shift_step combination set as above
            # repro: allow(RPR004)
            x, extras = sync_step_fn(x, extras, sub, k, gamma, phase=phase,
                                     shift_step=shift_step,
                                     use_lossy=False)
        if is_eval:
            if xbar is None:
                xbar = jnp.mean(x, axis=0)
            f = float(eval_loss(xbar))
            algo.schedule.observe_loss(k, f)
            losses.append(f)
            consensus.append(
                float(resid) / n if resid is not None
                else float(jnp.mean(jnp.sum((x - xbar) ** 2, -1))))
            its.append(k)
            if tel is not None:
                tel.emit("step", step=k, phase=phase, loss=f,
                         consensus=consensus[-1])
        else:
            # AGA still needs a loss signal between evals; reuse last.
            if losses:
                algo.schedule.observe_loss(k, losses[-1])

    out = {
        "iteration": np.array(its),
        "loss": np.array(losses),
        "consensus": np.array(consensus),
    }
    if push_sum:
        out["mass"] = np.array(mass_hist)       # Σw per step, invariantly n
        # final (n, 1) weight scalar
        out["push_weight"] = np.asarray(extras["push_weight"])
    if hasattr(algo.schedule, "history"):
        out["H_history"] = np.array(getattr(algo.schedule, "history"))
    return out
