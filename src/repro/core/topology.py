"""Gossip topologies: mixing matrices W, connectivity β, and circulant shift
decompositions.

The paper (Assumption 3) requires a doubly-stochastic W with
``null(I-W) = span(1)`` and ``β = ‖W − (1/n)𝟙𝟙ᵀ‖₂ < 1``.  All topologies here
are circulant (ring / static exponential / one-peer exponential / full) or
2D-circulant (grid on a torus), which means ``W·x`` decomposes into a weighted
sum of cyclic shifts along the node axis:

    W·x = Σ_s  w_s · roll(x, s, node_axis)

That decomposition is the TPU-native form: each roll along a sharded mesh axis
lowers to a single ``collective-permute`` over ICI (DESIGN.md §2.1), so the
sparse W is never materialized in the hot path.  The dense matrices built here
are used by tests (roll-mixing ≡ dense-W mixing), the logistic-regression
simulator, and β computation for the roofline/transient-stage analytics.

**Push-sum / directed graphs** (DESIGN.md §2.5): the directed circulants
(``directed_ring``, ``directed_exp``) are *asymmetric* (W ≠ Wᵀ) but still
doubly stochastic under full participation — any circulant whose weights sum
to 1 is.  Genuinely column-stochastic-only matrices arise from **faults**:
:func:`push_sum_matrix` renormalizes a sender's column over its surviving
receivers when nodes drop (or when per-node topology resampling gives every
node its own out-neighbor set), which preserves column sums — the push-sum
mass invariant ``Σ w = n`` — but not row sums.  :func:`beta` handles both
regimes via the Perron vector: ``β = ‖W − π𝟙ᵀ‖₂`` with ``Wπ = π``,
``Σπ = 1``, which reduces exactly to ``‖W − J‖₂`` when W is doubly
stochastic (π = 𝟙/n).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np

# shift (along flattened node axis) -> weight
ShiftWeights = Dict[int, float]
GridShiftWeights = Dict[Tuple[int, int], float]

# every topology with a 1-D circulant shift decomposition (grid is the one
# 2-D exception); schedule_period validates against the full set so a typo'd
# topology fails loudly instead of silently running as "static, period 1"
CIRCULANT_TOPOLOGIES = ("ring", "exp", "one_peer_exp", "full",
                        "disconnected", "directed_ring", "directed_exp")
DIRECTED_TOPOLOGIES = ("directed_ring", "directed_exp")
KNOWN_TOPOLOGIES = CIRCULANT_TOPOLOGIES + ("grid",)


def _require_power_of_two(n: int, what: str) -> int:
    p = int(round(math.log2(n)))
    if 2 ** p != n:
        raise ValueError(f"{what} requires power-of-two node count, got {n}")
    return p


# ---------------------------------------------------------------------------
# Shift decompositions
# ---------------------------------------------------------------------------
def shift_weights(topology: str, n: int, step: int = 0) -> ShiftWeights:
    """Circulant decomposition of W for 1D topologies.

    ``step`` matters only for the time-varying one-peer exponential graph
    (Assran et al. 2019): at step k each node averages with the single peer
    2^(k mod log2 n) hops away.
    """
    if n == 1:
        return {0: 1.0}
    if topology == "ring":
        # Each node averages with its two ring neighbors: w = 1/3 (|N_i|=3,
        # paper §3.4).  For n == 2 the two shifts coincide.
        if n == 2:
            return {0: 1.0 / 3.0, 1: 2.0 / 3.0}
        return {0: 1.0 / 3.0, 1: 1.0 / 3.0, n - 1: 1.0 / 3.0}
    if topology == "exp":
        # Static exponential graph: neighbors at 1, 2, 4, ... hops.
        p = _require_power_of_two(n, "exp topology")
        shifts = [0] + [2 ** j for j in range(p)]
        w = 1.0 / len(shifts)
        return {s: w for s in shifts}
    if topology == "one_peer_exp":
        p = _require_power_of_two(n, "one-peer exp topology")
        hop = 2 ** (step % p)
        return {0: 0.5, hop: 0.5}
    if topology == "full":
        return {s: 1.0 / n for s in range(n)}
    if topology == "disconnected":   # W = I  => Local SGD
        return {0: 1.0}
    if topology == "directed_ring":
        # One out-neighbor, one hop downstream (SGP's directed cycle).  All
        # weights are powers of two so W @ 1 is exact in floating point: the
        # push-sum weight stays *bitwise* 1 under full participation.
        return {0: 0.5, 1: 0.5}
    if topology == "directed_exp":
        # Directed exponential graph (Assran et al. 2019): node i sends to
        # i+1, i+2, i+4, ... with dyadic weights 2^-1, 2^-2, ..., keeping
        # 2^-p for itself.  Power-of-two weights => exact row/column sums.
        p = _require_power_of_two(n, "directed exp topology")
        out: ShiftWeights = {0: 2.0 ** -p}
        for j in range(p):
            out[2 ** j] = out.get(2 ** j, 0.0) + 2.0 ** -(j + 1)
        return out
    raise ValueError(f"no 1D shift decomposition for topology {topology!r}")


def grid_shape(n: int) -> Tuple[int, int]:
    """Near-square factorization for the torus grid."""
    r = int(math.sqrt(n))
    while n % r != 0:
        r -= 1
    return r, n // r


def grid_shift_weights(n: int) -> GridShiftWeights:
    """Torus grid: nodes average with 4 neighbors (|N_i|=5, paper §3.4)."""
    r, c = grid_shape(n)
    w = 1.0 / 5.0
    out: GridShiftWeights = {(0, 0): w}
    for dr, dc in ((1, 0), (r - 1, 0), (0, 1), (0, c - 1)):
        out[(dr, dc)] = out.get((dr, dc), 0.0) + w
    return out


# ---------------------------------------------------------------------------
# Dense matrices (tests / simulator / β)
# ---------------------------------------------------------------------------
def mixing_matrix(topology: str, n: int, step: int = 0) -> np.ndarray:
    """Dense doubly-stochastic W ∈ R^{n×n} for ``topology``."""
    if topology == "grid":
        r, c = grid_shape(n)
        W = np.zeros((n, n))
        for (dr, dc), w in grid_shift_weights(n).items():
            P = np.zeros((n, n))
            for i in range(n):
                ir, ic = divmod(i, c)
                j = ((ir + dr) % r) * c + (ic + dc) % c
                P[i, j] = 1.0
            W += w * P
        return W
    W = np.zeros((n, n))
    for s, w in shift_weights(topology, n, step).items():
        W += w * np.roll(np.eye(n), s, axis=1)    # W[i, (i+s)%n] = w_s
    return W


def beta(W: np.ndarray) -> float:
    """Mixing rate of W (largest singular value of the deviation from the
    stationary projector).

    * Doubly stochastic W (paper Assumption 3 / Remark 1):
      ``β = ‖W − (1/n)𝟙𝟙ᵀ‖₂`` — the original definition, unchanged.
    * Column-stochastic-only W (push-sum, SGP): the stationary distribution
      is the Perron vector π (``Wπ = π``, ``Σπ = 1``, π ≥ 0), and the rate
      generalizes to ``β = ‖W − π𝟙ᵀ‖₂``.  For doubly stochastic W the two
      coincide exactly (π = 𝟙/n), so the old code path is kept bitwise.
    * Anything else (not even column-stochastic) has no well-defined mixing
      rate here — raise instead of silently returning ‖W − J‖₂, which the
      pre-push-sum helper did for *any* matrix.
    """
    n = W.shape[0]
    if is_doubly_stochastic(W):
        J = np.ones((n, n)) / n
        return float(np.linalg.svd(W - J, compute_uv=False)[0])
    if not is_column_stochastic(W):
        raise ValueError(
            "beta(W) needs a (column-)stochastic matrix; got one whose "
            "columns do not sum to 1")
    pi = perron_vector(W)
    return float(np.linalg.svd(W - np.outer(pi, np.ones(n)),
                               compute_uv=False)[0])


def perron_vector(W: np.ndarray) -> np.ndarray:
    """Right Perron vector of a column-stochastic W: ``Wπ = π``, ``Σπ = 1``.

    Computed from the eigendecomposition (eigenvalue closest to 1).  For a
    reducible W — e.g. a fault matrix where dropped nodes are isolated on
    identity rows — the unit eigenvalue is degenerate and *a* stationary
    vector is returned; the corresponding β is ≥ 1, which is the honest
    answer (no global consensus while nodes are partitioned).
    """
    vals, vecs = np.linalg.eig(W)
    idx = int(np.argmin(np.abs(vals - 1.0)))
    pi = np.real(vecs[:, idx])
    s = pi.sum()
    if abs(s) < 1e-12:                      # defensive: degenerate eigvec
        pi = np.abs(pi)
        s = pi.sum()
    return pi / s


def effective_beta(topology: str, n: int) -> float:
    """β for static topologies; for the time-varying one-peer exponential
    graph, the per-period contraction ‖Π_k (W_k − J)‖ (0 for power-of-2 n —
    exact averaging after log2 n steps, paper §3)."""
    if n == 1:
        return 0.0
    if topology == "one_peer_exp":
        p = _require_power_of_two(n, "one-peer exp topology")
        P = np.eye(n)
        for k in range(p):
            P = mixing_matrix(topology, n, step=k) @ P
        return beta(P) ** (1.0 / p) if beta(P) > 0 else 0.0
    return beta(mixing_matrix(topology, n))


def schedule_period(topology: str, n: int) -> int:
    """Number of distinct mixing matrices over time: 1 for static topologies,
    log2(n) for the time-varying one-peer exponential graph.  Callers reduce
    the step index modulo this before using it as a *static* jit argument —
    bounding the number of compiled gossip-step variants.

    Unknown topologies raise: the old helper returned 1 for any string,
    which silently ran a typo'd (or directed, pre-push-sum) topology as
    "static with period 1" and only failed much later in ``shift_weights``.
    """
    if topology not in KNOWN_TOPOLOGIES:
        raise ValueError(f"unknown topology {topology!r}; "
                         f"expected one of {KNOWN_TOPOLOGIES}")
    if topology == "one_peer_exp" and n > 1:
        return _require_power_of_two(n, "one-peer exp topology")
    return 1


def is_doubly_stochastic(W: np.ndarray, tol: float = 1e-9) -> bool:
    n = W.shape[0]
    ones = np.ones(n)
    return (
        bool(np.all(W >= -tol))
        and np.allclose(W @ ones, ones, atol=tol)
        and np.allclose(ones @ W, ones, atol=tol)
    )


def is_column_stochastic(W: np.ndarray, tol: float = 1e-9) -> bool:
    """Columns sum to 1 (and entries are nonnegative): the push-sum
    contract.  ``𝟙ᵀW = 𝟙ᵀ`` is exactly what conserves total mass
    ``Σᵢ wᵢ = n`` across a round ``w ← W·w``."""
    n = W.shape[0]
    ones = np.ones(n)
    return (
        bool(np.all(W >= -tol))
        and np.allclose(ones @ W, ones, atol=tol)
    )


# ---------------------------------------------------------------------------
# Push-sum: column-stochastic matrices under faults / resampling
# ---------------------------------------------------------------------------
def push_sum_matrix(topology: str, n: int, step: int = 0,
                    active: Optional[np.ndarray] = None,
                    out_weights: Optional[List[ShiftWeights]] = None,
                    ) -> np.ndarray:
    """Column-stochastic W for one push-sum round, honoring failures.

    Column j describes how (active) sender j splits its mass among its
    receivers ``(j - s) % n`` for each shift s — the transpose convention of
    :func:`mixing_matrix`, where ``W[i, (i+s) % n] = w_s`` means node i
    *receives* from s hops upstream.  Under full participation this equals
    ``mixing_matrix(topology, n, step)`` exactly.

    Faults (``active[j] == False``): the dropped node neither sends nor
    receives — its column and row collapse to ``e_j`` (it keeps its own mass,
    frozen).  An active sender whose receiver is down renormalizes its
    out-weights over the surviving receivers, keeping the column sum at 1 —
    this is the whole trick: column-stochasticity (and hence ``Σw = n``)
    survives arbitrary drop patterns, while row sums (doubly-stochasticity)
    generally do not.

    ``out_weights`` (optional, one ShiftWeights per node) lets each sender
    use its *own* shift set — per-node topology resampling à la GossipGraD
    partner rotation.  Defaults to ``shift_weights(topology, n, step)`` for
    every node.
    """
    if active is None:
        active = np.ones(n, dtype=bool)
    active = np.asarray(active, dtype=bool)
    if active.shape != (n,):
        raise ValueError(f"active mask shape {active.shape} != ({n},)")
    if out_weights is None:
        shared = shift_weights(topology, n, step)
        out_weights = [shared] * n
    if len(out_weights) != n:
        raise ValueError("out_weights must have one entry per node")
    W = np.zeros((n, n))
    for j in range(n):
        if not active[j]:
            W[j, j] = 1.0
            continue
        # surviving receivers for sender j (receiver of shift s is (j-s)%n)
        live = {s: w for s, w in out_weights[j].items()
                if active[(j - s) % n]}
        z = sum(live.values())
        if z <= 0.0:                      # all receivers down: keep own mass
            W[j, j] = 1.0
            continue
        for s, w in live.items():
            W[(j - s) % n, j] += w / z
    return W


def global_push_matrix(n: int, active: Optional[np.ndarray] = None
                       ) -> np.ndarray:
    """The PGA global round as a push-sum matrix: exact averaging of the
    joint ``(x, w)`` pair over the **active** set,
    ``W = a aᵀ/|A| + diag(1 − a)`` (dropped nodes keep their own mass).

    Column-stochastic by construction (column j sums to ``a_j + (1−a_j) =
    1``), so the mass invariant survives the global phase too; the
    de-biased read after it is ``Σ_A x / Σ_A w`` — the true active-set
    average — and under full participation it is exactly ``𝟙𝟙ᵀ/n``, which
    resets every weight to ``mean(w) = 1``.
    """
    if active is None:
        active = np.ones(n, dtype=bool)
    a = np.asarray(active, dtype=float)
    n_live = a.sum()
    if n_live == 0:
        return np.eye(n)
    return np.outer(a, a) / n_live + np.diag(1.0 - a)


# ---------------------------------------------------------------------------
# Paper quantities: C_β, D_β, transient stages (Tables 2, 3)
# ---------------------------------------------------------------------------
def c_beta(b: float, H: int) -> float:
    """C_β = Σ_{k=0}^{H-1} β^k = (1-β^H)/(1-β)."""
    if b >= 1.0:
        return float(H)
    return (1.0 - b ** H) / (1.0 - b)


def d_beta(b: float, H: int) -> float:
    """D_β = min{H, 1/(1-β)}."""
    if b >= 1.0:
        return float(H)
    return min(float(H), 1.0 / (1.0 - b))


def transient_stage(algorithm: str, n: int, b: float, H: int,
                    iid: bool = False) -> float:
    """Transient-stage length (iterations) per paper Tables 2 & 3 / App. D."""
    if algorithm == "parallel":
        return 0.0
    if algorithm == "gossip":
        g = 1.0 - b
        return n ** 3 * b ** 4 / (g ** 2 if iid else g ** 4)
    if algorithm == "local":
        return n ** 3 * (H ** 2 if iid else H ** 4)
    if algorithm in ("gossip_pga", "gossip_aga"):
        cb, db = c_beta(b, H), d_beta(b, H)
        return n ** 3 * b ** 4 * cb ** 2 * (1.0 if iid else db ** 2)
    raise ValueError(f"no transient model for {algorithm!r}")
