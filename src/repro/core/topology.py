"""Gossip topologies: mixing matrices W, connectivity β, and circulant shift
decompositions.

The paper (Assumption 3) requires a doubly-stochastic W with
``null(I-W) = span(1)`` and ``β = ‖W − (1/n)𝟙𝟙ᵀ‖₂ < 1``.  All topologies here
are circulant (ring / static exponential / one-peer exponential / full) or
2D-circulant (grid on a torus), which means ``W·x`` decomposes into a weighted
sum of cyclic shifts along the node axis:

    W·x = Σ_s  w_s · roll(x, s, node_axis)

That decomposition is the TPU-native form: each roll along a sharded mesh axis
lowers to a single ``collective-permute`` over ICI (DESIGN.md §2.1), so the
sparse W is never materialized in the hot path.  The dense matrices built here
are used by tests (roll-mixing ≡ dense-W mixing), the logistic-regression
simulator, and β computation for the roofline/transient-stage analytics.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import numpy as np

ShiftWeights = Dict[int, float]          # shift (along flattened node axis) -> weight
GridShiftWeights = Dict[Tuple[int, int], float]


def _require_power_of_two(n: int, what: str) -> int:
    p = int(round(math.log2(n)))
    if 2 ** p != n:
        raise ValueError(f"{what} requires power-of-two node count, got {n}")
    return p


# ---------------------------------------------------------------------------
# Shift decompositions
# ---------------------------------------------------------------------------
def shift_weights(topology: str, n: int, step: int = 0) -> ShiftWeights:
    """Circulant decomposition of W for 1D topologies.

    ``step`` matters only for the time-varying one-peer exponential graph
    (Assran et al. 2019): at step k each node averages with the single peer
    2^(k mod log2 n) hops away.
    """
    if n == 1:
        return {0: 1.0}
    if topology == "ring":
        # Each node averages with its two ring neighbors: w = 1/3 (|N_i|=3,
        # paper §3.4).  For n == 2 the two shifts coincide.
        if n == 2:
            return {0: 1.0 / 3.0, 1: 2.0 / 3.0}
        return {0: 1.0 / 3.0, 1: 1.0 / 3.0, n - 1: 1.0 / 3.0}
    if topology == "exp":
        # Static exponential graph: neighbors at 1, 2, 4, ... hops.
        p = _require_power_of_two(n, "exp topology")
        shifts = [0] + [2 ** j for j in range(p)]
        w = 1.0 / len(shifts)
        return {s: w for s in shifts}
    if topology == "one_peer_exp":
        p = _require_power_of_two(n, "one-peer exp topology")
        hop = 2 ** (step % p)
        return {0: 0.5, hop: 0.5}
    if topology == "full":
        return {s: 1.0 / n for s in range(n)}
    if topology == "disconnected":   # W = I  => Local SGD
        return {0: 1.0}
    raise ValueError(f"no 1D shift decomposition for topology {topology!r}")


def grid_shape(n: int) -> Tuple[int, int]:
    """Near-square factorization for the torus grid."""
    r = int(math.sqrt(n))
    while n % r != 0:
        r -= 1
    return r, n // r


def grid_shift_weights(n: int) -> GridShiftWeights:
    """Torus grid: each node averages with 4 neighbors (|N_i|=5, paper §3.4)."""
    r, c = grid_shape(n)
    w = 1.0 / 5.0
    out: GridShiftWeights = {(0, 0): w}
    for dr, dc in ((1, 0), (r - 1, 0), (0, 1), (0, c - 1)):
        out[(dr, dc)] = out.get((dr, dc), 0.0) + w
    return out


# ---------------------------------------------------------------------------
# Dense matrices (tests / simulator / β)
# ---------------------------------------------------------------------------
def mixing_matrix(topology: str, n: int, step: int = 0) -> np.ndarray:
    """Dense doubly-stochastic W ∈ R^{n×n} for ``topology``."""
    if topology == "grid":
        r, c = grid_shape(n)
        W = np.zeros((n, n))
        for (dr, dc), w in grid_shift_weights(n).items():
            P = np.zeros((n, n))
            for i in range(n):
                ir, ic = divmod(i, c)
                j = ((ir + dr) % r) * c + (ic + dc) % c
                P[i, j] = 1.0
            W += w * P
        return W
    W = np.zeros((n, n))
    for s, w in shift_weights(topology, n, step).items():
        W += w * np.roll(np.eye(n), s, axis=1)    # W[i, (i+s)%n] = w_s
    return W


def beta(W: np.ndarray) -> float:
    """β = ‖W − (1/n)𝟙𝟙ᵀ‖₂ (paper Assumption 3 / Remark 1)."""
    n = W.shape[0]
    J = np.ones((n, n)) / n
    return float(np.linalg.svd(W - J, compute_uv=False)[0])


def effective_beta(topology: str, n: int) -> float:
    """β for static topologies; for the time-varying one-peer exponential
    graph, the per-period contraction ‖Π_k (W_k − J)‖ (0 for power-of-2 n —
    exact averaging after log2 n steps, paper §3)."""
    if n == 1:
        return 0.0
    if topology == "one_peer_exp":
        p = _require_power_of_two(n, "one-peer exp topology")
        P = np.eye(n)
        for k in range(p):
            P = mixing_matrix(topology, n, step=k) @ P
        return beta(P) ** (1.0 / p) if beta(P) > 0 else 0.0
    return beta(mixing_matrix(topology, n))


def schedule_period(topology: str, n: int) -> int:
    """Number of distinct mixing matrices over time: 1 for static topologies,
    log2(n) for the time-varying one-peer exponential graph.  Callers reduce
    the step index modulo this before using it as a *static* jit argument —
    bounding the number of compiled gossip-step variants."""
    if topology == "one_peer_exp" and n > 1:
        return _require_power_of_two(n, "one-peer exp topology")
    return 1


def is_doubly_stochastic(W: np.ndarray, tol: float = 1e-9) -> bool:
    n = W.shape[0]
    ones = np.ones(n)
    return (
        bool(np.all(W >= -tol))
        and np.allclose(W @ ones, ones, atol=tol)
        and np.allclose(ones @ W, ones, atol=tol)
    )


# ---------------------------------------------------------------------------
# Paper quantities: C_β, D_β, transient stages (Tables 2, 3)
# ---------------------------------------------------------------------------
def c_beta(b: float, H: int) -> float:
    """C_β = Σ_{k=0}^{H-1} β^k = (1-β^H)/(1-β)."""
    if b >= 1.0:
        return float(H)
    return (1.0 - b ** H) / (1.0 - b)


def d_beta(b: float, H: int) -> float:
    """D_β = min{H, 1/(1-β)}."""
    if b >= 1.0:
        return float(H)
    return min(float(H), 1.0 / (1.0 - b))


def transient_stage(algorithm: str, n: int, b: float, H: int,
                    iid: bool = False) -> float:
    """Transient-stage length (iterations) per paper Tables 2 & 3 / App. D."""
    if algorithm == "parallel":
        return 0.0
    if algorithm == "gossip":
        g = 1.0 - b
        return n ** 3 * b ** 4 / (g ** 2 if iid else g ** 4)
    if algorithm == "local":
        return n ** 3 * (H ** 2 if iid else H ** 4)
    if algorithm in ("gossip_pga", "gossip_aga"):
        cb, db = c_beta(b, H), d_beta(b, H)
        return n ** 3 * b ** 4 * cb ** 2 * (1.0 if iid else db ** 2)
    raise ValueError(f"no transient model for {algorithm!r}")
