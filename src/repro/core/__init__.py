"""Core: the paper's contribution — Gossip SGD with Periodic Global Averaging.

topology.py   — mixing matrices W, β, circulant shift decompositions
mixing.py     — roll-based (pjit) + shard_map/ppermute gossip, global averaging
schedule.py   — PGA fixed period, AGA adaptive period (paper Alg. 2), baselines
algorithms.py — Decentralized wiring + the exact-math reference simulator
"""
from repro.core import algorithms, mixing, schedule, topology  # noqa: F401
from repro.core.algorithms import Decentralized, simulate  # noqa: F401
from repro.core.schedule import make_schedule  # noqa: F401
