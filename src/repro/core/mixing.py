"""Mixing primitives: gossip communication and global averaging.

Three interchangeable implementations, proven equivalent by tests, selected
by the ``backend`` argument on :func:`communicate` (DESIGN.md §2.1):

* **roll-based (pjit / GSPMD)** — ``backend="reference"``:
  ``W·x = Σ_s w_s · roll(x, s, node_axis)``.  Used inside jitted train steps
  where parameters carry a leading node axis sharded over the mesh ``data``
  (or flattened ``(pod, data)``) axis.  Each roll along the sharded axis
  lowers to one ICI ``collective-permute``; the global average lowers to an
  ``all-reduce``.  This is the proven-equivalent oracle every other path is
  tested against.

* **fused Pallas kernels** — ``backend="pallas"``
  (:mod:`repro.kernels.mixing_pallas`): the whole round (optional SGD
  half-step, mix, optional consensus residual) in one pass over parameter
  blocks — one HBM round-trip instead of ``1 + |shifts|``.  Runs in
  interpret mode on CPU (same convention as kernels/ops.py) and compiles to
  Mosaic on TPU.

* **shard_map + ppermute** — the explicit decentralized runtime: each mesh
  slot *is* a node and exchanges its block with neighbors via
  ``jax.lax.ppermute`` / ``psum``.  Semantically identical; exposed for users
  who keep per-node state unstacked.

None of the views materialize W across nodes in the sharded hot path
(DESIGN.md §2.1; the Pallas backend keeps a tiny n×n circulant factor in
VMEM, which DESIGN.md §2.1 argues is the correct single-chip encoding).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import topology as topo

PyTree = Any

BACKENDS = ("reference", "pallas")


def _check_backend(backend: str, axis: int) -> bool:
    """True if the pallas backend should handle this call."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown mixing backend {backend!r} "
                         f"(expected one of {BACKENDS})")
    if backend == "pallas" and axis != 0:
        raise ValueError("pallas mixing backend requires the node axis at "
                         "position 0 (got axis={})".format(axis))
    return backend == "pallas"


# ---------------------------------------------------------------------------
# Roll-based mixing (pjit path)
# ---------------------------------------------------------------------------
def mix_array(x: jax.Array, weights: Dict[int, float], axis: int = 0,
              comm_dtype=None) -> jax.Array:
    """(W·x) along ``axis`` for circulant W given its shift decomposition.

    ``roll(x, -s)`` moves node (i+s)'s row into slot i, matching
    ``W[i, i+s] = w_s``; under GSPMD each term is one collective-permute.

    ``comm_dtype`` (e.g. bf16): neighbor terms are cast to the wire dtype
    before the roll — the collective-permute moves half the bytes; the self
    term and the weighted sum stay in the storage dtype (the paper's
    "orthogonal quantization" hook, §2 Related Work).
    """
    acc = None
    for s, w in weights.items():
        if s == 0:
            term = x
        else:
            src = x.astype(comm_dtype) if comm_dtype is not None else x
            term = jnp.roll(src, -s, axis=axis).astype(x.dtype)
        term = term * jnp.asarray(w, dtype=x.dtype)
        acc = term if acc is None else acc + term
    return acc


def mix_array_grid(x: jax.Array, n: int, axis: int = 0) -> jax.Array:
    """Torus-grid mixing: factor the node axis into (r, c) and roll each dim."""
    r, c = topo.grid_shape(n)
    shape = x.shape
    xg = x.reshape(shape[:axis] + (r, c) + shape[axis + 1:])
    acc = None
    for (dr, dc), w in topo.grid_shift_weights(n).items():
        term = xg
        if dr:
            term = jnp.roll(term, -dr, axis=axis)
        if dc:
            term = jnp.roll(term, -dc, axis=axis + 1)
        term = term * jnp.asarray(w, dtype=x.dtype)
        acc = term if acc is None else acc + term
    return acc.reshape(shape)


def mix_pytree(params: PyTree, topology: str, n: int, step: int = 0,
               axis: int = 0, comm_dtype=None,
               backend: str = "reference") -> PyTree:
    """Gossip step ``x ← W x`` applied leaf-wise over a pytree whose leaves
    carry the node axis at ``axis``."""
    if n == 1 or topology == "disconnected":
        return params
    if _check_backend(backend, axis):
        from repro.kernels import mixing_pallas
        return mixing_pallas.fused_step_mix(
            params, phase="gossip", topology=topology, n_nodes=n, step=step,
            comm_dtype=comm_dtype)
    if topology == "grid":
        return jax.tree.map(lambda p: mix_array_grid(p, n, axis), params)
    weights = topo.shift_weights(topology, n, step)
    return jax.tree.map(lambda p: mix_array(p, weights, axis, comm_dtype),
                        params)


def global_average_pytree(params: PyTree, axis: int = 0,
                          comm_dtype=None,
                          backend: str = "reference") -> PyTree:
    """Periodic global averaging ``x ← (1/n)𝟙𝟙ᵀ x`` (All-Reduce step).
    With ``comm_dtype`` the reduction runs on wire-dtype operands — the
    all-reduce moves half the bytes (node counts are small, so bf16
    accumulation over n ≤ 32 replicas is benign)."""
    if _check_backend(backend, axis):
        from repro.kernels import mixing_pallas
        leaves = jax.tree.leaves(params)
        return mixing_pallas.global_average(params, leaves[0].shape[0],
                                            comm_dtype=comm_dtype)
    def avg(p):
        src = p.astype(comm_dtype) if comm_dtype is not None else p
        m = jnp.mean(src, axis=axis, keepdims=True)
        return jnp.broadcast_to(m, p.shape).astype(p.dtype)
    return jax.tree.map(avg, params)


def pod_average_pytree(params: PyTree, n_pods: int, axis: int = 0,
                       comm_dtype=None,
                       backend: str = "reference") -> PyTree:
    """Hierarchical averaging (beyond-paper Hier-PGA, DESIGN.md §4): exact
    average *within* each pod's block of nodes — an all-reduce over the
    cheap intra-pod ICI, leaving cross-pod DCI traffic to the (rarer)
    global step."""
    if _check_backend(backend, axis):
        from repro.kernels import mixing_pallas
        leaves = jax.tree.leaves(params)
        return mixing_pallas.pod_average(params, leaves[0].shape[0], n_pods,
                                         comm_dtype=comm_dtype)
    def avg(p):
        n = p.shape[axis]
        per = n // n_pods
        shp = p.shape[:axis] + (n_pods, per) + p.shape[axis + 1:]
        src = p.astype(comm_dtype) if comm_dtype is not None else p
        g = src.reshape(shp)
        m = jnp.mean(g, axis=axis + 1, keepdims=True)
        return jnp.broadcast_to(m, g.shape).reshape(p.shape).astype(p.dtype)
    return jax.tree.map(avg, params)


# ---------------------------------------------------------------------------
# shard_map + ppermute (explicit decentralized runtime)
# ---------------------------------------------------------------------------
def _perm_for_shift(n: int, s: int) -> Tuple[Tuple[int, int], ...]:
    # node i receives from node (i + s) mod n  => edge (src=(i+s), dst=i)
    return tuple(((i + s) % n, i) for i in range(n))


def gossip_ppermute(x: jax.Array, axis_name: str, n: int,
                    weights: Dict[int, float]) -> jax.Array:
    """W·x where each mesh slot along ``axis_name`` holds one node's block.
    Must be called inside shard_map."""
    acc = None
    for s, w in weights.items():
        if s == 0:
            term = x
        else:
            term = jax.lax.ppermute(x, axis_name, _perm_for_shift(n, s))
        term = term * jnp.asarray(w, dtype=x.dtype)
        acc = term if acc is None else acc + term
    return acc


def global_average_ppermute(x: jax.Array, axis_name) -> jax.Array:
    """All-Reduce mean over the node axis (inside shard_map)."""
    return jax.lax.pmean(x, axis_name)


def make_shard_map_mixer(mesh: jax.sharding.Mesh, axis_name: str,
                         topology: str, step: int = 0) -> Callable:
    """Build ``f(x_stacked) -> W @ x_stacked`` running as shard_map over
    ``axis_name`` — the explicit runtime equivalent of :func:`mix_pytree`."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n = mesh.shape[axis_name]
    weights = topo.shift_weights(topology, n, step)

    def node_fn(x):
        return gossip_ppermute(x, axis_name, n, weights)

    spec = P(axis_name)
    return shard_map(node_fn, mesh=mesh, in_specs=(spec,), out_specs=spec)


# ---------------------------------------------------------------------------
# Communication-op selector used by the training step
# ---------------------------------------------------------------------------
def communicate(params: PyTree, *, phase: str, topology: str, n_nodes: int,
                step: int = 0, axis: int = 0, comm_dtype=None,
                n_pods: int = 1, backend: str = "reference") -> PyTree:
    """Apply one communication round to decentralized parameters.

    phase:
      "none"    — no communication (Local SGD between syncs; Parallel SGD's
                  gradient all-reduce happens in the grad path instead)
      "gossip"  — x ← W x
      "global"  — x ← x̄ (periodic All-Reduce global averaging)
      "pod_avg" — exact average within each pod block (Hier-PGA)

    backend:
      "reference" — the roll / jnp.mean path (oracle)
      "pallas"    — fused single-pass kernels (repro.kernels.mixing_pallas)
    """
    if phase == "none" or n_nodes == 1:
        return params
    if phase == "gossip":
        return mix_pytree(params, topology, n_nodes, step=step, axis=axis,
                          comm_dtype=comm_dtype, backend=backend)
    if phase == "global":
        return global_average_pytree(params, axis=axis,
                                     comm_dtype=comm_dtype, backend=backend)
    if phase == "pod_avg":
        return pod_average_pytree(params, n_pods, axis=axis,
                                  comm_dtype=comm_dtype, backend=backend)
    raise ValueError(f"unknown communication phase {phase!r}")
