"""Mixing primitives: gossip communication and global averaging.

Three interchangeable implementations, proven equivalent by tests, selected
by the ``backend`` argument on :func:`communicate` (DESIGN.md §2.1):

* **roll-based (pjit / GSPMD)** — ``backend="reference"``:
  ``W·x = Σ_s w_s · roll(x, s, node_axis)``.  Used inside jitted train steps
  where parameters carry a leading node axis sharded over the mesh ``data``
  (or flattened ``(pod, data)``) axis.  Each roll along the sharded axis
  lowers to one ICI ``collective-permute``; the global average lowers to an
  ``all-reduce``.  This is the proven-equivalent oracle every other path is
  tested against.

* **fused Pallas kernels** — ``backend="pallas"``
  (:mod:`repro.kernels.mixing_pallas`): the whole round (optional SGD
  half-step, mix, optional consensus residual) in one pass over parameter
  blocks — one HBM round-trip instead of ``1 + |shifts|``.  Runs in
  interpret mode on CPU (same convention as kernels/ops.py) and compiles to
  Mosaic on TPU.

* **shard_map + ppermute** — the explicit decentralized runtime: each mesh
  slot *is* a node and exchanges its block with neighbors via
  ``jax.lax.ppermute`` / ``psum``.  Semantically identical; exposed for users
  who keep per-node state unstacked.

When :func:`communicate` is given a ``mesh`` whose node axis is sharded,
the pallas backend routes through :func:`communicate_sharded` — a
shard_map wrapper that halo-exchanges neighbor shard blocks via
``ppermute`` and runs the fused per-shard kernel
(:func:`repro.kernels.mixing_pallas.shard_mix_block`) on each shard's
row-block, so ``backend="pallas"`` is safe (and collective-sparse) under
mesh sharding (DESIGN.md §2.1 dispatch table).  A mesh that also carries
the tensor-parallel ``model_axis`` runs the round 2-D: the packed
state's columns are sliced over it, so every halo/psum/collective stage
touches only ``D/k_model`` columns per device.

None of the views materialize W across nodes in the sharded hot path
(DESIGN.md §2.1; the Pallas backend keeps a tiny n×n circulant factor in
VMEM, which DESIGN.md §2.1 argues is the correct single-chip encoding).

**Wire compression** (DESIGN.md §2.3): :func:`communicate` and
:func:`communicate_sharded` take ``compressor=`` /  ``ef_state=`` /
``seed=``.  A lossy compressor (repro.compress) replaces the neighbor
payload with its compressed estimate ``q`` and the round runs in the
self-compensated form ``x + (M·q − (1−d)⊙q)`` — the node's own state
stays exact, the node average is preserved for any compressor, and the
shared per-step randomness makes a constant state an exact fixed point.
``compressor=None`` (or the identity compressor) routes to the exact
pre-compression code path, bit-identically.  With a compressor the
return value is ``(mixed, new_ef_state)``.

**CommSpec** (DESIGN.md §2.6): the ~12 round-invariant knobs above are
captured once in a frozen :class:`CommSpec` —
``communicate(params, spec, phase=..., step=...)`` is the primary
signature, built canonically by ``DistConfig.comm_spec()``.  The legacy
kwarg form still works as a thin shim that builds a spec (and emits a
``DeprecationWarning``); per-round arguments (``phase``/``step``/
``axis``/``ef_state``/``seed``) stay keyword arguments.

**Async overlap** (DESIGN.md §2.6): :func:`start_round` /
:func:`finish_round` split one gossip round around the compute of the
next step — ``start_round`` captures (and compresses) the double-buffered
wire payload, ``finish_round`` issues the ppermute of the *buffered*
state inside the next step's graph and mixes on arrival as the
self-compensated correction ``x ← y + (M·b − (1−d)⊙b)`` (≡
``y + (W − I)·b``), which preserves the node average exactly for any
buffer.  Global/PGA rounds stay synchronous — :func:`overlap_flush` runs
the exact collective and re-primes the buffer at the period boundary.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import warnings
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topology as topo

PyTree = Any

BACKENDS = ("reference", "pallas")
SHARD_MODES = ("auto", "stacked", "sharded")


@dataclasses.dataclass(frozen=True)
class CommSpec:
    """Round-invariant communication configuration (DESIGN.md §2.6).

    One frozen value object carries every knob of a communication round
    that does not change between rounds — topology, node/pod counts,
    backend routing (mesh/axes/shard mode), wire dtype, and the gossip /
    global compressors — so call sites thread *one* argument instead of
    hand-forwarding ~12 kwargs (the hand-forwarding is how PR 5's
    ``model_axis`` was silently dropped by ``Decentralized.communicate``).
    Per-round values (``phase``, ``step``, ``ef_state``, ``seed``) remain
    arguments of :func:`communicate` / :func:`start_round` /
    :func:`finish_round`.

    Build it with ``DistConfig.comm_spec(n_nodes, mesh=...)`` (the
    canonical constructor) and derive variants with :meth:`replace` —
    e.g. ``spec.replace(compressor=None)`` for a round that must return
    a bare pytree instead of the ``(mixed, ef)`` tuple.
    """
    topology: str
    n_nodes: int
    n_pods: int = 1
    backend: str = "reference"
    mesh: Optional[jax.sharding.Mesh] = None
    node_axis: str = "data"
    model_axis: str = "model"
    shard_mode: str = "auto"
    leaf_threshold: Optional[int] = None
    comm_dtype: Any = None
    compressor: Any = None
    global_compressor: Any = None

    def replace(self, **kw) -> "CommSpec":
        return dataclasses.replace(self, **kw)

    def validate(self) -> "CommSpec":
        if self.backend not in BACKENDS:
            raise ValueError(f"CommSpec: unknown backend {self.backend!r} "
                             f"(expected one of {BACKENDS})")
        if self.shard_mode not in SHARD_MODES:
            raise ValueError(f"CommSpec: unknown shard_mode "
                             f"{self.shard_mode!r} "
                             f"(expected one of {SHARD_MODES})")
        if self.n_nodes < 1:
            raise ValueError("CommSpec: n_nodes must be >= 1")
        if self.n_pods < 1:
            raise ValueError("CommSpec: n_pods must be >= 1")
        return self

    @property
    def lossy(self) -> bool:
        """True when the gossip wire payload is lossy-compressed."""
        return self.compressor is not None and self.compressor.lossy

    def uses_sharded(self) -> bool:
        """True when rounds route through the shard_map + ppermute path."""
        return use_sharded_backend(self.backend, self.mesh, self.node_axis,
                                   self.shard_mode)


# ---------------------------------------------------------------------------
# Telemetry hooks (DESIGN.md §2.7): when an ambient obs.Telemetry hub is
# installed, every round entry point self-reports a `comm_round` record
# (analytic vs measured wire bytes, phase/shift/backend tags) and wraps
# itself in a tracer span.  With no hub installed the hooks are a None
# check — the hot path pays nothing.  Records emitted while *tracing*
# (inside jit) carry traced=True and appear once per compiled variant;
# per-executed-round counts come from the trainer's step records.
# ---------------------------------------------------------------------------
def _hub():
    try:
        from repro import obs
    except ImportError:                              # pragma: no cover
        return None
    return obs.get_telemetry()


def _meter(tel, params: PyTree, spec: CommSpec, *, phase: str, step: int,
           role: str, wires=None) -> None:
    """Emit one ``comm_round`` record; metering must never break a round,
    so accounting errors degrade to a warning."""
    try:
        from repro.obs import meters as obs_meters
        sharded = spec.uses_sharded()
        km = 1
        if sharded and spec.mesh is not None:
            names = node_axis_names(spec.mesh, spec.node_axis)
            km = _model_names_count(spec.mesh, spec.model_axis, names)[1]
        fields = obs_meters.comm_round_fields(
            params, phase=phase, topology=spec.topology,
            n_nodes=spec.n_nodes, step=int(step), n_pods=spec.n_pods,
            backend=spec.backend, sharded=sharded,
            comm_dtype=spec.comm_dtype, compressor=spec.compressor,
            global_compressor=spec.global_compressor, model_shards=km,
            wires=wires, role=role)
        tel.emit("comm_round", **fields)
    except Exception as e:                           # pragma: no cover
        warnings.warn(f"mixing: comm_round meter failed ({e}); "
                      f"round unaffected")


def meter_round(params: PyTree, spec: CommSpec, *, phase: str,
                step: int = 0, role: str = "round", wires=None) -> None:
    """Public metering hook for step functions whose fused kernels bypass
    :func:`communicate` (e.g. the pallas residual-fused train step): emit
    the same ``comm_round`` record the metered entry points would.  No-op
    without an ambient telemetry hub."""
    tel = _hub()
    if tel is not None:
        _meter(tel, params, spec, phase=phase, step=step, role=role,
               wires=wires)


def _fence_maybe(handle, out) -> None:
    """Fence a span on concrete round outputs; inside a jit trace the
    outputs are tracers (no device work to wait on) — skip."""
    leaves = jax.tree.leaves(out)
    if leaves and not isinstance(leaves[0], jax.core.Tracer):
        handle.fence(leaves)


def _check_backend(backend: str, axis: int,
                   caller: str = "mixing.communicate") -> bool:
    """True if the pallas backend should handle this call.

    ``caller`` names the public entry point that reached the check, so the
    raise is attributable when routed through wrappers like
    ``simulate(backend=...)`` or ``Decentralized.communicate``.
    """
    if backend not in BACKENDS:
        raise ValueError(f"{caller}: unknown mixing backend {backend!r} "
                         f"(expected one of {BACKENDS})")
    if backend == "pallas" and axis != 0:
        raise ValueError(
            f"{caller}: pallas mixing backend requires the node axis at "
            f"position 0 (got axis={axis}); pass axis=0 or select "
            f"backend='reference' for a non-leading node axis")
    return backend == "pallas"


def _check_pods(n_nodes: int, n_pods: int, caller: str) -> None:
    """pod_avg needs equal pod blocks; validated up front (before any no-op
    early return) so a bad ``n_pods`` surfaces as this message instead of
    mis-shaped pod blocks/halos deeper in the round."""
    if n_pods < 1 or n_nodes % n_pods:
        raise ValueError(
            f"{caller}: n_pods={n_pods} does not divide n_nodes={n_nodes} "
            f"— the pod_avg round needs equal pod blocks "
            f"(DistConfig.validate_nodes catches this at config time)")


def node_axis_names(mesh: jax.sharding.Mesh, node_axis: str = "data"
                    ) -> Tuple[str, ...]:
    """Mesh axis names forming the gossip node axis under
    ``DistConfig.node_axis`` semantics (launch/mesh.py): ``"data"`` flattens
    ``(pod, data)`` when a pod axis exists; ``"pod"`` gossips across pods
    only (hierarchical mode)."""
    axes = dict(mesh.shape)
    if node_axis == "data":
        return tuple(a for a in ("pod", "data") if a in axes)
    if node_axis == "pod":
        # single-pod meshes have no 'pod' axis: one gossip node, no shards
        return ("pod",) if "pod" in axes else ()
    if node_axis in axes:  # explicit mesh axis (tests / custom meshes)
        return (node_axis,)
    raise ValueError(f"node_axis must be 'data', 'pod', or a mesh axis "
                     f"name, got {node_axis!r}")


def node_shard_count(mesh: Optional[jax.sharding.Mesh],
                     node_axis: str = "data") -> int:
    """How many shards the node axis is split over on ``mesh`` (1 = local)."""
    if mesh is None:
        return 1
    names = node_axis_names(mesh, node_axis)
    return int(np.prod([mesh.shape[a] for a in names], dtype=np.int64)) \
        if names else 1


def model_axis_names(mesh: jax.sharding.Mesh, model_axis: str = "model",
                     node_names: Tuple[str, ...] = ()) -> Tuple[str, ...]:
    """Mesh axis names forming the tensor-parallel model axis for the 2-D
    ``(node, model)`` sharded comm path (``DistConfig.model_axis``): the
    named axis when it exists on ``mesh`` and is not already part of the
    node axis, else ``()`` (column-replicated, the 1-D behavior)."""
    if not model_axis:
        return ()
    axes = dict(mesh.shape)
    if model_axis in axes and model_axis not in node_names:
        return (model_axis,)
    return ()


def _model_names_count(mesh: jax.sharding.Mesh, model_axis: str,
                       node_names: Tuple[str, ...]):
    """``(mnames, k_model)`` for one sharded round — the single source of
    the model-axis resolution every sharded entry point shares."""
    mnames = model_axis_names(mesh, model_axis, node_names=node_names)
    km = int(np.prod([mesh.shape[a] for a in mnames], dtype=np.int64)) \
        if mnames else 1
    return mnames, km


def model_shard_count(mesh: Optional[jax.sharding.Mesh],
                      model_axis: str = "model",
                      node_axis: str = "data") -> int:
    """How many column slices the model axis splits the packed comm state
    into on ``mesh`` (1 = replicated columns, the pre-2-D behavior)."""
    if mesh is None:
        return 1
    names = node_axis_names(mesh, node_axis)
    return _model_names_count(mesh, model_axis, names)[1]


def use_sharded_backend(backend: str, mesh: Optional[jax.sharding.Mesh],
                        node_axis: str = "data",
                        shard_mode: str = "auto") -> bool:
    """True when ``communicate`` should route pallas through the shard_map
    wrapper: the node axis is genuinely sharded and the mode allows it."""
    if shard_mode not in SHARD_MODES:
        raise ValueError(f"unknown comm_shard_mode {shard_mode!r} "
                         f"(expected one of {SHARD_MODES})")
    if backend != "pallas" or shard_mode == "stacked":
        return False
    sharded = node_shard_count(mesh, node_axis) > 1
    if shard_mode == "sharded" and not sharded:
        raise ValueError("comm_shard_mode='sharded' requires a mesh whose "
                         "node axis spans more than one device (got "
                         "mesh="
                         f"{'None' if mesh is None else dict(mesh.shape)})")
    return sharded


# ---------------------------------------------------------------------------
# Roll-based mixing (pjit path)
# ---------------------------------------------------------------------------
def mix_array(x: jax.Array, weights: Dict[int, float], axis: int = 0,
              comm_dtype=None) -> jax.Array:
    """(W·x) along ``axis`` for circulant W given its shift decomposition.

    ``roll(x, -s)`` moves node (i+s)'s row into slot i, matching
    ``W[i, i+s] = w_s``; under GSPMD each term is one collective-permute.

    ``comm_dtype`` (e.g. bf16): neighbor terms are cast to the wire dtype
    before the roll — the collective-permute moves half the bytes; the self
    term and the weighted sum stay in the storage dtype (the paper's
    "orthogonal quantization" hook, §2 Related Work).
    """
    acc = None
    for s, w in weights.items():
        if s == 0:
            term = x
        else:
            src = x.astype(comm_dtype) if comm_dtype is not None else x
            term = jnp.roll(src, -s, axis=axis).astype(x.dtype)
        term = term * jnp.asarray(w, dtype=x.dtype)
        acc = term if acc is None else acc + term
    return acc


def mix_array_grid(x: jax.Array, n: int, axis: int = 0) -> jax.Array:
    """Torus-grid mixing: factor node axis into (r, c), roll each dim."""
    r, c = topo.grid_shape(n)
    shape = x.shape
    xg = x.reshape(shape[:axis] + (r, c) + shape[axis + 1:])
    acc = None
    for (dr, dc), w in topo.grid_shift_weights(n).items():
        term = xg
        if dr:
            term = jnp.roll(term, -dr, axis=axis)
        if dc:
            term = jnp.roll(term, -dc, axis=axis + 1)
        term = term * jnp.asarray(w, dtype=x.dtype)
        acc = term if acc is None else acc + term
    return acc.reshape(shape)


def mix_pytree(params: PyTree, topology: str, n: int, step: int = 0,
               axis: int = 0, comm_dtype=None,
               backend: str = "reference",
               leaf_threshold: Optional[int] = None) -> PyTree:
    """Gossip step ``x ← W x`` applied leaf-wise over a pytree whose leaves
    carry the node axis at ``axis``."""
    use_pallas = _check_backend(backend, axis, caller="mixing.mix_pytree")
    if n == 1 or topology == "disconnected":
        return params
    if use_pallas:
        from repro.kernels import mixing_pallas
        return mixing_pallas.fused_step_mix(
            params, phase="gossip", topology=topology, n_nodes=n, step=step,
            comm_dtype=comm_dtype, leaf_threshold=leaf_threshold)
    if topology == "grid":
        return jax.tree.map(lambda p: mix_array_grid(p, n, axis), params)
    weights = topo.shift_weights(topology, n, step)
    return jax.tree.map(lambda p: mix_array(p, weights, axis, comm_dtype),
                        params)


def _collective_round_reference(params: PyTree, compressor, ef_state,
                                seed, n_pods: int = 1):
    """Reference compressed-collective averaging round on the packed
    ``(n, D)`` state (repro.compress.collective; DESIGN.md §2.3
    "Compressed collectives").  Returns ``(mixed, new_ef_state)``."""
    from repro.compress import collective as ccol
    from repro.kernels.mixing_pallas import flatten_nodes

    xf, unflatten = flatten_nodes(params)
    ef2 = ef_unflatten = None
    if ef_state is not None:
        ef2, ef_unflatten = flatten_nodes(ef_state)
    mixed, new_e = ccol.collective_round(xf, ef2, compressor.name, seed,
                                         n_pods=n_pods)
    return unflatten(mixed), (ef_unflatten(new_e) if ef2 is not None
                              else None)


def global_average_pytree(params: PyTree, axis: int = 0,
                          comm_dtype=None,
                          backend: str = "reference",
                          leaf_threshold: Optional[int] = None,
                          compressor=None, ef_state: Optional[PyTree] = None,
                          seed=0):
    """Periodic global averaging ``x ← (1/n)𝟙𝟙ᵀ x`` (All-Reduce step).
    With ``comm_dtype`` the reduction runs on wire-dtype operands — the
    all-reduce moves half the bytes (node counts are small, so bf16
    accumulation over n ≤ 32 replicas is benign).

    With a lossy ``compressor`` (``DistConfig.comm_global_compression``)
    the round runs the compressed collective instead — the compensated
    ``x + (r − ρ)`` around a chunked reduce-scatter → all-gather of
    int8/fp8 blocks (DESIGN.md §2.3 "Compressed collectives"); the payload
    supersedes ``comm_dtype`` and the return value becomes
    ``(mixed, new_ef_state)``.
    """
    use_pallas = _check_backend(backend, axis,
                                caller="mixing.global_average_pytree")
    if compressor is not None and compressor.lossy:
        if axis != 0:
            raise ValueError("mixing.global_average_pytree: the compressed "
                             "collective requires the node axis at "
                             f"position 0 (got axis={axis})")
        if use_pallas:
            from repro.kernels import mixing_pallas
            n = jax.tree.leaves(params)[0].shape[0]
            return mixing_pallas.collective_step_mix(
                params, compressor=compressor, ef_state=ef_state, seed=seed,
                phase="global", n_nodes=n)
        return _collective_round_reference(params, compressor, ef_state,
                                           seed)
    if use_pallas:
        from repro.kernels import mixing_pallas
        leaves = jax.tree.leaves(params)
        out = mixing_pallas.global_average(params, leaves[0].shape[0],
                                           comm_dtype=comm_dtype,
                                           leaf_threshold=leaf_threshold)
        return (out, ef_state) if compressor is not None else out
    def avg(p):
        src = p.astype(comm_dtype) if comm_dtype is not None else p
        m = jnp.mean(src, axis=axis, keepdims=True)
        return jnp.broadcast_to(m, p.shape).astype(p.dtype)
    out = jax.tree.map(avg, params)
    return (out, ef_state) if compressor is not None else out


def pod_average_pytree(params: PyTree, n_pods: int, axis: int = 0,
                       comm_dtype=None,
                       backend: str = "reference",
                       leaf_threshold: Optional[int] = None,
                       compressor=None, ef_state: Optional[PyTree] = None,
                       seed=0):
    """Hierarchical averaging (beyond-paper Hier-PGA, DESIGN.md §4): exact
    average *within* each pod's block of nodes — an all-reduce over the
    cheap intra-pod ICI, leaving cross-pod DCI traffic to the (rarer)
    global step.  With a lossy ``compressor`` the intra-pod collective
    runs compressed, same contract as :func:`global_average_pytree`."""
    use_pallas = _check_backend(backend, axis,
                                caller="mixing.pod_average_pytree")
    n = jax.tree.leaves(params)[0].shape[axis]
    _check_pods(n, n_pods, "mixing.pod_average_pytree")
    if compressor is not None and compressor.lossy:
        if axis != 0:
            raise ValueError("mixing.pod_average_pytree: the compressed "
                             "collective requires the node axis at "
                             f"position 0 (got axis={axis})")
        if use_pallas:
            from repro.kernels import mixing_pallas
            return mixing_pallas.collective_step_mix(
                params, compressor=compressor, ef_state=ef_state, seed=seed,
                phase="pod_avg", n_nodes=n, n_pods=n_pods)
        return _collective_round_reference(params, compressor, ef_state,
                                           seed, n_pods=n_pods)
    if use_pallas:
        from repro.kernels import mixing_pallas
        out = mixing_pallas.pod_average(params, n, n_pods,
                                        comm_dtype=comm_dtype,
                                        leaf_threshold=leaf_threshold)
        return (out, ef_state) if compressor is not None else out
    def avg(p):
        per = p.shape[axis] // n_pods
        shp = p.shape[:axis] + (n_pods, per) + p.shape[axis + 1:]
        src = p.astype(comm_dtype) if comm_dtype is not None else p
        g = src.reshape(shp)
        m = jnp.mean(g, axis=axis + 1, keepdims=True)
        return jnp.broadcast_to(m, g.shape).reshape(p.shape).astype(p.dtype)
    out = jax.tree.map(avg, params)
    return (out, ef_state) if compressor is not None else out


# ---------------------------------------------------------------------------
# shard_map + ppermute (explicit decentralized runtime)
# ---------------------------------------------------------------------------
def _perm_for_shift(n: int, s: int) -> Tuple[Tuple[int, int], ...]:
    # node i receives from node (i + s) mod n  => edge (src=(i+s), dst=i)
    return tuple(((i + s) % n, i) for i in range(n))


def gossip_ppermute(x: jax.Array, axis_name: str, n: int,
                    weights: Dict[int, float]) -> jax.Array:
    """W·x where each mesh slot along ``axis_name`` holds one node's block.
    Must be called inside shard_map."""
    acc = None
    for s, w in weights.items():
        if s == 0:
            term = x
        else:
            term = jax.lax.ppermute(x, axis_name, _perm_for_shift(n, s))
        term = term * jnp.asarray(w, dtype=x.dtype)
        acc = term if acc is None else acc + term
    return acc


def global_average_ppermute(x: jax.Array, axis_name) -> jax.Array:
    """All-Reduce mean over the node axis (inside shard_map)."""
    return jax.lax.pmean(x, axis_name)


def make_shard_map_mixer(mesh: jax.sharding.Mesh, axis_name: str,
                         topology: str, step: int = 0) -> Callable:
    """Build ``f(x_stacked) -> W @ x_stacked`` running as shard_map over
    ``axis_name`` — the explicit runtime equivalent of :func:`mix_pytree`."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n = mesh.shape[axis_name]
    weights = topo.shift_weights(topology, n, step)

    def node_fn(x):
        return gossip_ppermute(x, axis_name, n, weights)

    spec = P(axis_name)
    return shard_map(node_fn, mesh=mesh, in_specs=(spec,), out_specs=spec)


# ---------------------------------------------------------------------------
# Compressed rounds (reference math; DESIGN.md §2.3)
# ---------------------------------------------------------------------------
def compensated_round_factors(phase: str, topology: str, n: int,
                              step: int = 0, n_pods: int = 1):
    """``(w, M)`` for the self-compensated compressed round
    ``mixed = x + (M·q − w ⊙ q)`` with ``w = 1 − diag(W)`` (= the row sums
    of M for a doubly-stochastic round, so the correction vanishes when
    every node transmits the same ``q``)."""
    from repro.kernels.mixing_pallas import phase_matrices
    d, M = phase_matrices(phase, topology, n, step=step, n_pods=n_pods)
    return (1.0 - d).astype(np.float32), M


def _compressed_round_reference(params: PyTree, q: PyTree, phase: str,
                                topology: str, n: int, step: int,
                                n_pods: int, comm_dtype=None) -> PyTree:
    """Apply ``x + (M·q − w ⊙ q)`` leaf-wise (dense M: this is the oracle
    the fused kernels are tested against; n ≤ 64 so the n×n factor is
    trivial on one host).  For the ``"global"`` phase the estimate is
    additionally wire-cast per ``comm_dtype`` — the one collective whose
    operand is not the compressed payload (DESIGN.md §2.3); the cast
    applies to *both* occurrences of q, so the constant fixed point
    survives."""
    w, M = compensated_round_factors(phase, topology, n, step, n_pods)
    wj, Mj = jnp.asarray(w), jnp.asarray(M)
    cast = comm_dtype if phase == "global" else None

    def one(x, qq):
        x2 = x.reshape(n, -1).astype(jnp.float32)
        q2 = qq.reshape(n, -1).astype(jnp.float32)
        if cast is not None:
            q2 = q2.astype(cast).astype(jnp.float32)
        corr = Mj @ q2 - wj * q2
        return (x2 + corr).reshape(x.shape).astype(x.dtype)

    return jax.tree.map(one, params, q)


def _communicate_compressed(params: PyTree, *, spec: CommSpec, ef_state,
                            seed, phase: str, step: int, axis: int):
    """Compressor-aware dispatch behind :func:`communicate` — always
    returns ``(mixed, new_ef_state)``.  ``spec.global_compressor``
    (``DistConfig.comm_global_compression``) overrides the averaging
    phases — a lossy codec with the compressed collective, the identity
    codec with the exact psum path — while ``spec.compressor`` keeps
    handling gossip rounds."""
    compressor = spec.compressor
    global_compressor = spec.global_compressor
    n_nodes, n_pods = spec.n_nodes, spec.n_pods
    if phase not in ("none", "gossip", "global", "pod_avg"):
        raise ValueError(f"unknown communication phase {phase!r}")
    if phase == "pod_avg":
        _check_pods(n_nodes, n_pods, "mixing.communicate")
    if phase == "none" or n_nodes == 1:
        return params, ef_state
    glossy = global_compressor is not None and global_compressor.lossy
    if global_compressor is not None and phase in ("global", "pod_avg"):
        if glossy:
            # the collective supersedes the gossip compressor and
            # comm_dtype for the averaging phases (DESIGN.md §2.3
            # Compressed collectives)
            if spec.uses_sharded():
                return _communicate_sharded_collective(
                    params, compressor=global_compressor, ef_state=ef_state,
                    seed=seed, phase=phase, n_nodes=n_nodes, n_pods=n_pods,
                    mesh=spec.mesh, node_axis=spec.node_axis,
                    model_axis=spec.model_axis,
                    caller="mixing.communicate")
            if phase == "global":
                return global_average_pytree(
                    params, axis=axis, backend=spec.backend,
                    compressor=global_compressor, ef_state=ef_state,
                    seed=seed)
            return pod_average_pytree(
                params, n_pods, axis=axis, backend=spec.backend,
                compressor=global_compressor, ef_state=ef_state, seed=seed)
        # identity global codec: the averaging phase runs the exact psum
        # path bit-identically.  The global codec supersedes the gossip
        # compressor for these phases exactly like a lossy codec does —
        # recursing with the lossy gossip compressor attached would run
        # the compensated-psum gossip round instead (the documented
        # contract is "exact psum path, bit-identically")
        mixed = _communicate_impl(
            params, spec.replace(compressor=None, global_compressor=None),
            phase=phase, step=step, axis=axis)
        return mixed, ef_state
    if compressor is None or not compressor.lossy:
        # identity / no gossip compressor: the exact pre-compression path,
        # bit-identically
        mixed = _communicate_impl(
            params, spec.replace(compressor=None, global_compressor=None),
            phase=phase, step=step, axis=axis)
        return mixed, ef_state
    # gossip/pod_avg: the lossy payload IS the wire, comm_dtype is
    # superseded; global: the psum operand is uncompressed fp32 sums, so
    # comm_dtype still wire-casts it on every backend (DESIGN.md §2.3)
    if spec.uses_sharded():
        return communicate_sharded(
            params, spec.replace(global_compressor=None), phase=phase,
            step=step, ef_state=ef_state, seed=seed)
    if spec.backend == "pallas":
        from repro.kernels import mixing_pallas
        return mixing_pallas.compressed_step_mix(
            params, compressor=compressor, ef_state=ef_state, seed=seed,
            phase=phase, topology=spec.topology, n_nodes=n_nodes, step=step,
            n_pods=n_pods, comm_dtype=spec.comm_dtype)
    from repro import compress as compress_mod
    q, new_ef = compress_mod.apply_tree(compressor, params, ef_state, seed)
    mixed = _compressed_round_reference(params, q, phase, spec.topology,
                                        n_nodes, step, n_pods,
                                        comm_dtype=spec.comm_dtype)
    return mixed, new_ef


# ---------------------------------------------------------------------------
# Communication-op selector used by the training step
# ---------------------------------------------------------------------------
def communicate(params: PyTree, spec: Optional[CommSpec] = None, *,
                phase: str, step: int = 0, axis: int = 0,
                ef_state: Optional[PyTree] = None, seed=0,
                topology: Optional[str] = None,
                n_nodes: Optional[int] = None, comm_dtype=None,
                n_pods: int = 1, backend: str = "reference",
                mesh: Optional[jax.sharding.Mesh] = None,
                node_axis: str = "data", shard_mode: str = "auto",
                leaf_threshold: Optional[int] = None,
                compressor=None, global_compressor=None,
                model_axis: str = "model") -> PyTree:
    """Apply one communication round to decentralized parameters.

    Primary signature: ``communicate(params, spec, phase=..., step=...)``
    with a :class:`CommSpec` carrying every round-invariant knob
    (``DistConfig.comm_spec()`` builds it canonically).  Per-round values
    — ``phase``, ``step``, ``axis``, ``ef_state``, ``seed`` — stay
    keyword arguments.  The legacy all-kwargs form
    (``communicate(params, phase=..., topology=..., n_nodes=..., ...)``)
    still works as a thin shim that builds the spec, and emits a
    ``DeprecationWarning``; mixing ``spec=`` with legacy round-invariant
    kwargs is a ``TypeError`` (derive variants with ``spec.replace``).

    phase:
      "none"    — no communication (Local SGD between syncs; Parallel SGD's
                  gradient all-reduce happens in the grad path instead)
      "gossip"  — x ← W x
      "global"  — x ← x̄ (periodic All-Reduce global averaging)
      "pod_avg" — exact average within each pod block (Hier-PGA)

    backend:
      "reference" — the roll / jnp.mean path (oracle; GSPMD handles any
                    mesh sharding transparently)
      "pallas"    — fused single-pass kernels (repro.kernels.mixing_pallas)

    With a ``mesh`` whose node axis (``node_axis`` under
    ``DistConfig.node_axis`` semantics) spans more than one device, the
    pallas backend routes through :func:`communicate_sharded` — per-shard
    fused kernels with ppermute halo exchange — unless
    ``shard_mode="stacked"`` forces the local path.  ``shard_mode``
    mirrors ``DistConfig.comm_shard_mode``: "auto" (detect), "stacked"
    (never shard), "sharded" (require a sharded mesh, else raise).

    With a ``compressor`` (repro.compress; ``DistConfig.comm_compression``)
    the wire payload is compressed and the return value becomes
    ``(mixed, new_ef_state)``: ``ef_state`` is the per-node error-feedback
    memory (None disables EF — the compensated round still keeps the self
    term exact), ``seed`` the per-round randomness key (pass the training
    step for unbiased stochastic rounding).  The identity compressor
    routes to the exact uncompressed path, bit-identically
    (DESIGN.md §2.3).

    ``global_compressor`` (``DistConfig.comm_global_compression``)
    supersedes ``compressor`` for the ``"global"``/``"pod_avg"`` phases
    (gossip rounds keep their own compressor): a lossy codec runs the
    compressed reduce-scatter → all-gather collective (DESIGN.md §2.3
    "Compressed collectives", superseding ``comm_dtype`` too), the
    identity codec routes them to the exact psum path bit-identically —
    even when the gossip ``compressor`` is lossy.  Either way the return
    value becomes ``(mixed, new_ef_state)`` like ``compressor`` does.

    ``model_axis`` (``DistConfig.model_axis``) names the tensor-parallel
    mesh axis: when present on ``mesh`` the sharded path runs 2-D — the
    packed state's columns are sliced over it, so halos/psums/collective
    stages touch only ``D/k_model`` columns per device (DESIGN.md §2.1).
    """
    if spec is not None:
        overridden = [name for name, val, default in (
            ("topology", topology, None), ("n_nodes", n_nodes, None),
            ("comm_dtype", comm_dtype, None), ("n_pods", n_pods, 1),
            ("backend", backend, "reference"), ("mesh", mesh, None),
            ("node_axis", node_axis, "data"),
            ("shard_mode", shard_mode, "auto"),
            ("leaf_threshold", leaf_threshold, None),
            ("compressor", compressor, None),
            ("global_compressor", global_compressor, None),
            ("model_axis", model_axis, "model")) if val is not default]
        if overridden:
            raise TypeError(
                "mixing.communicate: round-invariant knobs "
                f"({', '.join(overridden)}) must live on the CommSpec — "
                "derive a per-call variant with spec.replace(...) instead "
                "of mixing spec= with legacy kwargs")
        return _communicate_metered(params, spec, phase=phase, step=step,
                                    axis=axis, ef_state=ef_state, seed=seed)
    if topology is None or n_nodes is None:
        raise TypeError("mixing.communicate: pass a CommSpec "
                        "(communicate(params, spec, phase=...)) or, via the "
                        "deprecated kwargs form, both topology= and "
                        "n_nodes=")
    warnings.warn(
        "the all-kwargs form of mixing.communicate is deprecated: build a "
        "CommSpec (DistConfig.comm_spec()) and call "
        "communicate(params, spec, phase=..., step=...)",
        DeprecationWarning, stacklevel=2)
    spec = CommSpec(topology=topology, n_nodes=n_nodes, n_pods=n_pods,
                    backend=backend, mesh=mesh, node_axis=node_axis,
                    model_axis=model_axis, shard_mode=shard_mode,
                    leaf_threshold=leaf_threshold, comm_dtype=comm_dtype,
                    compressor=compressor,
                    global_compressor=global_compressor)
    return _communicate_metered(params, spec, phase=phase, step=step,
                                axis=axis, ef_state=ef_state, seed=seed)


def _communicate_metered(params: PyTree, spec: CommSpec, *, phase: str,
                         step: int = 0, axis: int = 0,
                         ef_state: Optional[PyTree] = None,
                         seed=0) -> PyTree:
    """:func:`communicate` body + telemetry: one ``comm_round`` record
    and a ``comm/round`` span per public round (internal identity/exact
    re-dispatches go straight to ``_communicate_impl`` and never
    double-report)."""
    tel = _hub()
    if tel is None:
        return _communicate_impl(params, spec, phase=phase, step=step,
                                 axis=axis, ef_state=ef_state, seed=seed)
    _meter(tel, params, spec, phase=phase, step=step, role="round")
    with tel.span("comm/round", phase=phase, shift=int(step)) as sp:
        out = _communicate_impl(params, spec, phase=phase, step=step,
                                axis=axis, ef_state=ef_state, seed=seed)
        _fence_maybe(sp, out)
    return out


def _communicate_impl(params: PyTree, spec: CommSpec, *, phase: str,
                      step: int = 0, axis: int = 0,
                      ef_state: Optional[PyTree] = None, seed=0) -> PyTree:
    """Spec-driven body of :func:`communicate` (both signature shims land
    here; internal recursions target it directly so identity/exact
    re-dispatches never re-warn)."""
    _check_backend(spec.backend, axis, caller="mixing.communicate")
    if spec.compressor is not None or spec.global_compressor is not None:
        if axis != 0:
            raise ValueError("mixing.communicate: compression requires the "
                             f"node axis at position 0 (got axis={axis})")
        return _communicate_compressed(params, spec=spec, ef_state=ef_state,
                                       seed=seed, phase=phase, step=step,
                                       axis=axis)
    if phase == "pod_avg":
        _check_pods(spec.n_nodes, spec.n_pods, "mixing.communicate")
    if phase == "none" or spec.n_nodes == 1:
        return params
    if spec.uses_sharded():
        return communicate_sharded(params, spec, phase=phase, step=step)
    if phase == "gossip":
        return mix_pytree(params, spec.topology, spec.n_nodes, step=step,
                          axis=axis, comm_dtype=spec.comm_dtype,
                          backend=spec.backend,
                          leaf_threshold=spec.leaf_threshold)
    if phase == "global":
        return global_average_pytree(params, axis=axis,
                                     comm_dtype=spec.comm_dtype,
                                     backend=spec.backend,
                                     leaf_threshold=spec.leaf_threshold)
    if phase == "pod_avg":
        return pod_average_pytree(params, spec.n_pods, axis=axis,
                                  comm_dtype=spec.comm_dtype,
                                  backend=spec.backend,
                                  leaf_threshold=spec.leaf_threshold)
    raise ValueError(f"unknown communication phase {phase!r}")


# ---------------------------------------------------------------------------
# shard_map-aware pallas path: ppermute halo exchange + per-shard kernel
# ---------------------------------------------------------------------------
def _shard_blocks(M: np.ndarray, d: np.ndarray, n: int, k: int):
    """Block decomposition of one round for k node-axis shards of m = n/k
    rows each.

    Returns ``(offsets, Mstack, dstack)``: ``offsets`` is the sorted list of
    shard offsets q such that *some* shard r has a nonzero block
    ``M[r, (r+q) mod k]`` — only those blocks are halo-exchanged;
    ``Mstack[r]`` is shard r's ``(m, |offsets|·m)`` mixing factor over the
    received blocks (circulant topologies make every row identical; pod_avg
    is block-diagonal, hence per-shard rows), and ``dstack[r]`` its rows of
    the self-weight diagonal.  Passing Mstack/dstack as shard_map inputs
    sharded over the node axis hands each shard exactly its own factor with
    no device-side gather."""
    m = n // k
    offsets = [q for q in range(k)
               if any(np.any(M[r * m:(r + 1) * m,
                              ((r + q) % k) * m:(((r + q) % k) + 1) * m])
                      for r in range(k))]
    if not offsets:  # e.g. disconnected gossip: M = 0, the round is d ⊙ x
        offsets = [0]
    Mstack = np.zeros((k, m, len(offsets) * m), np.float32)
    for r in range(k):
        for j, q in enumerate(offsets):
            c = (r + q) % k
            Mstack[r, :, j * m:(j + 1) * m] = \
                M[r * m:(r + 1) * m, c * m:(c + 1) * m]
    return offsets, Mstack, d.reshape(k, m, 1).astype(np.float32)


def communicate_sharded(params: PyTree, spec: Optional[CommSpec] = None, *,
                        phase: str, topology: Optional[str] = None,
                        n_nodes: Optional[int] = None, step: int = 0,
                        comm_dtype=None, n_pods: int = 1,
                        mesh: Optional[jax.sharding.Mesh] = None,
                        node_axis: str = "data",
                        model_axis: str = "model",
                        grads: Optional[PyTree] = None,
                        gamma=None, with_residual: bool = False,
                        block_d: int = 2048,
                        interpret: Optional[bool] = None,
                        compressor=None, ef_state: Optional[PyTree] = None,
                        seed=0, global_compressor=None):
    """One communication round with the node axis sharded over ``mesh``.

    Accepts the round-invariant knobs either on a :class:`CommSpec`
    (``communicate_sharded(params, spec, phase=..., step=...)`` — the
    ``backend``/``shard_mode``/``leaf_threshold`` fields are ignored:
    calling this function *is* the sharded routing decision) or as the
    direct kwargs below.

    The stacked ``(n, D)`` state never exists on one device: a shard_map
    over the node axis gives each shard its ``(m, D)`` row-block, the
    neighbor blocks named by the round's block decomposition arrive via
    ``jax.lax.ppermute`` (wire-cast when ``comm_dtype`` is set — the cast
    bytes are what crosses the ICI), and the fused per-shard kernel
    (:func:`repro.kernels.mixing_pallas.shard_mix_block`) applies
    ``d ⊙ x_local + M_r · xs`` in one pass.  The ``"global"`` phase skips
    the halo machinery: it is a psum of wire-cast column sums (one
    all-reduce, exactly the reference collective).

    With a ``model_axis`` present on ``mesh`` (and distinct from the node
    axis) the round runs **2-D**: the packed matrix's columns are
    additionally sliced over the model axis
    (``flatten_nodes_sharded``, in/out specs ``P(node_axes, model_axes)``),
    so each device holds an ``(m, D/k_model)`` block, every halo
    ``ppermute`` moves only the local column slice (per-device wire bytes
    drop by ``k_model``), the global psum reduces over the node axis only,
    and the per-shard kernels run on the narrower blocks unchanged
    (DESIGN.md §2.1 dispatch table).  A mesh without the model axis
    (``k_model == 1``) follows exactly the 1-D code path.

    With ``grads``/``gamma`` the SGD half-step is applied before the
    exchange (the sent blocks must be half-stepped).  With
    ``with_residual`` returns ``(mixed, x̄, Σ_i‖x_i − x̄‖²)`` where the
    consensus pieces are psum-combined from per-shard kernel partials.

    With a lossy ``compressor`` the ppermute halo exchange moves the
    **compressed wire arrays** (int8/fp8 codes, top-k values + indices,
    per-row scales) instead of the fp32 blocks — this is where the
    wire-bytes reduction physically happens — and each shard rebuilds its
    neighbors' estimates locally before the compensated per-shard kernel
    (DESIGN.md §2.3).  Returns ``(mixed, new_ef_state)``.

    With a lossy ``global_compressor`` the averaging phases route to the
    compressed reduce-scatter → all-gather collective
    (:func:`_communicate_sharded_collective`; DESIGN.md §2.3 "Compressed
    collectives"), superseding ``compressor``/``comm_dtype`` for those
    phases; same ``(mixed, new_ef_state)`` contract.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.kernels import mixing_pallas

    if spec is not None:
        topology, n_nodes = spec.topology, spec.n_nodes
        comm_dtype, n_pods = spec.comm_dtype, spec.n_pods
        mesh, node_axis = spec.mesh, spec.node_axis
        model_axis = spec.model_axis
        compressor, global_compressor = spec.compressor, \
            spec.global_compressor
    if mesh is None:
        raise ValueError("communicate_sharded: a mesh is required (pass a "
                         "CommSpec built with mesh=..., or mesh= directly)")
    if topology is None or n_nodes is None:
        raise TypeError("communicate_sharded: pass a CommSpec or both "
                        "topology= and n_nodes=")
    names = node_axis_names(mesh, node_axis)
    if not names:
        raise ValueError(f"communicate_sharded: mesh {dict(mesh.shape)} has "
                         f"no axis for node_axis={node_axis!r} — use the "
                         f"stacked path (communicate) instead")
    k = node_shard_count(mesh, node_axis)
    if n_nodes % k:
        raise ValueError(f"communicate_sharded: n_nodes={n_nodes} not "
                         f"divisible by the {k} node-axis shards of "
                         f"mesh axes {names}")
    if phase not in ("gossip", "global", "pod_avg"):
        raise ValueError(f"communicate_sharded: no sharded kernel for "
                         f"phase {phase!r}")
    if phase == "pod_avg":
        _check_pods(n_nodes, n_pods, "mixing.communicate_sharded")
    mnames, km = _model_names_count(mesh, model_axis, names)
    if global_compressor is not None and phase in ("global", "pod_avg"):
        if grads is not None or with_residual:
            raise ValueError("communicate_sharded: the compressed "
                             "collective composes with neither the fused "
                             "half-step nor the fused residual (apply the "
                             "optimizer first; consensus falls back to "
                             "train.state.consensus_distance)")
        if global_compressor.lossy:
            return _communicate_sharded_collective(
                params, compressor=global_compressor, ef_state=ef_state,
                seed=seed, phase=phase, n_nodes=n_nodes, n_pods=n_pods,
                mesh=mesh, node_axis=node_axis, model_axis=model_axis,
                caller="mixing.communicate_sharded")
        # identity collective: the averaging phase runs the exact psum
        # path, bit-identically.  The global codec supersedes the gossip
        # compressor here (identity and lossy alike), so the recursion
        # must NOT re-attach a lossy gossip compressor — that would run
        # the compensated psum instead of the documented exact one.
        mixed = communicate_sharded(
            params, phase=phase, topology=topology, n_nodes=n_nodes,
            step=step, comm_dtype=comm_dtype, n_pods=n_pods, mesh=mesh,
            node_axis=node_axis, model_axis=model_axis, block_d=block_d,
            interpret=interpret)
        return mixed, ef_state
    if compressor is not None:
        if not compressor.lossy:   # identity: exact uncompressed path
            mixed = communicate_sharded(
                params, phase=phase, topology=topology, n_nodes=n_nodes,
                step=step, comm_dtype=comm_dtype, n_pods=n_pods, mesh=mesh,
                node_axis=node_axis, model_axis=model_axis,
                block_d=block_d, interpret=interpret)
            return mixed, ef_state
        if grads is not None or with_residual:
            raise ValueError("communicate_sharded: compression composes "
                             "with neither the fused half-step nor the "
                             "fused residual (apply the optimizer first; "
                             "consensus falls back to "
                             "train.state.consensus_distance)")
        return _communicate_sharded_compressed(
            params, compressor=compressor, ef_state=ef_state, seed=seed,
            phase=phase, topology=topology, n_nodes=n_nodes, step=step,
            n_pods=n_pods, mesh=mesh, names=names, k=k, mnames=mnames,
            km=km, block_d=block_d, interpret=interpret,
            comm_dtype=comm_dtype)
    with_g = grads is not None
    if with_g and gamma is None:
        raise ValueError("grads given without gamma")
    # grid gossip ignores comm_dtype in the reference path — mirror that
    wire_dtype = None if (phase == "gossip" and topology == "grid") \
        else comm_dtype

    n = n_nodes
    xf, unflatten = mixing_pallas.flatten_nodes_sharded(params, km)
    gf = mixing_pallas.flatten_nodes_sharded(grads, km)[0] if with_g \
        else None
    # 2-D specs: rows over the node axis, columns over the model axis
    # (flatten_nodes_sharded pads so the column split is exact); km == 1
    # keeps yesterday's 1-D specs verbatim
    xspec = P(names, mnames) if mnames else P(names)
    bar_spec = P(None, mnames) if mnames else P()

    d, M = mixing_pallas.phase_matrices(phase, topology, n, step=step,
                                        n_pods=n_pods)
    offsets, Mstack, dstack = _shard_blocks(M, d, n, k)
    perms = {q: tuple(((r + q) % k, r) for r in range(k))
             for q in offsets if q}

    def half_step(xb, gb):
        if gb is None:
            return xb
        return xb - jnp.asarray(gamma, jnp.float32) * gb

    def finish(mixed, cs):
        xbar = jax.lax.psum(cs, names) / n        # (1, D/km) over nodes
        # cancellation-free consensus: Σ‖x_i − x̄‖² directly (the fused
        # Σ‖x‖² − n‖x̄‖² form loses all precision when consensus ≪ ‖x‖²);
        # the extra pass touches only the shard's local (m, D/km) block,
        # and the scalar is completed by a psum over the model slices
        resid = jax.lax.psum(jnp.sum(jnp.square(mixed - xbar)), names)
        if mnames:
            resid = jax.lax.psum(resid, mnames)
        return mixed, xbar, resid

    if phase == "global":
        # x̄ everywhere: one all-reduce of wire-cast column sums over the
        # node axis only (each model shard averages its own column slice);
        # the mixed iterate is the broadcast mean, so the residual is 0.
        def body(xb, *rest):
            x = half_step(xb, rest[0] if with_g else None)
            xw = x.astype(wire_dtype).astype(jnp.float32) \
                if wire_dtype is not None else x
            xbar = jax.lax.psum(jnp.sum(xw, axis=0, keepdims=True),
                                names) / n
            mixed = jnp.broadcast_to(xbar, x.shape)
            if with_residual:
                return mixed, xbar, jnp.zeros((), jnp.float32)
            return mixed

        in_specs = (xspec,) + ((xspec,) if with_g else ())
        operands = (xf,) + ((gf,) if with_g else ())
    else:
        def body(xb, *rest):
            idx = 0
            gb = None
            if with_g:
                gb = rest[idx]; idx += 1
            Mr, dr = rest[idx], rest[idx + 1]
            x = half_step(xb, gb)
            send = x.astype(wire_dtype) if wire_dtype is not None else x
            parts = [send if q == 0
                     else jax.lax.ppermute(send, names, perms[q])
                     for q in offsets]
            xs = jnp.concatenate(parts, axis=0).astype(jnp.float32)
            out = mixing_pallas.shard_mix_block(
                x, xs, dr[0], Mr[0], with_residual=with_residual,
                block_d=block_d, interpret=interpret)
            if with_residual:
                return finish(*out)
            return out

        in_specs = (xspec,) + ((xspec,) if with_g else ()) \
            + (P(names), P(names))
        operands = (xf,) + ((gf,) if with_g else ()) \
            + (jnp.asarray(Mstack), jnp.asarray(dstack))

    out_specs = (xspec, bar_spec, P()) if with_residual else xspec
    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)
    out = fn(*operands)

    if with_residual:
        mixed, xbar, resid = out
        return unflatten(mixed), unflatten(xbar, drop_node=True), resid
    return unflatten(out)


def _communicate_sharded_compressed(params: PyTree, *, compressor, ef_state,
                                    seed, phase: str, topology: str,
                                    n_nodes: int, step: int, n_pods: int,
                                    mesh: jax.sharding.Mesh, names, k: int,
                                    mnames=(), km: int = 1,
                                    block_d: int,
                                    interpret: Optional[bool],
                                    comm_dtype=None):
    """Compressed halo exchange: each shard compresses its own row-block
    (row-local, so it runs *outside* the shard_map under GSPMD without
    collectives), ``ppermute``s the wire arrays to the neighbors named by
    the round's block decomposition, rebuilds their estimates ``q``, and
    applies the compensated per-shard kernel
    ``x + (M_r · qs − (1 − d_r) ⊙ q_self)``.  Node-independent wire
    arrays (leading axis 1, e.g. randk's shared column indices) ride
    replicated and are never ppermuted.

    2-D meshes (``km > 1``): for the quantizer compressors (int8/fp8,
    whose code arrays share the leaf's column layout) each leaf is padded
    to a ``km`` multiple *before* compression — inert zero columns, so
    scales, column-hash randomness, and therefore every rounding decision
    on real columns are bit-stable under resharding — and the code arrays
    are column-sliced over the model axis alongside the packed matrix
    (``flatten_nodes_sharded`` chunk order, spec negotiation in
    ``models.sharding.wire_column_spec``): the ppermuted wire bytes per
    device drop by ``km``.  Sparsifier payloads (top-k/rand-k values +
    global index sets) cannot column-slice — they ride the
    model-replicated 1-D path unchanged.

    The ``"global"`` phase applies the compensation ``x + (q̄ − q)``
    around one psum of column sums over the node axis; the psum itself is
    the reference collective (compressed all-reduce would need a
    compressed collective — the documented DESIGN.md §2.3 limitation), so
    its operand is wire-cast per ``comm_dtype`` exactly like the
    uncompressed path (every backend applies the same cast to ``q``,
    keeping parity and the constant fixed point).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.kernels import mixing_pallas
    from repro.models.sharding import wire_column_spec

    n = n_nodes
    # only the quantizers' code arrays share the leaf column layout, so
    # only they can ride the model-sliced 2-D path (sparsifier index sets
    # are leaf-global); km == 1 keeps the 1-D path bit-identical
    kmq = km if (km > 1 and compressor.name in ("int8", "fp8")) else 1
    mn = mnames if kmq > 1 else ()

    wires, new_ef, chunks = _sharded_wire_build(
        params, compressor=compressor, ef_state=ef_state, seed=seed, n=n,
        kmq=kmq)

    if phase == "global":
        wire_arrs = [a for w in wires for a in (*w.payload, *w.aux)]
        wire_specs = tuple(wire_column_spec(a.shape, n, names, mn, kmq)
                           for a in wire_arrs)
        build_q = _wire_build_q(compressor, wires, chunks)
        xf, unflatten = mixing_pallas.flatten_nodes_sharded(params, kmq)
        xspec = P(names, mn) if mn else P(names)

        def body(xb, *arrs):
            q = build_q(arrs)
            if comm_dtype is not None:
                q = q.astype(comm_dtype).astype(jnp.float32)
            qbar = jax.lax.psum(jnp.sum(q, axis=0, keepdims=True), names) / n
            return xb + (qbar - q)

        fn = shard_map(body, mesh=mesh, in_specs=(xspec,) + wire_specs,
                       out_specs=xspec, check_rep=False)
        return unflatten(fn(xf, *wire_arrs)), new_ef

    out = _sharded_compensated_gossip(
        params, wires, compressor=compressor, chunks=chunks, phase=phase,
        topology=topology, n_nodes=n, step=step, n_pods=n_pods, mesh=mesh,
        names=names, k=k, mn=mn, kmq=kmq, block_d=block_d,
        interpret=interpret)
    return out, new_ef


def _sharded_wire_build(params: PyTree, *, compressor, ef_state, seed,
                        n: int, kmq: int):
    """Row-local compression of the stacked state into per-leaf wire
    arrays (+ EF update) — the ``start_round`` half of a sharded
    compressed exchange.  Compression happens on the column-padded rows
    view when model-sliced (``ccol.pad_cols`` semantics: appended zeros,
    so absmax scales and absolute-column random bits on real columns are
    unchanged and pad columns code to exact zero); row-locality means it
    runs *outside* the shard_map under GSPMD without collectives.
    Passing the 2-D views as a list keeps jax.tree leaf order == salt
    order.  Returns ``(wires, new_ef_state, chunks)`` with ``chunks`` the
    per-leaf local column widths the decode side needs."""
    from repro import compress as compress_mod
    from repro.compress.collective import pad_cols

    leaves = jax.tree.leaves(params)
    sizes = [int(np.prod(lf.shape[1:], dtype=np.int64)) for lf in leaves]
    chunks = [-(-s // kmq) for s in sizes]
    x2 = [pad_cols(lf.reshape(n, -1).astype(jnp.float32), kmq)
          for lf in leaves]
    ef_leaves = jax.tree.leaves(ef_state) if ef_state is not None else None
    e2 = None
    if ef_leaves is not None:
        e2 = [pad_cols(e.reshape(n, -1).astype(jnp.float32), kmq)
              for e in ef_leaves]
    wires, new_e2 = compress_mod.compress_tree(compressor, x2, e2, seed)
    new_ef = None
    if ef_leaves is not None:
        new_ef = jax.tree.unflatten(
            jax.tree.structure(ef_state),
            [e[:, :s].reshape(lf.shape).astype(lf.dtype)
             for e, s, lf in zip(new_e2, sizes, ef_leaves)])
    return wires, new_ef, chunks


def _wire_build_q(compressor, wires, chunks):
    """Factory for the row-block estimate rebuild: ``build_q(arrs)``
    decodes a flat list of wire arrays back into the dense
    ``(rows, D_local)`` estimate (row-local jnp; runs inside the
    shard_map body).  On the model-sliced path each code array arrives as
    its local column chunk, so the concatenation is column-aligned with
    the packed matrix's per-shard layout."""
    from repro import compress as compress_mod

    counts = [len(w.payload) + len(w.aux) for w in wires]

    def build_q(arrs):
        out, off = [], 0
        for w0, c, d_leaf in zip(wires, counts, chunks):
            grp = arrs[off:off + c]
            wire = compress_mod.LeafWire(
                payload=tuple(grp[:len(w0.payload)]),
                aux=tuple(grp[len(w0.payload):]))
            out.append(compressor.decompress_leaf(wire, d_leaf))
            off += c
        return out[0] if len(out) == 1 else jnp.concatenate(out, axis=1)

    return build_q


def _sharded_compensated_gossip(params: PyTree, wires, *, compressor,
                                chunks, phase: str, topology: str,
                                n_nodes: int, step: int, n_pods: int,
                                mesh: jax.sharding.Mesh, names, k: int,
                                mn=(), kmq: int = 1, block_d: int = 2048,
                                interpret: Optional[bool] = None) -> PyTree:
    """The ``finish_round`` half of a sharded compressed gossip round:
    ``ppermute`` the wire arrays to the neighbors named by the round's
    block decomposition, rebuild their estimates ``q``, and apply the
    compensated per-shard kernel
    ``x + (M_r · qs − (1 − d_r) ⊙ q_self)``.  Node-independent wire
    arrays (leading axis 1, e.g. randk's shared column indices) ride
    replicated and are never ppermuted.  ``wires`` may hold *stale*
    payloads (the overlap double buffer) — the compensation preserves the
    node average for any transmitted estimate, which is exactly why the
    overlapped mode reuses this round unchanged."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.kernels import mixing_pallas
    from repro.models.sharding import wire_column_spec

    n = n_nodes
    wire_arrs = [a for w in wires for a in (*w.payload, *w.aux)]
    sharded_arr = [a.shape[0] == n for a in wire_arrs]
    wire_specs = tuple(wire_column_spec(a.shape, n, names, mn, kmq)
                       for a in wire_arrs)
    build_q = _wire_build_q(compressor, wires, chunks)

    xf, unflatten = mixing_pallas.flatten_nodes_sharded(params, kmq)
    xspec = P(names, mn) if mn else P(names)
    d, M = mixing_pallas.phase_matrices(phase, topology, n, step=step,
                                        n_pods=n_pods)
    offsets, Mstack, dstack = _shard_blocks(M, d, n, k)
    wstack = (1.0 - dstack).astype(np.float32)
    perms = {q: tuple(((r + q) % k, r) for r in range(k))
             for q in offsets if q}

    def body(xb, Mr, wr, *arrs):
        q_self = build_q(arrs)
        parts = [q_self if q == 0
                 else build_q([jax.lax.ppermute(a, names, perms[q])
                               if s else a
                               for a, s in zip(arrs, sharded_arr)])
                 for q in offsets]
        qs = jnp.concatenate(parts, axis=0)
        return mixing_pallas.shard_comp_mix_block(
            xb, q_self, qs, wr[0], Mr[0], block_d=block_d,
            interpret=interpret)

    in_specs = (xspec, P(names), P(names)) + wire_specs
    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=xspec,
                   check_rep=False)
    out = fn(xf, jnp.asarray(Mstack), jnp.asarray(wstack), *wire_arrs)
    return unflatten(out)


# ---------------------------------------------------------------------------
# Async overlap: double-buffered gossip rounds (DESIGN.md §2.6)
# ---------------------------------------------------------------------------
def start_round(params: PyTree, spec: CommSpec, *,
                ef_state: Optional[PyTree] = None, seed=0):
    """Open one overlapped gossip round: capture the double-buffered wire
    payload of ``params`` that :func:`finish_round` will exchange *during
    the next step's compute* (DESIGN.md §2.6).

    Returns ``(round_state, new_ef_state)``.  ``round_state`` is a
    jit-carryable pytree (thread it through the step function / scan
    carry):

    * dense modes (no lossy gossip compressor) — ``{"q": buffer}`` where
      the buffer is ``params`` cast to ``spec.comm_dtype`` when set (the
      cast is the wire cast, applied once at capture: it halves both the
      buffer bytes held across the step and the ppermute bytes, and both
      occurrences of the buffer in the compensated apply use the same
      cast value, so the node average survives exactly);
    * lossy sharded mode — ``{"wire": [...]}`` holding the packed
      codes/scales wire arrays of each leaf (the EF update happens here,
      against the payload actually transmitted);
    * lossy stacked modes — ``{"q": estimate}`` holding the dense
      decompressed estimate (the stacked paths never materialize wire
      bytes; EF updates here too).

    The round is *issued* logically at capture: the mixing matrix
    :func:`finish_round` applies must be the one of the issuing step
    (pass the capture step's ``gossip_shift_step`` as ``step=``).
    """
    tel = _hub()
    if tel is None:
        return _start_round_impl(params, spec, ef_state=ef_state, seed=seed)
    with tel.span("comm/issue") as sp:
        out = _start_round_impl(params, spec, ef_state=ef_state, seed=seed)
        _fence_maybe(sp, out)
    _meter(tel, params, spec, phase="gossip", step=0, role="issue",
           wires=out[0].get("wire") if isinstance(out[0], dict) else None)
    return out


def _start_round_impl(params: PyTree, spec: CommSpec, *,
                      ef_state: Optional[PyTree] = None, seed=0):
    n = spec.n_nodes
    if n == 1 or not spec.lossy:
        buf = params
        if spec.comm_dtype is not None and n > 1:
            buf = jax.tree.map(lambda p: p.astype(spec.comm_dtype), params)
        return {"q": buf}, ef_state
    if spec.uses_sharded():
        names = node_axis_names(spec.mesh, spec.node_axis)
        mnames, km = _model_names_count(spec.mesh, spec.model_axis, names)
        kmq = km if (km > 1 and spec.compressor.name in ("int8", "fp8")) \
            else 1
        wires, new_ef, _ = _sharded_wire_build(
            params, compressor=spec.compressor, ef_state=ef_state,
            seed=seed, n=n, kmq=kmq)
        return {"wire": [{"payload": tuple(w.payload),
                          "aux": tuple(w.aux)} for w in wires]}, new_ef
    from repro import compress as compress_mod
    q, new_ef = compress_mod.apply_tree(spec.compressor, params, ef_state,
                                        seed)
    return {"q": q}, new_ef


def finish_round(params: PyTree, round_state, spec: CommSpec, *,
                 step: int = 0, block_d: int = 2048,
                 interpret: Optional[bool] = None) -> PyTree:
    """Close the overlapped gossip round opened by :func:`start_round`:
    exchange the buffered payload ``b`` and mix it on arrival into the
    current iterate as the self-compensated correction

        ``x ← params + (M·b − (1 − diag W) ⊙ b)``  (≡ ``params + (W−I)·b``)

    which preserves the node average exactly for *any* buffer — in
    particular the one-step-stale one, giving the reference recursion
    ``x_{t+1} = (x_t − γ g_t) + (W − I)(x_{t−1} − γ g_{t−1})``
    (DESIGN.md §2.6).  ``step`` must be the shift step of the *issuing*
    step (the one that called ``start_round``).  Only gossip rounds
    overlap; global/pod-averaging phases flush via
    :func:`overlap_flush`.
    """
    tel = _hub()
    if tel is None:
        return _finish_round_impl(params, round_state, spec, step=step,
                                  block_d=block_d, interpret=interpret)
    _meter(tel, params, spec, phase="gossip", step=step, role="apply",
           wires=round_state.get("wire")
           if isinstance(round_state, dict) else None)
    with tel.span("comm/apply", shift=int(step)) as sp:
        out = _finish_round_impl(params, round_state, spec, step=step,
                                 block_d=block_d, interpret=interpret)
        _fence_maybe(sp, out)
    return out


def _finish_round_impl(params: PyTree, round_state, spec: CommSpec, *,
                       step: int = 0, block_d: int = 2048,
                       interpret: Optional[bool] = None) -> PyTree:
    if spec.n_nodes == 1:
        return params
    if "wire" in round_state:
        return _overlap_finish_sharded_wire(params, round_state, spec,
                                            step=step, block_d=block_d,
                                            interpret=interpret)
    q = round_state["q"]
    if spec.uses_sharded():
        return _overlap_finish_sharded_dense(params, q, spec, step=step,
                                             block_d=block_d,
                                             interpret=interpret)
    if spec.backend == "pallas":
        from repro.kernels import mixing_pallas
        w, M = compensated_round_factors("gossip", spec.topology,
                                         spec.n_nodes, step, spec.n_pods)
        xf, unflatten = mixing_pallas.flatten_nodes(params)
        qf = mixing_pallas.flatten_nodes(q)[0]
        out = mixing_pallas.shard_comp_mix_block(
            xf, qf, qf, jnp.asarray(w), jnp.asarray(M), block_d=block_d,
            interpret=interpret)
        return unflatten(out)
    return _compressed_round_reference(params, q, "gossip", spec.topology,
                                       spec.n_nodes, step, spec.n_pods)


def overlap_flush(params: PyTree, spec: CommSpec, *, phase: str,
                  step: int = 0, axis: int = 0,
                  ef_state: Optional[PyTree] = None, seed=0):
    """Synchronous round + buffer re-prime at a period boundary.

    Global/pod-averaging phases do not overlap — their collective must
    see the *current* iterate to restore the exact (pod) average, and the
    PGA period boundary is the natural pipeline flush (DESIGN.md §2.6).
    Runs the ordinary synchronous round for ``phase``, then re-opens the
    double buffer from the averaged iterate so the next gossip step
    overlaps against post-flush state.  Returns
    ``(mixed, round_state, new_ef_state)``.

    Note the EF state advances twice here when a lossy gossip compressor
    is active — once inside the collective round, once in the re-prime —
    matching the two payloads actually produced.
    """
    tel = _hub()
    if tel is not None:
        _meter(tel, params, spec, phase=phase, step=step, role="flush")
    span = (tel.span("comm/flush", phase=phase) if tel is not None
            else contextlib.nullcontext())
    with span:
        out = _communicate_impl(params, spec, phase=phase, step=step,
                                axis=axis, ef_state=ef_state, seed=seed)
        if spec.compressor is not None \
                or spec.global_compressor is not None:
            mixed, ef2 = out
        else:
            mixed, ef2 = out, ef_state
        buf, ef3 = start_round(mixed, spec, ef_state=ef2, seed=seed)
    return mixed, buf, ef3


def _overlap_finish_sharded_dense(params: PyTree, q: PyTree,
                                  spec: CommSpec, *, step: int,
                                  block_d: int,
                                  interpret: Optional[bool]) -> PyTree:
    """Sharded finish for the dense (uncompressed) buffer: ppermute the
    buffered row-blocks over the round's halo offsets and apply the
    compensated per-shard kernel.  The buffer is already wire-cast
    (``start_round``), so the f32 re-pack is an exact upcast and the
    ppermute payload is re-cast to the wire dtype — the bytes crossing
    the ICI match the synchronous path."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.kernels import mixing_pallas

    n, mesh = spec.n_nodes, spec.mesh
    names = node_axis_names(mesh, spec.node_axis)
    if not names:
        raise ValueError(f"mixing.finish_round: mesh {dict(mesh.shape)} "
                         f"has no axis for node_axis={spec.node_axis!r}")
    k = node_shard_count(mesh, spec.node_axis)
    if n % k:
        raise ValueError(f"mixing.finish_round: n_nodes={n} not divisible "
                         f"by the {k} node-axis shards of mesh axes {names}")
    mnames, km = _model_names_count(mesh, spec.model_axis, names)

    xf, unflatten = mixing_pallas.flatten_nodes_sharded(params, km)
    qf = mixing_pallas.flatten_nodes_sharded(q, km)[0]
    xspec = P(names, mnames) if mnames else P(names)
    d, M = mixing_pallas.phase_matrices("gossip", spec.topology, n,
                                        step=step, n_pods=spec.n_pods)
    offsets, Mstack, dstack = _shard_blocks(M, d, n, k)
    wstack = (1.0 - dstack).astype(np.float32)
    perms = {s: tuple(((r + s) % k, r) for r in range(k))
             for s in offsets if s}
    wire = spec.comm_dtype

    def body(xb, qb, Mr, wr):
        send = qb.astype(wire) if wire is not None else qb
        parts = [send if s == 0
                 else jax.lax.ppermute(send, names, perms[s])
                 for s in offsets]
        qs = jnp.concatenate(parts, axis=0).astype(jnp.float32)
        return mixing_pallas.shard_comp_mix_block(
            xb, qb, qs, wr[0], Mr[0], block_d=block_d, interpret=interpret)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(xspec, xspec, P(names), P(names)),
                   out_specs=xspec, check_rep=False)
    return unflatten(fn(xf, qf, jnp.asarray(Mstack), jnp.asarray(wstack)))


def _overlap_finish_sharded_wire(params: PyTree, round_state,
                                 spec: CommSpec, *, step: int,
                                 block_d: int,
                                 interpret: Optional[bool]) -> PyTree:
    """Sharded finish for the lossy buffer: rebuild the LeafWires held in
    ``round_state`` and run the compensated gossip exchange on them — the
    ppermute moves the buffered codes/scales themselves."""
    from repro import compress as compress_mod

    n, mesh = spec.n_nodes, spec.mesh
    names = node_axis_names(mesh, spec.node_axis)
    if not names:
        raise ValueError(f"mixing.finish_round: mesh {dict(mesh.shape)} "
                         f"has no axis for node_axis={spec.node_axis!r}")
    k = node_shard_count(mesh, spec.node_axis)
    if n % k:
        raise ValueError(f"mixing.finish_round: n_nodes={n} not divisible "
                         f"by the {k} node-axis shards of mesh axes {names}")
    mnames, km = _model_names_count(mesh, spec.model_axis, names)
    kmq = km if (km > 1 and spec.compressor.name in ("int8", "fp8")) else 1
    mn = mnames if kmq > 1 else ()
    sizes = [int(np.prod(lf.shape[1:], dtype=np.int64))
             for lf in jax.tree.leaves(params)]
    chunks = [-(-s // kmq) for s in sizes]
    wires = [compress_mod.LeafWire(payload=tuple(w["payload"]),
                                   aux=tuple(w["aux"]))
             for w in round_state["wire"]]
    return _sharded_compensated_gossip(
        params, wires, compressor=spec.compressor, chunks=chunks,
        phase="gossip", topology=spec.topology, n_nodes=n, step=step,
        n_pods=spec.n_pods, mesh=mesh, names=names, k=k, mn=mn, kmq=kmq,
        block_d=block_d, interpret=interpret)


# ---------------------------------------------------------------------------
# Push-sum: runtime dense column-stochastic W (DESIGN.md §2.5)
# ---------------------------------------------------------------------------
def push_sum_shard_offsets(n: int, k: int, shifts) -> Tuple[int, ...]:
    """Static shard-offset superset for sharded push-sum rounds.

    The phase-based sharded path derives its halo offsets from the concrete
    W at trace time (:func:`_shard_blocks`); push-sum W is a *runtime*
    operand, so the offsets must come from the static shift superset the
    fault schedule can ever use.  A shift ``s`` over ``m = n/k`` rows per
    shard reaches receiver shards ``(s // m) % k`` and — when it straddles a
    shard boundary (``s % m != 0``) — ``(s // m + 1) % k``.  Offset 0 is
    always included: fault renormalization puts dropped nodes on identity
    (diagonal) entries.
    """
    m = n // k
    offs = {0}
    for s in shifts:
        s = s % n
        offs.add((s // m) % k)
        if s % m:
            offs.add((s // m + 1) % k)
    return tuple(sorted(offs))


def _dense_shard_stacks(W: jax.Array, n: int, k: int, offsets):
    """Traced analogue of :func:`_shard_blocks` for a runtime dense W:
    gather each shard's ``(m, |offsets|·m)`` mixing factor and ``(m, 1)``
    self-diagonal from the (traced) matrix with jnp ops, so a new fault
    pattern is new *data*, not a new compile."""
    m = n // k
    Wj = jnp.asarray(W, jnp.float32)
    diag = jnp.diagonal(Wj)
    Mj = Wj - jnp.diag(diag)
    blocks = Mj.reshape(k, m, k, m)
    cols = (jnp.arange(k)[:, None] + jnp.asarray(offsets)[None, :]) % k
    # advanced indices split by a slice put the broadcast dims in front:
    # (k, |off|, m, m) → (k, m, |off|·m)
    picked = blocks[jnp.arange(k)[:, None], :, cols]
    Mstack = jnp.transpose(picked, (0, 2, 1, 3)).reshape(
        k, m, len(offsets) * m)
    return Mstack, diag.reshape(k, m, 1)


def _mix_dense_reference(params: PyTree, W: jax.Array, n: int,
                         comm_dtype=None) -> PyTree:
    """Reference dense round ``x ← d ⊙ x + M · cast(x)`` for a runtime W —
    the oracle the dense pallas/sharded paths are tested against.  Gossip
    wire semantics: only the off-diagonal (neighbor) term is wire-cast."""
    Wj = jnp.asarray(W, jnp.float32)
    dj = jnp.diagonal(Wj).reshape(n, 1)
    Mj = Wj - jnp.diag(jnp.diagonal(Wj))

    def one(x):
        x2 = x.reshape(n, -1).astype(jnp.float32)
        xw = x2.astype(comm_dtype).astype(jnp.float32) \
            if comm_dtype is not None else x2
        return (dj * x2 + Mj @ xw).reshape(x.shape).astype(x.dtype)

    return jax.tree.map(one, params)


def _compressed_round_dense(params: PyTree, q: PyTree, W: jax.Array,
                            n: int) -> PyTree:
    """Compensated compressed round ``x + (M·q − (1−d)⊙q)`` for a runtime
    dense W (reference oracle for ``compressed_step_mix_dense``)."""
    Wj = jnp.asarray(W, jnp.float32)
    dj = jnp.diagonal(Wj).reshape(n, 1)
    wj = 1.0 - dj
    Mj = Wj - jnp.diag(jnp.diagonal(Wj))

    def one(x, qq):
        x2 = x.reshape(n, -1).astype(jnp.float32)
        q2 = qq.reshape(n, -1).astype(jnp.float32)
        return (x2 + (Mj @ q2 - wj * q2)).reshape(x.shape).astype(x.dtype)

    return jax.tree.map(one, params, q)


def _push_sum_sharded(joint: PyTree, *, W: jax.Array, n_nodes: int,
                      offsets, comm_dtype, mesh: jax.sharding.Mesh,
                      node_axis: str, model_axis: str, block_d: int,
                      interpret: Optional[bool]) -> PyTree:
    """Sharded push-sum round: ppermute halo exchange over the *static*
    offset superset, per-shard factors gathered from the traced W.  The
    ppermute path is already directional (shard r receives from shard
    ``r+q``), so asymmetric W needs no new wiring — only the runtime
    Mstack/dstack (transpose-free: the weight column is mixed by the same
    per-shard kernel as the parameters, no Wᵀ ever forms)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.kernels import mixing_pallas

    names = node_axis_names(mesh, node_axis)
    if not names:
        raise ValueError(f"mixing._push_sum_sharded: mesh "
                         f"{dict(mesh.shape)} has no axis for "
                         f"node_axis={node_axis!r}")
    k = node_shard_count(mesh, node_axis)
    n = n_nodes
    if n % k:
        raise ValueError(f"mixing._push_sum_sharded: n_nodes={n} not "
                         f"divisible by the {k} node-axis shards")
    offsets = tuple(range(k)) if offsets is None else tuple(offsets)
    mnames, km = _model_names_count(mesh, model_axis, names)

    xf, unflatten = mixing_pallas.flatten_nodes_sharded(joint, km)
    xspec = P(names, mnames) if mnames else P(names)
    Mstack, dstack = _dense_shard_stacks(W, n, k, offsets)
    perms = {q: tuple(((r + q) % k, r) for r in range(k))
             for q in offsets if q}

    def body(xb, Mr, dr):
        send = xb.astype(comm_dtype) if comm_dtype is not None else xb
        parts = [send if q == 0
                 else jax.lax.ppermute(send, names, perms[q])
                 for q in offsets]
        xs = jnp.concatenate(parts, axis=0).astype(jnp.float32)
        return mixing_pallas.shard_mix_block(
            xb, xs, dr[0], Mr[0], block_d=block_d, interpret=interpret)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(xspec, P(names), P(names)),
                   out_specs=xspec, check_rep=False)
    return unflatten(fn(xf, Mstack, dstack))


def communicate_push_sum(params: PyTree, weight: jax.Array, *,
                         W: jax.Array, n_nodes: int, comm_dtype=None,
                         backend: str = "reference",
                         mesh: Optional[jax.sharding.Mesh] = None,
                         node_axis: str = "data",
                         shard_mode: str = "auto",
                         model_axis: str = "model",
                         leaf_threshold: Optional[int] = None,
                         offsets=None, block_d: int = 2048,
                         interpret: Optional[bool] = None,
                         compressor=None,
                         ef_state: Optional[PyTree] = None, seed=0):
    """One push-sum round: ``(x, w) ← (W·x, W·w)`` for a **runtime**
    column-stochastic ``W`` (DESIGN.md §2.5).

    ``weight`` is the per-node push-sum scalar, shape ``(n, 1)``; readers
    de-bias with ``x/w`` (:func:`repro.train.state.debias`).  W is a traced
    ``(n, n)`` operand — fault drops and per-step resampling change the
    data, never the compiled program.  The weight column rides the same
    round as the parameters (packed into the pallas staging buffer /
    sharded row-blocks alongside them), so x and w experience bit-identical
    mixing arithmetic and the de-bias ratio is exact at consensus.

    Backends mirror :func:`communicate`: ``"reference"`` (dense jnp
    oracle), ``"pallas"`` stacked (:func:`fused_step_mix_dense`), and —
    when ``mesh``'s node axis is sharded — the ppermute path
    (:func:`_push_sum_sharded`), whose halo set comes from the *static*
    ``offsets`` superset (:func:`push_sum_shard_offsets`; default: all
    shard offsets, always safe).

    With a lossy ``compressor`` the parameters run the compensated
    compressed round while the weight is mixed **exactly** (dense ``W·w``
    outside the codec — the de-bias denominator must never be lossy);
    returns ``(mixed, new_weight, new_ef_state)``.  Without a compressor
    returns ``(mixed, new_weight)``.  Sharded + compressed push-sum is
    unsupported (raise) — fall back to the stacked backends.
    """
    _check_backend(backend, 0, caller="mixing.communicate_push_sum")
    n = n_nodes
    if weight.shape[0] != n:
        raise ValueError(f"communicate_push_sum: weight has {weight.shape[0]}"
                         f" rows for n_nodes={n}")
    w2 = weight.reshape(n, -1).astype(jnp.float32)
    sharded = use_sharded_backend(backend, mesh, node_axis, shard_mode)

    tel = _hub()
    if tel is not None:
        # push-sum rounds mix against a *runtime* W (fault patterns are
        # data, not programs — DESIGN.md §2.5), so the static shift/send
        # accounting does not apply: report one send's worth of payload
        # bytes from the live tree and flag sends as data-dependent (-1)
        try:
            from repro.obs import meters as obs_meters
            sizes = obs_meters.per_node_leaf_sizes(params, n)
            elem = (np.dtype(comm_dtype).itemsize
                    if comm_dtype is not None else 4)
            leaves = jax.tree.leaves(params)
            tel.emit(
                "comm_round", phase="push_sum", role="round",
                topology="runtime", backend=backend, sharded=sharded,
                n_nodes=int(n), sends=-1,
                compression=(compressor.name if compressor is not None
                             else "none"),
                measured_bytes=int(sum(sizes)) * int(elem),
                analytic_bytes=None,
                traced=bool(leaves)
                and isinstance(leaves[0], jax.core.Tracer))
        except Exception as e:                       # pragma: no cover
            warnings.warn(f"mixing: push-sum comm meter failed ({e}); "
                          f"round unaffected")

    if compressor is not None and compressor.lossy:
        if sharded:
            raise ValueError(
                "mixing.communicate_push_sum: compressed push-sum has no "
                "sharded path (the fault-varying W would need runtime wire "
                "layouts); use comm_shard_mode='stacked'")
        # the weight is the de-bias denominator: mix it exactly, outside
        # the lossy codec — column-stochastic W keeps Σw = n to fp exactness
        Wj = jnp.asarray(W, jnp.float32)
        new_w = (Wj @ w2).astype(weight.dtype).reshape(weight.shape)
        if backend == "pallas":
            from repro.kernels import mixing_pallas
            mixed, new_ef = mixing_pallas.compressed_step_mix_dense(
                params, W=W, compressor=compressor, ef_state=ef_state,
                seed=seed, n_nodes=n, block_d=block_d, interpret=interpret)
            return mixed, new_w, new_ef
        from repro import compress as compress_mod
        q, new_ef = compress_mod.apply_tree(compressor, params, ef_state,
                                            seed)
        mixed = _compressed_round_dense(params, q, W, n)
        return mixed, new_w, new_ef

    joint = {"x": params, "w": weight}
    if sharded:
        out = _push_sum_sharded(joint, W=W, n_nodes=n, offsets=offsets,
                                comm_dtype=comm_dtype, mesh=mesh,
                                node_axis=node_axis, model_axis=model_axis,
                                block_d=block_d, interpret=interpret)
    elif backend == "pallas":
        from repro.kernels import mixing_pallas
        out = mixing_pallas.fused_step_mix_dense(
            joint, W, n_nodes=n, comm_dtype=comm_dtype, block_d=block_d,
            interpret=interpret, leaf_threshold=leaf_threshold)
    else:
        out = _mix_dense_reference(joint, W, n, comm_dtype=comm_dtype)
    # identity codec: exact path + EF pass-through
    if compressor is not None:
        return out["x"], out["w"], ef_state
    return out["x"], out["w"]


def _communicate_sharded_collective(params: PyTree, *, compressor, ef_state,
                                    seed, phase: str, n_nodes: int,
                                    n_pods: int, mesh: jax.sharding.Mesh,
                                    node_axis: str = "data",
                                    model_axis: str = "model",
                                    qblock: Optional[int] = None,
                                    caller: Optional[str] = None):
    """Compressed global/pod-averaging collective with the node axis
    sharded over ``mesh`` (DESIGN.md §2.3 "Compressed collectives").

    The chunked reduce-scatter runs as one ``all_to_all`` of the stage-1
    **wire arrays** (int8/fp8 codes + one *uint8 exponent byte* per
    power-of-two block scale — ``pow2_block_scale`` guarantees a pure
    exponent, so the fp32 scale word never crosses the ICI) — the
    compressed bytes are exactly what crosses the wire; each column
    segment's owner dequantizes, applies the anchored accumulate, and
    re-quantizes the (per-pod) mean chunk, which returns via an
    ``all_gather`` of stage-2 codes+exponents.  Stage-1 quantization, the
    EF residual ``e' = y − q₁``, and the local emulation ``ρ = Q₂(q₁)``
    are row-local and run *outside* the shard_map, so GSPMD keeps them
    collective-free; the compensated combine ``x + (r − ρ)`` is
    elementwise.  Returns ``(mixed, new_ef_state)``.

    On a 2-D ``(node, model)`` mesh the packed columns are sliced over
    the model axis: padding is to ``k_model · k · QBLOCK`` so every model
    shard's slice starts on a scale-block boundary (absolute-column
    randomness and block scales stay bit-stable under resharding), the
    reduce-scatter segments split ``D/k_model`` instead of ``D``, and the
    stage-2 column offset is ``model_slice + node_segment``.

    ``caller`` names the public entry point for validation errors; both
    dispatch paths (``communicate``/``communicate_sharded``) and direct
    callers get their own message instead of an opaque shard_map trace
    failure.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.compress import collective as ccol
    from repro.kernels import mixing_pallas

    who = caller or "mixing._communicate_sharded_collective"
    names = node_axis_names(mesh, node_axis)
    if not names:
        raise ValueError(f"{who}: mesh {dict(mesh.shape)} has no axis for "
                         f"node_axis={node_axis!r} — the compressed "
                         f"collective needs a sharded node axis (use the "
                         f"stacked path instead)")
    k = node_shard_count(mesh, node_axis)
    n = n_nodes
    if n % k:
        raise ValueError(f"{who}: n_nodes={n} not divisible by the {k} "
                         f"node-axis shards of mesh axes {names}")
    pods = n_pods if phase == "pod_avg" else 1
    _check_pods(n, pods, who)
    kind = compressor.name
    qb = ccol.QBLOCK if qblock is None else qblock
    mnames, km = _model_names_count(mesh, model_axis, names)

    xf, unflatten = mixing_pallas.flatten_nodes(params)
    ef2 = ef_unflatten = None
    if ef_state is not None:
        ef2, ef_unflatten = mixing_pallas.flatten_nodes(ef_state)
    D = xf.shape[1]
    # segment boundaries must land on scale blocks for every model slice:
    # pad to k_model·k·qblock (appended zero columns — real columns keep
    # their absolute indices, so scales and random bits are unchanged)
    xp = ccol.pad_cols(xf, km * k * qb)
    ep = ccol.pad_cols(ef2, km * k * qb)
    Dp = xp.shape[1]
    s1, s2 = ccol.stage_seeds(seed)

    y = xp if ep is None else xp + ep
    codes1, scales1, q1 = ccol.quantize_blocks(y, kind, s1, qb)
    new_ef = None if ep is None else (y - q1)[:, :D]
    _, _, rho = ccol.quantize_blocks(q1, kind, s2, qb)

    width = Dp // km          # columns per model slice
    seg = width // k          # columns per (node shard, model shard) owner
    axis_sizes = [mesh.shape[a] for a in names]
    msizes = [mesh.shape[a] for a in mnames]
    wspec = P(names, mnames) if mnames else P(names)

    def body(cb, eb):
        # reduce-scatter: the compressed wire arrays (codes + exponent
        # bytes) cross the ICI, node axis only — each model slice reduces
        # its own columns
        ac = jax.lax.all_to_all(cb, names, split_axis=1, concat_axis=0,
                                tiled=True)                     # (n, seg)
        ae = jax.lax.all_to_all(eb, names, split_axis=1, concat_axis=0,
                                tiled=True)                     # (n, seg/qb)
        q_seg = ccol.dequant_blocks(ac, ccol.exponent_scales(ae), qb)
        mbar = ccol.anchored_mean(q_seg, pods)                  # (p, seg)
        shard = 0
        for a, sz in zip(names, axis_sizes):
            shard = shard * sz + jax.lax.axis_index(a)
        mshard = 0
        for a, sz in zip(mnames, msizes):
            mshard = mshard * sz + jax.lax.axis_index(a)
        c2, sc2, _ = ccol.quantize_blocks(mbar, kind, s2, qb,
                                          col0=mshard * width + shard * seg)
        gc = jax.lax.all_gather(c2, names, axis=1, tiled=True)  # (p, width)
        ge = jax.lax.all_gather(ccol.scale_exponents(sc2), names, axis=1,
                                tiled=True)
        return ccol.dequant_blocks(gc, ccol.exponent_scales(ge), qb)

    fn = shard_map(body, mesh=mesh, in_specs=(wspec, wspec),
                   out_specs=P(None, mnames) if mnames else P(),
                   check_rep=False)
    r = fn(codes1, ccol.scale_exponents(scales1))               # (p, Dp)
    per = n // pods
    r_rows = jnp.broadcast_to(r[:, None], (pods, per, Dp)).reshape(n, Dp)
    mixed = (xp + (r_rows - rho))[:, :D]
    return unflatten(mixed), (ef_unflatten(new_ef) if ep is not None
                              else None)
