"""Composable algorithm layer: one registry, four hooks, no step forks.

Every training algorithm in the repo — the paper's Gossip-PGA (Alg. 1/2),
the baselines (parallel SGD, Local SGD, plain gossip, SlowMo), the
extensions (AGA, hierarchical PGA) and gradient tracking (GT-PGA) — shares
one skeleton: per-node grad -> optimizer half-step -> communication round.
This module captures what *differs* per algorithm so that ``train/step.py``
and ``core/algorithms.py simulate`` can each keep exactly one step body:

* ``slots`` — extra ``TrainState.extras`` entries as typed descriptors
  (init value, vmap/shard axes, checkpoint backfill), subsuming the old
  ad-hoc ``slow_params``/``slow_u``/``ef_state``/``push_weight`` fields and
  the ``state_axes(slowmo=, ef=, push=)`` flag creep.
* ``pre_update(extras, grads)`` — transform of the gradients consumed by
  the optimizer (GT-PGA's tracker recursion ``y <- y + g - g_prev``).
* ``comm_payload(extras, params_half)`` — extra pytrees that ride the
  communication round *jointly* with the params, through the same
  ``communicate``/``CommSpec`` call.  Because the payload travels inside
  one joint tree, every backend, compression/EF, push-sum and overlap
  mode composes with it for free (DESIGN.md §3 invariant).
* ``post_round(extras, mixed, phase, ctx)`` — algorithm-specific update
  after the round (SlowMo outer step, GT tracker absorption).  ``mixed``
  is always a dict ``{"params": tree, **payload}`` at the hook level;
  the call sites unwrap a bare params tree when the payload is empty so
  legacy algorithms keep byte-identical comm graphs.

Lookups raise caller-named ``ValueError`` listing valid names, consistent
with ``DistConfig.validate`` (never a raw ``KeyError``).

This module must not import ``repro.configs`` or ``repro.core.mixing`` at
module scope: ``configs/base.py`` sources ``ALGORITHMS`` from this registry
lazily and would otherwise form an import cycle.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

__all__ = [
    "Algorithm",
    "ExtraSlot",
    "StepContext",
    "algorithm_names",
    "backfill_kind",
    "extras_axes",
    "get_algorithm",
    "init_extras",
    "join_payload",
    "known_slot_names",
    "phases_for_algorithm",
    "push_sum_algorithm_names",
    "register",
    "state_slots",
    "unwrap_mixed",
    "wrap_mixed",
]


# --------------------------------------------------------------------------
# Slot descriptors
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ExtraSlot:
    """Descriptor for one entry of ``TrainState.extras``.

    ``kind`` fixes both the init shape and the vmap/shard axes:

    ============== ======================================= ================
    kind            shape                                   axes
    ============== ======================================= ================
    stacked_params  params tree with leading node axis      stacked axes
    unstacked       single-replica params tree              unstacked axes
    node_scalar     ``(n_nodes, 1)`` float32                ``("node", None)``
    ============== ======================================= ================

    ``init``: ``"zeros"`` (float32 zeros of the base shape), ``"ones"``
    (node_scalar only), or ``"row0"`` (node 0's params — SlowMo's anchor).
    ``backfill`` names what ``checkpoint/ckpt.py`` materialises when an
    older checkpoint lacks the slot (``"ones"`` for push weights, else
    ``"zeros"``).  ``payload`` marks the slot as riding the communication
    round jointly with the params (GT-PGA's tracker).
    """

    name: str
    kind: str = "stacked_params"  # stacked_params | unstacked | node_scalar
    init: str = "zeros"           # zeros | ones | row0
    backfill: str = "zeros"       # zeros | ones
    payload: bool = False

    def init_value(self, params_stacked: Any, n_nodes: int) -> Any:
        import jax
        import jax.numpy as jnp

        if self.kind == "node_scalar":
            fn = jnp.ones if self.init == "ones" else jnp.zeros
            return fn((n_nodes, 1), jnp.float32)
        base = params_stacked
        if self.kind == "unstacked":
            base = jax.tree.map(lambda p: p[0], params_stacked)
        if self.init == "row0":
            return base
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), base)

    def axes_value(self, params_axes_stacked: Any,
                   params_axes_unstacked: Any) -> Any:
        if self.kind == "node_scalar":
            return ("node", None)
        if self.kind == "unstacked":
            return params_axes_unstacked
        return params_axes_stacked


# Mode slots: owned by the communication stack, not by any one algorithm,
# but declared here so init/axes/backfill live in a single registry.
EF_SLOT = ExtraSlot("ef_state", kind="stacked_params", backfill="zeros")
PUSH_SLOT = ExtraSlot("push_weight", kind="node_scalar", init="ones",
                      backfill="ones")


# --------------------------------------------------------------------------
# Step context + algorithm protocol
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StepContext:
    """Per-step constants handed to hooks (built inside the traced step)."""

    dist: Any        # DistConfig (static)
    n_nodes: int     # static
    lr: Any          # traced scalar learning rate for this step


class Algorithm:
    """One decentralised training algorithm: phases + extras + hooks."""

    name: str = ""
    phases: Tuple[str, ...] = ()
    #: Phases consumed entirely by ``post_round`` with no comm round
    #: (SlowMo's outer step).  The step body skips ``communicate`` for
    #: these and the trainer/simulator keep their historical jit
    #: boundaries around them.
    owned_phases: Tuple[str, ...] = ()
    slots: Tuple[ExtraSlot, ...] = ()
    #: Eligible to compose with push-sum (directed, gossip-style mixing).
    push_sum_capable: bool = False
    #: True when ``pre_update`` is not the identity; disables the fused
    #: pallas half-step+mix kernel, whose in-kernel update consumes raw
    #: grads.
    transforms_grads: bool = False
    description: str = ""

    def payload_names(self) -> Tuple[str, ...]:
        return tuple(s.name for s in self.slots if s.payload)

    # -- hooks -------------------------------------------------------------
    def pre_update(self, extras: Dict[str, Any],
                   grads: Any) -> Tuple[Any, Dict[str, Any]]:
        """Return ``(update_grads, extras)`` — what the optimizer consumes."""
        return grads, extras

    def comm_payload(self, extras: Dict[str, Any],
                     params_half: Any) -> Dict[str, Any]:
        """Extra pytrees that ride the round jointly with the params."""
        return {n: extras[n] for n in self.payload_names()}

    def post_round(self, extras: Dict[str, Any], mixed: Dict[str, Any],
                   phase: str, ctx: StepContext) -> Tuple[Any, Dict[str, Any]]:
        """Consume the round output; return ``(new_params, extras)``.

        Default: absorb mixed payload slots back into ``extras`` and pass
        the mixed params through unchanged.
        """
        names = self.payload_names()
        if names:
            extras = dict(extras)
            for n in names:
                extras[n] = mixed[n]
        return mixed["params"], extras


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------
_REGISTRY: Dict[str, Algorithm] = {}


def register(algo: Algorithm) -> Algorithm:
    if not algo.name:
        raise ValueError("register: algorithm must set a non-empty name")
    _REGISTRY[algo.name] = algo
    return algo


def algorithm_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def push_sum_algorithm_names() -> Tuple[str, ...]:
    return tuple(n for n, a in _REGISTRY.items() if a.push_sum_capable)


def get_algorithm(name: str, *, caller: str = "get_algorithm") -> Algorithm:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"{caller}: unknown algorithm {name!r} "
            f"(expected one of {algorithm_names()})") from None


def phases_for_algorithm(algorithm: str) -> Tuple[str, ...]:
    """Phases an algorithm's schedule can emit, in canonical order."""
    return get_algorithm(algorithm, caller="phases_for_algorithm").phases


def known_slot_names() -> Tuple[str, ...]:
    """Every extras slot name any registered algorithm (or mode) can own."""
    names = []
    for algo in _REGISTRY.values():
        for slot in algo.slots:
            if slot.name not in names:
                names.append(slot.name)
    for slot in (EF_SLOT, PUSH_SLOT):
        if slot.name not in names:
            names.append(slot.name)
    return tuple(names)


def backfill_kind(slot_name: str) -> str:
    """Checkpoint backfill for a slot missing from an older checkpoint."""
    for algo in _REGISTRY.values():
        for slot in algo.slots:
            if slot.name == slot_name:
                return slot.backfill
    for slot in (EF_SLOT, PUSH_SLOT):
        if slot.name == slot_name:
            return slot.backfill
    return "zeros"


# --------------------------------------------------------------------------
# Extras construction (algorithm slots + mode slots)
# --------------------------------------------------------------------------
def state_slots(dist: Any) -> Tuple[ExtraSlot, ...]:
    """All extras slots for a config: algorithm-declared plus mode slots."""
    algo = get_algorithm(dist.algorithm, caller="state_slots")
    slots = tuple(algo.slots)
    if dist.comm_error_feedback:
        slots += (EF_SLOT,)
    if dist.push_sum:
        slots += (PUSH_SLOT,)
    return slots


def init_extras(dist: Any, params_stacked: Any,
                n_nodes: int) -> Dict[str, Any]:
    """Initial ``TrainState.extras`` for a config.

    The error-feedback slot mirrors the *joint* comm payload (params plus
    any algorithm payload slots), so compressed GT-PGA keeps one residual
    per transmitted leaf.
    """
    algo = get_algorithm(dist.algorithm, caller="init_extras")
    extras: Dict[str, Any] = {}
    for slot in algo.slots:
        extras[slot.name] = slot.init_value(params_stacked, n_nodes)
    if dist.comm_error_feedback:
        from repro.compress import init_ef_state

        payload = algo.comm_payload(extras, params_stacked)
        extras["ef_state"] = init_ef_state(
            join_payload(payload, params_stacked))
    if dist.push_sum:
        extras["push_weight"] = PUSH_SLOT.init_value(params_stacked, n_nodes)
    return extras


def extras_axes(dist: Any, params_axes_stacked: Any,
                params_axes_unstacked: Any) -> Dict[str, Any]:
    """vmap/shard axes tree matching ``init_extras``'s structure."""
    algo = get_algorithm(dist.algorithm, caller="extras_axes")
    axes: Dict[str, Any] = {}
    for slot in algo.slots:
        axes[slot.name] = slot.axes_value(params_axes_stacked,
                                          params_axes_unstacked)
    if dist.comm_error_feedback:
        payload_axes = {n: params_axes_stacked for n in algo.payload_names()}
        axes["ef_state"] = join_payload(payload_axes, params_axes_stacked)
    if dist.push_sum:
        axes["push_weight"] = PUSH_SLOT.axes_value(params_axes_stacked,
                                                   params_axes_unstacked)
    return axes


# --------------------------------------------------------------------------
# Joint-payload plumbing
# --------------------------------------------------------------------------
def join_payload(payload: Dict[str, Any], params: Any) -> Any:
    """The tree that rides the comm round.

    Bare params when the payload is empty — legacy algorithms must hand
    ``communicate`` the exact same tree as before the refactor so their
    comm graphs (and trajectories) stay bitwise identical.
    """
    if not payload:
        return params
    return {"params": params, **payload}


def wrap_mixed(mixed: Any, has_payload: bool) -> Dict[str, Any]:
    """Normalise a round's output to the ``post_round`` dict contract."""
    return mixed if has_payload else {"params": mixed}


def unwrap_mixed(joint: Any, has_payload: bool) -> Any:
    """Params tree of a joint round tree (inverse of ``join_payload``)."""
    return joint["params"] if has_payload else joint


# --------------------------------------------------------------------------
# Algorithms
# --------------------------------------------------------------------------
class _Parallel(Algorithm):
    name = "parallel"
    phases = ("global",)
    push_sum_capable = True
    description = "All-reduce every step (centralised baseline)."


class _Gossip(Algorithm):
    name = "gossip"
    phases = ("gossip",)
    push_sum_capable = True
    description = "One W-mixing per step (DSGD)."


class _Local(Algorithm):
    name = "local"
    phases = ("none", "global")
    push_sum_capable = True
    description = "H local steps, then a global average (Local SGD)."


class _GossipPGA(Algorithm):
    name = "gossip_pga"
    phases = ("gossip", "global")
    push_sum_capable = True
    description = "Gossip with a global average every H steps (Alg. 1)."


class _GossipAGA(Algorithm):
    name = "gossip_aga"
    phases = ("gossip", "global")
    push_sum_capable = True
    description = "Gossip-PGA with the adaptive H controller (App. G)."


class _SlowMo(Algorithm):
    name = "slowmo"
    phases = ("gossip", "slowmo")
    owned_phases = ("slowmo",)
    slots = (
        ExtraSlot("slow_params", kind="unstacked", init="row0"),
        ExtraSlot("slow_u", kind="unstacked", init="zeros"),
    )
    description = "Gossip with a periodic slow momentum outer step."

    def post_round(self, extras, mixed, phase, ctx):
        if phase not in self.owned_phases:
            return super().post_round(extras, mixed, phase, ctx)
        import jax
        import jax.numpy as jnp

        params_half = mixed["params"]
        beta = ctx.dist.slowmo_beta
        alpha = ctx.dist.slowmo_lr
        lr = ctx.lr
        xbar = jax.tree.map(
            lambda p: jnp.mean(p.astype(jnp.float32), axis=0), params_half)
        slow_u = jax.tree.map(
            lambda u, s, xb: beta * u.astype(jnp.float32)
            + (s.astype(jnp.float32) - xb) / lr,
            extras["slow_u"], extras["slow_params"], xbar)
        slow_params = jax.tree.map(
            lambda s, u: (s.astype(jnp.float32) - alpha * lr * u
                          ).astype(s.dtype),
            extras["slow_params"], slow_u)
        new_params = jax.tree.map(
            lambda s, p: jnp.broadcast_to(s[None], p.shape).astype(p.dtype),
            slow_params, params_half)
        return new_params, {**extras, "slow_params": slow_params,
                            "slow_u": slow_u}


class _HierPGA(Algorithm):
    name = "hier_pga"
    phases = ("gossip", "pod_avg", "global")
    description = "Two-level PGA: pod averages nested inside global ones."


class _GTPGA(Algorithm):
    name = "gt_pga"
    phases = ("gossip", "global")
    slots = (
        ExtraSlot("gt_tracker", kind="stacked_params", payload=True),
        ExtraSlot("gt_prev_grad", kind="stacked_params"),
    )
    transforms_grads = True
    description = ("Gradient tracking + PGA for non-IID data: the tracker "
                   "rides the round jointly with the params.")

    def pre_update(self, extras, grads):
        import jax

        # y_{k+1/2} = y_k + g_k - g_{k-1}; the optimizer consumes y, whose
        # node-mean equals the global gradient mean (y_0 = g_{-1} = 0), so
        # heterogeneous per-node drift cancels instead of stalling gossip.
        tracker = jax.tree.map(lambda y, g, p: y + (g - p),
                               extras["gt_tracker"], grads,
                               extras["gt_prev_grad"])
        return tracker, {**extras, "gt_tracker": tracker,
                         "gt_prev_grad": grads}


register(_Parallel())
register(_Gossip())
register(_Local())
register(_GossipPGA())
register(_GossipAGA())
register(_SlowMo())
register(_HierPGA())
register(_GTPGA())
