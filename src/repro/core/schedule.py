"""Communication schedules: fixed-period PGA and adaptive AGA (paper Alg. 2).

Host-side logic — the trainer asks the schedule *which compiled step variant*
("gossip" vs "global") to dispatch at iteration k.  Keeping the branch on the
host (instead of a ``lax.cond``) keeps each compiled HLO's collective profile
pure, which the roofline analysis depends on, and lets AGA change H without
recompilation (DESIGN.md §2.2).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional

from repro.configs.base import DistConfig


def _as_float(loss) -> Optional[float]:
    """Materialize a loss observation on the host.

    ``observe_loss`` accepts the loss *lazily* — a device scalar (or a
    thunk returning one) — so the trainer's hot loop never blocks on a
    per-step device→host sync; the transfer happens here, at period
    boundaries only, as an **explicit** ``jax.device_get`` (allowed under
    ``jax.transfer_guard_device_to_host("disallow")``, which the
    zero-per-step-sync regression test runs the hot loop under)."""
    if loss is None:
        return None
    if callable(loss):
        loss = loss()
    if hasattr(loss, "dtype") and hasattr(loss, "shape"):
        import jax
        loss = jax.device_get(loss)
    return float(loss)


class CommSchedule:
    """Base: decides the communication phase of step k (0-based).  Phase of
    step k applies *after* the local SGD update of step k, matching paper
    Alg. 1 where mod(k+1, H) == 0 triggers global averaging.

    Two entry points with distinct contracts:

    * :meth:`peek_phase` (and its alias :meth:`phase`) is **pure** — it
      never mutates schedule state, so dryrun/roofline/logging code can
      query any step's phase without desyncing a stateful schedule (the
      purity this module's docstring promises; regression-tested by
      ``test_schedule.test_aga_phase_is_pure``).
    * :meth:`advance` is the trainer's once-per-executed-step call: it
      returns the step's phase *and* commits any internal counters (AGA's
      period counter).  For stateless schedules the two coincide.
    """

    def peek_phase(self, step: int) -> str:
        """Phase of step k, with no side effects."""
        raise NotImplementedError

    def phase(self, step: int) -> str:
        """Pure alias of :meth:`peek_phase` (kept for callers predating
        the peek/advance split)."""
        return self.peek_phase(step)

    def advance(self, step: int) -> str:
        """Phase of step k, committing schedule state.  Call exactly once
        per executed training step, in step order."""
        return self.peek_phase(step)

    def gossip_shift_step(self, step: int, period: int = 1) -> int:
        """Index fed to the time-varying one-peer-exp graph, reduced modulo
        the topology's schedule period (bounds compiled variants)."""
        return step % max(period, 1)

    def observe_loss(self, step: int, loss) -> None:  # AGA hook
        """Feed the schedule a loss signal.  ``loss`` may be a python
        float, a 0-d device array, or a thunk returning either —
        stateful schedules hold it lazily and materialize only at
        period boundaries (:func:`_as_float`), so calling this every
        step costs no host sync."""
        pass

    # -- resume support ---------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-serializable internal state (empty for stateless
        schedules).  Stateful schedules (AGA's period counter and H
        adaptation) must round-trip through this, or a resumed run
        desyncs from the uninterrupted one — the Trainer writes it next
        to each checkpoint and reloads it on resume."""
        return {}

    def load_state_dict(self, state: dict) -> None:
        pass


@dataclass
class ParallelSchedule(CommSchedule):
    """Parallel SGD: exact global average every step (W = J)."""
    def peek_phase(self, step: int) -> str:
        return "global"


@dataclass
class GossipSchedule(CommSchedule):
    """Gossip SGD: H → ∞ (paper Remark 4)."""
    def peek_phase(self, step: int) -> str:
        return "gossip"


@dataclass
class LocalSchedule(CommSchedule):
    """Local SGD: W = I between periodic All-Reduce syncs."""
    H: int = 6

    def peek_phase(self, step: int) -> str:
        return "global" if (step + 1) % self.H == 0 else "none"


@dataclass
class PGASchedule(CommSchedule):
    """Gossip-PGA (paper Alg. 1): gossip every step, All-Reduce every H."""
    H: int = 6

    def peek_phase(self, step: int) -> str:
        return "global" if (step + 1) % self.H == 0 else "gossip"


@dataclass
class AGASchedule(CommSchedule):
    """Gossip-AGA (paper Alg. 2): H^(ℓ) = ceil(F_init / F(x_k) · H_init),
    clipped to H_max (Corollary 1 requires bounded periods).

    The paper removes the ^(1/4) exponent "for flexible period adjustment"
    (App. G) — we follow App. G exactly.
    """
    H_init: int = 4
    warmup: int = 64
    H_max: int = 64
    _C: int = field(default=0, init=False)
    _H: int = field(default=0, init=False)
    _F_init: Optional[float] = field(default=None, init=False)
    # the latest observation, held LAZILY: a float, a 0-d device array,
    # or a thunk — materialized by _as_float only at period boundaries
    # (_update_period) / serialization, never per step
    _F_last: Any = field(default=None, init=False)
    history: List[int] = field(default_factory=list, init=False)

    def __post_init__(self):
        self._H = self.H_init

    @property
    def current_H(self) -> int:
        return self._H

    def observe_loss(self, step: int, loss) -> None:
        self._F_last = loss

    def peek_phase(self, step: int) -> str:
        """Pure: what :meth:`advance` would return for the next executed
        step, with the period counter untouched — safe for dryrun/roofline/
        logging probes (the pre-split ``phase()`` advanced the live counter
        on every query, silently desyncing H adaptation)."""
        return "global" if self._C + 1 >= self._H else "gossip"

    def advance(self, step: int) -> str:
        ph = self.peek_phase(step)
        if ph == "global":
            self._C = 0
            self._update_period(step)
        else:
            self._C += 1
        return ph

    def state_dict(self) -> dict:
        return {"C": self._C, "H": self._H, "F_init": self._F_init,
                "F_last": _as_float(self._F_last),
                "history": list(self.history)}

    def load_state_dict(self, state: dict) -> None:
        self._C = int(state["C"])
        self._H = int(state["H"])
        self._F_init = state["F_init"]
        self._F_last = state["F_last"]
        self.history = list(state["history"])

    def _update_period(self, step: int) -> None:
        f_last = _as_float(self._F_last)
        if f_last is None:
            return
        self._F_last = f_last  # cache the materialized value
        if step < self.warmup or self._F_init is None:
            # running average F_init <- (F_init + F)/2 (paper Alg. 2 warmup)
            self._F_init = (f_last if self._F_init is None
                            else 0.5 * (self._F_init + f_last))
        else:
            import math
            h = math.ceil(self._F_init / max(f_last, 1e-12) * self.H_init)
            self._H = int(min(max(h, 1), self.H_max))
        self.history.append(self._H)


@dataclass
class HierPGASchedule(CommSchedule):
    """Hierarchical PGA (beyond-paper, DESIGN.md §4): gossip every step,
    intra-pod exact averaging every H_pod steps, global All-Reduce every
    H_global steps.  Matches the two-tier ICI/DCI cost structure of multi-pod
    TPU deployments: the cheap sync runs often, the expensive one rarely."""
    H_pod: int = 3
    H_global: int = 12

    def peek_phase(self, step: int) -> str:
        if (step + 1) % self.H_global == 0:
            return "global"
        if (step + 1) % self.H_pod == 0:
            return "pod_avg"
        return "gossip"


@dataclass
class SlowMoSchedule(CommSchedule):
    """SlowMo (Wang et al. 2019) outer loop: gossip base optimizer + slow
    momentum update at each exact-average boundary.  phase 'slowmo' tells the
    trainer to dispatch the slow-momentum step variant."""
    H: int = 6

    def peek_phase(self, step: int) -> str:
        return "slowmo" if (step + 1) % self.H == 0 else "gossip"


def make_schedule(dist: DistConfig) -> CommSchedule:
    a = dist.algorithm
    if a == "parallel":
        return ParallelSchedule()
    if a == "gossip":
        return GossipSchedule()
    if a == "local":
        return LocalSchedule(H=dist.H)
    if a in ("gossip_pga", "gt_pga"):
        # gt_pga keeps PGA's cadence — the tracker changes what rides the
        # round (repro.core.algo), not when rounds happen
        return PGASchedule(H=dist.H)
    if a == "gossip_aga":
        return AGASchedule(H_init=dist.aga_h_init, warmup=dist.aga_warmup,
                           H_max=dist.aga_h_max)
    if a == "slowmo":
        return SlowMoSchedule(H=dist.H)
    if a == "hier_pga":
        return HierPGASchedule(H_pod=dist.hier_h_pod, H_global=dist.H)
    from repro.core.algo import algorithm_names
    raise ValueError(f"make_schedule: unknown algorithm {a!r} "
                     f"(expected one of {algorithm_names()})")
