from repro.checkpoint.ckpt import (latest_step,  # noqa: F401
                                   restore_checkpoint, save_checkpoint)
