"""Sharding-aware checkpointing: pytree -> npz + structure manifest.

Arrays are gathered to host (``np.asarray`` addresses every shard), keyed by
their tree path; restore rebuilds into the template's structure and re-applies
the template's sharding via device_put.  msgpack-free, dependency-free.

Two contracts added for compressed runs (ISSUE 4 bugfixes):

* **dtype manifest** — npz cannot represent ml_dtypes leaves (bfloat16 /
  fp8 params, wire buffers): depending on the numpy version ``np.savez``
  either raises or silently degrades them to raw void (``|V2``) that
  ``restore`` cannot cast back.  Such leaves are saved as same-width
  unsigned-int **bit views** (uint16/uint8 — bit-exact, so resume is
  bitwise) and their true dtype is recorded in the manifest's ``dtypes``
  entry; restore views them back before the template cast.
* **optional extras reconcile** — ``TrainState.extras`` slots (the
  ``repro.core.algo`` descriptors: ``ef_state``, ``push_weight``, SlowMo's
  anchors, GT-PGA's tracker, ...) are config-dependent, so checkpoint and
  template can disagree on which slots exist.  Restore reconciles instead
  of KeyError-ing / silently dropping state: a checkpointed slot the
  template lacks grows into the template (a params-mirroring subtree grows
  a params-shaped fp32 slot; other shapes come from the npz itself), and a
  template slot the checkpoint predates is backfilled by the slot's
  registered kind — **ones** for ``push_weight`` (w = 1 is the push-sum
  init, Σw = n; a zero weight would make every de-biased read ``x/w``
  infinite), zeros for everything else (EF restarts empty, the correct
  semantic for newly-enabled compression; a zero GT tracker re-enters the
  tracking recursion from its init point).

Extras slots save under ``.extras/<slot>/...``; checkpoints written before
the extras dict (legacy top-level fields ``.ef_state/...``,
``.push_weight``, ``.slow_params/...``) restore transparently via a
per-key alias.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
from typing import Any, Dict, Optional

import jax
import numpy as np

PyTree = Any
_MANIFEST = "manifest.json"
_EXTRAS_PREFIX = ".extras/"                # TrainState extras slots
_DTYPES_KEY = "__dtype_manifest__"         # reserved npz entry, not a leaf
# TrainState fields that are NOT extras slots — a leading ".<name>" on any
# other key is a legacy (pre-extras) slot spelling
_CORE_FIELDS = ("params", "opt_state", "step", "extras")


def _known_slots():
    """Slot names the algorithm registry can own (legacy fallback when the
    registry is unavailable in standalone-checkpoint usage)."""
    try:
        from repro.core.algo import known_slot_names
        return set(known_slot_names())
    except ImportError:
        return {"slow_params", "slow_u", "ef_state", "push_weight"}


def _backfill_kind(slot_name: str) -> str:
    try:
        from repro.core.algo import backfill_kind
        return backfill_kind(slot_name)
    except ImportError:
        return "ones" if slot_name == "push_weight" else "zeros"


def _slot_of_key(key: str, known) -> Optional[str]:
    """Extras slot name a flat key addresses, else None.  Accepts both the
    current ``.extras/<slot>...`` spelling and the legacy top-level
    ``.<slot>...`` one."""
    if key.startswith(_EXTRAS_PREFIX):
        return key[len(_EXTRAS_PREFIX):].split("/", 1)[0]
    if key.startswith("."):
        name = key[1:].split("/", 1)[0]
        if name not in _CORE_FIELDS and name in known:
            return name
    return None


def _legacy_alias(key: str) -> Optional[str]:
    """Pre-extras spelling of an ``.extras/...`` key (``.ef_state/w`` for
    ``.extras/ef_state/w``)."""
    if key.startswith(_EXTRAS_PREFIX):
        return "." + key[len(_EXTRAS_PREFIX):]
    return None


def _flatten(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def _bit_view_dtype(dtype: np.dtype) -> Optional[np.dtype]:
    """The unsigned-int dtype to store ``dtype``'s raw bits, or None when
    npz handles it natively.  ml_dtypes types (bfloat16, float8_*) register
    as kind 'V', which npz cannot round-trip."""
    if dtype.kind != "V":
        return None
    return np.dtype({1: np.uint8, 2: np.uint16, 4: np.uint32}[dtype.itemsize])


def _resolve_dtype(name: str) -> np.dtype:
    """Dtype from its manifest name, covering the ml_dtypes families numpy
    itself cannot name."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def save_checkpoint(ckpt_dir: str, state: PyTree, step: int) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat, _ = _flatten(state)
    arrays: Dict[str, np.ndarray] = {}
    dtypes: Dict[str, str] = {}
    for k, v in flat.items():
        a = np.asarray(v)
        view = _bit_view_dtype(a.dtype)
        if view is not None:
            dtypes[k] = a.dtype.name
            a = a.view(view)
        arrays[k] = a
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    # the dtype manifest rides INSIDE the npz (authoritative, per step):
    # manifest.json only describes the latest save, so an older step
    # restored after a leaf changed dtype would otherwise be value-cast
    # from its raw bit view into garbage
    np.savez(path, **arrays,
             **{_DTYPES_KEY: np.asarray(json.dumps(dtypes))})
    with open(os.path.join(ckpt_dir, _MANIFEST), "w") as f:
        json.dump({"latest_step": step, "keys": sorted(arrays),
                   "dtypes": dtypes}, f, indent=1)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def _load_manifest(ckpt_dir: str) -> Dict[str, Any]:
    path = os.path.join(ckpt_dir, _MANIFEST)
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)


def _reconcile_extras(template: PyTree, data) -> PyTree:
    """Grow extras slots the checkpoint carries but the template lacks
    (see module docstring).  Non-TrainState templates pass through
    untouched."""
    try:
        from repro.train.state import TrainState
    except ImportError:                      # standalone-checkpoint usage
        return template
    if not isinstance(template, TrainState):
        return template
    known = _known_slots()
    present: Dict[str, list] = {}
    for k in data.files:
        if k == _DTYPES_KEY:
            continue
        name = _slot_of_key(k, known)
        if name is not None:
            present.setdefault(name, []).append(k)
    grow = {n: ks for n, ks in present.items() if n not in template.extras}
    if not grow:
        return template
    import jax.numpy as jnp
    params_suffixes = set(_flatten(template.params)[0])
    extras = dict(template.extras)
    for name, keys in sorted(grow.items()):
        bare_new, bare_old = _EXTRAS_PREFIX + name, "." + name
        if keys == [bare_new] or keys == [bare_old]:
            # bare single-array slot: shape comes from the npz itself
            extras[name] = jax.ShapeDtypeStruct(data[keys[0]].shape,
                                                jnp.float32)
            continue
        suffixes = {}
        for k in keys:
            base = bare_new if k.startswith(_EXTRAS_PREFIX) else bare_old
            suffixes[k[len(base) + 1:]] = k
        if set(suffixes) == params_suffixes:
            # params-mirroring slot (EF memory, GT tracker): grow a
            # params-shaped fp32 slot, preserving SDS-ness of the template
            extras[name] = jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
                if isinstance(p, jax.ShapeDtypeStruct)
                else jnp.zeros(p.shape, jnp.float32), template.params)
        else:
            # arbitrary subtree: rebuild a nested dict from the npz paths
            nested: Dict[str, Any] = {}
            for suffix, k in sorted(suffixes.items()):
                parts = suffix.split("/")
                d = nested
                for p in parts[:-1]:
                    d = d.setdefault(p, {})
                d[parts[-1]] = jax.ShapeDtypeStruct(data[k].shape,
                                                    jnp.float32)
            extras[name] = nested
    return dataclasses.replace(template, extras=extras)


def restore_checkpoint(ckpt_dir: str, template: PyTree,
                       step: Optional[int] = None) -> PyTree:
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    data = np.load(os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz"))
    if _DTYPES_KEY in data.files:            # per-step, authoritative
        dtypes = json.loads(str(data[_DTYPES_KEY]))
    else:                                    # older save: latest-step record
        dtypes = _load_manifest(ckpt_dir).get("dtypes", {})
    template = _reconcile_extras(template, data)
    flat, treedef = _flatten(template)
    known = _known_slots()
    leaves = []
    for key, tmpl in flat.items():
        if key not in data:
            slot = _slot_of_key(key, known)
            legacy = _legacy_alias(key)
            if legacy is not None and legacy in data:
                # pre-extras checkpoint: same slot, old spelling
                key = legacy
            elif slot is not None:
                # template expects a slot the checkpoint predates:
                # backfill by the slot's registered kind — ones for push
                # weights (zeros would blow up x/w), zeros otherwise (EF
                # restarts empty, GT tracking restarts from init)
                fill = (jax.numpy.ones if _backfill_kind(slot) == "ones"
                        else jax.numpy.zeros)
                leaves.append(fill(tmpl.shape, tmpl.dtype))
                continue
        arr = data[key]
        if key in dtypes:
            arr = arr.view(_resolve_dtype(dtypes[key]))
        elif arr.dtype.kind == "V" and hasattr(tmpl, "dtype"):
            # legacy checkpoint written before the dtype manifest: the npz
            # degraded the leaf to raw void — reinterpret via the template
            arr = arr.view(np.dtype(tmpl.dtype))
        elif arr.dtype.kind == "u" and hasattr(tmpl, "dtype") \
                and np.dtype(tmpl.dtype).kind == "V" \
                and arr.dtype.itemsize == np.dtype(tmpl.dtype).itemsize:
            # unsigned bit view whose manifest entry is missing (lost
            # manifest + older npz): a value cast would manufacture
            # garbage — reinterpret the bits via the template instead
            arr = arr.view(np.dtype(tmpl.dtype))
        if hasattr(tmpl, "sharding") and hasattr(tmpl.sharding, "mesh"):
            leaves.append(jax.device_put(arr, tmpl.sharding))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=tmpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
