"""Sharding-aware checkpointing: pytree -> npz + structure manifest.

Arrays are gathered to host (``np.asarray`` addresses every shard), keyed by
their tree path; restore rebuilds into the template's structure and re-applies
the template's sharding via device_put.  msgpack-free, dependency-free.

Two contracts added for compressed runs (ISSUE 4 bugfixes):

* **dtype manifest** — npz cannot represent ml_dtypes leaves (bfloat16 /
  fp8 params, wire buffers): depending on the numpy version ``np.savez``
  either raises or silently degrades them to raw void (``|V2``) that
  ``restore`` cannot cast back.  Such leaves are saved as same-width
  unsigned-int **bit views** (uint16/uint8 — bit-exact, so resume is
  bitwise) and their true dtype is recorded in the manifest's ``dtypes``
  entry; restore views them back before the template cast.
* **optional ``ef_state`` reconcile** — a ``TrainState`` checkpoint from a
  compressed run carries error-feedback memory that a fresh template built
  without compression lacks (and vice versa).  Restore reconciles instead
  of KeyError-ing / silently dropping the EF memory: a checkpointed
  ``ef_state`` is restored even when the template has ``ef_state=None``
  (the template grows a params-shaped fp32 slot), and a template expecting
  ``ef_state`` that the checkpoint predates gets fresh zeros (EF restarts
  empty, the correct semantic for newly-enabled compression).

The push-sum weight scalar (``TrainState.push_weight``, DESIGN.md §2.5)
gets the same optional-field reconcile: a checkpointed weight is restored
into a template built without push-sum (the slot grows from the npz
shape), and a push-sum template restoring a pre-push-sum checkpoint gets
fresh **ones** — not zeros: w = 1 is the push-sum init (Σw = n), and a
zero weight would make every de-biased read ``x/w`` infinite.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
from typing import Any, Dict, Optional

import jax
import numpy as np

PyTree = Any
_MANIFEST = "manifest.json"
_EF_PREFIX = ".ef_state/"
_EF_KEY = ".ef_state"                      # bare-array (single-leaf) ef_state
_PUSH_KEY = ".push_weight"                 # push-sum weight scalar (n, 1)
_DTYPES_KEY = "__dtype_manifest__"         # reserved npz entry, not a leaf


def _is_ef_key(key: str) -> bool:
    return key == _EF_KEY or key.startswith(_EF_PREFIX)


def _flatten(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def _bit_view_dtype(dtype: np.dtype) -> Optional[np.dtype]:
    """The unsigned-int dtype to store ``dtype``'s raw bits, or None when
    npz handles it natively.  ml_dtypes types (bfloat16, float8_*) register
    as kind 'V', which npz cannot round-trip."""
    if dtype.kind != "V":
        return None
    return np.dtype({1: np.uint8, 2: np.uint16, 4: np.uint32}[dtype.itemsize])


def _resolve_dtype(name: str) -> np.dtype:
    """Dtype from its manifest name, covering the ml_dtypes families numpy
    itself cannot name."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def save_checkpoint(ckpt_dir: str, state: PyTree, step: int) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat, _ = _flatten(state)
    arrays: Dict[str, np.ndarray] = {}
    dtypes: Dict[str, str] = {}
    for k, v in flat.items():
        a = np.asarray(v)
        view = _bit_view_dtype(a.dtype)
        if view is not None:
            dtypes[k] = a.dtype.name
            a = a.view(view)
        arrays[k] = a
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    # the dtype manifest rides INSIDE the npz (authoritative, per step):
    # manifest.json only describes the latest save, so an older step
    # restored after a leaf changed dtype would otherwise be value-cast
    # from its raw bit view into garbage
    np.savez(path, **arrays,
             **{_DTYPES_KEY: np.asarray(json.dumps(dtypes))})
    with open(os.path.join(ckpt_dir, _MANIFEST), "w") as f:
        json.dump({"latest_step": step, "keys": sorted(arrays),
                   "dtypes": dtypes}, f, indent=1)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def _load_manifest(ckpt_dir: str) -> Dict[str, Any]:
    path = os.path.join(ckpt_dir, _MANIFEST)
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)


def _reconcile_ef(template: PyTree, data) -> PyTree:
    """Align an optional ``TrainState.ef_state`` between checkpoint and
    template (see module docstring).  Non-TrainState templates pass
    through untouched."""
    try:
        from repro.train.state import TrainState
    except ImportError:                      # standalone-checkpoint usage
        return template
    if not isinstance(template, TrainState):
        return template
    ef_keys = [k for k in data.files if _is_ef_key(k)]
    if ef_keys and template.ef_state is None:
        import jax.numpy as jnp
        if ef_keys == [_EF_KEY]:
            # bare single-array EF memory: shape comes from the npz itself
            ef_tmpl = jax.ShapeDtypeStruct(data[_EF_KEY].shape, jnp.float32)
        else:
            # params-mirroring EF tree: grow a params-shaped fp32 slot
            ef_tmpl = jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
                if isinstance(p, jax.ShapeDtypeStruct)
                else jnp.zeros(p.shape, jnp.float32), template.params)
        return dataclasses.replace(template, ef_state=ef_tmpl)
    return template


def _reconcile_push(template: PyTree, data) -> PyTree:
    """Align the optional ``TrainState.push_weight`` between checkpoint and
    template (same contract shape as :func:`_reconcile_ef`)."""
    try:
        from repro.train.state import TrainState
    except ImportError:
        return template
    if not isinstance(template, TrainState):
        return template
    if _PUSH_KEY in data.files and template.push_weight is None:
        import jax.numpy as jnp
        slot = jax.ShapeDtypeStruct(data[_PUSH_KEY].shape, jnp.float32)
        return dataclasses.replace(template, push_weight=slot)
    return template


def restore_checkpoint(ckpt_dir: str, template: PyTree,
                       step: Optional[int] = None) -> PyTree:
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    data = np.load(os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz"))
    if _DTYPES_KEY in data.files:            # per-step, authoritative
        dtypes = json.loads(str(data[_DTYPES_KEY]))
    else:                                    # older save: latest-step record
        dtypes = _load_manifest(ckpt_dir).get("dtypes", {})
    template = _reconcile_ef(template, data)
    template = _reconcile_push(template, data)
    flat, treedef = _flatten(template)
    leaves = []
    for key, tmpl in flat.items():
        if key not in data and _is_ef_key(key):
            # template expects EF memory the checkpoint predates: fresh
            # zeros (EF restarts empty when compression is newly enabled)
            leaves.append(jax.numpy.zeros(tmpl.shape, tmpl.dtype))
            continue
        if key not in data and key == _PUSH_KEY:
            # push-sum template, pre-push-sum checkpoint: the weight
            # restarts at its init value 1 (zeros would blow up x/w)
            leaves.append(jax.numpy.ones(tmpl.shape, tmpl.dtype))
            continue
        arr = data[key]
        if key in dtypes:
            arr = arr.view(_resolve_dtype(dtypes[key]))
        elif arr.dtype.kind == "V" and hasattr(tmpl, "dtype"):
            # legacy checkpoint written before the dtype manifest: the npz
            # degraded the leaf to raw void — reinterpret via the template
            arr = arr.view(np.dtype(tmpl.dtype))
        elif arr.dtype.kind == "u" and hasattr(tmpl, "dtype") \
                and np.dtype(tmpl.dtype).kind == "V" \
                and arr.dtype.itemsize == np.dtype(tmpl.dtype).itemsize:
            # unsigned bit view whose manifest entry is missing (lost
            # manifest + older npz): a value cast would manufacture
            # garbage — reinterpret the bits via the template instead
            arr = arr.view(np.dtype(tmpl.dtype))
        if hasattr(tmpl, "sharding") and hasattr(tmpl.sharding, "mesh"):
            leaves.append(jax.device_put(arr, tmpl.sharding))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=tmpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
