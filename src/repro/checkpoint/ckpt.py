"""Sharding-aware checkpointing: pytree -> npz + structure manifest.

Arrays are gathered to host (``np.asarray`` addresses every shard), keyed by
their tree path; restore rebuilds into the template's structure and re-applies
the template's sharding via device_put.  msgpack-free, dependency-free.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any
_MANIFEST = "manifest.json"


def _flatten(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save_checkpoint(ckpt_dir: str, state: PyTree, step: int) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat, _ = _flatten(state)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    np.savez(path, **arrays)
    with open(os.path.join(ckpt_dir, _MANIFEST), "w") as f:
        json.dump({"latest_step": step, "keys": sorted(arrays)}, f, indent=1)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, template: PyTree,
                       step: Optional[int] = None) -> PyTree:
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    data = np.load(os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz"))
    flat, treedef = _flatten(template)
    leaves = []
    for key, tmpl in flat.items():
        arr = data[key]
        if hasattr(tmpl, "sharding") and hasattr(tmpl.sharding, "mesh"):
            leaves.append(jax.device_put(arr, tmpl.sharding))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=tmpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
