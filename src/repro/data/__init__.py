from repro.data.logistic import LogisticProblem, make_logistic_problem  # noqa: F401
from repro.data.synthetic import SyntheticStream, make_stream  # noqa: F401
