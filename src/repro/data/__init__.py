from repro.data.logistic import (LogisticProblem,  # noqa: F401
                                 make_logistic_problem)
from repro.data.synthetic import SyntheticStream, make_stream  # noqa: F401
