from repro.data.logistic import (LogisticProblem,  # noqa: F401
                                 dirichlet_noniid_problem,
                                 make_logistic_problem)
from repro.data.synthetic import SyntheticStream, make_stream  # noqa: F401
