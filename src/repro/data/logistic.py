"""Distributed logistic regression data — exactly paper §5.1.

f_i(x) = (1/M) Σ_m ln(1 + exp(-y_{i,m} h_{i,m}^T x))
h ~ N(0, 10 I_d); label y from the logistic model at a node-specific x*_i
(non-iid) or a shared x* (iid); each x*_i normalized.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class LogisticProblem:
    H: jnp.ndarray            # (n, M, d) features
    y: jnp.ndarray            # (n, M) labels in {+1, -1}
    d: int
    n: int
    M: int

    def grad_fn(self, batch: int = 0) -> Callable:
        """Per-node stochastic gradient: sample ``batch`` examples per node
        (0 = full gradient)."""
        H, y, M = self.H, self.y, self.M

        def full_grad(x, key, step):
            # x: (n, d)
            z = -y * jnp.einsum("nmd,nd->nm", H, x)
            s = jax.nn.sigmoid(z)                       # = 1-1/(1+e^z)
            g = -jnp.einsum("nm,nmd->nd", s * y, H) / M
            return g

        if batch <= 0:
            return full_grad

        def stoch_grad(x, key, step):
            idx = jax.random.randint(key, (self.n, batch), 0, M)
            Hb = jnp.take_along_axis(H, idx[..., None], axis=1)
            yb = jnp.take_along_axis(y, idx, axis=1)
            z = -yb * jnp.einsum("nmd,nd->nm", Hb, x)
            s = jax.nn.sigmoid(z)
            return -jnp.einsum("nm,nmd->nd", s * yb, Hb) / batch

        return stoch_grad

    def loss_fn(self) -> Callable:
        H, y = self.H, self.y

        def loss(xbar):
            z = -y * jnp.einsum("nmd,d->nm", H, xbar)
            return jnp.mean(jnp.logaddexp(0.0, z))

        return loss


def make_logistic_problem(n: int, M: int = 8000, d: int = 10, *,
                          iid: bool = False, seed: int = 0
                          ) -> LogisticProblem:
    rng = np.random.default_rng(seed)
    H = rng.normal(0.0, np.sqrt(10.0), size=(n, M, d))
    if iid:
        x_star = rng.standard_normal(d)
        x_star /= np.linalg.norm(x_star)
        xs = np.broadcast_to(x_star, (n, d))
    else:
        xs = rng.standard_normal((n, d))
        xs /= np.linalg.norm(xs, axis=1, keepdims=True)
    p = 1.0 / (1.0 + np.exp(-np.einsum("nmd,nd->nm", H, xs)))
    u = rng.uniform(size=(n, M))
    y = np.where(u <= p, 1.0, -1.0)
    return LogisticProblem(jnp.asarray(H), jnp.asarray(y), d, n, M)


def dirichlet_noniid_problem(n: int, M: int = 8000, d: int = 10, *,
                             alpha: float = 0.3, feature_shift: float = 2.0,
                             seed: int = 0) -> LogisticProblem:
    """Label-skew + feature-shift sharding of ONE shared task.

    Unlike :func:`make_logistic_problem`'s non-iid mode (a *different*
    optimum per node), every node here shares one ground-truth ``x*`` —
    so the global objective has a single well-defined minimizer — but the
    local objectives are heterogeneous in the federated-learning sense:

    * **Dirichlet label skew** — a global pool of 2n·M examples is labeled
      from the shared logistic model, then each node draws its local
      class proportions from ``Dirichlet(alpha, alpha)`` and samples its
      M examples from the class-conditional pools (with replacement when
      a pool runs short).  Small ``alpha`` → near-single-class nodes.
    * **feature shift** — node i's features are mean-shifted by
      ``feature_shift`` along a node-specific random unit direction, so
      even the input marginals P_i(h) differ.

    This is the regime where plain gossip SGD stalls at a consensus-bias
    floor and gradient tracking (gt_pga) keeps descending — the
    benchmarks/bench_logistic_transient.py non-IID crossover gate runs on
    this sharder.  Fully deterministic per ``seed``.
    """
    if n < 1:
        raise ValueError(f"dirichlet_noniid_problem: n must be >= 1, "
                         f"got {n}")
    if alpha <= 0.0:
        raise ValueError(f"dirichlet_noniid_problem: alpha must be > 0, "
                         f"got {alpha}")
    rng = np.random.default_rng(seed)
    pool = 2 * n * M
    Hp = rng.normal(0.0, np.sqrt(10.0), size=(pool, d))
    x_star = rng.standard_normal(d)
    x_star /= np.linalg.norm(x_star)
    p = 1.0 / (1.0 + np.exp(-Hp @ x_star))
    yp = np.where(rng.uniform(size=pool) <= p, 1.0, -1.0)
    by_class = {+1: np.flatnonzero(yp > 0), -1: np.flatnonzero(yp < 0)}

    H = np.empty((n, M, d))
    y = np.empty((n, M))
    for i in range(n):
        props = rng.dirichlet([alpha, alpha])
        n_pos = int(round(props[0] * M))
        for cls, count in ((+1, n_pos), (-1, M - n_pos)):
            if count == 0:
                continue
            src = by_class[cls]
            idx = rng.choice(src, size=count,
                             replace=count > src.size)
            sl = slice(0, count) if cls > 0 else slice(M - count, M)
            H[i, sl] = Hp[idx]
            y[i, sl] = cls
        shift = rng.standard_normal(d)
        shift /= np.linalg.norm(shift)
        H[i] += feature_shift * shift
    return LogisticProblem(jnp.asarray(H), jnp.asarray(y), d, n, M)
