"""Distributed logistic regression data — exactly paper §5.1.

f_i(x) = (1/M) Σ_m ln(1 + exp(-y_{i,m} h_{i,m}^T x))
h ~ N(0, 10 I_d); label y from the logistic model at a node-specific x*_i
(non-iid) or a shared x* (iid); each x*_i normalized.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class LogisticProblem:
    H: jnp.ndarray            # (n, M, d) features
    y: jnp.ndarray            # (n, M) labels in {+1, -1}
    d: int
    n: int
    M: int

    def grad_fn(self, batch: int = 0) -> Callable:
        """Per-node stochastic gradient: sample ``batch`` examples per node
        (0 = full gradient)."""
        H, y, M = self.H, self.y, self.M

        def full_grad(x, key, step):
            # x: (n, d)
            z = -y * jnp.einsum("nmd,nd->nm", H, x)
            s = jax.nn.sigmoid(z)                       # = 1-1/(1+e^z)
            g = -jnp.einsum("nm,nmd->nd", s * y, H) / M
            return g

        if batch <= 0:
            return full_grad

        def stoch_grad(x, key, step):
            idx = jax.random.randint(key, (self.n, batch), 0, M)
            Hb = jnp.take_along_axis(H, idx[..., None], axis=1)
            yb = jnp.take_along_axis(y, idx, axis=1)
            z = -yb * jnp.einsum("nmd,nd->nm", Hb, x)
            s = jax.nn.sigmoid(z)
            return -jnp.einsum("nm,nmd->nd", s * yb, Hb) / batch

        return stoch_grad

    def loss_fn(self) -> Callable:
        H, y = self.H, self.y

        def loss(xbar):
            z = -y * jnp.einsum("nmd,d->nm", H, xbar)
            return jnp.mean(jnp.logaddexp(0.0, z))

        return loss


def make_logistic_problem(n: int, M: int = 8000, d: int = 10, *,
                          iid: bool = False, seed: int = 0
                          ) -> LogisticProblem:
    rng = np.random.default_rng(seed)
    H = rng.normal(0.0, np.sqrt(10.0), size=(n, M, d))
    if iid:
        x_star = rng.standard_normal(d)
        x_star /= np.linalg.norm(x_star)
        xs = np.broadcast_to(x_star, (n, d))
    else:
        xs = rng.standard_normal((n, d))
        xs /= np.linalg.norm(xs, axis=1, keepdims=True)
    p = 1.0 / (1.0 + np.exp(-np.einsum("nmd,nd->nm", H, xs)))
    u = rng.uniform(size=(n, M))
    y = np.where(u <= p, 1.0, -1.0)
    return LogisticProblem(jnp.asarray(H), jnp.asarray(y), d, n, M)
