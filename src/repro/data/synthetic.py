"""Synthetic data pipeline.

Deterministic, seeded, infinitely streaming batches for every model family —
no external datasets in this offline container.  The LM stream has genuine
learnable structure (an affine next-token map corrupted by noise) so training
loss decreases; per-node distribution shift implements the paper's *non-iid*
regime (each node's token distribution is biased toward a node-specific region
of the vocabulary, strength ``non_iid_alpha``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional

import numpy as np

from repro.configs.base import DataConfig, ModelConfig

PyTree = Any


@dataclasses.dataclass
class SyntheticStream:
    """get_batch(step) -> batch dict with leading (n_nodes, per_node_batch)."""
    model_cfg: ModelConfig
    data_cfg: DataConfig
    n_nodes: int
    per_node_batch: int
    seq_len: int
    noise: float = 0.15          # fraction of corrupted next-token targets

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.data_cfg.seed, step]))

    def _node_logits(self, vocab: int) -> np.ndarray:
        """Per-node unigram biases (non-iid): node i prefers a vocab band."""
        if not self.data_cfg.non_iid or self.n_nodes == 1:
            return np.zeros((self.n_nodes, vocab))
        rng = np.random.default_rng(self.data_cfg.seed)
        centers = rng.uniform(0, vocab, size=self.n_nodes)
        pos = np.arange(vocab)[None, :]
        width = vocab / 4.0
        dist = np.minimum(np.abs(pos - centers[:, None]),
                          vocab - np.abs(pos - centers[:, None]))
        return -self.data_cfg.non_iid_alpha * (dist / width) ** 2

    def _sample_tokens(self, rng, vocab: int) -> np.ndarray:
        n, b, s = self.n_nodes, self.per_node_batch, self.seq_len
        logits = self._node_logits(vocab)
        p = np.exp(logits - logits.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        toks = np.stack([
            rng.choice(vocab, size=(b, s), p=p[i]) for i in range(n)])
        return toks.astype(np.int32)

    def _next_token_map(self, tokens: np.ndarray, vocab: int,
                        rng) -> np.ndarray:
        """targets[t] = (a*inputs[t] + c) mod V, with noise."""
        a, c = 31, 17
        tgt = (a * tokens + c) % vocab
        corrupt = rng.random(tgt.shape) < self.noise
        tgt = np.where(corrupt, rng.integers(0, vocab, tgt.shape), tgt)
        return tgt.astype(np.int32)

    def get_batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.model_cfg
        rng = self._rng(step)
        V = cfg.vocab_size
        if cfg.family == "encoder" and cfg.audio is not None:
            n, b, s = self.n_nodes, self.per_node_batch, self.seq_len
            d = cfg.d_model
            targets = self._sample_tokens(rng, V)
            # frame embeddings carry the unit identity (learnable objective)
            basis = np.random.default_rng(self.data_cfg.seed).standard_normal(
                (V, d)).astype(np.float32) / np.sqrt(d)
            frames = basis[targets] + 0.1 * rng.standard_normal(
                (n, b, s, d)).astype(np.float32)
            mask = (rng.random((n, b, s))
                    < cfg.audio.mask_prob * cfg.audio.mask_span / 2)
            return {"frames": frames.astype(np.float32), "mask": mask,
                    "targets": targets}
        tokens = self._sample_tokens(rng, V)
        batch: Dict[str, np.ndarray] = {
            "inputs": tokens,
            "targets": self._next_token_map(tokens, V, rng),
        }
        if cfg.family == "encoder":
            batch["mask"] = rng.random(tokens.shape) < 0.15
        if cfg.family == "vlm" and cfg.vision is not None:
            n_img = cfg.vision.n_tiles * cfg.vision.patches_per_tile
            n, b = self.n_nodes, self.per_node_batch
            batch["patches"] = rng.standard_normal(
                (n, b, n_img, cfg.d_model)).astype(np.float32) * 0.02
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.get_batch(step)
            step += 1


def make_stream(model_cfg: ModelConfig, data_cfg: DataConfig, *,
                n_nodes: int, global_batch: int, seq_len: int
                ) -> SyntheticStream:
    assert global_batch % n_nodes == 0, (global_batch, n_nodes)
    return SyntheticStream(model_cfg, data_cfg, n_nodes,
                           global_batch // n_nodes, seq_len)
