"""Training-step builder: per-node forward/backward (vmapped over the node
axis) → per-node optimizer update → communication round (the paper's Alg. 1).

One compiled variant per communication phase — "gossip(shift)", "global",
"none", "slowmo" — dispatched host-side by the schedule (DESIGN.md §2.2), so
each HLO carries exactly the collectives of its phase and cost/collective
analysis per phase is exact.

There is exactly ONE step body (``_core`` below): algorithm-specific
behaviour enters through the ``repro.core.algo`` hooks (``pre_update`` /
``comm_payload`` / ``post_round``), and the execution-mode axes (sync /
overlap / push-sum / fused-consensus) parameterize how the round itself
runs.  The returned callable keeps the historical per-mode signature.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core import algo as algo_lib
from repro.core import mixing
from repro.core import topology as topo
from repro.core.algo import phases_for_algorithm  # noqa: F401  (re-export)
from repro.models.model import Model
from repro.optim import clip_by_global_norm, make_optimizer
from repro.train.state import TrainState, consensus_distance, debias

PyTree = Any


def _grad_global_norm(grads: PyTree) -> jax.Array:
    """Global L2 norm over all nodes' grads — a cheap on-device monitor
    (one reduction per leaf inside the step; no host sync).  Emitted as
    ``metrics["grad_norm"]`` when monitors are on (DESIGN.md §2.7)."""
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def build_train_step(model: Model, tcfg: TrainConfig, n_nodes: int, *,
                     phase: str, shift_step: int = 0,
                     buf_shift: int = 0,
                     with_consensus: bool = False,
                     unroll: bool = False,
                     mesh: Optional[jax.sharding.Mesh] = None,
                     fault_hops: Optional[Tuple[int, ...]] = None
                     ) -> Callable:
    """Returns step(state, batch, lr) -> (state, metrics).

    ``phase``: one of ``phases_for_algorithm(dist.algorithm)``.
    batch leaves carry leading (n_nodes, per_node_batch, …).

    With ``DistConfig.comm_overlap`` the returned step has the 4-arg
    signature ``step(state, batch, lr, comm_buf) -> (state, metrics,
    comm_buf)`` (DESIGN.md §2.6): gossip phases *finish* the in-flight
    round primed one step ago — applying W with ``buf_shift``, the shift
    recorded when the buffer was primed — against the stale buffer, then
    *start* the next round from this step's half-step params; global /
    pod_avg / algorithm-owned phases run synchronously (the period
    boundary is the natural flush) and re-prime the buffer from their
    result; phase "none" passes the buffer through untouched.

    With a ``mesh`` whose node axis is sharded, the pallas comm backend
    routes through the shard_map-aware path (DESIGN.md §2.1 dispatch
    table) — per-shard fused kernels with ppermute halo exchange —
    honoring ``DistConfig.comm_shard_mode``.

    With ``DistConfig.push_sum`` the returned step has the 5-arg signature
    ``step(state, batch, lr, W, active)`` (DESIGN.md §2.5): ``W`` is the
    round's column-stochastic matrix as a **traced** ``(n, n)`` operand —
    fault drops and resampling are new data, never new compiles — and
    ``active`` the ``(n,)`` live mask; dropped nodes' grads are zeroed and
    their params/opt rows frozen.  ``fault_hops`` (from
    ``FaultSchedule.hop_superset``) statically bounds the sharded path's
    halo offsets.

    Algorithms with a comm payload (GT-PGA's tracker) ride it through the
    round as one joint tree ``{"params": ..., <slot>: ...}``, so every
    backend / compressor / overlap / push-sum combination above applies
    to the payload unchanged.
    """
    dist = tcfg.dist
    dist.validate_nodes(n_nodes)
    algo = algo_lib.get_algorithm(dist.algorithm, caller="build_train_step")
    sharded_comm = mixing.use_sharded_backend(
        dist.comm_backend, mesh, dist.node_axis, dist.comm_shard_mode)
    # the round-invariant knobs, captured once (DESIGN.md §2.1): every
    # communicate call below goes through this spec, so a knob added to
    # CommSpec is forwarded everywhere by construction
    spec = dist.comm_spec(n_nodes, mesh=mesh)
    spec_plain = spec.replace(compressor=None, global_compressor=None)
    # wire compressor (DESIGN.md §2.3): built once at step-build time; the
    # identity compressor routes to the exact uncompressed path inside
    # mixing.communicate, so only a *lossy* compressor changes the step
    compressor = spec.compressor
    lossy_comm = spec.lossy
    # compressed collective for the averaging phases (DESIGN.md §2.3
    # "Compressed collectives"): identity routes to the exact psum path
    # inside mixing, so only a lossy choice changes the step
    global_compressor = spec.global_compressor
    lossy_global = global_compressor is not None and global_compressor.lossy
    opt = make_optimizer(tcfg.optimizer, per_node=True)
    # DistConfig.remat/remat_policy -> blocks.make_remat policy string
    if dist.remat == "none":
        remat_policy = "none"
    elif dist.remat_policy == "dots":
        remat_policy = "dots"
    else:
        remat_policy = "default"

    mode = ("push" if dist.push_sum
            else "overlap" if dist.comm_overlap else "sync")
    owned = phase in algo.owned_phases

    def node_loss(params, batch):
        return model.loss(params, batch, remat=remat_policy,
                          z_loss=tcfg.z_loss, unroll=unroll)

    def total_loss(params, batch):
        losses, metrics = jax.vmap(node_loss)(params, batch)
        # sum over nodes => grads land per-node, unscaled (paper Alg. 1)
        return jnp.sum(losses), jax.tree.map(jnp.mean, metrics)

    grad_fn = jax.grad(total_loss, has_aux=True)

    def accum_grad_fn(params, batch):
        """Gradient accumulation: split the per-node batch into
        ``tcfg.microbatches`` slices and scan — activation memory drops ~m×
        at unchanged math (equal-size microbatch mean == full-batch mean)."""
        m = tcfg.microbatches

        def to_mb(t):
            n, b = t.shape[:2]
            return t.reshape((n, m, b // m) + t.shape[2:]).swapaxes(0, 1)

        mbs = jax.tree.map(to_mb, batch)

        def body(acc, mb):
            g, met = grad_fn(params, mb)
            acc = jax.tree.map(jnp.add, acc, g)
            return acc, met

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, mets = jax.lax.scan(body, zeros, mbs)
        grads = jax.tree.map(lambda g: g / m, grads)
        return grads, jax.tree.map(jnp.mean, mets)

    # -- push-sum round constants ------------------------------------------
    ps_offsets = None
    if mode == "push" and sharded_comm:
        # static halo superset for the sharded ppermute path: every shift
        # the topology (over its whole period) or the fault schedule's
        # resampling can ever emit — the runtime W only re-weights them
        k = mixing.node_shard_count(mesh, dist.node_axis)
        if phase == "global":
            ps_offsets = tuple(range(k))
        else:
            hops = set(fault_hops or ())
            period = max(1, topo.schedule_period(dist.topology, n_nodes))
            for s in range(period):
                hops |= set(topo.shift_weights(dist.topology, n_nodes, s))
            ps_offsets = mixing.push_sum_shard_offsets(n_nodes, k, hops)
    comm_dtype_ps = spec.comm_dtype

    def freeze_dropped(new: PyTree, old: PyTree,
                       active: jax.Array) -> PyTree:
        """Dropped nodes take no step: revert their node rows (params
        AND optimizer state — a zero grad still decays momentum, which
        would silently train the dead node).  Leaves without a node
        axis (shared counters) pass through."""
        a = active.astype(jnp.bool_)

        def one(nw, od):
            if not hasattr(nw, "ndim") or nw.ndim == 0 \
                    or nw.shape[0] != n_nodes:
                return nw
            m = a.reshape((n_nodes,) + (1,) * (nw.ndim - 1))
            return jnp.where(m, nw, od)

        return jax.tree.map(one, new, old)

    # -- per-mode round bodies ---------------------------------------------
    def _push_round(extras, params_half, step_seed, W, active):
        payload = algo.comm_payload(extras, params_half)
        has_payload = bool(payload)
        joint = algo_lib.join_payload(payload, params_half)
        w = extras["push_weight"]
        new_w = w
        if phase == "none" or n_nodes == 1:
            mixed = joint
        elif lossy_comm and phase == "gossip":
            mixed, new_w, new_ef = mixing.communicate_push_sum(
                joint, w, W=W, n_nodes=n_nodes,
                comm_dtype=comm_dtype_ps, backend=dist.comm_backend,
                mesh=mesh, node_axis=dist.node_axis,
                shard_mode=dist.comm_shard_mode,
                model_axis=dist.model_axis,
                leaf_threshold=dist.pallas_leaf_threshold,
                offsets=ps_offsets, compressor=compressor,
                ef_state=extras.get("ef_state"), seed=step_seed)
            if new_ef is not None:
                extras["ef_state"] = new_ef
        else:
            mixed, new_w = mixing.communicate_push_sum(
                joint, w, W=W, n_nodes=n_nodes,
                comm_dtype=comm_dtype_ps, backend=dist.comm_backend,
                mesh=mesh, node_axis=dist.node_axis,
                shard_mode=dist.comm_shard_mode,
                model_axis=dist.model_axis,
                leaf_threshold=dist.pallas_leaf_threshold,
                offsets=ps_offsets)
        if phase == "global":
            # a full-participation global round sets every w_i to
            # Σw/n = 1 in exact arithmetic; snap to it so the PGA
            # reset also washes out accumulated fp drift in w
            new_w = jnp.where(jnp.all(active > 0),
                              jnp.ones_like(new_w), new_w)
        extras["push_weight"] = new_w
        return algo_lib.wrap_mixed(mixed, has_payload)

    def _overlap_round(extras, params_half, step_seed, comm_buf, sctx):
        payload = algo.comm_payload(extras, params_half)
        has_payload = bool(payload)
        joint = algo_lib.join_payload(payload, params_half)
        new_buf = comm_buf
        if phase == "none" or n_nodes == 1:
            return algo_lib.wrap_mixed(joint, has_payload), new_buf, None
        if owned:
            # algorithm-owned phase (SlowMo outer step): no round to
            # finish — post_round consumes the half-step directly and its
            # result re-primes the in-flight buffer
            new_params, extras2 = algo.post_round(
                extras, algo_lib.wrap_mixed(joint, has_payload), phase, sctx)
            extras.clear()
            extras.update(extras2)
            reprime = algo_lib.join_payload(
                algo.comm_payload(extras, new_params), new_params)
            new_buf, new_ef = mixing.start_round(
                reprime, spec, ef_state=extras.get("ef_state"),
                seed=step_seed)
            # the dense buffer aliases new_params; copy so the jit
            # outputs (state, comm_buf) never share a buffer — both
            # are donated back to the next step
            new_buf = jax.tree.map(jnp.copy, new_buf)
            if new_ef is not None:
                extras["ef_state"] = new_ef
            return None, new_buf, new_params
        if phase == "gossip":
            # finish the round primed one step ago (its shift, not
            # this step's), then immediately issue the next one from
            # this half-step — x_{t+1} = y_t + (W(buf_shift) - I)·y_{t-1}
            mixed = mixing.finish_round(joint, comm_buf, spec,
                                        step=buf_shift)
            new_buf, new_ef = mixing.start_round(
                joint, spec, ef_state=extras.get("ef_state"),
                seed=step_seed)
        else:
            # global / pod_avg: synchronous flush + re-prime
            mixed, new_buf, new_ef = mixing.overlap_flush(
                joint, spec, phase=phase, step=shift_step,
                ef_state=extras.get("ef_state"), seed=step_seed)
            new_buf = jax.tree.map(jnp.copy, new_buf)
        if new_ef is not None:
            extras["ef_state"] = new_ef
        return algo_lib.wrap_mixed(mixed, has_payload), new_buf, None

    def _sync_round(extras, params_half, step_seed):
        payload = algo.comm_payload(extras, params_half)
        has_payload = bool(payload)
        joint = algo_lib.join_payload(payload, params_half)
        if owned:
            return algo_lib.wrap_mixed(joint, has_payload), None
        mixed = None
        fused_consensus = None
        lossy_round = (lossy_comm or
                       (lossy_global and phase in ("global", "pod_avg")))
        if (lossy_round and n_nodes > 1
                and phase in ("gossip", "global", "pod_avg")):
            # compressed round: the SR seed is the absolute step (so
            # rounding is unbiased across steps); consensus falls back
            # to consensus_distance below — residual fusion does not
            # compose with compression (DESIGN.md §2.3)
            mixed, new_ef = mixing.communicate(
                joint, spec, phase=phase, step=shift_step,
                axis=0, ef_state=extras.get("ef_state"), seed=step_seed)
            if new_ef is not None:
                extras["ef_state"] = new_ef
        elif (dist.comm_backend == "pallas" and with_consensus
                and n_nodes > 1 and not has_payload
                and phase in ("gossip", "global", "pod_avg")):
            # fused: the mixing kernel emits the consensus residual in
            # the same parameter pass instead of re-reading new_params
            # (bypasses communicate(), so meter the round explicitly)
            mixing.meter_round(params_half, spec_plain, phase=phase,
                               step=shift_step)
            if sharded_comm:
                mixed, _xbar, resid = mixing.communicate_sharded(
                    params_half, spec_plain, phase=phase,
                    step=shift_step, with_residual=True)
            else:
                from repro.kernels import mixing_pallas
                mixed, _xbar, resid = mixing_pallas.mix_residual(
                    params_half, phase=phase, topology=dist.topology,
                    n_nodes=n_nodes, step=shift_step,
                    comm_dtype=spec.comm_dtype, n_pods=dist.n_pods,
                    leaf_threshold=dist.pallas_leaf_threshold)
            fused_consensus = resid / n_nodes
        if mixed is None:
            mixed = mixing.communicate(
                joint, spec_plain, phase=phase, step=shift_step)
        return algo_lib.wrap_mixed(mixed, has_payload), fused_consensus

    # -- the one step body -------------------------------------------------
    def _core(state: TrainState, batch: PyTree, lr: jax.Array,
              comm_buf=None, W=None, active=None
              ) -> Tuple[TrainState, Dict[str, jax.Array], Any]:
        extras = dict(state.extras)
        if tcfg.microbatches > 1:
            grads, metrics = accum_grad_fn(state.params, batch)
        else:
            grads, metrics = grad_fn(state.params, batch)
        if mode == "push":
            af = active.astype(jnp.float32)
            grads = jax.tree.map(
                lambda g: g * af.reshape((n_nodes,) + (1,) * (g.ndim - 1)),
                grads)
        if with_consensus:
            metrics = dict(metrics)
            metrics["grad_norm"] = _grad_global_norm(grads)
        if tcfg.optimizer.grad_clip:
            grads = clip_by_global_norm(grads, tcfg.optimizer.grad_clip)
        upd, extras = algo.pre_update(extras, grads)
        extras = dict(extras)
        params_half, opt_state = opt.update(upd, state.opt_state,
                                            state.params, lr)
        sctx = algo_lib.StepContext(dist=dist, n_nodes=n_nodes, lr=lr)
        fused_consensus = None
        new_buf = comm_buf
        if mode == "push":
            params_half = freeze_dropped(params_half, state.params, active)
            opt_state = freeze_dropped(opt_state, state.opt_state, active)
            mixed = _push_round(extras, params_half, state.step, W, active)
            new_params, extras = algo.post_round(extras, mixed, phase, sctx)
        elif mode == "overlap":
            mixed, new_buf, owned_params = _overlap_round(
                extras, params_half, state.step, comm_buf, sctx)
            if owned_params is not None:
                new_params = owned_params
            else:
                new_params, extras = algo.post_round(extras, mixed, phase,
                                                     sctx)
        else:
            mixed, fused_consensus = _sync_round(extras, params_half,
                                                 state.step)
            new_params, extras = algo.post_round(extras, mixed, phase, sctx)
        metrics = dict(metrics)
        if mode == "push":
            # the checkable invariant: Σw = n for every column-stochastic
            # round, every fault pattern (DESIGN.md §2.5)
            new_w = extras["push_weight"]
            metrics["mass"] = jnp.sum(new_w.astype(jnp.float32))
            if with_consensus:
                metrics["consensus"] = consensus_distance(
                    debias(new_params, new_w))
        elif with_consensus:
            metrics["consensus"] = (fused_consensus
                                    if fused_consensus is not None
                                    else consensus_distance(new_params))
        new_state = TrainState(params=new_params, opt_state=opt_state,
                               step=state.step + 1, extras=extras)
        return new_state, metrics, new_buf

    # -- historical per-mode signatures ------------------------------------
    if mode == "push":
        def push_step(state: TrainState, batch: PyTree, lr: jax.Array,
                      W: jax.Array, active: jax.Array
                      ) -> Tuple[TrainState, Dict[str, jax.Array]]:
            new_state, metrics, _ = _core(state, batch, lr, W=W,
                                          active=active)
            return new_state, metrics

        return push_step

    if mode == "overlap":
        def overlap_step(state: TrainState, batch: PyTree, lr: jax.Array,
                         comm_buf
                         ) -> Tuple[TrainState, Dict[str, jax.Array], Any]:
            return _core(state, batch, lr, comm_buf=comm_buf)

        return overlap_step

    def step(state: TrainState, batch: PyTree, lr: jax.Array
             ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        new_state, metrics, _ = _core(state, batch, lr)
        return new_state, metrics

    return step
