"""Training-step builder: per-node forward/backward (vmapped over the node
axis) → per-node optimizer update → communication round (the paper's Alg. 1).

One compiled variant per communication phase — "gossip(shift)", "global",
"none", "slowmo" — dispatched host-side by the schedule (DESIGN.md §2.2), so
each HLO carries exactly the collectives of its phase and cost/collective
analysis per phase is exact.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core import mixing
from repro.core import topology as topo
from repro.models.model import Model
from repro.optim import clip_by_global_norm, make_optimizer
from repro.train.state import TrainState, consensus_distance, debias

PyTree = Any


def _grad_global_norm(grads: PyTree) -> jax.Array:
    """Global L2 norm over all nodes' grads — a cheap on-device monitor
    (one reduction per leaf inside the step; no host sync).  Emitted as
    ``metrics["grad_norm"]`` when monitors are on (DESIGN.md §2.7)."""
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def build_train_step(model: Model, tcfg: TrainConfig, n_nodes: int, *,
                     phase: str, shift_step: int = 0,
                     buf_shift: int = 0,
                     with_consensus: bool = False,
                     unroll: bool = False,
                     mesh: Optional[jax.sharding.Mesh] = None,
                     fault_hops: Optional[Tuple[int, ...]] = None
                     ) -> Callable:
    """Returns step(state, batch, lr) -> (state, metrics).

    ``phase``: "gossip" | "global" | "none" | "slowmo".
    batch leaves carry leading (n_nodes, per_node_batch, …).

    With ``DistConfig.comm_overlap`` the returned step has the 4-arg
    signature ``step(state, batch, lr, comm_buf) -> (state, metrics,
    comm_buf)`` (DESIGN.md §2.6): gossip phases *finish* the in-flight
    round primed one step ago — applying W with ``buf_shift``, the shift
    recorded when the buffer was primed — against the stale buffer, then
    *start* the next round from this step's half-step params; global /
    pod_avg / slowmo phases run synchronously (the period boundary is the
    natural flush) and re-prime the buffer from their result; phase
    "none" passes the buffer through untouched.

    With a ``mesh`` whose node axis is sharded, the pallas comm backend
    routes through the shard_map-aware path (DESIGN.md §2.1 dispatch
    table) — per-shard fused kernels with ppermute halo exchange —
    honoring ``DistConfig.comm_shard_mode``.

    With ``DistConfig.push_sum`` the returned step has the 5-arg signature
    ``step(state, batch, lr, W, active)`` (DESIGN.md §2.5): ``W`` is the
    round's column-stochastic matrix as a **traced** ``(n, n)`` operand —
    fault drops and resampling are new data, never new compiles — and
    ``active`` the ``(n,)`` live mask; dropped nodes' grads are zeroed and
    their params/opt rows frozen.  ``fault_hops`` (from
    ``FaultSchedule.hop_superset``) statically bounds the sharded path's
    halo offsets.
    """
    dist = tcfg.dist
    dist.validate_nodes(n_nodes)
    sharded_comm = mixing.use_sharded_backend(
        dist.comm_backend, mesh, dist.node_axis, dist.comm_shard_mode)
    # the round-invariant knobs, captured once (DESIGN.md §2.1): every
    # communicate call below goes through this spec, so a knob added to
    # CommSpec is forwarded everywhere by construction
    spec = dist.comm_spec(n_nodes, mesh=mesh)
    spec_plain = spec.replace(compressor=None, global_compressor=None)
    # wire compressor (DESIGN.md §2.3): built once at step-build time; the
    # identity compressor routes to the exact uncompressed path inside
    # mixing.communicate, so only a *lossy* compressor changes the step
    compressor = spec.compressor
    lossy_comm = spec.lossy
    # compressed collective for the averaging phases (DESIGN.md §2.3
    # "Compressed collectives"): identity routes to the exact psum path
    # inside mixing, so only a lossy choice changes the step
    global_compressor = spec.global_compressor
    lossy_global = global_compressor is not None and global_compressor.lossy
    opt = make_optimizer(tcfg.optimizer, per_node=True)
    # DistConfig.remat/remat_policy -> blocks.make_remat policy string
    if dist.remat == "none":
        remat_policy = "none"
    elif dist.remat_policy == "dots":
        remat_policy = "dots"
    else:
        remat_policy = "default"

    def node_loss(params, batch):
        return model.loss(params, batch, remat=remat_policy,
                          z_loss=tcfg.z_loss, unroll=unroll)

    def total_loss(params, batch):
        losses, metrics = jax.vmap(node_loss)(params, batch)
        # sum over nodes => grads land per-node, unscaled (paper Alg. 1)
        return jnp.sum(losses), jax.tree.map(jnp.mean, metrics)

    grad_fn = jax.grad(total_loss, has_aux=True)

    def accum_grad_fn(params, batch):
        """Gradient accumulation: split the per-node batch into
        ``tcfg.microbatches`` slices and scan — activation memory drops ~m×
        at unchanged math (equal-size microbatch mean == full-batch mean)."""
        m = tcfg.microbatches

        def to_mb(t):
            n, b = t.shape[:2]
            return t.reshape((n, m, b // m) + t.shape[2:]).swapaxes(0, 1)

        mbs = jax.tree.map(to_mb, batch)

        def body(acc, mb):
            g, met = grad_fn(params, mb)
            acc = jax.tree.map(jnp.add, acc, g)
            return acc, met

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, mets = jax.lax.scan(body, zeros, mbs)
        grads = jax.tree.map(lambda g: g / m, grads)
        return grads, jax.tree.map(jnp.mean, mets)

    if dist.push_sum:
        # static halo superset for the sharded ppermute path: every shift
        # the topology (over its whole period) or the fault schedule's
        # resampling can ever emit — the runtime W only re-weights them
        ps_offsets = None
        if sharded_comm:
            k = mixing.node_shard_count(mesh, dist.node_axis)
            if phase == "global":
                ps_offsets = tuple(range(k))
            else:
                hops = set(fault_hops or ())
                period = max(1, topo.schedule_period(dist.topology, n_nodes))
                for s in range(period):
                    hops |= set(topo.shift_weights(dist.topology, n_nodes, s))
                ps_offsets = mixing.push_sum_shard_offsets(n_nodes, k, hops)
        comm_dtype_ps = spec.comm_dtype

        def freeze_dropped(new: PyTree, old: PyTree,
                           active: jax.Array) -> PyTree:
            """Dropped nodes take no step: revert their node rows (params
            AND optimizer state — a zero grad still decays momentum, which
            would silently train the dead node).  Leaves without a node
            axis (shared counters) pass through."""
            a = active.astype(jnp.bool_)

            def one(nw, od):
                if not hasattr(nw, "ndim") or nw.ndim == 0 \
                        or nw.shape[0] != n_nodes:
                    return nw
                m = a.reshape((n_nodes,) + (1,) * (nw.ndim - 1))
                return jnp.where(m, nw, od)

            return jax.tree.map(one, new, old)

        def push_step(state: TrainState, batch: PyTree, lr: jax.Array,
                      W: jax.Array, active: jax.Array
                      ) -> Tuple[TrainState, Dict[str, jax.Array]]:
            if tcfg.microbatches > 1:
                grads, metrics = accum_grad_fn(state.params, batch)
            else:
                grads, metrics = grad_fn(state.params, batch)
            af = active.astype(jnp.float32)
            grads = jax.tree.map(
                lambda g: g * af.reshape((n_nodes,) + (1,) * (g.ndim - 1)),
                grads)
            if with_consensus:
                metrics = dict(metrics)
                metrics["grad_norm"] = _grad_global_norm(grads)
            if tcfg.optimizer.grad_clip:
                grads = clip_by_global_norm(grads, tcfg.optimizer.grad_clip)
            params_half, opt_state = opt.update(grads, state.opt_state,
                                                state.params, lr)
            params_half = freeze_dropped(params_half, state.params, active)
            opt_state = freeze_dropped(opt_state, state.opt_state, active)
            new_ef = state.ef_state
            new_w = state.push_weight
            if phase == "none" or n_nodes == 1:
                new_params = params_half
            elif lossy_comm and phase == "gossip":
                new_params, new_w, new_ef = mixing.communicate_push_sum(
                    params_half, state.push_weight, W=W, n_nodes=n_nodes,
                    comm_dtype=comm_dtype_ps, backend=dist.comm_backend,
                    mesh=mesh, node_axis=dist.node_axis,
                    shard_mode=dist.comm_shard_mode,
                    model_axis=dist.model_axis,
                    leaf_threshold=dist.pallas_leaf_threshold,
                    offsets=ps_offsets, compressor=compressor,
                    ef_state=state.ef_state, seed=state.step)
            else:
                new_params, new_w = mixing.communicate_push_sum(
                    params_half, state.push_weight, W=W, n_nodes=n_nodes,
                    comm_dtype=comm_dtype_ps, backend=dist.comm_backend,
                    mesh=mesh, node_axis=dist.node_axis,
                    shard_mode=dist.comm_shard_mode,
                    model_axis=dist.model_axis,
                    leaf_threshold=dist.pallas_leaf_threshold,
                    offsets=ps_offsets)
            if phase == "global":
                # a full-participation global round sets every w_i to
                # Σw/n = 1 in exact arithmetic; snap to it so the PGA
                # reset also washes out accumulated fp drift in w
                new_w = jnp.where(jnp.all(active > 0),
                                  jnp.ones_like(new_w), new_w)
            metrics = dict(metrics)
            # the checkable invariant: Σw = n for every column-stochastic
            # round, every fault pattern (DESIGN.md §2.5)
            metrics["mass"] = jnp.sum(new_w.astype(jnp.float32))
            if with_consensus:
                metrics["consensus"] = consensus_distance(
                    debias(new_params, new_w))
            new_state = TrainState(params=new_params, opt_state=opt_state,
                                   step=state.step + 1,
                                   slow_params=state.slow_params,
                                   slow_u=state.slow_u, ef_state=new_ef,
                                   push_weight=new_w)
            return new_state, metrics

        return push_step

    if dist.comm_overlap:
        def overlap_step(state: TrainState, batch: PyTree, lr: jax.Array,
                         comm_buf
                         ) -> Tuple[TrainState, Dict[str, jax.Array], Any]:
            if tcfg.microbatches > 1:
                grads, metrics = accum_grad_fn(state.params, batch)
            else:
                grads, metrics = grad_fn(state.params, batch)
            if with_consensus:
                metrics = dict(metrics)
                metrics["grad_norm"] = _grad_global_norm(grads)
            if tcfg.optimizer.grad_clip:
                grads = clip_by_global_norm(grads, tcfg.optimizer.grad_clip)
            params_half, opt_state = opt.update(grads, state.opt_state,
                                                state.params, lr)
            slow_params, slow_u = state.slow_params, state.slow_u
            new_ef = state.ef_state
            new_buf = comm_buf
            if phase == "none" or n_nodes == 1:
                new_params = params_half
            elif phase == "gossip":
                # finish the round primed one step ago (its shift, not
                # this step's), then immediately issue the next one from
                # this half-step — x_{t+1} = y_t + (W(buf_shift) - I)·y_{t-1}
                new_params = mixing.finish_round(params_half, comm_buf,
                                                 spec, step=buf_shift)
                new_buf, new_ef = mixing.start_round(
                    params_half, spec, ef_state=state.ef_state,
                    seed=state.step)
            elif phase == "slowmo":
                xbar = jax.tree.map(
                    lambda p: jnp.mean(p.astype(jnp.float32), 0),
                    params_half)
                beta, alpha = dist.slowmo_beta, dist.slowmo_lr
                slow_u = jax.tree.map(
                    lambda u, s, xb: beta * u.astype(jnp.float32)
                    + (s.astype(jnp.float32) - xb) / lr,
                    state.slow_u, state.slow_params, xbar)
                slow_params = jax.tree.map(
                    lambda s, u: (s.astype(jnp.float32) - alpha * lr * u
                                  ).astype(s.dtype),
                    state.slow_params, slow_u)
                new_params = jax.tree.map(
                    lambda s, p: jnp.broadcast_to(s[None],
                                                  p.shape).astype(p.dtype),
                    slow_params, params_half)
                new_buf, new_ef = mixing.start_round(
                    new_params, spec, ef_state=state.ef_state,
                    seed=state.step)
                # the dense buffer aliases new_params; copy so the jit
                # outputs (state, comm_buf) never share a buffer — both
                # are donated back to the next step
                new_buf = jax.tree.map(jnp.copy, new_buf)
            else:
                # global / pod_avg: synchronous flush + re-prime
                new_params, new_buf, new_ef = mixing.overlap_flush(
                    params_half, spec, phase=phase, step=shift_step,
                    ef_state=state.ef_state, seed=state.step)
                new_buf = jax.tree.map(jnp.copy, new_buf)
            if with_consensus:
                metrics = dict(metrics)
                metrics["consensus"] = consensus_distance(new_params)
            new_state = TrainState(params=new_params, opt_state=opt_state,
                                   step=state.step + 1,
                                   slow_params=slow_params, slow_u=slow_u,
                                   ef_state=new_ef)
            return new_state, metrics, new_buf

        return overlap_step

    def step(state: TrainState, batch: PyTree, lr: jax.Array
             ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        if tcfg.microbatches > 1:
            grads, metrics = accum_grad_fn(state.params, batch)
        else:
            grads, metrics = grad_fn(state.params, batch)
        if with_consensus:
            metrics = dict(metrics)
            metrics["grad_norm"] = _grad_global_norm(grads)
        if tcfg.optimizer.grad_clip:
            grads = clip_by_global_norm(grads, tcfg.optimizer.grad_clip)
        params_half, opt_state = opt.update(grads, state.opt_state,
                                            state.params, lr)
        slow_params, slow_u = state.slow_params, state.slow_u
        new_ef = state.ef_state
        fused_consensus = None
        if phase == "slowmo":
            xbar = jax.tree.map(lambda p: jnp.mean(p.astype(jnp.float32), 0),
                                params_half)
            beta, alpha = dist.slowmo_beta, dist.slowmo_lr
            slow_u = jax.tree.map(
                lambda u, s, xb: beta * u.astype(jnp.float32)
                + (s.astype(jnp.float32) - xb) / lr,
                state.slow_u, state.slow_params, xbar)
            slow_params = jax.tree.map(
                lambda s, u: (s.astype(jnp.float32) - alpha * lr * u
                              ).astype(s.dtype),
                state.slow_params, slow_u)
            new_params = jax.tree.map(
                lambda s, p: jnp.broadcast_to(
                    s[None], p.shape).astype(p.dtype),
                slow_params, params_half)
        else:
            new_params = None
            lossy_round = (lossy_comm or
                           (lossy_global and phase in ("global", "pod_avg")))
            if (lossy_round and n_nodes > 1
                    and phase in ("gossip", "global", "pod_avg")):
                # compressed round: the SR seed is the absolute step (so
                # rounding is unbiased across steps); consensus falls back
                # to consensus_distance below — residual fusion does not
                # compose with compression (DESIGN.md §2.3)
                new_params, new_ef = mixing.communicate(
                    params_half, spec, phase=phase, step=shift_step,
                    axis=0, ef_state=state.ef_state, seed=state.step)
            elif (dist.comm_backend == "pallas" and with_consensus
                    and n_nodes > 1
                    and phase in ("gossip", "global", "pod_avg")):
                # fused: the mixing kernel emits the consensus residual in
                # the same parameter pass instead of re-reading new_params
                # (bypasses communicate(), so meter the round explicitly)
                mixing.meter_round(params_half, spec_plain, phase=phase,
                                   step=shift_step)
                if sharded_comm:
                    new_params, _xbar, resid = mixing.communicate_sharded(
                        params_half, spec_plain, phase=phase,
                        step=shift_step, with_residual=True)
                else:
                    from repro.kernels import mixing_pallas
                    new_params, _xbar, resid = mixing_pallas.mix_residual(
                        params_half, phase=phase, topology=dist.topology,
                        n_nodes=n_nodes, step=shift_step,
                        comm_dtype=spec.comm_dtype, n_pods=dist.n_pods,
                        leaf_threshold=dist.pallas_leaf_threshold)
                fused_consensus = resid / n_nodes
            if new_params is None:
                new_params = mixing.communicate(
                    params_half, spec_plain, phase=phase, step=shift_step)
        if with_consensus:
            metrics = dict(metrics)
            metrics["consensus"] = (fused_consensus
                                    if fused_consensus is not None
                                    else consensus_distance(new_params))
        new_state = TrainState(params=new_params, opt_state=opt_state,
                               step=state.step + 1, slow_params=slow_params,
                               slow_u=slow_u, ef_state=new_ef)
        return new_state, metrics

    return step


def phases_for_algorithm(algorithm: str) -> Tuple[str, ...]:
    """Which step variants an algorithm needs compiled."""
    return {
        "parallel": ("global",),
        "gossip": ("gossip",),
        "local": ("none", "global"),
        "gossip_pga": ("gossip", "global"),
        "gossip_aga": ("gossip", "global"),
        "slowmo": ("gossip", "slowmo"),
        "hier_pga": ("gossip", "pod_avg", "global"),
    }[algorithm]
