from repro.train.state import (TrainState, consensus_distance,  # noqa: F401
                               stack_for_nodes, stacked_axes, state_axes)
from repro.train.step import (build_train_step,  # noqa: F401
                              phases_for_algorithm)
from repro.train.trainer import Trainer, quick_train  # noqa: F401
