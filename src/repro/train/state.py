"""TrainState pytree + logical-axes helpers for the decentralized layout."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any
def _IS_AXES(x):
    return isinstance(x, tuple)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: PyTree               # stacked: leading node axis
    opt_state: PyTree
    step: jax.Array
    slow_params: Optional[PyTree] = None   # SlowMo outer iterate (unstacked)
    slow_u: Optional[PyTree] = None        # SlowMo slow momentum
    ef_state: Optional[PyTree] = None      # per-node error-feedback memory
                                           # (compressed gossip, DESIGN.md
                                           # §2.3): stacked, fp32, zeros at
                                           # init; updated by the same
                                           # compress call that produces
                                           # the wire payload
    push_weight: Optional[jax.Array] = None
                                           # push-sum weight scalar, (n, 1)
                                           # fp32, ones at init (DESIGN.md
                                           # §2.5): mixed by every
                                           # column-stochastic round along
                                           # with params; readers de-bias
                                           # with debias(params, w).  Σw = n
                                           # is the mass invariant.


def init_push_weight(n_nodes: int) -> jax.Array:
    """Push-sum weights start at 1 on every node (SGP init: Σw = n)."""
    return jnp.ones((n_nodes, 1), jnp.float32)


def debias(params_stacked: PyTree, push_weight: Optional[jax.Array]
           ) -> PyTree:
    """De-biased read ``x/w`` (the push-sum estimate of the true average).

    ``push_weight is None`` (non-push-sum runs) is the identity.  The
    division happens in fp32 and casts back per leaf; w broadcasts over
    each leaf's trailing dims.
    """
    if push_weight is None:
        return params_stacked
    w = push_weight.reshape(-1).astype(jnp.float32)

    def one(p):
        wb = w.reshape((p.shape[0],) + (1,) * (p.ndim - 1))
        return (p.astype(jnp.float32) / wb).astype(p.dtype)

    return jax.tree.map(one, params_stacked)


def stack_for_nodes(tree: PyTree, n_nodes: int) -> PyTree:
    """x_i^(0) identical across nodes (paper Alg. 1 requirement)."""
    return jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (n_nodes,) + p.shape), tree)


def stacked_axes(axes_tree: PyTree) -> PyTree:
    return jax.tree.map(lambda a: ("node",) + tuple(a), axes_tree,
                        is_leaf=_IS_AXES)


def opt_state_axes(opt_name: str, params_axes: PyTree) -> PyTree:
    if opt_name == "sgd":
        return {"momentum": params_axes}
    if opt_name in ("adamw", "lamb"):
        return {"m": params_axes, "v": params_axes, "count": ()}
    raise ValueError(opt_name)


def state_axes(params_axes_stacked: PyTree, opt_name: str,
               slowmo: bool, params_axes_unstacked: PyTree,
               ef: bool = False, push: bool = False) -> TrainState:
    return TrainState(
        params=params_axes_stacked,
        opt_state=opt_state_axes(opt_name, params_axes_stacked),
        step=(),
        slow_params=params_axes_unstacked if slowmo else None,
        slow_u=params_axes_unstacked if slowmo else None,
        ef_state=params_axes_stacked if ef else None,
        push_weight=("node", None) if push else None,
    )


def consensus_distance(params_stacked: PyTree) -> jax.Array:
    """(1/n) Σ_i ‖x_i − x̄‖² summed over all parameters — the paper's
    consensus quantity (§4 Intuition)."""
    def one(p):
        p32 = p.astype(jnp.float32)
        xbar = jnp.mean(p32, axis=0, keepdims=True)
        return jnp.sum(jnp.square(p32 - xbar)) / p.shape[0]
    return sum(one(p) for p in jax.tree.leaves(params_stacked))
