"""TrainState pytree + logical-axes helpers for the decentralized layout.

Algorithm-specific state lives in ``TrainState.extras``, a flat dict whose
entries are declared by ``repro.core.algo`` slot descriptors (SlowMo's
``slow_params``/``slow_u``, GT-PGA's tracker) plus the mode slots the comm
stack owns (``ef_state`` for compressed gossip, ``push_weight`` for
push-sum).  The legacy keyword constructor and read-only attribute
accessors (``state.ef_state`` etc.) are kept so existing call sites and
checkpoints keep working.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

PyTree = Any
def _IS_AXES(x):
    return isinstance(x, tuple)


#: extras keys that the legacy TrainState fields mapped to, accepted as
#: keyword arguments by ``TrainState`` and exposed as attributes.
LEGACY_SLOTS = ("slow_params", "slow_u", "ef_state", "push_weight")


@dataclasses.dataclass(init=False)
class TrainState:
    params: PyTree               # stacked: leading node axis
    opt_state: PyTree
    step: jax.Array
    extras: Dict[str, PyTree]    # algorithm/mode slots (repro.core.algo):
                                 #   slow_params/slow_u — SlowMo outer
                                 #     iterate + slow momentum (unstacked)
                                 #   gt_tracker/gt_prev_grad — GT-PGA
                                 #     tracker recursion (stacked)
                                 #   ef_state — per-node error-feedback
                                 #     memory (compressed gossip, DESIGN.md
                                 #     §2.3): fp32, zeros at init; updated
                                 #     by the same compress call that
                                 #     produces the wire payload
                                 #   push_weight — push-sum weight scalar,
                                 #     (n, 1) fp32, ones at init (DESIGN.md
                                 #     §2.5); readers de-bias with
                                 #     debias(params, w); Σw = n invariant

    def __init__(self, params: PyTree, opt_state: PyTree, step: jax.Array,
                 extras: Optional[Dict[str, PyTree]] = None,
                 slow_params: Optional[PyTree] = None,
                 slow_u: Optional[PyTree] = None,
                 ef_state: Optional[PyTree] = None,
                 push_weight: Optional[jax.Array] = None):
        self.params = params
        self.opt_state = opt_state
        self.step = step
        merged = dict(extras) if extras else {}
        for name, value in zip(LEGACY_SLOTS,
                               (slow_params, slow_u, ef_state, push_weight)):
            if value is not None:
                merged[name] = value
        self.extras = merged

    @property
    def slow_params(self) -> Optional[PyTree]:
        return self.extras.get("slow_params")

    @property
    def slow_u(self) -> Optional[PyTree]:
        return self.extras.get("slow_u")

    @property
    def ef_state(self) -> Optional[PyTree]:
        return self.extras.get("ef_state")

    @property
    def push_weight(self) -> Optional[jax.Array]:
        return self.extras.get("push_weight")

    def with_extras(self, **updates: PyTree) -> "TrainState":
        """Copy with named extras entries replaced (None deletes)."""
        extras = dict(self.extras)
        for name, value in updates.items():
            if value is None:
                extras.pop(name, None)
            else:
                extras[name] = value
        return TrainState(params=self.params, opt_state=self.opt_state,
                          step=self.step, extras=extras)


jax.tree_util.register_dataclass(
    TrainState,
    data_fields=("params", "opt_state", "step", "extras"),
    meta_fields=())


def init_push_weight(n_nodes: int) -> jax.Array:
    """Push-sum weights start at 1 on every node (SGP init: Σw = n)."""
    return jnp.ones((n_nodes, 1), jnp.float32)


def debias(params_stacked: PyTree, push_weight: Optional[jax.Array]
           ) -> PyTree:
    """De-biased read ``x/w`` (the push-sum estimate of the true average).

    ``push_weight is None`` (non-push-sum runs) is the identity.  The
    division happens in fp32 and casts back per leaf; w broadcasts over
    each leaf's trailing dims.
    """
    if push_weight is None:
        return params_stacked
    w = push_weight.reshape(-1).astype(jnp.float32)

    def one(p):
        wb = w.reshape((p.shape[0],) + (1,) * (p.ndim - 1))
        return (p.astype(jnp.float32) / wb).astype(p.dtype)

    return jax.tree.map(one, params_stacked)


def stack_for_nodes(tree: PyTree, n_nodes: int) -> PyTree:
    """x_i^(0) identical across nodes (paper Alg. 1 requirement)."""
    return jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (n_nodes,) + p.shape), tree)


def stacked_axes(axes_tree: PyTree) -> PyTree:
    return jax.tree.map(lambda a: ("node",) + tuple(a), axes_tree,
                        is_leaf=_IS_AXES)


def opt_state_axes(opt_name: str, params_axes: PyTree) -> PyTree:
    if opt_name == "sgd":
        return {"momentum": params_axes}
    if opt_name in ("adamw", "lamb"):
        return {"m": params_axes, "v": params_axes, "count": ()}
    raise ValueError(opt_name)


def state_axes(params_axes_stacked: PyTree, opt_name: str,
               extras: Optional[Dict[str, PyTree]] = None) -> TrainState:
    """Axes tree mirroring a TrainState; ``extras`` axes come from
    ``repro.core.algo.extras_axes`` (slot-driven — no per-algorithm flags)."""
    return TrainState(
        params=params_axes_stacked,
        opt_state=opt_state_axes(opt_name, params_axes_stacked),
        step=(),
        extras=dict(extras) if extras else {},
    )


def consensus_distance(params_stacked: PyTree) -> jax.Array:
    """(1/n) Σ_i ‖x_i − x̄‖² summed over all parameters — the paper's
    consensus quantity (§4 Intuition)."""
    def one(p):
        p32 = p.astype(jnp.float32)
        xbar = jnp.mean(p32, axis=0, keepdims=True)
        return jnp.sum(jnp.square(p32 - xbar)) / p.shape[0]
    return sum(one(p) for p in jax.tree.leaves(params_stacked))
