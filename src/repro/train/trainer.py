"""Host training loop: schedule-driven phase dispatch, AGA feedback,
checkpoint hooks, metrics.

Works in two regimes:
  * CPU simulation (tests/examples): no mesh, n simulated nodes as a stacked
    leading axis on one device.
  * Mesh execution (launch/train.py, dry-run): state/batch sharded by the
    logical-axis rules; same code path, jit called with shardings.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import TrainConfig
from repro.core import topology as topo
from repro.core.schedule import make_schedule
from repro.data import make_stream
from repro.models.model import Model, make_model
from repro.optim import make_optimizer, make_schedule as make_lr
from repro.core import algo as algo_lib
from repro.train.state import TrainState, stack_for_nodes
from repro.train.step import build_train_step

PyTree = Any


class Trainer:
    def __init__(self, tcfg: TrainConfig, n_nodes: int, *,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 with_consensus: bool = False,
                 fault_schedule=None,
                 telemetry: Optional[obs.Telemetry] = None,
                 measure_occupancy: Optional[bool] = None):
        tcfg.dist.validate().validate_nodes(n_nodes)
        if fault_schedule is not None:
            if not tcfg.dist.push_sum:
                raise ValueError(
                    "Trainer: fault injection requires DistConfig."
                    "push_sum=True — only the push-sum weight scalar keeps "
                    "the average unbiased when nodes drop (DESIGN.md §2.5)")
            if fault_schedule.n_nodes != n_nodes:
                raise ValueError(
                    f"Trainer: fault_schedule built for "
                    f"{fault_schedule.n_nodes} nodes, trainer has {n_nodes}")
        self.tcfg = tcfg
        self.n_nodes = n_nodes
        self.mesh = mesh
        self.model = make_model(tcfg.model)
        self.lr_fn = make_lr(tcfg.optimizer)
        self.schedule = make_schedule(tcfg.dist)
        self.period = topo.schedule_period(tcfg.dist.topology, n_nodes)
        self.with_consensus = with_consensus
        self.fault_schedule = fault_schedule
        self.stream = make_stream(tcfg.model, tcfg.data, n_nodes=n_nodes,
                                  global_batch=tcfg.global_batch,
                                  seq_len=tcfg.seq_len)
        self._compiled: Dict[Any, Any] = {}
        # async overlap (DESIGN.md §2.6): the in-flight round's double
        # buffer and the shift it was primed with — host-side trajectory
        # state, primed lazily at run() start (resume == flush: a fresh
        # process re-primes from the checkpointed params)
        self._overlap = tcfg.dist.comm_overlap
        self._comm_buf = None
        self._buf_shift = 0
        # telemetry hub (DESIGN.md §2.7): the default hub preserves the
        # legacy behavior — step records at log boundaries land in an
        # in-memory ring (the .history view) and print via PrettySink;
        # pass a hub with a JsonlSink (launch/train --telemetry-dir) for
        # a persistent stream.  run() installs it as the ambient hub so
        # the mixing-round meters self-report during compiles.
        if telemetry is None:
            telemetry = obs.Telemetry(
                sinks=[obs.RingSink(), obs.PrettySink()])
        elif telemetry.ring() is None:
            telemetry.sinks.append(obs.RingSink())
        telemetry.tags.setdefault("algorithm", tcfg.dist.algorithm)
        self.telemetry = telemetry
        # device-side monitor window: per-step (lr, metrics) DEVICE
        # scalars accumulate here and materialize in ONE batched
        # device_get at log boundaries — never a per-step host sync
        self._pending: deque = deque(maxlen=1024)
        self._phase_counts: Dict[str, int] = {}
        # one-shot occupancy calibration for overlapped runs: costs two
        # extra (non-donating) compiles, so default-on only when a
        # persistent stream is attached (launch/train --telemetry-dir);
        # pass True/False to force either way
        self.measure_occupancy = measure_occupancy
        self._occ_measured = False
        self._sched_live = False   # True once this process advanced the
                                   # schedule (guards the resume reload)
        self._faults_live = False  # same guard for the fault counters

    @property
    def history(self) -> List[Dict[str, float]]:
        """Log-boundary step records — a view over the telemetry ring
        sink (same dicts the JSONL stream carries; the legacy keys
        ``step``/``phase``/``lr``/``time``/``loss``/... are preserved)."""
        ring = self.telemetry.ring()
        return ring.records("step") if ring is not None else []

    # ------------------------------------------------------------------
    def init_state(self, key: jax.Array) -> TrainState:
        params, _axes = self.model.init(key)
        params = stack_for_nodes(params, self.n_nodes)
        opt = make_optimizer(self.tcfg.optimizer, per_node=True)
        opt_state = opt.init(params)
        # algorithm/mode slots (SlowMo anchors, GT tracker, EF memory,
        # push weights) come from the slot descriptors — no per-algorithm
        # branching here
        extras = algo_lib.init_extras(self.tcfg.dist, params, self.n_nodes)
        return TrainState(params=params, opt_state=opt_state,
                          step=jnp.zeros((), jnp.int32), extras=extras)

    # ------------------------------------------------------------------
    def _get_step_fn(self, phase: str, shift: int, buf_shift: int = 0):
        # buf_shift keys the compile cache only for overlapped gossip
        # steps, where it is baked in statically (the W of the round
        # being *finished* — DESIGN.md §2.6); 0 everywhere else.  The
        # algorithm name rides in the key so trainers sharing a cache dict
        # (or a future config swap) can never replay another algorithm's
        # compiled phase
        key = (self.tcfg.dist.algorithm, phase, shift, buf_shift)
        if key not in self._compiled:
            hops = (self.fault_schedule.hop_superset(self.tcfg.dist.topology)
                    if self.fault_schedule is not None else None)
            fn = build_train_step(self.model, self.tcfg, self.n_nodes,
                                  phase=phase, shift_step=shift,
                                  buf_shift=buf_shift,
                                  with_consensus=self.with_consensus,
                                  mesh=self.mesh, fault_hops=hops)
            donate = (0, 3) if self._overlap else (0,)
            self._compiled[key] = jax.jit(fn, donate_argnums=donate)
        return self._compiled[key]

    # ------------------------------------------------------------------
    def _push_round(self, phase: str, k: int, shift: int):
        """Host-side (W, active) for the push-sum step at absolute step
        ``k`` — values only, the compiled step is W-agnostic.  ``advance``
        commits the fault counters (pure elsewhere)."""
        n = self.n_nodes
        if self.fault_schedule is not None:
            fs = self.fault_schedule
            active = fs.advance(k)
            if k in fs.drops:
                self.telemetry.emit("fault", step=k, kind="drop",
                                    nodes=list(fs.drops[k]))
            if k in fs.rejoins:
                self.telemetry.emit("fault", step=k, kind="rejoin",
                                    nodes=list(fs.rejoins[k]))
        else:
            active = np.ones(n, dtype=bool)
        if phase == "gossip":
            if self.fault_schedule is not None:
                W = self.fault_schedule.matrix(self.tcfg.dist.topology, k,
                                               shift_step=shift)
            else:
                W = topo.push_sum_matrix(self.tcfg.dist.topology, n,
                                         step=shift)
        elif phase == "global":
            W = topo.global_push_matrix(n, active)
        else:                       # "none": W is unused by the step
            W = np.eye(n)
        return (jnp.asarray(W, jnp.float32),
                jnp.asarray(active, jnp.float32))

    # ------------------------------------------------------------------
    def run(self, state: TrainState, steps: Optional[int] = None,
            log_every: Optional[int] = None) -> TrainState:
        # install the hub as the ambient one for the whole loop so the
        # mixing-round meters (core/mixing) self-report comm_round
        # records during compiles without plumbing
        with obs.telemetry_scope(self.telemetry):
            return self._run(state, steps, log_every)

    def _run(self, state: TrainState, steps: Optional[int],
             log_every: Optional[int]) -> TrainState:
        tcfg = self.tcfg
        steps = steps if steps is not None else tcfg.steps
        log_every = log_every if log_every is not None else tcfg.log_every
        t0 = time.time()
        # explicit transfer (allowed under a device->host transfer
        # guard); the hot loop below performs ZERO implicit syncs —
        # metrics stay on device until the batched log-boundary fetch
        # repro: allow(RPR001)
        start = int(jax.device_get(state.step))
        # resume-aware: schedule/lr/data keyed on the
        if start > 0 and not self._sched_live:  # absolute step counter —
            # and a stateful schedule (AGA's period counter) is trajectory
            # state too: a fresh process resuming a checkpoint reloads the
            # sidecar written next to it (no-op for stateless schedules or
            # in-process continuation, where the live state is already
            # correct)
            self.load_schedule(step=start)
        self._sched_live = True
        if start > 0 and not self._faults_live \
                and self.fault_schedule is not None:
            self.load_faults(step=start)
        self._faults_live = True
        if self._overlap and self.n_nodes > 1 and self._comm_buf is None:
            # prime the double buffer from the current params (warm-up
            # round mixes x_0 with itself; on resume this is exactly the
            # flush semantics — the stale buffer is not checkpointed)
            from repro.core import mixing
            spec = tcfg.dist.comm_spec(self.n_nodes, mesh=self.mesh)
            impl = algo_lib.get_algorithm(tcfg.dist.algorithm,
                                          caller="Trainer")
            joint = algo_lib.join_payload(
                impl.comm_payload(state.extras, state.params), state.params)
            buf, ef = mixing.start_round(
                joint, spec, ef_state=state.ef_state, seed=start)
            # the dense buffer aliases state.params — copy so donating
            # both state and buffer never hands XLA the same buffer twice
            self._comm_buf = jax.tree.map(jnp.copy, buf)
            if ef is not state.ef_state:
                state = state.with_extras(ef_state=ef)
            self._buf_shift = self.schedule.gossip_shift_step(
                start, self.period)
        for k in range(start, start + steps):
            batch = jax.tree.map(jnp.asarray, self.stream.get_batch(k))
            # advance() commits stateful schedules (AGA's period counter);
            # phase()/peek_phase() stay pure for dryrun/roofline/logging
            phase = (self.schedule.advance(k) if self.n_nodes > 1
                     else "none")
            shift = self.schedule.gossip_shift_step(k, self.period)
            lr = jnp.asarray(self.lr_fn(k), jnp.float32)
            with self.telemetry.span("train/step", step=k,
                                     phase=phase) as sp:
                if self._overlap:
                    bs = self._buf_shift if phase == "gossip" else 0
                    step_fn = self._get_step_fn(phase, shift, buf_shift=bs)
                    state, metrics, self._comm_buf = step_fn(
                        state, batch, lr, self._comm_buf)
                    if phase != "none":
                        # the buffer now in flight was primed at this
                        # step: record its shift for the finish_round
                        # that applies it
                        self._buf_shift = shift
                elif tcfg.dist.push_sum:
                    step_fn = self._get_step_fn(phase, shift)
                    W, active = self._push_round(phase, k, shift)
                    state, metrics = step_fn(state, batch, lr, W, active)
                else:
                    step_fn = self._get_step_fn(phase, shift)
                    state, metrics = step_fn(state, batch, lr)
                # --trace-fence: serialize the pipeline so the span is
                # device time, not async dispatch time
                sp.fence(metrics["loss"])
            # lazily: the schedule holds the DEVICE scalar and
            # materializes it only at period boundaries (explicit
            # device_get in schedule._as_float) — no per-step sync
            self.schedule.observe_loss(k, metrics["loss"])
            self._phase_counts[phase] = self._phase_counts.get(phase, 0) + 1
            self._pending.append((k, phase, lr, metrics))
            if self._overlap and phase not in ("gossip", "none"):
                # period boundary: the compiled step flushed the
                # in-flight round before its collective (DESIGN.md §2.6)
                self.telemetry.emit("flush", step=k, phase=phase)
            if log_every and (k % log_every == 0 or k == steps - 1):
                self._log_boundary(k, phase, t0)
                mo = self.measure_occupancy
                if mo is None:
                    mo = any(isinstance(s, obs.JsonlSink)
                             for s in self.telemetry.sinks)
                if (mo and self._overlap and self.n_nodes > 1
                        and not self._occ_measured and k > start):
                    self._occ_measured = True
                    self._measure_occupancy(state, k)
            if tcfg.ckpt_every and (k + 1) % tcfg.ckpt_every == 0:
                from repro.checkpoint import save_checkpoint
                save_checkpoint(tcfg.ckpt_dir, state, k + 1)
                self._save_schedule(k + 1)
                self._save_faults(k + 1)
                self.telemetry.emit("ckpt", step=k + 1,
                                    path=tcfg.ckpt_dir)
        return state

    # ------------------------------------------------------------------
    def _log_boundary(self, k: int, phase: str, t0: float) -> None:
        """Materialize the device-side monitor window in ONE batched,
        explicit transfer (``Telemetry.fetch``) and emit the ``step``
        record — ring sink (``.history``), pretty print, JSONL."""
        window = list(self._pending)
        self._pending.clear()
        if not window:
            return
        _, _, lr, metrics = window[-1]
        host = self.telemetry.fetch({
            "lr": lr, "metrics": metrics,
            "window_loss": [w[3]["loss"] for w in window]})
        rec = {"step": k, "phase": phase, "lr": float(host["lr"]),
               "time": time.time() - t0}
        rec.update({m: float(v) for m, v in host["metrics"].items()})
        wl = [float(x) for x in host["window_loss"]]
        rec["loss_window_mean"] = sum(wl) / len(wl)
        rec["window"] = len(wl)
        # executed-round counts by phase: joins the traced comm_round
        # records (emitted once per compiled variant) back to reality
        rec["phase_counts"] = dict(self._phase_counts)
        self.telemetry.emit("step", **rec)

    # ------------------------------------------------------------------
    def _measure_occupancy(self, state: TrainState, k: int) -> None:
        """One-shot pipeline-occupancy calibration for overlapped runs
        (DESIGN.md §2.7): time the overlapped step, the comm-free step,
        and a synchronous issue+apply round, then report

            occupancy = clip(1 - max(0, t_overlap - t_compute) / t_sync,
                             0, 1)

        — the fraction of the synchronous round cost hidden under
        compute.  Uses fresh non-donating jits so ``state`` survives;
        runs with the ambient hub scoped out so the probe rounds do not
        spam ``comm_round`` records."""
        try:
            self._measure_occupancy_impl(state, k)
        except Exception as e:   # calibration is best-effort telemetry
            import warnings
            warnings.warn(f"Trainer: occupancy calibration failed ({e}); "
                          f"continuing without an occupancy record")

    def _measure_occupancy_impl(self, state: TrainState, k: int) -> None:
        from repro.core import mixing
        tcfg = self.tcfg
        spec = tcfg.dist.comm_spec(self.n_nodes, mesh=self.mesh)
        shift = self.schedule.gossip_shift_step(k, self.period)
        batch = jax.tree.map(jnp.asarray, self.stream.get_batch(k))
        lr = jnp.asarray(self.lr_fn(k), jnp.float32)

        def build(phase):
            fn = build_train_step(self.model, tcfg, self.n_nodes,
                                  phase=phase, shift_step=shift,
                                  buf_shift=shift,
                                  with_consensus=self.with_consensus,
                                  mesh=self.mesh)
            return jax.jit(fn)   # no donation: timing-only probes

        step_ov, step_cmp = build("gossip"), build("none")
        with obs.telemetry_scope(None):
            t_ov = obs.fenced_time(step_ov, state, batch, lr,
                                   self._comm_buf, iters=3, warmup=1)
            t_cmp = obs.fenced_time(step_cmp, state, batch, lr,
                                    self._comm_buf, iters=3, warmup=1)
            t_issue = obs.fenced_time(
                mixing.start_round, state.params, spec, iters=3,
                warmup=1, ef_state=state.ef_state, seed=k)
            rs, _ = mixing.start_round(state.params, spec,
                                       ef_state=state.ef_state, seed=k)
            t_apply = obs.fenced_time(
                mixing.finish_round, state.params, rs, spec, iters=3,
                warmup=1, step=shift)
        t_sync = t_issue + t_apply
        occ = obs.meters.occupancy(
            t_cmp * 1e-6, t_sync * 1e-6, t_ov * 1e-6)
        self.telemetry.emit(
            "comm_round", phase="gossip", role="occupancy",
            occupancy=occ, t_step_overlap_us=t_ov,
            t_step_compute_us=t_cmp, t_round_sync_us=t_sync,
            topology=tcfg.dist.topology, backend=tcfg.dist.comm_backend,
            n_nodes=self.n_nodes, step=k)

    # ------------------------------------------------------------------
    def _schedule_path(self, step: int) -> str:
        import os
        return os.path.join(self.tcfg.ckpt_dir,
                            f"schedule_{step:08d}.json")

    def _save_schedule(self, step: int) -> None:
        """Sidecar for stateful schedules: AGA's period counter and H
        adaptation are part of the training trajectory, so a resumed run
        must reload them (stateless schedules write nothing)."""
        sd = self.schedule.state_dict()
        if not sd:
            return
        import json
        with open(self._schedule_path(step), "w") as f:
            json.dump(sd, f)

    def load_schedule(self, step: Optional[int] = None) -> None:
        """Restore the schedule's internal state saved alongside the
        checkpoint at ``step`` (default: latest).  Call when resuming a
        stateful-schedule run (gossip_aga) after
        ``checkpoint.restore_checkpoint``; a missing sidecar is a no-op
        (stateless schedules, or checkpoints predating the sidecar)."""
        import json
        import os
        from repro.checkpoint import latest_step
        step = step if step is not None else latest_step(self.tcfg.ckpt_dir)
        if step is None:
            return
        path = self._schedule_path(step)
        if os.path.exists(path):
            with open(path) as f:
                self.schedule.load_state_dict(json.load(f))

    # ------------------------------------------------------------------
    def _faults_path(self, step: int) -> str:
        import os
        return os.path.join(self.tcfg.ckpt_dir, f"faults_{step:08d}.json")

    def _save_faults(self, step: int) -> None:
        """Sidecar for the fault schedule's bookkeeping counters: a
        resumed run must report the same drop/rejoin totals as an
        uninterrupted one (the schedule itself is a pure function of the
        step, so only the counters are trajectory state)."""
        if self.fault_schedule is None:
            return
        import json
        with open(self._faults_path(step), "w") as f:
            json.dump(self.fault_schedule.state_dict(), f)

    def load_faults(self, step: Optional[int] = None) -> None:
        """Restore the fault counters saved alongside the checkpoint at
        ``step`` (default: latest); missing sidecar is a no-op."""
        if self.fault_schedule is None:
            return
        import json
        import os
        from repro.checkpoint import latest_step
        step = step if step is not None else latest_step(self.tcfg.ckpt_dir)
        if step is None:
            return
        path = self._faults_path(step)
        if os.path.exists(path):
            with open(path) as f:
                self.fault_schedule.load_state_dict(json.load(f))


def quick_train(tcfg: TrainConfig, n_nodes: int, steps: int, *,
                seed: int = 0, with_consensus: bool = False) -> Trainer:
    """Convenience: build, init, run — returns the Trainer (with .history)."""
    tr = Trainer(tcfg, n_nodes, with_consensus=with_consensus)
    state = tr.init_state(jax.random.PRNGKey(seed))
    tr.run(state, steps)
    return tr
