"""Three-term roofline analysis from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / peak_FLOP/s           (per chip)
  memory term     = HLO_bytes / HBM_bw                (per chip)
  collective term = collective_bytes / link_bw        (per chip)

``cost_analysis()`` runs on the post-SPMD per-device module, so FLOPs/bytes
are already per chip.  Collective bytes are not in cost_analysis — we parse
the optimized HLO and sum operand shard sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %x = bf16[16,3584]{1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|([a-z0-9\[\],{}\s]*?))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.IGNORECASE)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Per-collective-type byte counts (result shard sizes) + op counts.
    ``-start`` ops are counted, ``-done`` duplicates skipped."""
    per_type: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    counts: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str = m.group(1) or m.group(2) or ""
        kind = m.group(3).lower()
        b = _shape_bytes(shape_str)
        per_type[kind] += b
        counts[kind] += 1
    total = sum(per_type.values())
    return {"total_bytes": total, "per_type_bytes": per_type,
            "counts": counts}


@dataclasses.dataclass
class Roofline:
    flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_detail: Dict[str, Any]
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: Optional[float] = None

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        if self.model_flops is None or self.flops == 0:
            return None
        return self.model_flops / self.flops

    def to_dict(self) -> Dict[str, Any]:
        return {
            "flops": self.flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "coll_per_type": self.coll_detail.get("per_type_bytes"),
            "coll_counts": self.coll_detail.get("counts"),
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def raw_costs(compiled) -> Dict[str, float]:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # older jax: one dict per device
        cost = cost[0] if cost else {}
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll["total_bytes"]),
            "coll_detail": coll}


def from_costs(costs: Dict[str, float], *,
               model_flops: Optional[float] = None) -> Roofline:
    return Roofline(
        flops=costs["flops"], hlo_bytes=costs["bytes"],
        coll_bytes=costs["coll"],
        coll_detail=costs.get("coll_detail", {}),
        compute_s=costs["flops"] / PEAK_FLOPS,
        memory_s=costs["bytes"] / HBM_BW,
        collective_s=costs["coll"] / ICI_BW,
        model_flops=model_flops,
    )


def from_compiled(compiled, *, model_flops: Optional[float] = None
                  ) -> Roofline:
    return from_costs(raw_costs(compiled), model_flops=model_flops)


def scan_corrected_costs(costs_1rep: Dict[str, float],
                         costs_2rep: Dict[str, float],
                         n_reps: int) -> Dict[str, float]:
    """XLA's cost_analysis counts a while-loop (lax.scan) body ONCE regardless
    of trip count, so scanned-layer programs under-report flops/bytes/
    collectives by ~n_reps.  Correct by lowering 1-rep and 2-rep depth
    variants: per-rep cost = c2 − c1; total = c1 + (R−1)·(c2 − c1)."""
    out = {}
    for k in ("flops", "bytes", "coll"):
        per_rep = max(costs_2rep[k] - costs_1rep[k], 0.0)
        out[k] = costs_1rep[k] + (n_reps - 1) * per_rep
    out["coll_detail"] = {
        "total_bytes": out["coll"],
        "per_type_bytes": {
            k: costs_1rep["coll_detail"]["per_type_bytes"].get(k, 0)
            + (n_reps - 1) * max(
                costs_2rep["coll_detail"]["per_type_bytes"].get(k, 0)
                - costs_1rep["coll_detail"]["per_type_bytes"].get(k, 0), 0)
            for k in costs_1rep["coll_detail"]["per_type_bytes"]},
        "counts": costs_1rep["coll_detail"]["counts"],
    }
    return out


# ---------------------------------------------------------------------------
# MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per train step;
# 2·N·D forward-only (prefill); 2·N_active per token (decode).
# ---------------------------------------------------------------------------
def count_params(cfg, active_only: bool = False) -> int:
    """Analytic parameter count from the config (no allocation)."""
    d, V = cfg.d_model, cfg.vocab_size
    total = V * d  # embedding
    if not cfg.tie_embeddings:
        total += d * V
    for mixer, ffn in cfg.layers:
        total += 2 * d  # norms (approx; post-norms ignored)
        if mixer in ("attn", "attn_sw"):
            if cfg.mla is not None:
                m = cfg.mla
                qd = m.nope_head_dim + m.rope_head_dim
                total += d * cfg.n_heads * qd
                total += d * m.kv_lora_rank + d * m.rope_head_dim
                total += m.kv_lora_rank * cfg.n_heads * (
                    m.nope_head_dim + m.v_head_dim)
                total += cfg.n_heads * m.v_head_dim * d
            else:
                hd = cfg.resolved_head_dim
                total += d * cfg.n_heads * hd * 2  # q, o
                total += d * cfg.n_kv_heads * hd * 2  # k, v
        elif mixer == "mamba":
            s = cfg.ssm
            di = s.expand * d
            R = s.dt_rank or max(d // 16, 1)
            total += d * 2 * di + di * (R + 2 * s.d_state) + R * di \
                + di * s.d_state + 2 * di + di * d
        elif mixer == "mlstm":
            s = cfg.ssm
            di = s.mlstm_expand * d
            nh = max(di // (2 * s.mlstm_head_dim), 1)
            dk = s.mlstm_head_dim
            total += d * 2 * di + di * nh * dk * 2 + di * (di // nh) * nh \
                + 2 * di * nh + di * d
        elif mixer == "slstm":
            total += d * 4 * d + 4 * d * (d // cfg.ssm.slstm_heads) + d * d
        if ffn == "dense":
            total += 3 * d * cfg.d_ff
        elif ffn == "moe":
            m = cfg.moe
            n_e = m.top_k if active_only else m.n_routed
            total += d * m.n_routed  # router (always dense compute)
            total += n_e * 3 * d * m.d_ff_expert
            total += m.n_shared * 3 * d * m.d_ff_expert
    return int(total)


def model_flops_for(cfg, shape, n_chips: int) -> float:
    """Per-chip MODEL_FLOPS for one step of the given input shape."""
    n_active = count_params(cfg, active_only=True)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6 if shape.kind == "train" else 2
    return mult * n_active * tokens / n_chips
