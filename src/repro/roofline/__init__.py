from repro.roofline.analysis import (HBM_BW, ICI_BW, PEAK_FLOPS,  # noqa: F401
                                     Roofline, collective_bytes,
                                     count_params, from_compiled,
                                     model_flops_for)
