import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) combination against the production mesh and record memory analysis,
cost analysis, and the collective schedule for the roofline report.

  PYTHONPATH=src python -m repro.launch.dryrun \
      --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun \
      --all --mesh single --out results.json

Train shapes lower BOTH communication phases ("gossip" = Gossip-SGD step with
collective-permute mixing; "global" = the periodic All-Reduce averaging step);
the H-amortized combination is what Gossip-PGA executes (DESIGN.md §2.2).
Decode shapes lower ``serve_step`` — one new token against a seq_len cache.
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compress import round_wire_bytes
from repro.configs import (ASSIGNED_ARCHS, INPUT_SHAPES, DataConfig,
                           DistConfig, OptimizerConfig, TrainConfig,
                           get_model_config)
from repro.core.mixing import model_shard_count, use_sharded_backend
from repro.launch.mesh import make_production_mesh, n_gossip_nodes
from repro.launch.specs import serve_specs, train_specs
from repro.models.model import make_model
from repro.roofline import model_flops_for
from repro.roofline.analysis import (from_costs, raw_costs,
                                     scan_corrected_costs)
from repro.train.step import build_train_step


def _shallow_variants(cfg):
    """(1-rep, 2-rep, n_reps) depth variants for scan-cost correction."""
    p = len(cfg.prefix_pattern)
    L = len(cfg.pattern)
    reps = cfg.n_scan_blocks
    c1 = dataclasses.replace(cfg, n_layers=p + L)
    c2 = dataclasses.replace(cfg, n_layers=p + 2 * L)
    return c1, c2, reps

# long-context eligibility (DESIGN.md §Arch-applicability): SSM/hybrid run as
# is; gemma2 runs its sliding-window variant; other dense/moe/vlm archs and
# the encoder skip.
LONG_OK_FAMILIES = ("ssm", "hybrid")
DECODE_SKIP_FAMILIES = ("encoder",)

# archs whose per-node replicas don't fit 16-way TP: hierarchical mode
# (gossip across pods, FSDP+TP within) + 2D weight sharding when serving.
HIERARCHICAL_ARCHS = ("jamba-1.5-large-398b", "qwen1.5-32b")
SERVE_2D_ARCHS = ("jamba-1.5-large-398b", "qwen1.5-32b",
                  "qwen3-moe-30b-a3b", "llava-next-mistral-7b", "gemma2-9b")


def plan_for(arch: str, shape_name: str) -> Optional[Dict[str, Any]]:
    """What to lower for this (arch, shape) — or None if skipped (+reason)."""
    cfg = get_model_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "decode" and cfg.family in DECODE_SKIP_FAMILIES:
        return {"skip": f"{arch} is encoder-only: no decode step"}
    if shape_name == "long_500k":
        if cfg.family in LONG_OK_FAMILIES:
            pass
        elif arch == "gemma2-9b":
            cfg = get_model_config(arch, long_context=True)
        else:
            return {"skip": f"{arch} is pure full-attention: long_500k "
                            "requires sub-quadratic attention"}
    return {"cfg": cfg, "shape": shape}


def _compile_train(cfg, shape, mesh, *, dist: DistConfig, phase: str,
                   unroll: bool = False, microbatches: int = 1):
    model = make_model(cfg)
    specs = train_specs(cfg, mesh, shape, dist=dist)
    tcfg = TrainConfig(model=cfg, dist=dist, optimizer=OptimizerConfig(),
                       data=DataConfig(), global_batch=shape.global_batch,
                       seq_len=shape.seq_len, microbatches=microbatches)
    step = build_train_step(model, tcfg, specs.n_nodes, phase=phase,
                            unroll=unroll, mesh=mesh)
    with mesh:
        lowered = jax.jit(
            step,
            in_shardings=(specs.state_shardings, specs.batch_shardings,
                          specs.lr_sharding),
            out_shardings=(specs.state_shardings, None),
        ).lower(specs.state_sds, specs.batch_sds, specs.lr_sds)
        compiled = lowered.compile()
    return compiled, specs


def dryrun_train(cfg, shape, mesh, *, dist: DistConfig, phases=("gossip",
                                                                "global"),
                 microbatches: int = 1, fast: bool = False):
    n_chips = mesh.devices.size
    c1_cfg, c2_cfg, reps = _shallow_variants(cfg)
    out: Dict[str, Any] = {"phases": {}}
    for phase in phases:
        t0 = time.time()
        compiled, specs = _compile_train(cfg, shape, mesh, dist=dist,
                                         phase=phase,
                                         microbatches=microbatches)
        compile_s = time.time() - t0
        out["n_nodes"] = specs.n_nodes
        out["mode"] = specs.mode
        # scan-corrected costs from UNROLLED shallow depth variants (a scan
        # body is cost-counted once regardless of trip count)
        costs_full = raw_costs(compiled)
        if fast:
            costs = costs_full   # compile-proof only; costs under-counted
        else:
            comp1, _ = _compile_train(c1_cfg, shape, mesh, dist=dist,
                                      phase=phase, unroll=True,
                                      microbatches=microbatches)
            comp2, _ = _compile_train(c2_cfg, shape, mesh, dist=dist,
                                      phase=phase, unroll=True,
                                      microbatches=microbatches)
            costs = scan_corrected_costs(raw_costs(comp1), raw_costs(comp2),
                                         reps)
        mf = model_flops_for(cfg, shape, n_chips)
        rl = from_costs(costs, model_flops=mf)
        rl_raw = from_costs(costs_full, model_flops=mf)
        mem = compiled.memory_analysis()
        # analytic bytes-on-wire per node per round (DESIGN.md §2.3 cost
        # model): what the configured compressor/wire-dtype puts on the
        # ICI vs the uncompressed fp32 round
        leaf_sizes = [int(np.prod(lf.shape[1:], dtype=np.int64))
                      for lf in jax.tree.leaves(specs.state_sds.params)]
        per_node_params = sum(leaf_sizes)
        wb = round_wire_bytes(
            phase, dist.topology, specs.n_nodes, per_node_params,
            comm_dtype=dist.comm_dtype, compression=dist.comm_compression,
            k=dist.comm_compression_k, n_pods=dist.n_pods,
            leaf_sizes=leaf_sizes,
            global_compression=dist.comm_global_compression)
        wb_fp32 = round_wire_bytes(phase, dist.topology, specs.n_nodes,
                                   per_node_params, n_pods=dist.n_pods)
        # 2-D (node, model) runtime: per-device bytes divide by the model
        # axis only when this run actually routes through the sharded
        # path (same gate mixing uses) — stacked/reference runs keep
        # replicated columns and must not report a phantom reduction
        sharded_comm = use_sharded_backend(
            dist.comm_backend, mesh, dist.node_axis, dist.comm_shard_mode)
        model_shards = model_shard_count(
            mesh, dist.model_axis, dist.node_axis) if sharded_comm else 1
        wb_dev = round_wire_bytes(
            phase, dist.topology, specs.n_nodes, per_node_params,
            comm_dtype=dist.comm_dtype, compression=dist.comm_compression,
            k=dist.comm_compression_k, n_pods=dist.n_pods,
            leaf_sizes=leaf_sizes,
            global_compression=dist.comm_global_compression,
            model_shards=model_shards)
        out["phases"][phase] = {
            "compile_s": compile_s,
            "memory": _mem_dict(mem),
            "roofline": rl.to_dict(),
            "roofline_raw_scan": rl_raw.to_dict(),
            "wire": {"bytes_per_node": wb,
                     "fp32_bytes_per_node": wb_fp32,
                     "model_shards": model_shards,
                     "bytes_per_device": wb_dev,
                     "compression": dist.comm_compression,
                     "global_compression": dist.comm_global_compression,
                     "reduction": (wb_fp32 / wb) if wb else 1.0},
        }
        print(f"    [{phase:6s}] compile {compile_s:6.1f}s  "
              f"flops/chip {rl.flops:.3e}  bytes {rl.hlo_bytes:.3e}  "
              f"coll {rl.coll_bytes:.3e}  dominant={rl.dominant}  "
              f"useful={rl.useful_flops_ratio:.3f}", flush=True)
        print(f"    wire(analytic): {wb:.3e} B/node/round "
              f"({dist.comm_compression}; fp32 {wb_fp32:.3e}, "
              f"reduction {(wb_fp32 / wb) if wb else 1.0:.2f}x)", flush=True)
        print(f"    memory_analysis: {mem}", flush=True)
        print(f"    cost_analysis(scan-corrected): flops={rl.flops:.4e} "
              f"bytes={rl.hlo_bytes:.4e}", flush=True)
    return out


def _compile_serve(cfg, shape, mesh, *, param_sharding: str,
                   context_parallel: Optional[bool] = None,
                   donate_cache: bool = False, unroll: bool = False):
    model = make_model(cfg)
    specs = serve_specs(cfg, mesh, shape, param_sharding=param_sharding,
                        context_parallel=context_parallel)
    if shape.kind == "prefill":
        def prefill_step(params, batch):
            logits, caches, _ = model.forward(params, batch, mode="prefill",
                                              want_cache=True, unroll=unroll)
            return logits, caches

        with mesh:
            lowered = jax.jit(
                prefill_step,
                in_shardings=(specs.params_shardings, specs.batch_shardings),
            ).lower(specs.params_sds, specs.batch_sds)
            compiled = lowered.compile()
    else:
        def serve_step(params, caches, tokens, pos):
            return model.decode_step(params, caches, tokens, pos,
                                     unroll=unroll)

        with mesh:
            lowered = jax.jit(
                serve_step,
                in_shardings=(specs.params_shardings, specs.cache_shardings,
                              specs.tokens_sharding, specs.pos_sharding),
                out_shardings=(None, specs.cache_shardings),
                donate_argnums=(1,) if donate_cache else (),
            ).lower(specs.params_sds, specs.cache_sds, specs.tokens_sds,
                    specs.pos_sds)
            compiled = lowered.compile()
    return compiled, specs


def dryrun_serve(cfg, shape, mesh, *, param_sharding: str,
                 context_parallel: Optional[bool] = None,
                 donate_cache: bool = False, fast: bool = False):
    n_chips = mesh.devices.size
    c1_cfg, c2_cfg, reps = _shallow_variants(cfg)
    kw = dict(param_sharding=param_sharding,
              context_parallel=context_parallel, donate_cache=donate_cache)
    t0 = time.time()
    compiled, specs = _compile_serve(cfg, shape, mesh, **kw)
    compile_s = time.time() - t0
    costs_full = raw_costs(compiled)
    if fast:
        costs = costs_full   # compile-proof only; costs under-counted
    else:
        comp1, _ = _compile_serve(c1_cfg, shape, mesh, unroll=True, **kw)
        comp2, _ = _compile_serve(c2_cfg, shape, mesh, unroll=True, **kw)
        costs = scan_corrected_costs(raw_costs(comp1), raw_costs(comp2), reps)
    mf = model_flops_for(cfg, shape, n_chips)
    rl = from_costs(costs, model_flops=mf)
    rl_raw = from_costs(costs_full, model_flops=mf)
    mem = compiled.memory_analysis()
    print(f"    [{shape.kind:6s}] compile {compile_s:6.1f}s  "
          f"flops/chip {rl.flops:.3e}  bytes {rl.hlo_bytes:.3e}  "
          f"coll {rl.coll_bytes:.3e}  dominant={rl.dominant}", flush=True)
    print(f"    memory_analysis: {mem}", flush=True)
    print(f"    cost_analysis(scan-corrected): flops={rl.flops:.4e} "
          f"bytes={rl.hlo_bytes:.4e}", flush=True)
    return {"mode": specs.mode, "compile_s": compile_s,
            "memory": _mem_dict(mem), "roofline": rl.to_dict(),
            "roofline_raw_scan": rl_raw.to_dict()}


def _mem_dict(mem) -> Dict[str, Any]:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        out[k] = getattr(mem, k, None)
    return out


def run_one(arch: str, shape_name: str, mesh_kind: str, *,
            algorithm: str = "gossip_pga", topology: str = "ring",
            H: int = 6, fast: bool = False, compression: str = "none",
            compression_k: int = 32,
            error_feedback: bool = False,
            global_compression: str = "none") -> Dict[str, Any]:
    plan = plan_for(arch, shape_name)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_kind}
    if plan is None or "skip" in plan:
        rec["skipped"] = plan["skip"]
        print(f"  SKIP: {plan['skip']}", flush=True)
        return rec
    cfg, shape = plan["cfg"], plan["shape"]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    try:
        if shape.kind == "train":
            node_axis = ("pod" if arch in HIERARCHICAL_ARCHS
                         and mesh_kind == "multi" else "data")
            dist = DistConfig(algorithm=algorithm, topology=topology, H=H,
                              node_axis=node_axis,
                              fsdp=arch in HIERARCHICAL_ARCHS,
                              comm_compression=compression,
                              comm_compression_k=compression_k,
                              comm_error_feedback=error_feedback,
                              comm_global_compression=global_compression)
            rec.update(dryrun_train(cfg, shape, mesh, dist=dist, fast=fast))
        else:
            ps = "2d" if arch in SERVE_2D_ARCHS else "tp"
            rec.update(dryrun_serve(cfg, shape, mesh, param_sharding=ps,
                                    fast=fast))
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — recorded, not hidden
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"  FAIL: {rec['error']}", flush=True)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--algorithm", default="gossip_pga")
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--H", type=int, default=6)
    ap.add_argument("--out", default=None, help="append-mode JSONL output")
    ap.add_argument("--fast", action="store_true",
                    help="skip scan-cost correction compiles (compile-proof "
                         "only; roofline costs under-counted for scans)")
    ap.add_argument("--comm-compression", default="none",
                    choices=("none", "identity", "int8", "fp8", "topk",
                             "randk"),
                    help="wire compressor: lowers the compressed comm path "
                         "and feeds the wire-bytes cost model "
                         "(DESIGN.md §2.3)")
    ap.add_argument("--comm-compression-k", type=int, default=32)
    ap.add_argument("--comm-global-compression", default="none",
                    choices=("none", "identity", "int8", "fp8"),
                    help="compressed collective for the global/pod-avg "
                         "phases: the wire record's global-phase row "
                         "reports its real reduction (DESIGN.md §2.3 "
                         "Compressed collectives)")
    ap.add_argument("--error-feedback", action="store_true")
    args = ap.parse_args()

    archs = list(ASSIGNED_ARCHS) if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    results = []
    for mesh_kind in meshes:
        for arch in archs:
            for shape_name in shapes:
                print(f"== {arch} × {shape_name} × {mesh_kind} ==", flush=True)
                rec = run_one(arch, shape_name, mesh_kind,
                              algorithm=args.algorithm,
                              topology=args.topology, H=args.H,
                              fast=args.fast,
                              compression=args.comm_compression,
                              compression_k=args.comm_compression_k,
                              error_feedback=args.error_feedback,
                              global_compression=args.comm_global_compression)
                results.append(rec)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    n_fail = sum(1 for r in results if r.get("ok") is False)
    n_ok = sum(1 for r in results if r.get("ok"))
    n_skip = sum(1 for r in results if "skipped" in r)
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped (documented), "
          f"{n_fail} FAILED")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
