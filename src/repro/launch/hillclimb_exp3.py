import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""exp3 (qwen3-moe gossip communication) — gossip phase only: the global
phase is identical across variants except for the mixing op, so lowering the
gossip step per variant isolates exactly the quantity under test."""
from repro.configs import INPUT_SHAPES, DistConfig, get_model_config
from repro.launch.dryrun import dryrun_train
from repro.launch.hillclimb import OUT, record
from repro.launch.mesh import make_production_mesh


def main() -> None:
    mesh = make_production_mesh(multi_pod=False)
    cfg = get_model_config("qwen3-moe-30b-a3b")
    shape = INPUT_SHAPES["train_4k"]
    print("== exp3: qwen3-moe-30b-a3b train_4k (gossip phase) ==", flush=True)
    for variant, dist, hyp in [
        ("baseline_ring_f32",
         DistConfig(algorithm="gossip_pga", topology="ring", H=6),
         "baseline: ring gossip = 2 collective-permutes of the full fp32 "
         "param set per step"),
        ("one_peer_exp_f32",
         DistConfig(algorithm="gossip_pga", topology="one_peer_exp", H=6),
         "paper-faithful fix (one-peer exponential graph, Assran et al.): "
         "ONE permute per step — predict mixing bytes ~2x down"),
        ("one_peer_exp_bf16",
         DistConfig(algorithm="gossip_pga", topology="one_peer_exp", H=6,
                    comm_dtype="bfloat16"),
         "beyond-paper: bf16 wire on the permute — predict another ~2x"),
    ]:
        rec = dryrun_train(cfg, shape, mesh, dist=dist, phases=("gossip",))
        record("qwen3moe_comm", variant, hyp, rec, OUT)


if __name__ == "__main__":
    main()
