"""Serving launcher: ``python -m repro.launch.serve --arch <id>`` — batched
greedy decoding against the reduced config (CPU) or full config (TPU)."""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_model_config, list_archs
from repro.models import make_model
from repro.serve import BatchedServer, Engine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(list_archs()))
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--s-max", type=int, default=64)
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()

    cfg = get_model_config(args.arch, reduced=not args.full_config)
    if not cfg.causal:
        raise SystemExit(f"{args.arch} is encoder-only: no decode serving")
    model = make_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    server = BatchedServer(Engine(model, s_max=args.s_max), params,
                           n_slots=args.slots)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, size=6),
                    max_new=args.max_new) for i in range(args.requests)]
    for r in sorted(server.run(reqs), key=lambda r: r.uid):
        print(f"req {r.uid}: {list(r.prompt)} -> {r.generated}")


if __name__ == "__main__":
    main()
