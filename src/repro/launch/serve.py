"""Serving launcher: ``python -m repro.launch.serve --arch <id>`` — batched
greedy decoding against the reduced config (CPU) or full config (TPU)."""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_model_config, list_archs
from repro.models import make_model
from repro.serve import BatchedServer, Engine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(list_archs()))
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--s-max", type=int, default=64)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--telemetry-dir", default="",
                    help="write serve_req records (latency, tokens/s) to "
                         "<dir>/telemetry.jsonl")
    ap.add_argument("--trace", default="",
                    help="save a Chrome trace of serve/prefill + "
                         "serve/decode spans to this path")
    args = ap.parse_args()

    cfg = get_model_config(args.arch, reduced=not args.full_config)
    if not cfg.causal:
        raise SystemExit(f"{args.arch} is encoder-only: no decode serving")
    model = make_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    telemetry = None
    if args.telemetry_dir or args.trace:
        import os
        from repro import obs
        sinks = [obs.PrettySink(types=("serve_req",))]
        if args.telemetry_dir:
            os.makedirs(args.telemetry_dir, exist_ok=True)
            sinks.insert(0, obs.JsonlSink(
                os.path.join(args.telemetry_dir, "telemetry.jsonl")))
        telemetry = obs.Telemetry(sinks=sinks)
    server = BatchedServer(Engine(model, s_max=args.s_max), params,
                           n_slots=args.slots, telemetry=telemetry)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, size=6),
                    max_new=args.max_new) for i in range(args.requests)]
    for r in sorted(server.run(reqs), key=lambda r: r.uid):
        print(f"req {r.uid}: {list(r.prompt)} -> {r.generated}")
    if telemetry is not None:
        if args.trace:
            print("trace:", telemetry.tracer.save(args.trace))
        telemetry.close()


if __name__ == "__main__":
    main()
